import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Quickstart: the paper's example code, in this framework.

The paper's §IV-A snippet:

    def foo(env: CylonEnv = None):
        df1 = read_parquet(..., env=env)
        df2 = read_parquet(..., env=env)
        write_parquet(df1.merge(df2, ...), env=env)
    init()
    wait(CylonExecutor(parallelism=4).run_Cylon(foo))

Here: reserve a 4-device gang from the pool, run a distributed merge under
the stateful pseudo-BSP environment, pull the result to the host.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import CylonExecutor, DevicePool
from repro.dataframe import join

rng = np.random.default_rng(0)
N = 20_000
left = {"k": rng.integers(0, 5000, N).astype(np.int32),
        "x": rng.random(N).astype(np.float32)}
right = {"k": rng.integers(0, 5000, N).astype(np.int32),
         "y": rng.random(N).astype(np.float32)}


def foo(env, df1, df2):
    """User code sees the communicator-bearing env + local Table views."""
    out, l_stats, r_stats = join(df1, df2, env.comm, on="k",
                                 out_capacity=df1.capacity * 8)
    return out, l_stats.send_dropped


executor = CylonExecutor(parallelism=4, pool=DevicePool())
from repro.core import DistTable  # noqa: E402

df1 = DistTable.from_numpy(left, executor.parallelism)
df2 = DistTable.from_numpy(right, executor.parallelism)

result, dropped = executor.run_cylon(foo, df1, df2)
rows = result.to_numpy()
print(f"gang parallelism : {executor.parallelism}")
print(f"joined rows      : {len(rows['k'])}")
print(f"dropped (capacity): {int(np.asarray(dropped).sum())}")
print({k: v[:5] for k, v in rows.items()})

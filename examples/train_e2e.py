import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""End-to-end §IV-C: DDF preprocessing application -> CylonStore ->
distributed training application (~100M-param llama-family model).

Two "applications" on separate gang reservations of the same pool:
  1. preprocessing: dedup -> quality filter -> weights join -> sample-based
     balance, producing the training corpus into the CylonStore,
  2. training: gets the corpus (repartitioning to its own parallelism),
     packs batches, and trains a ~100M-param model for a few hundred steps
     under FSDP+SP sharding with checkpointing.

  PYTHONPATH=src python examples/train_e2e.py [--steps 300]
"""

import argparse
import time

import jax
from repro import compat
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import CylonExecutor, CylonStore, DevicePool
from repro.data import (CorpusConfig, batches_from_table, preprocess,
                        source_weights, synth_corpus)
from repro.launch.mesh import make_local_mesh, rules_for_mesh
from repro.models.config import ModelConfig
from repro.train import AdamWConfig, init_train_state, make_train_step
from repro.train.step import state_specs

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
args = ap.parse_args()

# ~100M params: 8L x 768d, llama-style
CFG = ModelConfig(name="llama-100m", family="dense", num_layers=8,
                  d_model=768, num_heads=12, num_kv_heads=4, d_ff=2048,
                  vocab_size=32000, head_dim=64, tie_embeddings=True)

pool = DevicePool()
prep_gang = CylonExecutor(parallelism=4, pool=pool)
store = CylonStore()

t0 = time.time()
corpus = synth_corpus(CorpusConfig(num_docs=8192, payload_tokens=args.seq,
                                   vocab_size=CFG.vocab_size),
                      prep_gang.parallelism)
weights = source_weights(8, prep_gang.parallelism)
preprocess(prep_gang, corpus, weights, store=store)
print(f"[prep] gang={prep_gang.parallelism} done in {time.time() - t0:.1f}s")

# training application on the full mesh (8 devices, data x model = 4 x 2)
table = store.get("train_corpus", target_parallelism=8)
mesh = make_local_mesh(8, model=2)
rules = rules_for_mesh(mesh)
batches = batches_from_table(table, args.batch, args.seq)

opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
state = init_train_state(jax.random.PRNGKey(0), CFG, jnp.bfloat16)
specs = state_specs(CFG, rules)
state = jax.tree_util.tree_map(
    lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), state, specs,
    is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P))

n_params = sum(x.size for x in jax.tree_util.tree_leaves(state["params"]))
print(f"[train] params={n_params / 1e6:.1f}M mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

step_fn = jax.jit(make_train_step(CFG, opt, rules, ce_chunk=128))
losses = []
with compat.set_mesh(mesh):
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {losses[-1]:8.4f} "
                  f"({time.time() - t0:.2f}s/step)", flush=True)

first, last = np.mean(losses[:10]), np.mean(losses[-10:])
print(f"[result] loss {first:.3f} -> {last:.3f} "
      f"({'OK: improved' if last < first - 0.5 else 'WARN: flat'})")

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Batched serving example: prefill + synchronized decode on a small model.

Loads a reduced qwen3-family config, runs batched generation (greedy and
sampled), and verifies the decode path against a full-forward replay.

  PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import transformer
from repro.serve import ServeEngine

cfg = get_smoke_config("qwen3-8b")
params = transformer.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)

B, S0, NEW = 8, 32, 48
engine = ServeEngine(cfg, params, cache_len=S0 + NEW)
rng = np.random.default_rng(0)
prompts = rng.integers(0, cfg.vocab_size, (B, S0)).astype(np.int32)

t0 = time.time()
res = engine.generate(prompts, max_new_tokens=NEW, temperature=0.0)
dt = time.time() - t0
print(f"greedy: {B}x{res.steps} tokens in {dt:.2f}s "
      f"({B * res.steps / dt:.1f} tok/s incl. compile)")

t0 = time.time()
res2 = engine.generate(prompts, max_new_tokens=NEW, temperature=0.8, seed=1)
dt = time.time() - t0
print(f"sampled: {B}x{res2.steps} tokens in {dt:.2f}s "
      f"({B * res2.steps / dt:.1f} tok/s cached)")

# verify: greedy decode must match argmax of a full forward at each step
full = np.concatenate([prompts, res.tokens[:, :, 0]
                       if res.tokens.ndim == 3 else res.tokens], axis=1)
h, _ = transformer.forward(params, cfg, {"tokens": jnp.asarray(full)})
w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
logits = jnp.einsum("bsd,vd->bsv", h, w.astype(jnp.bfloat16)
                    ).astype(jnp.float32)
ok = True
for t in range(res.steps):
    expect = np.asarray(jnp.argmax(logits[:, S0 - 1 + t], -1))
    got = res.tokens[:, t, 0] if res.tokens.ndim == 3 else res.tokens[:, t]
    ok &= bool((expect == got).all())
print(f"greedy decode == full-forward argmax replay: {ok}")

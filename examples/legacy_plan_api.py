import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Legacy imperative ``Plan`` builder API (kept for reference).

The lazy DataFrame frontend (``examples/pipeline_ops.py``,
``examples/planner_explain.py``) is the recommended entry point since
PR 4; this example shows the underlying builder the frontend lowers to —
the two are bit-identical on the same pipeline.  Typed expressions work
here too (``.filter(col("v0") > 0)``); the callable forms
(``.filter(lambda t: ...)``, ``.map_columns``) still run but emit
``DeprecationWarning``.

  PYTHONPATH=src python examples/legacy_plan_api.py
"""

import numpy as np

import repro.df as rdf
from repro.core import CylonEnv, DistTable, Plan, execute
from repro.expr import col

rng = np.random.default_rng(0)
N = 20_000
left = {"k": rng.integers(0, int(N * 0.9), N).astype(np.int32),
        "v0": rng.integers(0, 256, N).astype(np.float32)}
right = {"k": rng.integers(0, int(N * 0.9), N).astype(np.int32),
         "w": rng.integers(0, 256, N).astype(np.float32)}

env = CylonEnv()
lt = DistTable.from_numpy(left, env.parallelism)
rt = DistTable.from_numpy(right, env.parallelism)
tables = {"l": lt, "r": rt}

plan = (Plan.scan("l")
        .join(Plan.scan("r"), on="k", out_capacity=lt.capacity * 4)
        .filter(col("w") > 4)
        .groupby(["k"], {"v0": ["sum", "mean"]})
        .sort(["k"])
        .add_scalar(1.0, cols=["v0_sum"]))
print(plan.explain(tables))
out = execute(plan, env, tables).to_numpy()
print(f"rows={len(out['k'])}")

# the frontend path is the same physical plan, bit-for-bit
front = (rdf.from_table(lt).merge(rdf.from_table(rt), on="k",
                                  out_capacity=lt.capacity * 4)
         [col("w") > 4]
         .groupby("k").agg({"v0": ["sum", "mean"]})
         .sort_values("k")
         .assign(v0_sum=col("v0_sum") + 1.0))
got = front.collect(env=env).to_numpy()
identical = all(np.array_equal(out[c], got[c]) for c in out)
print(f"frontend == builder (bit-identical): {identical}")
assert identical

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Paper Fig 9's operator pipeline via the lazy DataFrame frontend.

Ordinary dataframe code — merge -> groupby.agg -> sort_values — while the
planner + pseudo-BSP execution run underneath, in three modes:
  bsp        one compiled BSP program (CylonFlow),
  bsp_staged one dispatch per communication stage,
  amt        per-operator dispatch + allgather shuffle (Dask-DDF-style),
with wall-time comparison and result parity check.

(The same pipeline written against the imperative ``Plan`` builder lives
in ``examples/legacy_plan_api.py``.)

  PYTHONPATH=src python examples/pipeline_ops.py
"""

import time

import numpy as np

import repro.df as rdf
from repro.core import DistTable
from repro.expr import col

rng = np.random.default_rng(0)
N = 50_000
left = {"k": rng.integers(0, int(N * 0.9), N).astype(np.int32),
        "v0": rng.random(N).astype(np.float32)}
right = {"k": rng.integers(0, int(N * 0.9), N).astype(np.int32),
         "w": rng.random(N).astype(np.float32)}

with rdf.session() as env:
    lt = DistTable.from_numpy(left, env.parallelism)
    l = rdf.from_table(lt)
    r = rdf.read_numpy(right)

    out = (l.merge(r, on="k", out_capacity=lt.capacity * 4)
           .groupby("k").agg({"v0": ["sum", "mean"]})
           .sort_values("k")
           .assign(v0_sum=col("v0_sum") + 1.0))
    print(f"plan stages (1 + comm boundaries): {out.num_stages()}")

    results = {}
    for mode in ("bsp", "bsp_staged", "amt"):
        t0 = time.perf_counter()
        res = out.collect(mode=mode)
        dt0 = time.perf_counter() - t0          # includes compile
        t0 = time.perf_counter()
        res = out.collect(mode=mode)
        dt = time.perf_counter() - t0           # cached program (stateful env)
        results[mode] = res.to_numpy()
        print(f"{mode:10s} first={dt0:7.3f}s cached={dt:7.3f}s "
              f"rows={len(results[mode]['k'])}")

bsp, amt = results["bsp"], results["amt"]
parity = all(np.allclose(np.sort(bsp[c]), np.sort(amt[c]), rtol=1e-4)
             for c in bsp)
print(f"bsp == amt results: {parity}")
assert parity

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Paper Fig 9's operator pipeline under three execution modes.

join -> groupby -> sort -> add_scalar executed as
  bsp        one compiled BSP program (CylonFlow),
  bsp_staged one dispatch per communication stage,
  amt        per-operator dispatch + allgather shuffle (Dask-DDF-style),
with wall-time comparison and result parity check.

  PYTHONPATH=src python examples/pipeline_ops.py
"""

import time

import numpy as np

from repro.core import CylonEnv, DistTable, Plan, execute

rng = np.random.default_rng(0)
N = 50_000
left = {"k": rng.integers(0, int(N * 0.9), N).astype(np.int32),
        "v0": rng.random(N).astype(np.float32)}
right = {"k": rng.integers(0, int(N * 0.9), N).astype(np.int32),
         "w": rng.random(N).astype(np.float32)}

env = CylonEnv()
lt = DistTable.from_numpy(left, env.parallelism)
rt = DistTable.from_numpy(right, env.parallelism)

plan = (Plan.scan("l")
        .join(Plan.scan("r"), on="k", out_capacity=lt.capacity * 4)
        .groupby(["k"], {"v0": ["sum", "mean"]})
        .sort(["k"])
        .add_scalar(1.0, cols=["v0_sum"]))
print(f"plan stages (1 + comm boundaries): {plan.num_stages()}")

results = {}
for mode in ("bsp", "bsp_staged", "amt"):
    t0 = time.perf_counter()
    out = execute(plan, env, {"l": lt, "r": rt}, mode=mode)
    dt0 = time.perf_counter() - t0          # includes compile
    t0 = time.perf_counter()
    out = execute(plan, env, {"l": lt, "r": rt}, mode=mode)
    dt = time.perf_counter() - t0           # cached program (stateful env)
    results[mode] = out.to_numpy()
    print(f"{mode:10s} first={dt0:7.3f}s cached={dt:7.3f}s "
          f"rows={len(results[mode]['k'])}")

bsp, amt = results["bsp"], results["amt"]
parity = all(np.allclose(np.sort(bsp[c]), np.sort(amt[c]), rtol=1e-4)
             for c in bsp)
print(f"bsp == amt results: {parity}")

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Query-optimizer demo: EXPLAIN + optimized vs unoptimized execution.

Builds the paper's Fig-9 pipeline with a dead column and a pushable filter,
prints both EXPLAIN plans (showing which rules fired), then executes both
and compares wall-clock, shuffle volume, and result parity.

  PYTHONPATH=src python examples/planner_explain.py
"""

import time

import numpy as np

from repro.core import CylonEnv, DistTable, Plan, execute

rng = np.random.default_rng(0)
N = 50_000
left = {"k": rng.integers(0, int(N * 0.9), N).astype(np.int32),
        "v0": rng.random(N).astype(np.float32),
        "junk": rng.random(N).astype(np.float32)}   # never used downstream
right = {"k": rng.integers(0, int(N * 0.9), N).astype(np.int32),
         "w": rng.random(N).astype(np.float32)}

env = CylonEnv()
lt = DistTable.from_numpy(left, env.parallelism)
rt = DistTable.from_numpy(right, env.parallelism)
cap = lt.capacity

plan = (Plan.scan("l")
        .join(Plan.scan("r"), on="k", out_capacity=cap * 4,
              bucket_capacity=cap, shuffle_out_capacity=cap * 2)
        .filter(lambda t: t.col("k") % 2 == 0, cols=["k"])
        .groupby(["k"], {"v0": ["sum", "mean"]}, bucket_capacity=cap * 4)
        .sort(["k"], bucket_capacity=cap * 4)
        .add_scalar(1.0, cols=["v0_sum"]))

tables = {"l": lt, "r": rt}
print("================ EXPLAIN (unoptimized) ================")
print(plan.explain(tables, optimize=False))
print()
print("================ EXPLAIN (optimized) ==================")
print(plan.explain(tables))
print()

results = {}
for opt in (False, True):
    tag = "optimized" if opt else "unoptimized"
    t0 = time.perf_counter()
    out, stats = execute(plan, env, tables, mode="bsp", optimize=opt,
                         collect_stats=True)
    first = time.perf_counter() - t0
    t0 = time.perf_counter()
    out, stats = execute(plan, env, tables, mode="bsp", optimize=opt,
                         collect_stats=True)
    cached = time.perf_counter() - t0
    results[tag] = out.to_numpy()
    print(f"{tag:12s} first={first:7.3f}s cached={cached:7.3f}s "
          f"stages={stats.num_stages} shuffles={stats.num_shuffles} "
          f"rows_shuffled={stats.rows_shuffled} "
          f"bytes_shuffled={stats.bytes_shuffled}")

a, b = results["unoptimized"], results["optimized"]
identical = all(np.array_equal(a[c], b[c]) for c in a)
print(f"\noptimized == unoptimized results (bit-identical): {identical}")

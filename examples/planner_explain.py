import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Query-optimizer demo: EXPLAIN + optimized vs unoptimized execution,
written against the lazy DataFrame frontend.

Builds the paper's Fig-9 pipeline with a dead column, a conjunction
filter whose sides split across the join inputs, and a derived column,
prints both EXPLAIN plans (expressions pretty-printed — no <lambda>
placeholders), then executes both and compares wall-clock, shuffle
volume, and result parity.

  PYTHONPATH=src python examples/planner_explain.py
"""

import time

import numpy as np

import repro.df as rdf
from repro.core import DistTable
from repro.expr import col

rng = np.random.default_rng(0)
N = 50_000
left = {"k": rng.integers(0, int(N * 0.9), N).astype(np.int32),
        "v0": rng.random(N).astype(np.float32),
        "junk": rng.random(N).astype(np.float32)}   # never used downstream
right = {"k": rng.integers(0, int(N * 0.9), N).astype(np.int32),
         "w": rng.random(N).astype(np.float32)}

with rdf.session() as env:
    lt = DistTable.from_numpy(left, env.parallelism)
    rt = DistTable.from_numpy(right, env.parallelism)
    cap = lt.capacity
    l, r = rdf.from_table(lt), rdf.from_table(rt)

    # one conjunction: k-side pushes below the shuffle boundaries, w-side
    # into the join's right input — each conjunct routed independently
    out = (l.merge(r, on="k", out_capacity=cap * 4, bucket_capacity=cap,
                   shuffle_out_capacity=cap * 2)
           [(col("k") % 2 == 0) & (col("w") > 0.05)]
           .assign(vw=col("v0") * col("w"))
           .groupby("k", bucket_capacity=cap * 4)
           .agg({"vw": ["sum", "mean"]})
           .sort_values("k", bucket_capacity=cap * 4))

    print("================ EXPLAIN (unoptimized) ================")
    print(out.explain(optimize=False))
    print()
    print("================ EXPLAIN (optimized) ==================")
    print(out.explain())
    print()

    results = {}
    for opt in (False, True):
        tag = "optimized" if opt else "unoptimized"
        t0 = time.perf_counter()
        res, stats = out.collect(mode="bsp", optimize=opt,
                                 collect_stats=True)
        first = time.perf_counter() - t0
        t0 = time.perf_counter()
        res, stats = out.collect(mode="bsp", optimize=opt,
                                 collect_stats=True)
        cached = time.perf_counter() - t0
        results[tag] = res.to_numpy()
        print(f"{tag:12s} first={first:7.3f}s cached={cached:7.3f}s "
              f"stages={stats.num_stages} shuffles={stats.num_shuffles} "
              f"rows_shuffled={stats.rows_shuffled} "
              f"bytes_shuffled={stats.bytes_shuffled}")

a, b = results["unoptimized"], results["optimized"]
identical = all(np.array_equal(a[c], b[c]) for c in a)
print(f"\noptimized == unoptimized results (bit-identical): {identical}")
assert identical

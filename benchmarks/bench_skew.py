"""Adaptive skew mitigation (``repro.adapt``) vs the blind baseline.

Three key distributions — uniform, Zipf(1.5), and the 99%-one-key table —
through the two skew-sensitive operators (raw groupby, hash join), with
``adaptive=`` on and off:

* **out-of-core morsel path** (the headline): on skewed keys the
  non-adaptive run overflows the hot rank's working capacity and burns
  degrade replays (each a fresh compile at new shapes); salting routes the
  hot key across the gang and the segment passes once.  Uniform keys
  measure the pure detection overhead instead (driver-side sampling),
  which must stay within noise.
* **in-core BSP path**: with capacities sized to survive the hot rank,
  the unsalted run still serializes on it (BSP lockstep waits for the
  hottest rank); salting levels the gang.

Every timed pair is also checked bit-identical (adaptive on == off ==
exact numpy oracle via sorted records) with zero dropped rows, and the
zero-new-compile-keys invariant of ``adaptive=False`` is asserted, so the
emitted numbers are parity-backed.  Standalone entry point writes the
committed artifact::

    PYTHONPATH=src python -m benchmarks.bench_skew   # BENCH_pr10_skew.json
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core import CylonEnv, DistTable, Plan, execute

from .common import record, time_fn

HOT = 7


def _dataset(kind: str, rows: int, rng) -> dict:
    if kind == "uniform":
        k = rng.integers(0, max(1, rows), rows).astype(np.int32)
    elif kind == "zipf":
        ranks = np.arange(1, 1001, dtype=np.float64)
        probs = ranks ** -1.5
        k = rng.choice(1000, size=rows, p=probs / probs.sum()).astype(np.int32)
    elif kind == "one_key":
        k = np.where(rng.random(rows) < 0.99, HOT,
                     rng.integers(0, 1000, rows)).astype(np.int32)
    else:
        raise ValueError(kind)
    return {"k": k, "v": rng.integers(0, 100, rows).astype(np.float32)}


def _sorted_records(d):
    cols = sorted(d)
    order = np.lexsort(tuple(np.asarray(d[c]) for c in reversed(cols)))
    return {c: np.asarray(d[c])[order] for c in cols}


def _assert_pair_identical(a, b, label):
    a, b = _sorted_records(a), _sorted_records(b)
    assert sorted(a) == sorted(b), label
    for c in a:
        np.testing.assert_array_equal(a[c], b[c], err_msg=label)


def run(rows: int = 160_000) -> None:
    n_dev = len(jax.devices())
    p = min(8, n_dev)
    env = CylonEnv(jax.devices()[:p])
    rng = np.random.default_rng(42)
    morsel = max(8, -(-(rows // p // 8) // 8) * 8)     # 8 morsels/rank
    build = {"k": np.arange(64, dtype=np.int32),
             "w": rng.integers(0, 100, 64).astype(np.float32)}
    gplan = Plan.scan("t").groupby(["k"], {"v": ["sum", "count"]},
                                   pre_aggregate=False)
    jplan = Plan.scan("t").join(Plan.scan("r"), on="k",
                                out_capacity=rows + 8192)
    speed = {}
    for kind in ("uniform", "zipf", "one_key"):
        data = _dataset(kind, rows, rng)
        for qname, plan, tables in (("groupby", gplan, {"t": data}),
                                    ("join", jplan,
                                     {"t": data, "r": build})):
            outs, stats = {}, {}
            for adaptive in (False, True):
                def do(a=adaptive, pl=plan, tb=tables):
                    out, st = execute(pl, env, dict(tb), optimize=False,
                                      collect_stats=True, adaptive=a,
                                      morsel_rows=morsel,
                                      capacity_factor=2.0)
                    do.last = (out, st)
                    return out
                secs = time_fn(do, warmup=1, iters=3)
                out, st = do.last
                assert st.rows_dropped == 0, (kind, qname, adaptive)
                outs[adaptive] = out.to_numpy()
                stats[adaptive] = st
                record("skew_morsel", f"{kind}_{qname}_"
                       f"{'adaptive' if adaptive else 'baseline'}_p{p}",
                       secs, parallelism=p, rows=rows, dataset=kind,
                       query=qname, adaptive=adaptive,
                       morsel_rows=morsel, degraded=st.degraded,
                       salted_shuffles=st.salted_shuffles,
                       autotune_steps=st.autotune_steps,
                       rows_dropped=st.rows_dropped)
                speed[(kind, qname, adaptive)] = secs
            _assert_pair_identical(outs[False], outs[True],
                                   f"{kind}/{qname}")
            if kind == "one_key":
                assert stats[True].salted_shuffles >= 1, qname
            ratio = speed[(kind, qname, False)] / speed[(kind, qname, True)]
            record("skew_morsel", f"{kind}_{qname}_speedup_p{p}", ratio,
                   parallelism=p, rows=rows, dataset=kind, query=qname,
                   note="baseline/adaptive wall ratio, not seconds",
                   parity="bit-identical", rows_dropped=0)

    # oracle spot-check on the skewed groupby (sums are exact in f32)
    data = _dataset("one_key", rows, rng)
    out = execute(gplan, env, {"t": data}, optimize=False,
                  morsel_rows=morsel, adaptive=True).to_numpy()
    got = _sorted_records({c: out[c] for c in ("k", "v_sum", "v_count")})
    uk = np.unique(data["k"])
    np.testing.assert_array_equal(got["k"], uk)
    np.testing.assert_array_equal(
        got["v_sum"],
        np.array([data["v"][data["k"] == k].sum() for k in uk], np.float32))

    # in-core BSP: capacities sized for the hot rank so the unsalted run
    # completes in-core — the remaining delta is lockstep serialization
    caps = dict(bucket_capacity=rows + 8192, out_capacity=rows + 8192)
    gplan_cap = Plan.scan("t").groupby(["k"], {"v": ["sum", "count"]},
                                       pre_aggregate=False, **caps)
    for kind in ("uniform", "one_key"):
        data = _dataset(kind, rows, rng)
        t = DistTable.from_numpy(data, p, capacity=2 * (rows // p))
        for adaptive in (False, True):
            def do(a=adaptive, tb=t):
                out, st = execute(gplan_cap, env, {"t": tb},
                                  mode="bsp_staged", optimize=False,
                                  collect_stats=True, adaptive=a)
                do.last = st
                return out
            secs = time_fn(do, warmup=2, iters=5)
            st = do.last
            assert st.rows_dropped == 0 and st.degraded == 0
            record("skew_incore", f"{kind}_groupby_"
                   f"{'adaptive' if adaptive else 'baseline'}_p{p}",
                   secs, parallelism=p, rows=rows, dataset=kind,
                   adaptive=adaptive, salted_shuffles=st.salted_shuffles)
            speed[("incore", kind, adaptive)] = secs
        record("skew_incore", f"{kind}_groupby_overhead_ratio_p{p}",
               speed[("incore", kind, True)] / speed[("incore", kind, False)],
               parallelism=p, rows=rows, dataset=kind,
               note="adaptive/baseline wall ratio, not seconds")

    # zero-new-compile-keys invariant with the knob off
    execute(gplan_cap, env, {"t": t}, mode="bsp_staged", optimize=False,
            adaptive=False, collect_stats=True)
    baseline_keys = set(env._cache)
    execute(gplan_cap, env, {"t": t}, mode="bsp_staged", optimize=False,
            adaptive=False, collect_stats=True)
    new_keys = len(set(env._cache) - baseline_keys)
    assert new_keys == 0, "adaptive=False minted compile-cache keys"
    record("skew_invariants", f"adaptive_off_new_cache_keys_p{p}",
           0.0, parallelism=p, new_keys=new_keys,
           note="count not seconds; must be 0")


def main() -> None:
    import argparse

    from .common import dump_json
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=160_000)
    ap.add_argument("--json", default="BENCH_pr10_skew.json")
    args = ap.parse_args()
    run(args.rows)
    path = dump_json(args.json, meta={"bench": "skew", "rows": args.rows})
    print(f"json -> {path}")


if __name__ == "__main__":
    main()

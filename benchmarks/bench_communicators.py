"""Paper Fig 7: communicator backends (OpenMPI vs Gloo vs UCX/UCC).

The modular-communicator reproduction: the same distributed join executed
with the ``xla`` (vendor-tuned), ``ring`` (Gloo-analogue), and ``bruck``
(UCC-analogue) collective schedules, at increasing parallelism.
"""

from __future__ import annotations

import jax

from repro.comm import available_communicators
from repro.core import CylonEnv, DistTable
from repro.dataframe import join

from .common import make_table_data, record, time_fn


def run(rows_per_rank: int = 50_000) -> None:
    n_dev = len(jax.devices())
    sizes = [p for p in (2, 4, 8) if p <= n_dev]
    for p in sizes:
        rows = rows_per_rank * p
        ld, rd = make_table_data(rows, seed=0), make_table_data(rows, seed=1)
        for name in available_communicators():
            env = CylonEnv(jax.devices()[:p], communicator=name)
            lt = DistTable.from_numpy(ld, p)
            rt = DistTable.from_numpy(rd, p)

            def do(l=lt, r=rt, e=env):
                def prog(ctx, a, b):
                    out, *_ = join(a, b, ctx.comm, on="k",
                                   out_capacity=a.capacity * 4)
                    return out
                return e.run(prog, l, r, key=("bench", p)).row_counts

            record("communicators(Fig7)", f"{name}_p{p}", time_fn(do),
                   parallelism=p, rows=rows, backend=name)

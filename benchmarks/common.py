"""Shared benchmark utilities (timing, data generation, CSV output).

Benchmarks run on the CPU backend with 8 placeholder devices (set by
``benchmarks.run`` before jax initializes).  Wall times on CPU measure
*relative* behaviour (scaling shape, schedule overheads, dispatch counts)
— the TPU roofline numbers live in the dry-run, not here.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

RESULTS: List[Dict] = []


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5,
            **kwargs) -> float:
    """Median wall seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def record(bench: str, case: str, seconds: float, **extra) -> None:
    row = {"bench": bench, "case": case, "seconds": round(seconds, 6),
           **extra}
    RESULTS.append(row)
    extras = " ".join(f"{k}={v}" for k, v in extra.items())
    print(f"{bench:24s} {case:32s} {seconds * 1e3:10.2f} ms  {extras}",
          flush=True)


def make_table_data(rows: int, cardinality: float = 0.9, seed: int = 0,
                    value_cols: int = 1,
                    exact_values: bool = False) -> Dict[str, np.ndarray]:
    """Paper §V data recipe: uniform int64->int32 keys, 90% cardinality.

    ``exact_values`` draws integer-valued float32 payloads, making float
    aggregation exact (and therefore order-insensitive) — used by the
    out-of-core bench to assert bit-identity across morsel splits."""
    rng = np.random.default_rng(seed)
    n_unique = max(1, int(rows * cardinality))
    data = {"k": rng.integers(0, n_unique, rows).astype(np.int32)}
    for i in range(value_cols):
        data[f"v{i}"] = (rng.integers(0, 256, rows).astype(np.float32)
                         if exact_values
                         else rng.random(rows).astype(np.float32))
    return data


def dump_json(path: str, meta: Optional[Dict] = None) -> str:
    """Write RESULTS (plus run metadata) as a ``BENCH_*.json`` artifact.

    CI uploads these so the perf trajectory accumulates across PRs; the
    ``meta`` block records enough context (backend, device count, scale)
    to compare runs.
    """
    import json
    payload = {
        "meta": {
            "backend": jax.default_backend(),
            "devices": len(jax.devices()),
            "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            **(meta or {}),
        },
        "results": RESULTS,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    return path


def dump_csv(path: Optional[str] = None) -> str:
    keys = ["bench", "case", "seconds"]
    extra_keys = sorted({k for r in RESULTS for k in r} - set(keys))
    lines = [",".join(keys + extra_keys)]

    def cell(v) -> str:  # quote compound values (e.g. stage_times lists)
        s = str(v)
        return '"' + s.replace('"', '""') + '"' if "," in s else s

    for r in RESULTS:
        lines.append(",".join(cell(r.get(k, "")) for k in keys + extra_keys))
    out = "\n".join(lines)
    if path:
        with open(path, "w") as f:
            f.write(out + "\n")
    return out

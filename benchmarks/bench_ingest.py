"""File ingest (``repro.io``) vs in-memory ingest, in-core and out-of-core.

Three ingest paths feed the same string-keyed groupby pipeline:

  parquet    — ``rdf.read_parquet`` (pyarrow row-group streaming;
               skipped when pyarrow is absent),
  csv        — ``rdf.read_csv`` (pyarrow lane, or the pure-python
               fallback when pyarrow is absent),
  numpy      — ``rdf.read_numpy`` from already-materialized host arrays
               (the no-parse baseline).

For each path the bench records the raw ingest wall time (cold + warm:
the second file read hits the process dictionary cache and is
recode-free) and the query wall time at 1x (in-core) and ``oversub``x
(out-of-core morsel streaming).  Integer-valued payloads keep float sums
exact, so every path must produce the SAME result — asserted, not
assumed.  Artifact: ``BENCH_pr9_ingest.json`` (``--json`` / CI).
"""

import os

if __name__ == "__main__":  # direct CLI use needs the 8-device CPU backend
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import shutil
import tempfile
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import CylonEnv
from repro.io import DictionaryCache, have_pyarrow

from .common import record, time_fn


def _make_files(tmp: str, global_rows: int, nfiles: int
                ) -> Tuple[Dict[str, List], List[str], List[str]]:
    """String-keyed nullable dataset written as Parquet + CSV twins."""
    rng = np.random.default_rng(5)
    nk = max(8, int(global_rows * 0.02))
    keys = [f"key{i:06d}" for i in range(nk)]
    cols: Dict[str, List] = {"k": [], "v0": []}
    pq_paths, csv_paths = [], []
    per = global_rows // nfiles
    for f in range(nfiles):
        k = [keys[rng.integers(0, nk)] if rng.random() > 0.05 else None
             for _ in range(per)]
        v0 = [float(rng.integers(0, 256)) if rng.random() > 0.05 else None
              for _ in range(per)]
        cols["k"] += k
        cols["v0"] += v0
        if have_pyarrow():
            import pyarrow as pa
            import pyarrow.parquet as pq
            p = os.path.join(tmp, f"part{f}.parquet")
            pq.write_table(pa.table({"k": k, "v0": v0}), p)
            pq_paths.append(p)
        c = os.path.join(tmp, f"part{f}.csv")
        with open(c, "w") as fh:
            fh.write("k,v0\n")
            for kk, vv in zip(k, v0):
                fh.write(f"{kk or ''},{'' if vv is None else repr(vv)}\n")
        csv_paths.append(c)
    return cols, pq_paths, csv_paths


def _query(df, env, morsel_rows: Optional[int]):
    res = (df.groupby("k").agg({"v0": ["sum", "count"]})
           .sort_values("k")
           .collect(env=env, morsel_rows=morsel_rows))
    return res.to_numpy()


def run(global_rows: int = 50_000, nfiles: int = 4, oversub: int = 8) -> None:
    import repro.df as rdf

    p = min(8, len(jax.devices()))
    env = CylonEnv(jax.devices()[:p])
    tmp = tempfile.mkdtemp(prefix="bench_ingest_")
    try:
        cols, pq_paths, csv_paths = _make_files(tmp, global_rows, nfiles)
        rows = len(cols["k"])
        morsel = max(8, (-(-rows // p // oversub) + 7) // 8 * 8)

        data_np = {
            "k": np.asarray([x if x is not None else "" for x in cols["k"]]),
            "v0": np.asarray([v if v is not None else np.nan
                              for v in cols["v0"]])}
        readers = [("numpy", lambda: rdf.read_numpy(data_np, env=env))]
        if pq_paths:
            pq_cache = DictionaryCache()
            readers.append(
                ("parquet", lambda: rdf.read_parquet(
                    pq_paths, env=env, dict_cache=pq_cache)))
        csv_cache = DictionaryCache()
        csv_case = "csv" if have_pyarrow() else "csv-python"
        readers.append(
            (csv_case, lambda: rdf.read_csv(csv_paths, env=env,
                                            dict_cache=csv_cache)))

        file_ref = None
        for case, reader in readers:
            t0 = time.perf_counter()
            df = reader()
            t_cold = time.perf_counter() - t0
            src = df.sources[next(iter(df.sources))]
            info = getattr(src, "provenance", None)
            bytes_read = info.bytes_read if info is not None else 0
            t_warm = time_fn(lambda: len(reader().sources),
                             warmup=0, iters=3)
            df2 = reader()   # file paths: second read hits the dict cache
            info2 = getattr(df2.sources[next(iter(df2.sources))],
                            "provenance", None)
            record("ingest", f"{case}_read_cold", t_cold, rows=rows,
                   files=nfiles if case != "numpy" else 0,
                   bytes_read=bytes_read,
                   mb_per_s=(round(bytes_read / t_cold / 1e6, 1)
                             if bytes_read else None))
            record("ingest", f"{case}_read_warm", t_warm, rows=rows,
                   dict_cache_hit=bool(info2 and info2.dict_cache_hit))
            if info2 is not None:
                assert info2.dict_cache_hit and info2.recodes == 0, case

            for tag, morsel_rows in (("1x", None), (f"{oversub}x", morsel)):
                out = _query(df, env, morsel_rows)
                t = time_fn(lambda: _query(df, env, morsel_rows),
                            warmup=1, iters=3)
                record("ingest", f"{case}_query_{tag}", t, rows=rows,
                       groups=len(out["k"]), morsel_rows=morsel_rows or 0)
                if case == "numpy":
                    continue
                # every FILE ingest path computes the identical answer
                # (the numpy baseline differs legitimately: "" stands in
                # for null keys there, forming one extra group)
                if file_ref is None:
                    file_ref = out
                else:
                    for c in file_ref:
                        np.testing.assert_array_equal(
                            file_ref[c], out[c], err_msg=(case, tag, c))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    import argparse

    from .common import dump_json

    ap = argparse.ArgumentParser(
        description="file-ingest bench: Parquet vs CSV vs read_numpy at "
                    "1x and oversub-x device capacity")
    ap.add_argument("--rows", type=int, default=50_000)
    ap.add_argument("--files", type=int, default=4)
    ap.add_argument("--oversub", type=int, default=8)
    ap.add_argument("--json", default="BENCH_pr9_ingest.json")
    args = ap.parse_args()
    run(args.rows, args.files, args.oversub)
    dump_json(args.json, meta={"bench": "ingest", "rows": args.rows,
                               "files": args.files, "oversub": args.oversub,
                               "pyarrow": have_pyarrow()})
    print(f"json -> {args.json}")

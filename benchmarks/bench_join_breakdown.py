"""Paper Fig 6: communication/computation breakdown of the join operator.

Times the full distributed join, its shuffle stage alone, and the local
sort-merge alone, per parallelism — reproducing the paper's observation
that communication dominates join wall time as parallelism grows.
"""

from __future__ import annotations

import jax

from repro.core import CylonEnv, DistTable
from repro.dataframe import join, join_local, shuffle

from .common import make_table_data, record, time_fn


def run(rows_per_rank: int = 50_000) -> None:
    n_dev = len(jax.devices())
    sizes = [p for p in (2, 4, 8) if p <= n_dev]
    for p in sizes:
        rows = rows_per_rank * p
        ld, rd = make_table_data(rows, seed=0), make_table_data(rows, seed=1)
        env = CylonEnv(jax.devices()[:p])
        lt = DistTable.from_numpy(ld, p)
        rt = DistTable.from_numpy(rd, p)

        def full(e=env, l=lt, r=rt):
            def prog(ctx, a, b):
                out, *_ = join(a, b, ctx.comm, on="k",
                               out_capacity=a.capacity * 4)
                return out
            return e.run(prog, l, r, key=("full", p)).row_counts

        def comm_only(e=env, l=lt, r=rt):
            def prog(ctx, a, b):
                sa, _ = shuffle(a, ctx.comm, key_cols=["k"])
                sb, _ = shuffle(b, ctx.comm, key_cols=["k"])
                return sa, sb
            return e.run(prog, l, r, key=("comm", p))[0].row_counts

        def compute_only(e=env, l=lt, r=rt):
            def prog(ctx, a, b):
                return join_local(a, b, "k", out_capacity=a.capacity * 4)
            return e.run(prog, l, r, key=("local", p)).row_counts

        t_full = time_fn(full)
        t_comm = time_fn(comm_only)
        t_comp = time_fn(compute_only)
        record("join_breakdown(Fig6)", f"full_p{p}", t_full, parallelism=p)
        record("join_breakdown(Fig6)", f"shuffle_p{p}", t_comm,
               parallelism=p, comm_fraction=round(t_comm / t_full, 3))
        record("join_breakdown(Fig6)", f"local_join_p{p}", t_comp,
               parallelism=p)

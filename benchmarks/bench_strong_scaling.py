"""Paper Fig 8: strong scaling of join / groupby / sort.

Fixed global rows, parallelism 1..8, comparing:
  * ``bsp``  — the CylonFlow execution model (this paper's contribution),
  * ``amt``  — the Dask-DDF-style baseline (per-operator dispatch +
    allgather-then-select object-store shuffle).

Also measures groupby with and without partial-aggregation pushdown at the
paper's 90% cardinality worst case vs a 1% low-cardinality case.
"""

from __future__ import annotations

import jax

from repro.core import CylonEnv, DistTable, Plan, execute
from repro.dataframe import groupby, join, sort

from .common import make_table_data, record, time_fn


def run(global_rows: int = 200_000) -> None:
    n_dev = len(jax.devices())
    sizes = [p for p in (1, 2, 4, 8) if p <= n_dev]
    ld = make_table_data(global_rows, seed=0)
    rd = make_table_data(global_rows, seed=1)

    for p in sizes:
        env = CylonEnv(jax.devices()[:p])
        lt = DistTable.from_numpy(ld, p)
        rt = DistTable.from_numpy(rd, p)

        plans = {
            "join": Plan.scan("l").join(Plan.scan("r"), on="k",
                                        out_capacity=lt.capacity * 4),
            "groupby": Plan.scan("l").groupby(["k"], {"v0": ["sum"]}),
            "sort": Plan.scan("l").sort(["k"]),
        }
        for opname, plan in plans.items():
            for mode in ("bsp", "amt"):
                def do(pl=plan, m=mode):
                    return execute(pl, env, {"l": lt, "r": rt},
                                   mode=m).row_counts
                record("strong_scaling(Fig8)", f"{opname}_{mode}_p{p}",
                       time_fn(do, iters=3), op=opname, mode=mode,
                       parallelism=p, rows=global_rows)

    # partial-aggregation pushdown (coalescing direction of the paper)
    p = min(8, n_dev)
    env = CylonEnv(jax.devices()[:p])
    for card, tag in ((0.9, "hi_card"), (0.01, "lo_card")):
        data = make_table_data(global_rows, cardinality=card, seed=2)
        t = DistTable.from_numpy(data, p)

        def do(pre: bool, t=t, env=env):
            def prog(ctx, a):
                out, _ = groupby(a, ctx.comm, ["k"], {"v0": ["sum"]},
                                 pre_aggregate=pre)
                return out
            return env.run(prog, t, key=("pre", pre, tag)).row_counts

        for pre in (True, False):
            record("strong_scaling(Fig8)",
                   f"groupby_preagg[{pre}]_{tag}_p{p}",
                   time_fn(do, pre, iters=3), cardinality=card,
                   pre_aggregate=pre, parallelism=p)

"""Benchmark suite — see ``benchmarks.run``."""

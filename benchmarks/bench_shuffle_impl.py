"""Shuffle-implementation microbench: sort-free radix scatter vs the
two-argsort baseline, and chunked vs monolithic all-to-all, on the shuffle
alone (no surrounding operators).

This isolates the PR-2 hot-path claim from pipeline noise: the sorted
implementation pays two O(n log n) argsorts per shuffle (send-side
bucketize + receive-side compaction); the radix path replaces both with
O(n) scatters driven by ``kernels.radix_partition``.  The win grows with
rows per rank (argsort's log factor + the extra gather pass).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core import CylonEnv, DistTable
from repro.dataframe import shuffle

from .common import make_table_data, record, time_fn


def run(rows_per_rank: int = 16384) -> None:
    n_dev = len(jax.devices())
    p = min(8, n_dev)
    env = CylonEnv(jax.devices()[:p])
    # sweep three sizes around the requested scale (min keeps smoke tiny)
    for cap in sorted({max(256, rows_per_rank // 16), rows_per_rank // 4,
                       rows_per_rank}):
        rows = cap * p // 2   # half-full partitions
        data = make_table_data(rows, value_cols=2)
        dt = DistTable.from_numpy(data, p, capacity=cap)

        times = {}
        for impl in ("sorted", "radix"):
            for chunks in (1, 4):
                def do(i=impl, c=chunks):
                    def prog(ctx, t):
                        out, _ = shuffle(t, ctx.comm, key_cols=["k"],
                                         impl=i, a2a_chunks=c)
                        return out
                    return env.run(prog, dt, key=("bench", i, c, cap)).row_counts
                times[(impl, chunks)] = time_fn(do, iters=5)
                record("shuffle_impl", f"{impl}_c{chunks}_cap{cap}_p{p}",
                       times[(impl, chunks)], parallelism=p, capacity=cap,
                       rows=rows, shuffle_impl=impl, a2a_chunks=chunks)
        record("shuffle_impl", f"speedup_radix_over_sorted_cap{cap}_p{p}",
               times[("sorted", 1)] / times[("radix", 1)], parallelism=p,
               capacity=cap, note="ratio not seconds")

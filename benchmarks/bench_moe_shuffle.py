"""Beyond-paper: the MoE token dispatch IS the paper's shuffle.

Times the sort-based grouped dispatch (``moe_apply``, the dataframe-shuffle
algorithm) against the GShard one-hot einsum formulation on growing token
counts — the O(T·E·C) one-hot tensors blow up exactly where the capacity
shuffle stays linear.  Also checks the two produce identical outputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, MoEConfig
from repro.models.moe import moe_apply, moe_apply_einsum, moe_init

from .common import record, time_fn


def run() -> None:
    cfg = ModelConfig(
        name="bench-moe", family="moe", num_layers=1, d_model=128,
        num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=256,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=256,
                      capacity_factor=4.0))
    key = jax.random.PRNGKey(0)
    params = moe_init(key, cfg, jnp.float32)
    rng = np.random.default_rng(0)

    sort_fn = jax.jit(lambda x: moe_apply(params, x, cfg)[0])
    einsum_fn = jax.jit(lambda x: moe_apply_einsum(params, x, cfg)[0])

    for s in (256, 1024, 4096):
        x = jnp.asarray(rng.standard_normal((4, s, 128)), jnp.float32)
        y1, y2 = sort_fn(x), einsum_fn(x)
        err = float(jnp.abs(y1 - y2).max())
        t_sort = time_fn(sort_fn, x, iters=3)
        t_ein = time_fn(einsum_fn, x, iters=3)
        record("moe_shuffle", f"sort_dispatch_T{4 * s}", t_sort,
               tokens=4 * s, max_err_vs_einsum=round(err, 6))
        record("moe_shuffle", f"einsum_dispatch_T{4 * s}", t_ein,
               tokens=4 * s)

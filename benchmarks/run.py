import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only NAME] [--quick]

Modules (paper artifact -> bench):
  Fig 6 comm/compute breakdown of join  -> bench_join_breakdown
  Fig 7 OpenMPI vs Gloo vs UCX/UCC      -> bench_communicators
  Fig 8 strong scaling + pre-agg        -> bench_strong_scaling
  Fig 9 pipeline of operators           -> bench_pipeline
  §V-C serial performance               -> bench_local_ops
  kernels (interpret vs oracle)         -> bench_kernels
  beyond-paper MoE-dispatch-as-shuffle  -> bench_moe_shuffle
  sort-free vs sorted shuffle (PR 2)    -> bench_shuffle_impl
  adaptive skew mitigation (PR 10)      -> bench_skew

The 8-device XLA_FLAGS above is set before jax initializes (scaling
benches need parallelism); the dry-run (512 devices) is a separate entry
point, and unit tests see the plain 1-device backend.
"""

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="smaller row counts (CI-speed)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny row counts (seconds; CI sanity check only)")
    ap.add_argument("--csv", default="bench_results.csv")
    ap.add_argument("--json", default=None,
                    help="JSON artifact path (default BENCH_<scale>.json)")
    args = ap.parse_args()

    from . import (bench_communicators, bench_ingest, bench_join_breakdown,
                   bench_kernels, bench_local_ops, bench_moe_shuffle,
                   bench_pipeline, bench_shuffle_impl, bench_skew,
                   bench_strong_scaling)
    from .common import RESULTS, dump_csv, dump_json

    scale = 50 if args.smoke else 4 if args.quick else 1
    suites = {
        "local_ops": lambda: bench_local_ops.run(200_000 // scale),
        "communicators": lambda: bench_communicators.run(50_000 // scale),
        "join_breakdown": lambda: bench_join_breakdown.run(50_000 // scale),
        "strong_scaling": lambda: bench_strong_scaling.run(200_000 // scale),
        "pipeline": lambda: bench_pipeline.run(100_000 // scale),
        # floor: below ~4k rows/rank the dispatch overhead buries the delta
        "shuffle_impl": lambda: bench_shuffle_impl.run(
            max(4096, 65_536 // scale)),
        # out-of-core Fig-9 at 8x device capacity (asserts bit-identity)
        "out_of_core": lambda: bench_pipeline.run_oversub(
            max(4000, 100_000 // scale), oversub=8),
        # lazy DataFrame frontend overhead vs raw Plan (asserts bit-identity)
        "df_frontend": lambda: bench_pipeline.run_frontend(
            max(4000, 100_000 // scale)),
        # file ingest (repro.io): Parquet vs CSV vs read_numpy, 1x + 8x
        "ingest": lambda: bench_ingest.run(max(4000, 50_000 // scale)),
        # adaptive skew mitigation vs blind baseline (asserts bit-identity)
        "skew": lambda: bench_skew.run(max(8000, 160_000 // scale)),
        "kernels": bench_kernels.run if not args.quick else bench_kernels.run,
        "moe_shuffle": bench_moe_shuffle.run,
    }
    t0 = time.time()
    suite_seconds = {}
    for name, fn in suites.items():
        if args.only and args.only not in name:
            continue
        print(f"\n=== {name} ===", flush=True)
        ts = time.time()
        fn()
        suite_seconds[name] = round(time.time() - ts, 3)
    total = time.time() - t0
    print(f"\n{len(RESULTS)} results in {total:.1f}s")
    dump_csv(args.csv)
    print(f"csv -> {args.csv}")
    scale_tag = "smoke" if args.smoke else "quick" if args.quick else "full"
    json_path = args.json or f"BENCH_{scale_tag}.json"
    dump_json(json_path, meta={"scale": scale_tag, "only": args.only,
                               "suite_seconds": suite_seconds,
                               "total_seconds": round(total, 3)})
    print(f"json -> {json_path}")


if __name__ == "__main__":
    main()

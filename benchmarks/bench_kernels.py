"""Kernel micro-benchmarks (interpret-mode correctness timing + ref compare).

On this CPU container Pallas kernels execute in interpret mode, so the
numbers quantify the *oracle agreement* and interpret overhead, not TPU
speed; the dry-run roofline covers the TPU-side projection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (attention_ref, flash_attention, radix_partition,
                           radix_partition_ref, segmented_sum,
                           segmented_sum_ref, ssd_scan, ssd_scan_ref)

from .common import record, time_fn


def run() -> None:
    rng = np.random.default_rng(0)

    seg = jnp.asarray(np.sort(rng.integers(0, 512, 4096)).astype(np.int32))
    vals = jnp.asarray(rng.random((4096, 4)).astype(np.float32))
    t_k = time_fn(lambda: segmented_sum(seg, vals, 512), iters=3)
    t_r = time_fn(lambda: segmented_sum_ref(seg, vals, 512), iters=3)
    err = float(jnp.abs(segmented_sum(seg, vals, 512)
                        - segmented_sum_ref(seg, vals, 512)).max())
    record("kernels", "segmented_sum_interp", t_k, max_err=err)
    record("kernels", "segmented_sum_ref", t_r)

    dest = jnp.asarray(rng.integers(0, 64, 8192).astype(np.int32))
    t_p = time_fn(jax.jit(lambda d: radix_partition(d, 64, impl="pallas")),
                  dest, iters=3)
    t_x = time_fn(jax.jit(lambda d: radix_partition(d, 64, impl="xla")),
                  dest, iters=3)
    t_r = time_fn(jax.jit(lambda d: radix_partition_ref(d, 64)),
                  dest, iters=3)
    want = radix_partition_ref(dest, 64)
    ok_p = all(bool(jnp.array_equal(a, b)) for a, b in
               zip(radix_partition(dest, 64, impl="pallas"), want))
    ok_x = all(bool(jnp.array_equal(a, b)) for a, b in
               zip(radix_partition(dest, 64, impl="xla"), want))
    record("kernels", "radix_partition_interp", t_p, exact=ok_p)
    record("kernels", "radix_partition_xla", t_x, exact=ok_x)
    record("kernels", "radix_partition_ref", t_r)

    q = jnp.asarray(rng.standard_normal((1, 4, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    t_k = time_fn(lambda: flash_attention(q, k, v), iters=3)
    t_r = time_fn(lambda: attention_ref(q, k, v), iters=3)
    err = float(jnp.abs(flash_attention(q, k, v)
                        - attention_ref(q, k, v)).max())
    record("kernels", "flash_attention_interp", t_k, max_err=err)
    record("kernels", "flash_attention_ref", t_r)

    x = jnp.asarray(rng.standard_normal((4, 512, 32)), jnp.float32)
    dt = jnp.asarray(rng.random((4, 512, 1)) * 0.1 + 0.01, jnp.float32)
    a = jnp.asarray(-rng.random((4, 1)) - 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal((4, 512, 16)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((4, 512, 16)), jnp.float32)
    t_k = time_fn(lambda: ssd_scan(x, dt, a, b, c), iters=3)
    t_r = time_fn(lambda: ssd_scan_ref(x, dt, a, b, c), iters=3)
    err = float(jnp.abs(ssd_scan(x, dt, a, b, c)[0]
                        - ssd_scan_ref(x, dt, a, b, c)[0]).max())
    record("kernels", "ssd_scan_interp", t_k, max_err=err)
    record("kernels", "ssd_scan_ref", t_r)

"""Paper §V-C serial performance: local operators vs numpy reference.

The paper credits CylonFlow's superior *sequential* performance to native
C++ execution over Arrow data; the analogue here is jit-compiled XLA
columnar kernels vs interpreted numpy.  One device, no communication.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.dataframe import Table, groupby_local, join_local, sort_local

from .common import make_table_data, record, time_fn


def numpy_join(l, r, on="k"):
    import numpy as np
    order = np.argsort(r[on], kind="stable")
    rk = r[on][order]
    lo = np.searchsorted(rk, l[on], "left")
    hi = np.searchsorted(rk, l[on], "right")
    counts = hi - lo
    total = counts.sum()
    li = np.repeat(np.arange(len(l[on])), counts)
    offs = (lo.repeat(counts)
            + (np.arange(total) - np.repeat(counts.cumsum() - counts, counts)))
    return {**{k: v[li] for k, v in l.items()},
            **{f"{k}_r": v[order][offs] for k, v in r.items() if k != on}}


def numpy_groupby(d, key="k", val="v0"):
    uk, inv = np.unique(d[key], return_inverse=True)
    sums = np.zeros(len(uk), np.float64)
    np.add.at(sums, inv, d[val])
    return uk, sums


def run(rows: int = 200_000) -> None:
    ld = make_table_data(rows, seed=0)
    rd = make_table_data(rows, seed=1)
    lt = Table.from_arrays(ld)
    rt = Table.from_arrays(rd)

    out_cap = rows * 4
    jit_join = jax.jit(lambda a, b: join_local(a, b, "k", out_capacity=out_cap))
    record("local_ops(V-C)", f"join_xla_{rows}",
           time_fn(jit_join, lt, rt), rows=rows)
    t0 = time.perf_counter()
    numpy_join(ld, rd)
    record("local_ops(V-C)", f"join_numpy_{rows}",
           time.perf_counter() - t0, rows=rows)

    jit_gb = jax.jit(lambda a: groupby_local(a, ["k"], {"v0": ["sum"]}))
    record("local_ops(V-C)", f"groupby_xla_{rows}",
           time_fn(jit_gb, lt), rows=rows)
    t0 = time.perf_counter()
    numpy_groupby(ld)
    record("local_ops(V-C)", f"groupby_numpy_{rows}",
           time.perf_counter() - t0, rows=rows)

    jit_sort = jax.jit(lambda a: sort_local(a, ["k"]))
    record("local_ops(V-C)", f"sort_xla_{rows}",
           time_fn(jit_sort, lt), rows=rows)
    t0 = time.perf_counter()
    np.sort(ld["k"], kind="stable")
    record("local_ops(V-C)", f"sort_numpy_{rows}",
           time.perf_counter() - t0, rows=rows)

"""Paper Fig 9: pipeline of operators (join -> groupby -> sort -> add_scalar).

Three execution modes of the same logical plan:
  bsp        — ONE compiled program, local ops implicitly coalesced
               (CylonFlow),
  bsp_staged — one dispatch per communication stage (coalescing within
               stages only),
  amt        — one dispatch per sub-operator + allgather-based shuffle
               (the Dask-DDF-style baseline).

Each mode runs with the planner optimizer OFF (the plan exactly as
written — note this includes groupby pre-aggregation, which is now an
optimizer rule rather than an implicit default) and ON (shuffle elision +
pushdowns + pre-agg), recording stage count, shuffle count, bytes on the
wire, and wall-clock — so BENCH_*.json captures the optimizer gain
alongside the paper's bsp/amt gap (10-24x pipeline speedup claim,
qualitative on the CPU stand-in backend).  Plans are compiled once per
(parallelism, optimize) cell; the timed region measures dispatch +
execution through ``run_physical``, not re-planning.
"""

import os

if __name__ == "__main__":  # direct CLI use needs the 8-device CPU backend
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import time
from typing import Dict

import jax
import numpy as np

from repro.core import CylonEnv, DistTable, Plan
from repro.planner import compile_plan, run_physical

from .common import make_table_data, record, time_fn


def make_plan(capacity: int) -> Plan:
    # ample bucket/out capacities: the unoptimized baseline re-shuffles
    # already-partitioned data, landing all rows in one self-dest bucket
    return (Plan.scan("l")
            .join(Plan.scan("r"), on="k", out_capacity=capacity * 4,
                  bucket_capacity=capacity)
            .groupby(["k"], {"v0": ["sum"]}, bucket_capacity=capacity * 4)
            .sort(["k"], bucket_capacity=capacity * 4)
            .add_scalar(1.0, cols=["v0_sum"]))


def run(global_rows: int = 100_000) -> None:
    n_dev = len(jax.devices())
    sizes = [p for p in (2, 4, 8) if p <= n_dev]
    ld = make_table_data(global_rows, seed=0)
    rd = make_table_data(global_rows, seed=1)

    for p in sizes:
        env = CylonEnv(jax.devices()[:p])
        lt = DistTable.from_numpy(ld, p)
        rt = DistTable.from_numpy(rd, p)
        plan = make_plan(lt.capacity)
        tables = {"l": lt, "r": rt}

        times = {}
        pplans = {opt: compile_plan(plan, tables, optimize_plan=opt)
                  for opt in (False, True)}
        for mode in ("bsp", "bsp_staged", "amt"):
            for opt in (False, True):
                tag = f"{mode}_{'opt' if opt else 'unopt'}"
                pplan = pplans[opt]
                _, stats = run_physical(pplan, env, tables, mode=mode,
                                        collect_stats=True)

                def do(pp=pplan, m=mode):
                    return run_physical(pp, env, tables, mode=m).row_counts
                times[tag] = time_fn(do, iters=3)
                record("pipeline(Fig9)", f"{tag}_p{p}", times[tag],
                       mode=mode, parallelism=p, optimized=opt,
                       stages=pplan.num_stages, shuffles=pplan.num_shuffles,
                       rows_shuffled=stats.rows_shuffled,
                       bytes_shuffled=stats.bytes_shuffled,
                       shuffle_impl=stats.shuffle_impl,
                       a2a_chunks=stats.a2a_chunks,
                       # first (compiling) run: per-stage attribution
                       wall_time_s=round(stats.wall_time_s, 6),
                       stage_times=[(n, round(t, 6))
                                    for n, t in stats.stage_times])
        record("pipeline(Fig9)", f"speedup_bsp_over_amt_p{p}",
               times["amt_unopt"] / times["bsp_unopt"], parallelism=p,
               note="ratio not seconds")
        record("pipeline(Fig9)", f"speedup_optimizer_bsp_p{p}",
               times["bsp_unopt"] / times["bsp_opt"], parallelism=p,
               note="ratio not seconds")

        # --- shuffle-implementation matrix: radix-vs-sorted bucketize × ---#
        # --- chunked-vs-monolithic all-to-all (unoptimized plan: 4 -------#
        # --- shuffles, so the shuffle path dominates the delta) ----------#
        # NOTE (radix, c1) equals the bsp_unopt cell above, but is re-timed
        # anyway: the speedup ratios below are only meaningful between
        # back-to-back measurements — reusing a number taken minutes earlier
        # under different machine load poisons the comparison.
        sweep = {}
        for impl in ("sorted", "radix"):
            for chunks in (1, 4):
                def do(pp=pplans[False], i=impl, c=chunks):
                    return run_physical(pp, env, tables, mode="bsp",
                                        shuffle_impl=i,
                                        a2a_chunks=c).row_counts
                sweep[(impl, chunks)] = time_fn(do, iters=3)
                record("pipeline(Fig9)", f"bsp_unopt_{impl}_c{chunks}_p{p}",
                       sweep[(impl, chunks)], mode="bsp", parallelism=p,
                       optimized=False, shuffle_impl=impl, a2a_chunks=chunks)
        record("pipeline(Fig9)", f"speedup_radix_over_sorted_p{p}",
               sweep[("sorted", 1)] / sweep[("radix", 1)], parallelism=p,
               note="ratio not seconds")
        record("pipeline(Fig9)", f"speedup_radix_chunked4_p{p}",
               sweep[("radix", 1)] / sweep[("radix", 4)], parallelism=p,
               note="ratio not seconds")


def run_oversub(global_rows: int = 100_000, oversub: int = 8,
                capacity_factor: float = 4.0) -> None:
    """Out-of-core Fig-9: the dataset is ``oversub``x the per-device morsel
    capacity and streams through the compiled stage DAG host-resident
    (``docs/out_of_core.md``).

    Device working capacity is pinned at ``capacity_factor * morsel_rows``
    with ``morsel_rows = rows/rank/oversub`` — i.e. the device never holds
    more than ~``1/oversub`` of its partition (plus the resident join build
    side).  Payloads are integer-valued float32 so the streamed result is
    asserted BIT-IDENTICAL to the in-core run, morsel split or not.
    """
    from repro.core import SpillTable

    p = min(8, len(jax.devices()))
    env = CylonEnv(jax.devices()[:p])
    ld = make_table_data(global_rows, seed=0, exact_values=True)
    rd = make_table_data(global_rows, seed=1, exact_values=True)
    rd["w"] = rd.pop("v0")
    lt = DistTable.from_numpy(ld, p)
    rt = DistTable.from_numpy(rd, p)
    cap = lt.capacity
    rows_rank = -(-global_rows // p)
    morsel = max(8, (-(-rows_rank // oversub) + 7) // 8 * 8)

    plan = (Plan.scan("l")
            .join(Plan.scan("r"), on="k", out_capacity=cap * 4,
                  bucket_capacity=cap * 2, shuffle_out_capacity=cap * 2)
            .groupby(["k"], {"v0": ["sum", "mean"]}, bucket_capacity=cap * 4)
            .sort(["k"], bucket_capacity=cap * 4)
            .add_scalar(1.0, cols=["v0_sum"]))
    tables_dev = {"l": lt, "r": rt}
    tables_host = {"l": SpillTable.from_numpy(ld, p, chunk_rows=morsel),
                   "r": rd}
    pplan = compile_plan(plan, tables_dev, optimize_plan=True)

    ref, ref_stats = run_physical(pplan, env, tables_dev, mode="bsp",
                                  collect_stats=True)
    out, ooc_stats = run_physical(pplan, env, tables_host, mode="bsp",
                                  collect_stats=True, morsel_rows=morsel,
                                  capacity_factor=capacity_factor)
    a, b = ref.to_numpy(), out.to_numpy()
    identical = (sorted(a) == sorted(b)
                 and all(np.array_equal(a[c], b[c]) for c in a))

    t_ref = time_fn(lambda: run_physical(pplan, env, tables_dev,
                                         mode="bsp").row_counts, iters=3)

    def do_ooc():
        sp = run_physical(pplan, env, tables_host, mode="bsp",
                          morsel_rows=morsel,
                          capacity_factor=capacity_factor)
        return sp.total_rows()

    t_ooc = time_fn(do_ooc, warmup=1, iters=3)
    record("pipeline(Fig9-ooc)", f"in_core_p{p}", t_ref, parallelism=p,
           rows=global_rows, rows_dropped=ref_stats.rows_dropped,
           wall_time_s=round(ref_stats.wall_time_s, 6))
    record("pipeline(Fig9-ooc)", f"oversub{oversub}_p{p}", t_ooc,
           parallelism=p, rows=global_rows, oversub=oversub,
           morsel_rows=ooc_stats.morsel_rows, morsels=ooc_stats.morsels,
           dispatches=ooc_stats.dispatches,
           wall_time_s=round(ooc_stats.wall_time_s, 6),
           stage_times=[(n, round(t, 6))
                        for n, t in ooc_stats.stage_times],
           spill_bytes=ooc_stats.spill_bytes,
           h2d_bytes=ooc_stats.h2d_bytes, d2h_bytes=ooc_stats.d2h_bytes,
           rows_shuffled=ooc_stats.rows_shuffled,
           rows_dropped=ooc_stats.rows_dropped,
           cache_misses=ooc_stats.cache_misses,
           cache_hits=ooc_stats.cache_hits,
           bit_identical=identical)
    record("pipeline(Fig9-ooc)", f"slowdown_oversub{oversub}_p{p}",
           t_ooc / t_ref, parallelism=p, note="ratio not seconds")
    if not identical:
        raise AssertionError("out-of-core result != in-core result")
    if ooc_stats.rows_dropped or ref_stats.rows_dropped:
        raise AssertionError(
            f"rows dropped (in-core {ref_stats.rows_dropped}, "
            f"out-of-core {ooc_stats.rows_dropped})")


def run_frontend(global_rows: int = 100_000) -> None:
    """Fig-9 via the lazy DataFrame frontend vs the raw ``Plan`` builder.

    Both paths execute through ``core.plan.execute`` (re-plan + cached
    program dispatch per call — the user-facing cost model), so the delta
    isolates what the frontend layer adds: plan construction captured in
    the DataFrame, source-dict plumbing, and session-env resolution.
    Target: <2% wall-clock overhead.  Also asserts bit-identity.
    """
    import repro.df as rdf
    from repro.core import execute
    from repro.expr import col

    p = min(8, len(jax.devices()))
    env = CylonEnv(jax.devices()[:p])
    ld = make_table_data(global_rows, seed=0, exact_values=True)
    rd = make_table_data(global_rows, seed=1, exact_values=True)
    rd["w"] = rd.pop("v0")
    lt = DistTable.from_numpy(ld, p)
    rt = DistTable.from_numpy(rd, p)
    cap = lt.capacity
    tables = {"l": lt, "r": rt}

    plan = (Plan.scan("l")
            .join(Plan.scan("r"), on="k", out_capacity=cap * 4)
            .filter((col("v0") > 4) & (col("w") < 250))
            .groupby(["k"], {"v0": ["sum", "mean"]})
            .sort(["k"])
            .with_columns({"v0_sum": col("v0_sum") + 1.0}))
    front = (rdf.from_table(lt, name="l")
             .merge(rdf.from_table(rt, name="r"), on="k",
                    out_capacity=cap * 4)
             [(col("v0") > 4) & (col("w") < 250)]
             .groupby("k").agg({"v0": ["sum", "mean"]})
             .sort_values("k")
             .assign(v0_sum=col("v0_sum") + 1.0))

    a = execute(plan, env, tables).to_numpy()
    b = front.collect(env=env).to_numpy()
    identical = (sorted(a) == sorted(b)
                 and all(np.array_equal(a[c], b[c]) for c in a))

    t_plan = time_fn(lambda: execute(plan, env, tables).row_counts, iters=5)
    t_front = time_fn(lambda: front.collect(env=env).row_counts, iters=5)
    overhead = t_front / t_plan - 1.0
    record("pipeline(Fig9-df)", f"plan_builder_p{p}", t_plan,
           parallelism=p, rows=global_rows)
    record("pipeline(Fig9-df)", f"df_frontend_p{p}", t_front,
           parallelism=p, rows=global_rows, bit_identical=identical)
    # the seconds column carries the raw ratio-1 (repo convention for
    # unitless records); overhead_pct is the human-readable field
    record("pipeline(Fig9-df)", f"frontend_overhead_p{p}", overhead,
           parallelism=p, overhead_pct=round(100 * overhead, 2),
           target_pct="<2", note="ratio-1 not seconds")
    if overhead > 0.02:
        print(f"WARNING: df frontend overhead {overhead:.1%} exceeds the "
              f"2% target (CPU wall-clock is noisy; re-run on an idle "
              f"machine before reading this as a regression)")
    if not identical:
        raise AssertionError("df frontend result != Plan builder result")


def run_faults(global_rows: int = 100_000, which: str = "off",
               oversub: int = 4) -> None:
    """Fault-tolerance cost model (``docs/fault_tolerance.md``):

    * ``off``    — fault-tolerance arguments armed but injection disabled:
      asserts ZERO new compile-cache entries vs the plain run (the harness
      is driver-side only) and records the wall-clock ratio (target ~1.0);
    * ``single`` — one injected fault, in-core (stage launch) and streamed
      (morsel execute): records recovery cost, asserts bit-identity;
    * ``storm``  — fixed-seed randomized multi-fault plans on the streamed
      pipeline: every run completes bit-identical with zero drops.
    """
    from repro.faults import FaultPlan, random_plan

    p = min(8, len(jax.devices()))
    env = CylonEnv(jax.devices()[:p])
    ld = make_table_data(global_rows, seed=0, exact_values=True)
    rd = make_table_data(global_rows, seed=1, exact_values=True)
    rd["w"] = rd.pop("v0")
    lt = DistTable.from_numpy(ld, p)
    rt = DistTable.from_numpy(rd, p)
    cap = lt.capacity
    rows_rank = -(-global_rows // p)
    morsel = max(8, (-(-rows_rank // oversub) + 7) // 8 * 8)
    plan = (Plan.scan("l")
            .join(Plan.scan("r"), on="k", out_capacity=cap * 4,
                  bucket_capacity=cap * 2, shuffle_out_capacity=cap * 2)
            .groupby(["k"], {"v0": ["sum"]}, bucket_capacity=cap * 4)
            .sort(["k"], bucket_capacity=cap * 4))
    tables_dev = {"l": lt, "r": rt}
    tables_host = {"l": ld, "r": rd}
    pplan = compile_plan(plan, tables_dev, optimize_plan=True)

    # fault-free baselines (also warm the compile cache for both paths)
    ref, _ = run_physical(pplan, env, tables_dev, mode="bsp",
                          collect_stats=True)
    ref_np = ref.to_numpy()
    out, _ = run_physical(pplan, env, tables_host, mode="bsp",
                          collect_stats=True, morsel_rows=morsel,
                          capacity_factor=4.0)
    ooc_np = out.to_numpy()

    def _identical(a, b):
        return (sorted(a) == sorted(b)
                and all(np.array_equal(a[c], b[c]) for c in a))

    if which == "off":
        t_plain = time_fn(lambda: run_physical(
            pplan, env, tables_dev, mode="bsp").row_counts, iters=5)
        # snapshot AFTER the plain run: the invariant is that arming the
        # fault-tolerance arguments compiles nothing the plain run didn't
        keys0 = set(env._cache)
        misses0 = env.cache_misses
        t_armed = time_fn(lambda: run_physical(
            pplan, env, tables_dev, mode="bsp", retries=5, timeout=60.0,
            overflow="degrade", faults=False).row_counts, iters=5)
        sp = run_physical(pplan, env, tables_host, mode="bsp",
                          morsel_rows=morsel, capacity_factor=4.0,
                          retries=5, timeout=60.0, faults=False)
        assert sp.total_rows() == out.total_rows()
        if set(env._cache) != keys0 or env.cache_misses != misses0:
            raise AssertionError(
                "fault-tolerance harness changed the compile cache with "
                f"injection off ({len(set(env._cache) - keys0)} new keys, "
                f"{env.cache_misses - misses0} new misses)")
        record("pipeline(Fig9-faults)", f"off_plain_p{p}", t_plain,
               parallelism=p, rows=global_rows)
        record("pipeline(Fig9-faults)", f"off_armed_p{p}", t_armed,
               parallelism=p, rows=global_rows, new_cache_keys=0,
               new_cache_misses=0)
        record("pipeline(Fig9-faults)", f"off_overhead_p{p}",
               t_armed / t_plain - 1.0, parallelism=p,
               overhead_pct=round(100 * (t_armed / t_plain - 1.0), 2),
               note="ratio-1 not seconds")
    elif which == "single":
        t_ic = time_fn(lambda: run_physical(
            pplan, env, tables_dev, mode="bsp").row_counts, iters=3)
        got, st = run_physical(pplan, env, tables_dev, mode="bsp",
                               collect_stats=True,
                               faults="stage:launch@0=raise")
        assert st.retries == 1 and _identical(ref_np, got.to_numpy())
        record("pipeline(Fig9-faults)", f"single_in_core_p{p}",
               st.wall_time_s, parallelism=p, rows=global_rows,
               baseline_s=round(t_ic, 6), retries=st.retries,
               faults_injected=st.faults_injected, bit_identical=True)
        got, st = run_physical(pplan, env, tables_host, mode="bsp",
                               collect_stats=True, morsel_rows=morsel,
                               capacity_factor=4.0,
                               faults="morsel:execute@1=raise")
        assert st.retries >= 1 and st.rows_dropped == 0
        assert _identical(ooc_np, got.to_numpy())
        record("pipeline(Fig9-faults)", f"single_out_of_core_p{p}",
               st.wall_time_s, parallelism=p, rows=global_rows,
               morsel_rows=morsel, retries=st.retries,
               faults_injected=st.faults_injected, bit_identical=True)
    elif which == "storm":
        fired = 0
        t0 = 0.0
        for seed in range(4):
            fp = random_plan(seed, nfaults=2, kinds=("raise",),
                             max_occurrence=4)
            fp = FaultPlan(fp.specs, seed=fp.seed, hang_s=0.05)
            got, st = run_physical(pplan, env, tables_host, mode="bsp",
                                   collect_stats=True, morsel_rows=morsel,
                                   capacity_factor=4.0, faults=fp)
            assert st.rows_dropped == 0
            assert _identical(ooc_np, got.to_numpy()), str(fp)
            fired += st.faults_injected
            t0 += st.wall_time_s
        if not fired:
            raise AssertionError("storm never fired a fault")
        record("pipeline(Fig9-faults)", f"storm_p{p}", t0 / 4,
               parallelism=p, rows=global_rows, seeds=4,
               faults_injected=fired, rows_dropped=0, bit_identical=True)
    else:
        raise ValueError(f"unknown --faults mode {which!r}")


def run_serving(global_rows: int = 100_000, k: int = 4,
                queries_per_gang: int = 6) -> None:
    """Concurrent multi-query serving vs serial submission
    (``docs/serving.md``): ``k`` gangs of ``n_dev // k`` devices carved
    from one ``DevicePool`` by a ``QueryScheduler`` sharing one
    ``ProgramCache``.

    The same ``k * queries_per_gang`` mixed Fig-9-style queries are
    submitted twice — with ``max_inflight=1`` (serial: one gang busy at a
    time) and ``max_inflight=k`` (concurrent: every gang busy) — and both
    sweeps record queries/sec plus p50/p99 end-to-end latency
    (submit -> result, so concurrent latencies include queue wait).  The
    shared cache is pre-warmed on every partition, so neither sweep pays
    compile cost and every handle must report ``cache_misses == 0``.
    """
    import repro.df as rdf
    from repro.core import DevicePool
    from repro.expr import col
    from repro.serve import ProgramCache, QueryScheduler

    n_dev = len(jax.devices())
    if k < 1 or n_dev % k:
        raise ValueError(f"--serve {k} must divide the {n_dev} devices")
    gang = n_dev // k
    ld = make_table_data(global_rows, seed=0, exact_values=True)
    rd = make_table_data(global_rows, seed=1, exact_values=True)
    rd["w"] = rd.pop("v0")
    lt = DistTable.from_numpy(ld, gang)
    rt = DistTable.from_numpy(rd, gang)
    cap = lt.capacity
    left = rdf.from_table(lt, name="l")      # not pinned to any env:
    right = rdf.from_table(rt, name="r")     # runs on whichever gang
    jkw = dict(out_capacity=cap * 4, bucket_capacity=cap * 2,
               shuffle_out_capacity=cap * 2)
    queries = [
        lambda: (left.merge(right, on="k", **jkw)
                 [(col("v0") > 4) & (col("w") < 250)]
                 .groupby("k").agg({"v0": ["sum"]}).sort_values("k")),
        lambda: (left.groupby("k").agg({"v0": ["sum", "mean"]})
                 .sort_values("k")),
        lambda: left[col("v0") > 64].sort_values("k"),
    ]

    shared = ProgramCache(registry=False)
    pool = DevicePool()
    # pre-warm every partition so neither sweep measures compilation
    for g in range(k):
        env = CylonEnv(jax.devices()[g * gang:(g + 1) * gang],
                       program_cache=shared)
        for q in queries:
            q().collect(env=env)
    warm_misses = shared.misses

    n_queries = k * queries_per_gang

    def sweep(inflight: int) -> Dict:
        sched = QueryScheduler(pool=pool, gang_size=gang,
                               max_inflight=inflight, max_queue=n_queries,
                               program_cache=shared,
                               name=f"bench-x{inflight}")
        t0 = time.perf_counter()
        handles = [sched.submit(queries[i % len(queries)](),
                                label=f"x{inflight}-{i}")
                   for i in range(n_queries)]
        for h in handles:
            h.result(timeout=600)
        wall = time.perf_counter() - t0
        sched.close()
        assert all(h.stats["cache_misses"] == 0 for h in handles), \
            "serving sweep recompiled a warm program"
        lat = sorted(h.stats["finished_monotonic"]
                     - h.stats["submitted_monotonic"] for h in handles)
        return {"wall": wall, "qps": n_queries / wall,
                "p50": lat[len(lat) // 2], "p99": lat[-1]
                if len(lat) < 100 else lat[int(len(lat) * 0.99)]}

    serial = sweep(1)
    concurrent = sweep(k)
    assert shared.misses == warm_misses, "sweeps recompiled something"
    for tag, s, inflight in (("serial", serial, 1),
                             ("concurrent", concurrent, k)):
        record("pipeline(Fig9-serve)", f"{tag}_k{k}_gang{gang}", s["wall"],
               gangs=k, gang_size=gang, max_inflight=inflight,
               queries=n_queries, rows=global_rows,
               queries_per_s=round(s["qps"], 3),
               latency_p50_s=round(s["p50"], 6),
               latency_p99_s=round(s["p99"], 6))
    record("pipeline(Fig9-serve)", f"speedup_concurrent_k{k}",
           serial["wall"] / concurrent["wall"], gangs=k, gang_size=gang,
           note="ratio not seconds")


if __name__ == "__main__":
    import argparse

    from .common import dump_json

    ap = argparse.ArgumentParser(
        description="Fig-9 pipeline extras: out-of-core morsel streaming "
                    "(default), --frontend=df overhead measurement, or "
                    "--faults fault-tolerance cost model")
    ap.add_argument("--rows", type=int, default=100_000)
    ap.add_argument("--oversub", type=int, default=8,
                    help="dataset size as a multiple of device capacity")
    ap.add_argument("--capacity-factor", type=float, default=4.0)
    ap.add_argument("--frontend", choices=["df"], default=None,
                    help="measure DataFrame-frontend overhead vs raw Plan")
    ap.add_argument("--faults", choices=["off", "single", "storm"],
                    default=None,
                    help="fault-tolerance bench: disabled-overhead / "
                         "single-fault recovery / randomized storm")
    ap.add_argument("--serve", type=int, default=None, metavar="K",
                    help="serving bench: K gangs of n_dev//K devices, "
                         "serial vs concurrent submission (queries/sec, "
                         "p50/p99 latency)")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    if args.serve:
        json_path = args.json or "BENCH_pr8_serving.json"
        run_serving(args.rows, args.serve)
        dump_json(json_path, meta={"bench": "serving", "gangs": args.serve,
                                   "rows": args.rows})
    elif args.faults:
        json_path = args.json or "BENCH_pr7_fault_tolerance.json"
        run_faults(args.rows, args.faults)
        dump_json(json_path, meta={"bench": "fault_tolerance",
                                   "faults": args.faults,
                                   "rows": args.rows})
    elif args.frontend == "df":
        json_path = args.json or "BENCH_pr4_df_frontend.json"
        run_frontend(args.rows)
        dump_json(json_path, meta={"bench": "df_frontend",
                                   "rows": args.rows})
    else:
        json_path = args.json or "BENCH_pr3_out_of_core.json"
        run_oversub(args.rows, args.oversub, args.capacity_factor)
        dump_json(json_path, meta={"bench": "out_of_core",
                                   "oversub": args.oversub,
                                   "rows": args.rows})
    print(f"json -> {json_path}")

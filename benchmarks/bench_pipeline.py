"""Paper Fig 9: pipeline of operators (join -> groupby -> sort -> add_scalar).

Three execution modes of the same logical plan:
  bsp        — ONE compiled program, local ops implicitly coalesced
               (CylonFlow),
  bsp_staged — one dispatch per communication stage (coalescing within
               stages only),
  amt        — one dispatch per sub-operator + allgather-based shuffle
               (the Dask-DDF-style baseline).

Each mode runs with the planner optimizer OFF (the plan exactly as
written — note this includes groupby pre-aggregation, which is now an
optimizer rule rather than an implicit default) and ON (shuffle elision +
pushdowns + pre-agg), recording stage count, shuffle count, bytes on the
wire, and wall-clock — so BENCH_*.json captures the optimizer gain
alongside the paper's bsp/amt gap (10-24x pipeline speedup claim,
qualitative on the CPU stand-in backend).  Plans are compiled once per
(parallelism, optimize) cell; the timed region measures dispatch +
execution through ``run_physical``, not re-planning.
"""

from __future__ import annotations

import jax

from repro.core import CylonEnv, DistTable, Plan
from repro.planner import compile_plan, run_physical

from .common import make_table_data, record, time_fn


def make_plan(capacity: int) -> Plan:
    # ample bucket/out capacities: the unoptimized baseline re-shuffles
    # already-partitioned data, landing all rows in one self-dest bucket
    return (Plan.scan("l")
            .join(Plan.scan("r"), on="k", out_capacity=capacity * 4,
                  bucket_capacity=capacity)
            .groupby(["k"], {"v0": ["sum"]}, bucket_capacity=capacity * 4)
            .sort(["k"], bucket_capacity=capacity * 4)
            .add_scalar(1.0, cols=["v0_sum"]))


def run(global_rows: int = 100_000) -> None:
    n_dev = len(jax.devices())
    sizes = [p for p in (2, 4, 8) if p <= n_dev]
    ld = make_table_data(global_rows, seed=0)
    rd = make_table_data(global_rows, seed=1)

    for p in sizes:
        env = CylonEnv(jax.devices()[:p])
        lt = DistTable.from_numpy(ld, p)
        rt = DistTable.from_numpy(rd, p)
        plan = make_plan(lt.capacity)
        tables = {"l": lt, "r": rt}

        times = {}
        pplans = {opt: compile_plan(plan, tables, optimize_plan=opt)
                  for opt in (False, True)}
        for mode in ("bsp", "bsp_staged", "amt"):
            for opt in (False, True):
                tag = f"{mode}_{'opt' if opt else 'unopt'}"
                pplan = pplans[opt]
                _, stats = run_physical(pplan, env, tables, mode=mode,
                                        collect_stats=True)

                def do(pp=pplan, m=mode):
                    return run_physical(pp, env, tables, mode=m).row_counts
                times[tag] = time_fn(do, iters=3)
                record("pipeline(Fig9)", f"{tag}_p{p}", times[tag],
                       mode=mode, parallelism=p, optimized=opt,
                       stages=pplan.num_stages, shuffles=pplan.num_shuffles,
                       rows_shuffled=stats.rows_shuffled,
                       bytes_shuffled=stats.bytes_shuffled,
                       shuffle_impl=stats.shuffle_impl,
                       a2a_chunks=stats.a2a_chunks)
        record("pipeline(Fig9)", f"speedup_bsp_over_amt_p{p}",
               times["amt_unopt"] / times["bsp_unopt"], parallelism=p,
               note="ratio not seconds")
        record("pipeline(Fig9)", f"speedup_optimizer_bsp_p{p}",
               times["bsp_unopt"] / times["bsp_opt"], parallelism=p,
               note="ratio not seconds")

        # --- shuffle-implementation matrix: radix-vs-sorted bucketize × ---#
        # --- chunked-vs-monolithic all-to-all (unoptimized plan: 4 -------#
        # --- shuffles, so the shuffle path dominates the delta) ----------#
        # NOTE (radix, c1) equals the bsp_unopt cell above, but is re-timed
        # anyway: the speedup ratios below are only meaningful between
        # back-to-back measurements — reusing a number taken minutes earlier
        # under different machine load poisons the comparison.
        sweep = {}
        for impl in ("sorted", "radix"):
            for chunks in (1, 4):
                def do(pp=pplans[False], i=impl, c=chunks):
                    return run_physical(pp, env, tables, mode="bsp",
                                        shuffle_impl=i,
                                        a2a_chunks=c).row_counts
                sweep[(impl, chunks)] = time_fn(do, iters=3)
                record("pipeline(Fig9)", f"bsp_unopt_{impl}_c{chunks}_p{p}",
                       sweep[(impl, chunks)], mode="bsp", parallelism=p,
                       optimized=False, shuffle_impl=impl, a2a_chunks=chunks)
        record("pipeline(Fig9)", f"speedup_radix_over_sorted_p{p}",
               sweep[("sorted", 1)] / sweep[("radix", 1)], parallelism=p,
               note="ratio not seconds")
        record("pipeline(Fig9)", f"speedup_radix_chunked4_p{p}",
               sweep[("radix", 1)] / sweep[("radix", 4)], parallelism=p,
               note="ratio not seconds")

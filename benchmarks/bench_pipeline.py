"""Paper Fig 9: pipeline of operators (join -> groupby -> sort -> add_scalar).

Three execution modes of the same logical plan:
  * bsp        — ONE compiled program, local ops implicitly coalesced
                 (CylonFlow),
  * bsp_staged — one dispatch per communication stage (coalescing within
                 stages only),
  * amt        — one dispatch per sub-operator + allgather-based shuffle
                 (the Dask-DDF-style baseline).

The bsp/amt gap reproduces the paper's 10-24x pipeline speedup claim
qualitatively (absolute ratios differ on the CPU stand-in backend).
"""

from __future__ import annotations

import jax

from repro.core import CylonEnv, DistTable, Plan, execute

from .common import make_table_data, record, time_fn


def run(global_rows: int = 100_000) -> None:
    n_dev = len(jax.devices())
    sizes = [p for p in (2, 4, 8) if p <= n_dev]
    ld = make_table_data(global_rows, seed=0)
    rd = make_table_data(global_rows, seed=1)

    for p in sizes:
        env = CylonEnv(jax.devices()[:p])
        lt = DistTable.from_numpy(ld, p)
        rt = DistTable.from_numpy(rd, p)
        plan = (Plan.scan("l")
                .join(Plan.scan("r"), on="k", out_capacity=lt.capacity * 4)
                .groupby(["k"], {"v0": ["sum"]})
                .sort(["k"])
                .add_scalar(1.0, cols=["v0_sum"]))

        times = {}
        for mode in ("bsp", "bsp_staged", "amt"):
            def do(m=mode):
                return execute(plan, env, {"l": lt, "r": rt},
                               mode=m).row_counts
            times[mode] = time_fn(do, iters=3)
            record("pipeline(Fig9)", f"{mode}_p{p}", times[mode],
                   mode=mode, parallelism=p, stages=plan.num_stages())
        record("pipeline(Fig9)", f"speedup_bsp_over_amt_p{p}",
               times["amt"] / times["bsp"], parallelism=p,
               note="ratio not seconds")

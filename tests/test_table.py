"""Unit + property tests for the columnar Table."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.dataframe import Table, concat_tables
from repro.dataframe.ops_local import filter_rows, sort_local


def test_from_arrays_pads_to_capacity(rng):
    t = Table.from_arrays({"a": rng.integers(0, 9, 5).astype(np.int32)},
                          capacity=16)
    assert t.capacity == 16
    assert int(t.row_count) == 5
    assert t.valid_mask().sum() == 5


def test_capacity_smaller_than_rows_raises(rng):
    with pytest.raises(ValueError):
        Table.from_arrays({"a": np.zeros(10, np.int32)}, capacity=4)


def test_mismatched_columns_raise():
    with pytest.raises(ValueError):
        Table.from_arrays({"a": np.zeros(3, np.int32),
                           "b": np.zeros(4, np.int32)})


def test_select_rename_with_column(rng):
    t = Table.from_arrays({"a": np.arange(4, dtype=np.int32),
                           "b": np.ones(4, np.float32)})
    assert t.select(["a"]).column_names == ("a",)
    assert "c" in t.rename({"b": "c"}).column_names
    t2 = t.with_column("d", jnp.zeros(4, jnp.float32))
    assert "d" in t2.column_names


def test_vector_columns_roundtrip(rng):
    payload = rng.integers(0, 100, (6, 8)).astype(np.int32)
    t = Table.from_arrays({"id": np.arange(6, dtype=np.int32),
                           "tok": payload}, capacity=8)
    out = t.to_numpy()
    np.testing.assert_array_equal(out["tok"], payload)


def test_concat_tables(rng):
    a = Table.from_arrays({"x": np.arange(3, dtype=np.int32)}, capacity=8)
    b = Table.from_arrays({"x": np.arange(10, 15, dtype=np.int32)},
                          capacity=8)
    c = concat_tables([a, b], capacity=16)
    np.testing.assert_array_equal(
        np.sort(c.to_numpy()["x"]), np.sort(np.concatenate(
            [np.arange(3), np.arange(10, 15)])).astype(np.int32))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(-1000, 1000), min_size=1, max_size=50),
       st.integers(0, 30))
def test_sort_local_matches_numpy(values, extra_cap):
    arr = np.asarray(values, np.int32)
    t = Table.from_arrays({"k": arr}, capacity=len(arr) + extra_cap)
    out = sort_local(t, ["k"]).to_numpy()["k"]
    np.testing.assert_array_equal(out, np.sort(arr, kind="stable"))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=1, max_size=60),
       st.integers(0, 49))
def test_filter_rows_property(values, threshold):
    arr = np.asarray(values, np.int32)
    t = Table.from_arrays({"k": arr}, capacity=len(arr) + 5)
    out = filter_rows(t, lambda tt: tt.col("k") > threshold).to_numpy()["k"]
    np.testing.assert_array_equal(np.sort(out),
                                  np.sort(arr[arr > threshold]))

"""Tests for the lazy DataFrame frontend (``repro.df``).

* frontend ops vs a pandas oracle (1 device; the distributed machinery
  degenerates to identity routing but the full planner/executor runs),
* session/env resolution semantics,
* Fig-9 pipeline bit-identity: frontend vs the raw ``Plan`` builder, in
  all three execution modes and under out-of-core morsel streaming,
* hypothesis property test: random expression trees through ``DataFrame``
  vs pandas (skipped when hypothesis is absent; CI installs it).
"""

import numpy as np
import pytest

pd = pytest.importorskip("pandas")

import repro.df as rdf  # noqa: E402
from repro.core import (CylonEnv, DistTable, Plan, SpillTable,  # noqa: E402
                        execute)
from repro.df.session import _stack  # noqa: E402  (per-thread)
from repro.expr import col, lit  # noqa: E402


@pytest.fixture
def env():
    e = CylonEnv()
    rdf.set_default_env(e)
    yield e
    rdf.reset_default_env()


def _data(rng, n=256, keys=32):
    return {"k": rng.integers(0, keys, n).astype(np.int32),
            "v0": rng.integers(0, 64, n).astype(np.float32),
            "junk": rng.random(n).astype(np.float32)}


def _sorted_records(d, keys):
    order = np.lexsort(tuple(np.asarray(d[k]) for k in reversed(keys)))
    return {k: np.asarray(v)[order] for k, v in d.items()}


# ---------------------------------------------------------------------- #
# Frontend ops vs pandas
# ---------------------------------------------------------------------- #
def test_filter_assign_select_vs_pandas(env, rng):
    data = _data(rng)
    df = rdf.read_numpy(data)
    out = (df[df.v0 * 2 > 10]
           .assign(v1=df.v0 + 1, flag=df.k % 2)
           [["k", "v1", "flag"]]
           .to_pandas())
    p = pd.DataFrame(data)
    p = p[p.v0 * 2 > 10]
    want = pd.DataFrame({"k": p.k, "v1": p.v0 + 1, "flag": p.k % 2})
    np.testing.assert_array_equal(out["k"], want["k"])
    np.testing.assert_array_equal(out["v1"],
                                  want["v1"].astype(np.float32))
    np.testing.assert_array_equal(out["flag"], want["flag"])


def test_merge_groupby_sort_vs_pandas(env, rng):
    ld, rd = _data(rng), _data(rng, keys=32)
    rd = {"k": rd["k"], "w": rd["v0"]}
    out = (rdf.read_numpy(ld).merge(rdf.read_numpy(rd), on="k",
                                    out_capacity=16384)
           .groupby("k").agg({"v0": ["sum", "mean"], "w": "max"})
           .sort_values("k").to_pandas())
    m = pd.DataFrame(ld).merge(pd.DataFrame(rd), on="k")
    g = m.groupby("k").agg(v0_sum=("v0", "sum"), v0_mean=("v0", "mean"),
                           w_max=("w", "max")).reset_index().sort_values("k")
    np.testing.assert_array_equal(out["k"], g["k"].astype(np.int32))
    np.testing.assert_allclose(out["v0_sum"],
                               g["v0_sum"].astype(np.float32), rtol=1e-6)
    np.testing.assert_allclose(out["v0_mean"],
                               g["v0_mean"].astype(np.float32), rtol=1e-6)
    np.testing.assert_array_equal(out["w_max"],
                                  g["w_max"].astype(np.float32))


def test_from_pandas_round_trip(env, rng):
    pdf = pd.DataFrame({"k": np.arange(10, dtype=np.int32),
                        "v": np.linspace(0, 1, 10, dtype=np.float32)})
    out = rdf.from_pandas(pdf)[col("k") % 2 == 0].to_pandas()
    np.testing.assert_array_equal(out["k"], [0, 2, 4, 6, 8])
    # string / categorical columns dictionary-encode and decode back
    spdf = pd.DataFrame({"s": ["b", "a", "b"],
                         "c": pd.Categorical(["x", "y", "x"])})
    sout = rdf.from_pandas(spdf).to_pandas()
    np.testing.assert_array_equal(sout["s"], spdf["s"])
    np.testing.assert_array_equal(sout["c"], np.asarray(spdf["c"]))
    with pytest.raises(TypeError, match="unsupported dtype"):
        rdf.from_pandas(pd.DataFrame({"t": pd.to_datetime(["2023-01-01"])}))
    with pytest.raises(TypeError, match="mixes strings with"):
        rdf.from_pandas(pd.DataFrame({"s": ["a", 3]}))


def test_schema_validation_errors(env, rng):
    df = rdf.read_numpy(_data(rng))
    with pytest.raises(KeyError, match="unknown column"):
        df.filter(col("nope") > 0)
    with pytest.raises(KeyError, match="unknown column"):
        df[["k", "nope"]]
    with pytest.raises(AttributeError, match="no attribute or column"):
        df.nope
    with pytest.raises(KeyError, match="unknown column"):
        df.groupby("nope")
    # derived schemas track renames: after agg only k / v0_sum exist
    agg = df.groupby("k").agg(v0="sum")
    assert agg.columns == ("k", "v0_sum")
    with pytest.raises(KeyError):
        agg.sort_values("v0")


def test_dataframes_immutable_and_shareable(env, rng):
    df = rdf.read_numpy(_data(rng))
    with pytest.raises(AttributeError):
        df.plan = None
    base = df[df.v0 > 8]
    a = base.groupby("k").agg(v0="sum")
    b = base.sort_values("k")          # both extend the same prefix
    assert a.columns == ("k", "v0_sum")
    assert b.columns == df.columns


def test_repartition_then_groupby_elides_shuffle(env, rng):
    df = rdf.read_numpy(_data(rng))
    text = df.repartition("k").groupby("k").agg(v0="sum").explain()
    assert "shuffle-elision" in text


# ---------------------------------------------------------------------- #
# Session semantics
# ---------------------------------------------------------------------- #
def test_session_scopes_env(env, rng):
    inner = CylonEnv()
    assert rdf.get_env() is env
    with rdf.session(inner) as got:
        assert got is inner and rdf.get_env() is inner
        with rdf.session() as nested:      # builds a fresh env, nests
            assert rdf.get_env() is nested
        assert rdf.get_env() is inner
    assert rdf.get_env() is env
    assert not _stack()


def test_collect_uses_session_env(rng):
    rdf.reset_default_env()
    data = _data(rng, n=64)
    with rdf.session() as env:
        df = rdf.read_numpy(data)
        before = env.cache_misses
        df.filter(df.v0 > 8).collect()
        assert env.cache_misses == before + 1   # compiled on the session env
    rdf.reset_default_env()


def test_explicit_env_overrides_session(env, rng):
    other = CylonEnv()
    df = rdf.read_numpy(_data(rng, n=64), env=other)
    df.collect(env=other)
    assert other.cache_misses == 1 and env.cache_misses == 0


# ---------------------------------------------------------------------- #
# Fig-9: frontend vs raw Plan builder, bit-identical in every mode
# ---------------------------------------------------------------------- #
def _fig9_sources(rng, n=512):
    # integer-valued float payloads: sums are exact, so results must be
    # BIT-identical regardless of frontend, mode, or morsel split
    ld = {"k": rng.integers(0, int(n * 0.9), n).astype(np.int32),
          "v0": rng.integers(0, 256, n).astype(np.float32),
          "junk": rng.random(n).astype(np.float32)}
    rd = {"k": rng.integers(0, int(n * 0.9), n).astype(np.int32),
          "w": rng.integers(0, 256, n).astype(np.float32)}
    return ld, rd


def fig9_frontend(l_df, r_df, cap):
    return (l_df.merge(r_df, on="k", out_capacity=cap * 4)
            [(col("v0") > 4) & (col("w") < 250)]
            .groupby("k").agg({"v0": ["sum", "mean"]})
            .sort_values("k")
            .assign(v0_sum=col("v0_sum") + 1.0))


def fig9_builder(cap):
    return (Plan.scan("l").join(Plan.scan("r"), on="k", out_capacity=cap * 4)
            .filter((col("v0") > 4) & (col("w") < 250))
            .groupby(["k"], {"v0": ["sum", "mean"]})
            .sort(["k"])
            .with_columns({"v0_sum": col("v0_sum") + 1.0}))


def test_fig9_frontend_matches_builder_all_modes(env, rng):
    ld, rd = _fig9_sources(rng)
    lt = DistTable.from_numpy(ld, env.parallelism)
    rt = DistTable.from_numpy(rd, env.parallelism)
    l_df, r_df = rdf.from_table(lt), rdf.from_table(rt)
    front = fig9_frontend(l_df, r_df, lt.capacity)
    plan = fig9_builder(lt.capacity)
    assert "<lambda>" not in front.explain()
    for mode in ("bsp", "bsp_staged", "amt"):
        a = front.collect(mode=mode).to_numpy()
        b = execute(plan, env, {"l": lt, "r": rt}, mode=mode).to_numpy()
        assert sorted(a) == sorted(b)
        for c in a:
            np.testing.assert_array_equal(a[c], b[c], err_msg=(mode, c))


def test_fig9_frontend_out_of_core_bit_identical(env, rng):
    ld, rd = _fig9_sources(rng)
    lt = DistTable.from_numpy(ld, env.parallelism)
    rt = DistTable.from_numpy(rd, env.parallelism)
    ref = fig9_frontend(rdf.from_table(lt), rdf.from_table(rt),
                        lt.capacity).collect().to_numpy()
    l_spill = rdf.read_numpy(ld, spill=True, chunk_rows=64)
    out = fig9_frontend(l_spill, rdf.from_table(rt), lt.capacity).collect(
        morsel_rows=64, capacity_factor=4.0)
    assert isinstance(out, SpillTable)
    o = out.to_numpy()
    assert sorted(ref) == sorted(o)
    for c in ref:
        np.testing.assert_array_equal(ref[c], o[c], err_msg=c)


# ---------------------------------------------------------------------- #
# Hypothesis: random expression trees through DataFrame vs pandas.
# Guarded with a plain import (not importorskip) so everything above
# still runs without hypothesis; CI installs it via requirements-dev.txt.
# ---------------------------------------------------------------------- #
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal envs
    HAVE_HYPOTHESIS = False


def _np_eval(e, frame):
    """Numpy oracle: evaluate an Expr against a dict of numpy columns."""
    import repro.expr as ex
    if isinstance(e, ex.Col):
        return frame[e.name]
    if isinstance(e, ex.Lit):
        return e.value
    if isinstance(e, ex.UnaryOp):
        v = _np_eval(e.operand, frame)
        return {"-": np.negative, "abs": np.abs,
                "~": np.invert}[e.op](v)
    ops = {"+": np.add, "-": np.subtract, "*": np.multiply,
           ">": np.greater, ">=": np.greater_equal, "<": np.less,
           "<=": np.less_equal, "==": np.equal, "!=": np.not_equal,
           "&": np.bitwise_and, "|": np.bitwise_or, "^": np.bitwise_xor}
    return ops[e.op](_np_eval(e.left, frame), _np_eval(e.right, frame))


if HAVE_HYPOTHESIS:
    @st.composite
    def numeric_exprs(draw, depth=0):
        """Random arithmetic expression over int32 columns a/b (+ small
        int literals; ops closed over int32 so the oracle is exact)."""
        if depth >= 3 or draw(st.booleans()):
            return draw(st.sampled_from([col("a"), col("b")]))
        op = draw(st.sampled_from(["+", "-", "*"]))
        left = draw(numeric_exprs(depth=depth + 1))
        right = (lit(draw(st.integers(-4, 4))) if draw(st.booleans())
                 else draw(numeric_exprs(depth=depth + 1)))
        from repro.expr import BinOp
        return BinOp(op, left, right)

    @st.composite
    def bool_exprs(draw):
        cmp = draw(st.sampled_from([">", ">=", "<", "<=", "==", "!="]))
        from repro.expr import BinOp
        e = BinOp(cmp, draw(numeric_exprs()), draw(numeric_exprs()))
        if draw(st.booleans()):
            e2 = BinOp(draw(st.sampled_from([">", "<", "=="])),
                       draw(numeric_exprs()), lit(draw(st.integers(-8, 8))))
            e = BinOp(draw(st.sampled_from(["&", "|", "^"])), e, e2)
        if draw(st.booleans()):
            e = ~e
        return e

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(pred=bool_exprs(), assign=numeric_exprs(),
           rows=st.lists(st.tuples(st.integers(-20, 20),
                                   st.integers(-20, 20)),
                         min_size=0, max_size=40))
    def test_random_expr_trees_match_pandas(pred, assign, rows):
        env = CylonEnv()
        a = np.array([r[0] for r in rows], np.int32)
        b = np.array([r[1] for r in rows], np.int32)
        data = {"a": a, "b": b}
        df = rdf.read_numpy(data, env=env, capacity=64)
        got = df.filter(pred).assign(z=assign).collect(env=env).to_numpy()

        mask = np.asarray(_np_eval(pred, data), bool) if len(a) else \
            np.zeros((0,), bool)
        want = {"a": a[mask], "b": b[mask]}
        want["z"] = np.asarray(_np_eval(assign, want)).astype(np.int32) \
            if mask.any() else np.zeros((mask.sum(),), np.int32)
        assert sorted(got) == ["a", "b", "z"]
        np.testing.assert_array_equal(got["a"], want["a"])
        np.testing.assert_array_equal(got["b"], want["b"])
        if mask.any():
            np.testing.assert_array_equal(got["z"], want["z"])


def test_merge_rejects_source_name_collision(env, rng):
    d1, d2 = _data(rng, n=32), _data(rng, n=32)
    a = rdf.read_numpy(d1, name="t")
    b = rdf.read_numpy(d2, name="t")      # different table, same scan name
    with pytest.raises(ValueError, match="source name collision"):
        a.merge(b, on="k")
    # same object under the same name is fine (self-merge)
    self_joined = a.merge(a.assign(v1=a.v0 + 1), on="k",
                          out_capacity=4096)
    assert "v0_r" in self_joined.columns


def test_read_numpy_rejects_capacity_with_spill(env, rng):
    with pytest.raises(TypeError, match="capacity only applies"):
        rdf.read_numpy(_data(rng, n=32), spill=True, capacity=64)


def test_session_stack_is_thread_local(env):
    import threading
    inner = CylonEnv()
    seen = {}

    def other_thread():
        # a session entered on the main thread must not leak here
        seen["env"] = rdf.get_env()

    with rdf.session(inner):
        t = threading.Thread(target=other_thread)
        t.start()
        t.join()
    assert seen["env"] is env            # default, not main thread's inner


def test_ingest_env_pins_collect_and_mismatch_is_clear(env, rng):
    # read_numpy(env=X) pins collect() to X even when another env is the
    # session default...
    other = CylonEnv()
    df = rdf.read_numpy(_data(rng, n=64), env=other)
    df.filter(df.v0 > 8).collect()
    assert other.cache_misses == 1 and env.cache_misses == 0
    # ...and a frame whose table is partitioned for a different gang size
    # fails with a clear message, not a shard_map divisibility error
    bad = rdf.from_table(DistTable.from_numpy(_data(rng, n=64), 2))
    with pytest.raises(ValueError, match="partitioned for 2 ranks"):
        bad.collect()        # session env has 1 device


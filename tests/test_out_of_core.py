"""Out-of-core morsel execution: spill tables, morsel streaming, combiners,
compile-cache invariants, overflow accounting, and store/repartition fixes.

Unit scope (1 CPU device): the distributed shuffle degenerates to identity
routing but the whole morsel machinery — segmenting, host spill, partial
aggregation + combine, sorted-run merge, resident join builds, stats — runs
for real.  8-device coverage lives in ``tests/md_scripts/out_of_core_parity.py``.
"""

import numpy as np
import pytest

from repro.core import (CylonEnv, CylonStore, DistTable, MorselSource, Plan,
                        SpillTable, execute, repartition, rescatter)
from repro.dataframe.ops_local import hash_columns, hash_columns_np
from repro.expr import col
from repro.dataframe.table import Table


# shared generators (tests/strategies.py): exact_table keeps float sums
# exact so morsel re-aggregation order cannot perturb bits
from strategies import exact_table as _exact_data  # noqa: E402
from strategies import one_key_table, zipf_table  # noqa: E402


# ---------------------------------------------------------------------- #
# SpillTable
# ---------------------------------------------------------------------- #
def test_spill_roundtrip_and_chunking(rng):
    data = {"k": rng.integers(0, 9, 100).astype(np.int32),
            "v": rng.random(100).astype(np.float32)}
    sp = SpillTable.from_numpy(data, 4, chunk_rows=8)
    assert sp.total_rows() == 100
    assert sp.rank_rows(0) == 25 and sp.rank_rows(3) == 25
    assert len(sp.rank_chunks(0)) == 4          # 25 rows in 8-row chunks
    out = sp.to_numpy()
    np.testing.assert_array_equal(out["k"], data["k"])
    np.testing.assert_array_equal(out["v"], data["v"])
    assert sp.nbytes() == 100 * 8


def test_spill_schema_survives_empty_ranks():
    sp = SpillTable.from_numpy({"k": np.arange(3, dtype=np.int32)}, 4)
    assert sp.rank_rows(3) == 0
    assert sp.column_names == ("k",)
    empty = sp.rank_concat(3)
    assert empty["k"].dtype == np.int32 and len(empty["k"]) == 0


def test_spill_rejects_mismatched_chunks():
    sp = SpillTable(2)
    sp.append(0, {"k": np.arange(4, dtype=np.int32)})
    with pytest.raises(ValueError):
        sp.append(1, {"k": np.arange(4, dtype=np.float32)})
    with pytest.raises(ValueError):
        sp.append(1, {"x": np.arange(4, dtype=np.int32)})


def test_spill_from_dist_keeps_rank_placement(rng):
    data = _exact_data(rng, 64)
    t = DistTable.from_numpy(data, 2)
    sp = SpillTable.from_dist(t)
    assert sp.parallelism == 2
    assert sp.rank_rows(0) == 32 and sp.rank_rows(1) == 32
    np.testing.assert_array_equal(sp.to_numpy()["k"], data["k"])


# ---------------------------------------------------------------------- #
# MorselSource
# ---------------------------------------------------------------------- #
def test_morsel_source_streams_fixed_capacity(rng):
    data = _exact_data(rng, 100)
    src = MorselSource(SpillTable.from_numpy(data, 2), morsel_rows=16)
    morsels = list(src)
    assert len(morsels) == src.num_morsels == 4   # 50 rows/rank @ 16/morsel
    assert all(m.capacity == 16 for m in morsels)
    assert src.h2d_bytes > 0
    got = np.concatenate([np.asarray(m.row_counts) for m in morsels])
    assert got.sum() == 100
    # streamed rows reassemble to the original per-rank blocks
    back = {r: [] for r in range(2)}
    for m in morsels:
        cols = np.asarray(m.columns["k"]).reshape(2, m.capacity)
        counts = np.asarray(m.row_counts)
        for r in range(2):
            back[r].append(cols[r, :counts[r]])
    full = np.concatenate([np.concatenate(back[0]), np.concatenate(back[1])])
    np.testing.assert_array_equal(full, data["k"])


def test_morsel_source_empty_input_yields_one_empty_morsel():
    sp = SpillTable.from_numpy({"k": np.zeros(0, np.int32)}, 2)
    morsels = list(MorselSource(sp, morsel_rows=8))
    assert len(morsels) == 1
    assert int(np.asarray(morsels[0].row_counts).sum()) == 0


# ---------------------------------------------------------------------- #
# Morsel execution vs in-core (1 device)
# ---------------------------------------------------------------------- #
def test_morsel_local_plan_bit_identical(rng):
    env = CylonEnv()
    data = {"k": rng.integers(0, 50, 500).astype(np.int32),
            "v0": rng.random(500).astype(np.float32)}
    plan = (Plan.scan("l").filter(col("v0") > 0.25)
            .add_scalar(2.0, cols=["v0"]))
    ref = execute(plan, env, {"l": DistTable.from_numpy(data, 1)}).to_numpy()
    out = execute(plan, env, {"l": data}, morsel_rows=64)
    assert isinstance(out, SpillTable)
    o = out.to_numpy()
    for c in ref:
        np.testing.assert_array_equal(ref[c], o[c])


def test_morsel_pipeline_bit_identical(rng):
    env = CylonEnv()
    ld = _exact_data(rng, 600)
    rd = {"k": rng.integers(0, 50, 400).astype(np.int32),
          "w": rng.integers(0, 100, 400).astype(np.float32)}
    plan = (Plan.scan("l").join(Plan.scan("r"), on="k", out_capacity=16384)
            .groupby(["k"], {"v0": ["sum", "mean"]})
            .sort(["k"]).add_scalar(1.0, cols=["v0_sum"]))
    lt, rt = DistTable.from_numpy(ld, 1), DistTable.from_numpy(rd, 1)
    for opt in (False, True):
        ref, rst = execute(plan, env, {"l": lt, "r": rt}, optimize=opt,
                           collect_stats=True)
        assert rst.rows_dropped == 0
        out, st = execute(plan, env, {"l": ld, "r": rd}, optimize=opt,
                          collect_stats=True, morsel_rows=64,
                          capacity_factor=16.0)
        assert st.rows_dropped == 0
        assert st.morsels >= 600 // 64
        assert st.spill_bytes > 0 and st.h2d_bytes > 0 and st.d2h_bytes > 0
        assert st.morsel_rows == 64
        ref_np, o = ref.to_numpy(), out.to_numpy()
        for c in ref_np:
            np.testing.assert_array_equal(ref_np[c], o[c])


def test_morsel_groupby_only_matches(rng):
    env = CylonEnv()
    data = _exact_data(rng, 333, keys=40)
    plan = Plan.scan("l").groupby(["k"], {"v0": ["sum", "min", "max"]})
    ref = execute(plan, env, {"l": DistTable.from_numpy(data, 1)},
                  optimize=False).to_numpy()
    out = execute(plan, env, {"l": data}, optimize=False,
                  morsel_rows=32).to_numpy()
    # combine emits sub-buckets, so rank-local order differs: compare keyed
    ro, oo = np.argsort(ref["k"]), np.argsort(out["k"])
    for c in ref:
        np.testing.assert_array_equal(ref[c][ro], out[c][oo])


def test_morsel_adversarial_keys_bit_identical(rng):
    # Zipf(1.5) and 99%-one-key tables (tests/strategies) through the
    # morsel path: adversarial key mass must not perturb results or drop
    # rows even on the 1-device harness (salting is a no-op at p=1, so
    # this pins the degenerate-gang behavior of the adaptive layer too)
    env = CylonEnv()
    for data in (zipf_table(rng, 500), one_key_table(rng, 500)):
        data = {"k": data["k"], "v0": data["v"]}
        plan = Plan.scan("l").groupby(["k"], {"v0": ["sum", "count"]})
        ref = execute(plan, env, {"l": DistTable.from_numpy(data, 1)},
                      optimize=False).to_numpy()
        out, st = execute(plan, env, {"l": data}, optimize=False,
                          morsel_rows=64, collect_stats=True)
        assert st.rows_dropped == 0
        o = out.to_numpy()
        ro, oo = np.argsort(ref["k"]), np.argsort(o["k"])
        for c in ref:
            np.testing.assert_array_equal(ref[c][ro], o[c][oo])


def test_morsel_respills_mismatched_parallelism(rng):
    # a spill bucketed for 4 ranks streamed on a 1-device env must keep
    # every row (re-bucketed host-side), not just rank 0's share
    env = CylonEnv()
    data = _exact_data(rng, 32)
    sp = SpillTable.from_numpy(data, 4)
    plan = Plan.scan("l").add_scalar(0.0, cols=["v0"])
    out = execute(plan, env, {"l": sp}, morsel_rows=8)
    assert out.total_rows() == 32
    np.testing.assert_array_equal(out.to_numpy()["k"], data["k"])


def test_morsel_warns_on_capacity_pressure(rng):
    # an exploding all-equal-key join overflows the per-morsel working
    # capacity; under overflow="warn" the loss must be loud even without
    # collect_stats (the default "degrade" policy instead recovers —
    # see test_morsel_degrade_recovers_every_row)
    env = CylonEnv()
    ld = {"k": np.zeros(64, np.int32), "v0": np.ones(64, np.float32)}
    rd = {"k": np.zeros(64, np.int32), "w": np.ones(64, np.float32)}
    plan = Plan.scan("l").join(Plan.scan("r"), on="k")
    with pytest.warns(RuntimeWarning, match="out-of-core execution dropped"):
        execute(plan, env, {"l": ld, "r": rd}, optimize=False,
                morsel_rows=16, overflow="warn")
    with pytest.warns(RuntimeWarning):
        _, st = execute(plan, env, {"l": ld, "r": rd}, optimize=False,
                        morsel_rows=16, collect_stats=True, overflow="warn")
    assert st.rows_dropped > 0


def test_morsel_degrade_recovers_every_row(rng):
    # the default policy: the same exploding join re-executes with halved
    # morsels / grown working capacity until every row fits — zero drops,
    # result identical to an amply-capacitated run
    env = CylonEnv()
    ld = {"k": np.zeros(64, np.int32),
          "v0": np.arange(64, dtype=np.float32)}
    rd = {"k": np.zeros(8, np.int32), "w": np.arange(8, dtype=np.float32)}
    plan = Plan.scan("l").join(Plan.scan("r"), on="k")
    sp, st = execute(plan, env, {"l": ld, "r": rd}, optimize=False,
                     morsel_rows=16, collect_stats=True)
    assert st.rows_dropped == 0
    assert st.degraded > 0
    out = sp.to_numpy()
    assert len(out["k"]) == 64 * 8
    order = np.lexsort((out["w"], out["v0"]))
    ref_v = np.repeat(np.arange(64, dtype=np.float32), 8)
    ref_w = np.tile(np.arange(8, dtype=np.float32), 64)
    np.testing.assert_array_equal(out["v0"][order], ref_v)
    np.testing.assert_array_equal(out["w"][order], ref_w)


def test_morsel_overflow_raise_policy(rng):
    from repro.faults import CapacityOverflow
    env = CylonEnv()
    ld = {"k": np.zeros(64, np.int32), "v0": np.ones(64, np.float32)}
    rd = {"k": np.zeros(64, np.int32), "w": np.ones(64, np.float32)}
    plan = Plan.scan("l").join(Plan.scan("r"), on="k")
    with pytest.raises(CapacityOverflow, match="dropped"):
        execute(plan, env, {"l": ld, "r": rd}, optimize=False,
                morsel_rows=16, overflow="raise")


def test_morsel_rejects_amt_and_dest_shuffle(rng):
    env = CylonEnv()
    data = _exact_data(rng, 64)
    plan = Plan.scan("l").shuffle(["k"])
    with pytest.raises(ValueError, match="allgather baseline"):
        execute(plan, env, {"l": data}, mode="amt", morsel_rows=16)
    bad = Plan.scan("l").shuffle(["k"], dest=np.zeros(64, np.int32))
    with pytest.raises(ValueError, match="cannot stream"):
        execute(bad, env, {"l": data}, optimize=False, morsel_rows=16)


# ---------------------------------------------------------------------- #
# Compile-cache regression: 8 morsels -> exactly 1 cache miss
# ---------------------------------------------------------------------- #
def test_eight_morsels_one_cache_miss(rng):
    env = CylonEnv()
    data = {"k": rng.integers(0, 9, 8 * 32).astype(np.int32),
            "v0": rng.random(8 * 32).astype(np.float32)}
    plan = (Plan.scan("l").filter(col("k") >= 0)
            .add_scalar(1.0, cols=["v0"]))
    h0, m0 = env.cache_hits, env.cache_misses
    out, st = execute(plan, env, {"l": data}, morsel_rows=32,
                      collect_stats=True)
    assert st.morsels == 8
    # the per-morsel zero-recompile invariant: ONE program built, 7 reuses
    assert env.cache_misses - m0 == 1 == st.cache_misses
    assert env.cache_hits - h0 == 7 == st.cache_hits
    # a second execution of the same plan compiles nothing at all
    _, st2 = execute(plan, env, {"l": data}, morsel_rows=32,
                     collect_stats=True)
    assert st2.cache_misses == 0 and st2.cache_hits == 8


# ---------------------------------------------------------------------- #
# Overflow safety: rows_dropped is deterministic and debug_overflow fires
# ---------------------------------------------------------------------- #
def test_rows_dropped_zero_for_capacitated_run(rng):
    env = CylonEnv()
    data = _exact_data(rng, 128)
    plan = Plan.scan("l").shuffle(["k"]).groupby(["k"], {"v0": ["sum"]})
    _, st = execute(plan, env, {"l": DistTable.from_numpy(data, 1)},
                    optimize=False, collect_stats=True)
    assert st.rows_dropped == 0


def test_rows_dropped_counts_shuffle_overflow(rng):
    env = CylonEnv()
    data = _exact_data(rng, 128)
    t = DistTable.from_numpy(data, 1)
    plan = Plan.scan("l").shuffle(["k"], out_capacity=32)
    with pytest.warns(RuntimeWarning, match="capacity pressure"):
        _, st = execute(plan, env, {"l": t}, optimize=False,
                        collect_stats=True, overflow="warn")
    assert st.rows_dropped == 128 - 32    # deterministic, post-hoc


def test_rows_dropped_counts_join_overflow(rng):
    env = CylonEnv()
    ld = {"k": np.zeros(32, np.int32), "v0": np.arange(32, dtype=np.float32)}
    rd = {"k": np.zeros(32, np.int32), "w": np.arange(32, dtype=np.float32)}
    plan = Plan.scan("l").join(Plan.scan("r"), on="k", out_capacity=64)
    with pytest.warns(RuntimeWarning, match="capacity pressure"):
        _, st = execute(plan, env, {"l": DistTable.from_numpy(ld, 1),
                                    "r": DistTable.from_numpy(rd, 1)},
                        optimize=False, collect_stats=True, overflow="warn")
    assert st.rows_dropped == 32 * 32 - 64


def test_in_core_degrade_recovers_join_overflow(rng):
    # default policy on the same under-capacitated join: the in-core run
    # detects the drop and replays the plan out-of-core, re-scattering the
    # complete result back to a DistTable — no rows lost
    env = CylonEnv()
    ld = {"k": np.zeros(32, np.int32), "v0": np.arange(32, dtype=np.float32)}
    rd = {"k": np.zeros(32, np.int32), "w": np.arange(32, dtype=np.float32)}
    plan = Plan.scan("l").join(Plan.scan("r"), on="k", out_capacity=64)
    out, st = execute(plan, env, {"l": DistTable.from_numpy(ld, 1),
                                  "r": DistTable.from_numpy(rd, 1)},
                      optimize=False, collect_stats=True)
    assert st.rows_dropped == 0
    assert st.degraded > 0
    assert isinstance(out, DistTable)
    assert out.total_rows() == 32 * 32


def test_debug_overflow_warns_on_drop(rng):
    env = CylonEnv()
    data = _exact_data(rng, 128)
    t = DistTable.from_numpy(data, 1)
    plan = Plan.scan("l").shuffle(["k"], out_capacity=32, debug_overflow=True)
    with pytest.warns(RuntimeWarning, match=r"shuffle\(k\) @ rank 0 dropped"):
        out = execute(plan, env, {"l": t}, optimize=False)
        np.asarray(out.row_counts)        # force execution + callback
    ok = Plan.scan("l").shuffle(["k"], debug_overflow=True)
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")    # no drop -> no warning
        out = execute(ok, env, {"l": t}, optimize=False)
        np.asarray(out.row_counts)


# ---------------------------------------------------------------------- #
# CylonStore / repartition fixes
# ---------------------------------------------------------------------- #
def test_repartition_explicit_zero_capacity_not_ignored(rng):
    t = DistTable.from_numpy(_exact_data(rng, 10), 2)
    with pytest.raises(ValueError, match="exceeds capacity"):
        repartition(t, 2, capacity=0)
    with pytest.raises(ValueError):
        DistTable.from_numpy(_exact_data(rng, 10), 2, capacity=0)


def test_repartition_preserves_dtypes_and_values(rng):
    data = {"i": rng.integers(-5, 5, 37).astype(np.int32),
            "u": rng.integers(0, 9, 37).astype(np.uint32),
            "f": rng.integers(0, 100, 37).astype(np.float32)}
    t = DistTable.from_numpy(data, 3)
    out = repartition(t, 5)
    assert out.parallelism == 5
    o = out.to_numpy()
    for c in data:
        assert o[c].dtype == data[c].dtype
        np.testing.assert_array_equal(o[c], data[c])


def test_repartition_empty_table_preserves_columns():
    t = DistTable.from_numpy({"k": np.zeros(0, np.int32),
                              "v": np.zeros(0, np.float32)}, 2)
    out = repartition(t, 3)
    assert out.parallelism == 3
    assert out.column_names == ("k", "v")
    assert out.total_rows() == 0
    assert out.columns["v"].dtype == np.float32


def test_store_get_repartitions_on_capacity_change(rng):
    store = CylonStore()
    t = DistTable.from_numpy(_exact_data(rng, 32), 2)
    store.put("t", t)
    assert store.get("t") is t
    assert store.get("t", target_parallelism=2) is t
    out = store.get("t", capacity=64)      # same gang, new capacity
    assert out.capacity == 64
    np.testing.assert_array_equal(out.to_numpy()["k"], t.to_numpy()["k"])
    out2 = store.get("t", target_parallelism=4)
    assert out2.parallelism == 4


def test_store_accepts_spill_tables(rng):
    store = CylonStore()
    data = _exact_data(rng, 48)
    store.put("sp", SpillTable.from_numpy(data, 4))
    got = store.get("sp", target_parallelism=2)
    assert isinstance(got, DistTable) and got.parallelism == 2
    np.testing.assert_array_equal(got.to_numpy()["k"], data["k"])


def test_rescatter_bucketed_matches_gather(rng):
    data = _exact_data(rng, 77)
    sp = SpillTable.from_numpy(data, 3, chunk_rows=10)
    out = rescatter(sp, 4)
    np.testing.assert_array_equal(out.to_numpy()["k"], data["k"])
    np.testing.assert_array_equal(out.to_numpy()["v0"], data["v0"])


# ---------------------------------------------------------------------- #
# Driver-side hash mirror (spill sub-bucketing)
# ---------------------------------------------------------------------- #
def test_hash_columns_np_matches_device_hash(rng):
    cols = {"k": rng.integers(-1000, 1000, 256).astype(np.int32),
            "f": rng.random(256).astype(np.float32),
            "u": rng.integers(0, 2**31, 256).astype(np.uint32)}
    t = Table({k: np.asarray(v) for k, v in cols.items()},
              np.int32(256))
    for keys in (["k"], ["k", "f"], ["u", "k", "f"]):
        dev = np.asarray(hash_columns(t, keys))
        host = hash_columns_np(cols, keys)
        np.testing.assert_array_equal(dev, host)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""8-device proof that the sort-free (radix) shuffle is bit-identical to the
PR-1 sorted implementation — same rows in the same slots on every rank —
with zero dropped rows at the default capacity factor, across all three
communicators and chunked vs monolithic all-to-all.  Also checks the
end-to-end Fig-9 pipeline under radix == sorted, and that ExecStats records
the shuffle_impl / a2a_chunks knobs."""

import numpy as np
import jax

from repro.core import CylonEnv, DistTable, Plan, execute
from repro.dataframe import shuffle

rng = np.random.default_rng(3)
N = 4000
data = {"k": rng.integers(0, 500, N).astype(np.int32),
        "v": rng.random(N).astype(np.float32)}


def run_shuffle(env, dt, **kw):
    def prog(ctx, t):
        out, stats = shuffle(t, ctx.comm, key_cols=["k"], **kw)
        return out, stats
    return env.run(prog, dt, key=("sortfree_parity",) + tuple(sorted(kw.items())))


for comm_name in ("xla", "ring", "bruck"):
    env = CylonEnv(communicator=comm_name)
    p = env.parallelism
    assert p == 8
    dt = DistTable.from_numpy(data, p, capacity=1024)

    # default capacity factor (2.0): no drops, and sorted == radix bitwise
    ref, rstats = run_shuffle(env, dt, impl="sorted")
    for chunks in (1, 4):
        got, gstats = run_shuffle(env, dt, impl="radix", a2a_chunks=chunks)
        assert gstats.shuffle_impl == "radix" and gstats.a2a_chunks == chunks
        assert int(np.asarray(gstats.send_dropped).sum()) == 0
        assert int(np.asarray(gstats.recv_dropped).sum()) == 0
        assert np.array_equal(np.asarray(ref.row_counts),
                              np.asarray(got.row_counts))
        for c in ref.column_names:   # full buffers: slot-level identity
            assert np.array_equal(np.asarray(ref.columns[c]),
                                  np.asarray(got.columns[c])), (comm_name, c)
        assert np.array_equal(np.asarray(rstats.sent_counts),
                              np.asarray(gstats.sent_counts))
    # multiset sanity vs the input
    out = got.to_numpy()
    assert np.array_equal(np.sort(out["k"]), np.sort(data["k"]))
    print(f"{comm_name}: sorted == radix (chunks 1,4), zero drops")

# --- end-to-end: Fig-9 pipeline, radix == sorted, stats record the knobs -- #
env = CylonEnv()
p = env.parallelism
ld = {"k": rng.integers(0, 500, N).astype(np.int32),
      "v0": rng.random(N).astype(np.float32)}
rd = {"k": rng.integers(0, 500, N).astype(np.int32),
      "w": rng.random(N).astype(np.float32)}
lt = DistTable.from_numpy(ld, p, capacity=1024)
rt = DistTable.from_numpy(rd, p, capacity=1024)
tables = {"l": lt, "r": rt}
fig9 = (Plan.scan("l")
        .join(Plan.scan("r"), on="k", out_capacity=16 * 1024,
              bucket_capacity=2 * 1024)
        .groupby(["k"], {"v0": ["sum", "mean"]}, bucket_capacity=16 * 1024)
        .sort(["k"])
        .add_scalar(1.0, cols=["v0_sum"]))

base, bstats = execute(fig9, env, tables, shuffle_impl="sorted",
                       collect_stats=True)
assert bstats.shuffle_impl == "sorted" and bstats.a2a_chunks == 1
a = base.to_numpy()
for impl, chunks in (("radix", 1), ("radix", 4)):
    got, gstats = execute(fig9, env, tables, shuffle_impl=impl,
                          a2a_chunks=chunks, collect_stats=True)
    assert (gstats.shuffle_impl, gstats.a2a_chunks) == (impl, chunks)
    assert gstats.rows_shuffled == bstats.rows_shuffled
    assert gstats.bytes_shuffled == bstats.bytes_shuffled
    b = got.to_numpy()
    assert sorted(a) == sorted(b)
    for c in a:
        assert np.array_equal(a[c], b[c]), (impl, chunks, c)
print(f"fig9: radix (c1,c4) bit-identical to sorted; "
      f"rows={bstats.rows_shuffled} bytes={bstats.bytes_shuffled}")

print("sortfree_shuffle_parity OK")

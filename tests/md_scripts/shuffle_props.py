import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Shuffle invariants on 8 ranks: row multiset preserved (no drops case),
dropped counted exactly (tight-capacity case), stats consistency, MoE
dispatch parity, repartition balance, CylonStore repartition."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import CylonEnv, CylonStore, DistTable
from repro.core.store import repartition
from repro.dataframe import repartition_balanced, shuffle

rng = np.random.default_rng(1)
env = CylonEnv()
p = env.parallelism
N = 2000
data = {"k": rng.integers(0, 97, N).astype(np.int32),
        "v": rng.random(N).astype(np.float32)}
dt = DistTable.from_numpy(data, p, capacity=1024)

# --- multiset preservation with ample capacity ------------------------- #
def do_shuffle(ctx, t):
    out, stats = shuffle(t, ctx.comm, key_cols=["k"], bucket_capacity=1024)
    return out, stats

out, stats = env.run(do_shuffle, dt)
res = out.to_numpy()
assert len(res["k"]) == N
# same multiset of (k, v) pairs
a = np.sort(np.stack([data["k"].astype(np.float64), data["v"]], 1), axis=0)
b = np.sort(np.stack([res["k"].astype(np.float64), res["v"]], 1), axis=0)
np.testing.assert_allclose(a, b, rtol=1e-6)
# co-location: every key's rows on one rank
counts = np.asarray(stats.recv_counts)  # (p, p)
assert counts.sum() == N
assert int(np.asarray(stats.send_dropped).sum()) == 0

# sent/recv consistency: what rank i sent to j is what j received from i
sent = np.asarray(stats.sent_counts)
assert (sent == counts.T).all()
assert sent.sum() == N

# --- tight capacity: drops counted ------------------------------------- #
def tight(ctx, t):
    out, stats = shuffle(t, ctx.comm, key_cols=["k"], bucket_capacity=8)
    return out, stats

out2, stats2 = env.run(tight, dt)
dropped = int(np.asarray(stats2.send_dropped).sum())
kept = len(out2.to_numpy()["k"])
assert kept + dropped == N, (kept, dropped)
assert dropped > 0  # 2000 rows into p*p*8 bucket slots must overflow

# --- sample-based repartition balance (paper §VI) ----------------------- #
skew = {"k": (rng.zipf(1.5, N) % 1000).astype(np.int32),
        "v": rng.random(N).astype(np.float32)}
sk = DistTable.from_numpy(skew, p, capacity=2048)

def balance(ctx, t):
    out, _ = repartition_balanced(t, ctx.comm, key_col="k",
                                  bucket_capacity=2048)
    return out

bal = env.run(balance, sk)
per_rank = np.asarray(bal.row_counts)
assert per_rank.sum() == N
assert per_rank.max() <= 3.0 * N / p, per_rank  # skew bounded

# --- CylonStore cross-parallelism hand-off ------------------------------ #
store = CylonStore()
store.put("t", dt)
got = store.get("t", target_parallelism=4)
assert got.parallelism == 4
np.testing.assert_allclose(np.sort(got.to_numpy()["v"]),
                           np.sort(data["v"]), rtol=1e-6)

print("shuffle_props OK")

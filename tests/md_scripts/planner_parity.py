import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Planner runtime verification on 8 devices:

1. Fig-9 pipeline: optimized bsp execution is BIT-IDENTICAL to unoptimized,
   with fewer shuffles / rows / bytes on the wire (ShuffleStats-derived).
2. shuffle(k) -> groupby(k): the elided shuffle halves rows shuffled.
3. Randomized pipelines: optimized == unoptimized across all three
   execution modes (sorted-column comparison, all DistTable results).
"""

import numpy as np

from repro.core import CylonEnv, DistTable, Plan, execute
from repro.expr import col

rng = np.random.default_rng(0)
N = 4000
CAP = 1024
ld = {"k": rng.integers(0, 500, N).astype(np.int32),
      "v0": rng.random(N).astype(np.float32),
      "junk": rng.random(N).astype(np.float32)}
rd = {"k": rng.integers(0, 500, N).astype(np.int32),
      "w": rng.random(N).astype(np.float32)}

env = CylonEnv()
p = env.parallelism
assert p == 8
lt = DistTable.from_numpy(ld, p, capacity=CAP)
rt = DistTable.from_numpy(rd, p, capacity=CAP)
TABLES = {"l": lt, "r": rt}

# ample capacities: the unoptimized baseline re-shuffles already-partitioned
# data, which lands every row in one self-destination bucket
BIG = 16 * CAP

# --- 1. Fig-9: bit-identical + strictly less communication --------------- #
fig9 = (Plan.scan("l")
        .join(Plan.scan("r"), on="k", out_capacity=BIG, bucket_capacity=2 * CAP)
        .groupby(["k"], {"v0": ["sum", "mean"]}, bucket_capacity=BIG)
        .sort(["k"])
        .add_scalar(1.0, cols=["v0_sum"]))

ref, rs = execute(fig9, env, TABLES, mode="bsp", optimize=False,
                  collect_stats=True)
opt, os_ = execute(fig9, env, TABLES, mode="bsp", optimize=True,
                   collect_stats=True)
a, b = ref.to_numpy(), opt.to_numpy()
assert sorted(a) == sorted(b)
for c in a:
    assert np.array_equal(a[c], b[c]), c         # bit-identical
assert os_.num_shuffles < rs.num_shuffles, (os_.num_shuffles, rs.num_shuffles)
assert os_.num_stages < rs.num_stages
assert os_.rows_shuffled < rs.rows_shuffled
assert os_.bytes_shuffled < rs.bytes_shuffled
print(f"fig9: shuffles {rs.num_shuffles}->{os_.num_shuffles}, "
      f"stages {rs.num_stages}->{os_.num_stages}, "
      f"rows {rs.rows_shuffled}->{os_.rows_shuffled}, "
      f"bytes {rs.bytes_shuffled}->{os_.bytes_shuffled}")

# --- 2. shuffle(k) -> groupby(k): one shuffle elided --------------------- #
sg = Plan.scan("l").shuffle(["k"]).groupby(["k"], {"v0": ["sum"]},
                                           bucket_capacity=8 * CAP)
ref2, rs2 = execute(sg, env, TABLES, optimize=False, collect_stats=True)
opt2, os2 = execute(sg, env, TABLES, optimize=True, collect_stats=True)
assert (rs2.num_shuffles, os2.num_shuffles) == (2, 1)
assert os2.rows_shuffled == N and rs2.rows_shuffled == 2 * N
x, y = ref2.to_numpy(), opt2.to_numpy()
for c in x:
    assert np.array_equal(x[c], y[c]), c
print(f"shuffle->groupby: rows shuffled {rs2.rows_shuffled}->"
      f"{os2.rows_shuffled}")

# --- 3. randomized pipelines: optimize on/off x all modes ---------------- #
def random_plan(prng):
    plan = Plan.scan("l")
    n_ops = prng.integers(2, 6)
    for _ in range(n_ops):
        op = prng.choice(["filter", "add", "project", "shuffle", "groupby",
                          "join", "sort"])
        cols = None
        if op == "filter":
            thr = float(prng.random())
            plan = plan.filter(col("v0") > thr)
        elif op == "add":
            plan = plan.with_columns(
                {"v0": col("v0") + float(prng.random())})
        elif op == "project":
            pass  # projection is exercised via dead-column elimination
        elif op == "shuffle":
            plan = plan.shuffle(["k"], bucket_capacity=BIG)
        elif op == "groupby":
            plan = plan.groupby(["k"], {"v0": ["sum", "count"]},
                                bucket_capacity=BIG)
            # after groupby only k / v0_* remain; rebuild a v0 for later ops
            plan = plan.with_columns({"v0_sum": col("v0_sum") * 1})
            plan = plan.project(["k", "v0_sum"])
            plan = Plan(plan.node)
            return plan  # keep pipelines simple after aggregation
        elif op == "join":
            plan = plan.join(Plan.scan("r"), on="k", out_capacity=BIG,
                             bucket_capacity=2 * CAP)
        elif op == "sort":
            plan = plan.sort(["k"], bucket_capacity=BIG)
    return plan


n_checked = 0
for trial in range(8):
    prng = np.random.default_rng(100 + trial)
    plan = random_plan(prng)
    base = execute(plan, env, TABLES, mode="bsp", optimize=False).to_numpy()
    for mode in ("bsp", "bsp_staged", "amt"):
        got = execute(plan, env, TABLES, mode=mode, optimize=True).to_numpy()
        assert sorted(got) == sorted(base), (trial, mode)
        for c in base:
            assert np.allclose(np.sort(base[c]), np.sort(got[c]),
                               rtol=1e-4, atol=1e-5), (trial, mode, c)
    n_checked += 1
print(f"randomized parity OK ({n_checked} pipelines x 3 modes)")

print("planner_parity OK")

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""File ingest on 8 devices: the ISSUE-9 acceptance scenario.

1. A string-keyed Fig-9 pipeline (merge + conjunctive filter + groupby +
   sort) over a MULTI-FILE dataset with nulls in the key AND value
   columns is bit-identical to the pandas oracle in all three in-core
   modes (bsp / bsp_staged / amt), with ``rows_dropped == 0``.
2. The same pipeline at 8x out-of-core oversubscription
   (``collect(morsel_rows=...)``) is bit-identical to the in-core run
   (integer-valued floats keep partial sums exact); a repeat run
   compiles nothing (zero per-morsel recompiles).
3. Later files introduce lexicographically-earlier keys, so the first
   read exercises incremental dictionary growth (``recodes > 0``); a
   second read of the unchanged source hits the dictionary cache and is
   recode-free + bit-identical in the physical (mask) layout.
4. ``ExecStats.rows_read`` / ``bytes_read`` attribute ingest volume to
   the scan stage; EXPLAIN labels the scan with its source.

Runs from Parquet when pyarrow is importable, else from CSV through the
pure-python fallback lane — same pipeline, same oracle.
"""

import tempfile

import numpy as np
import pandas as pd

import repro.df as rdf
from repro.core import CylonEnv
from repro.expr import col
from repro.io import DictionaryCache, have_pyarrow
from repro.nulls import mask_name

USE_PARQUET = have_pyarrow()
FMT = "parquet" if USE_PARQUET else "csv"
rng = np.random.default_rng(23)

N, NFILES, NK = 3200, 4, 240
ALL = [f"key{i:04d}" for i in range(NK)]


def _cell(pool):
    return str(rng.choice(pool)) if rng.random() > 0.1 else None


def _val():
    return float(rng.integers(0, 256)) if rng.random() > 0.1 else None


fact_cols = []
for f in range(NFILES):
    n = N // NFILES
    # file f draws from the TAIL of the key space; each later file adds
    # earlier keys -> the ingest dictionary grows and recode fires
    pool = ALL[NK - (f + 1) * (NK // NFILES):]
    fact_cols.append({"k": [_cell(pool) for _ in range(n)],
                      "v0": [_val() for _ in range(n)]})
dim_cols = {"k": ALL + [None],
            "w": [float(i) if i % 7 else None for i in range(NK)] + [3.0]}

tmp = tempfile.mkdtemp(prefix="ingest_parity_")


def _write(path, cols, header):
    if USE_PARQUET:
        import pyarrow as pa
        import pyarrow.parquet as pq
        pq.write_table(pa.table({h: cols[h] for h in header}), path)
    else:
        lines = [",".join(header)]
        for row in zip(*[cols[h] for h in header]):
            lines.append(",".join(
                "" if x is None else (x if isinstance(x, str) else repr(x))
                for x in row))
        with open(path, "w") as fh:
            fh.write("\n".join(lines) + "\n")


fact_paths = []
for f, cols in enumerate(fact_cols):
    p = os.path.join(tmp, f"facts{f}.{FMT}")
    _write(p, cols, ["k", "v0"])
    fact_paths.append(p)
dim_path = os.path.join(tmp, f"dim.{FMT}")
_write(dim_path, dim_cols, ["k", "w"])

env = CylonEnv()
assert env.parallelism == 8
rdf.set_default_env(env)

cache = DictionaryCache()
_read = rdf.read_parquet if USE_PARQUET else rdf.read_csv
facts = _read(fact_paths, dict_cache=cache)
dim = _read(dim_path, dict_cache=cache)

info = facts.sources[next(iter(facts.sources))].provenance
assert info.format == FMT and info.rows == N, info
assert info.recodes > 0, "later files must grow the dictionary"
assert not info.dict_cache_hit
text = facts.explain()
assert f"scan[{FMT}: {NFILES} files, ~{N} rows]" in text, text

PIVOT = ALL[NK // 2]
JKW = dict(out_capacity=4096, bucket_capacity=2048,
           shuffle_out_capacity=2048)
pipe = (facts.merge(dim, on="k", **JKW)
        [(col("v0") > 4) & (col("k") < PIVOT)]
        .groupby("k").agg({"v0": ["sum", "count"], "w": "max"})
        .sort_values("k"))

# --- pandas oracle (null keys never match / never form a group) ---------- #
pf = pd.concat([pd.DataFrame(c) for c in fact_cols], ignore_index=True)
pdim = pd.DataFrame(dim_cols)
m = pf.dropna(subset=["k"]).merge(pdim.dropna(subset=["k"]), on="k")
m = m[(m.v0 > 4) & (m.k < PIVOT)]
want = (m.groupby("k")
        .agg(v0_sum=("v0", "sum"), v0_count=("v0", "count"),
             w_max=("w", "max"))
        .reset_index().sort_values("k").reset_index(drop=True))

ref = None
for mode in ("bsp", "bsp_staged", "amt"):
    out, stats = pipe.collect(mode=mode, collect_stats=True)
    assert stats.rows_dropped == 0, (mode, stats)
    assert stats.rows_read == N + NK + 1, (mode, stats.rows_read)
    assert stats.bytes_read == sum(
        os.path.getsize(p) for p in fact_paths + [dim_path]), mode
    raw = out.to_numpy()
    assert list(raw["k"]) == list(want["k"]), mode
    np.testing.assert_array_equal(raw["v0_sum"],
                                  want["v0_sum"].astype(np.float32))
    np.testing.assert_array_equal(raw["v0_count"],
                                  want["v0_count"].to_numpy())
    # all-null w groups surface as null (pandas NaN)
    wm = out.to_numpy()
    np.testing.assert_array_equal(np.isnan(wm["w_max"]),
                                  want["w_max"].isna())
    np.testing.assert_array_equal(np.nan_to_num(wm["w_max"]),
                                  want["w_max"].fillna(0.0).astype(np.float32))
    if ref is None:
        ref = raw
    else:
        for c in ref:
            np.testing.assert_array_equal(ref[c], raw[c], err_msg=(mode, c))
    print(f"ingest pipeline[{FMT}/{mode}]: bit-identical to pandas oracle "
          f"({len(raw['k'])} groups, {stats.rows_read} rows ingested)")

# --- 8x out-of-core oversubscription ------------------------------------- #
MORSEL = (N // 8) // 8                       # 8 morsels per rank
spill, st = pipe.collect(morsel_rows=MORSEL, collect_stats=True,
                         capacity_factor=16.0)
assert st.rows_dropped == 0, st
assert st.morsels >= 8, st.morsels
raw = spill.to_numpy()
for c in ref:
    np.testing.assert_array_equal(ref[c], raw[c], err_msg=c)
print(f"ingest pipeline[{FMT}/out-of-core]: bit-identical over "
      f"{st.morsels} morsels")

# repeat run: every per-morsel program comes from the compile cache
_, st2 = pipe.collect(morsel_rows=MORSEL, collect_stats=True,
                      capacity_factor=16.0)
assert st2.cache_misses == 0, st2.cache_misses
assert st2.cache_hits > 0
print(f"repeat out-of-core run: 0 compiles, {st2.cache_hits} cache hits")

# --- second read: dictionary-cache hit, recode-free, bit-identical ------- #
facts2 = _read(fact_paths, dict_cache=cache)
info2 = facts2.sources[next(iter(facts2.sources))].provenance
assert info2.dict_cache_hit and info2.recodes == 0, info2
s1 = facts.sources[next(iter(facts.sources))]
s2 = facts2.sources[next(iter(facts2.sources))]
assert s1.dictionaries == s2.dictionaries
a = s1.to_numpy(decode=False, nulls="mask")
b = s2.to_numpy(decode=False, nulls="mask")
assert set(a) == set(b) and mask_name("k") in a
for c in a:
    np.testing.assert_array_equal(a[c], b[c], err_msg=c)
print("second read: cache hit, 0 recodes, identical physical layout")

print("OK")

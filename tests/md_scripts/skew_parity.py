import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Adaptive skew mitigation on 8 devices: the 99%-one-key table.

1. Raw (non-pre-aggregated) groupby and join on a table where 99% of all
   rows carry one hot key are BIT-IDENTICAL across bsp / bsp_staged / amt
   and a 16-morsel out-of-core run, adaptive on or off, and match the
   pandas-free numpy oracle.
2. Rows-routed balance: with salting the hottest rank's share of the
   salted-join output stays within 2x of the median rank; without it the
   hash home drowns (>= 4x the median) — the imbalance salting removes.
3. Zero-new-compile-keys invariant: with ``adaptive=False`` a repeat run
   (and an ``adaptive=True`` run on *uniform* keys, where no decision
   fires) adds nothing to the env's compile cache.
4. ``overflow="degrade"`` + salting: zero dropped rows everywhere.

Integer-valued float32 payloads keep sums exact, so bit-identity is
meaningful across salting's partial/re-merge split.
"""

import numpy as np

from repro.core import CylonEnv, DistTable, Plan, SpillTable, execute

rng = np.random.default_rng(11)
N = 40_000
HOT = 7
keys = np.where(rng.random(N) < 0.99, HOT,
                rng.integers(0, 1000, N)).astype(np.int32)
vals = rng.integers(0, 100, N).astype(np.float32)
data = {"k": keys, "v": vals}
build = {"k": np.arange(64, dtype=np.int32),
         "w": rng.integers(0, 100, 64).astype(np.float32)}

env = CylonEnv()
p = env.parallelism
assert p == 8
CAP = 2 * (N // p)
t = DistTable.from_numpy(data, p, capacity=CAP)
bt = DistTable.from_numpy(build, p)

# generous caps so the UNSALTED in-core runs survive the hot rank intact
# (the adaptive run shares them; salting just stops needing them)
gplan = (Plan.scan("t")
         .groupby(["k"], {"v": ["sum", "count"]}, pre_aggregate=False,
                  bucket_capacity=N + 8192, out_capacity=N + 8192)
         .sort(["k"], bucket_capacity=N + 8192))
jplan = Plan.scan("t").join(Plan.scan("r"), on="k",
                            bucket_capacity=N + 8192,
                            shuffle_out_capacity=N + 8192,
                            out_capacity=N + 8192)

# --- numpy oracle ------------------------------------------------------- #
uk = np.unique(keys)
want_sum = np.array([vals[keys == k].sum() for k in uk], np.float32)
want_cnt = np.array([(keys == k).sum() for k in uk], np.int32)


def check_groupby(out):
    got = out.to_numpy()
    np.testing.assert_array_equal(got["k"], uk)
    np.testing.assert_array_equal(got["v_sum"], want_sum)
    np.testing.assert_array_equal(got["v_count"], want_cnt)
    return got


def sorted_records(d, cols):
    order = np.lexsort(tuple(np.asarray(d[c]) for c in reversed(cols)))
    return {c: np.asarray(d[c])[order] for c in cols}


# --- 1. groupby parity across modes + out-of-core ----------------------- #
ref = None
for adaptive in (False, True):
    for mode in ("bsp", "bsp_staged", "amt"):
        out, st = execute(gplan, env, {"t": t}, mode=mode, optimize=False,
                          collect_stats=True, adaptive=adaptive)
        assert st.rows_dropped == 0, (mode, adaptive, st.rows_dropped)
        got = check_groupby(out)
        if adaptive and mode in ("bsp", "bsp_staged"):
            assert st.salted_shuffles >= 1, (mode, st.salted_shuffles)
        if ref is None:
            ref = got
        for c in ref:
            np.testing.assert_array_equal(ref[c], got[c], err_msg=mode)
print("groupby modes: OK")

MORSEL = -(-(N // p // 16) // 8) * 8          # ~16 morsels per rank
for adaptive in (False, True):
    sp, st = execute(gplan, env, {"t": data}, optimize=False,
                     collect_stats=True, morsel_rows=MORSEL,
                     capacity_factor=4.0, adaptive=adaptive)
    assert isinstance(sp, SpillTable)
    assert st.rows_dropped == 0, (adaptive, st.rows_dropped)
    assert st.morsels >= 16
    if adaptive:
        assert st.salted_shuffles >= 1
    got = sp.to_numpy()
    for c in ref:
        np.testing.assert_array_equal(ref[c], got[c], err_msg=str(adaptive))
print("groupby 16-morsel out-of-core: OK")

# --- 2. join parity + rows-routed balance ------------------------------- #
jref = None
ratios = {}
for adaptive in (False, True):
    out, st = execute(jplan, env, {"t": t, "r": bt}, mode="bsp_staged",
                      optimize=False, collect_stats=True, adaptive=adaptive)
    assert st.rows_dropped == 0, (adaptive, st.rows_dropped)
    # real in-core execution both ways (no silent degrade-to-morsel), so
    # the row_counts below reflect the actual routing
    assert st.degraded == 0, (adaptive, st.degraded)
    counts = np.asarray(out.row_counts, np.int64)
    ratios[adaptive] = counts.max() / max(np.median(counts), 1.0)
    got = sorted_records(out.to_numpy(), ["k", "v", "w"])
    if jref is None:
        jref = got
    for c in jref:
        np.testing.assert_array_equal(jref[c], got[c])
    if adaptive:
        assert st.salted_shuffles >= 1, st.salted_shuffles
# the whole point: salting turns a drowned hash home into a level gang
assert ratios[True] <= 2.0, ratios
assert ratios[False] >= 4.0, ratios
print(f"join balance: OK (max/median {ratios[False]:.1f} -> "
      f"{ratios[True]:.2f})")

for adaptive in (False, True):
    sp, st = execute(jplan, env, {"t": data, "r": build}, optimize=False,
                     collect_stats=True, morsel_rows=MORSEL,
                     capacity_factor=4.0, adaptive=adaptive)
    assert st.rows_dropped == 0, (adaptive, st.rows_dropped)
    got = sorted_records(sp.to_numpy(), ["k", "v", "w"])
    for c in jref:
        np.testing.assert_array_equal(jref[c], got[c])
print("join 16-morsel out-of-core: OK")

# --- 3. zero new compile-cache keys when adaptive=False ----------------- #
execute(gplan, env, {"t": t}, mode="bsp", optimize=False, adaptive=False,
        collect_stats=True)
baseline = set(env._cache)
execute(gplan, env, {"t": t}, mode="bsp", optimize=False, adaptive=False,
        collect_stats=True)
assert set(env._cache) == baseline, "adaptive=False recompiled"
# adaptive=True on uniform keys: no decision fires, so the off-keys serve
udata = {"k": rng.integers(0, 100_000, N).astype(np.int32), "v": vals}
ut = DistTable.from_numpy(udata, p, capacity=CAP)
_, ust = execute(gplan, env, {"t": ut}, mode="bsp", optimize=False,
                 adaptive=True, collect_stats=True)
assert ust.salted_shuffles == 0
assert set(env._cache) == baseline, "no-op adaptive minted new keys"
print("zero-new-keys: OK")

print("OK")

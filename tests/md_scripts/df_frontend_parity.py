import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""DataFrame-frontend verification on 8 devices:

1. Fig-9 via ``repro.df`` is BIT-IDENTICAL to the raw ``Plan`` builder in
   all three execution modes (bsp / bsp_staged / amt).
2. The same pipeline streamed out-of-core (``collect(morsel_rows=...)``
   from a host SpillTable source) is bit-identical to the in-core run.
3. The frontend's expression plans hit the SAME compile-cache entries as
   the builder's (value-based fingerprints), and EXPLAIN carries no
   <lambda> placeholders.
"""

import numpy as np

import repro.df as rdf
from repro.core import CylonEnv, DistTable, Plan, SpillTable, execute
from repro.expr import col

rng = np.random.default_rng(0)
N = 4000
NK = int(N * 0.9)   # paper §V recipe: 90% key cardinality (join ~1:1)
ld = {"k": rng.integers(0, NK, N).astype(np.int32),
      "v0": rng.integers(0, 256, N).astype(np.float32),   # integer-valued:
      "junk": rng.random(N).astype(np.float32)}           # exact float sums
rd = {"k": rng.integers(0, NK, N).astype(np.int32),
      "w": rng.integers(0, 256, N).astype(np.float32)}

env = CylonEnv()
assert env.parallelism == 8
rdf.set_default_env(env)
lt = DistTable.from_numpy(ld, 8)
rt = DistTable.from_numpy(rd, 8)
CAP = lt.capacity

# hash placement is only balanced in expectation: give the join shuffle
# receive headroom so neither path drops rows (see docs/planner.md)
JKW = dict(out_capacity=CAP * 4, bucket_capacity=CAP * 2,
           shuffle_out_capacity=CAP * 2)
plan = (Plan.scan("l").join(Plan.scan("r"), on="k", **JKW)
        .filter((col("v0") > 4) & (col("w") < 250))
        .groupby(["k"], {"v0": ["sum", "mean"]})
        .sort(["k"])
        .with_columns({"v0_sum": col("v0_sum") + 1.0}))
front = (rdf.from_table(lt, name="l")
         .merge(rdf.from_table(rt, name="r"), on="k", **JKW)
         [(col("v0") > 4) & (col("w") < 250)]
         .groupby("k").agg({"v0": ["sum", "mean"]})
         .sort_values("k")
         .assign(v0_sum=col("v0_sum") + 1.0))

text = front.explain()
assert "<lambda>" not in text and "filter[?]" not in text
assert "split-conjunction" in text and "predicate-pushdown" in text

# --- 1. all three modes bit-identical to the builder --------------------- #
for mode in ("bsp", "bsp_staged", "amt"):
    a = execute(plan, env, {"l": lt, "r": rt}, mode=mode).to_numpy()
    b = front.collect(mode=mode).to_numpy()
    assert sorted(a) == sorted(b), mode
    for c in a:
        assert np.array_equal(a[c], b[c]), (mode, c)
print("frontend == builder: bsp / bsp_staged / amt bit-identical")

# --- 2. identical plans share compiled programs (value-based keys) ------- #
h0, m0 = env.cache_hits, env.cache_misses
front.collect()                       # both plans already compiled above
execute(plan, env, {"l": lt, "r": rt})
assert env.cache_misses == m0 and env.cache_hits == h0 + 2
print("compile cache: frontend + builder re-runs are pure hits")

# --- 3. out-of-core streaming bit-identical ------------------------------ #
ref_table, ref_stats = front.collect(collect_stats=True)
assert ref_stats.rows_dropped == 0
ref = ref_table.to_numpy()
morsel = CAP // 4
ooc = (rdf.from_table(SpillTable.from_numpy(ld, 8, chunk_rows=morsel),
                      name="l")
       .merge(rdf.from_table(rt, name="r"), on="k", **JKW)
       [(col("v0") > 4) & (col("w") < 250)]
       .groupby("k").agg({"v0": ["sum", "mean"]})
       .sort_values("k")
       .assign(v0_sum=col("v0_sum") + 1.0))
out, stats = ooc.collect(morsel_rows=morsel, capacity_factor=8.0,
                         collect_stats=True)
assert isinstance(out, SpillTable)
o = out.to_numpy()
assert sorted(ref) == sorted(o)
for c in ref:
    assert np.array_equal(ref[c], o[c]), c
assert stats.rows_dropped == 0
assert stats.morsels >= 4
print(f"out-of-core: {stats.morsels} morsels, bit-identical, 0 drops")

print("df_frontend_parity OK")

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""MoE dispatch parity: the shard_map dataframe-shuffle path must equal the
grouped GSPMD path (ample capacity) on a (4 data x 2 model) mesh — forward
values, aux loss, and gradients."""

import numpy as np
import jax
from repro import compat
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_local_mesh, rules_for_mesh
from repro.models.config import ModelConfig, MoEConfig
from repro.models.moe import moe_apply_grouped, moe_apply_shuffle, moe_init

cfg = ModelConfig(
    name="parity-moe", family="moe", num_layers=1, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=96, vocab_size=256,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=96, num_shared=1,
                  capacity_factor=8.0))

mesh = make_local_mesh(8, model=2)
rules = rules_for_mesh(mesh)
rng = np.random.default_rng(0)
params = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jnp.asarray(rng.standard_normal((8, 32, 64)), jnp.float32)

with compat.set_mesh(mesh):
    def f_shuffle(p, xx):
        y, aux = moe_apply_shuffle(p, xx, cfg, rules)
        return y, aux

    def f_grouped(p, xx):
        y, aux = moe_apply_grouped(p, xx, cfg, rules)
        return y, aux

    y1, a1 = jax.jit(f_shuffle)(params, x)
    y2, a2 = jax.jit(f_grouped)(params, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-4)

    # gradient parity through both dispatch paths
    def loss_s(p, xx):
        y, aux = moe_apply_shuffle(p, xx, cfg, rules)
        return jnp.sum(y ** 2) + aux

    def loss_g(p, xx):
        y, aux = moe_apply_grouped(p, xx, cfg, rules)
        return jnp.sum(y ** 2) + aux

    g1 = jax.jit(jax.grad(loss_s))(params, x)
    g2 = jax.jit(jax.grad(loss_g))(params, x)
    flat1 = jax.tree_util.tree_leaves(g1)
    flat2 = jax.tree_util.tree_leaves(g2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-3, rtol=5e-3)

    # modular communicator (paper §IV-B) on the dispatch: ring/bruck
    # schedules must produce identical results to the native xla path
    import dataclasses
    for name in ("ring", "bruck"):
        cfg_c = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, communicator=name))
        yc, ac = jax.jit(
            lambda p, xx: moe_apply_shuffle(p, xx, cfg_c, rules))(params, x)
        np.testing.assert_allclose(np.asarray(yc), np.asarray(y1),
                                   atol=2e-4, rtol=1e-3)

print(f"moe_shuffle_parity OK (y diff {float(jnp.abs(y1 - y2).max()):.2e}, "
      f"ring/bruck schedules verified)")

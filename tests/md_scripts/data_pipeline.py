import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""§IV-C data pipeline semantics vs a numpy oracle: dedup keeps exactly the
min doc_id per dup-group, quality filter applied, weights joined, rows
balanced, and the CylonStore hand-off preserves the row multiset."""

import numpy as np

from repro.core import CylonExecutor, CylonStore, DevicePool
from repro.data import (CorpusConfig, batches_from_table, preprocess,
                        source_weights, synth_corpus)

P = 8
ccfg = CorpusConfig(num_docs=2048, payload_tokens=32, vocab_size=1000,
                    dup_rate=0.4, seed=3)
gang = CylonExecutor(parallelism=P, pool=DevicePool())
store = CylonStore()
corpus = synth_corpus(ccfg, P)
weights = source_weights(ccfg.num_sources, P)
out = preprocess(gang, corpus, weights, quality_min=0.2, store=store)
res = out.to_numpy()

# numpy oracle
raw = corpus.to_numpy()
order = np.argsort(raw["doc_id"])
raw = {k: v[order] for k, v in raw.items()}
keep_ids = set()
seen = {}
for did, grp in zip(raw["doc_id"], raw["dup_group"]):
    if grp not in seen:
        seen[grp] = did
keep = np.asarray([seen[g] == d for d, g in
                   zip(raw["doc_id"], raw["dup_group"])])
keep &= raw["quality"] >= 0.2
expect_ids = np.sort(raw["doc_id"][keep])

got_ids = np.sort(res["doc_id"])
np.testing.assert_array_equal(got_ids, expect_ids)

# weights joined correctly
wmap = dict(zip(*[weights.to_numpy()[c] for c in ("source", "weight")]))
for s, w in zip(res["source"][:200], res["weight"][:200]):
    assert abs(wmap[int(s)] - w) < 1e-6

# balanced partitions (paper §VI): max shard within 2x of mean
counts = np.asarray(out.row_counts)
assert counts.sum() == len(expect_ids)
assert counts.max() <= 2.0 * max(counts.mean(), 1)

# store hand-off with repartition preserves rows
got = store.get("train_corpus", target_parallelism=4)
np.testing.assert_array_equal(np.sort(got.to_numpy()["doc_id"]), expect_ids)

# batches are well-formed
b = next(batches_from_table(got, batch=4, seq_len=16))
assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
assert (b["tokens"] < ccfg.vocab_size).all()

print("data_pipeline OK")

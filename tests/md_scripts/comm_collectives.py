import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Communicator parity: ring/bruck vs xla for every collective, p in {6, 8}
(6 exercises the non-power-of-two ring fallback in bruck)."""

import numpy as np
import jax
from repro import compat
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.comm import get_communicator

rng = np.random.default_rng(0)

for p in (6, 8):
    mesh = Mesh(np.asarray(jax.devices()[:p]), ("df",))
    x_blocks = jnp.asarray(rng.standard_normal((p, p, 4, 3)), jnp.float32)
    x_flat = jnp.asarray(rng.standard_normal((p, 10)), jnp.float32)

    def run(comm_name, method, x):
        comm = get_communicator(comm_name, "df")

        def body(xl):
            return getattr(comm, method)(xl[0])[None]
        return jax.jit(compat.shard_map(
            body, mesh=mesh, in_specs=P("df"), out_specs=P("df"),
            check_vma=False))(x)

    for method, x in (("all_to_all", x_blocks), ("all_gather", x_flat),
                      ("all_reduce", x_flat), ("reduce_scatter", x_blocks)):
        ref = run("xla", method, x)
        for name in ("ring", "bruck"):
            got = run(name, method, x)
            assert np.allclose(got, ref, atol=1e-5), (p, name, method)

    # chunked all-to-all == monolithic, every backend, including a chunk
    # count (3) that does not divide the capacity axis (4 -> pad+slice)
    ref = run("xla", "all_to_all", x_blocks)
    for name in ("xla", "ring", "bruck"):
        comm = get_communicator(name, "df")
        for chunks in (1, 2, 3, 4):
            got = jax.jit(compat.shard_map(
                lambda xl, c=comm, k=chunks: c.all_to_all_chunked(
                    xl[0], chunks=k)[None],
                mesh=mesh, in_specs=P("df"), out_specs=P("df"),
                check_vma=False))(x_blocks)
            assert np.allclose(got, ref, atol=1e-5), (p, name, chunks)
    # broadcast + counts exchange
    for name in ("xla", "ring", "bruck"):
        comm = get_communicator(name, "df")
        out = jax.jit(compat.shard_map(
            lambda xl: comm.broadcast(xl[0], root=2)[None],
            mesh=mesh, in_specs=P("df"), out_specs=P("df"),
            check_vma=False))(x_flat)
        assert np.allclose(np.asarray(out),
                           np.asarray(x_flat)[2][None].repeat(p, 0),
                           atol=1e-6), (p, name, "broadcast")

print("comm_collectives OK")

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Out-of-core morsel execution on 8 devices:

1. Fig-9 pipeline streamed at 8x oversubscription (morsel_rows = rows/rank/8)
   is BIT-IDENTICAL to the in-core run, with zero dropped rows, bounded
   working capacity, and real spill/H2D/D2H traffic.
2. The per-morsel zero-recompile invariant: a repeat run compiles nothing.
3. Host-data entry: the same pipeline driven straight from numpy dicts
   (never materialized as a device DistTable) matches too.
4. Bucketed rescatter repartition round-trips across gang sizes.

Payload values are integer-valued float32 so aggregation is exact and
order-insensitive — bit-identity is meaningful across morsel splits.
"""

import numpy as np

from repro.core import (CylonEnv, DistTable, Plan, SpillTable, execute,
                        repartition)

rng = np.random.default_rng(7)
N = 32_000
ld = {"k": rng.integers(0, int(N * 0.9), N).astype(np.int32),
      "v0": rng.integers(0, 100, N).astype(np.float32),
      "junk": rng.random(N).astype(np.float32)}
rd = {"k": rng.integers(0, int(N * 0.9), N).astype(np.int32),
      "w": rng.integers(0, 100, N).astype(np.float32)}

env = CylonEnv()
p = env.parallelism
assert p == 8
lt = DistTable.from_numpy(ld, p)
rt = DistTable.from_numpy(rd, p)
CAP = lt.capacity
MORSEL = -(-(-(-N // p) // 8) // 8) * 8      # rows/rank/8, 8-aligned

fig9 = (Plan.scan("l")
        .join(Plan.scan("r"), on="k", out_capacity=CAP * 4,
              bucket_capacity=CAP * 2, shuffle_out_capacity=CAP * 2)
        .groupby(["k"], {"v0": ["sum", "mean"]}, bucket_capacity=CAP * 4)
        .sort(["k"], bucket_capacity=CAP * 4)
        .add_scalar(1.0, cols=["v0_sum"]))

# --- 1. oversubscribed streaming == in-core, bit for bit ---------------- #
for opt in (False, True):
    ref, rst = execute(fig9, env, {"l": lt, "r": rt}, optimize=opt,
                       collect_stats=True)
    assert rst.rows_dropped == 0, rst.rows_dropped
    out, st = execute(fig9, env, {"l": ld, "r": rd}, optimize=opt,
                      collect_stats=True, morsel_rows=MORSEL,
                      capacity_factor=4.0)
    assert isinstance(out, SpillTable)
    assert st.rows_dropped == 0, st.rows_dropped
    assert st.morsels >= 8 * 2               # >= 8 per streamed segment
    assert st.morsel_rows == MORSEL
    assert st.spill_bytes > 0 and st.h2d_bytes > 0 and st.d2h_bytes > 0
    # communication volume is identical to the in-core execution: morsels
    # change WHEN rows move, never HOW MANY
    assert st.rows_shuffled == rst.rows_shuffled, (
        st.rows_shuffled, rst.rows_shuffled)
    a, b = ref.to_numpy(), out.to_numpy()
    assert sorted(a) == sorted(b)
    for c in a:
        assert np.array_equal(a[c], b[c]), c
    print(f"fig9 opt={opt}: bit-identical at oversub=8 "
          f"({st.morsels} morsels, spill {st.spill_bytes}B, "
          f"h2d {st.h2d_bytes}B, d2h {st.d2h_bytes}B)")

# --- 2. zero recompiles on repeat ---------------------------------------- #
_, st2 = execute(fig9, env, {"l": ld, "r": rd}, optimize=True,
                 collect_stats=True, morsel_rows=MORSEL, capacity_factor=4.0)
assert st2.cache_misses == 0, st2.cache_misses
assert st2.cache_hits > 0
print(f"repeat run: 0 compiles, {st2.cache_hits} cache hits")

# --- 3. SpillTable source (host data never fits a DistTable) ------------- #
spill_l = SpillTable.from_numpy(ld, p, chunk_rows=MORSEL)
out3 = execute(fig9, env, {"l": spill_l, "r": rd}, optimize=True,
               morsel_rows=MORSEL, capacity_factor=4.0)
b3 = out3.to_numpy()
ref_np = execute(fig9, env, {"l": lt, "r": rt}, optimize=True).to_numpy()
for c in ref_np:
    assert np.array_equal(ref_np[c], b3[c]), c
print("spill-table source: bit-identical")

# --- 4. bucketed rescatter round-trip ------------------------------------ #
re5 = repartition(lt, 5)
assert re5.parallelism == 5
back = repartition(re5, 8)
a, b = lt.to_numpy(), back.to_numpy()
for c in a:
    assert np.array_equal(a[c], b[c]), c
print("rescatter 8->5->8: exact round-trip")

print("OK")

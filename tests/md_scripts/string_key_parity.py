import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Dictionary-encoded string columns on 8 devices:

1. A Fig-9-style pipeline keyed on a STRING column (merge + conjunctive
   filter with a string-literal predicate + groupby + sort) is
   bit-identical to the pandas oracle in all three execution modes
   (bsp / bsp_staged / amt).
2. The same pipeline streamed out-of-core (``collect(morsel_rows=...)``)
   is bit-identical to the in-core run, dictionaries preserved through
   spill and respill.
3. The two inputs are ingested with DIFFERENT key dictionaries, so the
   planner must insert recode nodes (asserted in EXPLAIN) and the merged
   dictionary must round-trip through the result.
4. Ranks left empty by the block distribution keep schema + dictionaries.
"""

import numpy as np
import pandas as pd

import repro.df as rdf
from repro.core import CylonEnv
from repro.expr import col

rng = np.random.default_rng(7)
N = 4000
NK = int(N * 0.9)   # paper §V recipe: ~90% key cardinality (join ~1:1)
ALL_KEYS = np.array([f"key{i:05d}" for i in range(NK)])

# different (overlapping) dictionaries on the two sides -> recode fires
lkeys = ALL_KEYS[: int(NK * 0.8)]
rkeys = ALL_KEYS[int(NK * 0.2):]
ld = {"k": rng.choice(lkeys, N),
      "v0": rng.integers(0, 256, N).astype(np.float32),   # integer-valued:
      "junk": rng.random(N).astype(np.float32)}           # exact float sums
rd = {"k": rng.choice(rkeys, N),
      "w": rng.integers(0, 256, N).astype(np.float32)}

env = CylonEnv()
assert env.parallelism == 8
rdf.set_default_env(env)

dl = rdf.read_numpy(ld, name="l")
dr = rdf.read_numpy(rd, name="r")
assert dl.collect().dictionaries["k"] == tuple(sorted(set(ld["k"])))
CAP = dl.collect().capacity

PIVOT = str(ALL_KEYS[NK // 2])
JKW = dict(out_capacity=CAP * 4, bucket_capacity=CAP * 2,
           shuffle_out_capacity=CAP * 2)
pipe = (dl.merge(dr, on="k", **JKW)
        [(col("v0") > 4) & (col("k") < PIVOT)]
        .groupby("k").agg({"v0": ["sum", "mean"]})
        .sort_values("k"))

text = pipe.explain()
assert "recode[k:" in text, text
assert "recode: join(k)" in text, text

# --- pandas oracle ------------------------------------------------------- #
j = pd.DataFrame(ld).merge(pd.DataFrame(rd), on="k")
j = j[(j.v0 > 4) & (j.k < PIVOT)]
want = (j.groupby("k").agg(v0_sum=("v0", "sum"), v0_mean=("v0", "mean"))
        .reset_index().sort_values("k").reset_index(drop=True))

ref = None
for mode in ("bsp", "bsp_staged", "amt"):
    out, stats = pipe.collect(mode=mode, collect_stats=True)
    assert stats.rows_dropped == 0, (mode, stats)
    raw = out.to_numpy()
    assert list(raw["k"]) == list(want["k"]), mode
    np.testing.assert_array_equal(raw["v0_sum"],
                                  want["v0_sum"].astype(np.float32))
    np.testing.assert_array_equal(raw["v0_mean"],
                                  want["v0_mean"].astype(np.float32))
    # merged dictionary round-trips on the result
    assert out.dictionaries["k"] == tuple(
        sorted(set(ld["k"]) | set(rd["k"]))), mode
    if ref is None:
        ref = raw
    else:
        for c in ref:
            np.testing.assert_array_equal(ref[c], raw[c], err_msg=(mode, c))
    print(f"string-key pipeline[{mode}]: bit-identical to pandas oracle "
          f"({len(raw['k'])} groups)")

# --- out-of-core: spill-resident probe side, 8 morsels ------------------- #
dls = rdf.read_numpy(ld, name="l", spill=True, chunk_rows=CAP // 2)
pipe_ooc = (dls.merge(dr, on="k", **JKW)
            [(col("v0") > 4) & (col("k") < PIVOT)]
            .groupby("k").agg({"v0": ["sum", "mean"]})
            .sort_values("k"))
spill, stats = pipe_ooc.collect(morsel_rows=CAP // 8, collect_stats=True,
                                capacity_factor=16.0)
assert stats.rows_dropped == 0, stats
assert stats.morsels >= 8, stats
raw = spill.to_numpy()
for c in ref:
    np.testing.assert_array_equal(ref[c], raw[c], err_msg=c)
assert spill.dictionaries["k"] == tuple(sorted(set(ld["k"]) | set(rd["k"])))
print(f"string-key pipeline[out-of-core]: bit-identical over "
      f"{stats.morsels} morsels")

# --- empty ranks keep schema + dictionaries ------------------------------ #
tiny = rdf.read_numpy({"k": np.asarray(["b", "a"]),
                       "v": np.asarray([1.0, 2.0], np.float32)},
                      name="tiny")
t = tiny.sort_values("k").collect()
counts = np.asarray(t.row_counts)
assert (counts == 0).any(), counts       # 2 rows over 8 ranks: some empty
got = t.to_numpy()
assert list(got["k"]) == ["a", "b"], got
assert t.dictionaries["k"] == ("a", "b")
print("empty ranks: schema + dictionaries preserved")

print("OK")

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Distributed dataframe integration: join/groupby/sort vs numpy oracles,
plan-mode parity (bsp == bsp_staged == amt), communicator equivalence."""

import collections

import numpy as np
import jax

from repro.core import CylonEnv, DistTable, Plan, execute
from repro.dataframe import groupby, join, sort

rng = np.random.default_rng(0)
N = 4000
data_l = {"k": rng.integers(0, 500, N).astype(np.int32),
          "v": rng.random(N).astype(np.float32)}
data_r = {"k": rng.integers(0, 500, N).astype(np.int32),
          "w": rng.random(N).astype(np.float32)}

env = CylonEnv(communicator="xla")
p = env.parallelism
lt = DistTable.from_numpy(data_l, p, capacity=4096)
rt = DistTable.from_numpy(data_r, p, capacity=4096)

# --- join vs oracle --------------------------------------------------- #
def do_join(ctx, l, r):
    out, ls, rs = join(l, r, ctx.comm, on="k", out_capacity=16384,
                       bucket_capacity=2048)
    return out, ls.send_dropped

out, dropped = env.run(do_join, lt, rt)
res = out.to_numpy()
rmap = collections.Counter(data_r["k"].tolist())
expect = sum(rmap[int(k)] for k in data_l["k"])
assert len(res["k"]) == expect, (len(res["k"]), expect)
assert int(np.asarray(dropped).sum()) == 0
exp_sum = sum(v * rmap[int(k)] for k, v in zip(data_l["k"], data_l["v"]))
assert np.isclose(res["v"].sum(), exp_sum, rtol=1e-4)

# --- groupby vs oracle ------------------------------------------------ #
def do_gb(ctx, t):
    out, _ = groupby(t, ctx.comm, keys=["k"],
                     aggs={"v": ["sum", "count", "mean"]})
    return out

g = env.run(do_gb, lt).to_numpy()
uk = np.unique(data_l["k"])
assert len(g["k"]) == len(uk)
order = np.argsort(g["k"])
for agg, fn in (("v_sum", np.sum), ("v_count", len), ("v_mean", np.mean)):
    want = np.asarray([fn(data_l["v"][data_l["k"] == k]) for k in uk])
    np.testing.assert_allclose(g[agg][order], want, rtol=1e-3, atol=1e-4)

# --- sort ------------------------------------------------------------- #
def do_sort(ctx, t):
    out, _ = sort(t, ctx.comm, by=["k"])
    return out

s = env.run(do_sort, lt).to_numpy()
np.testing.assert_array_equal(np.sort(data_l["k"]), s["k"])

# --- plan modes parity + communicators -------------------------------- #
plan = (Plan.scan("l").join(Plan.scan("r"), on="k", out_capacity=16384,
                            bucket_capacity=2048)
        .groupby(["k"], {"v": ["sum"]}, bucket_capacity=4096)
        .sort(["k"]).add_scalar(1.0, cols=["v_sum"]))
ref = execute(plan, env, {"l": lt, "r": rt}, mode="bsp").to_numpy()
for mode in ("bsp_staged", "amt"):
    got = execute(plan, env, {"l": lt, "r": rt}, mode=mode).to_numpy()
    for c in ref:
        assert np.allclose(np.sort(ref[c]), np.sort(got[c]), rtol=1e-4), \
            (mode, c)

for name in ("ring", "bruck"):
    env2 = CylonEnv(communicator=name)
    out2, _ = env2.run(do_join, lt, rt)
    assert len(out2.to_numpy()["k"]) == expect, name

print("dataframe_ops OK")

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""int8-compressed gradient all-reduce (error feedback) in an explicit-DP
training loop vs full-precision DP: convergence within tolerance."""

import numpy as np
import jax
from repro import compat
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.comm import get_communicator
from repro.train.compression import ef_compressed_all_reduce

rng = np.random.default_rng(0)
p = 8
mesh = Mesh(np.asarray(jax.devices()[:p]), ("data",))

# toy regression: w* recovered by DP-SGD with compressed reductions
D = 256
w_true = rng.standard_normal(D).astype(np.float32)
X = rng.standard_normal((p, 64, D)).astype(np.float32)
Y = X @ w_true + 0.01 * rng.standard_normal((p, 64)).astype(np.float32)

comm = get_communicator("xla", "data")


def make_step(compressed):
    def step(w, err, x, y):
        def loss(w):
            pred = x @ w
            return jnp.mean((pred - y) ** 2)
        g = jax.grad(loss)(w)
        if compressed:
            g, err = ef_compressed_all_reduce(g, err, comm)
        else:
            g = jax.lax.pmean(g, "data")
        return w - 0.05 * g, err

    def body(w, err, x, y):
        return step(w[0], err[0], x[0], y[0])

    return jax.jit(compat.shard_map(
        lambda w, e, x, y: tuple(z[None] for z in body(w, e, x, y)),
        mesh=mesh,
        in_specs=(P("data"), P("data"), P("data"), P("data")),
        out_specs=P("data"), check_vma=False))


for compressed in (False, True):
    w = jnp.zeros((p, D), jnp.float32)       # replicated copies
    err = jnp.zeros((p, D), jnp.float32)
    step = make_step(compressed)
    for _ in range(120):
        w, err = step(w, err, jnp.asarray(X), jnp.asarray(Y))
    final = np.asarray(w)[0]
    resid = np.linalg.norm(final - w_true) / np.linalg.norm(w_true)
    print(f"compressed={compressed}: relative residual {resid:.4f}")
    assert resid < 0.05, resid
    # replicas stayed in sync (identical reductions on every rank)
    assert np.allclose(np.asarray(w)[0], np.asarray(w)[-1], atol=1e-5)

print("compression_train OK")

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Elastic checkpoint/restart: save from an 8-device (4x2) mesh, restore
onto a 4-device (2x2) mesh (simulated node loss), losses keep decreasing."""

import tempfile

import numpy as np
import jax
from repro import compat
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.launch.mesh import rules_for_mesh
from repro.train import AdamWConfig, init_train_state, make_train_step, \
    restore, save
from repro.train.step import state_specs

cfg = get_smoke_config("llama3.2-3b")
rng = np.random.default_rng(0)
opt = AdamWConfig(warmup_steps=2, total_steps=20)


def mk_batch():
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                                  jnp.int32)}


def put(state, mesh, rules):
    specs = state_specs(cfg, rules)
    return jax.tree_util.tree_map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
        state, specs, is_leaf=lambda x: isinstance(x, P))


# phase 1: 8 devices (4x2)
mesh8 = jax.make_mesh((4, 2), ("data", "model"))
rules8 = rules_for_mesh(mesh8)
state = put(init_train_state(jax.random.PRNGKey(0), cfg, jnp.float32),
            mesh8, rules8)
step8 = jax.jit(make_train_step(cfg, opt, rules8, ce_chunk=16))
losses = []
with compat.set_mesh(mesh8):
    for _ in range(6):
        state, m = step8(state, mk_batch())
        losses.append(float(m["loss"]))

tmp = tempfile.mkdtemp()
save(f"{tmp}/ckpt_6", state, 6)

# phase 2: "node failure" -> restart on 4 devices (2x2)
mesh4 = jax.make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
rules4 = rules_for_mesh(mesh4)
specs4 = state_specs(cfg, rules4)
shardings4 = jax.tree_util.tree_map(
    lambda sp: NamedSharding(mesh4, sp), specs4,
    is_leaf=lambda x: isinstance(x, P))
like = init_train_state(jax.random.PRNGKey(0), cfg, jnp.float32)
state4 = restore(f"{tmp}/ckpt_6", like, shardings4)
assert int(state4["opt"]["step"]) == 6

step4 = jax.jit(make_train_step(cfg, opt, rules4, ce_chunk=16))
with compat.set_mesh(mesh4):
    for _ in range(6):
        state4, m = step4(state4, mk_batch())
        losses.append(float(m["loss"]))

assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses
print(f"elastic_checkpoint OK: losses {losses[0]:.3f} -> {losses[-1]:.3f} "
      f"across a 8->4 device restart")

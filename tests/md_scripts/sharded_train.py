import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Sharded-vs-unsharded training parity on a (4, 2) data x model mesh:
identical params + batch must give identical loss and matching updates."""

import numpy as np
import jax
from repro import compat
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.launch.mesh import make_local_mesh, rules_for_mesh
from repro.models.layers import NO_SHARDING
from repro.train import AdamWConfig, init_train_state, make_train_step
from repro.train.step import state_specs

rng = np.random.default_rng(0)

for arch in ("llama3.2-3b", "olmoe-1b-7b", "mamba2-780m", "jamba-v0.1-52b",
             "deepseek-v2-lite-16b"):
    cfg = get_smoke_config(arch)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32),
    }
    opt = AdamWConfig(warmup_steps=1, total_steps=4)
    state = init_train_state(jax.random.PRNGKey(0), cfg, jnp.float32)

    # single-device reference
    s1, m1 = jax.jit(make_train_step(cfg, opt, NO_SHARDING, ce_chunk=16))(
        state, batch)

    # sharded
    mesh = make_local_mesh(8, model=2)
    rules = rules_for_mesh(mesh)
    specs = state_specs(cfg, rules)
    sharded = jax.tree_util.tree_map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
        state, specs, is_leaf=lambda x: isinstance(x, P))
    with compat.set_mesh(mesh):
        s2, m2 = jax.jit(make_train_step(cfg, opt, rules, ce_chunk=16))(
            sharded, batch)

    l1, l2 = float(m1["loss"]), float(m2["loss"])
    assert np.isclose(l1, l2, rtol=2e-3), (arch, l1, l2)
    g1, g2 = float(m1["grad_norm"]), float(m2["grad_norm"])
    assert np.isclose(g1, g2, rtol=2e-2), (arch, g1, g2)
    # one representative param leaf identical after update
    p1 = jax.tree_util.tree_leaves(s1["params"])[0]
    p2 = jax.tree_util.tree_leaves(s2["params"])[0]
    assert np.allclose(np.asarray(p1), np.asarray(p2), atol=2e-4), arch
    print(f"{arch}: sharded loss {l2:.4f} == single {l1:.4f}")

print("sharded_train OK")

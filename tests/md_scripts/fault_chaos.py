import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Chaos suite: fault-tolerant execution on 8 devices under fixed-seed
randomized fault plans (``repro.faults.random_plan``).

1. Out-of-core join+groupby+sort pipeline under 8 randomized plans
   (raise + short hangs at random sites/occurrences): every run completes
   BIT-IDENTICAL to the fault-free reference with zero dropped rows, and
   the sweep as a whole actually fired faults.
2. In-core bsp_staged storm: consecutive stage-launch and all-to-all
   chunk faults burn most of one unit's retry budget; recovery is
   bit-identical.
3. corrupt-capacity chaos: corrupted working capacities force the degrade
   path; the result is still bit-identical (integer payloads + final
   sort) with zero drops.

When ``OBS_ARTIFACT_DIR`` is set (the CI chaos step sets it), a
machine-readable summary of every chaos run lands there.
"""

import json

import numpy as np

from repro.core import CylonEnv, DistTable, Plan, execute
from repro.faults import FaultPlan, RetryPolicy, random_plan

rng = np.random.default_rng(11)
N = 8_000
ld = {"k": rng.integers(0, N // 2, N).astype(np.int32),
      "v0": rng.integers(0, 100, N).astype(np.float32)}
rd = {"k": rng.integers(0, N // 2, N).astype(np.int32),
      "w": rng.integers(0, 100, N).astype(np.float32)}

env = CylonEnv()
assert env.parallelism == 8
MORSEL = -(-N // 8 // 4) // 8 * 8          # rows/rank/4, 8-aligned

# integer-valued payloads + a final sort: bit-identity is meaningful even
# when recovery (or degrade) reshapes the execution
pipe = (Plan.scan("l")
        .join(Plan.scan("r"), on="k")
        .groupby(["k"], {"v0": ["sum"], "w": ["max"]})
        .sort(["k"]))
tables = {"l": ld, "r": rd}

ref, rst = execute(pipe, env, tables, morsel_rows=MORSEL,
                   collect_stats=True, faults=False)
assert rst.rows_dropped == 0
ref_np = ref.to_numpy()
assert ref_np["k"].size > 0

runs = []

# --- 1. randomized single/double faults, 8 fixed seeds ------------------ #
fired_total = 0
for seed in range(8):
    fp = random_plan(seed, nfaults=2, kinds=("raise", "hang"),
                     max_occurrence=4)
    fp = FaultPlan(fp.specs, seed=fp.seed, hang_s=0.05)
    out, st = execute(pipe, env, tables, morsel_rows=MORSEL,
                      collect_stats=True, faults=fp)
    assert st.rows_dropped == 0, (seed, st.rows_dropped)
    got = out.to_numpy()
    assert sorted(got) == sorted(ref_np)
    for c in ref_np:
        np.testing.assert_array_equal(ref_np[c], got[c], err_msg=str(fp))
    assert st.retries >= st.faults_injected > 0 or st.faults_injected == 0
    fired_total += st.faults_injected
    runs.append({"phase": "random", "seed": seed, "plan": str(fp),
                 "faults_injected": st.faults_injected,
                 "retries": st.retries, "degraded": st.degraded,
                 "rows_dropped": st.rows_dropped})
assert fired_total > 0, "chaos sweep never fired a fault"
print(f"random plans: {fired_total} faults fired across 8 seeds, "
      f"0 rows dropped, bit-identical")

# --- 2. in-core storm: consecutive faults burn most of the budget ------- #
lt = DistTable.from_numpy(ld, 8)
rt = DistTable.from_numpy(rd, 8)
ic_tables = {"l": lt, "r": rt}
ic_ref, _ = execute(pipe, env, ic_tables, mode="bsp_staged", a2a_chunks=2,
                    collect_stats=True, faults=False)
ic_ref_np = ic_ref.to_numpy()
# @* fires on retry visits too: three stage launches + two a2a chunks in
# a row fault before anything passes, so one unit eats 5 of its 6 retries
out, st = execute(pipe, env, ic_tables, mode="bsp_staged", a2a_chunks=2,
                  collect_stats=True,
                  faults="stage:launch@*x3=raise;a2a:chunk@*x2=raise",
                  retries=RetryPolicy(retries=6, backoff_s=0.001))
assert st.faults_injected >= 3 and st.retries == st.faults_injected
got = out.to_numpy()
for c in ic_ref_np:
    np.testing.assert_array_equal(ic_ref_np[c], got[c])
runs.append({"phase": "storm", "plan": "stage:launch@*;a2a:chunk@*",
             "faults_injected": st.faults_injected, "retries": st.retries,
             "degraded": st.degraded, "rows_dropped": st.rows_dropped})
print(f"in-core storm: {st.faults_injected} faults, recovered "
      f"bit-identical")

# --- 3. corrupt-capacity chaos: degrade, never drop --------------------- #
out, st = execute(pipe, env, tables, morsel_rows=MORSEL, collect_stats=True,
                  faults="segment:launch@*x2=corrupt-capacity;"
                         "build:resident@0=corrupt-capacity")
assert st.faults_injected > 0
assert st.rows_dropped == 0, st.rows_dropped
got = out.to_numpy()
for c in ref_np:
    np.testing.assert_array_equal(ref_np[c], got[c])
runs.append({"phase": "corrupt", "plan": "segment+build corrupt-capacity",
             "faults_injected": st.faults_injected, "retries": st.retries,
             "degraded": st.degraded, "rows_dropped": st.rows_dropped})
print(f"corrupt-capacity: {st.faults_injected} corruptions, "
      f"{st.degraded} degrades, 0 rows dropped, bit-identical")

art = os.environ.get("OBS_ARTIFACT_DIR")
if art:
    os.makedirs(art, exist_ok=True)
    with open(os.path.join(art, "fault_chaos.json"), "w") as f:
        json.dump({"rows": N, "parallelism": 8, "morsel_rows": MORSEL,
                   "runs": runs}, f, indent=1, sort_keys=True)
    print(f"chaos artifacts -> {art}/fault_chaos.json")

print("OK")

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""EXPLAIN ANALYZE golden scenario on 8 devices: the Fig-9 pipeline
(join -> groupby -> sort -> add_scalar) under ``bsp_staged``, checked for

1. the annotated tree renders with measured actuals per node,
2. per-stage times sum to no more than the query wall time,
3. the report's byte totals match ``ExecStats`` / its shuffle records,
4. the Chrome trace is valid ``trace_event`` JSON with the expected
   span categories nested under one query span,
5. the metrics registry export carries the schema CI archives.

When ``OBS_ARTIFACT_DIR`` is set (the CI multidevice job does), the
trace and metrics JSON land there as build artifacts.
"""

import json

import numpy as np

from repro.core import CylonEnv, DistTable, Plan
from repro.obs import METRICS, run_analyzed

rng = np.random.default_rng(0)
N = 4000
CAP = 1024
ld = {"k": rng.integers(0, 500, N).astype(np.int32),
      "v0": rng.integers(0, 64, N).astype(np.float32),
      "junk": rng.random(N).astype(np.float32)}
rd = {"k": rng.integers(0, 500, N).astype(np.int32),
      "w": rng.integers(0, 64, N).astype(np.float32)}

env = CylonEnv()
assert env.parallelism == 8
TABLES = {"l": DistTable.from_numpy(ld, 8, capacity=CAP),
          "r": DistTable.from_numpy(rd, 8, capacity=CAP)}

fig9 = (Plan.scan("l")
        .join(Plan.scan("r"), on="k", out_capacity=16 * CAP,
              bucket_capacity=2 * CAP)
        .groupby(["k"], {"v0": ["sum"]}, bucket_capacity=2 * CAP)
        .sort(["k"], bucket_capacity=2 * CAP)
        .add_scalar(1.0, cols=["v0_sum"]))

result, report = run_analyzed(fig9, env, TABLES, mode="bsp_staged")
st = report.stats

# -- 1. annotated tree --------------------------------------------------- #
text = report.explain_analyze()
assert "== EXPLAIN ANALYZE: mode=bsp_staged" in text
assert "join[on=k]" in text and "act: moved" in text
assert f"rows={N}" in text                       # scan actuals, both sides
assert f"out_rows={result.total_rows()}" in text
assert st.rows_dropped == 0, st.shuffle_records
print(text)
print()
print(report.roofline_table())

# -- 2. stage times are attributable and bounded by the wall ------------- #
stage_names = [name for name, _ in st.stage_times]
assert stage_names == [f"stage:{i}" for i in range(st.dispatches)], \
    stage_names
assert all(secs > 0 for _, secs in st.stage_times)
assert sum(secs for _, secs in st.stage_times) <= st.wall_time_s + 1e-6

# -- 3. report totals match ExecStats / shuffle records ------------------ #
d = report.to_dict()
assert d["rows_shuffled"] == st.rows_shuffled
assert d["bytes_shuffled"] == st.bytes_shuffled
recs = st.shuffle_records
assert sum(r.rows for r in recs) == st.rows_shuffled
assert sum(r.bytes for r in recs) == st.bytes_shuffled
assert all(len(r.per_rank_rows) == 8 for r in recs)
assert all(sum(r.per_rank_rows) == r.rows for r in recs)
# stage_table slices the same records by stage: wire totals must agree
# (overflow-bucket records are excluded from the wire by design)
wire = sum(row["wire_bytes"] for row in report.stage_table())
overflow = sum(r.bytes for r in recs if r.label.endswith(":overflow"))
assert wire == st.bytes_shuffled - overflow, (wire, st.bytes_shuffled)

# -- 4. Chrome trace: valid, categorized, nested under one query span ---- #
payload = report.to_chrome_trace()
payload = json.loads(json.dumps(payload))        # round-trips as JSON
evs = payload["traceEvents"]
assert payload["displayTimeUnit"] == "ms"
cats = {e["cat"] for e in evs}
assert {"query", "stage", "shuffle"} <= cats
roots = [e for e in evs if e["cat"] == "query"]
assert len(roots) == 1 and roots[0]["ph"] == "X"
q0, q1 = roots[0]["ts"], roots[0]["ts"] + roots[0]["dur"]
for e in evs:
    assert {"name", "cat", "ph", "ts", "pid", "tid"} <= set(e), e
    assert q0 <= e["ts"] <= q1 + 1e-3, e

# -- 5. metrics export schema -------------------------------------------- #
snap = json.loads(METRICS.to_json())
assert {"counters", "gauges", "histograms", "query_records"} <= set(snap)
assert any(c["labels"] == {"mode": "bsp_staged"} and c["value"] >= 1
           for c in snap["counters"]["queries_total"])
rec = snap["query_records"][-1]
for key in ("fingerprint", "mode", "wall_time_s", "stage_times",
            "rows_shuffled", "bytes_shuffled", "rows_dropped",
            "cache_hits", "cache_misses"):
    assert key in rec, key
assert rec["fingerprint"] == report.pplan.fingerprint

# -- CI artifacts --------------------------------------------------------- #
art = os.environ.get("OBS_ARTIFACT_DIR")
if art:
    os.makedirs(art, exist_ok=True)
    report.to_chrome_trace(os.path.join(art, "fig9_trace.json"))
    report.to_json(os.path.join(art, "fig9_report.json"))
    METRICS.to_json(os.path.join(art, "metrics.json"))
    print(f"artifacts -> {art}")

print("explain_analyze_fig9 OK")

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Concurrent multi-query serving stress on 8 devices (PR 8 acceptance).

1. Warm determinism: three Fig-9-style queries compiled on each of the
   four canonical 2-device partitions through one shared ``ProgramCache``
   cost EXACTLY the same number of compiles per partition.
2. Concurrent storm: 16 mixed submissions from 8 threads on a
   ``QueryScheduler`` (gang_size=2, max_inflight=4) are BIT-IDENTICAL to
   the sequential 2-device reference, add ZERO new compiles (every handle
   reports ``cache_misses == 0``), always land on canonical partitions,
   and overlapping executions never share a device.
3. Session routing: ``collect()`` inside ``session(scheduler=...)`` from
   8 threads matches the reference.
4. Clean cancellation: queued queries cancel mid-queue with
   ``QueryCancelled`` while the inflight query completes bit-identical.
5. Faulted serving: threaded submission under a fixed-seed fault plan
   (stage-launch + all-to-all chunk raises, retry budget) recovers
   bit-identical.

When ``OBS_ARTIFACT_DIR`` is set, a machine-readable summary lands there.
"""

import json
import threading
import time

import numpy as np

import repro.df as rdf
from repro.core import CylonEnv, DevicePool
from repro.expr import col
from repro.faults import QueryCancelled, RetryPolicy
from repro.serve import ProgramCache, QueryScheduler

rng = np.random.default_rng(7)
N = 4000
NK = int(N * 0.9)
ld = {"k": rng.integers(0, NK, N).astype(np.int32),
      "v0": rng.integers(0, 256, N).astype(np.float32),
      "junk": rng.integers(0, 256, N).astype(np.float32)}
rd = {"k": rng.integers(0, NK, N).astype(np.int32),
      "w": rng.integers(0, 256, N).astype(np.float32)}

shared = ProgramCache(registry=False)
pool = DevicePool()
assert pool.size == 8
GANG = 2
PARTS = [(0, 1), (2, 3), (4, 5), (6, 7)]

sched = QueryScheduler(pool=pool, gang_size=GANG, max_inflight=4,
                       max_queue=64, program_cache=shared, name="stress")

# ingest inside the scheduler session: partitioned for gang_size=2, NOT
# pinned to any env, so the frames run on whichever gang is carved
with rdf.session(scheduler=sched):
    left = rdf.read_numpy(ld, name="l")
    right = rdf.read_numpy(rd, name="r")
CAP = next(iter(left.sources.values())).capacity
JKW = dict(out_capacity=CAP * 4, bucket_capacity=CAP * 2,
           shuffle_out_capacity=CAP * 2)

QUERIES = {
    "join": lambda: (left.merge(right, on="k", **JKW)
                     [(col("v0") > 4) & (col("w") < 250)]
                     .groupby("k").agg({"v0": ["sum"]})
                     .sort_values("k")),
    "groupby": lambda: (left.groupby("k")
                        .agg({"v0": ["sum", "mean"], "junk": ["max"]})
                        .sort_values("k")),
    "filter": lambda: (left[(col("v0") > 64) & (col("junk") < 200)]
                       .sort_values("k")),
}

# --- sequential reference + warm determinism ----------------------------- #
refs = {}
env0 = CylonEnv([pool.devices[i] for i in PARTS[0]], program_cache=shared)
for qname, q in QUERIES.items():
    refs[qname] = q().collect(env=env0).to_numpy()
per_part = shared.misses
assert per_part > 0
for part in PARTS[1:]:
    before = shared.misses
    env = CylonEnv([pool.devices[i] for i in part], program_cache=shared)
    for qname, q in QUERIES.items():
        got = q().collect(env=env).to_numpy()
        for c in refs[qname]:
            np.testing.assert_array_equal(refs[qname][c], got[c],
                                          err_msg=f"{qname} on {part}")
    assert shared.misses - before == per_part, (
        f"partition {part} compiled {shared.misses - before}, "
        f"expected exactly {per_part}")
base_misses = shared.misses
assert base_misses == 4 * per_part
print(f"warm: {per_part} programs/partition x 4 partitions, "
      f"per-partition compile counts exactly equal, bit-identical")

# --- concurrent storm: 16 mixed submissions from 8 threads --------------- #
names = sorted(QUERIES)
handles = [None] * 16
errors = []
barrier = threading.Barrier(8)


def submitter(t):
    try:
        barrier.wait()
        for j in (2 * t, 2 * t + 1):
            handles[j] = (names[j % 3],
                          sched.submit(QUERIES[names[j % 3]](),
                                       label=f"storm-{j}", timeout=300.0))
    except Exception as e:  # pragma: no cover - failure path
        errors.append(e)


threads = [threading.Thread(target=submitter, args=(t,)) for t in range(8)]
for t in threads:
    t.start()
for t in threads:
    t.join(timeout=60)
assert not errors, errors

spans = []
for qname, handle in handles:
    got = handle.result(timeout=600).to_numpy()
    for c in refs[qname]:
        np.testing.assert_array_equal(refs[qname][c], got[c],
                                      err_msg=handle.label)
    s = handle.stats
    assert s["cache_misses"] == 0, (handle.label, s)
    assert tuple(s["devices"]) in set(PARTS), s["devices"]
    spans.append((handle.label, s["started_monotonic"],
                  s["finished_monotonic"], frozenset(s["devices"])))
assert shared.misses == base_misses, "storm recompiled something"

# overlapping executions must hold disjoint device partitions
overlaps = 0
for i in range(len(spans)):
    for j in range(i + 1, len(spans)):
        la, a0, a1, da = spans[i]
        lb, b0, b1, db = spans[j]
        if a0 < b1 and b0 < a1:
            overlaps += 1
            assert not (da & db), f"{la} and {lb} overlapped on {da & db}"
assert overlaps > 0, "storm never ran two queries concurrently"
print(f"storm: 16 queries, {overlaps} concurrent pairs, 0 recompiles, "
      f"disjoint gangs, bit-identical")

# --- session routing from threads ---------------------------------------- #
route_errors = []


def routed(t):
    try:
        qname = names[t % 3]
        with rdf.session(scheduler=sched):
            got = QUERIES[qname]().collect().to_numpy()
        for c in refs[qname]:
            np.testing.assert_array_equal(refs[qname][c], got[c])
    except Exception as e:  # pragma: no cover - failure path
        route_errors.append(e)


threads = [threading.Thread(target=routed, args=(t,)) for t in range(8)]
for t in threads:
    t.start()
for t in threads:
    t.join(timeout=600)
assert not route_errors, route_errors
assert shared.misses == base_misses
print("session routing: 8 threads through session(scheduler=...), "
      "bit-identical")

# --- clean cancellation mid-queue ---------------------------------------- #
class SlowFrame:
    def __init__(self, inner, delay):
        self.inner, self.delay = inner, delay

    def collect(self, **kw):
        time.sleep(self.delay)
        return self.inner.collect(**kw)


narrow = QueryScheduler(pool=pool, gang_size=GANG, max_inflight=1,
                        max_queue=8, program_cache=shared, name="narrow")
running = narrow.submit(SlowFrame(QUERIES["groupby"](), 0.4))
time.sleep(0.1)                       # the single worker picks it up
queued = [narrow.submit(QUERIES[names[i % 3]]()) for i in range(3)]
victim = queued[1]
assert victim.cancel("mid-queue cancellation")
try:
    victim.result(timeout=5)
    raise AssertionError("cancelled query returned a result")
except QueryCancelled:
    pass
assert victim.stats["state"] == "cancelled"
got = running.result(timeout=600).to_numpy()
for c in refs["groupby"]:
    np.testing.assert_array_equal(refs["groupby"][c], got[c])
for i, handle in enumerate(queued):
    if handle is victim:
        continue
    got = handle.result(timeout=600).to_numpy()
    for c in refs[names[i % 3]]:
        np.testing.assert_array_equal(refs[names[i % 3]][c], got[c])
narrow.close()
print("cancellation: mid-queue cancel clean, survivors bit-identical")

# --- threaded submission under a fixed-seed fault plan ------------------- #
FAULTS = "stage:launch@0x1=raise;a2a:chunk@1x1=raise"
FKW = dict(mode="bsp_staged", a2a_chunks=2, collect_stats=True,
           faults=FAULTS, retries=RetryPolicy(retries=6, backoff_s=0.001))
fault_ref, fr_stats = QUERIES["join"]().collect(
    env=env0, mode="bsp_staged", a2a_chunks=2, collect_stats=True,
    faults=False)
fault_ref = fault_ref.to_numpy()
fh = [sched.submit(QUERIES["join"](), label=f"faulted-{i}", **FKW)
      for i in range(4)]
fired = 0
for handle in fh:
    out, st = handle.result(timeout=600)
    got = out.to_numpy()
    for c in fault_ref:
        np.testing.assert_array_equal(fault_ref[c], got[c],
                                      err_msg=handle.label)
    assert st.rows_dropped == 0
    fired += st.faults_injected
assert fired > 0, "fault plan never fired under serving"
print(f"faulted serving: {fired} faults fired across 4 queries, "
      f"recovered bit-identical")

final = sched.stats()
sched.close()
assert pool.available == 8, "leaked device leases"

art = os.environ.get("OBS_ARTIFACT_DIR")
if art:
    os.makedirs(art, exist_ok=True)
    with open(os.path.join(art, "serving_stress.json"), "w") as f:
        json.dump({"rows": N, "gang_size": GANG,
                   "programs_per_partition": per_part,
                   "storm_queries": 16, "concurrent_pairs": overlaps,
                   "faults_fired": fired, "scheduler": final},
                  f, indent=1, sort_keys=True, default=str)
    print(f"serving artifacts -> {art}/serving_stress.json")

print("OK")

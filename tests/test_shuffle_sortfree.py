"""Sort-free (radix) shuffle vs the sorted baseline: deterministic parity.

Multi-rank behaviour is simulated with ``jax.vmap(axis_name=...)`` — every
collective the communicators use (all_to_all / ppermute / all_gather) has a
batching rule for named axes, so p ranks run on the single CPU test device.
The randomized hypothesis property lives in
``test_shuffle_sortfree_props.py``; real 8-device bit-identity runs in
``tests/md_scripts/sortfree_shuffle_parity.py``.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.comm import get_communicator
from repro.dataframe import ShuffleStats, Table, shuffle

RNG = np.random.default_rng(7)


def run_ranks(comm_name, cols_np, counts_np, **kw):
    """Run ``shuffle`` on p simulated ranks; returns (out_cols, row_counts,
    stats) as numpy, plus the static stats tags."""
    comm = get_communicator(comm_name, "df")
    cols = {k: jnp.asarray(v) for k, v in cols_np.items()}
    counts = jnp.asarray(counts_np, jnp.int32)
    tags = {}

    def f(cols, count):
        out, st = shuffle(Table(dict(cols), count), comm, **kw)
        tags["impl"], tags["chunks"] = st.shuffle_impl, st.a2a_chunks
        return (dict(out.columns), out.row_count,
                (st.sent_counts, st.recv_counts, st.send_dropped,
                 st.recv_dropped))

    out_cols, rc, stats = jax.vmap(f, axis_name="df")(cols, counts)
    return (jax.tree_util.tree_map(np.asarray, (out_cols, rc, stats)),
            tags)


def make_ranks(p, cap, n_keys=50, skew=False):
    if skew:   # zipf-skewed keys: a few destinations absorb most rows
        k = (RNG.zipf(1.4, (p, cap)) % n_keys).astype(np.int32)
    else:
        k = RNG.integers(0, n_keys, (p, cap)).astype(np.int32)
    cols = {"k": k, "v": RNG.random((p, cap)).astype(np.float32)}
    counts = RNG.integers(0, cap + 1, p).astype(np.int32)
    return cols, counts


@pytest.mark.parametrize("comm_name", ["ring", "bruck", "xla"])
@pytest.mark.parametrize("p", [1, 2, 4, 8])
def test_radix_matches_sorted(comm_name, p):
    cols, counts = make_ranks(p, 64)
    ref, rtags = run_ranks(comm_name, cols, counts, key_cols=["k"],
                           bucket_capacity=32, impl="sorted")
    got, gtags = run_ranks(comm_name, cols, counts, key_cols=["k"],
                           bucket_capacity=32, impl="radix")
    assert (rtags["impl"], gtags["impl"]) == ("sorted", "radix")
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(a, b)   # bit-identical, slots included


@pytest.mark.parametrize("chunks", [1, 2, 3, 8])
def test_chunked_a2a_matches_monolithic(chunks):
    p = 4
    cols, counts = make_ranks(p, 48)
    ref, _ = run_ranks("ring", cols, counts, key_cols=["k"],
                       bucket_capacity=24, a2a_chunks=1)
    got, tags = run_ranks("ring", cols, counts, key_cols=["k"],
                          bucket_capacity=24, a2a_chunks=chunks)
    assert tags["chunks"] == chunks
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(a, b)


def test_skewed_overflow_parity_and_counts():
    p = 8
    cols, counts = make_ranks(p, 64, n_keys=5, skew=True)
    ref, _ = run_ranks("xla", cols, counts, key_cols=["k"],
                       bucket_capacity=16, impl="sorted")
    got, _ = run_ranks("xla", cols, counts, key_cols=["k"],
                       bucket_capacity=16, impl="radix")
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(a, b)
    (_, rc, (sent, recv, send_drop, recv_drop)) = got
    total = int(counts.sum())
    kept = int(rc.sum()) + int(recv_drop.sum())
    assert kept + int(send_drop.sum()) == total
    assert int(send_drop.sum()) > 0   # 5 hot keys into 8x16-slot buckets


def test_debug_overflow_warns():
    import warnings
    p = 2
    cols, counts = make_ranks(p, 32, n_keys=3)
    counts = np.full(p, 32, np.int32)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        run_ranks("xla", cols, counts, key_cols=["k"], bucket_capacity=8,
                  debug_overflow=True)
        # the warning names the op label (bare "shuffle" here) and the rank
        assert any("shuffle @ rank" in str(x.message)
                   and "dropped rows" in str(x.message) for x in w)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        run_ranks("xla", cols, counts, key_cols=["k"], bucket_capacity=64,
                  out_capacity=128, debug_overflow=True)
        assert not any("dropped rows" in str(x.message) for x in w)


def test_stats_static_tags_roundtrip_pytree():
    st = ShuffleStats(jnp.zeros(4, jnp.int32), jnp.zeros(4, jnp.int32),
                      jnp.asarray(0), jnp.asarray(0),
                      shuffle_impl="sorted", a2a_chunks=4)
    leaves, treedef = jax.tree_util.tree_flatten(st)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.shuffle_impl == "sorted" and back.a2a_chunks == 4


def test_unknown_impl_raises():
    comm = get_communicator("xla", "df")
    t = Table.from_arrays({"k": np.zeros(8, np.int32)})
    with pytest.raises(ValueError, match="unknown shuffle impl"):
        shuffle(t, comm, key_cols=["k"], impl="quantum")

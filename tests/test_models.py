"""Per-arch smoke tests + model-component parity tests (1 device, CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import transformer as T
from repro.models.attention import chunked_attention
from repro.models.config import SHAPES
from repro.models.layers import NO_SHARDING
from repro.kernels import attention_ref
from repro.train import AdamWConfig, init_train_state, make_train_step

RNG = np.random.default_rng(0)
B, S = 2, 32


def _batch(cfg):
    batch = {}
    if cfg.family == "audio":
        shape = (B, S, cfg.num_codebooks)
        batch["tokens"] = jnp.asarray(RNG.integers(0, cfg.vocab_size, shape),
                                      jnp.int32)
        batch["labels"] = jnp.asarray(RNG.integers(0, cfg.vocab_size, shape),
                                      jnp.int32)
    elif cfg.family == "vlm":
        p = 8
        batch["patch_embeds"] = jnp.asarray(
            RNG.standard_normal((B, p, cfg.d_model)), jnp.float32)
        batch["tokens"] = jnp.asarray(
            RNG.integers(0, cfg.vocab_size, (B, S - p)), jnp.int32)
        batch["labels"] = jnp.asarray(
            RNG.integers(0, cfg.vocab_size, (B, S - p)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)),
                                      jnp.int32)
        batch["labels"] = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)),
                                      jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced same-family config: one forward + one train step, no NaNs."""
    cfg = get_smoke_config(arch)
    batch = _batch(cfg)
    state = init_train_state(jax.random.PRNGKey(0), cfg, jnp.float32)
    h, aux = T.forward(state["params"], cfg, batch, NO_SHARDING)
    assert h.shape[0] == B and h.shape[-1] == cfg.d_model
    assert bool(jnp.isfinite(h).all())
    step = make_train_step(cfg, AdamWConfig(warmup_steps=1, total_steps=4),
                           NO_SHARDING, ce_chunk=16)
    state2, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    d0 = jax.tree_util.tree_leaves(state["params"])[1]
    d1 = jax.tree_util.tree_leaves(state2["params"])[1]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_smoke_config(a).family != "vlm"])
def test_smoke_decode_matches_prefill(arch):
    """Greedy-decode logits equal full-prefill logits at the last position."""
    cfg = get_smoke_config(arch)
    batch = _batch(cfg)
    params = T.init_params(jax.random.PRNGKey(1), cfg, jnp.float32)
    logits_full, _ = T.prefill(params, cfg, batch, S + 4, NO_SHARDING)
    short = {k: (v[:, :-1] if k == "tokens" else v) for k, v in batch.items()}
    _, caches = T.prefill(params, cfg, short, S + 4, NO_SHARDING)
    tok = batch["tokens"][:, -1:]
    ld, _ = T.decode_step(params, cfg, caches, tok,
                          jnp.full((B,), S - 1, jnp.int32), NO_SHARDING)
    np.testing.assert_allclose(ld, logits_full, atol=3e-2)


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    spec = {
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "mamba2-780m": (48, 1536, None, None, 0, 50280),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    }
    for arch, (nl, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.num_layers == nl, arch
        assert cfg.d_model == d, arch
        if h is not None:
            assert cfg.num_heads == h, arch
            assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch
    ds = get_config("deepseek-v2-lite-16b")
    assert ds.mla.kv_lora_rank == 512 and ds.moe.top_k == 6
    ol = get_config("olmoe-1b-7b")
    assert ol.moe.num_experts == 64 and ol.moe.top_k == 8
    jb = get_config("jamba-v0.1-52b")
    assert jb.layer_pattern == "mmmmammm" and jb.moe.num_experts == 16
    assert get_config("musicgen-large").num_codebooks == 4
    assert get_config("mamba2-780m").ssm.d_state == 128


def test_layer_layout_all_archs():
    for arch in ARCHS:
        cfg = get_config(arch)
        n_prefix, period, n_periods = T.layer_layout(cfg)
        assert n_prefix + period * n_periods == cfg.num_layers


def test_chunked_attention_mla_value_dim():
    """Dv != D (MLA): chunked path matches the dense oracle."""
    q = jnp.asarray(RNG.standard_normal((2, 4, 300, 24)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 2, 300, 24)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 2, 300, 16)), jnp.float32)
    o1 = chunked_attention(q, k, v, causal=True, block_k=64)
    o2 = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(o1, o2, atol=2e-3)


def test_long_500k_eligibility():
    from repro.launch.shapes import cell
    subq = {a for a in ARCHS if get_config(a).sub_quadratic}
    assert subq == {"mamba2-780m", "jamba-v0.1-52b"}
    for a in ARCHS:
        c = cell(a, "long_500k")
        assert c.eligible == (a in subq)


def test_padded_vocab_loss_excludes_pad_rows():
    """CE over a padded vocab equals CE over the exact vocab."""
    cfg = get_smoke_config("mamba2-780m")  # vocab 256 (= padded), force pad:
    import dataclasses
    cfg2 = dataclasses.replace(cfg, vocab_size=250)
    params = T.init_params(jax.random.PRNGKey(0), cfg2, jnp.float32)
    batch = {
        "tokens": jnp.asarray(RNG.integers(0, 250, (B, S)), jnp.int32),
        "labels": jnp.asarray(RNG.integers(0, 250, (B, S)), jnp.int32),
    }
    loss, _ = T.loss_fn(params, cfg2, batch, NO_SHARDING, ce_chunk=16)
    assert np.isfinite(float(loss))
    # manual CE with explicit -inf masking must agree
    h, _ = T.forward(params, cfg2, batch, NO_SHARDING)
    w = params["embed"].astype(jnp.bfloat16)
    logits = jnp.einsum("bsd,vd->bsv", h, w).astype(jnp.float32)
    logits = jnp.where(jnp.arange(logits.shape[-1]) < 250, logits, -1e30)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, batch["labels"][..., None], -1)[..., 0]
    manual = jnp.mean(lse - ll + 1e-4 * lse ** 2)
    np.testing.assert_allclose(float(loss), float(manual), rtol=1e-4)

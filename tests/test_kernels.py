"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (attention_ref, flash_attention, radix_partition,
                           radix_partition_ref, radix_partition_xla,
                           segmented_sum, segmented_sum_ref, ssd_scan,
                           ssd_scan_chunked_jnp, ssd_scan_ref)

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------- #
# segmented_sum
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("n,segs,cols", [(64, 5, 1), (500, 37, 3),
                                         (1024, 512, 2), (300, 1, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_segmented_sum_sweep(n, segs, cols, dtype):
    seg = jnp.asarray(np.sort(RNG.integers(0, segs, n)).astype(np.int32))
    if dtype == jnp.float32:
        vals = jnp.asarray(RNG.random((n, cols)).astype(np.float32))
    else:
        vals = jnp.asarray(RNG.integers(-50, 50, (n, cols)).astype(np.int32))
    got = segmented_sum(seg, vals, segs)
    want = segmented_sum_ref(seg, vals, segs)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_segmented_sum_1d():
    seg = jnp.asarray(np.sort(RNG.integers(0, 9, 100)).astype(np.int32))
    vals = jnp.asarray(RNG.random(100).astype(np.float32))
    got = segmented_sum(seg, vals, 9)
    want = segmented_sum_ref(seg, vals, 9)
    np.testing.assert_allclose(got, want, rtol=1e-5)
    assert got.shape == (9,)


# ---------------------------------------------------------------------- #
# radix_partition
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("n,buckets", [(17, 3), (256, 16), (1000, 128),
                                       (513, 7), (2048, 1024)])
@pytest.mark.parametrize("impl", ["auto", "pallas", "xla"])
def test_radix_partition_sweep(n, buckets, impl):
    dest = jnp.asarray(RNG.integers(0, buckets, n).astype(np.int32))
    r1, h1 = radix_partition(dest, buckets, impl=impl)
    r2, h2 = radix_partition_ref(dest, buckets)
    np.testing.assert_array_equal(r1, r2)
    np.testing.assert_array_equal(h1, h2)
    # histogram property
    np.testing.assert_array_equal(
        np.asarray(h1), np.bincount(np.asarray(dest), minlength=buckets))


@pytest.mark.parametrize("n,buckets,block_rows", [(1000, 9, 128),
                                                  (4096, 17, 256),
                                                  (130, 5, 64)])
def test_radix_partition_xla_blocked_regime(n, buckets, block_rows):
    # force the lax.scan-over-blocks path (the dense/blocked switch is
    # size-based by default) and check it against the sort-based oracle
    dest = jnp.asarray(RNG.integers(0, buckets, n).astype(np.int32))
    r1, h1 = radix_partition_xla(dest, buckets, block_rows=block_rows)
    r2, h2 = radix_partition_ref(dest, buckets)
    np.testing.assert_array_equal(r1, r2)
    np.testing.assert_array_equal(h1, h2)


def test_radix_partition_xla_is_vmap_safe():
    # the shuffle calls this inside shard_map/vmap regions; the pure-jnp
    # formulation must batch (an interpret-mode pallas_call would not)
    dest = jnp.asarray(RNG.integers(0, 8, (4, 256)).astype(np.int32))
    ranks, hist = jax.vmap(lambda d: radix_partition_xla(d, 8))(dest)
    for i in range(4):
        r, h = radix_partition_ref(dest[i], 8)
        np.testing.assert_array_equal(ranks[i], r)
        np.testing.assert_array_equal(hist[i], h)


# ---------------------------------------------------------------------- #
# flash attention
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("b,hq,hkv,sq,sk,d", [
    (1, 4, 4, 128, 128, 64),    # MHA square
    (2, 8, 2, 256, 256, 64),    # GQA
    (1, 4, 1, 128, 128, 128),   # MQA
    (1, 2, 2, 100, 100, 32),    # non-multiple seq (padding path)
    (1, 4, 2, 128, 384, 64),    # cross lengths (kv longer)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hq, hkv, sq, sk, d, dtype):
    q = jnp.asarray(RNG.standard_normal((b, hq, sq, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, hkv, sk, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, hkv, sk, d)), dtype)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = attention_ref(q, k, v, causal=True)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), atol=atol)


def test_flash_attention_non_causal():
    q = jnp.asarray(RNG.standard_normal((1, 2, 128, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 128, 32)), jnp.float32)
    got = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, atol=2e-3)


# ---------------------------------------------------------------------- #
# ssd scan
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("bh,t,p,n,chunk", [
    (2, 64, 16, 8, 32), (3, 256, 16, 8, 64), (1, 100, 8, 4, 32),
    (4, 128, 64, 128, 128),
])
def test_ssd_scan_sweep(bh, t, p, n, chunk):
    x = jnp.asarray(RNG.standard_normal((bh, t, p)), jnp.float32)
    dt = jnp.asarray(RNG.random((bh, t, 1)) * 0.1 + 0.01, jnp.float32)
    a = jnp.asarray(-RNG.random((bh, 1)) - 0.05, jnp.float32)
    b = jnp.asarray(RNG.standard_normal((bh, t, n)), jnp.float32)
    c = jnp.asarray(RNG.standard_normal((bh, t, n)), jnp.float32)
    y_ref, h_ref = ssd_scan_ref(x, dt, a, b, c)
    y_k, h_k = ssd_scan(x, dt, a, b, c, chunk=chunk)
    y_j, h_j = ssd_scan_chunked_jnp(x, dt, a, b, c, chunk=chunk)
    np.testing.assert_allclose(y_k, y_ref, atol=3e-3)
    np.testing.assert_allclose(y_j, y_ref, atol=3e-3)
    np.testing.assert_allclose(h_k, h_ref, atol=3e-3)
    np.testing.assert_allclose(h_j, h_ref, atol=3e-3)

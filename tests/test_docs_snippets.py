"""Executable documentation: every fenced ```python block in README.md and
docs/*.md runs in CI, so docs can never silently drift from the API again.

Convention (documented in ``docs/index.md``):

* blocks in one file execute **in order in a shared namespace**, so a later
  snippet may use names an earlier one defined;
* every file's namespace is seeded with the standard preamble below
  (numpy/pandas/repro imports plus small example columns ``keys`` /
  ``vals`` / ``names``), so snippets can stay as short as prose wants them
  to be;
* each file runs against a fresh 1-device ``CylonEnv`` session.

Anything not runnable belongs in a non-python fence (```text, ```bash, …).
"""

import os
import re

import numpy as np
import pytest

pd = pytest.importorskip("pandas")

import repro.df as rdf  # noqa: E402
from repro.core import CylonEnv  # noqa: E402

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))
_FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)


def _doc_files():
    files = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        files += sorted(os.path.join(docs, f) for f in os.listdir(docs)
                        if f.endswith(".md"))
    return files


def _blocks(path):
    with open(path, encoding="utf-8") as f:
        text = f.read()
    return [m.group(1) for m in _FENCE.finditer(text)]


def _preamble():
    """The namespace every docs snippet may assume (docs/index.md)."""
    rng = np.random.default_rng(0)
    return {
        "np": np,
        "pd": pd,
        "rdf": rdf,
        "rng": rng,
        "keys": rng.integers(0, 29, 128).astype(np.int32),
        "vals": rng.integers(0, 8, 128).astype(np.float32),
        "names": rng.choice(np.array(["ash", "birch", "cedar", "oak"]), 128),
    }


FILES = _doc_files()


def test_docs_exist_and_have_snippets():
    assert any(_blocks(f) for f in FILES), "no python snippets found"


@pytest.mark.parametrize("path", FILES,
                         ids=[os.path.relpath(f, REPO) for f in FILES])
def test_docs_snippets_execute(path):
    blocks = _blocks(path)
    if not blocks:
        pytest.skip("no python snippets")
    env = CylonEnv()
    rdf.set_default_env(env)
    ns = _preamble()
    try:
        for i, block in enumerate(blocks):
            code = compile(block, f"{os.path.basename(path)}[snippet {i}]",
                           "exec")
            exec(code, ns)  # noqa: S102 - executing our own docs is the point
    finally:
        rdf.reset_default_env()

"""Unit tests for the partitioning-aware query optimizer (repro.planner).

Static tests (rules, lowering, EXPLAIN, fingerprints) run on 1 device;
8-device runtime parity + ShuffleStats coverage lives in
``tests/md_scripts/planner_parity.py``.
"""

import numpy as np
import pytest

from repro.core import CylonEnv, DistTable, Plan, execute
from repro.expr import col, lit
from repro.planner import (Partitioning, compile_plan, explain, fingerprint,
                           from_plan, optimize)

#: legacy-callable tests below intentionally exercise the deprecated
#: Plan.filter(callable) shim
legacy = pytest.mark.filterwarnings("ignore::DeprecationWarning")

CAT = {"l": (("k", "v0", "junk"), 8000), "r": (("k", "w"), 8000)}


def fig9_plan():
    return (Plan.scan("l").join(Plan.scan("r"), on="k")
            .groupby(["k"], {"v0": ["sum"]}).sort(["k"])
            .add_scalar(1.0, cols=["v0_sum"]))


# ---------------------------------------------------------------------- #
# Partitioning lattice
# ---------------------------------------------------------------------- #
def test_partitioning_lattice():
    h = Partitioning.hash_(("k",))
    assert h.matches_hash(("k",)) and not h.matches_hash(("k", "j"))
    assert h.colocates(("k", "j"))          # subset-hash co-locates supersets
    assert not Partitioning.hash_(("k", "j")).colocates(("k",))
    r = Partitioning.range_("k")
    assert r.colocates(("k", "j")) and r.matches_range("k")
    assert not r.matches_hash(("k",))       # range never aligns with hash
    assert Partitioning.none().colocates(("k",)) is False
    assert h.restrict(("v",)).kind == "none"
    assert h.restrict(("k", "v")) == h


# ---------------------------------------------------------------------- #
# Shuffle elision (the acceptance pipeline)
# ---------------------------------------------------------------------- #
def test_elides_shuffle_before_groupby():
    plan = Plan.scan("l").shuffle(["k"]).groupby(["k"], {"v0": ["sum"]})
    unopt = compile_plan(plan, CAT, optimize_plan=False)
    opt = compile_plan(plan, CAT, optimize_plan=True)
    assert unopt.num_shuffles == 2
    assert opt.num_shuffles == 1            # groupby's shuffle elided
    assert any("shuffle-elision" in f for f in opt.fired)


def test_explicit_redundant_shuffle_removed():
    plan = Plan.scan("l").shuffle(["k"]).shuffle(["k"]).groupby(
        ["k"], {"v0": ["sum"]})
    opt = compile_plan(plan, CAT)
    assert opt.num_shuffles == 1            # second shuffle + groupby elided


def test_join_chain_elides_one_side():
    chain = (Plan.scan("l").join(Plan.scan("r"), on="k")
             .join(Plan.scan("r"), on="k"))
    unopt = compile_plan(chain, CAT, optimize_plan=False)
    opt = compile_plan(chain, CAT)
    assert unopt.num_shuffles == 4
    assert opt.num_shuffles == 3            # second join's left side elided
    assert any("join-side-selection" in f for f in opt.fired)


def test_sort_after_sort_elided():
    plan = Plan.scan("l").sort(["k"]).sort(["k", "v0"])
    opt = compile_plan(plan, CAT)
    assert opt.num_shuffles == 1            # range(k) satisfies sort by k,v0


def test_out_capacity_blocks_elision():
    # changing the table capacity is observable; elision must not fire
    plan = Plan.scan("l").shuffle(["k"]).groupby(
        ["k"], {"v0": ["sum"]}, out_capacity=128)
    opt = compile_plan(plan, CAT)
    assert opt.num_shuffles == 2


def test_fig9_stage_and_shuffle_counts():
    plan = fig9_plan()
    unopt = compile_plan(plan, CAT, optimize_plan=False)
    opt = compile_plan(plan, CAT)
    assert (unopt.num_stages, unopt.num_shuffles) == (4, 4)
    assert (opt.num_stages, opt.num_shuffles) == (3, 3)


# ---------------------------------------------------------------------- #
# Projection / predicate / pre-agg pushdown
# ---------------------------------------------------------------------- #
def test_projection_pushdown_drops_dead_columns():
    opt = compile_plan(fig9_plan(), CAT)
    assert any("projection-pushdown: drop [junk] before join" in f
               for f in opt.fired)
    # the left scan feeds a projection that keeps only the live columns
    scan_l = next(n for n in opt.order
                  if n.op == "scan" and n.params["name"] == "l")
    proj = next(n for n in opt.order if scan_l in n.inputs)
    assert proj.op == "project" and proj.params["cols"] == ("k", "v0")


def test_projection_preserves_join_suffix():
    # right side's v0 collides with left's; dropping left v0 would rename
    # the required v0_r, so the optimizer must keep left v0 alive
    cat = {"l": (("k", "v0"), 100), "r": (("k", "v0"), 100)}
    plan = (Plan.scan("l").join(Plan.scan("r"), on="k")
            .project(["k", "v0_r"]))
    opt = compile_plan(plan, cat)
    assert opt.root.schema == ("k", "v0_r")
    join = next(n for n in opt.order if n.op == "join")
    assert "v0" in join.inputs[0].schema


def test_predicate_pushdown_below_shuffle():
    plan = (Plan.scan("l").shuffle(["k"])
            .filter(col("v0") > 0))
    opt = compile_plan(plan, CAT)
    order_ops = [n.op for n in opt.order]
    assert order_ops.index("filter") < order_ops.index("shuffle")
    assert any("predicate-pushdown" in f for f in opt.fired)


@legacy
def test_opaque_predicate_not_pushed_into_join():
    plan = (Plan.scan("l").join(Plan.scan("r"), on="k")
            .filter(lambda t: t.col("v0") > 0))       # no cols declared
    opt = compile_plan(plan, CAT)
    order_ops = [n.op for n in opt.order]
    assert order_ops.index("filter") > order_ops.index("join")


def test_declared_predicate_pushed_into_join_side():
    plan = (Plan.scan("l").join(Plan.scan("r"), on="k")
            .filter(col("w") > 0))
    opt = compile_plan(plan, CAT)
    join = next(n for n in opt.order if n.op == "join")
    # the filter must now sit under the join's right input subtree
    right_ops = set()

    def walk(n):
        right_ops.add(n.op)
        for i in n.inputs:
            walk(i)
    walk(join.inputs[1])
    assert "filter" in right_ops


def test_predicate_not_pushed_below_capacity_or_dest_shuffle():
    # out_capacity makes the overflow cut observable; dest is row-aligned
    plan = (Plan.scan("l").shuffle(["k"], out_capacity=16)
            .filter(col("v0") > 0))
    opt = compile_plan(plan, CAT)
    order_ops = [n.op for n in opt.order]
    assert order_ops.index("filter") > order_ops.index("shuffle")
    plan2 = (Plan.scan("l").shuffle(["k"], dest=np.zeros(8, np.int32))
             .filter(col("v0") > 0))
    opt2 = compile_plan(plan2, CAT)
    order_ops2 = [n.op for n in opt2.order]
    assert order_ops2.index("filter") > order_ops2.index("shuffle")


def test_dest_shuffle_has_no_hash_property():
    # dest-routed rows are not hash-placed; groupby must keep its shuffle
    plan = (Plan.scan("l").shuffle(["k"], dest=np.zeros(8, np.int32))
            .groupby(["k"], {"v0": ["sum"]}))
    opt = compile_plan(plan, CAT)
    assert opt.num_shuffles == 2
    assert not any("shuffle-elision" in f for f in opt.fired)


@legacy
def test_fingerprint_distinguishes_large_captured_arrays():
    base = np.zeros(5000, np.float32)
    other = base.copy()
    other[2500] = 1.0

    def mk(arr):
        return Plan.scan("l").filter(
            lambda t, _a=arr: t.col("v0") > _a[0], cols=["v0"]).shuffle(["k"])
    fa = fingerprint(from_plan(mk(base).node, dict(CAT)))
    fb = fingerprint(from_plan(mk(other).node, dict(CAT)))
    assert fa != fb


def test_preaggregation_fires_for_algebraic_aggs():
    plan = Plan.scan("l").groupby(["k"], {"v0": ["sum", "mean"]})
    opt = compile_plan(plan, CAT)
    assert any("pre-aggregation" in f for f in opt.fired)
    gb = next(n for n in opt.order if n.op == "groupby")
    assert gb.params["pre_aggregate"] is True


def test_user_preagg_choice_respected():
    plan = Plan.scan("l").groupby(["k"], {"v0": ["sum"]}, pre_aggregate=False)
    opt = compile_plan(plan, CAT)
    gb = next(n for n in opt.order if n.op == "groupby")
    assert gb.params["pre_aggregate"] is False
    assert not any("pre-aggregation" in f for f in opt.fired)


# ---------------------------------------------------------------------- #
# Structural fingerprint (compile-cache key)
# ---------------------------------------------------------------------- #
def test_fingerprint_is_structural_not_identity():
    a = from_plan(fig9_plan().node, dict(CAT))
    b = from_plan(fig9_plan().node, dict(CAT))
    assert fingerprint(a) == fingerprint(b)


def test_fingerprint_distinguishes_plans():
    base = Plan.scan("l").groupby(["k"], {"v0": ["sum"]})
    other = Plan.scan("l").groupby(["k"], {"v0": ["max"]})
    fa = fingerprint(from_plan(base.node, dict(CAT)))
    fb = fingerprint(from_plan(other.node, dict(CAT)))
    assert fa != fb


@legacy
def test_fingerprint_distinguishes_captured_values():
    # same bytecode, different captured threshold -> different plans
    def mk(th):
        return Plan.scan("l").filter(lambda t, _th=th: t.col("v0") > _th,
                                     cols=["v0"]).shuffle(["k"])
    fa = fingerprint(from_plan(mk(0.1).node, dict(CAT)))
    fb = fingerprint(from_plan(mk(0.9).node, dict(CAT)))
    assert fa != fb


@legacy
def test_execute_distinguishes_captured_values(rng):
    env = CylonEnv()
    data = {"k": rng.integers(0, 10, 64).astype(np.int32),
            "v0": rng.random(64).astype(np.float32)}
    t = DistTable.from_numpy(data, env.parallelism)

    def mk(th):
        return Plan.scan("l").filter(lambda tb, _th=th: tb.col("v0") > _th,
                                     cols=["v0"])
    n1 = len(execute(mk(0.1), env, {"l": t}).to_numpy()["k"])
    n2 = len(execute(mk(0.9), env, {"l": t}).to_numpy()["k"])
    assert n1 == (data["v0"] > 0.1).sum()
    assert n2 == (data["v0"] > 0.9).sum()


def test_missing_scan_schema_raises_helpfully():
    plan = Plan.scan("nope").sort(["k"])
    with pytest.raises(KeyError, match="has no schema"):
        compile_plan(plan, CAT)
    with pytest.raises(KeyError, match="has no schema"):
        explain(plan)          # no tables at all


@legacy
def test_fingerprint_hashes_callables_by_code():
    def pred(t):
        return t.col("v0") > 0
    a = Plan.scan("l").filter(pred, cols=["v0"]).shuffle(["k"])
    b = Plan.scan("l").filter(pred, cols=["v0"]).shuffle(["k"])
    fa = fingerprint(from_plan(a.node, dict(CAT)))
    fb = fingerprint(from_plan(b.node, dict(CAT)))
    assert fa == fb


def test_execute_reuses_cache_for_identical_plans(rng):
    env = CylonEnv()
    data = {"k": rng.integers(0, 10, 64).astype(np.int32),
            "v0": rng.random(64).astype(np.float32)}
    t = DistTable.from_numpy(data, env.parallelism)

    def mk():
        return Plan.scan("l").shuffle(["k"]).groupby(["k"], {"v0": ["sum"]})

    execute(mk(), env, {"l": t})
    n0 = len(env._cache)
    out = execute(mk(), env, {"l": t})    # fresh builder objects, same shape
    assert len(env._cache) == n0
    uk = np.unique(data["k"])
    np.testing.assert_array_equal(np.sort(out.to_numpy()["k"]), uk)


# ---------------------------------------------------------------------- #
# Execution (1 device): optimized == unoptimized, stats plumbing
# ---------------------------------------------------------------------- #
def test_optimized_matches_unoptimized_1dev(rng):
    env = CylonEnv()
    data = {"k": rng.integers(0, 16, 128).astype(np.int32),
            "v0": rng.random(128).astype(np.float32),
            "junk": rng.random(128).astype(np.float32)}
    rdata = {"k": rng.integers(0, 16, 128).astype(np.int32),
             "w": rng.random(128).astype(np.float32)}
    lt = DistTable.from_numpy(data, env.parallelism)
    rt = DistTable.from_numpy(rdata, env.parallelism)
    plan = (Plan.scan("l").join(Plan.scan("r"), on="k", out_capacity=4096)
            .groupby(["k"], {"v0": ["sum"]}).sort(["k"]))
    ref = execute(plan, env, {"l": lt, "r": rt}, optimize=False).to_numpy()
    opt = execute(plan, env, {"l": lt, "r": rt}, optimize=True).to_numpy()
    for c in ref:
        np.testing.assert_array_equal(ref[c], opt[c])


def test_collect_stats(rng):
    env = CylonEnv()
    data = {"k": rng.integers(0, 16, 64).astype(np.int32),
            "v0": rng.random(64).astype(np.float32)}
    t = DistTable.from_numpy(data, env.parallelism)
    plan = Plan.scan("l").shuffle(["k"]).groupby(["k"], {"v0": ["sum"]})
    out, stats = execute(plan, env, {"l": t}, collect_stats=True)
    assert stats.num_shuffles == 1
    assert stats.shuffle_labels == ["shuffle(k)"]
    assert stats.rows_shuffled == 64
    assert stats.bytes_shuffled == 64 * 8   # two 4-byte columns
    assert stats.dispatches == 1


# ---------------------------------------------------------------------- #
# EXPLAIN golden snapshots
# ---------------------------------------------------------------------- #
GOLDEN_FIG9_OPT = """\
== physical plan: 3 stages, 3 shuffles, mode=bsp, shuffle=radix/c1, fingerprint=3186d8a6b80e ==
stage 0:
  scan[l]                                      rows~     8000  part=none         cols=junk,k,v0
  project[k,v0]                                rows~     8000  part=none         cols=k,v0
  scan[r]                                      rows~     8000  part=none         cols=k,w
  project[k]                                   rows~     8000  part=none         cols=k
  join[on=k]                                   rows~     8000  part=hash(k)      cols=k,v0
stage 1:
  groupby[k; v0:sum] (shuffle-elided)          rows~     7200  part=hash(k)      cols=k,v0_sum
  sort[k]                                      rows~     7200  part=range(k)     cols=k,v0_sum
stage 2:
  add_scalar[v0_sum]                           rows~     7200  part=range(k)     cols=k,v0_sum
rules fired:
  - shuffle-elision: groupby(k) runs local-only — input already hash(k)
  - projection-pushdown: drop [junk] before join
  - projection-pushdown: drop [w] before join
  - projection-pushdown: drop [junk,w] before groupby"""

GOLDEN_FIG9_UNOPT = """\
== physical plan: 4 stages, 4 shuffles, mode=bsp, shuffle=radix/c1, fingerprint=37858a051ca8 ==
stage 0:
  scan[l]                                      rows~     8000  part=none         cols=junk,k,v0
  scan[r]                                      rows~     8000  part=none         cols=k,w
  join[on=k]                                   rows~     8000  part=hash(k)      cols=junk,k,v0,w
stage 1:
  groupby[k; v0:sum]                           rows~     7200  part=hash(k)      cols=k,v0_sum
stage 2:
  sort[k]                                      rows~     7200  part=range(k)     cols=k,v0_sum
stage 3:
  add_scalar[v0_sum]                           rows~     7200  part=range(k)     cols=k,v0_sum
rules fired: (none)"""


def test_explain_golden_fig9_optimized():
    assert fig9_plan().explain(CAT) == GOLDEN_FIG9_OPT


def test_explain_golden_fig9_unoptimized():
    assert fig9_plan().explain(CAT, optimize=False) == GOLDEN_FIG9_UNOPT


def test_explain_marks_elided_join_side():
    chain = (Plan.scan("l").join(Plan.scan("r"), on="k")
             .join(Plan.scan("r"), on="k"))
    text = chain.explain(CAT)
    assert "join[on=k] (left-elided)" in text
    assert "join-side-selection" in text


# ---------------------------------------------------------------------- #
# Expression-driven rules (PR 4): conjunction split, with_columns
# ---------------------------------------------------------------------- #
def test_conjunction_splits_across_join_sides():
    # one filter, one conjunct per join side: each must land in its input
    plan = (Plan.scan("l").join(Plan.scan("r"), on="k")
            .filter((col("v0") > 0) & (col("w") < 1)))
    opt = compile_plan(plan, CAT)
    assert any("split-conjunction" in f for f in opt.fired)
    join = next(n for n in opt.order if n.op == "join")

    def ops_under(node):
        seen = set()

        def walk(n):
            seen.add(n.op)
            for i in n.inputs:
                walk(i)
        walk(node)
        return seen
    assert "filter" in ops_under(join.inputs[0])
    assert "filter" in ops_under(join.inputs[1])
    assert not any(n.op == "filter" and join in n.inputs for n in opt.order)


def test_unpushable_conjunction_fused_back():
    # both conjuncts read the aggregate output: split enables nothing and
    # must be re-fused into ONE filter (a single compaction)
    plan = (Plan.scan("l").groupby(["k"], {"v0": ["sum"]})
            .filter((col("v0_sum") > 0) & (col("v0_sum") < 10)))
    opt = compile_plan(plan, CAT)
    assert sum(1 for n in opt.order if n.op == "filter") == 1


def test_bitwise_and_on_ints_not_split():
    # & on integer expressions is bitwise, not logical: splitting would
    # change semantics, so the rule must not fire
    plan = (Plan.scan("l").join(Plan.scan("r"), on="k")
            .filter((col("k") & col("w")) > 0))
    opt = compile_plan(plan, CAT)
    assert not any("split-conjunction" in f for f in opt.fired)


def test_filter_pushed_below_with_columns():
    plan = (Plan.scan("l").with_columns({"v1": col("v0") * 2})
            .filter(col("k") > 0))
    opt = compile_plan(plan, CAT)
    order_ops = [n.op for n in opt.order]
    assert order_ops.index("filter") < order_ops.index("with_columns")


def test_filter_on_assigned_column_not_pushed():
    plan = (Plan.scan("l").with_columns({"v1": col("v0") * 2})
            .filter(col("v1") > 0))
    opt = compile_plan(plan, CAT)
    order_ops = [n.op for n in opt.order]
    assert order_ops.index("filter") > order_ops.index("with_columns")


def test_dead_assignment_pruned_and_inputs_dropped():
    # v1 is never consumed; its junk input must not survive to the shuffle
    plan = (Plan.scan("l")
            .with_columns({"v1": col("junk") + 1, "v2": col("v0") * 2})
            .shuffle(["k"]).project(["k", "v2"]))
    opt = compile_plan(plan, CAT)
    assert any("dead-assignment" in f for f in opt.fired)
    wc = next(n for n in opt.order if n.op == "with_columns")
    assert set(wc.params["exprs"]) == {"v2"}
    assert any("drop [junk" in f for f in opt.fired)


def test_expression_liveness_prunes_inputs_exactly():
    # filter(v0) + final project(k): junk must be dropped before the wire
    plan = (Plan.scan("l").shuffle(["k"]).filter(col("v0") > 0)
            .project(["k"]))
    opt = compile_plan(plan, CAT)
    shuf = next(n for n in opt.order if n.op == "shuffle")
    assert "junk" not in shuf.inputs[0].schema


def test_explain_has_no_lambda_placeholders():
    plan = (Plan.scan("l").join(Plan.scan("r"), on="k")
            .filter((col("v0") * 2 > lit(5)) & (col("w") < 1))
            .with_columns({"z": -col("v0") + 1}))
    text = plan.explain(CAT)
    assert "<lambda>" not in text and "filter[?]" not in text
    assert "v0 * 2 > 5" in text
    assert "z=-v0 + 1" in text


# ---------------------------------------------------------------------- #
# Value-based cache keys for expressions (PR 4 satellite)
# ---------------------------------------------------------------------- #
def test_expr_fingerprint_value_based():
    # same expression built via different code paths -> same fingerprint
    def build_a():
        return Plan.scan("l").filter(col("v0") * 2 > lit(5)).shuffle(["k"])

    def build_b():
        two, five = lit(2), 5
        return Plan.scan("l").filter((col("v0") * two) > five).shuffle(["k"])
    fa = fingerprint(from_plan(build_a().node, dict(CAT)))
    fb = fingerprint(from_plan(build_b().node, dict(CAT)))
    assert fa == fb


def test_expr_plans_share_cache_where_lambdas_miss(rng):
    """The compile-cache instability fix: structurally identical plans from
    *different* lambda objects miss the cache (bytecode identity), while the
    equivalent typed-expression plans hit it (value identity)."""
    env = CylonEnv()
    data = {"k": rng.integers(0, 10, 64).astype(np.int32),
            "v0": rng.random(64).astype(np.float32)}
    t = DistTable.from_numpy(data, env.parallelism)

    with pytest.warns(DeprecationWarning):
        p1 = Plan.scan("l").filter(lambda tb: tb.col("v0") > 0.5,
                                   cols=["v0"])
    with pytest.warns(DeprecationWarning):
        # same semantics, different spelling -> different bytecode -> miss
        p2 = Plan.scan("l").filter(
            lambda tb: 0.5 < tb.col("v0"), cols=["v0"])
    execute(p1, env, {"l": t})
    n0 = len(env._cache)
    execute(p2, env, {"l": t})
    assert len(env._cache) == n0 + 1      # lambdas force a miss

    def mk_first():
        return Plan.scan("l").filter(col("v0") > 0.5)

    def mk_second():  # separately built; 0.5 < col reflects to col > 0.5
        return Plan.scan("l").filter(0.5 < col("v0"))
    execute(mk_first(), env, {"l": t})
    n1 = len(env._cache)
    out = execute(mk_second(), env, {"l": t})
    assert len(env._cache) == n1          # exprs hit the same entry
    assert len(out.to_numpy()["k"]) == (data["v0"] > 0.5).sum()


# ---------------------------------------------------------------------- #
# Backward-compat shims (PR 4 satellite)
# ---------------------------------------------------------------------- #
def test_legacy_callable_shim_warns_and_matches_expr_path(rng):
    """Plan.filter(callable) / map_columns keep working via OpaqueExpr —
    each emits a DeprecationWarning and is bit-identical to the typed
    expression path."""
    env = CylonEnv()
    data = {"k": rng.integers(0, 16, 96).astype(np.int32),
            "v0": rng.random(96).astype(np.float32)}
    t = DistTable.from_numpy(data, env.parallelism)

    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy_plan = Plan.scan("l").filter(
            lambda tb: tb.col("v0") > 0.5, cols=["v0"])
    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy_plan = legacy_plan.map_columns(lambda v: v * 2.0, ["v0"])
    new_plan = (Plan.scan("l").filter(col("v0") > 0.5)
                .with_columns({"v0": col("v0") * 2.0}))

    a = execute(legacy_plan, env, {"l": t}).to_numpy()
    b = execute(new_plan, env, {"l": t}).to_numpy()
    assert sorted(a) == sorted(b)
    for c in a:
        np.testing.assert_array_equal(a[c], b[c])
    # the opaque wrapper also keeps the declared-columns pushdown contract
    opt = compile_plan(legacy_plan.shuffle(["k"]), CAT)
    labels = [n.op for n in opt.order]
    assert labels.index("filter") < labels.index("shuffle")


def test_optimize_does_not_mutate_builder_plan(rng):
    """Optimizing (or EXPLAINing) a plan must not corrupt the user's
    builder tree: dead-assignment pruning once deleted entries from the
    exprs dict *shared* with the builder node via from_plan's shallow
    param copy."""
    env = CylonEnv()
    data = {"k": rng.integers(0, 16, 64).astype(np.int32),
            "v0": rng.random(64).astype(np.float32),
            "junk": rng.random(64).astype(np.float32)}
    t = DistTable.from_numpy(data, env.parallelism)
    plan = (Plan.scan("l")
            .with_columns({"v1": col("junk") + 1, "v2": col("v0") * 2})
            .shuffle(["k"]).project(["k", "v2"]))
    compile_plan(plan, CAT)               # optimizer prunes dead v1 ...
    wc = next(n for n in plan.topo() if n.op == "with_columns")
    assert set(wc.params["exprs"]) == {"v1", "v2"}   # ... but not here
    # and an unoptimized run still computes everything as written
    full = (Plan.scan("l")
            .with_columns({"v1": col("junk") + 1, "v2": col("v0") * 2}))
    compile_plan(full.project(["k", "v2"]).shuffle(["k"]), CAT)
    out = execute(full, env, {"l": t}, optimize=False).to_numpy()
    np.testing.assert_allclose(out["v1"], data["junk"] + 1, rtol=1e-6)


def test_fully_dead_with_columns_degenerates_to_noop():
    plan = (Plan.scan("l").with_columns({"v1": col("junk") + 1})
            .shuffle(["k"]).project(["k"]))
    opt = compile_plan(plan, CAT)
    assert not any(n.op == "with_columns" for n in opt.order)

"""Local DDF operators vs numpy oracles (unit + hypothesis property)."""

import collections

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.dataframe import Table, groupby_local, join_local, join_overflow


def _mk(keys, vals, cap_extra=0):
    keys = np.asarray(keys, np.int32)
    vals = np.asarray(vals, np.float32)
    return Table.from_arrays({"k": keys, "v": vals},
                             capacity=len(keys) + cap_extra)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 15), min_size=1, max_size=40),
       st.lists(st.integers(0, 15), min_size=1, max_size=40))
def test_join_local_row_count_and_sums(lk, rk):
    lt = _mk(lk, np.arange(len(lk)))
    rt = Table.from_arrays({"k": np.asarray(rk, np.int32),
                            "w": np.ones(len(rk), np.float32)})
    out_cap = 4 * (len(lk) + len(rk)) * 4
    out = join_local(lt, rt, "k", out_capacity=out_cap).to_numpy()
    rmap = collections.Counter(rk)
    expect = sum(rmap[k] for k in lk)
    assert len(out["k"]) == expect
    # each left row appears exactly count[k] times
    vmap = collections.Counter(out["v"].tolist())
    for i, k in enumerate(lk):
        if rmap[k]:
            assert vmap[float(i)] == rmap[k]


def test_join_overflow_counts(rng):
    lt = _mk([1] * 10, np.zeros(10))
    rt = _mk([1] * 10, np.zeros(10))
    # 100 result rows, capacity 30 -> 70 dropped
    dropped = int(join_overflow(lt, rt, "k", out_capacity=30))
    assert dropped == 70


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 8),
                          st.floats(-100, 100, allow_nan=False,
                                    allow_subnormal=False,  # XLA FTZ
                                    width=32)),
                min_size=1, max_size=50))
def test_groupby_local_all_aggs(pairs):
    keys = np.asarray([p[0] for p in pairs], np.int32)
    vals = np.asarray([p[1] for p in pairs], np.float32)
    t = Table.from_arrays({"k": keys, "v": vals}, capacity=len(pairs) + 7)
    out = groupby_local(t, ["k"], {"v": ["sum", "count", "min", "max"]})
    res = out.to_numpy()
    order = np.argsort(res["k"])
    uk = np.unique(keys)
    np.testing.assert_array_equal(res["k"][order], uk)
    for i, k in enumerate(uk):
        sel = vals[keys == k]
        j = order[i]
        np.testing.assert_allclose(res["v_sum"][j], sel.sum(), rtol=2e-5,
                                   atol=1e-4)
        assert res["v_count"][j] == len(sel)
        np.testing.assert_allclose(res["v_min"][j], sel.min(), rtol=1e-6)
        np.testing.assert_allclose(res["v_max"][j], sel.max(), rtol=1e-6)

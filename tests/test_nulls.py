"""The nullable data model (``repro.nulls``) against the pandas oracle.

* unit tests of the host-side mask layer: ``extract_null_columns`` /
  ``apply_null_columns`` round-trips, reserved-name policy, canonical
  zeros in null slots,
* Kleene three-valued logic through the expression layer (null
  comparisons are null; a filter keeps only TRUE rows),
* engine null semantics end-to-end vs pandas: null join keys never
  match, groupby drops null-key rows and skips null values
  (count/size distinct, all-null min/max is null), sorts place nulls
  last,
* hypothesis property suite (skipped without hypothesis; CI installs
  it): randomized nullable tables through filter / join / groupby /
  sort pipelines vs pandas, in-core and out-of-core.
"""

import numpy as np
import pytest

pd = pytest.importorskip("pandas")

import repro.df as rdf  # noqa: E402
from repro.core import CylonEnv  # noqa: E402
from repro.expr import col  # noqa: E402
from repro.nulls import (apply_null_columns, base_name,  # noqa: E402
                         check_reserved_names, data_columns,
                         extract_null_columns, is_mask, mask_name,
                         nullable_columns)


@pytest.fixture
def env():
    e = CylonEnv()
    rdf.set_default_env(e)
    yield e
    rdf.reset_default_env()


# --------------------------------------------------------------------- #
# Host-side mask layer
# --------------------------------------------------------------------- #
def test_mask_naming():
    assert mask_name("v") == "__m_v"
    assert is_mask("__m_v") and not is_mask("v")
    assert base_name("__m_v") == "v"
    assert data_columns(["v", "__m_v", "k"]) == ["v", "k"]
    assert nullable_columns(["v", "__m_v", "k"]) == {"v"}
    with pytest.raises(ValueError, match="reserved"):
        check_reserved_names(["__m_v"])


def test_extract_apply_round_trip():
    data = {"f": np.array([1.0, np.nan, 3.0]),
            "s": np.array(["a", None, "c"], object),
            "i": np.array([1, 2, 3], np.int64)}
    phys = extract_null_columns(dict(data))
    # masks only where nulls exist; null slots hold the canonical fill
    assert set(phys) == {"f", "s", "i", mask_name("f"), mask_name("s")}
    assert not np.isnan(phys["f"]).any()
    assert all(x is not None for x in phys["s"])
    back = apply_null_columns(phys)
    assert np.isnan(back["f"][1]) and back["s"][1] is None
    assert back["f"][0] == 1.0 and back["s"][2] == "c"
    np.testing.assert_array_equal(back["i"], data["i"])


def test_apply_widens_int_to_float():
    out = apply_null_columns({"n": np.array([5, 0, 7], np.int64),
                              mask_name("n"): np.array([True, False, True])})
    assert out["n"].dtype == np.float64
    assert out["n"][0] == 5.0 and np.isnan(out["n"][1])


# --------------------------------------------------------------------- #
# Kleene logic through the expression layer
# --------------------------------------------------------------------- #
def test_filter_null_comparison_drops_row(env):
    # null > 2 is null, not True: the row is filtered out (pandas agrees,
    # since NaN comparisons are False there)
    pdf = pd.DataFrame({"k": [1, 2, 3, 4],
                        "v": [1.0, np.nan, 3.0, np.nan]})
    got = rdf.from_pandas(pdf)[col("v") > 0].to_pandas()
    assert sorted(got["k"]) == [1, 3]


def test_kleene_or_with_null_operand(env):
    # null | True is True (Kleene), so rows where the other disjunct is
    # True survive even when v is null
    pdf = pd.DataFrame({"k": [1, 2, 3, 4],
                        "v": [1.0, np.nan, 3.0, np.nan]})
    df = rdf.from_pandas(pdf)
    got = df[(col("v") > 2) | (col("k") == 2)].to_pandas()
    assert sorted(got["k"]) == [2, 3]


def test_is_null_fill_null_exprs(env):
    pdf = pd.DataFrame({"k": [1, 2, 3], "v": [1.0, np.nan, 3.0]})
    df = rdf.from_pandas(pdf)
    got = df.assign(miss=col("v").is_null(),
                    filled=col("v").fill_null(-1.0)).to_pandas()
    got = got.sort_values("k").reset_index(drop=True)
    assert list(got["miss"].astype(bool)) == [False, True, False]
    assert list(got["filled"]) == [1.0, -1.0, 3.0]


# --------------------------------------------------------------------- #
# Engine null semantics vs pandas (fixed adversarial cases)
# --------------------------------------------------------------------- #
def test_join_null_keys_never_match(env):
    l = pd.DataFrame({"k": [1.0, np.nan, 2.0, np.nan],
                      "v": [10.0, 20.0, 30.0, 40.0]})
    r = pd.DataFrame({"k": [1.0, np.nan, 2.0], "w": [1.0, 2.0, 3.0]})
    got = (rdf.from_pandas(l).merge(rdf.from_pandas(r), on="k",
                                    out_capacity=64)
           .to_pandas().sort_values("k").reset_index(drop=True))
    want = (l.dropna(subset=["k"]).merge(r.dropna(subset=["k"]), on="k")
            .sort_values("k").reset_index(drop=True))
    assert len(got) == len(want) == 2
    np.testing.assert_array_equal(got["k"], want["k"])
    np.testing.assert_array_equal(got["v"], want["v"])
    np.testing.assert_array_equal(got["w"], want["w"])


def test_groupby_null_semantics(env):
    pdf = pd.DataFrame({
        "k": [1.0, 1.0, np.nan, 2.0, 2.0, np.nan],
        "v": [1.0, np.nan, 5.0, np.nan, np.nan, 6.0]})
    got = (rdf.from_pandas(pdf).groupby("k")
           .agg({"v": ["sum", "mean", "min", "count", "size"]})
           .sort_values("k").to_pandas())
    want = (pdf.groupby("k")
            .agg(v_sum=("v", "sum"), v_mean=("v", "mean"),
                 v_min=("v", "min"), v_count=("v", "count"),
                 v_size=("v", "size"))
            .reset_index())
    # the NaN-key rows form no group
    np.testing.assert_array_equal(got["k"], want["k"])
    np.testing.assert_array_equal(got["v_sum"], want["v_sum"])
    np.testing.assert_array_equal(got["v_count"], want["v_count"])
    np.testing.assert_array_equal(got["v_size"], want["v_size"])
    # group k=2 is all-null: sum is 0 (pandas), mean/min are null
    np.testing.assert_array_equal(got["v_mean"].isna(), want["v_mean"].isna())
    np.testing.assert_array_equal(got["v_min"].isna(), want["v_min"].isna())
    np.testing.assert_array_equal(got["v_mean"].fillna(0.0),
                                  want["v_mean"].fillna(0.0))


def test_sort_nulls_last(env):
    pdf = pd.DataFrame({"k": [3.0, np.nan, 1.0, np.nan, 2.0],
                        "v": [1.0, 2.0, 3.0, 4.0, 5.0]})
    got = rdf.from_pandas(pdf).sort_values("k").to_pandas()
    want = pdf.sort_values("k", na_position="last").reset_index(drop=True)
    np.testing.assert_array_equal(got["k"], want["k"])
    np.testing.assert_array_equal(got["v"], want["v"])


def test_masks_survive_shuffle_and_out_of_core(env):
    rng = np.random.default_rng(3)
    n = 64
    pdf = pd.DataFrame({
        "k": np.where(rng.random(n) > 0.2,
                      rng.integers(0, 6, n).astype(float), np.nan),
        "v": np.where(rng.random(n) > 0.2,
                      rng.integers(0, 40, n).astype(float), np.nan)})
    q = (rdf.from_pandas(pdf).repartition("k")
         .groupby("k").agg({"v": ["sum", "count"]}).sort_values("k"))
    want = (pdf.groupby("k").agg(v_sum=("v", "sum"), v_count=("v", "count"))
            .reset_index().sort_values("k").reset_index(drop=True))
    incore = q.to_pandas()
    np.testing.assert_array_equal(incore["k"], want["k"])
    np.testing.assert_array_equal(incore["v_count"], want["v_count"])
    np.testing.assert_allclose(incore["v_sum"], want["v_sum"], rtol=1e-6)
    spill, stats = (rdf.from_pandas(pdf)
                    .groupby("k").agg({"v": ["sum", "count"]})
                    .sort_values("k")
                    .collect(morsel_rows=16, collect_stats=True))
    assert stats.rows_dropped == 0, stats
    ooc = pd.DataFrame(spill.to_numpy())
    np.testing.assert_array_equal(ooc["k"], want["k"])
    np.testing.assert_array_equal(ooc["v_count"], want["v_count"])
    np.testing.assert_allclose(ooc["v_sum"], want["v_sum"], rtol=1e-5)


# --------------------------------------------------------------------- #
# Hypothesis property suite (pandas oracle).  Generators live in
# ``tests/strategies.py`` (shared with the props / strings / skew
# suites); its guard keeps the fixed cases running in minimal envs —
# CI installs hypothesis.
# --------------------------------------------------------------------- #
from strategies import (HAVE_HYPOTHESIS, null_heavy_frame,  # noqa: E402
                        nullable_frame as _nullable_frame,
                        random_nullable_frame as _random_frame)

if HAVE_HYPOTHESIS:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st


# -- oracle checkers (shared by hypothesis + fixed smoke variants) ------ #
def _check_dropna(pdf):
    env = CylonEnv()
    df = rdf.from_pandas(pdf, env=env)
    got = df.dropna(subset=["v"]).collect(env=env).to_numpy()
    want = pdf.dropna(subset=["v"])
    assert len(got["k"]) == len(want)
    np.testing.assert_array_equal(np.sort(got["v"]),
                                  np.sort(want["v"].to_numpy()))


def _check_groupby(pdf):
    env = CylonEnv()
    got = pd.DataFrame(
        (rdf.from_pandas(pdf, env=env).groupby("k")
         .agg({"v": ["sum", "count", "min"]})
         .sort_values("k").collect(env=env).to_numpy()))
    want = (pdf.groupby("k")
            .agg(v_sum=("v", "sum"), v_count=("v", "count"),
                 v_min=("v", "min"))
            .reset_index().sort_values("k").reset_index(drop=True))
    assert len(got) == len(want)
    if len(want):
        np.testing.assert_array_equal(got["k"], want["k"])
        np.testing.assert_array_equal(got["v_sum"], want["v_sum"])
        np.testing.assert_array_equal(got["v_count"], want["v_count"])
        np.testing.assert_array_equal(np.isnan(got["v_min"]),
                                      want["v_min"].isna())
        np.testing.assert_array_equal(got["v_min"].fillna(0.0),
                                      want["v_min"].fillna(0.0))


def _check_join(l, r):
    env = CylonEnv()
    got = (rdf.from_pandas(l, env=env)
           .merge(rdf.from_pandas(r, env=env), on="k", out_capacity=1024)
           .collect(env=env).to_numpy())
    want = l.dropna(subset=["k"]).merge(r.dropna(subset=["k"]), on="k")
    assert len(got["k"]) == len(want)
    for c in ("k", "v", "w"):
        g = np.sort(np.nan_to_num(got[c], nan=1e9))
        w = np.sort(np.nan_to_num(want[c].to_numpy(), nan=1e9))
        np.testing.assert_array_equal(g, w, err_msg=c)
    g_nulls = {c: int(np.isnan(got[c]).sum()) for c in ("v", "w")}
    w_nulls = {c: int(want[c].isna().sum()) for c in ("v", "w")}
    assert g_nulls == w_nulls


def _check_sort(pdf, morsel_rows):
    env = CylonEnv()
    res = (rdf.from_pandas(pdf, env=env).sort_values("k")
           .collect(env=env, morsel_rows=morsel_rows))
    got = res.to_numpy()
    want = pdf.sort_values("k", na_position="last")
    np.testing.assert_array_equal(got["k"], want["k"].to_numpy())
    # same multiset of records (tie order differs legitimately)
    gk = np.nan_to_num(np.stack([got["k"], got["v"]]), nan=1e9)
    wk = np.nan_to_num(np.stack([want["k"].to_numpy(),
                                 want["v"].to_numpy()]), nan=1e9)
    np.testing.assert_array_equal(gk[:, np.lexsort(gk)],
                                  wk[:, np.lexsort(wk)])


# -- fixed smoke variants: always run, seeded random frames ------------- #
def test_random_frames_smoke():
    rng = np.random.default_rng(17)
    for trial in range(3):
        _check_dropna(_random_frame(rng))
        _check_groupby(_random_frame(rng))
        _check_join(_random_frame(rng, names=("v",), max_rows=24),
                    _random_frame(rng, names=("w",), max_rows=24))
        _check_sort(_random_frame(rng), None if trial else 8)


def test_null_heavy_frames():
    # 90%-null cells: valid-row sampling, null-key drops, all-null groups
    rng = np.random.default_rng(23)
    pdf = null_heavy_frame(rng, n=64, null_frac=0.9)
    _check_groupby(pdf)
    _check_sort(pdf, None)
    _check_join(null_heavy_frame(rng, n=24, null_frac=0.9),
                null_heavy_frame(rng, n=24, names=("w",), null_frac=0.9))


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data())
    def test_prop_dropna_filter_matches_pandas(data):
        _check_dropna(_nullable_frame(data.draw))

    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data())
    def test_prop_groupby_matches_pandas(data):
        _check_groupby(_nullable_frame(data.draw))

    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data())
    def test_prop_join_matches_pandas(data):
        _check_join(_nullable_frame(data.draw, names=("v",), max_rows=24),
                    _nullable_frame(data.draw, names=("w",), max_rows=24))

    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data(), morsel_rows=st.sampled_from([None, 8, 16]))
    def test_prop_sort_nulls_last(data, morsel_rows):
        _check_sort(_nullable_frame(data.draw), morsel_rows)

"""ServeEngine unit tests (1 device, tiny config)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import transformer
from repro.serve import ServeEngine


def _engine(arch="llama3.2-3b", cache=24):
    cfg = get_smoke_config(arch)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    return cfg, params, ServeEngine(cfg, params, cache_len=cache)


def test_greedy_matches_full_forward_replay(rng):
    cfg, params, eng = _engine()
    B, S0, NEW = 2, 8, 6
    prompts = rng.integers(0, cfg.vocab_size, (B, S0)).astype(np.int32)
    res = eng.generate(prompts, max_new_tokens=NEW, temperature=0.0)
    assert res.steps == NEW
    full = np.concatenate([prompts, res.tokens], axis=1)
    h, _ = transformer.forward(params, cfg, {"tokens": jnp.asarray(full)})
    w = params["embed"]  # tied
    logits = jnp.einsum("bsd,vd->bsv", h,
                        w.astype(jnp.bfloat16)).astype(jnp.float32)
    for t in range(NEW):
        expect = np.asarray(jnp.argmax(logits[:, S0 - 1 + t, :cfg.vocab_size],
                                       -1))
        np.testing.assert_array_equal(expect, res.tokens[:, t])


def test_sampling_is_reproducible(rng):
    cfg, _, eng = _engine()
    prompts = rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    # hot temperature: an untrained model's logits are sharply peaked, so
    # mild temperatures all collapse to argmax and seeds cannot differ
    a = eng.generate(prompts, max_new_tokens=8, temperature=20.0, seed=7)
    b = eng.generate(prompts, max_new_tokens=8, temperature=20.0, seed=7)
    c = eng.generate(prompts, max_new_tokens=8, temperature=20.0, seed=8)
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert not np.array_equal(a.tokens, c.tokens)


def test_eos_early_stop(rng):
    cfg, params, _ = _engine()
    eng = ServeEngine(cfg, params, cache_len=24, eos_id=None)
    prompts = rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    res = eng.generate(prompts, max_new_tokens=10)
    first = int(res.tokens[0, 0])
    eng2 = ServeEngine(cfg, params, cache_len=24, eos_id=first)
    res2 = eng2.generate(prompts[:1], max_new_tokens=10)
    assert res2.steps == 1  # stopped at the first (EOS) token

"""Roofline machinery unit tests: HLO parsing + term math (no big compiles)."""

import jax
from repro import compat
import jax.numpy as jnp
import numpy as np

from repro.launch import roofline


def test_shape_bytes():
    assert roofline._shape_bytes("bf16[8,4096,3072]{2,1,0}") \
        == 8 * 4096 * 3072 * 2
    assert roofline._shape_bytes("f32[]") == 0 or True  # scalar: no dims
    assert roofline._shape_bytes("(f32[2,2], s32[4])") == 16 + 16


def test_group_size_parsing():
    assert roofline._group_size("replica_groups={{0,1,2,3}}") == 4
    assert roofline._group_size("replica_groups=[16,16]<=[256]") == 16
    assert roofline._group_size("no groups here") is None


def test_wire_model():
    assert roofline._wire_bytes("all-reduce", 100, 2) == 100.0
    assert roofline._wire_bytes("all-gather", 160, 16) == 150.0
    assert roofline._wire_bytes("reduce-scatter", 10, 16) == 150.0
    assert roofline._wire_bytes("collective-permute", 7, 4) == 7.0
    assert roofline._wire_bytes("all-reduce", 100, 1) == 0.0


def test_parse_collectives_on_real_hlo():
    """Compile a tiny psum program on 1 device and parse its HLO."""
    mesh = jax.make_mesh((1,), ("x",))
    with compat.set_mesh(mesh):
        f = jax.jit(compat.shard_map(
            lambda x: jax.lax.psum(x, "x"),
            in_specs=jax.sharding.PartitionSpec("x"),
            out_specs=jax.sharding.PartitionSpec()))
        hlo = f.lower(jnp.ones((8,))).compile().as_text()
    stats = roofline.parse_collectives(hlo)
    assert "total_wire_bytes" in stats
    # p=1 group -> zero wire bytes regardless of op presence
    assert stats["total_wire_bytes"] == 0.0


def test_roofline_terms_dominance():
    t = roofline.roofline_terms(197e12, 0.0, 0.0)  # exactly 1s of compute
    assert t["dominant"] == "compute"
    assert t["compute_s"] == 1.0
    t = roofline.roofline_terms(0.0, 819e9, 50e9 * 2)
    assert t["dominant"] == "collective"
    assert t["step_s_lower_bound"] == 2.0


def test_model_flops_conventions():
    from repro.configs import get_config
    cfg = get_config("llama3.2-3b")
    n = cfg.active_param_count()
    assert 2.8e9 < n < 4.0e9  # ~3.2B
    assert roofline.model_flops(cfg, "train", 256, 4096) \
        == 6.0 * n * 256 * 4096
    assert roofline.model_flops(cfg, "decode", 128, 32768) == 2.0 * n * 128
    moe = get_config("olmoe-1b-7b")
    assert moe.active_param_count() < 0.4 * moe.param_count()

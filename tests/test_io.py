"""Tests for ``repro.io``: Parquet/CSV ingest into the spill format.

* multi-file Parquet with nulls in key AND value columns vs a pandas
  oracle (records identical after canonical re-ordering),
* repeat-read bit-identity + the process-level dictionary cache
  (second read: cache hit, zero recodes, identical physical layout),
* incremental dictionary growth across files (a later file introduces a
  lexicographically-earlier key -> stale chunks recoded at finalize),
* both CSV lanes (pyarrow streaming / pure-python fallback via
  ``REPRO_NO_PYARROW``) agree, including numeric int->float promotion,
* ``from_pandas`` with mixed NaN / ``None`` round-trips (regression),
* frontend ``dropna`` / ``fillna`` / ``isna`` vs pandas,
* EXPLAIN renders ``scan[parquet: N files, ~M rows]`` and EXPLAIN
  ANALYZE reports the scan ingest stage; ``ExecStats.rows_read``.

pyarrow-dependent tests skip when it is absent (satellite CI lane runs
this file with ``REPRO_NO_PYARROW=1`` to exercise the fallback paths).
"""

import os

import numpy as np
import pytest

pd = pytest.importorskip("pandas")

import repro.df as rdf  # noqa: E402
from repro.core import CylonEnv  # noqa: E402
from repro.io import (DictionaryCache, IngestInfo, have_pyarrow,  # noqa: E402
                      read_csv, read_parquet)
from repro.nulls import mask_name  # noqa: E402

needs_pyarrow = pytest.mark.skipif(
    not have_pyarrow(), reason="pyarrow unavailable or REPRO_NO_PYARROW set")


@pytest.fixture
def env():
    e = CylonEnv()
    rdf.set_default_env(e)
    yield e
    rdf.reset_default_env()


def _write_parquet(path, cols):
    import pyarrow as pa
    import pyarrow.parquet as pq
    pq.write_table(pa.table(cols), str(path))


def _pq_dataset(tmp_path, nfiles=3, rows=20):
    """nfiles Parquet files: unique ``i``, nullable string key ``k``,
    nullable float ``v``, nullable int ``n``.  Returns (paths, oracle)."""
    rng = np.random.default_rng(11)
    paths, frames = [], []
    for f in range(nfiles):
        i = np.arange(f * rows, (f + 1) * rows)
        k = [f"key{rng.integers(0, 8):02d}" if rng.random() > 0.2 else None
             for _ in range(rows)]
        v = [float(rng.integers(0, 50)) if rng.random() > 0.2 else None
             for _ in range(rows)]
        n = [int(rng.integers(0, 9)) if rng.random() > 0.2 else None
             for _ in range(rows)]
        p = tmp_path / f"part{f}.parquet"
        _write_parquet(p, {"i": i, "k": k, "v": v, "n": n})
        paths.append(str(p))
        frames.append(pd.DataFrame({"i": i, "k": k, "v": v, "n": n}))
    oracle = pd.concat(frames, ignore_index=True)
    return paths, oracle


def _by_id(cols):
    """Re-order ingested columns by the unique ``i`` id (round-robin
    chunking permutes global row order legitimately)."""
    order = np.argsort(np.asarray(cols["i"]))
    return {c: np.asarray(cols[c], dtype=object)[order] for c in cols}


def _assert_records_equal(got, want_df):
    got = _by_id(got)
    for c in want_df.columns:
        w = want_df[c].to_numpy()
        g = got[c]
        for a, b in zip(g, w):
            a_null = a is None or (isinstance(a, float) and np.isnan(a))
            b_null = b is None or (isinstance(b, float) and np.isnan(b))
            assert a_null == b_null, (c, a, b)
            if not a_null:
                assert a == b, (c, a, b)


# --------------------------------------------------------------------- #
# Parquet ingest
# --------------------------------------------------------------------- #
@needs_pyarrow
def test_read_parquet_multi_file_with_nulls(tmp_path):
    paths, oracle = _pq_dataset(tmp_path)
    spill = read_parquet(paths, parallelism=2, batch_rows=8,
                         dict_cache=DictionaryCache())
    assert spill.total_rows() == len(oracle)
    info = spill.provenance
    assert isinstance(info, IngestInfo)
    assert info.format == "parquet"
    assert len(info.files) == 3 and info.rows == len(oracle)
    assert info.bytes_read == sum(os.path.getsize(p) for p in paths)
    assert info.batches >= 3 and not info.dict_cache_hit
    assert str(info) == f"parquet: 3 files, ~{len(oracle)} rows"
    _assert_records_equal(spill.to_numpy(), oracle)
    # physical layout invariants: masks exist, null slots hold zeros
    raw = spill.to_numpy(decode=False, nulls="mask")
    for c in ("k", "v", "n"):
        m = raw[mask_name(c)]
        assert m.dtype == np.bool_ and not m.all()
        assert not np.asarray(raw[c])[~m].any(), c


@needs_pyarrow
def test_read_parquet_glob_and_columns(tmp_path):
    paths, oracle = _pq_dataset(tmp_path)
    spill = read_parquet(str(tmp_path / "*.parquet"), parallelism=2,
                         columns=["i", "v"], dict_cache=DictionaryCache())
    assert spill.provenance.files == tuple(sorted(paths))
    got = spill.to_numpy()
    assert set(got) == {"i", "v"}
    _assert_records_equal(got, oracle[["i", "v"]])


@needs_pyarrow
def test_read_parquet_missing_source(tmp_path):
    with pytest.raises(FileNotFoundError):
        read_parquet(str(tmp_path / "nope-*.parquet"), parallelism=2)


@needs_pyarrow
def test_read_parquet_empty_dataset(tmp_path):
    import pyarrow as pa
    schema = pa.schema([("i", pa.int64()), ("k", pa.string())])
    _write_parquet(tmp_path / "empty.parquet",
                   pa.table({"i": [], "k": []}, schema=schema))
    spill = read_parquet(str(tmp_path / "empty.parquet"), parallelism=2,
                         dict_cache=DictionaryCache())
    assert spill.total_rows() == 0
    assert set(spill.column_names) >= {"i", "k"}
    assert spill.dictionaries["k"] == ("",)


@needs_pyarrow
def test_repeat_read_cache_hit_and_bit_identity(tmp_path):
    paths, _ = _pq_dataset(tmp_path)
    cache = DictionaryCache()
    s1 = read_parquet(paths, parallelism=2, batch_rows=8, dict_cache=cache)
    assert cache.misses == 1 and cache.hits == 0
    s2 = read_parquet(paths, parallelism=2, batch_rows=8, dict_cache=cache)
    assert cache.hits == 1
    assert s2.provenance.dict_cache_hit
    # cached dictionaries are final from batch one -> nothing to recode
    assert s2.provenance.recodes == 0
    assert s1.dictionaries == s2.dictionaries
    a = s1.to_numpy(decode=False, nulls="mask")
    b = s2.to_numpy(decode=False, nulls="mask")
    assert set(a) == set(b)
    for c in a:
        np.testing.assert_array_equal(a[c], b[c], err_msg=c)


@needs_pyarrow
def test_cache_invalidated_by_rewrite(tmp_path):
    paths, _ = _pq_dataset(tmp_path, nfiles=1)
    cache = DictionaryCache()
    read_parquet(paths, parallelism=1, dict_cache=cache)
    # rewrite with different content: size/mtime key no longer matches
    _write_parquet(paths[0], {"i": np.arange(4), "k": ["zz", None, "a", "b"],
                              "v": [1.0, None, 3.0, 4.0],
                              "n": [1, 2, None, 4]})
    s = read_parquet(paths, parallelism=1, dict_cache=cache)
    assert not s.provenance.dict_cache_hit
    assert cache.misses == 2
    assert s.dictionaries["k"] == ("a", "b", "zz")


@needs_pyarrow
def test_incremental_dictionary_growth_recodes(tmp_path):
    # file2 introduces a lexicographically-earlier key, so every code
    # assigned while reading file1 is stale and must be remapped
    _write_parquet(tmp_path / "a.parquet", {"k": ["m", "z", None, "m"]})
    _write_parquet(tmp_path / "b.parquet", {"k": ["a", "m", "a", None]})
    spill = read_parquet([str(tmp_path / "a.parquet"),
                          str(tmp_path / "b.parquet")],
                         parallelism=2, dict_cache=DictionaryCache())
    assert spill.dictionaries["k"] == ("a", "m", "z")
    assert spill.provenance.recodes >= 1
    got = spill.to_numpy()
    vals = sorted(x for x in got["k"] if x is not None)
    assert vals == ["a", "a", "m", "m", "m", "z"]
    assert sum(x is None for x in got["k"]) == 2
    # null slots are canonical code 0 even after the remap
    raw = spill.to_numpy(decode=False, nulls="mask")
    assert not raw["k"][~raw[mask_name("k")]].any()


@needs_pyarrow
def test_all_null_string_column(tmp_path):
    import pyarrow as pa
    _write_parquet(tmp_path / "n.parquet",
                   pa.table({"i": [1, 2, 3],
                             "s": pa.array([None, None, None],
                                           type=pa.string())}))
    spill = read_parquet(str(tmp_path / "n.parquet"), parallelism=1,
                         dict_cache=DictionaryCache())
    assert spill.dictionaries["s"] == ("",)
    got = spill.to_numpy()
    assert all(x is None for x in got["s"])


# --------------------------------------------------------------------- #
# CSV ingest (both lanes)
# --------------------------------------------------------------------- #
def _write_csv_dataset(tmp_path):
    (tmp_path / "a.csv").write_text(
        "i,k,v\n0,alpha,1.5\n1,,\n2,beta,3.0\n3,alpha,\n")
    (tmp_path / "b.csv").write_text(
        "i,k,v\n4,gamma,2.5\n5,beta,\n6,,0.5\n")
    oracle = pd.DataFrame({
        "i": [0, 1, 2, 3, 4, 5, 6],
        "k": ["alpha", None, "beta", "alpha", "gamma", "beta", None],
        "v": [1.5, None, 3.0, None, 2.5, None, 0.5]})
    return [str(tmp_path / "a.csv"), str(tmp_path / "b.csv")], oracle


def test_read_csv_python_lane(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_NO_PYARROW", "1")
    assert not have_pyarrow()
    paths, oracle = _write_csv_dataset(tmp_path)
    spill = read_csv(paths, parallelism=2, batch_rows=3,
                     dict_cache=DictionaryCache())
    assert spill.provenance.format == "csv"
    _assert_records_equal(spill.to_numpy(), oracle)


@needs_pyarrow
def test_csv_lanes_agree(tmp_path, monkeypatch):
    paths, oracle = _write_csv_dataset(tmp_path)
    arrow = read_csv(paths, parallelism=2, dict_cache=DictionaryCache())
    _assert_records_equal(arrow.to_numpy(), oracle)
    monkeypatch.setenv("REPRO_NO_PYARROW", "1")
    python = read_csv(paths, parallelism=2, dict_cache=DictionaryCache())
    a, b = _by_id(arrow.to_numpy()), _by_id(python.to_numpy())
    assert set(a) == set(b)
    assert arrow.dictionaries == python.dictionaries
    for c in a:
        for x, y in zip(a[c], b[c]):
            assert (x is None) == (y is None), c
            if x is not None:
                assert x == y or (np.isnan(x) and np.isnan(y)), c


def test_csv_python_lane_numeric_promotion(tmp_path, monkeypatch):
    # first file parses x as int64, second needs float: widen at finalize
    monkeypatch.setenv("REPRO_NO_PYARROW", "1")
    (tmp_path / "a.csv").write_text("i,x\n0,1\n1,2\n")
    (tmp_path / "b.csv").write_text("i,x\n2,3.5\n3,\n")
    spill = read_csv([str(tmp_path / "a.csv"), str(tmp_path / "b.csv")],
                     parallelism=1, dict_cache=DictionaryCache())
    got = _by_id(spill.to_numpy())
    want = [1.0, 2.0, 3.5, None]
    for g, w in zip(got["x"], want):
        if w is None:
            assert np.isnan(g)
        else:
            assert g == w


def test_csv_header_mismatch(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_NO_PYARROW", "1")
    (tmp_path / "a.csv").write_text("i,x\n0,1\n")
    (tmp_path / "b.csv").write_text("i,y\n1,2\n")
    with pytest.raises(ValueError, match="header"):
        read_csv([str(tmp_path / "a.csv"), str(tmp_path / "b.csv")],
                 parallelism=1, dict_cache=DictionaryCache())


# --------------------------------------------------------------------- #
# from_pandas nulls (regression) + frontend missing-data ops
# --------------------------------------------------------------------- #
def test_from_pandas_mixed_nan_none(env):
    pdf = pd.DataFrame({
        "a": [1.0, np.nan, 3.0, np.nan],
        "s": ["x", None, "y", None],
        "b": [10, 20, 30, 40]})          # no nulls: stays int, no mask
    out = rdf.from_pandas(pdf).to_pandas()
    out = out.sort_values("b").reset_index(drop=True)
    assert list(out["b"]) == [10, 20, 30, 40]
    np.testing.assert_array_equal(out["a"], pdf["a"])   # NaN==NaN here
    assert list(out["s"]) == ["x", None, "y", None]
    raw = rdf.from_pandas(pdf).to_numpy(nulls="mask")
    assert mask_name("a") in raw and mask_name("s") in raw
    assert mask_name("b") not in raw


def test_frontend_dropna_fillna_isna(env):
    pdf = pd.DataFrame({"k": [1, 2, 3, 4, 5],
                        "a": [1.0, np.nan, 3.0, np.nan, 5.0],
                        "b": [np.nan, 2.0, 3.0, np.nan, 5.0]})
    df = rdf.from_pandas(pdf)

    got = df.dropna().to_pandas().sort_values("k").reset_index(drop=True)
    want = pdf.dropna().reset_index(drop=True)
    assert list(got["k"]) == list(want["k"])

    got = (df.dropna(subset=["a"]).to_pandas()
           .sort_values("k").reset_index(drop=True))
    want = pdf.dropna(subset=["a"]).reset_index(drop=True)
    assert list(got["k"]) == list(want["k"])
    np.testing.assert_array_equal(got["b"], want["b"])

    got = (df.fillna(0.0, subset=["a", "b"]).to_pandas()
           .sort_values("k").reset_index(drop=True))
    want = pdf.fillna(0.0)
    np.testing.assert_array_equal(got["a"], want["a"])
    np.testing.assert_array_equal(got["b"], want["b"])

    got = (df.isna(subset=["a", "b"]).to_pandas()
           .sort_values("k").reset_index(drop=True))
    np.testing.assert_array_equal(got["a"].astype(bool), pdf["a"].isna())
    np.testing.assert_array_equal(got["b"].astype(bool), pdf["b"].isna())


def test_dropna_elided_for_non_null_columns(env):
    # no masks anywhere: the optimizer proves the is_null checks false
    df = rdf.read_numpy({"k": np.arange(8, dtype=np.int32),
                         "v": np.ones(8, np.float32)})
    text = df.dropna().explain()
    assert "null-elision: is_null(k) is always false" in text, text
    assert "null-elision: is_null(v) is always false" in text, text


# --------------------------------------------------------------------- #
# EXPLAIN / EXPLAIN ANALYZE / ExecStats surfacing
# --------------------------------------------------------------------- #
@needs_pyarrow
def test_explain_scan_source_label(env, tmp_path):
    paths, oracle = _pq_dataset(tmp_path)
    df = rdf.read_parquet(paths, dict_cache=DictionaryCache())
    text = df.dropna(subset=["k"]).explain()
    assert f"scan[parquet: 3 files, ~{len(oracle)} rows]" in text, text


@needs_pyarrow
def test_explain_analyze_scan_stage_and_stats(env, tmp_path):
    paths, oracle = _pq_dataset(tmp_path)
    df = rdf.read_parquet(paths, dict_cache=DictionaryCache())
    q = df.dropna(subset=["k"]).groupby("k").agg({"v": "sum"})
    out, stats = q.collect(collect_stats=True)
    assert stats.rows_read == len(oracle)
    assert stats.bytes_read == sum(os.path.getsize(p) for p in paths)
    assert stats.rows_dropped == 0
    text = df.dropna(subset=["k"]).groupby("k").agg(
        {"v": "sum"}).explain_analyze()
    assert "stage scan: ingested" in text, text
    assert f"{len(oracle)} rows" in text, text


# --------------------------------------------------------------------- #
# End-to-end: Parquet -> merge/groupby/sort pipeline vs pandas (1 device)
# --------------------------------------------------------------------- #
@needs_pyarrow
def test_parquet_pipeline_vs_pandas(env, tmp_path):
    paths, oracle = _pq_dataset(tmp_path, nfiles=2, rows=24)
    _write_parquet(tmp_path / "dim.parquet",
                   {"k": [f"key{i:02d}" for i in range(8)] + [None],
                    "w": [float(i) for i in range(8)] + [None]})
    facts = rdf.read_parquet(paths, dict_cache=DictionaryCache())
    dim = rdf.read_parquet(str(tmp_path / "dim.parquet"),
                           dict_cache=DictionaryCache())
    q = (facts.merge(dim, on="k", out_capacity=512)
         .groupby("k").agg({"v": ["sum", "count"], "w": "max"})
         .sort_values("k"))
    # engine semantics: null keys never match / never form a group
    pdim = pd.DataFrame({"k": [f"key{i:02d}" for i in range(8)] + [None],
                         "w": [float(i) for i in range(8)] + [None]})
    m = oracle.dropna(subset=["k"]).merge(pdim.dropna(subset=["k"]), on="k")
    want = (m.groupby("k")
            .agg(v_sum=("v", "sum"), v_count=("v", "count"),
                 w_max=("w", "max"))
            .reset_index().sort_values("k").reset_index(drop=True))
    ref = None
    for mode in ("bsp", "bsp_staged", "amt"):
        out, stats = q.collect(mode=mode, collect_stats=True)
        assert stats.rows_dropped == 0, (mode, stats)
        got = out.to_numpy()
        assert list(got["k"]) == list(want["k"]), mode
        np.testing.assert_allclose(got["v_sum"], want["v_sum"], rtol=1e-6)
        np.testing.assert_array_equal(got["v_count"],
                                      want["v_count"].to_numpy())
        np.testing.assert_array_equal(got["w_max"], want["w_max"])
        if ref is None:
            ref = got
        else:
            for c in ref:   # bit-identical across in-core modes
                np.testing.assert_array_equal(ref[c], got[c],
                                              err_msg=(mode, c))
    # out-of-core over morsels: keys/counts exact, float aggs to tolerance
    spill, stats = q.collect(morsel_rows=8, collect_stats=True)
    assert stats.rows_dropped == 0 and stats.morsels > 1, stats
    got = spill.to_numpy()
    assert list(got["k"]) == list(want["k"])
    np.testing.assert_array_equal(got["v_count"], want["v_count"].to_numpy())
    np.testing.assert_allclose(got["v_sum"], want["v_sum"], rtol=1e-5)

"""Unit tests for the typed column-expression AST (``repro.expr``).

Covers: operator tree construction, column liveness, value-based
fingerprints, evaluation vs a numpy oracle (dtype promotion, NaN and
comparison semantics), pretty-printing round-trips, and the OpaqueExpr
legacy wrapper.
"""

import numpy as np
import pytest

from repro.dataframe.ops_local import filter_expr, with_columns
from repro.dataframe.table import Table
from repro.expr import (BinOp, Col, Expr, Lit, OpaqueExpr, UnaryOp, col,
                        ensure_expr, lit, token)


def make_table(**cols):
    return Table.from_arrays({k: np.asarray(v) for k, v in cols.items()})


# ---------------------------------------------------------------------- #
# Tree construction + liveness
# ---------------------------------------------------------------------- #
def test_operator_overloads_build_tree():
    e = col("v") * 2 > lit(5)
    assert isinstance(e, BinOp) and e.op == ">"
    assert isinstance(e.left, BinOp) and e.left.op == "*"
    assert isinstance(e.left.left, Col) and e.left.left.name == "v"
    assert isinstance(e.right, Lit) and e.right.value == 5


def test_columns_exact_liveness():
    e = (col("a") + col("b") * col("a")) > -col("c")
    assert e.columns() == frozenset({"a", "b", "c"})
    assert lit(3).columns() == frozenset()


def test_reflected_scalars():
    a = 2 * col("v")
    b = col("v") * 2  # multiplication argument order is preserved
    assert a.fingerprint() != b.fingerprint()
    r = 0.5 < col("v")  # python reflects to col("v") > 0.5
    assert r.op == ">" and isinstance(r.left, Col)


def test_is_boolean_classification():
    assert (col("v") > 0).is_boolean()
    assert ((col("v") > 0) & (col("w") < 1)).is_boolean()
    assert (~(col("v") > 0)).is_boolean()
    assert not (col("v") & col("w")).is_boolean()   # bitwise on ints
    assert not (col("v") + 1).is_boolean()
    assert not OpaqueExpr(lambda t: t.col("v") > 0).is_boolean()


def test_no_truthiness():
    with pytest.raises(TypeError, match="truth value"):
        bool(col("v") > 0)


def test_immutability_and_validation():
    e = col("v")
    with pytest.raises(AttributeError):
        e.name = "w"
    with pytest.raises(TypeError):
        ensure_expr(["not", "a", "scalar"])
    with pytest.raises(TypeError):
        lit(np.arange(3))
    with pytest.raises(ValueError):
        BinOp("??", col("a"), col("b"))


def test_string_literals_lift_but_never_evaluate_raw():
    # strings build expressions (df.s == "oak") but must be lowered to
    # dictionary codes by the planner before evaluation (docs/data_model.md)
    e = ensure_expr("oak")
    assert isinstance(e, Lit) and e.value == "oak"
    cmp = col("s") == "oak"
    assert isinstance(cmp.right, Lit) and cmp.right.value == "oak"
    t = make_table(s=np.arange(4, dtype=np.int32))
    with pytest.raises(TypeError, match="lowered against a column dict"):
        cmp.evaluate(t)


# ---------------------------------------------------------------------- #
# Fingerprints (value identity)
# ---------------------------------------------------------------------- #
def test_fingerprint_value_based_across_construction_sites():
    def site_a():
        return (col("v") * 2 > lit(5)) & (col("w") != 0)

    def site_b():
        left = BinOp(">", BinOp("*", Col("v"), Lit(2)), Lit(5))
        return left & (col("w") != 0)
    assert site_a().fingerprint() == site_b().fingerprint()


def test_fingerprint_distinguishes_values_and_dtypes():
    assert (col("v") > 1).fingerprint() != (col("v") > 2).fingerprint()
    assert (col("v") > 1).fingerprint() != (col("v") > 1.0).fingerprint()
    assert (col("v") > np.float32(1)).fingerprint() != \
        (col("v") > 1.0).fingerprint()          # pinned vs weak literal
    assert (col("v") > 1).fingerprint() != (col("w") > 1).fingerprint()
    assert (col("a") - col("b")).fingerprint() != \
        (col("b") - col("a")).fingerprint()     # order matters


def test_token_delegates_to_expr_fingerprint():
    e = col("v") + 1
    assert token(e) == f"expr:{e.fingerprint()}"
    assert token({"x": e}) == "{" + f"x:expr:{e.fingerprint()}" + "}"


# ---------------------------------------------------------------------- #
# Evaluation vs numpy oracle
# ---------------------------------------------------------------------- #
def test_arithmetic_matches_numpy_oracle():
    rng = np.random.default_rng(0)
    a = rng.random(64).astype(np.float32) + 0.5
    b = rng.random(64).astype(np.float32) + 0.5
    t = make_table(a=a, b=b)
    cases = {
        "add": (col("a") + col("b"), a + b),
        "sub": (col("a") - col("b"), a - b),
        "mul": (col("a") * col("b"), a * b),
        "div": (col("a") / col("b"), a / b),
        "floordiv": (col("a") // col("b"), np.floor_divide(a, b)),
        "mod": (col("a") % col("b"), np.mod(a, b)),
        "pow": (col("a") ** 2, a ** 2),
        "neg": (-col("a"), -a),
        "abs": (abs(col("a") - col("b")), np.abs(a - b)),
    }
    for name, (expr, want) in cases.items():
        got = np.asarray(expr.evaluate(t))
        assert got.dtype == want.dtype, name
        np.testing.assert_allclose(got, want, rtol=1e-6, err_msg=name)


def test_comparisons_and_boolean_algebra_match_numpy():
    a = np.array([1, 5, 3, 7, 2], np.int32)
    b = np.array([4, 5, 1, 0, 2], np.int32)
    t = make_table(a=a, b=b)
    for op, np_op in ((">", np.greater), (">=", np.greater_equal),
                      ("<", np.less), ("<=", np.less_equal),
                      ("==", np.equal), ("!=", np.not_equal)):
        got = np.asarray(BinOp(op, col("a"), col("b")).evaluate(t))
        assert got.dtype == np.bool_
        np.testing.assert_array_equal(got, np_op(a, b), err_msg=op)
    e = ((col("a") > 2) & (col("b") < 4)) | ~(col("a") == col("b"))
    want = ((a > 2) & (b < 4)) | ~(a == b)
    np.testing.assert_array_equal(np.asarray(e.evaluate(t)), want)


def test_dtype_promotion_int_float():
    i = np.arange(8, dtype=np.int32)
    f = np.linspace(0, 1, 8, dtype=np.float32)
    t = make_table(i=i, f=f)
    assert np.asarray((col("i") + col("f")).evaluate(t)).dtype == np.float32
    # python scalars stay weak: int32 + 1 keeps int32, int32 + 1.5 -> float
    assert np.asarray((col("i") + 1).evaluate(t)).dtype == np.int32
    got = np.asarray((col("i") * 1.5).evaluate(t))
    assert np.issubdtype(got.dtype, np.floating)
    np.testing.assert_allclose(got, i * 1.5)


def test_nan_comparison_semantics():
    v = np.array([1.0, np.nan, 3.0, np.nan], np.float32)
    t = make_table(v=v)
    with np.errstate(invalid="ignore"):
        np.testing.assert_array_equal(
            np.asarray((col("v") > 2.0).evaluate(t)), v > 2.0)
        np.testing.assert_array_equal(
            np.asarray((col("v") == col("v")).evaluate(t)), v == v)
    # filtering drops NaN rows for any comparison (IEEE: NaN cmp -> False)
    kept = filter_expr(t, col("v") > 0).to_numpy()["v"]
    np.testing.assert_array_equal(kept, np.array([1.0, 3.0], np.float32))


def test_opaque_expr_evaluates_and_declares():
    t = make_table(v=np.array([1.0, -2.0, 3.0], np.float32))
    e = OpaqueExpr(lambda tb: tb.col("v") > 0, cols=("v",))
    assert e.columns() == frozenset({"v"})
    np.testing.assert_array_equal(np.asarray(e.evaluate(t)),
                                  [True, False, True])
    assert OpaqueExpr(lambda tb: tb.col("v")).columns() is None


# ---------------------------------------------------------------------- #
# Table-level helpers
# ---------------------------------------------------------------------- #
def test_filter_expr_requires_boolean():
    t = make_table(v=np.arange(4, dtype=np.int32))
    with pytest.raises(TypeError, match="must be boolean"):
        filter_expr(t, col("v") + 1)


def test_filter_expr_respects_padding():
    t = Table.from_arrays({"v": np.array([5, -1, 7], np.int32)}, capacity=8)
    out = filter_expr(t, col("v") > 0)
    assert int(out.row_count) == 2
    np.testing.assert_array_equal(out.to_numpy()["v"], [5, 7])


def test_with_columns_simultaneous_and_broadcast():
    t = make_table(a=np.array([1.0, 2.0], np.float32),
                   b=np.array([10.0, 20.0], np.float32))
    out = with_columns(t, {"a": col("b"), "b": col("a"), "c": lit(7.0),
                           "d": col("a") * col("b")})
    o = out.to_numpy()
    np.testing.assert_array_equal(o["a"], [10.0, 20.0])  # swap: reads input
    np.testing.assert_array_equal(o["b"], [1.0, 2.0])
    np.testing.assert_array_equal(o["c"], [7.0, 7.0])    # scalar broadcast
    np.testing.assert_array_equal(o["d"], [10.0, 40.0])


def test_missing_column_error_names_have():
    t = make_table(v=np.arange(4, dtype=np.int32))
    with pytest.raises(KeyError, match="not in table"):
        col("nope").evaluate(t)


# ---------------------------------------------------------------------- #
# Pretty-printing (EXPLAIN labels)
# ---------------------------------------------------------------------- #
def test_render_minimal_python_accurate_parens():
    assert repr(col("v") * 2 > lit(5)) == "v * 2 > 5"
    assert repr((col("a") > 0) & (col("b") < 1)) == "(a > 0) & (b < 1)"
    assert repr((col("a") + col("b")) * col("c")) == "(a + b) * c"
    assert repr(-col("v") + 1) == "-v + 1"
    assert repr(~(col("a") > 0)) == "~(a > 0)"
    assert repr(abs(col("a") - col("b"))) == "abs(a - b)"


def test_render_parses_back_to_same_tree():
    # the printed form, eval'd with col() bindings, rebuilds the same expr
    cases = [
        col("v") * 2 > lit(5),
        (col("a") > 0) & ((col("b") < 1) | (col("a") == col("b"))),
        -col("a") + col("b") * col("c"),
        col("a") % 3 != 0,
        (col("a") ** col("b")) ** col("c"),   # right-assoc ** needs parens
        col("a") ** (col("b") ** col("c")),
        (-col("a")) ** 2,                     # unary base of ** needs parens
        -(col("a") ** 2),
    ]
    names = {"a": col("a"), "b": col("b"), "c": col("c"), "v": col("v")}
    for e in cases:
        rebuilt = eval(repr(e), {"__builtins__": {}}, dict(names))
        assert rebuilt.fingerprint() == e.fingerprint(), repr(e)

"""Fault-tolerant execution (``repro.faults``, ``docs/fault_tolerance.md``).

The central property: ANY single injected fault at ANY registered site is
recovered by checkpoint replay + retry, and the recovered result is
**bit-identical** to the fault-free run — committed outputs come only from
the attempt that succeeded.  Around it: the deterministic fault plan
machinery, overflow policies, deadlines/cancellation, checkpoint guards,
the chunked all-to-all validation, warning dedupe, and the
zero-overhead-when-disabled compile-cache invariant.
"""

import time
import warnings

import numpy as np
import pytest

try:  # optional: the randomized property test; the deterministic
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # sweep below covers every site without it
    HAVE_HYPOTHESIS = False

from repro import flags  # noqa: E402
from repro.comm import get_communicator  # noqa: E402
from repro.core.env import CylonEnv, DistTable  # noqa: E402
from repro.core.plan import Plan, execute  # noqa: E402
from repro.core.store import Checkpoint, SpillTable  # noqa: E402
from repro.expr import col  # noqa: E402
from repro.faults import (SITES, CancellationToken, CapacityOverflow,  # noqa: E402
                          FaultPlan, FaultSpec, InjectedFault,
                          OverflowPolicy, QueryCancelled, QueryTimeout,
                          RetryPolicy, parse_fault_plan, random_plan,
                          resolve_faults)

# ---------------------------------------------------------------------- #
# Shared single-device env + canonical queries (built lazily, reused so
# hypothesis examples pay compile cost once)
# ---------------------------------------------------------------------- #
_STATE: dict = {}


def _env() -> CylonEnv:
    if "env" not in _STATE:
        _STATE["env"] = CylonEnv()
    return _STATE["env"]


def _morsel_case():
    """Out-of-core query visiting every morsel-executor fault site:
    resident join build, streamed filter+join segment, groupby combine."""
    if "morsel" not in _STATE:
        n = 96
        tables = {
            "l": {"k": (np.arange(n) % 7).astype(np.int32),
                  "v0": np.linspace(0.0, 1.0, n).astype(np.float32)},
            "r": {"k": np.arange(7, dtype=np.int32),
                  "w": (np.arange(7) * 2.0).astype(np.float32)},
        }
        plan = (Plan.scan("l").filter(col("v0") >= 0.0)
                .join(Plan.scan("r"), on="k")
                .groupby(["k"], {"v0": ["sum"]}))
        sp, stats = execute(plan, _env(), tables, morsel_rows=32,
                            collect_stats=True, faults=False)
        assert stats.rows_dropped == 0 and stats.retries == 0
        _STATE["morsel"] = (plan, tables, sp.to_numpy())
        _STATE["morsel_count"] = stats.morsels
    return _STATE["morsel"]


def _staged_case():
    """In-core bsp_staged query (covers stage:launch / a2a:chunk)."""
    if "staged" not in _STATE:
        n = 128
        tables = {"l": DistTable.from_numpy(
            {"k": (np.arange(n) % 11).astype(np.int32),
             "v0": np.arange(n, dtype=np.float32)}, _env().parallelism)}
        plan = Plan.scan("l").groupby(["k"], {"v0": ["sum", "count"]})
        out, stats = execute(plan, _env(), tables, mode="bsp_staged",
                             collect_stats=True, faults=False)
        assert stats.retries == 0
        _STATE["staged"] = (plan, tables, out.to_numpy())
    return _STATE["staged"]


def _assert_same(ref, got):
    assert sorted(ref) == sorted(got)
    for c in ref:
        np.testing.assert_array_equal(ref[c], got[c])


# ---------------------------------------------------------------------- #
# THE property: one fault anywhere -> recovered, bit-identical
# ---------------------------------------------------------------------- #
def _check_single_fault(site: str, at: int, require_fire: bool = False):
    plan_obj = FaultPlan((FaultSpec(site, kind="raise", at=at),))
    if site in ("stage:launch", "a2a:chunk"):
        qplan, tables, ref = _staged_case()
        out, stats = execute(qplan, _env(), tables, mode="bsp_staged",
                             collect_stats=True, faults=plan_obj)
    else:
        qplan, tables, ref = _morsel_case()
        out, stats = execute(qplan, _env(), tables, morsel_rows=32,
                             collect_stats=True, faults=plan_obj)
    _assert_same(ref, out.to_numpy())
    assert stats.rows_dropped == 0
    if require_fire:
        assert stats.faults_injected == 1, f"site {site} never visited"
    # if the site was visited often enough for the fault to fire, the
    # recovery must be visible in the stats
    if stats.faults_injected:
        assert stats.retries > 0, f"site {site} fault not retried"


if HAVE_HYPOTHESIS:
    @settings(max_examples=24, deadline=None)
    @given(site=st.sampled_from(SITES), at=st.integers(0, 2))
    def test_single_fault_any_site_recovers_bit_identical(site, at):
        _check_single_fault(site, at)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_single_fault_any_site_recovers_bit_identical():
        pass


def test_single_fault_every_site_fires_and_recovers():
    """Deterministic sweep at occurrence 0: every registered site is
    actually visited by the canonical queries (the randomized property
    would silently pass on a never-visited site)."""
    for site in SITES:
        _check_single_fault(site, at=0, require_fire=True)


# ---------------------------------------------------------------------- #
# Fixed adversarial cases
# ---------------------------------------------------------------------- #
def test_fault_during_resident_build_spill():
    # the build side is evaluated+shuffled once and kept device-resident;
    # a fault there must replay the whole build, not leave a torn resident
    qplan, tables, ref = _morsel_case()
    out, stats = execute(qplan, _env(), tables, morsel_rows=32,
                         collect_stats=True,
                         faults="build:resident@0=raise")
    assert stats.faults_injected == 1 and stats.retries > 0
    _assert_same(ref, out.to_numpy())


def test_fault_on_last_morsel():
    # 96 rows / 32-row morsels = 3 morsels in the first streamed segment
    # (occurrence 2 is its last); the fault-free run's morsel count gives
    # the last morsel of the whole query.  A faulted last morsel means the
    # attempt's nearly-complete output spill is discarded wholesale and
    # rebuilt, not re-appended
    qplan, tables, ref = _morsel_case()
    total = _STATE["morsel_count"]
    for occ in (2, total - 1):
        out, stats = execute(qplan, _env(), tables, morsel_rows=32,
                             collect_stats=True,
                             faults=f"morsel:execute@{occ}=raise")
        assert stats.faults_injected == 1 and stats.retries > 0
        _assert_same(ref, out.to_numpy())


def test_hang_fault_expires_and_is_retried():
    qplan, tables, ref = _morsel_case()
    plan_obj = FaultPlan((FaultSpec("morsel:execute", kind="hang", at=1),),
                         hang_s=0.05)
    out, stats = execute(qplan, _env(), tables, morsel_rows=32,
                         collect_stats=True, faults=plan_obj)
    assert stats.retries > 0
    _assert_same(ref, out.to_numpy())


def test_timeout_mid_backoff():
    # a persistent fault + slow backoff: the deadline must fire from
    # inside the backoff sleep, not wait for the next dispatch
    qplan, tables, _ = _staged_case()
    plan_obj = FaultPlan((FaultSpec("stage:launch", kind="raise",
                                    at=0, times=99),))
    pol = RetryPolicy(retries=50, backoff_s=0.5, backoff_max_s=0.5)
    t0 = time.monotonic()
    with pytest.raises(QueryTimeout):
        execute(qplan, _env(), tables, mode="bsp_staged",
                collect_stats=True, faults=plan_obj, retries=pol,
                timeout=0.3)
    assert time.monotonic() - t0 < 2.0


def test_hang_fault_respects_deadline():
    qplan, tables, _ = _staged_case()
    plan_obj = FaultPlan((FaultSpec("stage:launch", kind="hang",
                                    at=0, times=99),), hang_s=30.0)
    t0 = time.monotonic()
    with pytest.raises(QueryTimeout):
        execute(qplan, _env(), tables, mode="bsp_staged",
                faults=plan_obj, timeout=0.3)
    assert time.monotonic() - t0 < 2.0


def test_cancellation_token():
    qplan, tables, _ = _staged_case()
    tok = CancellationToken()
    tok.cancel("shed load")
    with pytest.raises(QueryCancelled, match="shed load"):
        execute(qplan, _env(), tables, mode="bsp_staged", timeout=tok)


def test_retries_exhausted_raises_injected_fault():
    # at=None fires on EVERY visit, so replays keep faulting until the
    # retry budget runs out — the last injected fault surfaces as-is
    qplan, tables, _ = _staged_case()
    plan_obj = FaultPlan((FaultSpec("stage:launch", kind="raise",
                                    at=None, times=99),))
    with pytest.raises(InjectedFault):
        execute(qplan, _env(), tables, mode="bsp_staged", faults=plan_obj,
                retries=RetryPolicy(retries=2, backoff_s=0.001))


def test_corrupt_capacity_degrades_and_recovers():
    # a corrupted working capacity drops rows on the first attempt; the
    # degrade loop must re-execute until the full result is produced
    qplan, tables, ref = _morsel_case()
    out, stats = execute(qplan, _env(), tables, morsel_rows=32,
                         collect_stats=True,
                         faults="segment:launch@0=corrupt-capacity")
    assert stats.rows_dropped == 0
    got = out.to_numpy()
    rs, gs = np.argsort(ref["k"]), np.argsort(got["k"])
    np.testing.assert_array_equal(ref["k"][rs], got["k"][gs])
    # degrade legitimately reshapes morsels, so float32 sums may differ in
    # the last bit (different accumulation order) — equal values, not bits
    np.testing.assert_allclose(ref["v0_sum"][rs], got["v0_sum"][gs],
                               rtol=1e-6)


# ---------------------------------------------------------------------- #
# FaultPlan machinery: parsing, determinism, validation
# ---------------------------------------------------------------------- #
def test_parse_fault_plan_syntax():
    p = parse_fault_plan("morsel:execute@1x2=raise;spill:*=hang;seed=7")
    assert p.seed == 7
    assert p.specs[0] == FaultSpec("morsel:execute", kind="raise",
                                   at=1, times=2)
    assert p.specs[1].site == "spill:*" and p.specs[1].kind == "hang"
    assert "morsel:execute@1x2=raise" in str(p)


def test_fault_spec_rejects_unknown_site_and_kind():
    with pytest.raises(ValueError, match="matches no registered site"):
        FaultSpec("no:such:site")
    with pytest.raises(ValueError, match="kind"):
        FaultSpec("morsel:execute", kind="explode")


def test_fault_run_is_deterministic():
    spec = "morsel:execute@1=raise"
    r1, r2 = resolve_faults(spec), resolve_faults(spec)
    for run in (r1, r2):
        run.check("morsel:execute", morsel=0)      # occurrence 0: no fire
    with pytest.raises(InjectedFault):
        r1.check("morsel:execute", morsel=1)
    with pytest.raises(InjectedFault):
        r2.check("morsel:execute", morsel=1)
    assert r1.injected == r2.injected == 1
    # exhausted: occurrence 2 passes
    r1.check("morsel:execute", morsel=2)


def test_random_plan_deterministic():
    a, b = random_plan(123, nfaults=3), random_plan(123, nfaults=3)
    assert str(a) == str(b)
    assert str(random_plan(124, nfaults=3)) != str(a)


def test_repro_faults_flag_plumbing():
    qplan, tables, ref = _staged_case()
    with flags.fault_injection("stage:launch@0=raise"):
        out, stats = execute(qplan, _env(), tables, mode="bsp_staged",
                             collect_stats=True)
    assert stats.faults_injected == 1 and stats.retries > 0
    _assert_same(ref, out.to_numpy())


def test_session_level_defaults():
    import repro.df as rdf
    n = 64
    data = {"k": (np.arange(n) % 5).astype(np.int32),
            "v": np.ones(n, np.float32)}
    with rdf.session(faults="stage:launch@0=raise", retries=3) as env:
        df = rdf.read_numpy(data, env=env)
        out, stats = df.groupby("k").agg(v="sum").collect(
            mode="bsp_staged", collect_stats=True)
        assert stats.faults_injected == 1 and stats.retries > 0
        # explicit per-call argument overrides the session default
        _, stats2 = df.groupby("k").agg(v="sum").collect(
            mode="bsp_staged", collect_stats=True, faults=False)
        assert stats2.faults_injected == 0


# ---------------------------------------------------------------------- #
# Checkpoints (core.store.Checkpoint)
# ---------------------------------------------------------------------- #
def _spill(n=32, p=2):
    return SpillTable.from_numpy(
        {"k": np.arange(n, dtype=np.int32),
         "v": np.ones(n, np.float32)}, p)


def test_checkpoint_validate_roundtrip():
    sp = _spill()
    ck = Checkpoint(sp)
    assert ck.validate() is sp           # replay reads the same spill
    assert ck.validate() is sp           # any number of times
    ck.release()
    assert ck.released
    with pytest.raises(RuntimeError, match="released"):
        ck.validate()


def test_checkpoint_detects_mutation():
    sp = _spill()
    ck = Checkpoint(sp)
    sp.append(0, {"k": np.array([99], np.int32),
                  "v": np.array([1.0], np.float32)})
    with pytest.raises(RuntimeError, match="changed since"):
        ck.validate()


def test_checkpoint_refcount():
    ck = Checkpoint(_spill())
    ck.retain()
    ck.release()
    assert not ck.released               # one reference still held
    ck.validate()
    ck.release()
    assert ck.released
    with pytest.raises(RuntimeError, match="released"):
        ck.retain()


# ---------------------------------------------------------------------- #
# Chunked all-to-all validation (satellite: clear errors up front)
# ---------------------------------------------------------------------- #
def test_all_to_all_chunked_validates_chunks():
    comm = get_communicator("xla", "df")
    x = np.zeros((2, 4, 3), np.float32)
    with pytest.raises(ValueError, match="chunks must be a positive int"):
        comm.all_to_all_chunked(x, chunks=0)
    with pytest.raises(ValueError, match="chunks must be a positive int"):
        comm.all_to_all_chunked(x, chunks=-2)
    with pytest.raises(ValueError, match="chunks must be a positive int"):
        comm.all_to_all_chunked(x, chunks=2.5)
    with pytest.raises(ValueError, match="chunks must be a positive int"):
        comm.all_to_all_chunked(x, chunks=True)
    with pytest.raises(ValueError,
                       match=r"capacity axis \(axis 1, 4 rows\) into 9"):
        comm.all_to_all_chunked(x, chunks=9)
    with pytest.raises(ValueError, match=r"got shape \(5,\)"):
        comm.all_to_all_chunked(np.zeros((5,), np.float32), chunks=2)


# ---------------------------------------------------------------------- #
# Overflow warning dedupe (satellite: once per (label, rank) per query)
# ---------------------------------------------------------------------- #
def test_overflow_warning_deduped_per_label_and_rank():
    # the morsel executor fires the debug_overflow callback once per
    # shuffle PER MORSEL per rank; dedupe to one warning per (label, rank)
    # per query, reset at the next query start
    from repro.dataframe.shuffle import (_overflow_warn,
                                         reset_overflow_warnings)
    reset_overflow_warnings()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        for _ in range(5):                       # 5 morsels, same site
            _overflow_warn(0, 8, 0, label="join(k):left")
        _overflow_warn(1, 8, 0, label="join(k):left")   # other rank
        _overflow_warn(0, 0, 4, label="groupby(k)")     # other op
        _overflow_warn(0, 0, 0, label="sort(k)")        # no drop: silent
    msgs = [str(w.message) for w in rec]
    assert len(msgs) == 3
    assert sum("join(k):left @ rank 0" in m for m in msgs) == 1
    assert sum("join(k):left @ rank 1" in m for m in msgs) == 1
    assert sum("groupby(k) @ rank 0" in m for m in msgs) == 1
    reset_overflow_warnings()                    # next query warns afresh
    with pytest.warns(RuntimeWarning, match=r"join\(k\):left @ rank 0"):
        _overflow_warn(0, 8, 0, label="join(k):left")


def test_overflow_summary_warns_once_per_query():
    # an exploding join drops on every morsel under overflow="warn"; the
    # end-of-query summary must be ONE warning attributing the total
    env = _env()
    tables = {"l": {"k": np.zeros(64, np.int32),
                    "v0": np.ones(64, np.float32)},
              "r": {"k": np.zeros(16, np.int32),
                    "w": np.ones(16, np.float32)}}
    plan = Plan.scan("l").join(Plan.scan("r"), on="k")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        _, stats = execute(plan, env, tables, optimize=False,
                           morsel_rows=16, collect_stats=True,
                           overflow="warn")
    assert stats.rows_dropped > 0
    summary = [w for w in rec
               if "out-of-core execution dropped" in str(w.message)]
    assert len(summary) == 1
    assert str(stats.rows_dropped) in str(summary[0].message)


# ---------------------------------------------------------------------- #
# Zero overhead when disabled: identical compile-cache keys
# ---------------------------------------------------------------------- #
def test_injection_disabled_compiles_nothing_new():
    qplan, tables, _ = _morsel_case()
    env = _env()
    keys0 = set(env._cache)
    m0 = env.cache_misses
    # same query, fault-tolerance knobs at defaults + explicit: no new
    # compiled programs, so the keys cannot depend on the harness
    for kw in ({}, {"retries": 5, "timeout": 60.0, "faults": False,
                    "overflow": "degrade"}):
        _, stats = execute(qplan, env, tables, morsel_rows=32,
                           collect_stats=True, **kw)
        assert stats.cache_misses == 0
    assert set(env._cache) == keys0
    assert env.cache_misses == m0


def test_overflow_policy_validation():
    qplan, tables, _ = _staged_case()
    with pytest.raises(ValueError, match="overflow"):
        execute(qplan, _env(), tables, overflow="explode")
    assert OverflowPolicy.ALL == ("raise", "warn", "degrade")


def test_explain_analyze_reports_retries():
    from repro.obs.analyze import run_analyzed
    qplan, tables, _ = _staged_case()
    _, report = run_analyzed(qplan, _env(), tables, mode="bsp_staged",
                             faults="stage:launch@0=raise")
    text = report.explain_analyze()
    assert "retries=1" in text and "degraded=0" in text
    assert report.to_dict()["retries"] == 1

"""Optimizer, checkpointing, compression unit tests (1 device)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import (AdamWConfig, AsyncCheckpointer, adamw_update,
                         clip_by_global_norm, dequantize_int8, global_norm,
                         init_opt_state, latest_step, lr_at, quantize_int8,
                         restore, save)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1e-3) < 1e-9          # end of warmup
    assert lrs[-1] == pytest.approx(1e-4, rel=1e-3)  # min ratio
    assert all(a >= b - 1e-12 for a, b in zip(lrs[1:], lrs[2:]))  # decay


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_adamw_reduces_quadratic():
    """AdamW on f(w) = |w|^2 converges toward 0."""
    w = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = init_opt_state(w)
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                      weight_decay=0.0, clip_norm=100.0)
    for _ in range(150):
        g = jax.tree_util.tree_map(lambda p: 2 * p, w)
        w, state, _ = adamw_update(w, g, state, cfg)
    assert float(jnp.abs(w["w"]).max()) < 0.25


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "b": {"c": jnp.asarray(7, jnp.int32)}}
    path = str(tmp_path / "ckpt_5")
    save(path, state, step=5)
    like = jax.tree_util.tree_map(jnp.zeros_like, state)
    out = restore(path, like)
    np.testing.assert_array_equal(out["a"], state["a"])
    assert int(out["b"]["c"]) == 7
    assert latest_step(str(tmp_path)) == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    state = {"a": jnp.zeros((2, 3))}
    path = str(tmp_path / "ckpt_1")
    save(path, state)
    with pytest.raises(ValueError):
        restore(path, {"a": jnp.zeros((3, 3))})


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer()
    state = {"w": jnp.ones((128, 128))}
    ck.save(str(tmp_path / "ckpt_1"), state, 1)
    ck.wait()
    out = restore(str(tmp_path / "ckpt_1"), state)
    np.testing.assert_array_equal(out["w"], state["w"])


def test_int8_quantization_roundtrip(rng):
    g = jnp.asarray(rng.standard_normal((1000,)) * 0.01, jnp.float32)
    q, scale = quantize_int8(g, block=256)
    back = dequantize_int8(q, scale, g.shape, jnp.float32)
    # error bounded by scale/2 per block
    err = np.abs(np.asarray(back - g))
    bound = np.repeat(np.asarray(scale), 256)[:1000] * 0.5 + 1e-9
    assert (err <= bound).all()

"""Concurrency + serving layer (PR 8): thread-safe ``DevicePool``
free-list, single-flight ``ProgramCache``, thread-safe ``CylonEnv.run``,
and the driver-side ``QueryScheduler``.

Unit-scale (1 CPU device); the 8-device concurrent-serving stress scenario
is ``tests/md_scripts/serving_stress.py`` (``-m multidevice``).
"""

import threading
import time

import numpy as np
import pytest

import repro.df as rdf
from repro.core import CylonEnv, DevicePool, Lease, PoolExhausted
from repro.faults import CancellationToken, QueryCancelled, QueryTimeout
from repro.serve import (AdmissionRejected, ProgramCache, QueryHandle,
                         QueryScheduler)


class FakeDevice:
    """Stand-in device for pool-only tests (pool never touches XLA)."""

    def __init__(self, i):
        self.id = i

    def __repr__(self):
        return f"dev{self.id}"


def fake_pool(n=8):
    return DevicePool([FakeDevice(i) for i in range(n)])


# --------------------------------------------------------------------- #
# DevicePool: locked free-list (the reserve() check-then-act bugfix)
# --------------------------------------------------------------------- #
class TestDevicePool:
    def test_reserve_lowest_first(self):
        pool = fake_pool(8)
        a = pool.reserve(2)
        b = pool.reserve(3)
        assert [d.id for d in a] == [0, 1]
        assert [d.id for d in b] == [2, 3, 4]
        assert pool.available == 3

    def test_release_recarves_same_placement(self):
        pool = fake_pool(8)
        a = pool.reserve(2)
        pool.reserve(2)
        first_ids = [d.id for d in a]
        a.release()
        again = pool.reserve(2)
        assert [d.id for d in again] == first_ids

    def test_exhaustion_raises(self):
        pool = fake_pool(4)
        pool.reserve(3)
        with pytest.raises(PoolExhausted):
            pool.reserve(2)
        with pytest.raises(PoolExhausted):
            pool.reserve(5)          # larger than the pool itself
        assert pool.try_reserve(2) is None

    def test_release_is_idempotent(self):
        pool = fake_pool(4)
        lease = pool.reserve(2)
        lease.release()
        lease.release()              # no double-free
        pool.release(lease)
        assert pool.available == 4
        assert lease.released

    def test_release_all(self):
        pool = fake_pool(4)
        pool.reserve(1)
        lease = pool.reserve(2)
        pool.release_all()
        assert pool.available == 4
        assert lease.released

    def test_lease_is_sequence_and_context_manager(self):
        pool = fake_pool(4)
        with pool.reserve(2) as lease:
            assert isinstance(lease, Lease)
            assert len(lease) == 2
            assert lease[0].id == 0
            assert [d.id for d in lease] == [0, 1]
            assert not lease.released
        assert lease.released
        assert pool.available == 4

    def test_blocking_reserve_token_deadline(self):
        pool = fake_pool(2)
        pool.reserve(2)
        with pytest.raises(QueryTimeout):
            pool.reserve(1, block=True, poll_s=0.01,
                         token=CancellationToken(0.05))

    def test_blocking_reserve_token_cancel(self):
        pool = fake_pool(2)
        held = pool.reserve(2)
        token = CancellationToken()
        threading.Timer(0.05, token.cancel).start()
        with pytest.raises(QueryCancelled):
            pool.reserve(1, block=True, poll_s=0.01, token=token)
        held.release()

    def test_blocking_reserve_waits_for_release(self):
        pool = fake_pool(2)
        held = pool.reserve(2)
        got = []

        def taker():
            lease = pool.reserve(2, block=True, poll_s=0.01)
            got.append([d.id for d in lease])
            lease.release()
        t = threading.Thread(target=taker)
        t.start()
        time.sleep(0.05)
        assert not got               # still blocked
        held.release()
        t.join(timeout=5)
        assert got == [[0, 1]]

    def test_concurrent_reserve_release_never_overlaps(self):
        """The original bump-pointer ``_next`` check-then-act race: two
        threads could read the same cursor and get overlapping devices.
        The free-list must never hand out one device twice."""
        pool = fake_pool(8)
        held_ids = set()
        guard = threading.Lock()
        errors = []

        def churn(_):
            for _ in range(60):
                lease = pool.reserve(2, block=True, poll_s=0.001)
                ids = {d.id for d in lease}
                with guard:
                    if held_ids & ids:
                        errors.append(f"overlap: {held_ids & ids}")
                    held_ids.update(ids)
                time.sleep(0.0005)
                with guard:
                    held_ids.difference_update(ids)
                lease.release()
        threads = [threading.Thread(target=churn, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not errors, errors
        assert pool.available == 8


# --------------------------------------------------------------------- #
# ProgramCache: process-level, single-flight
# --------------------------------------------------------------------- #
class TestProgramCache:
    def test_get_or_build_roundtrip(self):
        cache = ProgramCache(registry=False)
        calls = []
        value, built = cache.get_or_build("k", lambda: calls.append(1) or 42)
        assert (value, built) == (42, True)
        value, built = cache.get_or_build("k", lambda: calls.append(1) or 99)
        assert (value, built) == (42, False)
        assert len(calls) == 1
        assert "k" in cache and len(cache) == 1
        assert cache.peek("k") == 42
        assert cache.stats() == {"entries": 1, "hits": 1, "misses": 1,
                                 "singleflight_waits": 0}
        cache.clear()
        assert len(cache) == 0

    def test_single_flight_builds_once(self):
        cache = ProgramCache(registry=False)
        builds = []
        barrier = threading.Barrier(8)
        results = []

        def builder():
            builds.append(threading.get_ident())
            time.sleep(0.05)         # widen the race window
            return "compiled"

        def racer():
            barrier.wait()
            results.append(cache.get_or_build("prog", builder))
        threads = [threading.Thread(target=racer) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
        assert len(builds) == 1, "builder must run exactly once"
        assert all(v == "compiled" for v, _ in results)
        assert sum(1 for _, built in results if built) == 1
        assert cache.stats()["singleflight_waits"] >= 1

    def test_failed_build_is_retried(self):
        cache = ProgramCache(registry=False)
        attempts = []

        def flaky():
            attempts.append(1)
            if len(attempts) == 1:
                raise RuntimeError("compile boom")
            return "ok"
        with pytest.raises(RuntimeError, match="compile boom"):
            cache.get_or_build("k", flaky)
        assert "k" not in cache      # failed entry must not poison the key
        value, built = cache.get_or_build("k", flaky)
        assert (value, built) == ("ok", True)


# --------------------------------------------------------------------- #
# CylonEnv.run: thread-safe compile cache (the unsynchronized-mutation fix)
# --------------------------------------------------------------------- #
def _sum_col(ctx, t):
    return {"s": t.columns["v"].sum(keepdims=True)}


def _ingest(data_np, env):
    df = rdf.read_numpy(data_np, env=env)
    return next(iter(df.sources.values()))


class TestEnvThreadSafety:
    def test_concurrent_run_same_program_compiles_once(self, rng):
        env = CylonEnv()
        data = _ingest({"v": rng.normal(size=256)}, env)
        barrier = threading.Barrier(8)
        outs, errors = [], []

        def worker():
            try:
                barrier.wait()
                for _ in range(5):
                    outs.append(env.run(_sum_col, data))
            except Exception as e:   # pragma: no cover - failure path
                errors.append(e)
        threads = [threading.Thread(target=worker) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errors
        # zero-recompile invariant under threads: one miss, the rest hits
        assert env.cache_misses == 1
        assert env.cache_hits == 39
        assert len(env._cache) == 1
        ref = outs[0]["s"]
        assert all(np.array_equal(o["s"], ref) for o in outs)

    def test_fresh_env_shared_cache_zero_misses(self, rng):
        """A freshly carved gang over the same devices reuses compiled
        programs from the shared ProgramCache: zero recompiles."""
        shared = ProgramCache(registry=False)
        data_np = {"v": rng.normal(size=256)}
        env1 = CylonEnv(program_cache=shared)
        t1 = _ingest(data_np, env1)
        env1.run(_sum_col, t1)
        assert (env1.cache_misses, env1.cache_hits) == (1, 0)

        env2 = CylonEnv(program_cache=shared)   # fresh gang, same devices
        t2 = _ingest(data_np, env2)
        out = env2.run(_sum_col, t2)
        assert env2.cache_misses == 0
        assert env2.cache_hits == 1
        assert np.array_equal(out["s"], env1.run(_sum_col, t1)["s"])

    def test_private_caches_stay_isolated(self, rng):
        """Default envs keep private caches — a second env recompiles
        (existing per-env counter semantics are unchanged)."""
        data_np = {"v": rng.normal(size=64)}
        env1, env2 = CylonEnv(), CylonEnv()
        env1.run(_sum_col, _ingest(data_np, env1))
        env2.run(_sum_col, _ingest(data_np, env2))
        assert env1.cache_misses == 1
        assert env2.cache_misses == 1


# --------------------------------------------------------------------- #
# session(): scheduler scoping + the silently-ignored-communicator bugfix
# --------------------------------------------------------------------- #
class TestSessionArgs:
    def test_env_plus_communicator_raises(self):
        env = CylonEnv()
        with pytest.raises(TypeError, match="communicator"):
            with rdf.session(env=env, communicator="ring"):
                pass

    def test_env_plus_devices_still_raises(self):
        env = CylonEnv()
        with pytest.raises(TypeError, match="devices"):
            with rdf.session(env=env, devices=env.devices):
                pass

    def test_scheduler_exclusive_with_env_args(self):
        env = CylonEnv()
        sched = QueryScheduler(gang_size=1)
        try:
            for kw in ({"env": env}, {"devices": env.devices},
                       {"communicator": "ring"}):
                with pytest.raises(TypeError, match="scheduler"):
                    with rdf.session(scheduler=sched, **kw):
                        pass
        finally:
            sched.close()


# --------------------------------------------------------------------- #
# QueryScheduler
# --------------------------------------------------------------------- #
class _SlowFrame:
    """collect() that parks the worker before running a real query."""

    def __init__(self, inner, delay=0.3):
        self.inner, self.delay = inner, delay

    def collect(self, **kw):
        time.sleep(self.delay)
        return self.inner.collect(**kw)


class _BoomFrame:
    def collect(self, **kw):
        raise ValueError("deliberate query failure")


@pytest.fixture
def frame(rng):
    return rdf.read_numpy({"k": rng.integers(0, 20, 2048),
                           "v": rng.normal(size=2048)})


def _query(df):
    return df[df.k > 5].groupby("k").agg({"v": ["sum"]}).sort_values("k")


class TestQueryScheduler:
    def test_submit_result_matches_direct_collect(self, frame):
        expect = _query(frame).collect().to_numpy()
        with QueryScheduler(gang_size=1) as sched:
            handle = sched.submit(_query(frame))
            out = handle.result(timeout=120).to_numpy()
        assert set(out) == set(expect)
        for name in expect:
            assert np.array_equal(out[name], expect[name]), name

    def test_handle_stats_lifecycle(self, frame):
        with QueryScheduler(gang_size=1) as sched:
            handle = sched.submit(_query(frame), label="lifecycle")
            handle.result(timeout=120)
        s = handle.stats
        assert s["label"] == "lifecycle"
        assert s["state"] == "done"
        assert s["devices"] == [0]
        assert s["queue_wait_s"] >= 0 and s["wall_s"] > 0
        assert s["submitted_at"] <= s["started_at"] <= s["finished_at"]
        assert s["cache_misses"] >= 0 and s["cache_hits"] >= 0
        assert handle.done() and handle.exception() is None

    def test_session_routes_collect_through_scheduler(self, frame):
        expect = _query(frame).collect().to_numpy()
        with QueryScheduler(gang_size=1) as sched:
            with rdf.session(scheduler=sched):
                out = _query(frame).collect().to_numpy()
            assert sched.stats()["submitted"] == 1
        for name in expect:
            assert np.array_equal(out[name], expect[name]), name

    def test_inner_env_session_masks_scheduler(self, frame):
        with QueryScheduler(gang_size=1) as sched:
            with rdf.session(scheduler=sched):
                with rdf.session() as env:      # innermost wins: plain env
                    _query(frame).collect()
                    assert env.cache_misses > 0
            assert sched.stats()["submitted"] == 0

    def test_repeat_query_fresh_gang_zero_misses(self, frame):
        """Acceptance: a repeated query on a freshly carved gang reports
        cache_misses == 0 through the shared ProgramCache."""
        shared = ProgramCache(registry=False)
        with QueryScheduler(gang_size=1, program_cache=shared) as sched:
            h1 = sched.submit(_query(frame))
            h1.result(timeout=120)
            assert h1.stats["cache_misses"] > 0
            h2 = sched.submit(_query(frame))    # fresh gang (new CylonEnv)
            h2.result(timeout=120)
        assert h2.stats["cache_misses"] == 0
        assert h2.stats["cache_hits"] == h1.stats["cache_misses"] + \
            h1.stats["cache_hits"]

    def test_queueing_past_inflight_then_admission_reject(self, frame):
        sched = QueryScheduler(gang_size=1, max_inflight=1, max_queue=1)
        try:
            h1 = sched.submit(_SlowFrame(_query(frame)))
            time.sleep(0.05)                     # worker picks up h1
            h2 = sched.submit(_query(frame))     # queued
            with pytest.raises(AdmissionRejected):
                sched.submit(_query(frame))      # over capacity: shed
            h1.result(timeout=120)
            h2.result(timeout=120)
            s = sched.stats()
            assert s["completed"] == 2 and s["rejected"] == 1
        finally:
            sched.close()

    def test_cancel_mid_queue(self, frame):
        sched = QueryScheduler(gang_size=1, max_inflight=1, max_queue=4)
        try:
            h1 = sched.submit(_SlowFrame(_query(frame)))
            time.sleep(0.05)
            h2 = sched.submit(_query(frame))
            assert h2.cancel("changed my mind")
            with pytest.raises(QueryCancelled):
                h2.result(timeout=5)             # resolves without a worker
            assert h2.stats["state"] == "cancelled"
            assert not h2.cancel()               # already finished
            h1.result(timeout=120)               # unaffected
        finally:
            sched.close()

    def test_deadline_covers_queue_wait(self, frame):
        sched = QueryScheduler(gang_size=1, max_inflight=1, max_queue=4)
        try:
            h1 = sched.submit(_SlowFrame(_query(frame), delay=0.5))
            time.sleep(0.05)
            h2 = sched.submit(_query(frame), timeout=0.1)  # expires in queue
            with pytest.raises(QueryTimeout):
                h2.result(timeout=30)
            assert h2.stats["state"] == "timeout"
            h1.result(timeout=120)
        finally:
            sched.close()

    def test_failed_query_propagates(self, frame):
        with QueryScheduler(gang_size=1) as sched:
            handle = sched.submit(_BoomFrame())
            with pytest.raises(ValueError, match="deliberate"):
                handle.result(timeout=30)
            assert handle.stats["state"] == "failed"
            assert isinstance(handle.exception(), ValueError)

    def test_close_rejects_new_and_cancels_pending(self, frame):
        sched = QueryScheduler(gang_size=1, max_inflight=1, max_queue=8)
        h1 = sched.submit(_SlowFrame(_query(frame)))
        time.sleep(0.05)
        h2 = sched.submit(_query(frame))
        sched.close(cancel_pending=True, wait=True)
        with pytest.raises(QueryCancelled):
            h2.result(timeout=5)
        with pytest.raises(RuntimeError, match="closed"):
            sched.submit(_query(frame))
        assert h1.done()                         # workers drained

    def test_result_timeout_is_wait_bound_only(self, frame):
        sched = QueryScheduler(gang_size=1)
        try:
            handle = sched.submit(_SlowFrame(_query(frame), delay=0.4))
            with pytest.raises(TimeoutError):
                handle.result(timeout=0.05)
            handle.result(timeout=120)           # query itself unaffected
            assert handle.stats["state"] == "done"
        finally:
            sched.close()

    def test_validates_gang_size(self):
        with pytest.raises(ValueError):
            QueryScheduler(gang_size=0)
        with pytest.raises(ValueError):
            QueryScheduler(gang_size=99)
        with QueryScheduler(gang_size=1) as sched:
            with pytest.raises(ValueError):
                sched.submit(object(), gang_size=99)

    def test_repr_and_handle_repr(self, frame):
        with QueryScheduler(gang_size=1, name="t") as sched:
            assert "t" in repr(sched)
            handle = sched.submit(_query(frame), label="shown")
            assert "shown" in repr(handle)
            handle.result(timeout=120)
            assert isinstance(handle, QueryHandle)

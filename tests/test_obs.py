"""Observability tests (``repro.obs``): span/trace mechanics, Chrome-trace
export, the metrics registry, counter accuracy against independently
computed values across all execution modes, and THE acceptance invariant —
tracing on/off yields bit-identical results and compiles nothing new.

Unit scope (1 CPU device); the 8-device EXPLAIN ANALYZE golden scenario
lives in ``tests/md_scripts/explain_analyze_fig9.py``.
"""

import json

import numpy as np
import pytest

from repro.core import CylonEnv, DistTable, Plan, execute
from repro.obs import (METRICS, NULL_TRACER, MetricsRegistry, Tracer,
                       last_trace, record_exec, resolve_tracer, run_analyzed)
from repro.planner import compile_plan

#: row width of the (int32 k, float32 v0) test tables — the independent
#: bytes-per-row figure the counter-accuracy tests check against
ROW_BYTES = 8


def _data(rng, n=96, keys=12):
    """Integer-valued float32 payloads: aggregation is exact, so traced and
    untraced runs must agree to the bit."""
    return {"k": rng.integers(0, keys, n).astype(np.int32),
            "v0": rng.integers(0, 64, n).astype(np.float32)}


# ---------------------------------------------------------------------- #
# Tracer / Span mechanics
# ---------------------------------------------------------------------- #
def test_span_nesting_attrs_and_durations():
    tr = Tracer("t")
    with tr.span("query", "query") as q:
        with tr.span("stage:0", "stage", dispatch=0) as s:
            s.set(rows=10)
        tr.instant("chunk[0]", "chunk", bytes=64)
    assert q.span.end_s is not None
    trace = tr.finish()
    root = trace.root()
    assert root.name == "query" and root.parent_id is None
    assert [c.name for c in trace.children(root)] == ["stage:0", "chunk[0]"]
    stage = trace.find("stage")[0]
    assert stage.attrs == {"dispatch": 0, "rows": 10}
    assert root.duration_s >= stage.duration_s >= 0.0
    inst = trace.find("chunk")[0]
    assert inst.instant and inst.duration_s == 0.0
    assert trace.duration_s == root.duration_s


def test_finish_closes_open_spans_and_is_idempotent():
    tr = Tracer()
    tr.span("query", "query")               # never exited
    t1 = tr.finish()
    assert t1.root().end_s is not None
    assert tr.finish() is t1                # frozen, not rebuilt
    assert last_trace() is t1


def test_fence_returns_value():
    tr = Tracer()
    with tr.span("s") as h:
        assert h.fence(41) == 41            # block_until_ready passthrough


def test_chrome_trace_export(tmp_path):
    tr = Tracer("q")
    with tr.span("query", "query"):
        with tr.span("stage:0", "stage"):
            tr.instant("shuffle(k)", "shuffle", rows=4, bytes=32)
    path = tmp_path / "trace.json"
    payload = tr.finish().to_chrome_trace(str(path))
    assert json.loads(path.read_text()) == payload
    assert payload["displayTimeUnit"] == "ms"
    evs = {e["name"]: e for e in payload["traceEvents"]}
    assert evs["query"]["ph"] == "X" and evs["shuffle(k)"]["ph"] == "i"
    assert evs["shuffle(k)"]["args"] == {"rows": 4, "bytes": 32}
    # timestamps are relative microseconds; children nest in the parent
    q, s = evs["query"], evs["stage:0"]
    assert q["ts"] == 0.0
    assert s["ts"] >= q["ts"]
    assert s["ts"] + s["dur"] <= q["ts"] + q["dur"] + 1e-3
    assert all(e["pid"] == 0 and e["tid"] == 0
               for e in payload["traceEvents"])


def test_null_tracer_is_falsy_noop():
    assert not NULL_TRACER and NULL_TRACER.enabled is False
    with NULL_TRACER.span("x", "stage", rows=1) as h:
        assert h.set(more=2) is h
        assert h.fence(42) == 42
    assert NULL_TRACER.instant("y") is None
    assert NULL_TRACER.finish() is None


def test_resolve_tracer_env_and_args(monkeypatch):
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    assert resolve_tracer(None) is NULL_TRACER
    monkeypatch.setenv("REPRO_TRACE", "1")
    assert isinstance(resolve_tracer(None), Tracer)
    monkeypatch.setenv("REPRO_TRACE", "0")
    assert resolve_tracer(None) is NULL_TRACER
    assert resolve_tracer(False) is NULL_TRACER
    assert isinstance(resolve_tracer(True), Tracer)
    t = Tracer("mine")
    assert resolve_tracer(t) is t           # passthrough, not re-wrapped
    assert resolve_tracer(NULL_TRACER) is NULL_TRACER


# ---------------------------------------------------------------------- #
# Metrics registry
# ---------------------------------------------------------------------- #
def test_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("queries_total")
    c.inc(mode="bsp")
    c.inc(2, mode="bsp")
    c.inc(mode="amt")
    assert c.value(mode="bsp") == 3 and c.value(mode="amt") == 1
    assert c.value(mode="nope") == 0
    with pytest.raises(ValueError):
        c.inc(-1)
    assert reg.counter("queries_total") is c   # create-on-first-use
    g = reg.gauge("queue_depth")
    g.set(5)
    g.set(2)
    assert g.value() == 2
    h = reg.histogram("wall", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 10.0):
        h.observe(v)
    s = h.series()
    assert s["count"] == 3 and s["bucket_counts"] == [1, 1, 1]
    assert s["min"] == 0.05 and s["max"] == 10.0 and s["sum"] == 10.55
    snap = json.loads(reg.to_json())
    assert snap["counters"]["queries_total"][0]["labels"] == {"mode": "amt"}
    assert snap["gauges"]["queue_depth"][0]["value"] == 2


def test_query_record_cap_and_reset():
    reg = MetricsRegistry(max_query_records=3)
    for i in range(5):
        reg.record_query({"i": i})
    assert [r["i"] for r in reg.query_records] == [2, 3, 4]  # drop-oldest
    assert all("recorded_at" in r for r in reg.query_records)
    reg.reset()
    assert reg.query_records == []
    assert reg.snapshot()["counters"] == {}


def test_record_exec_folds_stats_into_registry(rng):
    env = CylonEnv()
    t = DistTable.from_numpy(_data(rng), env.parallelism)
    plan = Plan.scan("l").shuffle(["k"])
    _, st = execute(plan, env, {"l": t}, optimize=False, collect_stats=True)
    reg = MetricsRegistry()
    rec = record_exec(st, "fp123", 0.5, query="q1", registry=reg)
    assert rec["fingerprint"] == "fp123" and rec["mode"] == "bsp"
    assert reg.counter("queries_total").value(mode="bsp") == 1
    assert (reg.counter("rows_shuffled_total").value(mode="bsp")
            == st.rows_shuffled)
    assert reg.histogram("query_wall_s").series(mode="bsp")["count"] == 1
    assert reg.query_records[-1]["rows_shuffled"] == st.rows_shuffled


# ---------------------------------------------------------------------- #
# Counter accuracy: stats vs independently computed volumes
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("mode", ["bsp", "bsp_staged", "amt"])
def test_counter_accuracy_all_modes(rng, mode):
    n = 96
    env = CylonEnv()
    data = _data(rng, n)
    t = DistTable.from_numpy(data, env.parallelism)
    # unoptimized: the explicit shuffle AND the groupby's own shuffle each
    # move all n rows of (int32 k, float32 v0) = 8 bytes/row
    plan = Plan.scan("l").shuffle(["k"]).groupby(["k"], {"v0": ["sum"]})
    before = METRICS.counter("rows_shuffled_total").value(mode=mode)
    out, st = execute(plan, env, {"l": t}, mode=mode, optimize=False,
                      collect_stats=True)
    assert st.rows_shuffled == 2 * n
    assert st.bytes_shuffled == 2 * n * ROW_BYTES
    assert st.rows_dropped == 0
    recs = {r.label: r for r in st.shuffle_records}
    assert recs["shuffle(k)"].rows == n
    assert recs["groupby(k)"].rows == n
    assert recs["shuffle(k)"].bytes == n * ROW_BYTES
    # ... and the execution folded the same numbers into the global registry
    after = METRICS.counter("rows_shuffled_total").value(mode=mode)
    assert after - before == 2 * n
    assert len(out.to_numpy()["k"]) == len(np.unique(data["k"]))


def test_counter_accuracy_out_of_core(rng):
    n, m = 96, 16
    env = CylonEnv()
    data = _data(rng, n)
    plan = Plan.scan("l").shuffle(["k"])
    out, st = execute(plan, env, {"l": data}, optimize=False,
                      collect_stats=True, morsel_rows=m)
    # per-morsel shuffles must sum to exactly one pass over the data
    assert st.morsels == n // m
    assert st.rows_shuffled == n
    assert st.bytes_shuffled == n * ROW_BYTES
    assert {r.label: r.rows for r in st.shuffle_records} == {"shuffle(k)": n}
    assert out.total_rows() == n


def test_cache_hit_accuracy(rng):
    env = CylonEnv()
    t = DistTable.from_numpy(_data(rng), env.parallelism)
    plan = Plan.scan("l").shuffle(["k"]).groupby(["k"], {"v0": ["sum"]})
    _, s1 = execute(plan, env, {"l": t}, mode="bsp_staged", optimize=False,
                    collect_stats=True)
    assert s1.cache_hits + s1.cache_misses == s1.dispatches == 2
    _, s2 = execute(plan, env, {"l": t}, mode="bsp_staged", optimize=False,
                    collect_stats=True)
    assert s2.cache_misses == 0 and s2.cache_hits == s2.dispatches == 2


def test_exec_stats_timing_fields(rng):
    env = CylonEnv()
    t = DistTable.from_numpy(_data(rng), env.parallelism)
    plan = Plan.scan("l").shuffle(["k"]).groupby(["k"], {"v0": ["sum"]})
    _, st = execute(plan, env, {"l": t}, mode="bsp_staged", optimize=False,
                    collect_stats=True)
    assert st.wall_time_s > 0
    assert [nm for nm, _ in st.stage_times] == ["stage:0", "stage:1"]
    assert all(secs >= 0 for _, secs in st.stage_times)
    assert sum(secs for _, secs in st.stage_times) <= st.wall_time_s + 1e-6


# ---------------------------------------------------------------------- #
# THE invariant: tracing is invisible to results and to the compile cache
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("mode", ["bsp", "bsp_staged", "amt"])
def test_tracing_invisible_to_results_and_cache(rng, mode):
    env = CylonEnv()
    ld = _data(rng, 128)
    rd = {"k": rng.integers(0, 12, 64).astype(np.int32),
          "w": rng.integers(0, 64, 64).astype(np.float32)}
    lt = DistTable.from_numpy(ld, env.parallelism)
    rt = DistTable.from_numpy(rd, env.parallelism)
    tables = {"l": lt, "r": rt}
    plan = (Plan.scan("l").join(Plan.scan("r"), on="k", out_capacity=8192)
            .groupby(["k"], {"v0": ["sum"]}).sort(["k"]))
    ref, s0 = execute(plan, env, tables, mode=mode, collect_stats=True)
    keys0 = set(env._cache)
    tr = Tracer("rerun")
    out, s1 = execute(plan, env, tables, mode=mode, collect_stats=True,
                      trace=tr)
    assert set(env._cache) == keys0          # tracing compiled NOTHING new
    assert s1.cache_misses == 0 and s1.cache_hits == s1.dispatches
    ref_np, out_np = ref.to_numpy(), out.to_numpy()
    for c in ref_np:
        np.testing.assert_array_equal(ref_np[c], out_np[c])
    trace = tr.finish()
    root = trace.root()
    assert root.category == "query"
    # the traced fingerprint is the plan's structural fingerprint
    assert root.attrs["fingerprint"] == compile_plan(plan,
                                                     tables).fingerprint
    assert trace.find("stage") and trace.find("shuffle")
    assert last_trace() is trace


def test_tracing_invisible_out_of_core(rng):
    env = CylonEnv()
    data = _data(rng, 128)
    plan = Plan.scan("l").shuffle(["k"]).groupby(["k"], {"v0": ["sum"]})
    kw = dict(optimize=False, collect_stats=True, morsel_rows=32)
    ref, s0 = execute(plan, env, {"l": data}, **kw)
    keys0 = set(env._cache)
    tr = Tracer("ooc")
    out, s1 = execute(plan, env, {"l": data}, trace=tr, **kw)
    assert set(env._cache) == keys0
    assert s1.cache_misses == 0
    ref_np, out_np = ref.to_numpy(), out.to_numpy()
    for c in ref_np:
        np.testing.assert_array_equal(ref_np[c], out_np[c])
    trace = tr.finish()
    assert trace.find("morsel")              # per-morsel spans
    assert trace.find("transfer", "h2d")     # MorselSource H2D volumes


# ---------------------------------------------------------------------- #
# Drop diagnostics name the op label and rank (never silent, never vague)
# ---------------------------------------------------------------------- #
def test_shuffle_drop_warning_names_label_and_rank(rng):
    env = CylonEnv()
    t = DistTable.from_numpy(_data(rng, 128), 1)
    plan = Plan.scan("l").shuffle(["k"], out_capacity=32,
                                  debug_overflow=True)
    with pytest.warns(RuntimeWarning, match=r"shuffle\(k\) @ rank 0"):
        out = execute(plan, env, {"l": t}, optimize=False)
        np.asarray(out.row_counts)           # force execution + callback


def test_morsel_drop_warning_attributes_loss(rng):
    env = CylonEnv()
    ld = {"k": np.zeros(64, np.int32), "v0": np.ones(64, np.float32)}
    rd = {"k": np.zeros(64, np.int32), "w": np.ones(64, np.float32)}
    plan = Plan.scan("l").join(Plan.scan("r"), on="k")
    with pytest.warns(RuntimeWarning,
                      match=r"capacity pressure \(join\(k\).*@ rank 0"):
        execute(plan, env, {"l": ld, "r": rd}, optimize=False,
                morsel_rows=16, overflow="warn")


# ---------------------------------------------------------------------- #
# EXPLAIN ANALYZE (plan-level; the df frontend wraps run_analyzed)
# ---------------------------------------------------------------------- #
def test_run_analyzed_report(rng, tmp_path):
    env = CylonEnv()
    ld = _data(rng, 128)
    rd = {"k": rng.integers(0, 12, 64).astype(np.int32),
          "w": rng.integers(0, 64, 64).astype(np.float32)}
    tables = {"l": DistTable.from_numpy(ld, env.parallelism),
              "r": DistTable.from_numpy(rd, env.parallelism)}
    plan = (Plan.scan("l").join(Plan.scan("r"), on="k", out_capacity=8192)
            .groupby(["k"], {"v0": ["sum"]}).sort(["k"]))
    result, report = run_analyzed(plan, env, tables)
    text = report.explain_analyze()
    assert "== EXPLAIN ANALYZE: mode=bsp_staged" in text
    assert "act: moved" in text              # measured per-node volumes
    assert "rows=128" in text                # scan actuals
    assert f"out_rows={result.total_rows()}" in text
    assert report.wall_time_s > 0
    stages = report.stage_table()
    assert [r["stage"] for r in stages] == sorted(r["stage"] for r in stages)
    # per-row width varies per stage (the right join side is projected to
    # just k = 4 bytes/row), but stays within the schema's bounds
    assert all(4 * r["rows_shuffled"] <= r["wire_bytes"]
               <= ROW_BYTES * r["rows_shuffled"]
               for r in stages if r["rows_shuffled"])
    md = report.roofline_table()
    assert md.splitlines()[0].startswith("| stage |")
    d = json.loads(report.to_json())
    assert d["mode"] == "bsp_staged" and d["rows_dropped"] == 0
    assert d["fingerprint"] == report.pplan.fingerprint
    assert {r["label"] for r in d["shuffle_records"]} \
        == {r.label for r in report.stats.shuffle_records}
    payload = report.to_chrome_trace(str(tmp_path / "t.json"))
    cats = {e["cat"] for e in payload["traceEvents"]}
    assert {"query", "stage", "shuffle"} <= cats
    assert str(report).startswith("== EXPLAIN ANALYZE")


def test_run_analyzed_trace_off_keeps_tables(rng):
    env = CylonEnv()
    t = DistTable.from_numpy(_data(rng), env.parallelism)
    plan = Plan.scan("l").groupby(["k"], {"v0": ["sum"]})
    _, report = run_analyzed(plan, env, {"l": t}, trace=False)
    assert report.trace is None
    with pytest.raises(ValueError, match="no trace attached"):
        report.to_chrome_trace()
    assert "EXPLAIN ANALYZE" in report.explain_analyze()
    assert report.stage_table()              # tables survive without a trace


def test_df_collect_analyze(rng):
    rdf = pytest.importorskip("repro.df")
    env = CylonEnv()
    rdf.set_default_env(env)
    try:
        df = rdf.read_numpy(_data(rng))
        out, report = df.groupby("k").agg(v0="sum").collect(analyze=True)
        assert "act:" in report.explain_analyze()
        assert report.result_rows == out.total_rows()
        with pytest.raises(TypeError, match="already collects stats"):
            df.collect(analyze=True, collect_stats=True)
        text = df.groupby("k").agg(v0="sum").explain_analyze()
        assert "EXPLAIN ANALYZE" in text and "| stage |" in text
    finally:
        rdf.reset_default_env()

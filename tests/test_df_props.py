"""Pandas-oracle tests for distributed join / groupby / sort end-to-end.

Ranks are simulated with ``jax.vmap(axis_name=...)`` on the single test
device (the same harness as the shuffle property tests), so multi-rank
behaviour — empty ranks, skewed keys, duplicate keys, exact-capacity
tables, multi-dtype columns — is exercised without a subprocess.

Two tiers:

* fixed-case tests (always run): handpicked adversarial cases through the
  same checkers,
* hypothesis property tests (skipped when hypothesis is absent; CI
  installs it): randomized tables against the pandas oracle.
"""

import numpy as np
import pytest

pd = pytest.importorskip("pandas")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.comm import get_communicator  # noqa: E402
from repro.dataframe import Table, join_local, shuffle  # noqa: E402
from repro.dataframe.groupby import groupby as df_groupby  # noqa: E402
from repro.dataframe.sort import sort as df_sort  # noqa: E402

from strategies import (HAVE_HYPOTHESIS, all_rows_one_rank,  # noqa: E402
                        draw_rank_tables, random_rank_tables)

CAP = 16  # per-rank capacity; small so exact-capacity cases are cheap


def _mk_rank_arrays(rows_per_rank, cols):
    """rows_per_rank: list (len p) of dicts of 1-D arrays -> (p, CAP) stack
    plus (p,) counts.  Rows beyond a rank's count are zero padding."""
    p = len(rows_per_rank)
    counts = np.array([len(next(iter(r.values()))) if r else 0
                       for r in rows_per_rank], np.int32)
    out = {}
    for name, dtype in cols.items():
        buf = np.zeros((p, CAP), dtype)
        for r, rows in enumerate(rows_per_rank):
            if counts[r]:
                buf[r, :counts[r]] = np.asarray(rows[name], dtype)
        out[name] = buf
    return out, counts


def _gather(cols_out, counts_out):
    """(p, cap) device outputs + (p,) counts -> host dict of valid rows in
    rank order."""
    counts = np.asarray(counts_out)
    return {k: np.concatenate([np.asarray(v)[r, :counts[r]]
                               for r in range(len(counts))])
            for k, v in cols_out.items()}


def _sorted_records(d, keys):
    order = np.lexsort(tuple(d[k] for k in reversed(keys)))
    return {k: v[order] for k, v in d.items()}


def _assert_same_records(got, want, keys):
    assert sorted(got) == sorted(want)
    g, w = _sorted_records(got, keys), _sorted_records(want, keys)
    for c in want:
        np.testing.assert_array_equal(g[c], w[c], err_msg=c)


# ---------------------------------------------------------------------- #
# Distributed drivers (vmap-simulated ranks)
# ---------------------------------------------------------------------- #
def _dist_join(p, lranks, rranks):
    comm = get_communicator("xla", "df")
    lcols, lcounts = _mk_rank_arrays(
        lranks, {"k": np.int32, "v": np.float32, "i": np.int32})
    rcols, rcounts = _mk_rank_arrays(
        rranks, {"k": np.int32, "w": np.float32, "u": np.uint32})

    def f(lk, lv, li, lc, rk, rw, ru, rc):
        lt = Table({"k": lk, "v": lv, "i": li}, lc)
        rt = Table({"k": rk, "w": rw, "u": ru}, rc)
        kw = dict(bucket_capacity=CAP, out_capacity=p * CAP)
        ls, _ = shuffle(lt, comm, key_cols=["k"], **kw)
        rs, _ = shuffle(rt, comm, key_cols=["k"], **kw)
        out = join_local(ls, rs, "k", out_capacity=(p * CAP) ** 2)
        return dict(out.columns), out.row_count

    cols, counts = jax.vmap(f, axis_name="df")(
        jnp.asarray(lcols["k"]), jnp.asarray(lcols["v"]),
        jnp.asarray(lcols["i"]), jnp.asarray(lcounts),
        jnp.asarray(rcols["k"]), jnp.asarray(rcols["w"]),
        jnp.asarray(rcols["u"]), jnp.asarray(rcounts))
    return _gather(cols, counts)


def _dist_groupby(p, ranks, aggs):
    comm = get_communicator("xla", "df")
    cols, counts = _mk_rank_arrays(
        ranks, {"k": np.int32, "v": np.float32})

    def f(k, v, c):
        t = Table({"k": k, "v": v}, c)
        out, _ = df_groupby(t, comm, ["k"], aggs, pre_aggregate=True,
                            bucket_capacity=CAP, out_capacity=p * CAP)
        return dict(out.columns), out.row_count

    out_cols, out_counts = jax.vmap(f, axis_name="df")(
        jnp.asarray(cols["k"]), jnp.asarray(cols["v"]), jnp.asarray(counts))
    return _gather(out_cols, out_counts)


def _dist_sort(p, ranks):
    comm = get_communicator("xla", "df")
    cols, counts = _mk_rank_arrays(
        ranks, {"k": np.int32, "v": np.float32})

    def f(k, v, c):
        t = Table({"k": k, "v": v}, c)
        out, _ = df_sort(t, comm, ["k", "v"], samples=8,
                         bucket_capacity=CAP, out_capacity=p * CAP)
        return dict(out.columns), out.row_count

    out_cols, out_counts = jax.vmap(f, axis_name="df")(
        jnp.asarray(cols["k"]), jnp.asarray(cols["v"]), jnp.asarray(counts))
    return _gather(out_cols, out_counts)


# ---------------------------------------------------------------------- #
# Pandas oracles + checkers
# ---------------------------------------------------------------------- #
def _concat_ranks(ranks, name, dtype):
    parts = [np.asarray(r[name]) for r in ranks if r]
    return (np.concatenate(parts).astype(dtype) if parts
            else np.zeros(0, dtype))


def _check_join(p, lranks, rranks):
    got = _dist_join(p, lranks, rranks)
    ldf = pd.DataFrame({"k": _concat_ranks(lranks, "k", np.int32),
                        "v": _concat_ranks(lranks, "v", np.float32),
                        "i": _concat_ranks(lranks, "i", np.int32)})
    rdf = pd.DataFrame({"k": _concat_ranks(rranks, "k", np.int32),
                        "w": _concat_ranks(rranks, "w", np.float32),
                        "u": _concat_ranks(rranks, "u", np.uint32)})
    want_df = ldf.merge(rdf, on="k", how="inner")
    want = {c: want_df[c].to_numpy() for c in ("k", "v", "i", "w", "u")}
    _assert_same_records(got, want, ["k", "v", "i", "w", "u"])


def _check_groupby(p, ranks):
    aggs = {"v": ["sum", "mean", "min", "max", "count"]}
    got = _dist_groupby(p, ranks, aggs)
    ks = [np.asarray(r["k"], np.int32) for r in ranks if r]
    vs = [np.asarray(r["v"], np.float32) for r in ranks if r]
    if not ks:
        assert all(len(v) == 0 for v in got.values())
        return
    df = pd.DataFrame({"k": np.concatenate(ks), "v": np.concatenate(vs)})
    g = df.groupby("k")["v"].agg(["sum", "min", "max", "count"])
    # mirror the engine's mean = f32 sum / f32 count (one rounding, not
    # pandas' f64 mean rounded to f32 afterwards)
    want = {"k": g.index.to_numpy(np.int32),
            "v_sum": g["sum"].to_numpy(np.float32),
            "v_mean": (g["sum"].to_numpy(np.float32)
                       / g["count"].to_numpy(np.float32)),
            "v_min": g["min"].to_numpy(np.float32),
            "v_max": g["max"].to_numpy(np.float32),
            "v_count": g["count"].to_numpy(np.int32)}
    _assert_same_records(got, want, ["k"])


def _check_sort(p, ranks):
    got = _dist_sort(p, ranks)
    ks = [np.asarray(r["k"], np.int32) for r in ranks if r]
    vs = [np.asarray(r["v"], np.float32) for r in ranks if r]
    allk = np.concatenate(ks) if ks else np.zeros(0, np.int32)
    allv = np.concatenate(vs) if vs else np.zeros(0, np.float32)
    # global key order is exact; cross-rank tie order follows the sort keys
    np.testing.assert_array_equal(got["k"], np.sort(allk, kind="stable"))
    want_df = pd.DataFrame({"k": allk, "v": allv}).sort_values(["k", "v"])
    _assert_same_records(got, {"k": want_df["k"].to_numpy(),
                               "v": want_df["v"].to_numpy()}, ["k", "v"])


# ---------------------------------------------------------------------- #
# Fixed adversarial cases (run with or without hypothesis)
# ---------------------------------------------------------------------- #
def _rows(k, v=None, i=None, w=None, u=None):
    out = {"k": np.asarray(k, np.int32)}
    if v is not None:
        out["v"] = np.asarray(v, np.float32)
    if i is not None:
        out["i"] = np.asarray(i, np.int32)
    if w is not None:
        out["w"] = np.asarray(w, np.float32)
    if u is not None:
        out["u"] = np.asarray(u, np.uint32)
    return out


def test_join_empty_ranks_and_duplicates():
    lranks = [_rows([1, 1, 2], [1., 2., 3.], [7, 8, 9]), {},
              _rows([2, 3], [4., 5.], [1, 2]), {}]
    rranks = [{}, _rows([1, 2, 2], w=[10., 20., 30.], u=[1, 2, 3]),
              {}, _rows([9], w=[0.], u=[0])]
    _check_join(4, lranks, rranks)


def test_join_exact_capacity_and_skew(rng):
    # every left row on one hot key, both tables at exact capacity
    lranks = [_rows([5] * CAP, rng.random(CAP), np.arange(CAP))
              for _ in range(2)]
    rranks = [_rows([5] * CAP, w=rng.random(CAP), u=np.arange(CAP))
              for _ in range(2)]
    _check_join(2, lranks, rranks)


def test_groupby_empty_ranks_duplicates_skew(rng):
    ranks = [_rows([3] * CAP, rng.integers(0, 50, CAP)), {},
             _rows([3, 4, 4, 5], [1, 2, 3, 4]), {}]
    _check_groupby(4, ranks)
    _check_groupby(1, [_rows([0, 0, 0], [1, 2, 3])])


def test_sort_empty_ranks_and_ties(rng):
    ranks = [_rows([2, 2, 1], [3., 1., 2.]), {},
             _rows([0] * CAP, rng.integers(0, 9, CAP)), {}]
    _check_sort(4, ranks)


def test_all_rows_one_rank(rng):
    # adversarial layout from tests/strategies: one rank holds every row
    ranks = all_rows_one_rank(rng, 4, CAP, names=("v",))
    _check_groupby(4, ranks)
    _check_sort(4, ranks)


def test_random_rank_tables_smoke(rng):
    # fixed-seed twin of the hypothesis suites below (always runs)
    for _ in range(3):
        _check_join(2, random_rank_tables(rng, 2, ("v", "i"), cap=CAP),
                    random_rank_tables(rng, 2, ("w", "u"), cap=CAP))
        _check_groupby(4, random_rank_tables(rng, 4, ("v",), cap=CAP))
        _check_sort(4, random_rank_tables(rng, 4, ("v",), cap=CAP))


# ---------------------------------------------------------------------- #
# Hypothesis property tests (pandas oracle).  Strategies live in
# ``tests/strategies.py`` (shared with the nulls / strings / skew suites);
# the guard keeps fixed-case tests running without hypothesis — CI
# installs it via requirements-dev.txt.
# ---------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(data=st.data(), p=st.sampled_from([1, 2, 4]))
    def test_join_matches_pandas(data, p):
        lranks = draw_rank_tables(data, p, ("v", "i"), cap=CAP)
        rranks = draw_rank_tables(data, p, ("w", "u"), cap=CAP)
        _check_join(p, lranks, rranks)

    @settings(max_examples=15, deadline=None)
    @given(data=st.data(), p=st.sampled_from([1, 2, 4]))
    def test_groupby_matches_pandas(data, p):
        _check_groupby(p, draw_rank_tables(data, p, ("v",), cap=CAP))

    @settings(max_examples=15, deadline=None)
    @given(data=st.data(), p=st.sampled_from([1, 2, 4]))
    def test_sort_matches_pandas(data, p):
        _check_sort(p, draw_rank_tables(data, p, ("v",), cap=CAP))

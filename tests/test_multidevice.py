"""Multi-device integration tests.

Each scenario runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` set before jax
initializes (the unit-test process itself stays 1-device, per the
assignment).  Scripts assert internally and end with an OK line.
"""

import os
import subprocess
import sys

import pytest

SCRIPTS = os.path.join(os.path.dirname(__file__), "md_scripts")
SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def _run(name: str, timeout: int = 900) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    env.pop("XLA_FLAGS", None)  # script sets its own
    proc = subprocess.run(
        [sys.executable, os.path.join(SCRIPTS, name)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"{name} failed\n--- stdout ---\n{proc.stdout[-4000:]}"
            f"\n--- stderr ---\n{proc.stderr[-4000:]}")
    assert "OK" in proc.stdout


@pytest.mark.multidevice
def test_comm_collectives():
    _run("comm_collectives.py")


@pytest.mark.multidevice
def test_dataframe_ops():
    _run("dataframe_ops.py")


@pytest.mark.multidevice
def test_shuffle_props():
    _run("shuffle_props.py")


@pytest.mark.multidevice
def test_sortfree_shuffle_parity():
    _run("sortfree_shuffle_parity.py")


@pytest.mark.multidevice
def test_planner_parity():
    _run("planner_parity.py")


@pytest.mark.multidevice
def test_out_of_core_parity():
    _run("out_of_core_parity.py")


@pytest.mark.multidevice
def test_string_key_parity():
    _run("string_key_parity.py")


@pytest.mark.multidevice
def test_df_frontend_parity():
    _run("df_frontend_parity.py")


@pytest.mark.multidevice
def test_sharded_train():
    _run("sharded_train.py", timeout=1800)


@pytest.mark.multidevice
def test_elastic_checkpoint():
    _run("elastic_checkpoint.py")


@pytest.mark.multidevice
def test_compression_train():
    _run("compression_train.py")


@pytest.mark.multidevice
def test_moe_shuffle_parity():
    _run("moe_shuffle_parity.py")


@pytest.mark.multidevice
def test_data_pipeline():
    _run("data_pipeline.py")


@pytest.mark.multidevice
def test_explain_analyze_fig9():
    _run("explain_analyze_fig9.py")


@pytest.mark.multidevice
def test_fault_chaos():
    _run("fault_chaos.py")


@pytest.mark.multidevice
def test_serving_stress():
    _run("serving_stress.py", timeout=1800)


@pytest.mark.multidevice
def test_ingest_parity():
    _run("ingest_parity.py")


@pytest.mark.multidevice
def test_skew_parity():
    _run("skew_parity.py")

"""The adaptive skew-mitigation layer (``repro.adapt``) under adversarial
data, locked against a pandas oracle and against its own off-switch.

Unit scope (1 CPU device): detection, tuning, and re-routing are all
driver-side host logic, so the detector / tuner / splitter-estimator /
respill contracts are pinned directly.  Salting itself is gated off at
``p == 1`` by construction, which this suite also pins — ``adaptive=True``
must be bit-identical to ``adaptive=False`` whenever no mitigation fires,
with zero new compile-cache keys.  8-device salted execution lives in
``tests/md_scripts/skew_parity.py``.

Covered here:

* hot-key detection: fires on the 99%-one-key table, stays silent on
  uniform keys / tiny tables / small samples / ``p == 1``,
* decision pass: raw groupbys and joins fire, pre-aggregated groupbys and
  oversized build sides don't; cache token is empty iff nothing fired,
* salted routing math: cold rows keep their hash home, hot rows fan out
  over ``k`` ranks,
* morsel autotuner: observed-peak jump, the salted no-double-split rule,
  capacity growth at the morsel floor, expansion carry-over, and the
  ``adaptive=False`` fallback being exactly the legacy blind halving,
* splitter estimator: refresh on imbalance, give-up on identical
  resample, disabled config,
* ``respill_routed``: arbitrary host re-routing preserves every row,
* end-to-end: adaptive on == adaptive off bitwise (in-core and morsel)
  vs the pandas oracle, ``rows_dropped == 0`` under ``overflow="degrade"``
  with autotune replanning, and the session/collect knob threading.
"""

import numpy as np
import pytest

pd = pytest.importorskip("pandas")

import jax.numpy as jnp  # noqa: E402

import repro.df as rdf  # noqa: E402
from repro.adapt import (AdaptiveConfig, MorselTuner,  # noqa: E402
                         SplitterEstimator, resolve_adaptive)
from repro.adapt.config import DISABLED  # noqa: E402
from repro.adapt.hotkeys import (SaltDecision, detect_hot_keys,  # noqa: E402
                                 plan_salt_decisions, salt_cache_token,
                                 sample_key_columns)
from repro.comm import get_communicator  # noqa: E402
from repro.core import CylonEnv, DistTable, Plan, SpillTable, execute  # noqa: E402
from repro.core.store import respill_routed  # noqa: E402
from repro.dataframe.groupby import salted_dest  # noqa: E402
from repro.dataframe.ops_local import hash_columns_np  # noqa: E402
from repro.dataframe.table import Table  # noqa: E402
from repro.expr import col  # noqa: E402
from repro.faults import default_degrade_step  # noqa: E402
from repro.planner import compile_plan  # noqa: E402
from repro.planner.explain import adapt_note  # noqa: E402

from strategies import one_key_table, zipf_table  # noqa: E402

P = 4  # simulated gang size for driver-side detection units


@pytest.fixture
def env():
    e = CylonEnv()
    rdf.set_default_env(e)
    yield e
    rdf.reset_default_env()


# --------------------------------------------------------------------- #
# Config resolution
# --------------------------------------------------------------------- #
def test_resolve_adaptive_forms():
    assert resolve_adaptive(None).enabled
    assert resolve_adaptive(True).enabled
    off = resolve_adaptive(False)
    assert not (off.enabled or off.salting or off.autotune
                or off.splitter_refresh)
    assert off == DISABLED
    assert resolve_adaptive({"salt_k": 3}).salt_k == 3
    cfg = AdaptiveConfig(max_hot_keys=2)
    assert resolve_adaptive(cfg) is cfg
    with pytest.raises(TypeError, match="unknown adaptive"):
        resolve_adaptive({"salt_q": 3})
    with pytest.raises(TypeError, match="adaptive="):
        resolve_adaptive("yes")


# --------------------------------------------------------------------- #
# Hot-key detection
# --------------------------------------------------------------------- #
def test_detect_hot_keys_fires_on_one_key(rng):
    data = one_key_table(rng, 4096)
    cfg = AdaptiveConfig()
    hot = detect_hot_keys(sample_key_columns(data, ["k"], cfg),
                          ["k"], P, cfg)
    assert len(hot) >= 1
    # the detected hash is the hot key's hash
    want = int(hash_columns_np({"k": np.array([7], np.int32)}, ["k"])[0])
    assert want in hot


def test_detect_hot_keys_silent_cases(rng):
    cfg = AdaptiveConfig()
    uniform = {"k": rng.integers(0, 10_000, 4096).astype(np.int32)}
    assert detect_hot_keys(sample_key_columns(uniform, ["k"], cfg),
                           ["k"], P, cfg) == ()
    skewed = one_key_table(rng, 4096)
    # p == 1: every rank is "the hot rank", salting is meaningless
    assert detect_hot_keys(sample_key_columns(skewed, ["k"], cfg),
                           ["k"], 1, cfg) == ()
    # sample below the noise floor
    tiny = {k: v[:16] for k, v in skewed.items()}
    assert detect_hot_keys(sample_key_columns(tiny, ["k"], cfg),
                           ["k"], P, cfg) == ()
    # salting feature-toggled off
    off = AdaptiveConfig(salting=False)
    assert detect_hot_keys(sample_key_columns(skewed, ["k"], off),
                           ["k"], P, off) == ()


def test_detection_is_null_aware(rng):
    # null-heavy keys: masked rows are excluded from the sample, so an
    # all-null-but-one-key column still detects that one real key
    from repro.nulls import mask_name
    n = 2048
    keys = np.full(n, 7, np.int32)
    valid = rng.random(n) < 0.5
    data = {"k": keys, mask_name("k"): valid,
            "v": np.ones(n, np.float32)}
    cfg = AdaptiveConfig()
    sampled = sample_key_columns(data, ["k"], cfg)
    assert len(sampled["k"]) == int(valid.sum())
    assert len(detect_hot_keys(sampled, ["k"], P, cfg)) == 1


# --------------------------------------------------------------------- #
# The per-plan decision pass
# --------------------------------------------------------------------- #
def _lower(plan, tables):
    return compile_plan(plan, tables, optimize_plan=False)


def test_decisions_raw_groupby_fires_preagg_does_not(rng):
    data = one_key_table(rng, 4096)
    cfg = AdaptiveConfig()
    raw = _lower(Plan.scan("t").groupby(["k"], {"v": ["sum"]},
                                        pre_aggregate=False), {"t": data})
    events = []
    salt = plan_salt_decisions(raw.order, {"t": data}, P, cfg, events)
    assert len(salt) == 1
    (dec,) = salt.values()
    assert dec.kind == "groupby" and dec.k == P and dec.keys == ("k",)
    assert events and events[0]["kind"] == "salted"
    assert adapt_note(events[0]) == f"salted[k:{P}, hot:{len(dec.hot_hashes)}]"
    # pre-aggregation is itself the first-line mitigation: never salted
    pre = _lower(Plan.scan("t").groupby(["k"], {"v": ["sum"]},
                                        pre_aggregate=True), {"t": data})
    assert plan_salt_decisions(pre.order, {"t": data}, P, cfg) == {}


def test_decisions_chase_through_row_preserving_ops(rng):
    # detection walks filter/project back to the scan: a filtered raw
    # groupby over skewed input still fires
    data = one_key_table(rng, 4096)
    plan = (Plan.scan("t").with_columns({"v2": col("v") + 1.0})
            .groupby(["k"], {"v": ["sum"]}, pre_aggregate=False))
    low = _lower(plan, {"t": data})
    salt = plan_salt_decisions(low.order, {"t": data}, P, AdaptiveConfig())
    assert len(salt) == 1


def test_decisions_join_broadcast_cap(rng):
    probe = one_key_table(rng, 4096)
    build = {"k": np.arange(64, dtype=np.int32),
             "w": np.ones(64, np.float32)}
    plan = Plan.scan("l").join(Plan.scan("r"), on="k")
    low = _lower(plan, {"l": probe, "r": build})
    events = []
    salt = plan_salt_decisions(low.order, {"l": probe, "r": build}, P,
                               AdaptiveConfig(), events)
    assert len(salt) == 1
    (dec,) = salt.values()
    assert dec.kind == "join" and dec.hot_cap >= 1 and dec.hot_cap % 8 == 0
    assert adapt_note(events[0]).startswith("salted[broadcast")
    # a build side with too many hot rows must NOT broadcast
    fat = {"k": np.full(4096, 7, np.int32), "w": np.ones(4096, np.float32)}
    stingy = AdaptiveConfig(max_broadcast_rows=100)
    assert plan_salt_decisions(low.order, {"l": probe, "r": fat}, P,
                               stingy) == {}


def test_salt_cache_token_empty_iff_no_decisions(rng):
    assert salt_cache_token({}) == ()
    d = SaltDecision("groupby", ("k",), (123,), k=4, node_index=0)
    tok = salt_cache_token({5: d})
    assert tok and tok[0] == "salt"
    assert salt_cache_token({5: d}, nids=[9]) == ()
    assert salt_cache_token({5: d}, nids=[5]) == tok


# --------------------------------------------------------------------- #
# Salted routing math (pure jnp, no collectives)
# --------------------------------------------------------------------- #
def test_salted_dest_spreads_hot_keeps_cold(rng):
    comm = get_communicator("xla", "skew")  # size 1 off-vmap; patch p via P
    cap = 64
    # contiguous hot block (a stride-P hot pattern would alias with the
    # arange%k salt and collapse to one dest — position-dependent salting
    # is fine for real skew, where hot rows are dense, not periodic)
    keys = np.where(np.arange(cap) < 16, 7,
                    rng.integers(100, 200, cap)).astype(np.int32)
    t = Table({"k": jnp.asarray(keys)}, cap)
    h = hash_columns_np({"k": keys}, ["k"])
    hot_hash = int(hash_columns_np({"k": np.array([7], np.int32)}, ["k"])[0])

    class _FakeComm:
        def size(self):
            return P

    dest, is_hot = salted_dest(t, _FakeComm(), ["k"], (hot_hash,), P)
    dest, is_hot = np.asarray(dest), np.asarray(is_hot)
    np.testing.assert_array_equal(is_hot, keys == 7)
    # cold rows: exactly the unsalted home
    np.testing.assert_array_equal(dest[~is_hot],
                                  (h[~is_hot] % P).astype(np.int32))
    # hot rows land on every rank, not one
    assert len(np.unique(dest[is_hot])) == P
    assert comm is not None


# --------------------------------------------------------------------- #
# Morsel autotuner
# --------------------------------------------------------------------- #
def _drop_stats(p, worst):
    a = np.zeros((p, 3), np.int64)
    a[0, 2] = worst
    return [a]


def test_tuner_jumps_to_observed_peak():
    ev = []
    t = MorselTuner(AdaptiveConfig(), events=ev)
    m, w = t.degrade(1024, 2048, _drop_stats(4, 6144))
    # peak = 2048 + 6144 = 8192 -> M' ~ 1024 * (2048/8192) * 0.9 = 230
    assert m == 232 and w == 2048
    assert t.steps == 1 and ev[0]["how"] == "shrink-morsel"
    # the jump beats blind halving: one step instead of three
    assert m < 1024 // 2 // 2


def test_tuner_salted_segment_never_double_splits():
    # a salted segment that still overflows keeps its morsel size (the
    # routing is already balanced) and grows capacity to the peak instead
    ev = []
    t = MorselTuner(AdaptiveConfig(), events=ev)
    m, w = t.degrade(256, 512, _drop_stats(4, 100), salted=True)
    assert m == 256                      # morsels untouched
    assert w >= 612 and w % 8 == 0       # round8(612 * 1.25)
    assert ev[0]["how"] == "grow-capacity"


def test_tuner_floor_and_fit_miss():
    t = MorselTuner(AdaptiveConfig())
    # at the morsel floor the only lever left is capacity
    assert t.degrade(8, 64, _drop_stats(2, 9)) == (8, 128)
    # "estimate says it fits" (zero observed drop) still must shrink
    m, w = t.degrade(64, 128, _drop_stats(2, 0))
    assert m < 64 and w == 128


def test_tuner_expansion_carry_over():
    t = MorselTuner(AdaptiveConfig(), capacity_factor=2.0)
    assert t.initial_morsel(512) == 512
    t.observe_expansion(100, 800)        # 8x join blow-up
    assert t.initial_morsel(512) == 128  # 512 * 2 / 8
    # disabled tuner never pre-shrinks
    t2 = MorselTuner(DISABLED, capacity_factor=2.0)
    t2.observe_expansion(100, 800)
    assert t2.initial_morsel(512) == 512


def test_disabled_fallback_is_legacy_halving():
    assert not MorselTuner(DISABLED).enabled
    # PR 7's blind schedule, preserved verbatim for adaptive=False
    assert default_degrade_step(1024, 2048) == (512, 2048)
    assert default_degrade_step(16, 2048) == (8, 2048)
    assert default_degrade_step(8, 2048) == (8, 4096)


# --------------------------------------------------------------------- #
# Splitter estimator
# --------------------------------------------------------------------- #
def _estimator(cfg, resample):
    return SplitterEstimator(np.array([10, 20, 30]), resample, 8, cfg,
                             events=[], label="sort(k)")


def test_splitter_refresh_on_imbalance():
    fresh = np.array([1, 2, 3])
    est = _estimator(AdaptiveConfig(), lambda s: fresh)
    # balanced counts: no refresh however many rows flow
    assert not est.observe(np.array([100, 100, 100, 100]))
    assert est.refreshes == 0
    # one rank takes ~everything -> refresh with a boosted sample
    assert est.observe(np.array([0, 4000, 0, 0]))
    assert est.refreshes == 1
    np.testing.assert_array_equal(est.splitters, fresh)


def test_splitter_gives_up_on_identical_resample():
    est = _estimator(AdaptiveConfig(),
                     lambda s: np.array([10, 20, 30]))
    assert not est.observe(np.array([0, 4000, 0, 0]))
    # identical resample: the imbalance is the data; budget closed
    assert est.refreshes == est._cfg.max_refreshes
    assert not est.observe(np.array([0, 4000, 0, 0]))


def test_splitter_disabled_never_refreshes():
    est = _estimator(DISABLED, lambda s: np.array([1, 2, 3]))
    assert not est.enabled
    assert not est.observe(np.array([0, 40000, 0, 0]))
    assert est.refreshes == 0


# --------------------------------------------------------------------- #
# Host re-routing primitive
# --------------------------------------------------------------------- #
def test_respill_routed_preserves_rows(rng):
    data = {"k": rng.integers(0, 97, 300).astype(np.int32),
            "v": rng.random(300).astype(np.float32)}
    sp = SpillTable.from_numpy(data, 4, chunk_rows=32)
    out = respill_routed(sp, lambda c: c["k"].astype(np.int64) % 4)
    assert out.total_rows() == 300
    for r in range(4):
        cols = out.rank_concat(r)
        assert (cols["k"] % 4 == r).all()
    got = out.to_numpy()
    np.testing.assert_array_equal(np.sort(got["v"]), np.sort(data["v"]))


# --------------------------------------------------------------------- #
# End-to-end: oracle parity + bit-identity with the off-switch (p = 1)
# --------------------------------------------------------------------- #
def _oracle_groupby(data):
    return (pd.DataFrame(data).groupby("k")
            .agg(v_sum=("v", "sum"), v_count=("v", "count"))
            .reset_index().sort_values("k").reset_index(drop=True))


@pytest.mark.parametrize("make", [one_key_table, zipf_table])
def test_adaptive_on_off_bit_identical_vs_pandas(env, rng, make):
    data = make(rng, 2048)
    plan = (Plan.scan("t").groupby(["k"], {"v": ["sum", "count"]},
                                   pre_aggregate=False).sort(["k"]))
    t = DistTable.from_numpy(data, 1)
    ref, st_off = execute(plan, env, {"t": t}, adaptive=False,
                          collect_stats=True)
    got, st_on = execute(plan, env, {"t": t}, adaptive=True,
                         collect_stats=True)
    assert st_off.adaptive is False and st_on.adaptive is True
    # p == 1: nothing fires, and the cache keys must be shared
    assert st_on.salted_shuffles == 0
    assert st_on.cache_hits >= 1  # re-used the adaptive=False programs
    ref_np, got_np = ref.to_numpy(), got.to_numpy()
    for c in ref_np:
        np.testing.assert_array_equal(ref_np[c], got_np[c])
    want = _oracle_groupby(data)
    np.testing.assert_array_equal(got_np["k"], want["k"])
    np.testing.assert_array_equal(got_np["v_sum"],
                                  want["v_sum"].astype(np.float32))
    np.testing.assert_array_equal(got_np["v_count"], want["v_count"])


def test_degrade_autotune_recovers_every_row(env):
    # the exploding join from the PR 7 degrade test, now replanned by the
    # tuner: zero drops, same rows, and the replay count is recorded
    ld = {"k": np.zeros(64, np.int32), "v0": np.arange(64, dtype=np.float32)}
    rd = {"k": np.zeros(8, np.int32), "w": np.arange(8, dtype=np.float32)}
    plan = Plan.scan("l").join(Plan.scan("r"), on="k")
    outs = {}
    for adaptive in (False, True):
        sp, st = execute(plan, env, {"l": ld, "r": rd}, optimize=False,
                         morsel_rows=16, collect_stats=True,
                         adaptive=adaptive)
        assert st.rows_dropped == 0
        assert st.degraded > 0
        if adaptive:
            assert st.autotune_steps == st.degraded
            assert any(e["kind"] == "autotune" for e in st.adapt_events)
        out = sp.to_numpy()
        assert len(out["k"]) == 64 * 8
        order = np.lexsort((out["w"], out["v0"]))
        outs[adaptive] = {c: out[c][order] for c in out}
    for c in outs[True]:
        np.testing.assert_array_equal(outs[True][c], outs[False][c])


def test_session_and_collect_knob_threading(env, rng):
    data = one_key_table(rng, 512)
    df = rdf.read_numpy(data)
    q = df.groupby("k").agg({"v": ["sum"]})
    _, st = q.collect(collect_stats=True)
    assert st.adaptive is True           # default on
    with rdf.session(env=env, adaptive=False):
        _, st = q.collect(collect_stats=True)
        assert st.adaptive is False
        # per-call argument beats the session default
        _, st = q.collect(collect_stats=True, adaptive=True)
        assert st.adaptive is True
    _, st = q.collect(collect_stats=True,
                      adaptive={"salting": False})
    assert st.adaptive is True

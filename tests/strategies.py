"""Shared adversarial-table generators for the test suite.

One home for the randomized inputs that the pandas-oracle suites feed the
engine, in two interchangeable tiers:

* **hypothesis strategies** (``HAVE_HYPOTHESIS`` guards them — CI installs
  hypothesis, minimal envs skip the property tests but still run every
  fixed case), and
* **fixed-seed fallbacks** built on ``np.random.Generator`` so the same
  adversarial shapes are exercised deterministically with no extra deps.

The adversarial shapes the skew work (``repro.adapt``, ``tests/test_skew``)
cares about are first-class here: power-law / Zipf key draws, the
99%-one-key table, all-rows-on-one-rank layouts, empty ranks, null-heavy
frames, and string-keyed tables.  Import from tests as plain modules
(pytest puts ``tests/`` on ``sys.path``)::

    from strategies import one_key_table, zipf_table, HAVE_HYPOTHESIS
"""

import numpy as np

try:
    from hypothesis import HealthCheck, given, settings  # noqa: F401
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal envs
    st = None
    HAVE_HYPOTHESIS = False

__all__ = [
    "HAVE_HYPOTHESIS", "st", "POOL",
    "zipf_keys", "zipf_table", "one_key_table", "exact_table",
    "string_table", "string_keyed_skew_table", "null_heavy_frame",
    "random_nullable_frame", "all_rows_one_rank", "random_rank_tables",
    "draw_rank_tables", "nullable_frame", "string_tables",
]

#: small sorted vocabulary for dictionary-encoded string columns
POOL = ["ash", "birch", "cedar", "elm", "fir", "oak", "pine", "yew"]


# --------------------------------------------------------------------- #
# Fixed-seed adversarial tables (np.random.Generator based)
# --------------------------------------------------------------------- #
def zipf_keys(rng, n, a=1.5, vocab=1000):
    """Power-law int32 keys: rank-frequency ~ 1/rank**a over ``vocab``
    distinct values — the classic heavy-head shuffle-skew distribution."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** -a
    probs /= probs.sum()
    return rng.choice(vocab, size=n, p=probs).astype(np.int32)


def zipf_table(rng, n, a=1.5, vocab=1000):
    """Zipf-keyed table with an exact-sum float32 payload."""
    return {"k": zipf_keys(rng, n, a, vocab),
            "v": rng.integers(0, 100, n).astype(np.float32)}


def one_key_table(rng, n, hot=7, frac=0.99, vocab=1000):
    """``frac`` of all rows carry one hot key; the rest are uniform.
    The worst case for hash partitioning: one rank receives ~everything."""
    keys = np.where(rng.random(n) < frac, hot,
                    rng.integers(0, vocab, n)).astype(np.int32)
    return {"k": keys, "v": rng.integers(0, 100, n).astype(np.float32)}


def exact_table(rng, n, keys=50):
    """Integer-valued float32 payloads: float sums are exact, so morsel
    re-aggregation order cannot perturb bits."""
    return {"k": rng.integers(0, keys, n).astype(np.int32),
            "v0": rng.integers(0, 100, n).astype(np.float32)}


def string_table(rng, n=128, pool=POOL, value_col="v"):
    """Dictionary-encodable string-keyed table over a small pool."""
    return {"s": rng.choice(np.asarray(pool), n),
            value_col: rng.integers(0, 16, n).astype(np.float32)}


def string_keyed_skew_table(rng, n=256, hot="oak", frac=0.99, pool=POOL,
                            value_col="v"):
    """String-keyed twin of ``one_key_table``: ``frac`` of rows carry one
    hot word, the rest draw uniformly from ``pool``."""
    s = rng.choice(np.asarray(pool), n)
    s[rng.random(n) < frac] = hot
    return {"s": s, value_col: rng.integers(0, 16, n).astype(np.float32)}


def null_heavy_frame(rng, n=64, names=("v",), null_frac=0.9, key_range=6):
    """pandas frame where ``null_frac`` of every cell is null (float-NaN
    encoding) — stresses valid-row sampling and null-key drop paths.
    Needs pandas; import guarded at call sites."""
    import pandas as pd
    cols = {"k": np.where(rng.random(n) < null_frac, np.nan,
                          rng.integers(0, key_range, n).astype(float))}
    for nm in names:
        cols[nm] = np.where(rng.random(n) < null_frac, np.nan,
                            rng.integers(-30, 31, n).astype(float))
    return pd.DataFrame(cols)


def random_nullable_frame(rng, names=("v",), max_rows=40, null_frac=0.3):
    """Moderately-null pandas frame (fixed-seed twin of the hypothesis
    ``nullable_frame`` strategy below)."""
    import pandas as pd
    n = int(rng.integers(0, max_rows + 1))
    cols = {"k": np.where(rng.random(n) < null_frac, np.nan,
                          rng.integers(0, 6, n).astype(float))}
    for nm in names:
        cols[nm] = np.where(rng.random(n) < null_frac, np.nan,
                            rng.integers(-30, 31, n).astype(float))
    return pd.DataFrame(cols)


def _value_columns(rng_or_vals, n, names):
    """Shared column typing for the per-rank generators: v/w are float32,
    u is uint32, anything else int32."""
    rows = {}
    for nm, vals in zip(names, rng_or_vals):
        if nm in ("v", "w"):
            rows[nm] = np.asarray(vals, np.float32)
        elif nm == "u":
            rows[nm] = (np.asarray(vals, np.int64) + 50).astype(np.uint32)
        else:
            rows[nm] = np.asarray(vals, np.int32)
    return rows


def all_rows_one_rank(rng, p, n, names=("v",), key_range=7, loaded=0):
    """Per-rank row dicts (for the vmap rank harness) where rank
    ``loaded`` holds every row and all other ranks are empty."""
    ranks = [{} for _ in range(p)]
    rows = {"k": rng.integers(0, key_range, n).astype(np.int32)}
    rows.update(_value_columns(
        [rng.integers(-50, 51, n) for _ in names], n, names))
    ranks[loaded] = rows
    return ranks


def random_rank_tables(rng, p, names, cap=16, key_range=7):
    """Fixed-seed twin of ``draw_rank_tables``: per-rank counts hit the
    extremes (empty / one row / half / exact capacity) with duplicate-rich
    small-range keys."""
    ranks = []
    for _ in range(p):
        n = int(rng.choice([0, 1, cap // 2, cap]))
        if n == 0:
            ranks.append({})
            continue
        rows = {"k": rng.integers(0, key_range, n).astype(np.int32)}
        rows.update(_value_columns(
            [rng.integers(-50, 51, n) for _ in names], n, names))
        ranks.append(rows)
    return ranks


# --------------------------------------------------------------------- #
# Hypothesis strategies (guarded: None without hypothesis)
# --------------------------------------------------------------------- #
def draw_rank_tables(data, p, names, cap=16, key_range=7):
    """Per-rank row dicts drawn interactively from ``st.data()``: counts
    in {0, 1, cap/2, cap} including the extremes, keys from a small range
    (duplicates + skew), integer-valued floats so aggregation results are
    exact.  (Used by the join/groupby/sort property suites.)"""
    ranks = []
    for _ in range(p):
        n = data.draw(st.sampled_from([0, 1, cap // 2, cap]))
        if n == 0:
            ranks.append({})
            continue
        keys = data.draw(st.lists(st.integers(0, key_range - 1),
                                  min_size=n, max_size=n))
        rows = {"k": np.asarray(keys, np.int32)}
        rows.update(_value_columns(
            [data.draw(st.lists(st.integers(-50, 50),
                                min_size=n, max_size=n))
             for _ in names], n, names))
        ranks.append(rows)
    return ranks


def nullable_frame(draw, names=("v",), max_rows=40):
    """A pandas frame: float key ``k`` in a small range (duplicates) and
    float value columns, every cell independently nullable.  Integer-valued
    floats keep aggregation sums exact in float32."""
    import pandas as pd
    n = draw(st.integers(0, max_rows))
    cols = {}
    kvals = draw(st.lists(st.integers(0, 5), min_size=n, max_size=n))
    knull = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    cols["k"] = np.where(knull, np.nan, np.asarray(kvals, float))
    for nm in names:
        vals = draw(st.lists(st.integers(-30, 30), min_size=n, max_size=n))
        nulls = draw(st.lists(st.booleans(), min_size=n, max_size=n))
        cols[nm] = np.where(nulls, np.nan, np.asarray(vals, float))
    return pd.DataFrame(cols)


if HAVE_HYPOTHESIS:
    _words = st.text(alphabet="abcdef", min_size=0, max_size=5)
    _pools = st.lists(_words, min_size=1, max_size=12, unique=True)

    @st.composite
    def string_tables(draw, value_col="v"):
        """Random string pool + rows over it (forces fresh dictionaries,
        including cross-table mismatches that must recode)."""
        pool = draw(_pools)
        n = draw(st.integers(1, 48))
        idx = draw(st.lists(st.integers(0, len(pool) - 1),
                            min_size=n, max_size=n))
        vals = draw(st.lists(st.integers(0, 9), min_size=n, max_size=n))
        return {"s": np.asarray([pool[i] for i in idx]),
                value_col: np.asarray(vals, np.float32)}
else:  # pragma: no cover - exercised in minimal envs
    string_tables = None

"""Hypothesis property: the sort-free shuffle is row-set-identical to the
PR-1 sorted implementation across communicators, parallelisms, skewed
destinations, and capacity overflow.

The property is stronger than row-set identity — outputs are asserted
bit-identical per rank (same rows in the same slots), which holds because
radix ranks are stable and the prefix-sum compaction enumerates rows in the
same order as the stable sort.  Ranks are simulated with
``jax.vmap(axis_name=...)`` on the single test device.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.comm import get_communicator  # noqa: E402
from repro.dataframe import Table, shuffle  # noqa: E402

CAP = 32


def _run(comm_name, p, dest_rows, counts, chunks, impl):
    comm = get_communicator(comm_name, "df")
    dest = jnp.asarray(dest_rows, jnp.int32)          # (p, CAP) in [0, p)
    vals = jnp.arange(p * CAP, dtype=jnp.float32).reshape(p, CAP)
    counts = jnp.asarray(counts, jnp.int32)

    def f(d, v, n):
        t = Table({"d": d, "v": v}, n)
        out, stats = shuffle(t, comm, dest=d, bucket_capacity=16,
                             impl=impl, a2a_chunks=chunks)
        return (dict(out.columns), out.row_count, stats.sent_counts,
                stats.recv_counts, stats.send_dropped, stats.recv_dropped)

    out = jax.vmap(f, axis_name="df")(dest, vals, counts)
    return jax.tree_util.tree_map(np.asarray, out)


@settings(max_examples=25, deadline=None)
@given(data=st.data(),
       p=st.sampled_from([1, 2, 4, 8]),
       comm_name=st.sampled_from(["ring", "bruck", "xla"]),
       chunks=st.integers(1, 4),
       hot=st.booleans())
def test_radix_shuffle_equals_sorted(data, p, comm_name, chunks, hot):
    # skewed destinations: optionally concentrate most rows on one rank so
    # the 16-slot buckets overflow and the drop paths are exercised too
    if hot:
        hot_rank = data.draw(st.integers(0, p - 1))
        dest_rows = data.draw(st.lists(
            st.lists(st.sampled_from([hot_rank] * 3 + list(range(p))),
                     min_size=CAP, max_size=CAP),
            min_size=p, max_size=p))
    else:
        dest_rows = data.draw(st.lists(
            st.lists(st.integers(0, p - 1), min_size=CAP, max_size=CAP),
            min_size=p, max_size=p))
    counts = data.draw(st.lists(st.integers(0, CAP), min_size=p, max_size=p))

    ref = _run(comm_name, p, dest_rows, counts, chunks=1, impl="sorted")
    got = _run(comm_name, p, dest_rows, counts, chunks=chunks, impl="radix")
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(a, b)

    # conservation: every row is either delivered, dropped at the send
    # bucket, or dropped at the receive capacity — never silently lost
    (_, rc, sent, recv, send_drop, recv_drop) = got
    assert int(rc.sum()) + int(send_drop.sum()) + int(recv_drop.sum()) \
        == int(np.sum(counts))
    assert np.array_equal(sent, recv.T)   # what i sent j, j received from i

"""Dictionary-encoded string columns end-to-end vs a pandas oracle.

The encoding invariant (``repro.dataframe.schema``): dictionaries are
lexicographically sorted, so int32 codes are order-isomorphic to their
strings — sort/min/max/range-partition on codes equals the same on
strings, and code equality equals string equality within one dictionary.
Joins across *different* dictionaries go through a planner-inserted
``recode`` node (visible in EXPLAIN).

Tiers: encoding-layer unit tests, literal-lowering semantics, frontend
pipelines (join/groupby/sort/filter) against pandas, spill/out-of-core
paths incl. empty ranks, clear-error checks, and hypothesis property
tests over random string pools (skipped without hypothesis; CI installs
it).
"""

import numpy as np
import pytest

pd = pytest.importorskip("pandas")

import repro.df as rdf  # noqa: E402
from repro.core import CylonEnv, DistTable, SpillTable  # noqa: E402
from repro.core.store import repartition, respill  # noqa: E402
from repro.dataframe.schema import (DictTypeError, decode_codes,  # noqa: E402
                                    encode_strings, lower_expr,
                                    merge_dictionaries, recode_mapping)
from repro.expr import col, lit  # noqa: E402

# shared generators (tests/strategies.py): POOL, the string-table
# fallbacks, and the hypothesis composites — the flag keeps the fixed
# cases running in minimal envs, CI installs hypothesis
from strategies import (HAVE_HYPOTHESIS, POOL,  # noqa: E402
                        string_keyed_skew_table, string_table as _sdata,
                        string_tables)

if HAVE_HYPOTHESIS:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st


@pytest.fixture
def env():
    e = CylonEnv()
    rdf.set_default_env(e)
    yield e
    rdf.reset_default_env()


def _records(d, keys):
    d = {k: np.asarray(v) for k, v in d.items()}
    order = np.lexsort(tuple(d[k] for k in reversed(keys)))
    return {k: v[order] for k, v in d.items()}


def _assert_same(got, want, keys):
    assert sorted(got) == sorted(want)
    g, w = _records(got, keys), _records(want, keys)
    for c in want:
        if np.asarray(w[c]).dtype.kind in ("U", "O"):
            np.testing.assert_array_equal(np.asarray(g[c], str),
                                          np.asarray(w[c], str), err_msg=c)
        else:
            np.testing.assert_allclose(np.asarray(g[c], np.float64),
                                       np.asarray(w[c], np.float64),
                                       rtol=1e-6, err_msg=c)


# ---------------------------------------------------------------------- #
# Encoding layer
# ---------------------------------------------------------------------- #
def test_encode_sorted_and_order_isomorphic(rng):
    arr = rng.choice(np.asarray(POOL), 64)
    codes, d = encode_strings(arr)
    assert list(d) == sorted(set(arr))            # sorted, duplicate-free
    np.testing.assert_array_equal(decode_codes(codes, d), arr)
    # order isomorphism: sorting codes sorts strings
    np.testing.assert_array_equal(
        decode_codes(np.sort(codes), d), np.sort(arr))
    assert codes.dtype == np.int32


def test_encode_empty_and_object_arrays():
    codes, d = encode_strings(np.asarray([], dtype=object))
    assert d == () and codes.shape == (0,)
    codes, d = encode_strings(np.asarray(["b", "a"], dtype=object))
    assert d == ("a", "b") and list(codes) == [1, 0]
    with pytest.raises(TypeError, match="all-string"):
        encode_strings(np.asarray(["a", 1], dtype=object))


def test_decode_rejects_out_of_range_codes():
    # decode runs on valid rows only; an out-of-range code is upstream
    # corruption and must fail loudly, never alias a dictionary entry
    with pytest.raises(ValueError, match="out of range"):
        decode_codes(np.asarray([0, 2], np.int32), ("a", "b"))
    with pytest.raises(ValueError, match="out of range"):
        decode_codes(np.asarray([-1], np.int32), ("a",))
    with pytest.raises(ValueError, match="out of range"):
        decode_codes(np.asarray([0], np.int32), ())


def test_recode_mapping_roundtrip():
    old = ("b", "d")
    new = merge_dictionaries(old, ("a", "c", "d"))
    assert new == ("a", "b", "c", "d")
    m = recode_mapping(old, new)
    codes = np.asarray([0, 1, 1, 0], np.int32)
    np.testing.assert_array_equal(
        decode_codes(m[codes], new), decode_codes(codes, old))
    with pytest.raises(ValueError, match="missing"):
        recode_mapping(("z",), ("a", "b"))
    # empty old dictionary still yields a valid (len-1) gather table
    assert recode_mapping((), ("a",)).shape == (1,)


def test_lower_expr_comparison_table():
    d = {"s": ("ash", "cedar", "oak")}
    tbl = [  # Exprs are unhashable by design (== builds a tree)
        (col("s") == "cedar", "s == 1"),
        (col("s") == "nope", "s == -1"),
        (col("s") != "oak", "s != 2"),
        (col("s") < "cedar", "s < 1"),
        (col("s") <= "cedar", "s < 2"),
        (col("s") > "cedar", "s >= 2"),
        (col("s") >= "cedar", "s >= 1"),
        # absent literal: strictly-between boundary, lo == hi
        (col("s") < "beech", "s < 1"),
        (col("s") <= "beech", "s < 1"),
        (col("s") > "beech", "s >= 1"),
    ]
    for e, want in tbl:
        lowered, out_d = lower_expr(e, d)
        assert out_d is None
        assert repr(lowered) == want, repr(e)
    # reflected: "cedar" < s  ==  s > "cedar"
    lowered, _ = lower_expr(lit("cedar") < col("s"), d)
    assert repr(lowered) == "s >= 2"


def test_lower_expr_rejections():
    d = {"s": ("a", "b"), "t": ("a", "c")}
    with pytest.raises(DictTypeError, match="arithmetic"):
        lower_expr(col("s") + 1, d)
    with pytest.raises(DictTypeError, match="numeric"):
        lower_expr(col("s") == 3, d)
    with pytest.raises(DictTypeError, match="different dictionaries"):
        lower_expr(col("s") == col("t"), d)
    with pytest.raises(DictTypeError, match="boolean"):
        lower_expr(col("s") & True, d)
    # same dictionary: plain code comparison is exact
    lowered, _ = lower_expr(col("s") == col("s"), d)
    assert repr(lowered) == "s == s"
    # bare string literal: constant column over a singleton dictionary
    lowered, out_d = lower_expr(lit("x"), d)
    assert out_d == ("x",) and lowered.value == 0


# ---------------------------------------------------------------------- #
# Ingest / egress
# ---------------------------------------------------------------------- #
def test_disttable_ingest_decodes_back(rng):
    data = _sdata(rng)
    t = DistTable.from_numpy(dict(data), 1)
    assert list(t.dictionaries["s"]) == sorted(set(data["s"]))
    np.testing.assert_array_equal(t.to_numpy()["s"], data["s"])
    # decode=False exposes the raw codes
    raw = t.to_numpy(decode=False)["s"]
    assert raw.dtype == np.int32


def test_spilltable_empty_ranks_keep_dictionaries(rng):
    data = {k: v[:2] for k, v in _sdata(rng).items()}
    sp = SpillTable.from_numpy(data, parallelism=4)   # ranks 2,3 empty
    assert sp.rank_rows(2) == 0 and sp.rank_rows(3) == 0
    assert sp.dictionaries["s"]
    np.testing.assert_array_equal(sp.to_numpy()["s"], data["s"])
    # respill / rescatter / repartition all preserve the dictionaries
    assert respill(sp, 2).dictionaries == sp.dictionaries
    dist = repartition(sp, 2)
    assert dist.dictionaries == sp.dictionaries
    np.testing.assert_array_equal(dist.to_numpy()["s"], data["s"])


def test_device_table_rejects_raw_strings():
    from repro.dataframe import Table
    with pytest.raises(TypeError, match="dictionary codes"):
        Table.from_arrays({"s": np.asarray(["a", "b"])})


# ---------------------------------------------------------------------- #
# Frontend pipelines vs pandas (1 device: full planner/executor runs)
# ---------------------------------------------------------------------- #
def test_string_filter_vs_pandas(env, rng):
    data = _sdata(rng)
    df = rdf.read_numpy(data)
    p = pd.DataFrame(data)
    for e, mask in [
        (df.s == "oak", p.s == "oak"),
        (df.s != "oak", p.s != "oak"),
        (df.s < "elm", p.s < "elm"),
        (df.s >= "cedar", p.s >= "cedar"),
        (df.s <= "frost", p.s <= "frost"),      # literal not in the pool
        ((df.s > "birch") & (df.v > 4), (p.s > "birch") & (p.v > 4)),
    ]:
        _assert_same(df[e].to_numpy(),
                     {c: p[c][mask].to_numpy() for c in p}, ["s", "v"])


def test_string_groupby_vs_pandas(env, rng):
    data = _sdata(rng)
    out = (rdf.read_numpy(data).groupby("s")
           .agg({"v": ["sum", "mean", "count"]}).to_numpy())
    want = (pd.DataFrame(data).groupby("s")
            .agg(v_sum=("v", "sum"), v_mean=("v", "mean"),
                 v_count=("v", "count")).reset_index())
    _assert_same(out, {c: want[c].to_numpy() for c in want}, ["s"])


def test_string_keyed_skew_groupby_vs_pandas(env, rng):
    # 99% of rows on one hot word (tests/strategies adversarial shape)
    data = string_keyed_skew_table(rng, n=256)
    out = (rdf.read_numpy(data).groupby("s")
           .agg({"v": ["sum", "count"]}).to_numpy())
    want = (pd.DataFrame(data).groupby("s")
            .agg(v_sum=("v", "sum"), v_count=("v", "count")).reset_index())
    _assert_same(out, {c: want[c].to_numpy() for c in want}, ["s"])


def test_groupby_string_min_max_vs_pandas(env, rng):
    # min/max of codes == lexicographic min/max of strings
    data = _sdata(rng)
    out = (rdf.read_numpy(data).groupby("v")
           .agg({"s": ["min", "max"]}).to_numpy())
    want = (pd.DataFrame(data).groupby("v")
            .agg(s_min=("s", "min"), s_max=("s", "max")).reset_index())
    _assert_same(out, {c: want[c].to_numpy() for c in want}, ["v"])


def test_string_sort_vs_pandas(env, rng):
    data = _sdata(rng)
    out = rdf.read_numpy(data).sort_values("s").to_numpy()
    np.testing.assert_array_equal(out["s"], np.sort(data["s"]))


def test_merge_same_dictionary_no_recode(env, rng):
    ld = _sdata(rng)
    rd = {"s": ld["s"].copy(), "w": rng.integers(0, 9, 128).astype(np.float32)}
    dl, dr = rdf.read_numpy(ld, name="l"), rdf.read_numpy(rd, name="r")
    m = dl.merge(dr, on="s", out_capacity=65536)
    assert "recode[" not in m.explain()
    want = pd.DataFrame(ld).merge(pd.DataFrame(rd), on="s")
    _assert_same(m.to_numpy(), {c: want[c].to_numpy() for c in want},
                 ["s", "v", "w"])


def test_merge_dictionary_mismatch_recodes(env, rng):
    ld = _sdata(rng, pool=POOL[:5])
    rd = {"s": rng.choice(np.asarray(POOL[3:]), 128),
          "w": rng.integers(0, 9, 128).astype(np.float32)}
    dl, dr = rdf.read_numpy(ld, name="l"), rdf.read_numpy(rd, name="r")
    m = dl.merge(dr, on="s", out_capacity=65536)
    text = m.explain()
    assert "recode[s:|D|=8]" in text
    assert "recode: join(s)" in text
    want = pd.DataFrame(ld).merge(pd.DataFrame(rd), on="s")
    _assert_same(m.to_numpy(), {c: want[c].to_numpy() for c in want},
                 ["s", "v", "w"])
    # the result dictionary is the merged (sorted-union) one
    assert m.collect().dictionaries["s"] == tuple(sorted(set(POOL)))


def test_stale_compiled_plan_rejects_different_dictionaries(env, rng):
    # recode tables + lowered literals are baked in at compile time; a
    # fingerprint-cached plan must not run against tables whose
    # dictionaries changed (it would decode fabricated strings)
    from repro.core import Plan
    from repro.planner import compile_plan, run_physical
    t1 = DistTable.from_numpy(
        {"s": np.asarray(["ash", "oak"]), "v": np.asarray([1, 2], np.int32)}, 1)
    t2 = DistTable.from_numpy(
        {"s": np.asarray(["elm", "yew"]), "v": np.asarray([1, 2], np.int32)}, 1)
    plan = Plan.scan("t").sort(["s"])
    pplan = compile_plan(plan, {"t": t1})
    out = run_physical(pplan, env, {"t": t1})        # matching: fine
    assert list(out.to_numpy()["s"]) == ["ash", "oak"]
    with pytest.raises(ValueError, match="differ from the ones this plan"):
        run_physical(pplan, env, {"t": t2})
    with pytest.raises(ValueError, match="differ from the ones this plan"):
        from repro.planner import run_morsel
        run_morsel(pplan, env, {"t": t2}, morsel_rows=8)


def test_compile_plan_does_not_mutate_logical_dag(env, rng):
    # recompiling a caller-held LogicalNode DAG against different
    # dictionaries must not reuse run-1 recode tables / lowered literals
    from repro.core import Plan
    from repro.planner import compile_plan, from_plan, run_physical
    mk = lambda ks: DistTable.from_numpy(
        {"s": np.asarray(ks), "v": np.arange(len(ks), dtype=np.int32)}, 1)
    plan = Plan.scan("l").join(Plan.scan("r"), on="s")
    t1 = {"l": mk(["ash", "oak"]), "r": mk(["elm", "oak"])}
    t2 = {"l": mk(["m", "p"]), "r": mk(["o", "p"])}
    node = from_plan(plan.node, {k: (("s", "v"), 2.0) for k in t1})
    compile_plan(node, t1)
    out = run_physical(compile_plan(node, t2), CylonEnv(), t2)
    assert list(out.to_numpy()["s"]) == ["p"]


def test_merge_string_key_vs_numeric_key_raises(env, rng):
    ld = _sdata(rng)
    rd = {"s": rng.integers(0, 8, 128).astype(np.int32),
          "w": rng.integers(0, 9, 128).astype(np.float32)}
    dl, dr = rdf.read_numpy(ld, name="l"), rdf.read_numpy(rd, name="r")
    with pytest.raises(TypeError, match="numeric key"):
        dl.merge(dr, on="s").collect()


def test_assign_string_passthrough_and_literal(env, rng):
    data = _sdata(rng)
    df = rdf.read_numpy(data).assign(s2=col("s"), tag=lit("hi"))
    out = df.to_numpy()
    np.testing.assert_array_equal(out["s2"], data["s"])
    assert set(out["tag"]) == {"hi"}


def test_string_arithmetic_raises_clearly(env, rng):
    df = rdf.read_numpy(_sdata(rng))
    with pytest.raises(TypeError, match="arithmetic"):
        df.assign(bad=df.s + 1).collect()
    with pytest.raises(TypeError, match="numeric value"):
        df[df.s > 3].collect()
    with pytest.raises(TypeError, match="not defined on the"):
        df.groupby("v").agg({"s": "sum"}).collect()


def test_out_of_core_string_pipeline_bit_identical(env, rng):
    data = _sdata(rng, n=256)
    pipe_args = dict(name="t")
    incore = (rdf.read_numpy(data, **pipe_args)
              [col("s") != "oak"]
              .groupby("s").agg({"v": ["sum", "count"]})
              .sort_values("s"))
    ref = incore.to_numpy()
    spill_df = rdf.read_numpy(data, spill=True, chunk_rows=32, **pipe_args)
    ooc = (spill_df[col("s") != "oak"]
           .groupby("s").agg({"v": ["sum", "count"]})
           .sort_values("s"))
    got, stats = ooc.collect(morsel_rows=32, collect_stats=True)
    assert stats.rows_dropped == 0
    raw = got.to_numpy()
    for c in ref:
        np.testing.assert_array_equal(ref[c], raw[c], err_msg=c)


# ---------------------------------------------------------------------- #
# EXPLAIN golden: the annotated example in docs/planner.md
# ---------------------------------------------------------------------- #
GOLDEN_RECODE = """\
== physical plan: 2 stages, 2 shuffles, mode=bsp, shuffle=radix/c1, fingerprint=54546f12dedd ==
stage 0:
  scan[l]                                      rows~      512  part=none         cols=k,v
  recode[k:|D|=6]                              rows~      512  part=none         cols=k,v
  filter[k < 4]                                rows~      256  part=none         cols=k,v
  scan[r]                                      rows~      512  part=none         cols=k,w
  recode[k:|D|=6]                              rows~      512  part=none         cols=k,w
  project[k]                                   rows~      512  part=none         cols=k
  join[on=k]                                   rows~      512  part=hash(k)      cols=k,v
stage 1:
  groupby[k; v:sum] (shuffle-elided)           rows~      460  part=hash(k)      cols=k,v_sum
rules fired:
  - recode: join(k) left input remapped onto the merged dictionary (|4| -> |6|)
  - recode: join(k) right input remapped onto the merged dictionary (|4| -> |6|)
  - shuffle-elision: groupby(k) runs local-only — input already hash(k)
  - predicate-pushdown: filter on (k) moved into join left input
  - projection-pushdown: drop [w] before join
  - projection-pushdown: drop [w] before groupby"""


def golden_recode_plan():
    """The docs/planner.md EXPLAIN example (keep the two in sync)."""
    from repro.core import Plan
    left = Plan.scan("l").join(Plan.scan("r"), on="k")
    return (left.filter(col("k") < "fir")
            .groupby(["k"], {"v": ["sum"]}))


GOLDEN_CAT = {
    "l": (("k", "v"), 512, {"k": ("ash", "birch", "cedar", "elm")}),
    "r": (("k", "w"), 512, {"k": ("cedar", "elm", "fir", "oak")}),
}


def test_explain_golden_recode():
    assert golden_recode_plan().explain(GOLDEN_CAT) == GOLDEN_RECODE


# ---------------------------------------------------------------------- #
# Hypothesis: random string pools vs pandas
# ---------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:
    _words = st.text(alphabet="abcdef", min_size=0, max_size=5)

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    @given(data=string_tables())
    def test_hypothesis_groupby_random_pools(env, data):
        out = (rdf.read_numpy(data).groupby("s")
               .agg({"v": ["sum", "count"]}).to_numpy())
        want = (pd.DataFrame(data).groupby("s")
                .agg(v_sum=("v", "sum"), v_count=("v", "count"))
                .reset_index())
        _assert_same(out, {c: want[c].to_numpy() for c in want}, ["s"])

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    @given(ld=string_tables(), rd=string_tables(value_col="w"))
    def test_hypothesis_merge_random_pools_forces_recode(env, ld, rd):
        dl = rdf.from_table(DistTable.from_numpy(dict(ld), 1), name="l")
        dr = rdf.from_table(DistTable.from_numpy(dict(rd), 1), name="r")
        m = dl.merge(dr, on="s", out_capacity=8192)
        want = pd.DataFrame(ld).merge(pd.DataFrame(rd), on="s")
        _assert_same(m.to_numpy(), {c: want[c].to_numpy() for c in want},
                     ["s", "v", "w"])

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    @given(data=string_tables(), pivot=_words)
    def test_hypothesis_ordering_vs_pandas(env, data, pivot):
        df = rdf.read_numpy(data)
        p = pd.DataFrame(data)
        for e, mask in [(df.s < pivot, p.s < pivot),
                        (df.s >= pivot, p.s >= pivot),
                        (df.s == pivot, p.s == pivot)]:
            _assert_same(df[e].to_numpy(),
                         {c: p[c][mask].to_numpy() for c in p}, ["s", "v"])

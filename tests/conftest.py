"""Shared fixtures.  NOTE: no XLA_FLAGS here — unit tests run on the plain
1-device CPU backend; multi-device coverage lives in subprocess scripts
under ``tests/md_scripts/`` (see ``test_multidevice.py``)."""

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "multidevice: 8-device subprocess integration scenario")


@pytest.fixture
def rng():
    return np.random.default_rng(0)

"""Sample-refreshed range splitters for the out-of-core sort path.

The morsel driver's original contract was one-shot: pool a small
evenly-spaced sample per rank before the segment runs, take ``p-1``
quantiles, and route every morsel with those splitters forever.  On
adversarial value distributions (all rows in one quantile bucket, sorted
input, heavy duplicates) the one-shot sample lands all traffic on one
rank and the segment degrades into overflow replays.

:class:`SplitterEstimator` keeps the same splitters *values* flowing
into the same compiled program (splitters are a runtime argument — the
program is keyed on shape/dtype, so a refresh never recompiles) but
watches the per-rank routed-row counts each morsel actually produced.
When the hottest rank's cumulative share exceeds ``imbalance_bound``
times its fair share (max / mean — median would hide a split where half
the ranks sit empty, and max/mean is capped at ``p`` so the bound stays
meaningful at small gang sizes) it re-samples with a ``refresh_boost``x
larger budget and swaps in the new splitters for subsequent morsels.

A mid-stream refresh intentionally breaks the range-disjointness
invariant (early morsels were routed by the old splitters), so the
driver MUST host-re-route the output spill by the *final* splitters
whenever ``refreshes > 0`` before the per-rank local sort.  The
estimator only decides; the driver owns the re-route.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import numpy as np

from .config import AdaptiveConfig

#: don't judge imbalance before this many routed rows have been seen
_MIN_OBSERVED = 256


class SplitterEstimator:
    """Refreshable splitter source for one sort segment.

    ``sample_fn(samples)`` re-pools from the segment's input spill and
    returns a fresh ``(p-1,)`` splitter array — supplied by the driver so
    this module stays free of spill-layout knowledge.
    """

    def __init__(self, splitters: np.ndarray,
                 sample_fn: Callable[[int], np.ndarray],
                 samples: int, cfg: AdaptiveConfig,
                 events: Optional[List[Dict[str, Any]]] = None,
                 label: str = ""):
        self.splitters = splitters
        self._sample_fn = sample_fn
        self._samples = samples
        self._cfg = cfg
        self._events = events
        self._label = label
        self.refreshes = 0
        p = len(splitters) + 1
        self._routed = np.zeros(p, np.int64)

    @property
    def enabled(self) -> bool:
        return bool(self._cfg.enabled and self._cfg.splitter_refresh)

    def imbalance(self) -> float:
        """Hottest rank's routed rows over the fair (mean) share, since
        the last refresh."""
        mean = float(self._routed.mean())
        return float(self._routed.max()) / max(mean, 1.0)

    def observe(self, row_counts: np.ndarray) -> bool:
        """Feed one morsel's per-rank routed rows; True iff this call
        triggered a refresh (so the driver can log / count it)."""
        self._routed += np.asarray(row_counts, np.int64)
        if (not self.enabled
                or self.refreshes >= self._cfg.max_refreshes
                or int(self._routed.sum()) < _MIN_OBSERVED
                or self.imbalance() <= self._cfg.imbalance_bound):
            return False
        seen = self.imbalance()
        self._samples *= max(2, self._cfg.refresh_boost)
        fresh = self._sample_fn(self._samples)
        if fresh is None or np.array_equal(fresh, self.splitters):
            # a bigger sample told the same story: the imbalance is the
            # data, not the sample — stop burning refresh budget on it
            self.refreshes = self._cfg.max_refreshes
            return False
        self.splitters = fresh
        self.refreshes += 1
        self._routed[:] = 0
        if self._events is not None:
            self._events.append({"kind": "splitter_refresh",
                                 "label": self._label,
                                 "imbalance": round(seen, 3),
                                 "samples": self._samples,
                                 "refresh": self.refreshes})
        return True

"""Morsel-size autotuning for ``overflow="degrade"``.

PR 7's degrade loop was blind: on any overflow it halved the segment's
morsel rows (or, once at the floor, doubled the shuffle capacity) and
replayed — each attempt a fresh compile.  The overflow report already
says *how far* over capacity the hot rank landed; :class:`MorselTuner`
uses it to jump straight to a morsel size that fits:

    peak ≈ W + max per-rank dropped rows        (from the stat triples)
    M'   = round8(M · (W / peak) · margin)

so a 10x overflow costs one replay, not four.  Two refinements:

* **no double-split** — a segment that salting already rebalanced but
  which still overflows (e.g. the capacity estimate was simply too
  small) must not also shrink its morsels; the tuner grows ``W`` to the
  observed peak instead, keeping the salted routing intact;
* **expansion carry-over** — segments that blow up row counts (joins)
  report their observed output/input expansion; the next segment's
  *initial* morsel size is pre-shrunk when the expansion exceeds the
  capacity factor, avoiding the first overflow entirely.

With ``autotune`` off the driver falls back to
``faults.default_degrade_step`` — the original blind halving, preserved
verbatim so ``adaptive=False`` replays are bit-identical to PR 7.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .config import AdaptiveConfig


def _round8(x: float) -> int:
    return max(8, -(-int(x) // 8) * 8)


class MorselTuner:
    """Per-run controller for degrade replays and initial morsel sizing."""

    def __init__(self, cfg: AdaptiveConfig, capacity_factor: float = 2.0,
                 events: Optional[List[Dict[str, Any]]] = None):
        self._cfg = cfg
        self._capacity_factor = max(capacity_factor, 1.0)
        self._events = events
        self.steps = 0          # surfaces as ExecStats.autotune_steps
        self._expansion = 1.0   # max observed out/in row expansion

    @property
    def enabled(self) -> bool:
        return bool(self._cfg.enabled and self._cfg.autotune)

    # -- expansion carry-over ------------------------------------------- #
    def observe_expansion(self, in_rows: int, out_rows: int) -> None:
        """Record a finished segment's row expansion (joins > 1.0)."""
        if in_rows > 0:
            self._expansion = max(self._expansion, out_rows / in_rows)

    def initial_morsel(self, m0: int) -> int:
        """Initial morsel rows for the next segment, pre-shrunk when the
        observed expansion would overflow ``W = factor * m0`` anyway."""
        if not self.enabled or self._expansion <= self._capacity_factor:
            return m0
        return min(m0, _round8(m0 * self._capacity_factor / self._expansion))

    # -- degrade replanning --------------------------------------------- #
    @staticmethod
    def _peak_drop(stat_arrays: Sequence[np.ndarray]) -> int:
        """Worst per-rank dropped-row count across the attempt's shuffle
        stat triples ``(p, 3) = [rows, bytes, dropped]``."""
        worst = 0
        for arr in stat_arrays:
            a = np.asarray(arr)
            if a.ndim == 2 and a.shape[1] >= 3:
                worst = max(worst, int(a[:, 2].max()))
        return worst

    def degrade(self, m_seg: int, w_seg: int,
                stat_arrays: Sequence[np.ndarray],
                salted: bool = False, label: str = ""
                ) -> Tuple[int, int]:
        """Pick the next ``(morsel_rows, capacity)`` after an overflow."""
        peak = w_seg + self._peak_drop(stat_arrays)
        if salted:
            # the routing is already balanced — splitting morsels would
            # recompile every salted program for no routing benefit;
            # grow the capacity to the observed peak instead
            m_new, w_new = m_seg, _round8(peak * 1.25)
            how = "grow-capacity"
        elif m_seg <= 8:
            m_new, w_new = m_seg, _round8(w_seg * 2)
            how = "grow-capacity"
        else:
            m_new = _round8(m_seg * (w_seg / peak) * self._cfg.autotune_margin)
            if m_new >= m_seg:   # estimate said "fits" but it didn't
                m_new = _round8(m_seg // 2)
            m_new = max(8, m_new)
            w_new = w_seg
            how = "shrink-morsel"
        self.steps += 1
        if self._events is not None:
            self._events.append({"kind": "autotune", "label": label,
                                 "how": how, "peak": int(peak),
                                 "morsel_rows": [int(m_seg), int(m_new)],
                                 "capacity": [int(w_seg), int(w_new)]})
        return m_new, w_new

"""Hot-key detection + salting decisions (driver-side).

Detection never runs on device: the driver samples the key columns of a
shuffle boundary's input (evenly spaced over valid rows, nulls excluded
— they are dropped or never match anyway), hashes the sample with
``hash_columns_np`` (the bit-identical numpy twin of the device hash, so
a "hot hash" here is exactly a hot destination there), and declares a
key *hot* when its sampled frequency exceeds ``hot_key_factor / p`` —
``factor``x its fair share of one rank's rows.

A fired decision is a :class:`SaltDecision`:

* ``groupby`` — hot rows are spread over ``k`` consecutive ranks
  (``(hash % p + arange % k) % p``); partials for a hot key then live on
  ``k`` ranks and are re-merged on the key's home rank (a second, tiny
  shuffle in-core; a host re-route of the partial spill out-of-core);
* ``join`` — hot *build* rows are excluded from the hash shuffle and
  broadcast to every rank (``replicate_hot_rows``); hot *probe* rows
  skip the wire entirely and stay on their source rank.

Decisions are plan-structural facts plus data-dependent constants; the
executors append ``SaltDecision.cache_token()`` to their compile-cache
keys **only when a decision fired**, so ``adaptive=True`` on well-behaved
data compiles the exact same programs as ``adaptive=False``.

The input of a boundary is not materialized before execution, so
sampling *chases* the boundary's streamed input back to a scan through
ops that preserve the key columns' row multiset
(``planner.logical.preserves_rows_and_columns``); a chase that fails
(filter, recode, another boundary, ...) simply disables salting for that
node — the degrade path still guarantees no row is ever lost.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..dataframe.ops_local import hash_columns_np
from ..nulls import mask_name
from .config import AdaptiveConfig

#: never salt from a sample smaller than this (frequencies too noisy)
_MIN_SAMPLE = 32
#: build sides larger than this are counted from a sample (x2 slack)
#: instead of an exact host hash pass
_EXACT_COUNT_LIMIT = 2_000_000


@dataclasses.dataclass(frozen=True)
class SaltDecision:
    """One fired salting decision at one shuffle boundary."""

    kind: str                     # "groupby" | "join"
    keys: Tuple[str, ...]         # key columns the boundary hashes on
    hot_hashes: Tuple[int, ...]   # uint32 hash values declared hot
    k: int = 1                    # groupby: sub-partitions per hot key
    hot_cap: int = 0              # join: broadcast buffer rows per rank
    node_index: int = -1          # topo index (node-identity independent)

    def cache_token(self) -> Tuple:
        """What the compile-cache key carries for this decision.  Uses the
        topo index, not the nid, so two identically-shaped plans share
        compiled salted programs."""
        return (self.node_index, self.kind, self.keys, self.hot_hashes,
                self.k, self.hot_cap)

    def note(self) -> str:
        """The EXPLAIN ANALYZE annotation (``docs/adaptive.md``)."""
        if self.kind == "groupby":
            return f"salted[k:{self.k}, hot:{len(self.hot_hashes)}]"
        return (f"salted[broadcast, hot:{len(self.hot_hashes)}, "
                f"cap:{self.hot_cap}]")


# ---------------------------------------------------------------------- #
# Host-side sampling over any table-ish execute() input
# ---------------------------------------------------------------------- #
def _host_key_rows(table: Any, cols: Sequence[str],
                   limit: Optional[int]) -> Optional[Dict[str, np.ndarray]]:
    """Valid, non-null-key rows of ``cols`` as host numpy arrays.

    Accepts a ``DistTable`` (valid per-rank prefixes), a ``SpillTable``
    (rank chunks), or a raw numpy column mapping; returns ``None`` when a
    column is missing.  ``limit`` bounds the rows *pulled per rank* so a
    detection pass never transfers more than it needs."""
    want = list(cols) + [mask_name(c) for c in cols]

    def finish(parts: Dict[str, List[np.ndarray]]) -> Dict[str, np.ndarray]:
        out = {c: (np.concatenate(parts[c]) if parts[c]
                   else np.zeros((0,), np.int32)) for c in parts}
        keep = None
        for c in cols:
            m = out.get(mask_name(c))
            if m is not None:
                m = m.astype(bool)
                keep = m if keep is None else (keep & m)
        if keep is not None:
            out = {c: v[keep] for c, v in out.items()}
        return {c: out[c] for c in cols}

    if hasattr(table, "row_counts") and hasattr(table, "capacity"):
        if any(c not in table.columns for c in cols):
            return None
        counts = np.asarray(table.row_counts)
        cap = table.capacity
        parts: Dict[str, List[np.ndarray]] = {c: [] for c in want
                                              if c in table.columns}
        host = {c: np.asarray(table.columns[c]) for c in parts}
        for r in range(table.parallelism):
            n = int(counts[r])
            take = n if limit is None else min(n, limit)
            if take:
                idx = r * cap + (np.arange(take) * n) // max(take, 1)
                for c in parts:
                    parts[c].append(host[c][idx])
        return finish(parts)

    if hasattr(table, "rank_concat"):  # SpillTable
        if any(c not in table.column_names for c in cols):
            return None
        parts = {c: [] for c in want if c in table.column_names}
        for r in range(table.parallelism):
            cols_r = table.rank_concat(r)
            n = len(next(iter(cols_r.values()))) if cols_r else 0
            take = n if limit is None else min(n, limit)
            if take:
                idx = (np.arange(take) * n) // max(take, 1)
                for c in parts:
                    parts[c].append(cols_r[c][idx])
        return finish(parts)

    if isinstance(table, Mapping):
        if any(c not in table for c in cols):
            return None
        parts = {}
        for c in want:
            if c in table:
                arr = np.asarray(table[c])
                n = len(arr)
                take = n if limit is None else min(n, limit)
                idx = (np.arange(take) * n) // max(take, 1)
                parts[c] = [arr[idx]]
        return finish(parts)
    return None


def sample_key_columns(table: Any, cols: Sequence[str],
                       cfg: AdaptiveConfig
                       ) -> Optional[Dict[str, np.ndarray]]:
    """Evenly-spaced detection sample of ``cols`` (nulls excluded)."""
    return _host_key_rows(table, cols, limit=max(1, cfg.sample_rows))


# ---------------------------------------------------------------------- #
# Detection
# ---------------------------------------------------------------------- #
def detect_hot_keys(sampled: Optional[Mapping[str, np.ndarray]],
                    key_cols: Sequence[str], p: int,
                    cfg: AdaptiveConfig) -> Tuple[int, ...]:
    """Hot key *hashes* in a sample: frequency above ``factor/p`` (capped
    at 50% so small gangs can still fire), top ``max_hot_keys`` by count.

    Working on hashes rather than values keeps detection dtype-agnostic
    and exactly aligned with the device routing; a hash collision at
    worst salts one extra (cold) key, which stays correct."""
    if sampled is None or p <= 1 or not cfg.salting:
        return ()
    h = hash_columns_np(dict(sampled), list(key_cols))
    n = len(h)
    if n < _MIN_SAMPLE:
        return ()
    frac = min(0.5, cfg.hot_key_factor / p)
    thresh = max(4, int(np.ceil(n * frac)))
    vals, counts = np.unique(h, return_counts=True)
    order = np.argsort(counts)[::-1][:cfg.max_hot_keys]
    return tuple(sorted(int(vals[i]) for i in order
                        if counts[i] >= thresh))


def _count_hot_rows(table: Any, key_cols: Sequence[str],
                    hot: Tuple[int, ...], total_rows: int) -> Optional[int]:
    """How many of ``table``'s rows carry a hot key hash.

    Exact (full host hash pass) for modest tables; estimated from a
    bounded sample with 2x slack beyond ``_EXACT_COUNT_LIMIT`` rows."""
    exact = total_rows <= _EXACT_COUNT_LIMIT
    rows = _host_key_rows(table, key_cols,
                          limit=None if exact else 65536)
    if rows is None:
        return None
    h = hash_columns_np(dict(rows), list(key_cols))
    if not len(h):
        return 0
    got = int(np.isin(h, np.asarray(sorted(hot), h.dtype)).sum())
    if exact:
        return got
    return int(np.ceil(2.0 * got * total_rows / len(h)))


def _table_rows(table: Any) -> int:
    if hasattr(table, "total_rows"):
        try:
            return int(table.total_rows())
        except TypeError:
            pass
    if isinstance(table, Mapping) and table:
        return len(np.asarray(next(iter(table.values()))))
    return 0


def _chase_scan(node, cols: Sequence[str]):
    """Walk ``inputs[0]`` to a scan through key-preserving ops (or None)."""
    from ..planner.logical import preserves_rows_and_columns
    n = node
    while n.op != "scan":
        if not preserves_rows_and_columns(n, cols):
            return None
        n = n.inputs[0]
    return n


def _round8(x: int) -> int:
    return max(8, -(-int(x) // 8) * 8)


# ---------------------------------------------------------------------- #
# Per-plan decision pass (shared by the in-core and morsel drivers)
# ---------------------------------------------------------------------- #
def plan_salt_decisions(order: Sequence[Any], tables: Mapping[str, Any],
                        p: int, cfg: AdaptiveConfig,
                        events: Optional[List[Dict[str, Any]]] = None
                        ) -> Dict[int, SaltDecision]:
    """Detect skew at every salting candidate of a lowered plan.

    ``order`` is the plan's topo order; returns ``{nid: SaltDecision}``
    for the candidates where detection fired.  Purely driver-side: an
    empty result leaves execution (and every compile-cache key) exactly
    as ``adaptive=False`` would."""
    from ..planner.rules import skew_candidates
    out: Dict[int, SaltDecision] = {}
    if p <= 1 or not (cfg.enabled and cfg.salting):
        return out
    index = {n.nid: i for i, n in enumerate(order)}
    for node in skew_candidates(order):
        keys = (list(node.params["keys"]) if node.op == "groupby"
                else [node.params["on"]])
        scan = _chase_scan(node.inputs[0], keys)
        if scan is None:
            continue
        src = tables.get(scan.params["name"])
        if src is None or _table_rows(src) < cfg.min_table_rows:
            continue
        hot = detect_hot_keys(sample_key_columns(src, keys, cfg),
                              keys, p, cfg)
        if not hot:
            continue
        if node.op == "groupby":
            d = SaltDecision("groupby", tuple(keys), hot,
                             k=min(p, cfg.salt_k or p),
                             node_index=index[node.nid])
        else:
            bscan = _chase_scan(node.inputs[1], keys)
            if bscan is None:
                continue
            build = tables.get(bscan.params["name"])
            if build is None:
                continue
            n_hot = _count_hot_rows(build, keys, hot, _table_rows(build))
            if n_hot is None or n_hot > cfg.max_broadcast_rows:
                continue
            d = SaltDecision("join", tuple(keys), hot,
                             hot_cap=_round8(n_hot + 8),
                             node_index=index[node.nid])
        out[node.nid] = d
        if events is not None:
            events.append({"kind": "salted", "op": node.op,
                           "node_index": d.node_index,
                           "keys": list(d.keys),
                           "hot_keys": len(d.hot_hashes), "k": d.k,
                           "hot_cap": d.hot_cap})
    return out


def salt_cache_token(salt: Mapping[int, SaltDecision],
                     nids: Optional[Sequence[int]] = None) -> Tuple:
    """Compile-cache key suffix for the decisions covering ``nids`` (all
    when None).  Empty tuple when nothing fired — the no-new-keys case."""
    picked = sorted((d.cache_token() for nid, d in salt.items()
                     if nids is None or nid in set(nids)))
    return ("salt",) + tuple(picked) if picked else ()

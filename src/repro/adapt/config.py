"""The ``adaptive=`` knob: one frozen config for all three mitigations.

``resolve_adaptive`` normalizes what executors accept::

    adaptive=None            -> defaults (enabled)
    adaptive=True / False    -> enabled / disabled wholesale
    adaptive={"salt_k": 4}   -> defaults with overrides
    adaptive=AdaptiveConfig  -> passes through

Feature toggles (``salting`` / ``splitter_refresh`` / ``autotune``) turn
individual mitigations off while keeping the rest; thresholds are
documented in ``docs/adaptive.md``.
"""

from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Knobs for runtime skew mitigation (``repro.adapt``)."""

    #: master switch; ``False`` disables every mitigation (and, by
    #: construction, leaves every compile-cache key untouched)
    enabled: bool = True
    # -- hot-key salting -------------------------------------------------- #
    salting: bool = True
    #: a key is *hot* when its sampled frequency exceeds
    #: ``hot_key_factor / p`` (i.e. ``factor``x its fair share of rows)
    hot_key_factor: float = 2.0
    #: at most this many distinct hot keys are salted per shuffle boundary
    max_hot_keys: int = 8
    #: sub-partitions a hot key is spread over for groupby salting;
    #: 0 = auto (the gang size ``p``)
    salt_k: int = 0
    #: detection sample size (driver-side, evenly spaced over valid rows)
    sample_rows: int = 4096
    #: tables smaller than this never trigger salting (skew on tiny
    #: inputs is not worth a second shuffle / a broadcast)
    min_table_rows: int = 256
    #: broadcast-join cap: if the *build* side holds more hot rows than
    #: this, replication would cost more than the skew, so don't salt
    max_broadcast_rows: int = 65536
    # -- sample-refreshed range splitters (out-of-core sort) -------------- #
    splitter_refresh: bool = True
    #: refresh when the hottest rank's observed routed-rows share exceeds
    #: this multiple of the fair (mean) share
    imbalance_bound: float = 1.5
    #: sample-budget multiplier applied on each refresh
    refresh_boost: int = 4
    #: refreshes per sort segment (each forces one host re-route pass)
    max_refreshes: int = 2
    # -- morsel-size autotuning (overflow="degrade") ---------------------- #
    autotune: bool = True
    #: safety margin under the capacity implied by the observed overflow
    autotune_margin: float = 0.9

    def token(self):
        """Stable value tuple (used in adapt-event reporting only — the
        compile cache keys on fired *decisions*, never on the config)."""
        return tuple(getattr(self, f.name)
                     for f in dataclasses.fields(self))


#: the everything-off config ``adaptive=False`` resolves to
DISABLED = AdaptiveConfig(enabled=False, salting=False,
                          splitter_refresh=False, autotune=False)


def resolve_adaptive(adaptive: Any) -> AdaptiveConfig:
    """Normalize the ``adaptive=`` argument to an ``AdaptiveConfig``."""
    if adaptive is None or adaptive is True:
        return AdaptiveConfig()
    if adaptive is False:
        return DISABLED
    if isinstance(adaptive, AdaptiveConfig):
        return adaptive
    if isinstance(adaptive, dict):
        unknown = set(adaptive) - {f.name
                                   for f in dataclasses.fields(AdaptiveConfig)}
        if unknown:
            raise TypeError(f"unknown adaptive= keys: {sorted(unknown)}")
        return AdaptiveConfig(**adaptive)
    raise TypeError(f"adaptive= must be None/bool/dict/AdaptiveConfig, "
                    f"got {type(adaptive).__name__}")

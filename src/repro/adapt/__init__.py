"""Runtime skew mitigation (``docs/adaptive.md``).

The planner trusts compile-time partitioning; production key
distributions do not return the favor.  ``repro.adapt`` threads three
runtime mitigations through both executors, all gated by the
``adaptive=`` knob (default on, keyed into the compile cache only when a
mitigation actually fires — a run where nothing fires compiles the exact
same programs as ``adaptive=False``):

* **hot-key salting** (``hotkeys``) — a cheap driver-side sample pass at
  shuffle boundaries detects keys whose frequency would overwhelm one
  rank; hot keys are salted into ``k`` sub-partitions for groupby
  (partials re-merged on their home rank) and broadcast-joined for join
  (hot build rows replicated, hot probe rows kept local);
* **sample-refreshed range splitters** (``splitters``) — the
  out-of-core sort path's one-shot splitter sample becomes a refreshable
  estimator that re-samples with a larger budget when observed per-rank
  imbalance exceeds a bound, re-routing subsequent morsels;
* **morsel autotuning** (``autotune``) — ``overflow="degrade"``'s blind
  morsel halving is replaced by a controller that picks ``morsel_rows``
  from the observed overflow magnitude and spill/H2D expansion ratios,
  per segment.

Every mitigation is proven bit-identical to the non-adaptive path by
``tests/test_skew.py`` / ``tests/md_scripts/skew_parity.py``.
"""

from .autotune import MorselTuner
from .config import AdaptiveConfig, resolve_adaptive
from .hotkeys import (SaltDecision, detect_hot_keys, plan_salt_decisions,
                      sample_key_columns)
from .splitters import SplitterEstimator

__all__ = [
    "AdaptiveConfig", "resolve_adaptive",
    "SaltDecision", "detect_hot_keys", "plan_salt_decisions",
    "sample_key_columns",
    "SplitterEstimator", "MorselTuner",
]

"""Process-level compiled-program cache with single-flight builds.

The structural-fingerprint compile cache used to live on each ``CylonEnv``,
which meant a *freshly carved* gang (a new env over a leased device
partition, the serving scheduler's normal mode of operation) always paid
full trace+compile cost even for a query the process had compiled a
thousand times before.  ``ProgramCache`` hoists that storage to process
level: entries are keyed by

    (program key, gang signature)

where the program key is whatever the env submission layer uses today
(the structural plan fingerprint + mode/communicator/shuffle knobs), and
the gang signature pins the *placement* — backend platform, device ids,
axis name — because a compiled ``shard_map`` program is bound to its mesh.
Two gangs carved over the same devices (the common case under the
``DevicePool`` free-list, which hands out lowest-ids-first so released
partitions are re-carved identically) therefore share one compiled
program; gangs over different devices correctly compile their own.

Builds are **single-flight**: when two threads race the same key, exactly
one runs the builder while the rest wait on the entry's event and then
reuse the result.  A failed build clears the entry so a later caller can
retry (waiters of a failed build re-enter the loop and may become the new
builder).

``GLOBAL_PROGRAM_CACHE`` is the process-wide instance the serving
scheduler wires into every gang it carves; ``CylonEnv`` defaults to a
private instance so single-env semantics (and the existing cache-counter
tests) are unchanged.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Optional, Tuple

__all__ = ["ProgramCache", "GLOBAL_PROGRAM_CACHE"]


class _Entry:
    __slots__ = ("event", "value", "ready")

    def __init__(self):
        self.event = threading.Event()
        self.value = None
        self.ready = False


class ProgramCache:
    """Thread-safe map ``key -> compiled program`` with single-flight
    population and hit/miss/wait counters.

    ``registry``: a ``repro.obs.MetricsRegistry`` (default: the process
    registry) receiving ``program_cache_*`` counters; pass ``False`` to
    disable metric export (micro-tests).
    """

    def __init__(self, registry: Any = None):
        self._lock = threading.Lock()
        self._entries: Dict[Any, _Entry] = {}
        #: cumulative counters (also exported to the metrics registry)
        self.hits = 0
        self.misses = 0
        self.singleflight_waits = 0
        if registry is False:
            self._registry = None
        else:
            from ..obs.metrics import METRICS
            self._registry = registry if registry is not None else METRICS

    def _count(self, what: str) -> None:
        if self._registry is not None:
            self._registry.counter(
                f"program_cache_{what}_total",
                f"shared program-cache {what.replace('_', ' ')}").inc()

    def get_or_build(self, key: Any, builder: Callable[[], Any]
                     ) -> Tuple[Any, bool]:
        """Return ``(program, built)``: the cached program for ``key``,
        building it via ``builder()`` at most once per key across all
        threads.  ``built`` is True iff *this* call ran the builder."""
        while True:
            with self._lock:
                entry = self._entries.get(key)
                if entry is None:
                    entry = self._entries[key] = _Entry()
                    owner = True
                elif entry.ready:
                    self.hits += 1
                    self._count("hits")
                    return entry.value, False
                else:
                    owner = False
                    self.singleflight_waits += 1
            if owner:
                try:
                    value = builder()
                except BaseException:
                    with self._lock:
                        # clear the failed entry so a later caller retries
                        if self._entries.get(key) is entry:
                            del self._entries[key]
                        self.misses += 1
                    self._count("misses")
                    entry.event.set()
                    raise
                with self._lock:
                    entry.value = value
                    entry.ready = True
                    self.misses += 1
                entry.event.set()
                self._count("misses")
                return value, True
            self._count("singleflight_waits")
            entry.event.wait()
            # entry is either ready (common) or was cleared by a failed
            # build — loop to re-read under the lock (and maybe rebuild)

    def peek(self, key: Any) -> Optional[Any]:
        """The cached program for ``key`` or None (never builds/waits)."""
        with self._lock:
            entry = self._entries.get(key)
            return entry.value if entry is not None and entry.ready else None

    def __len__(self) -> int:
        with self._lock:
            return sum(1 for e in self._entries.values() if e.ready)

    def __contains__(self, key: Any) -> bool:
        with self._lock:
            e = self._entries.get(key)
            return e is not None and e.ready

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"entries": sum(1 for e in self._entries.values()
                                   if e.ready),
                    "hits": self.hits, "misses": self.misses,
                    "singleflight_waits": self.singleflight_waits}

    def clear(self) -> None:
        """Drop all completed entries (in-flight builds finish into the
        void: their owners still return the built program)."""
        with self._lock:
            done = [k for k, e in self._entries.items() if e.ready]
            for k in done:
                del self._entries[k]


#: the process-level cache the serving scheduler shares across every gang
#: it carves — the "thousandth user's query compiles nothing" cache
GLOBAL_PROGRAM_CACHE = ProgramCache()

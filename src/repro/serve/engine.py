"""Minimal batched serving engine: prefill → synchronized decode.

Host-side driver over the model's ``prefill`` / ``decode_step``:

* fixed-size request batches with a shared prompt length per batch, which
  matches the framework's uniform-position decode contract (``pos``
  identical across the batch; see ``transformer.decode_step``),
* greedy or temperature sampling,
* stop on EOS or ``max_new_tokens``.

The jitted step is cached on the engine (stateful reuse — the same
pseudo-BSP idea the paper applies to dataframe operators: initialize the
environment once, submit many steps).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer
from ..models.config import ModelConfig
from ..models.layers import NO_SHARDING, ShardingRules


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, <=max_new_tokens)
    steps: int
    prefill_len: int


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, cache_len: int,
                 rules: ShardingRules = NO_SHARDING,
                 eos_id: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.cache_len = cache_len
        self.rules = rules
        self.eos_id = eos_id
        self._prefill = jax.jit(
            lambda p, b: transformer.prefill(p, cfg, b, cache_len, rules))
        self._decode = jax.jit(
            lambda p, c, t, pos: transformer.decode_step(p, cfg, c, t, pos,
                                                         rules),
            donate_argnums=(1,))   # KV caches update in place

    def _sample(self, logits: jax.Array, key, temperature: float):
        if temperature == 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / temperature, axis=-1)

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32,
                 temperature: float = 0.0, seed: int = 0
                 ) -> GenerationResult:
        """prompts: (B, S0) int32 (or (B, S0, K) for audio)."""
        cfg = self.cfg
        prompts = jnp.asarray(prompts, jnp.int32)
        b, s0 = prompts.shape[0], prompts.shape[1]
        assert s0 + max_new_tokens <= self.cache_len
        batch = {"tokens": prompts}
        logits, caches = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(seed)

        out: List[jax.Array] = []
        finished = np.zeros((b,), bool)
        tok = None
        for step in range(max_new_tokens):
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub, temperature)       # (B,) / (B,K)
            out.append(tok)
            if self.eos_id is not None:
                finished |= np.asarray(tok).reshape(b, -1)[:, 0] == self.eos_id
                if finished.all():
                    break
            pos = jnp.full((b,), s0 + step, jnp.int32)
            step_tok = tok.reshape((b, 1) if tok.ndim == 1 else (b, 1, -1))
            logits, caches = self._decode(self.params, caches, step_tok, pos)
        tokens = np.stack([np.asarray(t) for t in out], axis=1)
        return GenerationResult(tokens=tokens, steps=len(out),
                                prefill_len=s0)

"""Driver-side multi-query scheduler: many ``collect()``s, one cluster.

The paper's core move (§IV-A) is running the BSP dataframe engine *inside*
a generic executor so many independent applications share one set of
resources — CylonFlow partitions a Dask/Ray cluster into gangs and serves
jobs onto them.  ``QueryScheduler`` is that driver: it owns a
``core.env.DevicePool``, carves **per-query gangs** (a fresh ``CylonEnv``
over a leased, disjoint device partition) of configurable ``gang_size``,
executes each admitted query on a worker thread, and hands back
``Future``-style ``QueryHandle``s::

    sched = QueryScheduler(gang_size=2, max_inflight=4)
    h = sched.submit(df)            # non-blocking
    out = h.result(timeout=30.0)    # DistTable, bit-identical to df.collect()

    with rdf.session(scheduler=sched):
        out = df.collect()          # routed: submit + handle.result()

Admission control: at most ``max_inflight`` queries execute concurrently
(one worker thread each); up to ``max_queue`` more wait in FIFO order;
past that, ``submit`` raises ``AdmissionRejected`` immediately (shed load
at the door, don't time out in the hall).  Every query gets a
``repro.faults.CancellationToken`` — armed with ``timeout`` (submit
argument, else the scheduler default) and parented on a scheduler-wide
token — whose deadline covers *queue wait plus execution*; ``cancel()``
works mid-queue (the entry is unlinked and completes immediately with
``QueryCancelled``) and mid-flight (cooperative, at the executors' check
points).  ``close(cancel_pending=True)`` cancels everything via the
parent token.

Compiled programs are shared across gangs through a process-level
``ProgramCache`` (``repro.serve.cache``): a freshly carved gang over
devices an earlier gang already used reuses every compiled program — the
repeat query compiles nothing (``handle.stats["cache_misses"] == 0``).

Everything here is driver-side threading; device work stays the same
compiled pseudo-BSP programs as single-query execution, which is why
concurrent results are bit-identical to sequential runs.
"""

from __future__ import annotations

import collections
import itertools
import threading
import time
from typing import Any, Deque, Dict, List, Optional

from ..core.env import CylonEnv, DevicePool
from ..faults import CancellationToken, QueryCancelled, QueryTimeout
from ..obs.metrics import METRICS, record_serve_query
from .cache import GLOBAL_PROGRAM_CACHE, ProgramCache

__all__ = ["AdmissionRejected", "QueryHandle", "QueryScheduler"]

_seq = itertools.count()


class AdmissionRejected(RuntimeError):
    """``submit`` refused: queue and inflight capacity are both full."""


class _Item:
    __slots__ = ("handle", "frame", "kw", "gang_size")

    def __init__(self, handle, frame, kw, gang_size):
        self.handle = handle
        self.frame = frame
        self.kw = kw
        self.gang_size = gang_size


class QueryHandle:
    """Future-style handle for one submitted query.

    ``stats`` is a live dict the scheduler updates as the query moves
    ``queued -> running -> done|failed|cancelled``: submit/start/finish
    wall-clock timestamps, queue wait, execution wall time, the gang's
    device ids, and the per-query compile-cache traffic.
    """

    def __init__(self, scheduler: "QueryScheduler", label: str,
                 token: CancellationToken):
        self._scheduler = scheduler
        self._event = threading.Event()
        self._result: Any = None
        self._exception: Optional[BaseException] = None
        self.label = label
        self.token = token
        self.stats: Dict[str, Any] = {
            "label": label, "state": "queued",
            "submitted_at": time.time(),
            "submitted_monotonic": time.monotonic(),
        }

    # -- completion ------------------------------------------------------ #
    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        """Block until the query finishes and return what ``collect``
        returned (re-raising its error).  ``timeout`` bounds *this wait*,
        not the query — on expiry the query keeps running and ``result``
        raises ``TimeoutError``."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"query {self.label!r} not finished after {timeout}s "
                f"(state: {self.stats['state']})")
        if self._exception is not None:
            raise self._exception
        return self._result

    def exception(self, timeout: Optional[float] = None
                  ) -> Optional[BaseException]:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"query {self.label!r} not finished after {timeout}s")
        return self._exception

    def cancel(self, reason: str = "") -> bool:
        """Cancel the query: a queued entry completes immediately with
        ``QueryCancelled``; a running one is cancelled cooperatively at
        the executors' next token check.  Returns False if the query had
        already finished."""
        if self.done():
            return False
        self.token.cancel(reason or f"handle.cancel() on {self.label!r}")
        self._scheduler._cancel_queued(self)
        return True

    def __repr__(self) -> str:
        return f"<QueryHandle {self.label!r} {self.stats['state']}>"


class QueryScheduler:
    """Admit many concurrent queries onto gangs carved from one pool.

    Parameters
    ----------
    pool:          a ``DevicePool`` to carve gangs from (default: a fresh
                   pool over all local devices).  The pool may be shared
                   with non-scheduler users; the scheduler only blocks on
                   its own reservations.
    gang_size:     devices per query gang (default 1).  Ingests made
                   inside ``session(scheduler=...)`` partition for this.
    max_inflight:  concurrently executing queries (default: pool size //
                   gang_size — every gang busy).
    max_queue:     queued submissions past that before ``submit`` raises
                   ``AdmissionRejected`` (default 64; 0 = no queueing).
    timeout:       default per-query deadline in seconds, covering queue
                   wait + execution (``submit(timeout=...)`` overrides).
    communicator:  communicator for carved gangs ("xla" | "ring" | "bruck").
    program_cache: the shared ``ProgramCache`` (default: the process-level
                   ``GLOBAL_PROGRAM_CACHE``).
    name:          label for metrics/threads (default "serve").
    """

    def __init__(self, pool: Optional[DevicePool] = None,
                 devices: Optional[List[Any]] = None,
                 gang_size: int = 1,
                 max_inflight: Optional[int] = None,
                 max_queue: int = 64,
                 timeout: Optional[float] = None,
                 communicator: str = "xla",
                 program_cache: Optional[ProgramCache] = None,
                 registry: Any = None,
                 name: str = "serve"):
        if pool is not None and devices is not None:
            raise TypeError("pass either pool= or devices=, not both")
        self.pool = pool if pool is not None else DevicePool(devices)
        if gang_size < 1 or gang_size > self.pool.size:
            raise ValueError(
                f"gang_size {gang_size} not in [1, pool size "
                f"{self.pool.size}]")
        self.gang_size = gang_size
        capacity = max(1, self.pool.size // gang_size)
        self.max_inflight = (capacity if max_inflight is None
                             else max(1, int(max_inflight)))
        if max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {max_queue}")
        self.max_queue = max_queue
        self.default_timeout = timeout
        self.communicator = communicator
        self.programs = (program_cache if program_cache is not None
                         else GLOBAL_PROGRAM_CACHE)
        self.name = name
        self._registry = registry if registry is not None else METRICS
        self._token = CancellationToken()   # parent of every query token
        self._cond = threading.Condition(threading.Lock())
        self._queue: Deque[_Item] = collections.deque()
        self._inflight = 0
        self._closed = False
        self._counts = {"submitted": 0, "completed": 0, "failed": 0,
                        "cancelled": 0, "rejected": 0}
        self._workers = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"{name}-worker-{i}")
            for i in range(self.max_inflight)]
        for w in self._workers:
            w.start()

    # ------------------------------------------------------------------ #
    # submission
    # ------------------------------------------------------------------ #
    def submit(self, frame: Any, *, timeout: Optional[float] = None,
               label: Optional[str] = None, gang_size: Optional[int] = None,
               **collect_kw: Any) -> QueryHandle:
        """Admit one query (non-blocking): ``frame.collect(...)`` will run
        on a freshly carved gang; ``collect_kw`` passes through to it.

        ``timeout`` (else the scheduler default) arms the query's
        ``CancellationToken`` at *submission*, so the deadline covers
        queue wait + execution.  Raises ``AdmissionRejected`` when the
        queue is full.
        """
        gang = self.gang_size if gang_size is None else int(gang_size)
        if gang < 1 or gang > self.pool.size:
            raise ValueError(f"gang_size {gang} not in [1, pool size "
                             f"{self.pool.size}]")
        token = CancellationToken(
            timeout if timeout is not None else self.default_timeout,
            parent=self._token)
        handle = QueryHandle(self, label or f"q{next(_seq)}", token)
        with self._cond:
            if self._closed:
                raise RuntimeError(f"scheduler {self.name!r} is closed")
            if (self._inflight + len(self._queue)
                    >= self.max_inflight + self.max_queue):
                # every worker slot busy and the overflow queue is full
                self._counts["rejected"] += 1
                self._registry.counter(
                    "serve_admission_rejected_total",
                    "submissions shed by admission control").inc(
                    scheduler=self.name)
                raise AdmissionRejected(
                    f"scheduler {self.name!r} at capacity: "
                    f"{self._inflight} inflight (max {self.max_inflight}), "
                    f"{len(self._queue)} queued (max {self.max_queue})")
            self._counts["submitted"] += 1
            self._queue.append(_Item(handle, frame, dict(collect_kw), gang))
            self._cond.notify()
            self._export_gauges_locked()
        self._registry.counter("serve_submitted_total",
                               "queries admitted").inc(scheduler=self.name)
        return handle

    # ------------------------------------------------------------------ #
    # worker side
    # ------------------------------------------------------------------ #
    def _worker(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._closed:
                    self._cond.wait()
                if not self._queue:      # closed and drained
                    return
                item = self._queue.popleft()
                self._inflight += 1
                self._export_gauges_locked()
            try:
                self._execute(item)
            finally:
                with self._cond:
                    self._inflight -= 1
                    self._export_gauges_locked()
                    self._cond.notify_all()

    def _execute(self, item: _Item) -> None:
        handle = item.handle
        if handle.done():                # cancelled while queued, unlinked
            return
        stats = handle.stats
        stats["queue_wait_s"] = (time.monotonic()
                                 - stats["submitted_monotonic"])
        try:
            handle.token.check(f"queued ({handle.label})")
        except BaseException as e:       # deadline passed / cancelled in queue
            self._finish(handle, None, e)
            return
        lease = None
        try:
            lease = self.pool.reserve(item.gang_size, block=True,
                                      token=handle.token)
            env = CylonEnv(lease, communicator=self.communicator,
                           program_cache=self.programs)
            stats["devices"] = [d.id for d in lease]
            stats["state"] = "running"
            stats["started_at"] = time.time()
            stats["started_monotonic"] = time.monotonic()
            result = item.frame.collect(env=env, timeout=handle.token,
                                        **item.kw)
            stats["wall_s"] = time.monotonic() - stats["started_monotonic"]
            stats["cache_hits"] = env.cache_hits
            stats["cache_misses"] = env.cache_misses
            self._finish(handle, result, None)
        except BaseException as e:
            if "started_monotonic" in stats:
                stats["wall_s"] = (time.monotonic()
                                   - stats["started_monotonic"])
            self._finish(handle, None, e)
        finally:
            if lease is not None:
                # record completion before freeing the gang so overlapping
                # [started, finished] intervals imply concurrently held,
                # disjoint device partitions
                lease.release()

    def _finish(self, handle: QueryHandle, result: Any,
                exc: Optional[BaseException]) -> None:
        if handle.done():
            return
        stats = handle.stats
        stats["finished_at"] = time.time()
        stats["finished_monotonic"] = time.monotonic()
        if exc is None:
            stats["state"] = "done"
            outcome = "completed"
        elif isinstance(exc, QueryCancelled):
            stats["state"] = "cancelled"
            outcome = "cancelled"
        else:
            stats["state"] = ("timeout" if isinstance(exc, QueryTimeout)
                              else "failed")
            stats["error"] = f"{type(exc).__name__}: {exc}"
            outcome = "failed"
        handle._result = result
        handle._exception = exc
        with self._cond:
            self._counts[outcome] += 1
        record_serve_query(stats, scheduler=self.name,
                           registry=self._registry)
        handle._event.set()

    def _cancel_queued(self, handle: QueryHandle) -> None:
        """Unlink a cancelled entry from the queue so it completes now
        instead of waiting for a worker slot."""
        removed = False
        with self._cond:
            for item in self._queue:
                if item.handle is handle:
                    self._queue.remove(item)
                    removed = True
                    break
            if removed:
                self._export_gauges_locked()
        if removed:
            try:
                handle.token.check("cancelled in queue")
                e: BaseException = QueryCancelled(
                    f"query {handle.label!r} cancelled while queued")
            except BaseException as caught:
                e = caught
            self._finish(handle, None, e)

    # ------------------------------------------------------------------ #
    # lifecycle / introspection
    # ------------------------------------------------------------------ #
    def stats(self) -> Dict[str, Any]:
        """Point-in-time snapshot: counts, queue depth, inflight, pool
        occupancy, shared-program-cache totals."""
        with self._cond:
            snap = dict(self._counts)
            snap["queue_depth"] = len(self._queue)
            snap["inflight"] = self._inflight
        snap["pool_available"] = self.pool.available
        snap["pool_size"] = self.pool.size
        snap["gang_size"] = self.gang_size
        snap["max_inflight"] = self.max_inflight
        snap["max_queue"] = self.max_queue
        snap["program_cache"] = self.programs.stats()
        return snap

    def close(self, cancel_pending: bool = False, wait: bool = True) -> None:
        """Stop admitting; optionally cancel everything queued/running via
        the scheduler-wide parent token; ``wait`` joins the workers after
        they drain the queue."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if cancel_pending:
            self._token.cancel(f"scheduler {self.name!r} shutting down")
            with self._cond:
                pending = [item.handle for item in self._queue]
                self._queue.clear()
                self._cond.notify_all()
            for handle in pending:
                self._finish(handle, None, QueryCancelled(
                    f"query {handle.label!r} cancelled: scheduler "
                    f"{self.name!r} shutting down"))
        if wait:
            for w in self._workers:
                w.join()

    def __enter__(self) -> "QueryScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close(cancel_pending=exc[0] is not None)

    def _export_gauges_locked(self) -> None:
        self._registry.gauge(
            "serve_queue_depth", "queued submissions").set(
            len(self._queue), scheduler=self.name)
        self._registry.gauge(
            "serve_inflight", "concurrently executing queries").set(
            self._inflight, scheduler=self.name)

    def __repr__(self) -> str:
        with self._cond:
            return (f"<QueryScheduler {self.name!r} gang_size="
                    f"{self.gang_size} inflight={self._inflight}/"
                    f"{self.max_inflight} queued={len(self._queue)}/"
                    f"{self.max_queue}>")

"""Serving layer: batched prefill/decode engine over the model zoo."""

from .engine import GenerationResult, ServeEngine

__all__ = ["GenerationResult", "ServeEngine"]

"""Serving layer: concurrent multi-query scheduling over shared gangs,
the process-level compiled-program cache, and the batched LLM demo engine.

Submodules import lazily (module ``__getattr__``) so ``repro.core`` can
reference ``repro.serve.cache`` without a cycle and importing the
scheduler never drags in the model-zoo demo engine.
"""

from typing import Any

__all__ = [
    "AdmissionRejected", "GLOBAL_PROGRAM_CACHE", "GenerationResult",
    "ProgramCache", "QueryHandle", "QueryScheduler", "ServeEngine",
]

_HOMES = {
    "AdmissionRejected": "scheduler",
    "QueryHandle": "scheduler",
    "QueryScheduler": "scheduler",
    "ProgramCache": "cache",
    "GLOBAL_PROGRAM_CACHE": "cache",
    "GenerationResult": "engine",
    "ServeEngine": "engine",
}


def __getattr__(name: str) -> Any:
    home = _HOMES.get(name)
    if home is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f".{home}", __name__), name)


def __dir__():
    return sorted(__all__)

"""Version-compatibility shims for the jax APIs this repo relies on.

The codebase is written against the modern spelling (``jax.shard_map`` with
``check_vma``, ``jax.set_mesh`` as ambient-mesh context manager).  Older
installs (jax 0.4.x) expose the same functionality under
``jax.experimental.shard_map.shard_map`` (``check_rep``) and the legacy
``Mesh`` context manager.  Route every use through this module so a single
site owns the version split.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax

_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_SET_MESH = hasattr(jax, "set_mesh")


def _ambient_mesh():
    """Mesh installed by the legacy ``with mesh:`` context (jax 0.4.x)."""
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def shard_map(f: Callable, mesh: Optional[Any] = None, *, in_specs,
              out_specs, check_vma: bool = True) -> Callable:
    """``jax.shard_map`` across jax versions.

    ``mesh=None`` uses the ambient mesh (``set_mesh`` below).
    """
    if _HAS_NEW_SHARD_MAP:
        kw = {} if mesh is None else {"mesh": mesh}
        return jax.shard_map(f, in_specs=in_specs, out_specs=out_specs,
                             check_vma=check_vma, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    if mesh is None:
        mesh = _ambient_mesh()
        if mesh is None:
            raise ValueError(
                "shard_map without an explicit mesh requires an ambient mesh "
                "(wrap the call in `with compat.set_mesh(mesh):`)")
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def axis_size(axis: str):
    """``jax.lax.axis_size`` across jax versions (static inside shard_map)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)  # constant-folded to the static axis size


def set_mesh(mesh):
    """Ambient-mesh context manager across jax versions."""
    if _HAS_SET_MESH:
        return jax.set_mesh(mesh)
    return mesh  # legacy Mesh is itself a context manager

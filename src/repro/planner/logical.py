"""Typed logical plan with per-node physical properties.

This replaces the ad-hoc ``core.plan.Node`` as the optimizer's working
representation.  Every node carries three derived properties, recomputed by
``annotate`` after each rewrite pass:

* ``schema``        — sorted tuple of live output columns,
* ``partitioning``  — how rows are placed across ranks
                      (``none`` | ``hash(cols)`` | ``range(col)``),
* ``est_rows``      — global row-count estimate (heuristic; drives
                      join-side selection and EXPLAIN only).

The partitioning lattice is what makes shuffle elision sound:

* ``hash(C)``  — row placement is ``hash_columns(C) % p`` (the deterministic
  murmur-style hash in ``dataframe.ops_local``), so two tables hashed on the
  same columns are co-partitioned.
* ``range(c)`` — rank ``r`` holds the ``r``-th contiguous key range of ``c``
  (sample-sort splitters); equal keys are co-located but *not* aligned with
  any hash partitioning.

``colocates(cols)`` (equal keys share a rank) is the requirement of
``groupby``; ``matches_hash`` (exact placement equality) is the stronger
requirement of ``join`` co-partitioning; ``matches_range`` is required by
``sort``.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

#: ops that may execute a shuffle (communication boundaries)
COMM_OPS = ("shuffle", "join", "groupby", "sort")
#: purely local ops (``recode`` remaps dictionary codes via a static
#: gather table — inserted by ``planner.dictionary``, never by users)
LOCAL_OPS = ("scan", "project", "filter", "with_columns", "add_scalar",
             "recode", "noop")

#: paper §V data recipe: ~90% key cardinality (drives groupby estimates)
DEFAULT_GROUP_RATIO = 0.9
#: selectivity guess for filters with unknown predicates
DEFAULT_FILTER_SELECTIVITY = 0.5

_ids = itertools.count()


@dataclasses.dataclass(frozen=True)
class Partitioning:
    kind: str = "none"            # "none" | "hash" | "range"
    cols: Tuple[str, ...] = ()

    @staticmethod
    def none() -> "Partitioning":
        return Partitioning()

    @staticmethod
    def hash_(cols: Sequence[str]) -> "Partitioning":
        return Partitioning("hash", tuple(cols))

    @staticmethod
    def range_(col: str) -> "Partitioning":
        return Partitioning("range", (col,))

    def colocates(self, cols: Sequence[str]) -> bool:
        """Rows with equal values on ``cols`` are guaranteed to share a rank."""
        return (bool(self.cols) and self.kind in ("hash", "range")
                and set(self.cols) <= set(cols))

    def matches_hash(self, cols: Sequence[str]) -> bool:
        """Placement is exactly ``hash_columns(cols) % p``."""
        return self.kind == "hash" and self.cols == tuple(cols)

    def matches_range(self, col: str) -> bool:
        """Rank r holds the r-th contiguous range of ``col``."""
        return self.kind == "range" and self.cols == (col,)

    def restrict(self, live: Sequence[str]) -> "Partitioning":
        """Drop the property if its columns are no longer live."""
        if self.kind == "none" or set(self.cols) <= set(live):
            return self
        return Partitioning.none()

    def __str__(self) -> str:
        if self.kind == "none":
            return "none"
        return f"{self.kind}({','.join(self.cols)})"


@dataclasses.dataclass
class LogicalNode:
    """One operator in the logical DAG (mutable: rules rewrite in place)."""

    op: str
    inputs: List["LogicalNode"]
    params: Dict[str, Any]
    schema: Tuple[str, ...] = ()
    partitioning: Partitioning = dataclasses.field(default_factory=Partitioning)
    est_rows: float = 0.0
    #: per-column dictionaries of dictionary-encoded string columns in the
    #: output schema (``dataframe.schema``); device columns hold codes
    dicts: Dict[str, Tuple[str, ...]] = dataclasses.field(default_factory=dict)
    #: output columns that MAY contain nulls (carry a ``__m_*`` validity
    #: mask at runtime).  Conservative in the nullable direction: the
    #: optimizer uses ``c not in nulls`` to elide mask work, never the
    #: reverse, so over-approximating nullability is always sound.
    nulls: frozenset = frozenset()
    nid: int = dataclasses.field(default_factory=lambda: next(_ids))

    # -- physical classification (consulted by lowering & staging) ------- #
    def is_comm(self) -> bool:
        """True if this node still executes at least one shuffle."""
        return self.shuffle_count() > 0

    def shuffle_count(self) -> int:
        p = self.params
        if self.op == "shuffle":
            return 1
        if self.op == "join":
            return int(not p.get("elide_left")) + int(not p.get("elide_right"))
        if self.op in ("groupby", "sort"):
            return 0 if p.get("elide_shuffle") else 1
        return 0


def topo(root: LogicalNode) -> List[LogicalNode]:
    seen, order = set(), []

    def visit(n: LogicalNode) -> None:
        if n.nid in seen:
            return
        seen.add(n.nid)
        for i in n.inputs:
            visit(i)
        order.append(n)

    visit(root)
    return order


def consumers(root: LogicalNode) -> Dict[int, int]:
    """nid -> number of consumers in the DAG (root counts as one extra)."""
    count: Dict[int, int] = {root.nid: 1}
    for n in topo(root):
        for i in n.inputs:
            count[i.nid] = count.get(i.nid, 0) + 1
        count.setdefault(n.nid, 0)
    return count


# ---------------------------------------------------------------------- #
# Schema inference helpers
# ---------------------------------------------------------------------- #
def join_schema(left: Sequence[str], right: Sequence[str], on: str,
                suffix: str = "_r") -> Tuple[str, ...]:
    cols = list(left)
    for name in right:
        if name == on:
            continue
        cols.append(name if name not in left else name + suffix)
    return tuple(sorted(cols))


def groupby_schema(keys: Sequence[str], aggs: Mapping[str, Sequence[str]]
                   ) -> Tuple[str, ...]:
    from ..dataframe.groupby import _normalize
    _, post = _normalize(aggs)
    return tuple(sorted(set(keys) | {name for name, _, _ in post}))


# ---------------------------------------------------------------------- #
# Property annotation (bottom-up, idempotent)
# ---------------------------------------------------------------------- #
def annotate(root: LogicalNode,
             catalog: Optional[Mapping[str, Tuple[Tuple[str, ...], float]]] = None
             ) -> LogicalNode:
    """Recompute schema / partitioning / est_rows for every node.

    ``catalog`` maps scan names to ``(columns, est_rows)``; when omitted,
    scan nodes keep whatever properties they already carry (used when
    re-annotating after a rewrite pass).
    """
    for n in topo(root):
        _annotate_node(n, catalog)
    return root


def _restrict_dicts(dicts: Mapping[str, Tuple[str, ...]],
                    schema: Sequence[str]) -> Dict[str, Tuple[str, ...]]:
    live = set(schema)
    return {c: d for c, d in dicts.items() if c in live}


def _annotate_node(n: LogicalNode, catalog) -> None:
    p = n.params
    ins = n.inputs
    if n.op == "scan":
        if catalog is not None:
            name = p["name"]
            if name not in catalog:
                raise KeyError(
                    f"scan {name!r} has no schema: pass it in `tables` "
                    f"(a DistTable, a column sequence, or a (cols, rows) "
                    f"pair); known names: {sorted(catalog)}")
            entry = catalog[name]
            cols, rows = entry[0], entry[1]
            n.schema = tuple(sorted(cols))
            n.est_rows = float(rows)
            n.dicts = dict(entry[2]) if len(entry) > 2 else {}
            n.nulls = frozenset(entry[3]) if len(entry) > 3 else frozenset()
            if len(entry) > 4 and entry[4]:
                # ingest provenance summary (repro.io) — EXPLAIN renders
                # ``scan[parquet: N files, ~M rows]``
                n.params.setdefault("source", entry[4])
        n.partitioning = Partitioning.none()  # block-distributed source
        return

    i0 = ins[0]
    if n.op == "noop":                        # identity left by shuffle elision
        n.schema, n.partitioning, n.est_rows = i0.schema, i0.partitioning, i0.est_rows
        n.dicts = dict(i0.dicts)
        n.nulls = i0.nulls
    elif n.op == "project":
        n.schema = tuple(sorted(p["cols"]))
        n.partitioning = i0.partitioning.restrict(n.schema)
        n.est_rows = i0.est_rows
        n.dicts = _restrict_dicts(i0.dicts, n.schema)
        n.nulls = i0.nulls & set(n.schema)
    elif n.op == "filter":
        n.schema = i0.schema
        n.partitioning = i0.partitioning
        n.est_rows = i0.est_rows * DEFAULT_FILTER_SELECTIVITY
        n.dicts = dict(i0.dicts)
        n.nulls = i0.nulls
    elif n.op == "with_columns":
        # assignments may introduce new columns; rewriting a partitioning
        # column's values breaks the placement property
        from ..dataframe.schema import expr_dictionary
        assigned = set(p["exprs"])
        n.schema = tuple(sorted(set(i0.schema) | assigned))
        n.partitioning = (Partitioning.none()
                          if assigned & set(i0.partitioning.cols)
                          else i0.partitioning)
        n.est_rows = i0.est_rows
        dicts = {c: d for c, d in i0.dicts.items() if c not in assigned}
        # already-lowered string-literal assignments record their output
        # dictionary in ``assign_dicts`` (planner.dictionary)
        assign_dicts = p.get("assign_dicts", {})
        for name, e in p["exprs"].items():
            d = (assign_dicts.get(name)
                 or expr_dictionary(e, i0.dicts))
            if d is not None:
                dicts[name] = d
        n.dicts = dicts
        nulls = set(i0.nulls) - assigned
        for name, e in p["exprs"].items():
            nullable = getattr(e, "nullable", None)
            if nullable is None or nullable(i0.nulls):
                nulls.add(name)
        n.nulls = frozenset(nulls)
    elif n.op == "add_scalar":
        n.schema = i0.schema
        touched = p.get("cols")
        touched = set(i0.schema if touched is None else touched)
        n.partitioning = (Partitioning.none()
                          if touched & set(i0.partitioning.cols)
                          else i0.partitioning)
        n.est_rows = i0.est_rows
        n.dicts = dict(i0.dicts)
        n.nulls = i0.nulls
    elif n.op == "recode":
        # static per-column code remap onto the target dictionaries; the
        # recoded columns' hash placement no longer holds (codes changed)
        n.schema = i0.schema
        n.partitioning = (Partitioning.none()
                          if set(p["cols"]) & set(i0.partitioning.cols)
                          else i0.partitioning)
        n.est_rows = i0.est_rows
        n.dicts = {**i0.dicts, **p["targets"]}
        n.nulls = i0.nulls
    elif n.op == "shuffle":
        n.schema = i0.schema
        # an explicit dest array routes rows arbitrarily — no hash property
        n.partitioning = (Partitioning.none() if "dest" in p
                          else Partitioning.hash_(p["key_cols"]))
        n.est_rows = i0.est_rows
        n.dicts = dict(i0.dicts)
        n.nulls = i0.nulls
    elif n.op == "join":
        l, r = ins
        n.schema = join_schema(l.schema, r.schema, p["on"])
        n.partitioning = (l.partitioning if p.get("elide_left")
                          and p.get("elide_right")
                          else Partitioning.hash_((p["on"],)))
        n.est_rows = max(l.est_rows, r.est_rows)
        # key column comes from the left side (inputs agree post-recode);
        # colliding right columns follow the ``_r`` suffix rename
        dicts = dict(l.dicts)
        lcols = set(l.schema)
        for c, d in r.dicts.items():
            if c == p["on"]:
                continue
            dicts[c if c not in lcols else c + "_r"] = d
        n.dicts = _restrict_dicts(dicts, n.schema)
        # null join keys never match (they are dropped): the output key is
        # non-null; value columns keep nullability through the _r rename
        nulls = set(l.nulls) - {p["on"]}
        for c in r.nulls:
            if c == p["on"]:
                continue
            nulls.add(c if c not in lcols else c + "_r")
        n.nulls = frozenset(nulls & set(n.schema))
    elif n.op == "groupby":
        n.schema = groupby_schema(p["keys"], p["aggs"])
        if p.get("elide_shuffle"):
            # groups stay where their rows already were
            n.partitioning = i0.partitioning.restrict(n.schema)
        else:
            n.partitioning = Partitioning.hash_(p["keys"])
        n.est_rows = i0.est_rows * DEFAULT_GROUP_RATIO
        # keys keep their dictionaries; min/max of codes = min/max of
        # strings (sorted dictionaries), so those outputs stay encoded
        dicts = {k: i0.dicts[k] for k in p["keys"] if k in i0.dicts}
        for col, agg_names in p["aggs"].items():
            if col in i0.dicts:
                for a in agg_names:
                    if a in ("min", "max"):
                        dicts[f"{col}_{a}"] = i0.dicts[col]
        n.dicts = _restrict_dicts(dicts, n.schema)
        # null keys form no groups; sum/count/size never yield null; an
        # all-null group has null min/max/mean of a nullable input column
        nulls = set()
        for col, agg_names in p["aggs"].items():
            if col in i0.nulls:
                for a in agg_names:
                    if a in ("min", "max", "mean"):
                        nulls.add(f"{col}_{a}")
        n.nulls = frozenset(nulls & set(n.schema))
    elif n.op == "sort":
        n.schema = i0.schema
        n.partitioning = Partitioning.range_(p["by"][0])
        n.est_rows = i0.est_rows
        n.dicts = dict(i0.dicts)
        n.nulls = i0.nulls
    else:
        raise ValueError(f"unknown op {n.op!r}")


def preserves_rows_and_columns(n: LogicalNode, cols: Sequence[str]) -> bool:
    """True iff ``n``'s output carries exactly its first input's rows with
    the values of ``cols`` unchanged.

    This is the invariant the skew detector's chase needs: if every node
    between a shuffle boundary and a scan preserves the key columns' row
    multiset, the scan's key distribution IS the boundary's, so the
    driver can sample the (already materialized) scan instead of the
    not-yet-computed boundary input.  Filters, recodes, and comm ops all
    change the multiset (or the codes), so they stop the chase.
    """
    wanted = set(cols)
    if n.op == "noop":
        return True
    if n.op == "project":
        return wanted <= set(n.params["cols"])
    if n.op == "with_columns":
        return not (wanted & set(n.params["exprs"]))
    if n.op == "add_scalar":
        touched = n.params.get("cols")
        return touched is not None and not (wanted & set(touched))
    return False


# ---------------------------------------------------------------------- #
# Conversion from the core builder (duck-typed: needs .op/.inputs/.params)
# ---------------------------------------------------------------------- #
def from_plan(node, catalog: Mapping[str, Tuple[Tuple[str, ...], float]]
              ) -> LogicalNode:
    """Convert a ``core.plan`` builder tree into an annotated logical DAG."""
    memo: Dict[int, LogicalNode] = {}

    def conv(n) -> LogicalNode:
        if id(n) in memo:
            return memo[id(n)]
        out = LogicalNode(n.op, [conv(i) for i in n.inputs], dict(n.params))
        memo[id(n)] = out
        return out

    return annotate(conv(node), catalog)


def copy_dag(root: LogicalNode) -> LogicalNode:
    """Structural copy of a LogicalNode DAG (sharing preserved, params
    shallow-copied like ``from_plan``).  ``compile_plan`` copies before
    the rewrite passes so a caller-held DAG is never mutated — compiling
    it twice against different catalogs must not leak recode tables or
    lowered literals from the first run into the second."""
    memo: Dict[int, LogicalNode] = {}

    def conv(n: LogicalNode) -> LogicalNode:
        if n.nid in memo:
            return memo[n.nid]
        out = LogicalNode(n.op, [conv(i) for i in n.inputs], dict(n.params),
                          schema=n.schema, partitioning=n.partitioning,
                          est_rows=n.est_rows, dicts=dict(n.dicts),
                          nulls=n.nulls)
        memo[n.nid] = out
        return out

    return conv(root)


def build_catalog(tables: Optional[Mapping[str, Any]]
                  ) -> Dict[str, Tuple[Tuple[str, ...], float,
                                       Dict[str, Tuple[str, ...]],
                                       frozenset]]:
    """Normalize scan metadata to ``(columns, est_rows, dictionaries,
    nullable_columns[, source])`` — ``source`` is the ingest-provenance
    summary string for tables read by ``repro.io`` (EXPLAIN label).

    Values may be DistTable-likes (``column_names`` + ``total_rows`` +
    optional ``dictionaries``), numpy column dicts, ``(cols, rows)`` pairs,
    or plain column sequences; dictionaries default to none (all-numeric)
    and nullability to none.  ``__m_*`` validity-mask columns are physical
    companions, not logical schema: they are stripped from the column list
    and recorded as their base column's nullability instead.
    """
    from ..dataframe.schema import dictionary_of, is_string_array
    from ..nulls import _valid_of, data_columns, nullable_columns
    cat: Dict[str, Tuple[Tuple[str, ...], float,
                         Dict[str, Tuple[str, ...]], frozenset]] = {}
    for name, t in (tables or {}).items():
        if hasattr(t, "column_names"):
            rows = float(t.total_rows()) if hasattr(t, "total_rows") else 1024.0
            dicts = dict(getattr(t, "dictionaries", {}) or {})
            names = tuple(t.column_names)
            prov = getattr(t, "provenance", None)
            cat[name] = (tuple(data_columns(names)), rows, dicts,
                         frozenset(nullable_columns(names)),
                         str(prov) if prov is not None else None)
        elif isinstance(t, Mapping):
            # raw numpy column dict (morsel-streamed source): string
            # columns will be dictionary-encoded at ingest — mirror the
            # dictionary here (codes not needed) so the plan agrees.
            # NaN/None slots (or an explicit __m_* companion) make the
            # column nullable, exactly as ``extract_null_columns`` will
            # normalize it at ingest — smallest-valid-value fill keeps the
            # dictionary itself null-free.
            # NOTE: this np.unique runs per compile; for large string
            # sources ingest once into a SpillTable/DistTable (which
            # carries .dictionaries) instead of passing raw dicts
            import numpy as _np
            cols, dicts, rows = [], {}, 1024.0
            nulls = set(nullable_columns(t.keys()))
            for cname, arr in t.items():
                if cname.startswith("__m_"):
                    continue
                arr = _np.asarray(arr)
                cols.append(cname)
                rows = float(len(arr))
                valid = _valid_of(arr)
                if not valid.all():
                    nulls.add(cname)
                if is_string_array(arr):
                    vals = arr[valid] if not valid.all() else arr
                    # all-null columns ingest as the "" fill value
                    dicts[cname] = (dictionary_of(vals) if len(vals)
                                    else ("",))
            cat[name] = (tuple(cols), rows, dicts, frozenset(nulls))
        elif (isinstance(t, tuple) and len(t) in (2, 3, 4)
              and not isinstance(t[0], str)):
            dicts = dict(t[2]) if len(t) > 2 else {}
            nulls = frozenset(t[3]) if len(t) > 3 else frozenset()
            cat[name] = (tuple(data_columns(t[0])), float(t[1]), dicts,
                         nulls | frozenset(nullable_columns(t[0])))
        else:
            cat[name] = (tuple(t), 1024.0, {}, frozenset())
    return cat

"""Rewrite-rule engine over the logical plan.

Rules mutate the DAG in place and return human-readable "fired" records
(surfaced by EXPLAIN).  ``optimize`` runs the rule list to a fixpoint,
re-annotating node properties after every pass so later rules see the
effects of earlier ones (e.g. predicate pushdown exposes a shuffle whose
input partitioning now satisfies its requirement).

Rule inventory (the paper's communication-pattern view of DDF operators,
arXiv:2209.06146, turned into rewrites):

* shuffle elision        — drop the shuffle inside join/groupby/sort (or an
                           explicit ``shuffle`` node) when the input's
                           partitioning already satisfies the operator's
                           requirement; the collective term vanishes.
* join-side selection    — when one join side is already co-partitioned on
                           the key, shuffle only the other side.
* conjunction splitting  — a filter on ``a & b`` sitting on a communication
                           boundary splits into two stacked filters so each
                           conjunct can be pushed independently (e.g. one
                           side of a join each); conjuncts that end up
                           adjacent again are re-fused after the fixpoint.
* predicate pushdown     — move filters below shuffles/sorts/with_columns
                           (and into join or groupby inputs when the
                           predicate's column set allows it) so fewer rows
                           hit the wire.  Typed expressions carry exact
                           column sets; opaque callables without declared
                           columns stay put.
* projection pushdown    — insert projections below communication boundaries
                           so dead columns never hit the wire; expression
                           inputs are pruned exactly (``Expr.columns()``)
                           and dead ``with_columns`` assignments dropped.
* pre-aggregation        — algebraic aggs (sum/count/min/max/mean) are
                           locally pre-aggregated before the groupby shuffle
                           so one row per (rank, group) moves instead of one
                           row per input row.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

from ..expr import BinOp, Expr, FillNull, IsNull, Lit, UnaryOp
from .logical import COMM_OPS, LogicalNode, annotate, consumers, topo

#: params that carry optimizer decisions rather than user intent
DECISION_KEYS = ("elide_shuffle", "elide_left", "elide_right",
                 "side_selected", "pre_aggregate")


# ---------------------------------------------------------------------- #
# Shuffle elision
# ---------------------------------------------------------------------- #
def elide_shuffles(root: LogicalNode) -> List[str]:
    fired: List[str] = []
    for n in topo(root):
        p = n.params
        if n.op == "shuffle":
            # An explicit shuffle whose placement already holds is an
            # identity; turn it into a noop (keeps DAG sharing + root id).
            if (not p.get("elided")
                    and "dest" not in p and "out_capacity" not in p
                    and n.inputs[0].partitioning.matches_hash(p["key_cols"])):
                note = f"shuffle({','.join(p['key_cols'])})"
                n.op = "noop"
                n.params = {"note": f"{note} elided", "elided": True}
                fired.append(
                    f"shuffle-elision: {note} removed — input already "
                    f"{n.inputs[0].partitioning}")
        elif n.op == "groupby" and not p.get("elide_shuffle"):
            if ("out_capacity" not in p
                    and n.inputs[0].partitioning.colocates(p["keys"])):
                p["elide_shuffle"] = True
                fired.append(
                    f"shuffle-elision: groupby({','.join(p['keys'])}) runs "
                    f"local-only — input already {n.inputs[0].partitioning}")
        elif n.op == "sort" and not p.get("elide_shuffle"):
            if ("out_capacity" not in p
                    and n.inputs[0].partitioning.matches_range(p["by"][0])):
                p["elide_shuffle"] = True
                fired.append(
                    f"shuffle-elision: sort({','.join(p['by'])}) runs "
                    f"local-only — input already {n.inputs[0].partitioning}")
        elif n.op == "join":
            key = (n.params["on"],)
            for side, inp in (("left", n.inputs[0]), ("right", n.inputs[1])):
                flag = f"elide_{side}"
                if not p.get(flag) and inp.partitioning.matches_hash(key):
                    p[flag] = True
                    fired.append(
                        f"shuffle-elision: join({n.params['on']}) {side} side "
                        f"pre-partitioned — input already {inp.partitioning}")
    return fired


def select_join_sides(root: LogicalNode) -> List[str]:
    """Record the shuffle-side decision for joins with one co-partitioned
    input (the elision flags carry the decision; this surfaces it)."""
    fired: List[str] = []
    for n in topo(root):
        if n.op != "join" or n.params.get("side_selected"):
            continue
        el, er = n.params.get("elide_left"), n.params.get("elide_right")
        if bool(el) == bool(er):
            continue
        n.params["side_selected"] = True
        kept = "right" if el else "left"
        kept_rows = n.inputs[1 if el else 0].est_rows
        other_rows = n.inputs[0 if el else 1].est_rows
        fired.append(
            f"join-side-selection: join({n.params['on']}) shuffles {kept} "
            f"side only (~{int(kept_rows)} rows; other side ~"
            f"{int(other_rows)} rows already placed)")
    return fired


# ---------------------------------------------------------------------- #
# Null-check elision (provably non-null inputs need no mask work)
# ---------------------------------------------------------------------- #
def _elide_nulls(e: Expr, nulls) -> Tuple[Expr, List[str]]:
    """Rewrite ``is_null(x)`` -> ``False`` and ``fill_null(x, f)`` -> ``x``
    when ``x`` is provably non-null given the input's nullable set.
    Soundness rests on the annotation being conservative: ``nullable()``
    over-approximates, so an elision here can never drop a real null."""
    if isinstance(e, BinOp):
        l, fl = _elide_nulls(e.left, nulls)
        r, fr = _elide_nulls(e.right, nulls)
        if fl or fr:
            return BinOp(e.op, l, r), fl + fr
        return e, []
    if isinstance(e, UnaryOp):
        op, f = _elide_nulls(e.operand, nulls)
        return (UnaryOp(e.op, op), f) if f else (e, [])
    if isinstance(e, IsNull):
        op, f = _elide_nulls(e.operand, nulls)
        if not op.nullable(nulls):
            return Lit(False), f + [f"is_null({op!r}) is always false"]
        return (IsNull(op), f) if f else (e, [])
    if isinstance(e, FillNull):
        op, fo = _elide_nulls(e.operand, nulls)
        fill, ff = _elide_nulls(e.fill, nulls)
        if not op.nullable(nulls):
            return op, fo + ff + [f"fill_null({op!r}, ...) is an identity"]
        return (FillNull(op, fill), fo + ff) if fo or ff else (e, [])
    return e, []


def elide_null_checks(root: LogicalNode) -> List[str]:
    """Drop ``is_null`` / ``fill_null`` over provably non-null expressions
    (scan nullability threaded through ``LogicalNode.nulls``), so queries
    written defensively against nullable schemas compile to zero mask work
    on clean data."""
    fired: List[str] = []
    for n in topo(root):
        nulls = n.inputs[0].nulls if n.inputs else frozenset()
        if n.op == "filter":
            e, hits = _elide_nulls(n.params["expr"], nulls)
            if hits:
                n.params["expr"] = e
                fired.extend(f"null-elision: {h} (filter)" for h in hits)
        elif n.op == "with_columns":
            exprs, changed = {}, []
            for name, ex in n.params["exprs"].items():
                ne, hits = _elide_nulls(ex, nulls)
                exprs[name] = ne
                changed.extend(hits)
            if changed:
                # copy before mutating: the inner dict may be shared with
                # the user's builder tree (from_plan shallow-copies params)
                n.params = dict(n.params)
                n.params["exprs"] = exprs
                fired.extend(f"null-elision: {h} (with_columns)"
                             for h in changed)
    return fired


# ---------------------------------------------------------------------- #
# Conjunction splitting + predicate pushdown
# ---------------------------------------------------------------------- #
def _pred_cols(node: LogicalNode) -> Optional[Tuple[str, ...]]:
    """Columns the filter's expression reads; None = unknown (opaque)."""
    cols = node.params["expr"].columns()
    return None if cols is None else tuple(sorted(cols))


def split_conjunctions(root: LogicalNode) -> List[str]:
    """``filter(a & b)`` directly above a communication boundary becomes
    ``filter(a)`` over ``filter(b)`` so pushdown can route each conjunct
    independently (e.g. into different join inputs).  Sound only for
    provably boolean conjuncts (`&` on integers is bitwise).  Conjuncts
    that end up adjacent after the fixpoint are re-fused, so a split that
    enabled no pushdown costs nothing."""
    fired: List[str] = []
    for n in topo(root):
        if n.op != "filter" or n.inputs[0].op not in COMM_OPS:
            continue
        e = n.params["expr"]
        if not (isinstance(e, BinOp) and e.op == "&" and e.is_boolean()):
            continue
        inner = LogicalNode("filter", [n.inputs[0]], {"expr": e.right})
        n.params = {"expr": e.left}
        n.inputs = [inner]
        fired.append(f"split-conjunction: filter[{e!r}] split for "
                     f"independent pushdown")
    return fired


def fuse_adjacent_filters(root: LogicalNode) -> None:
    """Re-merge stacked filters into one conjunction (post-fixpoint: undoes
    conjunction splits that enabled no pushdown, saving a compaction)."""
    ncons = consumers(root)
    for n in topo(root):
        while (n.op == "filter" and n.inputs[0].op == "filter"
               and ncons.get(n.inputs[0].nid, 0) == 1):
            inner = n.inputs[0]
            n.params = {"expr": n.params["expr"] & inner.params["expr"]}
            n.inputs = [inner.inputs[0]]


def push_predicates(root: LogicalNode) -> List[str]:
    fired: List[str] = []
    ncons = consumers(root)
    for n in topo(root):
        if n.op != "filter":
            continue
        child = n.inputs[0]
        if ncons.get(child.nid, 0) != 1:
            continue  # rewiring a shared node would change its other users
        if child.op in ("shuffle", "sort"):
            # An explicit dest array is row-aligned with the pre-filter
            # table, and an explicit out_capacity makes the overflow cut
            # observable — both pin the filter above the shuffle.
            if "dest" in child.params or "out_capacity" in child.params:
                continue
            # filter(shuffle(x)) -> shuffle(filter(x)): swap the two nodes'
            # identities so parents of the filter need no rewiring.
            n.op, child.op = child.op, n.op
            n.params, child.params = child.params, n.params
            fired.append(f"predicate-pushdown: filter moved below "
                         f"{n.op}")
        elif child.op == "with_columns":
            cols = _pred_cols(n)
            if cols is None or set(cols) & set(child.params["exprs"]):
                continue  # predicate reads an assigned column
            n.op, child.op = child.op, n.op
            n.params, child.params = child.params, n.params
            fired.append("predicate-pushdown: filter moved below "
                         "with_columns")
        elif child.op == "groupby":
            cols = _pred_cols(n)
            if cols is None or not set(cols) <= set(child.params["keys"]):
                continue  # predicate reads aggregate outputs
            n.op, child.op = child.op, n.op
            n.params, child.params = child.params, n.params
            fired.append("predicate-pushdown: key-only filter moved below "
                         "groupby")
        elif child.op == "join":
            cols = _pred_cols(n)
            if cols is None:
                continue
            jp = child.params
            lschema = set(child.inputs[0].schema)
            rschema = set(child.inputs[1].schema)
            if set(cols) <= lschema:
                side = 0
            elif set(cols) <= rschema and not set(cols) & lschema:
                side = 1
            else:
                continue
            pushed = LogicalNode("filter", [child.inputs[side]],
                                 dict(n.params))
            # the filter node becomes the join; the old join node is retired
            # into the pushed position via fresh node to preserve sharing
            n.op = "join"
            n.params = jp
            n.inputs = list(child.inputs)
            n.inputs[side] = pushed
            fired.append(
                f"predicate-pushdown: filter on ({','.join(cols)}) moved "
                f"into join {'left' if side == 0 else 'right'} input")
    return fired


# ---------------------------------------------------------------------- #
# Projection pushdown (dead-column elimination at comm boundaries)
# ---------------------------------------------------------------------- #
def _required_from(node: LogicalNode, required: Set[str], i: int) -> Set[str]:
    """Columns ``node`` needs from input ``i`` to produce ``required``."""
    p = node.params
    if node.op in ("scan",):
        return set()
    if node.op in ("project", "noop"):
        return set(required)
    if node.op == "filter":
        cols = node.params["expr"].columns()
        if cols is None:
            return set(node.inputs[i].schema)  # opaque predicate: keep all
        return set(required) | set(cols)
    if node.op == "with_columns":
        # conservative: every assignment's inputs stay live until
        # prune_dead_assignments drops assignments nobody consumes
        need = set(required) - set(p["exprs"])
        for expr in p["exprs"].values():
            cols = expr.columns()
            if cols is None:
                return set(node.inputs[i].schema)
            need |= cols
        return need
    if node.op == "add_scalar":
        cols = p.get("cols")
        return set(required) | (set(cols) if cols else set())
    if node.op == "recode":
        # the remapped columns stay live (the gather table references them)
        return set(required) | set(p["cols"])
    if node.op == "shuffle":
        return set(required) | set(p["key_cols"])
    if node.op == "sort":
        return set(required) | set(p["by"])
    if node.op == "groupby":
        return set(p["keys"]) | set(p["aggs"])
    if node.op == "join":
        on = p["on"]
        left = set(node.inputs[0].schema)
        if i == 0:
            out = (required & left) | {on}
            for name in node.inputs[1].schema:
                # keep a colliding left column alive when its suffixed right
                # twin is required, so the suffix assignment stays stable
                if name != on and name in left and name + "_r" in required:
                    out.add(name)
            return out
        out: Set[str] = {on}
        for name in node.inputs[1].schema:
            if name == on:
                continue
            produced = name if name not in left else name + "_r"
            if produced in required:
                out.add(name)
        return out
    raise ValueError(node.op)


def _required_sets(root: LogicalNode) -> Tuple[List[LogicalNode],
                                               Dict[int, Set[str]]]:
    """Backward liveness: nid -> columns any consumer needs from that node."""
    order = topo(root)
    required: Dict[int, Set[str]] = {root.nid: set(root.schema)}
    for n in reversed(order):
        req = required.setdefault(n.nid, set(n.schema))
        for i, inp in enumerate(n.inputs):
            required.setdefault(inp.nid, set()).update(
                _required_from(n, req, i))
    return order, required


def prune_dead_assignments(root: LogicalNode) -> List[str]:
    """Drop ``with_columns`` assignments whose target no consumer reads, so
    their input columns stop pinning liveness (runs before projection
    pushdown in each pass; a fully-pruned node degenerates to a noop)."""
    fired: List[str] = []
    order, required = _required_sets(root)
    for n in order:
        if n.op != "with_columns":
            continue
        exprs = n.params["exprs"]
        dead = sorted(set(exprs) - required[n.nid])
        if not dead:
            continue
        # copy before mutating: from_plan shallow-copies params, so the
        # inner dict is still shared with the user's builder tree
        n.params["exprs"] = {name: e for name, e in exprs.items()
                             if name not in dead}
        fired.append(f"dead-assignment: with_columns drops unused "
                     f"[{','.join(dead)}]")
        if not n.params["exprs"]:
            n.op = "noop"
            n.params = {"note": "with_columns pruned empty"}
    return fired


def push_projections(root: LogicalNode) -> List[str]:
    fired: List[str] = []
    order, required = _required_sets(root)
    for n in order:
        if n.op not in COMM_OPS:
            continue
        for i, inp in enumerate(n.inputs):
            live = required[inp.nid] & set(inp.schema)
            if not live or live >= set(inp.schema):
                continue
            dropped = sorted(set(inp.schema) - live)
            if inp.op == "project":
                inp.params["cols"] = tuple(sorted(live))
            else:
                n.inputs[i] = LogicalNode(
                    "project", [inp], {"cols": tuple(sorted(live))})
            fired.append(
                f"projection-pushdown: drop [{','.join(dropped)}] before "
                f"{n.op}")
    return fired


# ---------------------------------------------------------------------- #
# Pre-aggregation pushdown
# ---------------------------------------------------------------------- #
def push_preaggregation(root: LogicalNode) -> List[str]:
    fired: List[str] = []
    for n in topo(root):
        p = n.params
        if (n.op != "groupby" or p.get("elide_shuffle")
                or "pre_aggregate" in p):
            continue
        # _normalize accepts only algebraic aggs, so decomposition is safe.
        p["pre_aggregate"] = True
        keys = ",".join(p["keys"])
        fired.append(
            f"pre-aggregation: groupby({keys}) aggregates locally before "
            f"its shuffle (one row per rank-group on the wire)")
    return fired


def prune_identity_projects(root: LogicalNode) -> None:
    """Unlink projections that select their input's full schema (left
    behind when later passes narrow the schemas upstream of them)."""
    for n in topo(root):
        for i, inp in enumerate(n.inputs):
            if (inp.op == "project"
                    and set(inp.params["cols"]) == set(inp.inputs[0].schema)):
                n.inputs[i] = inp.inputs[0]


# ---------------------------------------------------------------------- #
# Skew-mitigation candidates (consumed by repro.adapt — NOT in RULES:
# salting is a runtime decision, the optimizer only says where it's legal)
# ---------------------------------------------------------------------- #
def skew_candidates(nodes) -> List[LogicalNode]:
    """Shuffle boundaries where hot-key salting is semantically safe.

    * ``groupby`` — only when it actually shuffles and is NOT
      pre-aggregated (pre-aggregation collapses each rank's hot rows to
      one partial per key, which is already skew-immune);
    * ``join`` — only when BOTH sides shuffle (an elided side's rows sit
      wherever the producer left them, so broadcasting hot build rows
      would duplicate the pairs that rank already matches locally).

    Plain ``shuffle`` nodes are never candidates: their contract is
    co-partitioning for a downstream consumer, which salt would break.
    """
    out: List[LogicalNode] = []
    for n in nodes:
        p = n.params
        if (n.op == "groupby" and not p.get("elide_shuffle")
                and not p.get("pre_aggregate")):
            out.append(n)
        elif (n.op == "join" and not p.get("elide_left")
                and not p.get("elide_right")):
            out.append(n)
    return out


# ---------------------------------------------------------------------- #
# Driver
# ---------------------------------------------------------------------- #
RULES = (elide_null_checks, elide_shuffles, select_join_sides,
         split_conjunctions, push_predicates, prune_dead_assignments,
         push_projections, push_preaggregation)


def optimize(root: LogicalNode, catalog=None,
             max_passes: int = 8) -> Tuple[LogicalNode, List[str]]:
    """Run all rules to a fixpoint; returns (root, fired descriptions)."""
    annotate(root, catalog)
    fired: List[str] = []
    for _ in range(max_passes):
        pass_fired: List[str] = []
        for rule in RULES:
            hits = rule(root)
            if hits:
                pass_fired.extend(hits)
                annotate(root)  # refresh properties for downstream rules
        if not pass_fired:
            break
        fired.extend(pass_fired)
    fuse_adjacent_filters(root)
    prune_identity_projects(root)
    annotate(root)
    return root, fired

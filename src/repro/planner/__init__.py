"""Partitioning-aware query optimizer for the distributed dataframe layer.

The paper wins by minimizing dispatches and communication boundaries; this
subsystem makes those boundaries an optimization target:

* ``logical``  — typed logical plan with per-node properties
                 (partitioning / est_rows / live columns),
* ``rules``    — rewrite rules: shuffle elision, join-side selection,
                 predicate & projection pushdown, pre-aggregation,
* ``physical`` — lowering to a stage DAG executed through ``CylonEnv.run``
                 with a structural-fingerprint compile cache,
* ``explain``  — EXPLAIN rendering of stages, properties, and fired rules.

``core.plan.execute`` lowers every plan through here; use
``compile_plan`` + ``run_physical`` directly for more control.
"""

from .logical import (COMM_OPS, LOCAL_OPS, LogicalNode, Partitioning,
                      annotate, build_catalog, copy_dag, from_plan, topo)
from .rules import optimize
from .dictionary import DictTypeError, apply_dictionaries
from .physical import (ExecStats, PhysicalPlan, attach_dictionaries,
                       eval_node, fingerprint, lower, run_physical,
                       shuffle_allgather)
from .morsel import run_morsel
from .explain import explain, render


def compile_plan(plan, tables=None, optimize_plan: bool = True) -> PhysicalPlan:
    """Builder tree (or LogicalNode) -> optimized, lowered PhysicalPlan.

    Dictionary resolution (``planner.dictionary``: recode insertion for
    mismatched join dictionaries, string-literal lowering, validation) runs
    unconditionally — it is a correctness pass, not an optimization.
    """
    catalog = build_catalog(tables)
    node = getattr(plan, "node", plan)
    if isinstance(node, LogicalNode):
        # copy: the rewrite passes below mutate in place, and the caller's
        # DAG may be recompiled against different tables/dictionaries
        root = annotate(copy_dag(node), catalog or None)
    else:
        root = from_plan(node, catalog)
    fired = apply_dictionaries(root)
    if optimize_plan:
        root, opt_fired = optimize(root, catalog)
        fired = fired + opt_fired
    return lower(root, fired)


__all__ = [
    "COMM_OPS", "LOCAL_OPS", "DictTypeError", "ExecStats", "LogicalNode",
    "Partitioning", "PhysicalPlan", "annotate", "apply_dictionaries",
    "attach_dictionaries", "build_catalog", "compile_plan", "copy_dag",
    "eval_node",
    "explain", "fingerprint", "from_plan", "lower", "optimize", "render",
    "run_morsel", "run_physical", "shuffle_allgather", "topo",
]

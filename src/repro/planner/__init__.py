"""Partitioning-aware query optimizer for the distributed dataframe layer.

The paper wins by minimizing dispatches and communication boundaries; this
subsystem makes those boundaries an optimization target:

* ``logical``  — typed logical plan with per-node properties
                 (partitioning / est_rows / live columns),
* ``rules``    — rewrite rules: shuffle elision, join-side selection,
                 predicate & projection pushdown, pre-aggregation,
* ``physical`` — lowering to a stage DAG executed through ``CylonEnv.run``
                 with a structural-fingerprint compile cache,
* ``explain``  — EXPLAIN rendering of stages, properties, and fired rules.

``core.plan.execute`` lowers every plan through here; use
``compile_plan`` + ``run_physical`` directly for more control.
"""

from .logical import (COMM_OPS, LOCAL_OPS, LogicalNode, Partitioning,
                      annotate, build_catalog, from_plan, topo)
from .rules import optimize
from .physical import (ExecStats, PhysicalPlan, eval_node, fingerprint,
                       lower, run_physical, shuffle_allgather)
from .morsel import run_morsel
from .explain import explain, render


def compile_plan(plan, tables=None, optimize_plan: bool = True) -> PhysicalPlan:
    """Builder tree (or LogicalNode) -> optimized, lowered PhysicalPlan."""
    catalog = build_catalog(tables)
    node = getattr(plan, "node", plan)
    if isinstance(node, LogicalNode):
        root = annotate(node, catalog or None)
    else:
        root = from_plan(node, catalog)
    fired = []
    if optimize_plan:
        root, fired = optimize(root, catalog)
    return lower(root, fired)


__all__ = [
    "COMM_OPS", "LOCAL_OPS", "ExecStats", "LogicalNode", "Partitioning",
    "PhysicalPlan", "annotate", "build_catalog", "compile_plan", "eval_node",
    "explain", "fingerprint", "from_plan", "lower", "optimize", "render",
    "run_morsel", "run_physical", "shuffle_allgather", "topo",
]

"""Out-of-core morsel execution: stream datasets larger than device
capacity through the compiled stage DAG (``docs/out_of_core.md``).

The in-core executor (``run_physical``) requires every partition to fit a
fixed per-rank device capacity.  ``run_morsel`` removes that bound: the
streamed input lives in a host-resident ``core.store.SpillTable`` and is
driven through the plan in fixed-capacity *morsels* — one compiled program
per plan segment, a structural-fingerprint cache hit for every morsel after
the first — with double-buffered host->device transfer
(``core.env.MorselSource``) and device->host spill of each morsel's output.

Communication boundaries become external state transitions:

* **shuffle** — hash placement is row-wise, so each morsel's shuffle lands
  rows on their *final* rank; the driver appends every rank's received rows
  to that rank's host spill bucket.  No cross-morsel fixup is needed.
* **groupby** — each morsel emits mergeable partials (``{col}_{agg}``; mean
  stays sum+count) that are hash-placed like the rows they summarize, so
  all partials of a key share a rank.  The cross-morsel combiner
  sub-buckets each rank's spilled partials by key hash (the driver-side
  numpy mirror of the device hash) so every key's partials meet exactly
  once on device, then re-aggregates + finalizes per sub-bucket.
* **sort** — splitters are sampled ONCE from the segment's input spill and
  broadcast to every morsel, so all morsels agree on the rank->key-range
  map; morsels only *route* rows, and the driver runs one stable
  vectorized sort per rank over the spilled range partition.  Cross-rank
  tie order follows the ``by`` columns only, exactly like the in-core
  sample sort.
* **join** — the build (right) side is evaluated once, shuffled to its
  final placement, and kept device-resident; the probe (left) side streams
  against it morsel by morsel.

Supported plan shape: a streamed operator chain from one scan to the root
(``inputs[0]`` edges), with tree-shaped build sides hanging off joins.
Explicit-``dest`` shuffles are row-aligned with the full table and cannot
stream.

Device memory is bounded by the *working capacity* ``W = capacity_factor x
morsel_rows`` (shuffle receive / join output headroom), the resident build
sides, and the groupby combine sub-bucket size — never by the streamed
input.  Capacity pressure drops are ALWAYS counted (the morsel programs
collect the overflow triple unconditionally) and what happens next is the
``overflow=`` policy (``repro.faults.OverflowPolicy``): the default
``degrade`` re-executes the overflowing segment with halved morsel size
(then grown working capacity) until every row fits; ``warn`` keeps the
truncated result and raises one ``RuntimeWarning`` attributing the drops;
``raise`` fails the query with ``CapacityOverflow``.  See
``docs/fault_tolerance.md``.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..adapt import MorselTuner, SplitterEstimator, resolve_adaptive
from ..adapt.hotkeys import plan_salt_decisions, salt_cache_token
from ..core.env import DistTable, MorselSource
from ..core.store import Checkpoint, SpillTable, _round8, respill_routed
from ..faults import (CapacityOverflow, OverflowPolicy, default_degrade_step,
                      resolve_faults, resolve_overflow, resolve_retry,
                      resolve_token, run_with_retries)
from ..dataframe import ops_local
from ..dataframe.groupby import (_normalize, combine_groupby_partials,
                                 groupby_partial)
from ..dataframe.ops_local import hash_columns, hash_columns_np
from ..dataframe.shuffle import replicate_hot_rows, reset_overflow_warnings
from ..dataframe.shuffle import shuffle as df_shuffle
from ..dataframe.table import Table
from ..nulls import mask_name
from ..obs.metrics import record_exec
from ..obs.trace import NULL_TRACER
from .logical import LogicalNode, topo
from .physical import (ExecStats, PhysicalPlan, _hot_mask, _row_bytes,
                       _shuffle_kw, _stat_vec, _sum_stats, _token,
                       attach_dictionaries, build_shuffle_records,
                       check_scan_dictionaries, describe_drops,
                       emit_shuffle_events, eval_node, fingerprint,
                       pair_stat_labels, plan_stat_labels)


@dataclasses.dataclass
class _Acc:
    """Driver-side transfer/dispatch accounting for one morsel run."""

    morsels: int = 0
    dispatches: int = 0
    spill_bytes: int = 0
    h2d_bytes: int = 0
    d2h_bytes: int = 0


# ---------------------------------------------------------------------- #
# Plan-shape analysis
# ---------------------------------------------------------------------- #
def spine(pplan: PhysicalPlan) -> List[LogicalNode]:
    """The streamed operator chain: scan -> ... -> root along inputs[0]."""
    chain: List[LogicalNode] = []
    n = pplan.root
    while True:
        chain.append(n)
        if not n.inputs:
            break
        n = n.inputs[0]
    chain.reverse()
    if chain[0].op != "scan":
        raise ValueError(
            "out-of-core execution streams along inputs[0] edges and needs "
            f"a scan at the head; found {chain[0].op!r}")
    spine_ids = {c.nid for c in chain}
    covered = set(spine_ids)
    for c in chain:
        if c.op == "shuffle" and "dest" in c.params:
            raise ValueError(
                "explicit-dest shuffles are row-aligned with the full table "
                "and cannot stream; use key_cols")
        if c.op == "join":
            sub_ids = {s.nid for s in topo(c.inputs[1])}
            if sub_ids & spine_ids:
                raise ValueError(
                    "out-of-core execution needs tree-shaped build sides "
                    "(the join build side shares nodes with the streamed "
                    "chain)")
            covered |= sub_ids
    extra = sorted(n.op for n in pplan.order if n.nid not in covered)
    if extra:
        raise ValueError(
            f"nodes unreachable from the streamed chain: {extra}")
    return chain


def segments(chain_tail: Sequence[LogicalNode]
             ) -> List[Tuple[List[LogicalNode], str]]:
    """Split the post-scan chain into morsel-program segments.

    A segment runs per-morsel with no cross-morsel interaction except its
    terminal combiner: ``groupby`` ends its segment (partials -> combine),
    ``sort`` forms its own segment (its input spill must be materialized so
    splitters can be sampled once; outputs are merged).  Everything else
    streams straight through (``stream`` terminal).
    """
    segs: List[Tuple[List[LogicalNode], str]] = []
    cur: List[LogicalNode] = []
    for n in chain_tail:
        if n.op == "sort":
            if cur:
                segs.append((cur, "stream"))
                cur = []
            segs.append(([n], "sort"))
        elif n.op == "groupby":
            cur.append(n)
            segs.append((cur, "groupby"))
            cur = []
        else:
            cur.append(n)
    if cur:
        segs.append((cur, "stream"))
    return segs


# ---------------------------------------------------------------------- #
# Host-side helpers
# ---------------------------------------------------------------------- #
def _as_spill(source: Any, parallelism: int) -> SpillTable:
    from ..core.store import respill
    if isinstance(source, DistTable):
        source = SpillTable.from_dist(source)
    elif isinstance(source, dict):
        source = SpillTable.from_numpy(source, parallelism)
    elif not isinstance(source, SpillTable):
        raise TypeError(f"cannot stream a {type(source).__name__}")
    # a spill bucketed for a different gang would silently lose every rank
    # beyond this env's mesh — re-bucket host-side
    return respill(source, parallelism)


def _to_dist(source: Any, parallelism: int) -> DistTable:
    """Build-side inputs must be device-resident (they are assumed to fit)."""
    if isinstance(source, DistTable):
        return source
    from ..core.store import rescatter
    if isinstance(source, dict):
        source = SpillTable.from_numpy(source, parallelism)
    return rescatter(source, parallelism)  # handles any spill gang size


def _schema_of(dist: DistTable) -> Dict[str, Tuple[np.dtype, Tuple[int, ...]]]:
    p, cap = dist.parallelism, dist.capacity
    return {k: (np.dtype(v.dtype), tuple(v.shape[1:]))
            for k, v in dist.columns.items()}


def _append_out(out_spill: SpillTable, dist: DistTable, acc: _Acc) -> None:
    """Spill one morsel-output DistTable to per-rank host buckets (D2H)."""
    p, cap = dist.parallelism, dist.capacity
    counts = np.asarray(dist.row_counts)
    acc.d2h_bytes += counts.nbytes
    host = {}
    for name, arr in dist.columns.items():
        a = np.asarray(arr)
        acc.d2h_bytes += a.nbytes
        host[name] = a.reshape((p, cap) + a.shape[1:])
    for r in range(p):
        c = int(counts[r])
        if c:
            acc.spill_bytes += out_spill.append(
                r, {k: v[r, :c] for k, v in host.items()})


def _host_splitters(spill: SpillTable, col: str, p: int,
                    samples: int) -> np.ndarray:
    """Fixed global splitters for an out-of-core sample sort: per-rank
    evenly-spaced samples pooled into p-1 global quantiles (the driver-side
    twin of ``dataframe.sort._sample_splitters``)."""
    pool = []
    for r in range(spill.parallelism):
        cols_r = spill.rank_concat(r)
        keys = cols_r[col]
        m = cols_r.get(mask_name(col))
        if m is not None:
            # null keys are routed straight to the last rank (nulls-last);
            # their canonical-zero values must not skew the quantiles
            keys = keys[np.asarray(m).astype(bool)]
        n = len(keys)
        if n:
            k = np.sort(keys)
            take = min(samples, n)
            idx = (np.arange(take) * n) // take
            pool.append(k[idx])
    if not pool:
        dtype, _ = spill.schema[col]
        return np.zeros((max(p - 1, 0),), dtype)
    pooled = np.sort(np.concatenate(pool))
    qpos = (np.arange(1, p) * len(pooled)) // p
    return pooled[qpos]


def _host_sort_ranks(spill: SpillTable, by: Sequence[str]) -> SpillTable:
    """Cross-morsel sort combiner: one stable vectorized host sort per rank
    over the range-partitioned rows.  The morsel programs only *route* rows
    (pre-sorting runs on device would be wasted — a vectorized lexsort over
    the concatenation beats a per-row Python k-way merge, and stability
    preserves arrival order for ties)."""
    out = SpillTable(spill.parallelism, schema=spill.schema,
                     dictionaries=spill.dictionaries)
    for r in range(spill.parallelism):
        cols = spill.rank_concat(r)
        n = len(next(iter(cols.values()))) if cols else 0
        if n:
            # minor -> major; per column the null flag outranks the value
            # (nulls-last, matching ops_local._order_keys)
            lex: List[np.ndarray] = []
            for b in reversed(tuple(by)):
                lex.append(cols[b])
                m = cols.get(mask_name(b))
                if m is not None:
                    lex.append((~np.asarray(m).astype(bool)).astype(np.int8))
            order = np.lexsort(tuple(lex))
            out.append(r, {k: v[order] for k, v in cols.items()})
    return out


# ---------------------------------------------------------------------- #
# Morsel-program node evaluation (runs inside shard_map)
# ---------------------------------------------------------------------- #
def _morsel_shuffle_kw(node: LogicalNode, W: int, shuffle_impl: str,
                       a2a_chunks: int, debug_overflow: bool
                       ) -> Dict[str, Any]:
    """Shuffle kwargs for a morsel program: plan-level capacities (sized for
    in-core tables) are replaced by the working capacity ``W``."""
    kw = _shuffle_kw(node)
    for k in ("bucket_capacity", "out_capacity", "samples"):
        kw.pop(k, None)
    kw["bucket_capacity"] = W
    kw.setdefault("impl", shuffle_impl)
    kw.setdefault("a2a_chunks", a2a_chunks)
    if debug_overflow:
        kw.setdefault("debug_overflow", True)
    return kw


def _groupby_wire_width(table: Table, keys, physical, pre: bool) -> int:
    if not pre:
        return _row_bytes(table)
    width = sum(table.columns[k].dtype.itemsize for k in keys)
    for col, names in physical.items():
        width += sum(4 if a == "count" else table.columns[col].dtype.itemsize
                     for a in names)
    return width


def _eval_stream_node(node: LogicalNode, ctx, cur: Table,
                      residents: Dict[int, Table], W: int,
                      shuffle_impl: str, a2a_chunks: int,
                      stats_out, debug_overflow: bool, salt=None) -> Table:
    p_ = node.params
    dec = salt.get(node.nid) if salt else None
    if node.op == "noop":
        return cur
    if node.op == "project":
        # masks ride along with their base columns (never named explicitly)
        cols = list(p_["cols"])
        cols += [mask_name(c) for c in p_["cols"]
                 if mask_name(c) in cur.columns]
        return cur.select(cols)
    if node.op == "filter":
        return ops_local.filter_expr(cur, p_["expr"])
    if node.op == "with_columns":
        return ops_local.with_columns(cur, p_["exprs"])
    if node.op == "add_scalar":
        return ops_local.add_scalar(cur, p_["value"], p_.get("cols"))
    if node.op == "recode":
        return ops_local.recode(cur, p_["cols"])

    # communication ops: capacities are re-derived from the morsel working
    # capacity W — plan-level bucket/out capacities describe in-core tables.
    # bucket_capacity = W lets a single destination absorb a whole morsel
    # (already-placed inputs route every row to the self bucket).
    kw = _morsel_shuffle_kw(node, W, shuffle_impl, a2a_chunks, debug_overflow)

    if node.op == "shuffle":
        lbl = f"shuffle({','.join(p_['key_cols'])})"
        out, st = df_shuffle(cur, ctx.comm, key_cols=p_["key_cols"],
                             out_capacity=W, label=lbl, **kw)
        stats_out.append((lbl, _stat_vec(st, _row_bytes(cur))))
        return out

    if node.op == "join":
        on = p_["on"]
        l, r = cur, residents[node.nid]
        if not p_.get("elide_left"):
            if dec is not None:
                # salted probe (repro.adapt): hot rows stay on their source
                # rank — the resident build side broadcast-appended every
                # hot build row, so the local hash join still finds them
                h = hash_columns(l, [on])
                base = (h % jnp.uint32(ctx.comm.size())).astype(jnp.int32)
                dest = jnp.where(_hot_mask(h, dec.hot_hashes),
                                 jnp.asarray(ctx.comm.rank(), jnp.int32),
                                 base)
                l, st = df_shuffle(l, ctx.comm, dest=dest, out_capacity=W,
                                   label=f"join({on}):left", **kw)
            else:
                l, st = df_shuffle(l, ctx.comm, key_cols=[on],
                                   out_capacity=W,
                                   label=f"join({on}):left", **kw)
            stats_out.append((f"join({on}):left",
                              _stat_vec(st, _row_bytes(cur))))
        out_cap = p_.get("morsel_out_capacity") or W
        out, ov = ops_local.join_local(l, r, on, out_capacity=out_cap,
                                       with_overflow=True)
        z = jnp.zeros((), jnp.int32)
        stats_out.append((f"join({on}):overflow", jnp.stack([z, z, ov])))
        return out

    if node.op == "groupby":
        keys = list(p_["keys"])
        physical, _post = _normalize(p_["aggs"])
        pre = bool(p_.get("pre_aggregate", False))
        gsalt = ((dec.hot_hashes, dec.k)
                 if dec is not None and not pre else None)
        out, st = groupby_partial(cur, ctx.comm, keys, physical,
                                  pre_aggregate=pre,
                                  elide_shuffle=bool(p_.get("elide_shuffle")),
                                  salt=gsalt, out_capacity=W,
                                  label=f"groupby({','.join(keys)})", **kw)
        if st is not None:
            stats_out.append(
                (f"groupby({','.join(keys)})",
                 _stat_vec(st, _groupby_wire_width(cur, keys, physical, pre))))
        return out

    raise ValueError(f"op {node.op!r} cannot run in a morsel segment")


def _seg_stat_labels(seg_nodes: Sequence[LogicalNode]) -> List[str]:
    """Driver-side stat labels for one stream segment, in the exact order
    ``_eval_stream_node`` appends them (the compiled program returns bare
    arrays; attribution is reconstructed from the static plan)."""
    labels: List[str] = []
    for n in seg_nodes:
        p_ = n.params
        if n.op == "shuffle":
            labels.append(f"shuffle({','.join(p_['key_cols'])})")
        elif n.op == "join":
            if not p_.get("elide_left"):
                labels.append(f"join({p_['on']}):left")
            labels.append(f"join({p_['on']}):overflow")
        elif n.op == "groupby" and not p_.get("elide_shuffle"):
            labels.append(f"groupby({','.join(p_['keys'])})")
    return labels


# ---------------------------------------------------------------------- #
# Program builders (each compiled once per segment, reused per morsel).
# Every program returns (table, stat triples) — overflow accounting is
# unconditional so capacity-pressure drops are never silent.
# ---------------------------------------------------------------------- #
def _make_stream_prog(seg_nodes, join_nids, W, shuffle_impl, a2a_chunks,
                      debug_overflow, salt=None):
    def prog(ctx, morsel, *extras):
        residents = dict(zip(join_nids, extras))
        stats: List[Tuple[str, Any]] = []
        cur = morsel
        for node in seg_nodes:
            cur = _eval_stream_node(node, ctx, cur, residents, W,
                                    shuffle_impl, a2a_chunks, stats,
                                    debug_overflow, salt=salt)
        return cur, tuple(a for _, a in stats)
    return prog


def _make_sort_prog(node, W, shuffle_impl, a2a_chunks, debug_overflow):
    """Range-route one morsel by the broadcast splitters.  No device-side
    sort: the host combiner (``_host_sort_ranks``) orders each rank."""
    by = tuple(node.params["by"])
    kw = _morsel_shuffle_kw(node, W, shuffle_impl, a2a_chunks, debug_overflow)

    def prog(ctx, morsel, splitters):
        key = morsel.columns[by[0]]
        dest = jnp.searchsorted(splitters, key,
                                side="right").astype(jnp.int32)
        m = morsel.columns.get(mask_name(by[0]))
        if m is not None:  # nulls-last: null keys land on the final rank
            dest = jnp.where(m, dest, ctx.comm.size() - 1)
        shuffled, st = df_shuffle(morsel, ctx.comm, dest=dest,
                                  out_capacity=W,
                                  label=f"sort({','.join(by)})", **kw)
        return shuffled, (_stat_vec(st, _row_bytes(morsel)),)
    return prog


# ---------------------------------------------------------------------- #
# Resident build sides (join right inputs; assumed to fit on device)
# ---------------------------------------------------------------------- #
def _build_resident(env, jnode: LogicalNode, tables, shuffle_impl,
                    a2a_chunks, collected, acc: _Acc,
                    capacity_factor: float, tracer=NULL_TRACER,
                    salt=None) -> DistTable:
    rroot = jnode.inputs[1]
    sub_order = topo(rroot)
    scan_names = [s.params["name"] for s in sub_order if s.op == "scan"]
    on = jnode.params["on"]
    elide = bool(jnode.params.get("elide_right"))
    dec = salt.get(jnode.nid) if (salt and not elide) else None
    jkw = {k: v for k, v in _shuffle_kw(jnode).items()
           if k != "out_capacity"}
    jkw.setdefault("impl", shuffle_impl)
    jkw.setdefault("a2a_chunks", a2a_chunks)
    if "shuffle_out_capacity" in jnode.params:
        jkw["out_capacity"] = jnode.params["shuffle_out_capacity"]

    def prog(ctx, *local_tables):
        tmap = dict(zip(scan_names, local_tables))
        values: Dict[int, Table] = {}
        stats: List[Tuple[str, Any]] = []
        for node in sub_order:
            values[node.nid] = eval_node(
                node, ctx.comm, values, tmap, "direct", stats,
                shuffle_impl=shuffle_impl, a2a_chunks=a2a_chunks)
        r = values[rroot.nid]
        if not elide:
            width = _row_bytes(r)
            # receive headroom: hash placement is only balanced in
            # expectation, so a capacity-tight build table would drop rows
            jkw.setdefault("out_capacity",
                           _round8(int(r.capacity * capacity_factor)))
            jkw.setdefault("bucket_capacity",
                           _round8(int(r.capacity * capacity_factor)))
            if dec is not None:
                # salted build (repro.adapt): hot rows skip the hash
                # shuffle (overflow bin, uncounted) and are broadcast-
                # appended so every rank's probe morsels find them locally
                h = hash_columns(r, [on])
                hot = _hot_mask(h, dec.hot_hashes)
                base = (h % jnp.uint32(ctx.comm.size())).astype(jnp.int32)
                dest = jnp.where(hot, jnp.int32(ctx.comm.size()), base)
                r2, st = df_shuffle(r, ctx.comm, dest=dest,
                                    label=f"join({on}):right", **jkw)
                stats.append((f"join({on}):right", _stat_vec(st, width)))
                r2, bst = replicate_hot_rows(r, ctx.comm, hot,
                                             dec.hot_cap, r2)
                stats.append((f"join({on}):broadcast",
                              _stat_vec(bst, width)))
                r = r2
            else:
                r, st = df_shuffle(r, ctx.comm, key_cols=[on],
                                   label=f"join({on}):right", **jkw)
                stats.append((f"join({on}):right", _stat_vec(st, width)))
        return r, tuple(a for _, a in stats)

    args = [_to_dist(tables[n], env.parallelism) for n in scan_names]
    labels = plan_stat_labels(sub_order)
    if not elide:
        labels.append(f"join({on}):right")
    if dec is not None:
        labels.append(f"join({on}):broadcast")
    with tracer.span(f"build:join({on})", "stage", ops="resident-build"):
        resident, stats = env.run(
            prog, *args,
            key=("morsel-resident", fingerprint(rroot),
                 # the subtree fingerprint does not cover the join node's own
                 # params (shuffle kwargs, capacities)
                 _token(dict(jnode.params)),
                 env.communicator_name, shuffle_impl, a2a_chunks,
                 capacity_factor, tuple(env._arg_sig(a) for a in args))
                 + salt_cache_token(salt or {}, [jnode.nid]))
        acc.dispatches += 1
        pairs = pair_stat_labels(labels, stats)
        collected.extend(pairs)
        if tracer.enabled:
            jax.block_until_ready(resident.row_counts)
            emit_shuffle_events(tracer, pairs, a2a_chunks)
    return resident


# ---------------------------------------------------------------------- #
# Cross-morsel groupby combine (hash sub-buckets, rank-local)
# ---------------------------------------------------------------------- #
def _combine_groupby(env, part_spill: SpillTable, gnode: LogicalNode,
                     M: int, acc: _Acc, fp: str, si: int,
                     faults=None, token=None) -> SpillTable:
    keys = list(gnode.params["keys"])
    physical, post = _normalize(gnode.params["aggs"])
    # the partials carry no mask for sum/count, so mean nullability is not
    # recoverable from them — the planner's annotation of the groupby
    # *input* supplies it (conservative in the nullable direction)
    nullable = tuple(sorted(set(gnode.inputs[0].nulls) & set(physical)))
    p = part_spill.parallelism
    widest = max(part_spill.rank_rows(r) for r in range(p))
    B = max(1, -(-widest // M))

    # driver-side sub-bucketing: (hash // p) decorrelates from the rank
    # placement (hash % p), so buckets stay balanced on hash-placed ranks.
    # One stable argsort groups each rank's rows by bucket — O(n log n),
    # not O(B*n) repeated mask scans
    rank_sorted: List[Dict[str, np.ndarray]] = []
    rank_offsets: List[np.ndarray] = []
    max_bucket = 1
    for r in range(p):
        cols_r = part_spill.rank_concat(r)
        n = len(next(iter(cols_r.values())))
        if n:
            h = hash_columns_np(cols_r, keys)
            sub = ((h // np.uint32(p)) % np.uint32(B)).astype(np.int64)
            counts_r = np.bincount(sub, minlength=B)
            order = np.argsort(sub, kind="stable")
            cols_r = {k: v[order] for k, v in cols_r.items()}
        else:
            counts_r = np.zeros((B,), np.int64)
        max_bucket = max(max_bucket, int(counts_r.max()))
        rank_sorted.append(cols_r)
        rank_offsets.append(np.concatenate([[0], np.cumsum(counts_r)]))
    cap_b = _round8(max_bucket)

    def prog(ctx, partials):
        return combine_groupby_partials(partials, keys, physical, post,
                                        nullable_cols=nullable)

    out_spill: Optional[SpillTable] = None
    schema = part_spill.schema
    for b in range(B):
        counts = np.zeros((p,), np.int32)
        cols: Dict[str, jnp.ndarray] = {}
        for name, (dtype, trail) in schema.items():
            buf = np.zeros((p, cap_b) + trail, dtype)
            for r in range(p):
                lo, hi = rank_offsets[r][b], rank_offsets[r][b + 1]
                sel = rank_sorted[r][name][lo:hi]
                buf[r, :len(sel)] = sel
                counts[r] = len(sel)
            acc.h2d_bytes += buf.nbytes
            cols[name] = jnp.asarray(buf.reshape((p * cap_b,) + trail))
        acc.h2d_bytes += counts.nbytes
        if faults is not None:
            faults.check("spill:combine", token=token, segment=si, bucket=b)
        dist = DistTable(cols, jnp.asarray(counts), cap_b)
        out = env.run(prog, dist,
                      key=("morsel-combine", fp, si, cap_b, nullable,
                           env.communicator_name,
                           env._arg_sig(dist)))
        acc.dispatches += 1
        if out_spill is None:
            out_spill = SpillTable(p, schema=_schema_of(out))
        _append_out(out_spill, out, acc)
    return out_spill


# ---------------------------------------------------------------------- #
# Driver
# ---------------------------------------------------------------------- #
#: bound on capacity-degrade re-executions: halving morsel_rows from any
#: sane starting point down to 8 plus a few working-capacity doublings
#: fits comfortably; past this the overflow is not capacity-shaped.
_MAX_DEGRADE_BUILD = 8
_MAX_DEGRADE_SEG = 24


def run_morsel(pplan: PhysicalPlan, env, tables: Dict[str, Any],
               morsel_rows: int, mode: str = "bsp",
               collect_stats: bool = False, shuffle_impl: str = "radix",
               a2a_chunks: int = 1, capacity_factor: float = 2.0,
               samples: int = 64, debug_overflow: bool = False,
               tracer=None, retries=None, timeout=None, overflow=None,
               faults=None, adaptive=None):
    """Stream a plan over morsels of ``morsel_rows`` rows per rank.

    Returns a host-resident ``SpillTable`` (or ``(SpillTable, ExecStats)``
    with ``collect_stats=True``).  Device memory is bounded by the working
    capacity ``W = capacity_factor * morsel_rows`` plus resident build
    sides, independent of the streamed input size.

    ``tracer`` (``repro.obs.Tracer``) records build/segment/combine spans,
    per-morsel dispatch spans with spill-append volumes, and per-shuffle
    data events — driver-side only, never part of a compile-cache key.

    Fault tolerance (``repro.faults``, ``docs/fault_tolerance.md``): each
    segment's input spill is a schema-stamped ``core.store.Checkpoint``; a
    segment attempt that faults (``retries`` replays with backoff, fenced
    by ``timeout``) is replayed from that checkpoint verbatim, and its
    partial output spill is discarded — committed results come only from
    the attempt that succeeded, so recovered runs are bit-identical to
    fault-free ones.  ``overflow`` (default ``degrade``) re-executes an
    overflowing segment with halved ``morsel_rows`` (then grown working
    capacity) until no row is dropped; ``faults`` arms a deterministic
    ``FaultPlan`` (None consults ``REPRO_FAULTS``).

    ``adaptive`` (None | bool | dict | ``repro.adapt.AdaptiveConfig``)
    gates runtime skew mitigation (``docs/adaptive.md``): hot-key salting
    of streamed joins/groupbys (with the partial spill host-re-routed to
    key home ranks ahead of the combine), sample-refreshed sort splitters
    when the observed per-rank routing imbalance exceeds a bound, and a
    degrade controller that picks the replay morsel size from the
    observed overflow peak instead of blind halving.  A run where no
    mitigation fires uses exactly the ``adaptive=False`` cache keys.
    """
    if mode == "amt":
        raise ValueError(
            "out-of-core morsel execution requires direct shuffles; the "
            "amt allgather baseline is inherently in-core")
    tr = tracer if tracer is not None else NULL_TRACER
    reset_overflow_warnings()
    fr = resolve_faults(faults)
    policy = resolve_retry(retries)
    token = resolve_token(timeout)
    ovf = resolve_overflow(overflow)
    counters = {"retries": 0, "degraded": 0}

    def _count_retry(attempt, exc):
        counters["retries"] += 1

    p = env.parallelism
    chain = spine(pplan)
    src_name = chain[0].params["name"]
    if src_name not in tables:
        raise KeyError(f"plan scans missing from tables: [{src_name!r}]")
    check_scan_dictionaries(pplan.order, tables)
    # runtime skew mitigation (repro.adapt): decisions are sampled from the
    # host-resident sources before any spill conversion; an empty decision
    # set leaves every compile-cache key exactly as adaptive=False would
    acfg = resolve_adaptive(adaptive)
    adapt_events: List[Dict[str, Any]] = []
    salt = plan_salt_decisions(pplan.order, tables, p, acfg, adapt_events)
    tuner = MorselTuner(acfg, capacity_factor=capacity_factor,
                        events=adapt_events)
    M = _round8(morsel_rows)
    W = max(M, _round8(int(M * capacity_factor)))
    fp = pplan.fingerprint
    acc = _Acc()
    collected: List[Tuple[str, Any]] = []
    hits0, misses0 = env.cache_hits, env.cache_misses
    timing = collect_stats or tr.enabled
    stage_times: List[Tuple[str, float]] = []
    t_query0 = time.perf_counter() if timing else 0.0

    residents: Dict[int, DistTable] = {}
    for node in chain:
        if node.op != "join":
            continue
        t0 = time.perf_counter() if timing else 0.0
        jname = f"build:join({node.params['on']})"
        cf = capacity_factor
        for _ in range(_MAX_DEGRADE_BUILD):
            def _build_once(_node=node, _cf=cf, _jname=jname):
                token.check(_jname)
                # corrupt-capacity scales the build headroom (part of the
                # compile key, so a corrupted build compiles separately
                # and cannot poison the clean cache entry)
                scale = fr.capacity("build:resident", 256, token=token,
                                    join=_node.nid) / 256.0
                pairs: List[Tuple[str, Any]] = []
                dist = _build_resident(env, _node, tables, shuffle_impl,
                                       a2a_chunks, pairs, acc, _cf * scale,
                                       tracer=tr, salt=salt)
                return dist, pairs

            dist, pairs = run_with_retries(
                _build_once, policy=policy, token=token, tracer=tr,
                label=jname, on_retry=_count_retry)
            _, _, b_drop = _sum_stats([a for _, a in pairs])
            if b_drop and ovf == OverflowPolicy.DEGRADE:
                counters["degraded"] += 1
                cf *= 2.0
                continue
            if b_drop and ovf == OverflowPolicy.RAISE:
                raise CapacityOverflow(
                    f"{jname} dropped {b_drop} rows at "
                    f"capacity_factor={cf} (overflow='raise')")
            break
        else:
            raise CapacityOverflow(
                f"{jname} still dropping rows after "
                f"{_MAX_DEGRADE_BUILD} capacity doublings "
                f"(capacity_factor={cf})")
        residents[node.nid] = dist
        collected.extend(pairs)
        if timing:
            jax.block_until_ready(residents[node.nid].row_counts)
            stage_times.append((jname, time.perf_counter() - t0))

    def _respill():
        token.check("spill:respill")
        fr.check("spill:respill", token=token)
        return _as_spill(tables[src_name], p)

    spill = run_with_retries(_respill, policy=policy, token=token,
                             tracer=tr, label="spill:respill",
                             on_retry=_count_retry)

    live_ckpts: List[Checkpoint] = []
    try:
        for si, (nodes, terminal) in enumerate(segments(chain[1:])):
            t0 = time.perf_counter() if timing else 0.0
            seg_name = f"segment:{si}:{terminal}"
            with tr.span(seg_name, "stage",
                         ops=",".join(n.op for n in nodes)) as seg_sp:
                if terminal == "sort" and \
                        nodes[0].params.get("elide_shuffle"):
                    # range-partitioned already: no device work, just order
                    token.check(seg_name)
                    spill = _host_sort_ranks(spill, nodes[0].params["by"])
                    if timing:
                        stage_times.append(
                            (seg_name, time.perf_counter() - t0))
                    continue

                # the segment's input spill is its replay checkpoint:
                # validated before every attempt, released only on commit
                ckpt = Checkpoint(spill)
                live_ckpts.append(ckpt)
                M_seg, W_seg = tuner.initial_morsel(M), W

                def _segment_attempt(_nodes=nodes, _terminal=terminal,
                                     _si=si, _seg_name=seg_name):
                    seg_in = ckpt.validate()
                    token.check(_seg_name)
                    W_a = fr.capacity("segment:launch", W_seg, token=token,
                                      segment=_si)
                    est: Optional[SplitterEstimator] = None
                    if _terminal == "sort":
                        node = _nodes[0]
                        by = node.params["by"]
                        n_samp = node.params.get("samples", samples)
                        spl = _host_splitters(seg_in, by[0], p, n_samp)
                        # refreshable splitters: if the one-shot sample
                        # routes too many rows to one rank, re-sample with
                        # a boosted budget and re-route what already landed
                        est = SplitterEstimator(
                            spl,
                            lambda s, _in=seg_in, _b=by[0]:
                                _host_splitters(_in, _b, p, s),
                            n_samp, acfg, events=adapt_events,
                            label=f"sort({','.join(by)})")
                        extras: Tuple[Any, ...] = (jnp.asarray(spl),)
                        acc.h2d_bytes += spl.nbytes
                        prog = _make_sort_prog(node, W_a, shuffle_impl,
                                               a2a_chunks, debug_overflow)
                        seg_labels = [f"sort({','.join(by)})"]
                    else:
                        join_nodes = [n for n in _nodes if n.op == "join"]
                        extras = tuple(residents[n.nid]
                                       for n in join_nodes)
                        prog = _make_stream_prog(
                            _nodes, [n.nid for n in join_nodes], W_a,
                            shuffle_impl, a2a_chunks, debug_overflow,
                            salt=salt)
                        seg_labels = _seg_stat_labels(_nodes)
                    key = ("morsel-seg", fp, _si, M_seg, W_a, shuffle_impl,
                           a2a_chunks, env.communicator_name,
                           debug_overflow,
                           tuple(env._arg_sig(e) for e in extras)) \
                        + salt_cache_token(salt, [n.nid for n in _nodes])
                    source = MorselSource(seg_in, M_seg, env, tracer=tr,
                                          faults=fr, token=token)
                    out_spill: Optional[SpillTable] = None
                    pairs: List[Tuple[str, Any]] = []
                    for mi, morsel in enumerate(source):
                        with tr.span(f"morsel[{mi}]", "morsel",
                                     segment=_si):
                            if mi == 0:
                                fr.check("morsel:compile", token=token,
                                         segment=_si)
                            fr.check("morsel:execute", token=token,
                                     segment=_si, morsel=mi)
                            out, unit_stats = env.run(prog, morsel,
                                                      *extras, key=key)
                            acc.dispatches += 1
                            acc.morsels += 1
                            unit_pairs = pair_stat_labels(seg_labels,
                                                          unit_stats)
                            pairs.extend(unit_pairs)
                            if out_spill is None:
                                out_spill = SpillTable(
                                    p, schema=_schema_of(out))
                            b0 = acc.spill_bytes
                            fr.check("transfer:d2h", token=token,
                                     segment=_si, morsel=mi)
                            _append_out(out_spill, out, acc)
                            fr.check("spill:append", token=token,
                                     segment=_si, morsel=mi)
                            tr.instant(f"spill:morsel[{mi}]", "spill",
                                       segment=_si,
                                       bytes=acc.spill_bytes - b0)
                            if tr.enabled:
                                emit_shuffle_events(tr, unit_pairs,
                                                    a2a_chunks)
                            if est is not None and est.observe(
                                    np.asarray(out.row_counts)):
                                # same shapes/dtypes -> same program; only
                                # the splitter VALUES change, so the swap
                                # never recompiles
                                extras = (jnp.asarray(est.splitters),)
                                acc.h2d_bytes += est.splitters.nbytes
                    acc.h2d_bytes += source.h2d_bytes
                    res = out_spill
                    if _terminal == "groupby":
                        gdec = salt.get(_nodes[-1].nid) if salt else None
                        if gdec is not None and res is not None:
                            # salted partials live on k salt ranks; route
                            # every partial to its key's home rank so the
                            # rank-local combiner sees each key exactly once
                            gkeys = list(_nodes[-1].params["keys"])
                            res = respill_routed(
                                res,
                                lambda cols, _k=gkeys:
                                    (hash_columns_np(cols, _k)
                                     % np.uint32(p)).astype(np.int64),
                                tracer=tr)
                        # the combiner runs inside the attempt: a fault
                        # mid-combine replays the whole segment from its
                        # input checkpoint (partials are discarded)
                        with tr.span(f"combine:groupby[{_si}]", "stage"):
                            res = _combine_groupby(env, res, _nodes[-1],
                                                   M_seg, acc, fp, _si,
                                                   faults=fr, token=token)
                    elif _terminal == "sort":
                        if est is not None and est.refreshes and \
                                res is not None:
                            # a refresh breaks range disjointness between
                            # early and late morsels — re-route the spilled
                            # rows by the final splitters before ordering
                            fin = est.splitters

                            def _dest(cols, _f=fin, _b=by[0]):
                                d = np.searchsorted(
                                    _f, cols[_b],
                                    side="right").astype(np.int64)
                                m = cols.get(mask_name(_b))
                                if m is not None:  # nulls-last
                                    d = np.where(
                                        np.asarray(m).astype(bool),
                                        d, p - 1)
                                return d
                            res = respill_routed(res, _dest, tracer=tr)
                        with tr.span(f"host_sort({','.join(by)})",
                                     "stage"):
                            res = _host_sort_ranks(res, by)
                    return (res, pairs, source.num_morsels,
                            source.h2d_bytes)

                for _ in range(_MAX_DEGRADE_SEG):
                    out_spill, attempt_pairs, seg_morsels, seg_h2d = \
                        run_with_retries(_segment_attempt, policy=policy,
                                         token=token, tracer=tr,
                                         label=seg_name,
                                         on_retry=_count_retry)
                    _, _, seg_drop = _sum_stats(
                        [a for _, a in attempt_pairs])
                    if seg_drop and ovf == OverflowPolicy.DEGRADE:
                        # never drop a row: replay with a morsel size that
                        # fits.  The tuner jumps straight to the size the
                        # observed overflow peak implies (and never splits
                        # a salted segment — its routing is already
                        # balanced, so it grows W instead); with autotune
                        # off, the original blind halving applies.
                        counters["degraded"] += 1
                        if tuner.enabled:
                            M_seg, W_seg = tuner.degrade(
                                M_seg, W_seg,
                                [pr[1] for pr in attempt_pairs],
                                salted=any(n.nid in salt for n in nodes),
                                label=seg_name)
                        else:
                            M_seg, W_seg = default_degrade_step(M_seg,
                                                                W_seg)
                        continue
                    if seg_drop and ovf == OverflowPolicy.RAISE:
                        raise CapacityOverflow(
                            f"{seg_name} dropped {seg_drop} rows "
                            f"(overflow='raise'); raise capacity_factor "
                            f"or use overflow='degrade'")
                    break
                else:
                    raise CapacityOverflow(
                        f"{seg_name} still dropping rows after "
                        f"{_MAX_DEGRADE_SEG} degrade steps "
                        f"(morsel_rows={M_seg}, working_capacity={W_seg})")

                # commit: only the successful attempt's stats are recorded,
                # keyed by (label, segment) so per-label histograms never
                # mix morsel counts from different segments
                if tuner.enabled:
                    tuner.observe_expansion(
                        sum(spill.rank_rows(r) for r in range(p)),
                        sum(out_spill.rank_rows(r) for r in range(p))
                        if out_spill is not None else 0)
                collected.extend(
                    (lbl, arr, si) for lbl, arr in attempt_pairs)
                ckpt.release()
                seg_sp.set(morsels=seg_morsels, h2d_bytes=seg_h2d)
                spill = out_spill
            if timing:
                stage_times.append((seg_name, time.perf_counter() - t0))
    finally:
        # a cancelled/failed query releases its checkpoints (the spills
        # they guard belong to the run and are dropped with it)
        for c in live_ckpts:
            if not c.released:
                c.release()

    spill = attach_dictionaries(spill, pplan.root)
    rows, byts, dropped = _sum_stats([pr[1] for pr in collected])
    records = build_shuffle_records(collected)
    if dropped and ovf == OverflowPolicy.WARN:
        where = describe_drops(records)
        warnings.warn(
            f"out-of-core execution dropped {dropped} rows to capacity "
            f"pressure ({where or 'unattributed'}) — raise capacity_factor "
            f"(currently {capacity_factor}) or morsel_rows, or use "
            f"overflow='degrade' to trade speed for completeness",
            RuntimeWarning, stacklevel=2)
    if not collect_stats:
        return spill
    from .physical import scan_read_stats
    rows_read, bytes_read = scan_read_stats(pplan.scan_names, tables)
    stats = ExecStats(
        "morsel", pplan.num_stages, pplan.num_shuffles, acc.dispatches,
        rows, byts, pplan.shuffle_labels(), pplan.fired,
        rows_read=rows_read, bytes_read=bytes_read,
        shuffle_impl=shuffle_impl, a2a_chunks=a2a_chunks,
        rows_dropped=dropped,
        cache_hits=env.cache_hits - hits0,
        cache_misses=env.cache_misses - misses0,
        morsel_rows=M, morsels=acc.morsels, spill_bytes=acc.spill_bytes,
        h2d_bytes=acc.h2d_bytes, d2h_bytes=acc.d2h_bytes,
        wall_time_s=time.perf_counter() - t_query0,
        stage_times=stage_times, shuffle_records=records,
        retries=counters["retries"], degraded=counters["degraded"],
        faults_injected=fr.injected,
        adaptive=acfg.enabled, salted_shuffles=len(salt),
        splitter_refreshes=sum(1 for e in adapt_events
                               if e.get("kind") == "splitter_refresh"),
        autotune_steps=tuner.steps, adapt_events=list(adapt_events))
    record_exec(stats, fp, stats.wall_time_s)
    return spill, stats

"""EXPLAIN: render a (logical or lowered) plan with stages, partitioning
properties, row estimates, and the optimizer rules that fired.

>>> from repro.core import Plan
>>> from repro.planner import explain
>>> print(explain(Plan.scan("t").shuffle(["k"]).groupby(["k"], {"v": ["sum"]}),
...               {"t": (("k", "v"), 10_000)}))
"""

from __future__ import annotations

from typing import Any, Dict, Mapping, Optional

from .logical import LogicalNode
from .physical import PhysicalPlan


def _label(n: LogicalNode) -> str:
    p = n.params
    if n.op == "scan":
        # ingested sources (repro.io) carry a provenance summary:
        # ``scan[parquet: 3 files, ~1000 rows]``
        return f"scan[{p['source']}]" if p.get("source") else \
            f"scan[{p['name']}]"
    if n.op == "noop":
        return f"noop[{p.get('note', '')}]"
    if n.op == "project":
        return f"project[{','.join(p['cols'])}]"
    if n.op == "filter":
        return f"filter[{p['expr']!r}]"
    if n.op == "with_columns":
        assigns = ",".join(f"{name}={e!r}"
                           for name, e in sorted(p["exprs"].items()))
        return f"with_columns[{assigns}]"
    if n.op == "add_scalar":
        cols = p.get("cols")
        return f"add_scalar[{','.join(cols) if cols else '*'}]"
    if n.op == "recode":
        parts = ",".join(f"{c}:|D|={len(p['targets'][c])}"
                         for c in sorted(p["targets"]))
        return f"recode[{parts}]"
    if n.op == "shuffle":
        extra = "".join(f"; {k}={p[k]}" for k in ("impl", "a2a_chunks")
                        if k in p)
        return f"shuffle[{','.join(p['key_cols'])}{extra}]"
    if n.op == "join":
        notes = [s for s, f in (("left-elided", "elide_left"),
                                ("right-elided", "elide_right")) if p.get(f)]
        extra = f" ({', '.join(notes)})" if notes else ""
        return f"join[on={p['on']}]{extra}"
    if n.op == "groupby":
        aggs = ";".join(f"{c}:{','.join(a)}" for c, a in sorted(p["aggs"].items()))
        notes = []
        if p.get("elide_shuffle"):
            notes.append("shuffle-elided")
        elif p.get("pre_aggregate"):
            notes.append("pre-agg")
        extra = f" ({', '.join(notes)})" if notes else ""
        return f"groupby[{','.join(p['keys'])}; {aggs}]{extra}"
    if n.op == "sort":
        extra = " (shuffle-elided)" if p.get("elide_shuffle") else ""
        return f"sort[{','.join(p['by'])}]{extra}"
    return n.op


#: public alias — EXPLAIN ANALYZE (``repro.obs.analyze``) renders the same
#: per-node labels with measured actuals appended
node_label = _label


def adapt_note(event: Mapping[str, Any]) -> str:
    """EXPLAIN ANALYZE annotation for one fired adaptive event (the dict
    form recorded in ``ExecStats.adapt_events`` — serializable, so reports
    round-trip through ``to_dict``).  Mirrors ``SaltDecision.note``."""
    if event.get("op") == "groupby":
        return f"salted[k:{event['k']}, hot:{event['hot_keys']}]"
    return (f"salted[broadcast, hot:{event['hot_keys']}, "
            f"cap:{event['hot_cap']}]")


def render(pplan: PhysicalPlan, mode: str = "bsp",
           shuffle_impl: str = "radix", a2a_chunks: int = 1,
           morsel_rows: Optional[int] = None) -> str:
    # amt executes the allgather object-store shuffle; the bucketize/chunking
    # knobs are inert there, so show what actually runs
    shuf = ("allgather" if mode == "amt"
            else f"{shuffle_impl}/c{a2a_chunks}")
    ooc = ("" if morsel_rows is None
           else f"out-of-core={morsel_rows} rows/morsel, ")
    lines = [
        f"== physical plan: {pplan.num_stages} stages, "
        f"{pplan.num_shuffles} shuffles, mode={mode}, "
        f"shuffle={shuf}, {ooc}"
        f"fingerprint={pplan.fingerprint[:12]} =="
    ]
    by_stage: Dict[int, list] = {}
    for n in pplan.order:
        by_stage.setdefault(pplan.stage_of[n.nid], []).append(n)
    for s in sorted(by_stage):
        lines.append(f"stage {s}:")
        for n in by_stage[s]:
            lines.append(
                f"  {_label(n):44s} rows~{int(n.est_rows):>9d}  "
                f"part={str(n.partitioning):12s} cols={','.join(n.schema)}")
    if pplan.fired:
        lines.append("rules fired:")
        for f in pplan.fired:
            lines.append(f"  - {f}")
    else:
        lines.append("rules fired: (none)")
    return "\n".join(lines)


def explain(plan: Any, tables: Optional[Mapping[str, Any]] = None,
            optimize_plan: bool = True, mode: str = "bsp",
            shuffle_impl: str = "radix", a2a_chunks: int = 1,
            morsel_rows: Optional[int] = None) -> str:
    """Render EXPLAIN output for a ``core.plan.Plan`` (or raw builder node /
    LogicalNode).  ``tables`` supplies scan schemas: DistTables,
    ``(cols, rows)`` pairs, or plain column sequences.  ``shuffle_impl`` /
    ``a2a_chunks`` are the plan-wide shuffle knobs shown in the header
    (per-node overrides appear in the node labels); ``morsel_rows`` marks
    out-of-core morsel execution in the header."""
    from . import compile_plan  # deferred: the package imports this module
    return render(compile_plan(plan, tables, optimize_plan=optimize_plan),
                  mode, shuffle_impl=shuffle_impl,
                  a2a_chunks=a2a_chunks, morsel_rows=morsel_rows)

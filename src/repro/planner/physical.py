"""Lowering: logical DAG -> staged physical plan -> CylonEnv execution.

A *stage* is a maximal set of operators executable in one BSP program
without crossing a communication boundary (the paper's §III-B coalescing,
made explicit).  Elided shuffles do not open a boundary, so optimization
shrinks both the stage count (fewer dispatches in ``bsp_staged``) and the
shuffle count (fewer collectives in every mode).

The compile cache is keyed by a **structural fingerprint** of the plan
(op/param/topology hash, independent of node identity), so two separately
built but identical plans share one compiled program per env.

Execution modes (same contract as the original ``core.plan.execute``):

* ``bsp``        — entire plan in ONE ``env.run`` dispatch,
* ``bsp_staged`` — one dispatch per stage (driver round-trip at every
                   communication boundary),
* ``amt``        — one dispatch per operator, shuffles implemented as
                   allgather-then-select (the Dask/Ray object-store
                   pattern, O(p·data)).
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
import time
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..comm import Communicator
from ..faults import (CapacityOverflow, OverflowPolicy, resolve_faults,
                      resolve_overflow, resolve_retry, resolve_token,
                      run_with_retries)
from ..obs.metrics import record_exec
from ..obs.trace import NULL_TRACER
from ..dataframe import ops_local
from ..expr import token as expr_token
from ..dataframe.groupby import (_normalize, finalize_groupby,
                                 nullable_agg_cols)
from ..dataframe.groupby import groupby as df_groupby
from ..dataframe.ops_local import hash_columns
from ..dataframe.shuffle import ShuffleStats, _round_up
from ..dataframe.shuffle import shuffle as df_shuffle
from ..dataframe.sort import _range_dest
from ..dataframe.sort import sort as df_sort
from ..nulls import mask_name
from ..dataframe.table import Table
from .logical import LogicalNode, topo

#: param keys that are operator semantics, not shuffle kwargs
_SEMANTIC = {
    "join": ("on", "out_capacity", "shuffle_out_capacity", "elide_left",
             "elide_right", "side_selected", "morsel_out_capacity"),
    "groupby": ("keys", "aggs", "elide_shuffle", "pre_aggregate"),
    "sort": ("by", "elide_shuffle"),
    "shuffle": ("key_cols",),
}


# ---------------------------------------------------------------------- #
# Structural fingerprint
# ---------------------------------------------------------------------- #
# Canonical value tokens live in ``repro.expr`` (expressions fingerprint by
# VALUE — two structurally equal expression trees share a token however
# they were built — while legacy callables hash bytecode + captured
# closure values, the best a callable allows).
_token = expr_token


def fingerprint(root: LogicalNode) -> str:
    """Structural hash: equal for identically-shaped plans regardless of
    node identity / construction order (fixes nid-keyed cache misses)."""
    idx: Dict[int, int] = {}
    parts: List[str] = []
    for n in topo(root):
        idx[n.nid] = len(idx)
        params = ",".join(f"{k}={_token(v)}" for k, v in sorted(n.params.items()))
        parts.append(f"{n.op}({params})<-{[idx[i.nid] for i in n.inputs]}")
    return hashlib.sha1("\n".join(parts).encode()).hexdigest()


# ---------------------------------------------------------------------- #
# Physical plan
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class PhysicalPlan:
    root: LogicalNode
    order: List[LogicalNode]              # full topological order
    stage_of: Dict[int, int]              # nid -> stage index
    num_stages: int
    num_shuffles: int
    fingerprint: str
    fired: Tuple[str, ...] = ()           # optimizer rules that fired

    @property
    def scan_names(self) -> List[str]:
        return sorted({n.params["name"] for n in self.order
                       if n.op == "scan"})

    def shuffle_labels(self) -> List[str]:
        """Static labels for every shuffle executed, in topo order."""
        labels: List[str] = []
        for n in self.order:
            p = n.params
            if n.op == "shuffle":
                labels.append(f"shuffle({','.join(p['key_cols'])})")
            elif n.op == "join":
                if not p.get("elide_left"):
                    labels.append(f"join({p['on']}):left")
                if not p.get("elide_right"):
                    labels.append(f"join({p['on']}):right")
            elif n.op == "groupby" and not p.get("elide_shuffle"):
                labels.append(f"groupby({','.join(p['keys'])})")
            elif n.op == "sort" and not p.get("elide_shuffle"):
                labels.append(f"sort({','.join(p['by'])})")
        return labels


def lower(root: LogicalNode, fired: Sequence[str] = ()) -> PhysicalPlan:
    order = topo(root)
    stage_of: Dict[int, int] = {}
    for n in order:
        stage_of[n.nid] = max(
            (stage_of[i.nid] + (1 if i.is_comm() else 0) for i in n.inputs),
            default=0)
    num_stages = max(stage_of.values(), default=0) + 1
    num_shuffles = sum(n.shuffle_count() for n in order)
    return PhysicalPlan(root, order, stage_of, num_stages, num_shuffles,
                        fingerprint(root), tuple(fired))


# ---------------------------------------------------------------------- #
# Shuffle implementations (direct vs the AMT object-store baseline)
# ---------------------------------------------------------------------- #
def shuffle_allgather(table: Table, comm: Communicator,
                      key_cols=None, dest=None, out_capacity=None, **_):
    """Every rank receives ALL rows and keeps those hashed to it.

    Models Dask partd / Ray object-store data sharing: data is published
    globally rather than routed, costing O(p·rows) bandwidth per rank.
    """
    p = comm.size()
    rank = comm.rank()
    cap = table.capacity
    out_cap = out_capacity or cap
    valid = table.valid_mask()
    if dest is None:
        h = hash_columns(table, key_cols)
        dest = (h % jnp.uint32(p)).astype(jnp.int32)
    dest = jnp.where(valid, dest, p)

    gathered_dest = comm.all_gather(dest).reshape(-1)            # (p*cap,)
    keep = gathered_dest == rank
    order = jnp.argsort(jnp.where(keep, 0, 1), stable=True)[:out_cap]
    new_count = jnp.minimum(jnp.sum(keep), out_cap).astype(jnp.int32)
    cols = {}
    for name, col in table.columns.items():
        g = comm.all_gather(col).reshape((-1,) + col.shape[1:])
        cols[name] = jnp.take(g, order, axis=0)
    sent = jax.ops.segment_sum(jnp.ones((cap,), jnp.int32), dest,
                               num_segments=p + 1)[:p]
    stats = ShuffleStats(sent, sent, jnp.asarray(0, jnp.int32),
                         jnp.maximum(jnp.sum(keep) - out_cap, 0)
                         .astype(jnp.int32),
                         shuffle_impl="allgather")
    return Table(cols, new_count).mask_padding(), stats


def _row_bytes(table: Table) -> int:
    return sum(int(v.dtype.itemsize) * math.prod(v.shape[1:])
               for v in table.columns.values())


def _stat_vec(st: ShuffleStats, width: int) -> jax.Array:
    """(rows sent, bytes sent, rows dropped) — the per-shuffle stats triple
    collected inside the program and summed driver-side."""
    rows = jnp.sum(st.sent_counts)
    dropped = (st.send_dropped + st.recv_dropped).astype(jnp.int32)
    return jnp.stack([rows, rows * width, dropped])


# ---------------------------------------------------------------------- #
# Per-shuffle stat attribution (driver-side labels for the in-program
# stats triples; the compiled programs return arrays only, so the label
# sequence is reconstructed from the static plan in dispatch order)
# ---------------------------------------------------------------------- #
def node_stat_labels(node: LogicalNode, salt=None) -> List[str]:
    """Stat labels ``eval_node`` appends for one node, in append order.

    Mirrors ``eval_node`` exactly: shuffle-executing ops contribute one
    label per shuffle; joins additionally contribute an ``:overflow``
    entry (local join output capacity pressure, zero wire bytes).  With a
    fired salting decision (``salt`` maps nid -> SaltDecision) a groupby
    additionally appends its ``:remerge`` partial shuffle and a join its
    ``:broadcast`` hot-row replication (before ``:overflow``)."""
    p = node.params
    salted = salt is not None and node.nid in salt
    if node.op == "shuffle":
        return [f"shuffle({','.join(p['key_cols'])})"]
    if node.op == "join":
        labels = []
        if not p.get("elide_left"):
            labels.append(f"join({p['on']}):left")
        if not p.get("elide_right"):
            labels.append(f"join({p['on']}):right")
        if salted:
            labels.append(f"join({p['on']}):broadcast")
        labels.append(f"join({p['on']}):overflow")
        return labels
    if node.op == "groupby" and not p.get("elide_shuffle"):
        label = f"groupby({','.join(p['keys'])})"
        return [label, f"{label}:remerge"] if salted else [label]
    if node.op == "sort" and not p.get("elide_shuffle"):
        return [f"sort({','.join(p['by'])})"]
    return []


def plan_stat_labels(nodes: Sequence[LogicalNode], salt=None) -> List[str]:
    out: List[str] = []
    for n in nodes:
        out.extend(node_stat_labels(n, salt))
    return out


def pair_stat_labels(labels: Sequence[str], arrays: Sequence[Any]
                     ) -> List[Tuple[str, Any]]:
    """Zip driver-side labels with the in-program stat arrays; falls back
    to positional labels on a mismatch rather than mis-attributing."""
    if len(labels) != len(arrays):
        labels = [f"stats[{i}]" for i in range(len(arrays))]
    return list(zip(labels, arrays))


@dataclasses.dataclass
class ShuffleRecord:
    """Aggregated per-label shuffle accounting with per-rank attribution.

    ``per_rank_rows[r]`` — rows rank ``r`` sent through this shuffle;
    ``per_rank_dropped[r]`` — rows lost at rank ``r`` (send-bucket or
    receive/ join-output capacity pressure).  ``:overflow`` labels carry
    drops only (no wire traffic)."""

    label: str
    rows: int
    bytes: int
    dropped: int
    per_rank_rows: Tuple[int, ...]
    per_rank_dropped: Tuple[int, ...]
    #: out-of-core segment index the label executed in (None in-core).
    #: Keying records by (label, segment) keeps a plan that runs the same
    #: shuffle label in several segments — e.g. a groupby replayed after a
    #: degrade split — attributable per segment instead of smeared into
    #: one row, which is what the skew detector and EXPLAIN ANALYZE need.
    segment: Optional[int] = None


def build_shuffle_records(pairs: Sequence[Tuple]) -> List[ShuffleRecord]:
    """Aggregate labeled (p, 3) stat arrays by (label, segment) — summing
    across repeated executions of the same plan node, e.g. one per morsel.
    ``pairs`` entries are ``(label, array)`` (in-core; segment None) or
    ``(label, array, segment)`` (morsel executor)."""
    agg: Dict[Tuple[str, Optional[int]], np.ndarray] = {}
    order: List[Tuple[str, Optional[int]]] = []
    for pair in pairs:
        label, a = pair[0], pair[1]
        seg = pair[2] if len(pair) > 2 else None
        a = np.asarray(a).reshape(-1, 3).astype(np.int64)
        key = (label, seg)
        if key in agg:
            agg[key] = agg[key] + a
        else:
            agg[key] = a.copy()
            order.append(key)
    return [ShuffleRecord(
        label, int(agg[k][:, 0].sum()), int(agg[k][:, 1].sum()),
        int(agg[k][:, 2].sum()),
        tuple(int(x) for x in agg[k][:, 0]),
        tuple(int(x) for x in agg[k][:, 2]),
        segment=seg) for k in order for label, seg in [k]]


def describe_drops(records: Sequence[ShuffleRecord], limit: int = 6) -> str:
    """Name the op labels and ranks where capacity pressure dropped rows
    (the attribution the rows_dropped RuntimeWarning reports)."""
    offenders = [(r.label, rank, d)
                 for r in records
                 for rank, d in enumerate(r.per_rank_dropped) if d]
    parts = [f"{label} @ rank {rank}: {d} rows"
             for label, rank, d in offenders[:limit]]
    if len(offenders) > limit:
        parts.append(f"... {len(offenders) - limit} more")
    return "; ".join(parts)


def emit_shuffle_events(tracer, pairs: Sequence[Tuple[str, Any]],
                        a2a_chunks: int) -> None:
    """Per-shuffle (and per all-to-all chunk) instant events under the
    currently open stage span.  Device-side op timing is invisible to the
    driver, so these carry data volumes, not durations."""
    for pair in pairs:
        label, a = pair[0], pair[1]
        a = np.asarray(a).reshape(-1, 3)
        rows, byts, dropped = (int(a[:, 0].sum()), int(a[:, 1].sum()),
                               int(a[:, 2].sum()))
        with tracer.span(f"shuffle:{label}", "shuffle", rows=rows,
                         bytes=byts, dropped=dropped):
            if not label.endswith(":overflow"):
                for c in range(max(1, a2a_chunks)):
                    tracer.instant(f"a2a:{label}[chunk {c}]", "chunk",
                                   chunk=c, chunks=a2a_chunks,
                                   bytes=byts // max(1, a2a_chunks))


# ---------------------------------------------------------------------- #
# Node evaluation (runs inside shard_map; shared by all modes)
# ---------------------------------------------------------------------- #
def _shuffle_kw(node: LogicalNode) -> Dict[str, Any]:
    keep = _SEMANTIC.get(node.op, ())
    return {k: v for k, v in node.params.items()
            if k not in keep and k not in ("elided", "note", "expr", "exprs")}


def eval_node(node: LogicalNode, comm: Communicator,
              values: Dict[int, Table], tables: Dict[str, Table],
              shuffle_mode: str,
              stats_out: Optional[List[Tuple[str, jax.Array]]] = None,
              shuffle_impl: str = "radix", a2a_chunks: int = 1,
              salt=None) -> Table:
    p = node.params
    ins = [values[i.nid] for i in node.inputs]
    shuffle_fn = df_shuffle if shuffle_mode == "direct" else shuffle_allgather
    decision = salt.get(node.nid) if (salt and shuffle_mode == "direct") \
        else None

    def run_shuffle(label: str, table: Table, **kw) -> Table:
        out, st = shuffle_fn(table, comm, label=label, **kw)
        if stats_out is not None:
            stats_out.append((label, _stat_vec(st, _row_bytes(table))))
        return out

    if node.op == "scan":
        return tables[p["name"]]
    if node.op == "noop":
        return ins[0]
    if node.op == "project":
        # masks ride along with their base columns (never named explicitly)
        cols = list(p["cols"])
        cols += [mask_name(c) for c in p["cols"]
                 if mask_name(c) in ins[0].columns]
        return ins[0].select(cols)
    if node.op == "filter":
        return ops_local.filter_expr(ins[0], p["expr"])
    if node.op == "with_columns":
        return ops_local.with_columns(ins[0], p["exprs"])
    if node.op == "add_scalar":
        return ops_local.add_scalar(ins[0], p["value"], p.get("cols"))
    if node.op == "recode":
        return ops_local.recode(ins[0], p["cols"])

    kw = _shuffle_kw(node)
    if shuffle_mode == "direct":
        # plan-level defaults; per-node params (Plan.shuffle(impl=...,
        # a2a_chunks=...)) take precedence
        kw.setdefault("impl", shuffle_impl)
        kw.setdefault("a2a_chunks", a2a_chunks)
    else:
        kw.pop("impl", None)
        kw.pop("a2a_chunks", None)
        kw.pop("debug_overflow", None)
    if node.op == "shuffle":
        out_cap = kw.pop("out_capacity", None)
        return run_shuffle(f"shuffle({','.join(p['key_cols'])})", ins[0],
                           key_cols=p["key_cols"], out_capacity=out_cap, **kw)

    if node.op == "join":
        on = p["on"]
        l, r = ins
        jkw = {k: v for k, v in kw.items() if k != "out_capacity"}
        if "shuffle_out_capacity" in p:  # receive headroom for skewed keys
            jkw["out_capacity"] = p["shuffle_out_capacity"]
        if decision is not None and not p.get("elide_left") \
                and not p.get("elide_right"):
            return _eval_join_salted(node, comm, l, r, decision, jkw,
                                     stats_out)
        if not p.get("elide_left"):
            l = run_shuffle(f"join({on}):left", l, key_cols=[on], **jkw)
        if not p.get("elide_right"):
            r = run_shuffle(f"join({on}):right", r, key_cols=[on], **jkw)
        if stats_out is not None:
            out, ov = ops_local.join_local(l, r, on,
                                           out_capacity=p.get("out_capacity"),
                                           with_overflow=True)
            z = jnp.zeros((), jnp.int32)
            stats_out.append((f"join({on}):overflow", jnp.stack([z, z, ov])))
            return out
        return ops_local.join_local(l, r, on,
                                    out_capacity=p.get("out_capacity"))

    if node.op == "groupby":
        keys, aggs = p["keys"], p["aggs"]
        physical, post = _normalize(aggs)
        nullable = nullable_agg_cols(ins[0], physical)
        if p.get("elide_shuffle"):
            # input already co-partitioned on the keys: local-only groupby
            final = ops_local.groupby_local(ins[0], keys, physical)
            return finalize_groupby(final, keys, post, nullable)
        if (decision is not None and shuffle_mode == "direct"
                and not p.get("pre_aggregate")):
            return _eval_groupby_salted(node, comm, ins[0], decision, kw,
                                        stats_out)
        if shuffle_mode == "direct":
            pre = bool(p.get("pre_aggregate", False))
            out, st = df_groupby(ins[0], comm, keys, aggs,
                                 pre_aggregate=pre,
                                 label=f"groupby({','.join(keys)})", **kw)
            if stats_out is not None:
                if pre:
                    # the wire carries keys + stage-1 partial-agg columns
                    width = sum(ins[0].columns[k].dtype.itemsize for k in keys)
                    for col, names in physical.items():
                        width += sum(4 if a == "count"
                                     else ins[0].columns[col].dtype.itemsize
                                     for a in names)
                else:
                    width = _row_bytes(ins[0])
                stats_out.append((f"groupby({','.join(keys)})",
                                  _stat_vec(st, width)))
            return out
        # AMT path: ship raw rows (Dask-style task granularity, no pre-agg)
        shuffled = run_shuffle(f"groupby({','.join(keys)})", ins[0],
                               key_cols=list(keys),
                               **{k: v for k, v in kw.items()
                                  if k != "pre_aggregate"})
        final = ops_local.groupby_local(shuffled, keys, physical)
        return finalize_groupby(final, keys, post, nullable)

    if node.op == "sort":
        by = p["by"]
        if p.get("elide_shuffle"):
            return ops_local.sort_local(ins[0], by)
        if shuffle_mode == "direct":
            out, st = df_sort(ins[0], comm, by,
                              label=f"sort({','.join(by)})", **kw)
            if stats_out is not None:
                stats_out.append((f"sort({','.join(by)})",
                                  _stat_vec(st, _row_bytes(ins[0]))))
            return out
        dest = _range_dest(ins[0], by[0], comm, kw.pop("samples", 64))
        shuffled = run_shuffle(f"sort({','.join(by)})", ins[0], dest=dest,
                               **kw)
        return ops_local.sort_local(shuffled, by)

    raise ValueError(node.op)


# ---------------------------------------------------------------------- #
# Salted evaluation (repro.adapt; in-core, inside shard_map)
# ---------------------------------------------------------------------- #
def _hot_mask(h: jax.Array, hot_hashes) -> jax.Array:
    """Rows whose key hash is one of the (static) hot constants."""
    hot = jnp.zeros(h.shape, jnp.bool_)
    for v in hot_hashes:
        hot = hot | (h == jnp.uint32(v))
    return hot


def _eval_groupby_salted(node: LogicalNode, comm: Communicator,
                         table: Table, decision, kw, stats_out) -> Table:
    """Two-shuffle salted groupby: salted row shuffle + stage-1 partials,
    then a tiny unsalted partial re-merge on each key's home rank.

    Both shuffles get full-table bucket/out capacities: the whole point of
    the decision is that one rank would otherwise receive ~everything, so
    per-destination "balanced share" sizing is exactly what we can't
    assume until the salt has done its job."""
    from ..dataframe.groupby import groupby_salted
    p = node.params
    keys = list(p["keys"])
    cap = table.capacity
    label = f"groupby({','.join(keys)})"
    skw = dict(kw, bucket_capacity=cap, label=label)
    skw["out_capacity"] = skw.get("out_capacity") or cap
    rkw = dict(kw, bucket_capacity=cap, out_capacity=cap,
               label=f"{label}:remerge")
    out, st1, st2 = groupby_salted(table, comm, keys, p["aggs"],
                                   decision.hot_hashes, decision.k,
                                   shuffle_kw=skw, remerge_kw=rkw)
    if stats_out is not None:
        physical, _ = _normalize(p["aggs"])
        width = sum(table.columns[k].dtype.itemsize for k in keys)
        for col, names in physical.items():
            width += sum(4 if a == "count"
                         else table.columns[col].dtype.itemsize
                         for a in names)
        stats_out.append((label, _stat_vec(st1, _row_bytes(table))))
        stats_out.append((f"{label}:remerge", _stat_vec(st2, width)))
    return out


def _eval_join_salted(node: LogicalNode, comm: Communicator,
                      l: Table, r: Table, decision, jkw, stats_out) -> Table:
    """Skew-mitigated hash join: hot probe rows stay on their source rank,
    hot build rows skip the hash shuffle (overflow bin, uncounted) and are
    broadcast-appended to every rank's build table instead — so each hot
    probe row meets every build row of its key locally, exactly once."""
    from ..dataframe.shuffle import replicate_hot_rows
    p = node.params
    on = p["on"]
    psize = comm.size()
    rank = comm.rank()

    hot_l = _hot_mask(hash_columns(l, [on]), decision.hot_hashes)
    hot_r = _hot_mask(hash_columns(r, [on]), decision.hot_hashes)
    base_l = (hash_columns(l, [on]) % jnp.uint32(psize)).astype(jnp.int32)
    base_r = (hash_columns(r, [on]) % jnp.uint32(psize)).astype(jnp.int32)
    dest_l = jnp.where(hot_l, jnp.asarray(rank, jnp.int32), base_l)
    dest_r = jnp.where(hot_r, jnp.int32(psize), base_r)  # excluded

    # probe: the self-bucket must hold every hot row this rank keeps, and
    # the output every kept-hot + received-cold row
    lkw = dict(jkw, bucket_capacity=l.capacity)
    lkw["out_capacity"] = (lkw.get("out_capacity")
                           or _round_up(2 * l.capacity, 8))
    rkw = dict(jkw)
    rkw["out_capacity"] = rkw.get("out_capacity") or r.capacity

    l2, st_l = df_shuffle(l, comm, dest=dest_l,
                          label=f"join({on}):left", **lkw)
    r2, st_r = df_shuffle(r, comm, dest=dest_r,
                          label=f"join({on}):right", **rkw)
    r2, st_b = replicate_hot_rows(r, comm, hot_r, decision.hot_cap, r2)
    if stats_out is not None:
        stats_out.append((f"join({on}):left", _stat_vec(st_l, _row_bytes(l))))
        stats_out.append((f"join({on}):right", _stat_vec(st_r, _row_bytes(r))))
        stats_out.append((f"join({on}):broadcast",
                          _stat_vec(st_b, _row_bytes(r))))
        out, ov = ops_local.join_local(l2, r2, on,
                                       out_capacity=p.get("out_capacity"),
                                       with_overflow=True)
        z = jnp.zeros((), jnp.int32)
        stats_out.append((f"join({on}):overflow", jnp.stack([z, z, ov])))
        return out
    return ops_local.join_local(l2, r2, on,
                                out_capacity=p.get("out_capacity"))


# ---------------------------------------------------------------------- #
# Driver-side execution
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class ExecStats:
    """Driver-side observability for one plan execution."""

    mode: str
    num_stages: int
    num_shuffles: int
    dispatches: int
    rows_shuffled: int
    bytes_shuffled: int
    shuffle_labels: List[str]
    fired: Tuple[str, ...]
    shuffle_impl: str = "radix"   # bucketize path: radix | sorted | allgather
    a2a_chunks: int = 1           # all-to-all pipeline depth
    #: rows lost to capacity pressure anywhere in the plan (send buckets,
    #: receive tables, join output) — deterministic post-hoc overflow check;
    #: 0 for a correctly-capacitated run
    rows_dropped: int = 0
    #: compile-cache traffic during this execution (CylonEnv counters delta)
    cache_hits: int = 0
    cache_misses: int = 0
    # -- ingest attribution (repro.io scans; docs/io.md) ------------------ #
    rows_read: int = 0        # rows entering the plan through its scans
    bytes_read: int = 0       # source bytes behind those scans (io ingest)
    # -- out-of-core morsel execution only (see docs/out_of_core.md) ----- #
    morsel_rows: Optional[int] = None  # per-rank morsel capacity, None=in-core
    morsels: int = 0                   # morsel program dispatches
    spill_bytes: int = 0               # valid rows written to host spill
    h2d_bytes: int = 0                 # host->device morsel transfer bytes
    d2h_bytes: int = 0                 # device->host spill transfer bytes
    # -- timing (populated on collect_stats=True / traced runs; fenced ---- #
    # -- with jax.block_until_ready so device execution is covered) ------- #
    wall_time_s: float = 0.0           # end-to-end dispatch+execute wall time
    #: per-dispatch-unit wall times: (unit label, seconds).  One entry per
    #: stage in bsp_staged, per operator in amt, per segment (plus resident
    #: builds / combines) out-of-core; a single "program" entry in bsp,
    #: where XLA fuses all stages into one dispatch.
    stage_times: List[Tuple[str, float]] = \
        dataclasses.field(default_factory=list)
    #: per-shuffle-label accounting with per-rank attribution (aggregated
    #: across morsels); rows/bytes sum to rows_shuffled/bytes_shuffled
    shuffle_records: List["ShuffleRecord"] = \
        dataclasses.field(default_factory=list)
    # -- fault tolerance (repro.faults; docs/fault_tolerance.md) ---------- #
    retries: int = 0           # dispatch units replayed after a fault
    degraded: int = 0          # capacity-degrade re-executions (overflow)
    faults_injected: int = 0   # faults the active FaultPlan fired this query
    # -- runtime skew mitigation (repro.adapt; docs/adaptive.md) ---------- #
    adaptive: bool = False         # was the adaptive layer enabled
    salted_shuffles: int = 0       # shuffle boundaries that got salted
    splitter_refreshes: int = 0    # sort splitter re-samples that fired
    autotune_steps: int = 0        # tuner-chosen degrade replans
    #: one dict per fired mitigation ({"kind": "salted" | ...}) — the
    #: machine-readable trail EXPLAIN ANALYZE renders as annotations
    adapt_events: List[Dict[str, Any]] = \
        dataclasses.field(default_factory=list)


def check_scan_dictionaries(order: Sequence[LogicalNode],
                            tables: Dict[str, Any]) -> None:
    """Reject runtime tables whose dictionaries differ from compile time.

    Recode gather tables and lowered string literals are baked into the
    compiled plan from the *compile-time* catalog; running that plan
    against a table with a different dictionary would silently decode
    fabricated strings.  Tables without a ``dictionaries`` attribute (raw
    numpy dicts) were encoded by ``build_catalog`` at compile time and are
    re-encoded identically at ingest, so only holder mismatches can occur.
    """
    for n in order:
        if n.op != "scan":
            continue
        t = tables.get(n.params["name"])
        got = getattr(t, "dictionaries", None)
        if got is None:
            continue
        want = {c: d for c, d in n.dicts.items() if c in n.schema}
        if dict(got) != want:
            diff = sorted(set(got) ^ set(want)
                          | {c for c in set(got) & set(want)
                             if tuple(got[c]) != want[c]})
            raise ValueError(
                f"scan {n.params['name']!r}: table dictionaries for "
                f"{diff} differ from the ones this plan was compiled "
                f"against — re-run compile_plan/execute with the current "
                f"tables (recode tables and lowered string literals are "
                f"baked in at compile time)")


def attach_dictionaries(out, root: LogicalNode):
    """Re-attach driver-side dictionaries to an execution result.

    The compiled programs move int32 codes only; the annotated root knows
    which output columns are dictionary-encoded and by what dictionary
    (``LogicalNode.dicts``), so the driver restores the metadata here.
    """
    if root.dicts and hasattr(out, "dictionaries"):
        live = set(getattr(out, "column_names", ()) or root.dicts)
        out.dictionaries = {c: d for c, d in root.dicts.items() if c in live}
    return out


def scan_read_stats(names: Sequence[str], tables: Dict[str, Any]
                    ) -> Tuple[int, int]:
    """(rows_read, bytes_read) across a plan's scan tables.

    Rows come from the holder's ``total_rows``; bytes from the ``repro.io``
    ingest provenance (``IngestInfo.bytes_read``) when the table was read
    from Parquet/CSV, 0 for tables built in memory."""
    rows = byts = 0
    for n in names:
        t = tables.get(n)
        if t is None:
            continue
        total = getattr(t, "total_rows", None)
        if callable(total):
            try:
                rows += int(total())
            except Exception:
                pass
        prov = getattr(t, "provenance", None)
        if prov is not None:
            byts += int(getattr(prov, "bytes_read", 0))
    return rows, byts


def _sum_stats(collected) -> Tuple[int, int, int]:
    """``collected``: (p, 3) arrays -> (rows sent, bytes sent, rows dropped)."""
    tot = np.zeros((3,), np.int64)
    for a in collected:
        tot += np.asarray(a).reshape(-1, 3).sum(axis=0)
    return int(tot[0]), int(tot[1]), int(tot[2])


def run_physical(pplan: PhysicalPlan, env, tables: Dict[str, Any],
                 mode: str = "bsp", collect_stats: bool = False,
                 shuffle_impl: str = "radix", a2a_chunks: int = 1,
                 morsel_rows: Optional[int] = None, tracer=None,
                 retries=None, timeout=None, overflow=None, faults=None,
                 scan_capacity: Optional[int] = None, adaptive=None,
                 **morsel_kw):
    """Execute a lowered plan against DistTables on a ``CylonEnv``.

    Returns a DistTable, or ``(DistTable, ExecStats)`` with
    ``collect_stats=True``.  ``shuffle_impl``/``a2a_chunks`` set the
    plan-wide shuffle defaults (per-node params override); both are part of
    the compile-cache key and recorded in the stats so benchmark output can
    attribute wins.

    ``tracer`` (a ``repro.obs.Tracer``) records per-dispatch stage spans —
    fenced with ``jax.block_until_ready`` so durations cover device
    execution — plus per-shuffle data-volume events when stats are
    collected.  Tracing is purely driver-side: it is NOT part of any
    compile-cache key and cannot change what gets compiled.  With
    ``collect_stats=True`` (tracer or not), ``ExecStats`` additionally
    carries ``wall_time_s`` / per-unit ``stage_times`` / per-label
    ``shuffle_records``, and the execution is folded into the process-global
    ``repro.obs.METRICS`` registry.

    ``morsel_rows`` switches to the out-of-core morsel executor
    (``planner.morsel.run_morsel``): the input is streamed through the
    compiled stage DAG in fixed-capacity morsels and the result is returned
    as a host-resident ``core.store.SpillTable``.  Extra ``morsel_kw``
    (``capacity_factor``, ``samples``, ``debug_overflow``) are forwarded.

    Fault tolerance (``repro.faults``, ``docs/fault_tolerance.md``):
    ``retries`` (None | int | ``RetryPolicy``) replays failed dispatch
    units with exponential backoff; ``timeout`` (seconds or a
    ``CancellationToken``) fences every dispatch and backoff sleep;
    ``overflow`` (``raise | warn | degrade``, default ``degrade``) decides
    what to do when capacity pressure drops rows — ``degrade`` re-executes
    out-of-core until every row fits (observable drops require
    ``collect_stats=True`` in-core; the morsel executor always counts).
    ``faults`` arms a deterministic ``FaultPlan`` (None consults
    ``REPRO_FAULTS``).  All of this is driver-side: with injection
    disabled, compile-cache keys are identical to a run without the
    harness.

    ``adaptive`` (None | bool | dict | ``AdaptiveConfig``) gates runtime
    skew mitigation (``repro.adapt``, ``docs/adaptive.md``): hot-key
    salting at shuffle boundaries here, splitter refresh + morsel
    autotuning in the out-of-core executor.  Default on; a run where no
    mitigation fires uses exactly the ``adaptive=False`` cache keys.
    """
    if morsel_rows is not None:
        from .morsel import run_morsel
        return run_morsel(pplan, env, tables, morsel_rows, mode=mode,
                          collect_stats=collect_stats,
                          shuffle_impl=shuffle_impl, a2a_chunks=a2a_chunks,
                          tracer=tracer, retries=retries, timeout=timeout,
                          overflow=overflow, faults=faults,
                          adaptive=adaptive, **morsel_kw)
    if morsel_kw:
        raise TypeError(f"unexpected kwargs without morsel_rows: "
                        f"{sorted(morsel_kw)}")
    from ..dataframe.shuffle import reset_overflow_warnings
    reset_overflow_warnings()
    fr = resolve_faults(faults)
    policy = resolve_retry(retries)
    token = resolve_token(timeout)
    ovf = resolve_overflow(overflow)
    counters = {"retries": 0}

    def _count_retry(attempt, exc):
        counters["retries"] += 1

    tr = tracer if tracer is not None else NULL_TRACER
    names = pplan.scan_names
    missing = [n for n in names if n not in tables]
    if missing:
        raise KeyError(f"plan scans missing from tables: {missing}")
    check_scan_dictionaries(pplan.order, tables)
    # host-resident ingest sources (repro.io SpillTables) scatter onto the
    # gang for in-core execution.  The default per-rank capacity leaves 2x
    # headroom over a balanced split (downstream shuffles inherit scan
    # capacity, and hash placement skews); ``scan_capacity`` overrides.
    # Provenance rides along for the scan read stats.
    from ..core.store import SpillTable, _round8
    from ..core.store import rescatter as _rescatter
    spills = {n: tables[n] for n in names
              if isinstance(tables[n], SpillTable)}
    if spills:
        def _cap(s):
            if scan_capacity is not None:
                return scan_capacity
            per = -(-max(s.total_rows(), 1) // env.parallelism)
            return _round8(2 * per)
        tables = {**tables, **{n: _rescatter(s, env.parallelism,
                                             capacity=_cap(s))
                               for n, s in spills.items()}}
    root = pplan.root
    order = pplan.order
    fp = pplan.fingerprint
    shuffle_mode = "allgather" if mode == "amt" else "direct"
    # -- runtime skew detection (repro.adapt) -- driver-side sampling of
    # the (now device-resident) scan tables; an empty decision set leaves
    # every compile-cache key below exactly as adaptive=False would.
    # AMT shuffles are allgather-based (every rank sees all rows), which
    # is skew-immune by construction, so salting is direct-mode only.
    from ..adapt import resolve_adaptive
    from ..adapt.hotkeys import plan_salt_decisions, salt_cache_token
    acfg = resolve_adaptive(adaptive)
    adapt_events: List[Dict[str, Any]] = []
    salt = (plan_salt_decisions(order, tables, env.parallelism, acfg,
                                adapt_events)
            if shuffle_mode == "direct" else {})
    eval_kw = dict(shuffle_impl=shuffle_impl, a2a_chunks=a2a_chunks,
                   salt=salt)
    hits0, misses0 = env.cache_hits, env.cache_misses
    timing = collect_stats or tr.enabled
    stage_times: List[Tuple[str, float]] = []
    t_query0 = time.perf_counter() if timing else 0.0

    def mk_stats(dispatches: int, pairs) -> ExecStats:
        rows, byts, dropped = _sum_stats([pr[1] for pr in pairs])
        rows_read, bytes_read = scan_read_stats(names, tables)
        stats = ExecStats(mode, pplan.num_stages, pplan.num_shuffles,
                          dispatches, rows, byts, pplan.shuffle_labels(),
                          pplan.fired,
                          shuffle_impl=("allgather" if mode == "amt"
                                        else shuffle_impl),
                          a2a_chunks=a2a_chunks, rows_dropped=dropped,
                          cache_hits=env.cache_hits - hits0,
                          cache_misses=env.cache_misses - misses0,
                          rows_read=rows_read, bytes_read=bytes_read,
                          wall_time_s=time.perf_counter() - t_query0,
                          stage_times=stage_times,
                          shuffle_records=build_shuffle_records(pairs),
                          retries=counters["retries"],
                          faults_injected=fr.injected,
                          adaptive=acfg.enabled,
                          salted_shuffles=len(salt),
                          adapt_events=list(adapt_events))
        record_exec(stats, fp, stats.wall_time_s)
        return stats

    def finish(result, stats):
        """Apply the overflow policy to a finished stats run: raise, warn
        once (attributed), or degrade — replay the whole plan out-of-core
        (drops are counted unconditionally there, and the morsel executor's
        own degrade loop shrinks morsels until everything fits), then
        re-scatter the spill back to a device-resident ``DistTable``."""
        if not stats.rows_dropped or ovf == OverflowPolicy.WARN:
            if stats.rows_dropped:
                warnings.warn(
                    f"capacity pressure dropped {stats.rows_dropped} rows "
                    f"({describe_drops(stats.shuffle_records)}) — raise "
                    f"capacities or use overflow='degrade'",
                    RuntimeWarning, stacklevel=3)
            return result, stats
        if ovf == OverflowPolicy.RAISE:
            raise CapacityOverflow(
                f"capacity pressure dropped {stats.rows_dropped} rows "
                f"({describe_drops(stats.shuffle_records)}); raise "
                f"bucket/out capacities or use overflow='degrade'")
        # degrade: the in-core capacities were wrong, so in-core replay
        # cannot help — stream the plan out-of-core instead, starting at
        # the scan tables' own per-rank capacity
        from ..core.store import rescatter
        from .morsel import run_morsel
        caps = [t.capacity for t in (tables[n] for n in names)
                if hasattr(t, "capacity")]
        m0 = max(caps) if caps else 128
        try:
            spill, d_stats = run_morsel(
                pplan, env, tables, m0, mode="bsp", collect_stats=True,
                shuffle_impl=shuffle_impl, a2a_chunks=a2a_chunks, tracer=tr,
                retries=policy, timeout=token,
                overflow=OverflowPolicy.DEGRADE, faults=fr, adaptive=acfg)
        except ValueError as e:
            raise CapacityOverflow(
                f"capacity pressure dropped {stats.rows_dropped} rows "
                f"({describe_drops(stats.shuffle_records)}) and the plan "
                f"cannot degrade to out-of-core execution ({e}); raise "
                f"capacities or handle overflow='raise'") from e
        out = attach_dictionaries(rescatter(spill, env.parallelism), root)
        d_stats.degraded += 1
        d_stats.retries += stats.retries
        d_stats.dispatches += stats.dispatches
        return out, d_stats

    if mode == "bsp":
        def prog(ctx, *local_tables):
            tmap = dict(zip(names, local_tables))
            values: Dict[int, Table] = {}
            stats: List[Tuple[str, jax.Array]] = []
            for node in order:
                values[node.nid] = eval_node(
                    node, ctx.comm, values, tmap, "direct",
                    stats if collect_stats else None, **eval_kw)
            out = values[root.nid]
            if collect_stats:
                return out, tuple(a for _, a in stats)
            return out

        with tr.span("stage:program", "stage", mode=mode,
                     stages=pplan.num_stages, dispatch=0) as sp:
            t0 = time.perf_counter() if timing else 0.0

            def dispatch():
                token.check("stage:program")
                fr.check("stage:launch", token=token, stage=0)
                if pplan.num_shuffles:
                    for c in range(max(1, a2a_chunks)):
                        fr.check("a2a:chunk", token=token, stage=0, chunk=c)
                return env.run(prog, *[tables[n] for n in names],
                               key=("bsp", fp, env.communicator_name,
                                    collect_stats, shuffle_impl, a2a_chunks)
                                   + salt_cache_token(salt))

            res = run_with_retries(dispatch, policy=policy, token=token,
                                   tracer=tr, label="stage:program",
                                   on_retry=_count_retry)
            sp.set(compiled=env.cache_misses > misses0)
            out = res[0] if collect_stats else res
            if timing:
                jax.block_until_ready(
                    (out.row_counts,) + (res[1] if collect_stats else ()))
                stage_times.append(("program", time.perf_counter() - t0))
            if collect_stats and tr.enabled:
                emit_shuffle_events(
                    tr, pair_stat_labels(plan_stat_labels(order, salt),
                                         res[1]),
                    a2a_chunks)
        if collect_stats:
            pairs = pair_stat_labels(plan_stat_labels(order, salt), res[1])
            return finish(attach_dictionaries(out, root), mk_stats(1, pairs))
        return attach_dictionaries(out, root)

    if mode in ("bsp_staged", "amt"):
        values: Dict[int, Any] = {}
        collected: List[Tuple[str, Any]] = []
        dispatches = 0

        if mode == "bsp_staged":
            groups: Dict[int, List[LogicalNode]] = {}
            for node in order:
                groups.setdefault(pplan.stage_of[node.nid], []).append(node)
            units = [groups[s] for s in sorted(groups)]
            unit_names = [f"stage:{s}" for s in sorted(groups)]
        else:
            units = [[node] for node in order]
            unit_names = [f"op:{i}:{n.op}" for i, n in enumerate(order)]

        for uidx, unit in enumerate(units):
            unit_ids = {n.nid for n in unit}
            ext: List[LogicalNode] = []
            for n in unit:
                for i in n.inputs:
                    if i.nid not in unit_ids and i.nid not in {e.nid for e in ext}:
                        ext.append(i)
            scans = [n for n in unit if n.op == "scan"]
            later = set()
            for other in order:
                if other.nid in unit_ids:
                    continue
                later.update(i.nid for i in other.inputs)
            outs = [n for n in unit
                    if n.nid == root.nid or n.nid in later]

            def prog(ctx, *local_ins, _unit=unit, _ext=ext, _scans=scans,
                     _outs=outs):
                vals = {e.nid: t for e, t in zip(_ext, local_ins)}
                tmap = dict(zip([s.params["name"] for s in _scans],
                                local_ins[len(_ext):]))
                stats: List[Tuple[str, jax.Array]] = []
                for node in _unit:
                    vals[node.nid] = eval_node(
                        node, ctx.comm, vals, tmap, shuffle_mode,
                        stats if collect_stats else None, **eval_kw)
                out = tuple(vals[n.nid] for n in _outs)
                if collect_stats:
                    return out, tuple(a for _, a in stats)
                return out

            args = [values[e.nid] for e in ext] + \
                   [tables[s.params["name"]] for s in scans]
            with tr.span(unit_names[uidx], "stage", mode=mode,
                         dispatch=uidx,
                         ops=",".join(n.op for n in unit)) as sp:
                t0 = time.perf_counter() if timing else 0.0
                m0 = env.cache_misses
                has_comm = any(n.is_comm() for n in unit)

                unit_salt = salt_cache_token(salt, [n.nid for n in unit])

                def dispatch(_uidx=uidx, _args=args, _prog=prog,
                             _has_comm=has_comm, _usalt=unit_salt):
                    token.check(unit_names[_uidx])
                    fr.check("stage:launch", token=token, stage=_uidx)
                    if _has_comm:
                        for c in range(max(1, a2a_chunks)):
                            fr.check("a2a:chunk", token=token, stage=_uidx,
                                     chunk=c)
                    return env.run(
                        _prog, *_args,
                        key=(mode, fp, _uidx, env.communicator_name,
                             collect_stats, shuffle_impl, a2a_chunks)
                            + _usalt)

                res = run_with_retries(dispatch, policy=policy, token=token,
                                       tracer=tr, label=unit_names[uidx],
                                       on_retry=_count_retry)
                sp.set(compiled=env.cache_misses > m0)
                if collect_stats:
                    out_tuple, unit_stats = res
                    unit_pairs = pair_stat_labels(
                        plan_stat_labels(unit, salt), unit_stats)
                    collected.extend(unit_pairs)
                else:
                    out_tuple = res
                dispatches += 1
                for n, val in zip(outs, out_tuple):
                    jax.block_until_ready(val.row_counts)  # completion barrier
                    values[n.nid] = val
                if timing:
                    if collect_stats:
                        jax.block_until_ready(unit_stats)
                    stage_times.append(
                        (unit_names[uidx], time.perf_counter() - t0))
                if collect_stats and tr.enabled:
                    emit_shuffle_events(tr, unit_pairs, a2a_chunks)

        result = attach_dictionaries(values[root.nid], root)
        if collect_stats:
            return finish(result, mk_stats(dispatches, collected))
        return result

    raise ValueError(f"unknown mode {mode!r}")

"""Dictionary resolution pass: make string semantics explicit in the plan.

Runs on every compile — **before** and independently of the optimizer,
because it is a *correctness* pass, not a rewrite heuristic (it fires with
``optimize=False`` too).  Three jobs, all driven by the per-node
``LogicalNode.dicts`` annotation:

1. **Recode insertion** — a join whose two inputs carry *different*
   dictionaries for the key column compares codes from different code
   spaces; a ``recode`` node (static int32 gather table,
   ``dataframe.schema.recode_mapping``) is inserted above each divergent
   input, remapping onto the sorted union of both dictionaries.  The node
   is visible in EXPLAIN (``recode[k: |D|=N]``) and runs inside the
   compiled program like any local operator.  Equal keys then share codes
   gang-wide, so hashing/sorting/merging codes is exact.

2. **String-literal lowering** — ``filter`` / ``with_columns`` expressions
   containing string literals are rewritten into int32 code comparisons
   against the input's dictionary (``dataframe.schema.lower_expr``):
   ``col("s") < "oak"`` becomes ``s < lit(int32(k))`` via searchsorted on
   the sorted dictionary.  The lowered literal is part of the expression
   fingerprint, so different dictionaries compile distinct programs.

3. **Validation** — operations with no dictionary-code semantics raise
   ``DictTypeError`` at compile time with a message naming the column:
   arithmetic on string columns, sum/mean aggregates over them, string
   vs numeric comparisons, and joins of a string key against a numeric
   key.

The pass mutates the logical DAG in place (the builder tree the user holds
is never touched — ``from_plan`` copies params) and returns EXPLAIN-style
"fired" records for every recode it inserted.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from ..dataframe.schema import (DictTypeError, lower_expr, merge_dictionaries,
                                recode_mapping)
from .logical import LogicalNode, annotate, topo

__all__ = ["apply_dictionaries", "DictTypeError"]


def _insert_recode(join: LogicalNode, side: int, on: str,
                   target: Tuple[str, ...]) -> str:
    inp = join.inputs[side]
    old = inp.dicts[on]
    node = LogicalNode(
        "recode", [inp],
        {"cols": {on: recode_mapping(old, target)},
         "targets": {on: target}})
    join.inputs[side] = node
    name = "left" if side == 0 else "right"
    return (f"recode: join({on}) {name} input remapped onto the merged "
            f"dictionary (|{len(old)}| -> |{len(target)}|)")


def _resolve_joins(root: LogicalNode) -> List[str]:
    """Insert recode nodes until every join's key dictionaries agree.

    Topo order + re-annotation per pass lets merged dictionaries flow into
    downstream joins (a join chain converges in as many passes as its
    depth; the bound is a safety net, not a tuning knob).
    """
    fired: List[str] = []
    for _ in range(64):
        hits = 0
        for n in topo(root):
            if n.op != "join":
                continue
            on = n.params["on"]
            l, r = n.inputs
            ld, rd = l.dicts.get(on), r.dicts.get(on)
            if (ld is None) != (rd is None):
                side = "left" if ld is None else "right"
                raise DictTypeError(
                    f"join on {on!r} mixes a dictionary-encoded string key "
                    f"with a numeric key (the {side} input is numeric)")
            if ld is None or ld == rd:
                continue
            target = merge_dictionaries(ld, rd)
            if ld != target:
                fired.append(_insert_recode(n, 0, on, target))
            if rd != target:
                fired.append(_insert_recode(n, 1, on, target))
            hits += 1
        if not hits:
            return fired
        annotate(root)
    raise RuntimeError("recode insertion did not converge")


def _lower_exprs(root: LogicalNode) -> None:
    for n in topo(root):
        p = n.params
        dicts = n.inputs[0].dicts if n.inputs else {}
        if n.op == "filter":
            lowered, out_dict = lower_expr(p["expr"], dicts)
            if out_dict is not None:
                raise DictTypeError(
                    f"filter predicate {p['expr']!r} yields a string value, "
                    f"not a boolean mask")
            p["expr"] = lowered
        elif n.op == "with_columns":
            # copy before mutating: the exprs dict may still be shared
            # with the user's builder tree (from_plan is a shallow copy)
            exprs, assign_dicts = {}, {}
            for name, e in p["exprs"].items():
                exprs[name], d = lower_expr(e, dicts)
                if d is not None:
                    assign_dicts[name] = d
            p["exprs"] = exprs
            if assign_dicts:
                p["assign_dicts"] = assign_dicts


def _validate(root: LogicalNode) -> None:
    for n in topo(root):
        p = n.params
        dicts = n.inputs[0].dicts if n.inputs else {}
        if n.op == "groupby":
            for col, agg_names in p["aggs"].items():
                if col not in dicts:
                    continue
                bad = [a for a in agg_names
                       if a not in ("min", "max", "count")]
                if bad:
                    raise DictTypeError(
                        f"aggregate(s) {bad} are not defined on the "
                        f"dictionary-encoded string column {col!r}; "
                        f"supported: min, max, count")
        elif n.op == "add_scalar":
            touched = p.get("cols")
            touched = set(dicts if touched is None else touched)
            bad = sorted(touched & set(dicts))
            if bad:
                raise DictTypeError(
                    f"add_scalar touches dictionary-encoded string "
                    f"column(s) {bad}; arithmetic is not defined on "
                    f"strings — pass cols= to restrict it")


def apply_dictionaries(root: LogicalNode) -> List[str]:
    """Run the full pass on an annotated DAG; returns fired-recode records.

    The DAG is left re-annotated (recode nodes change downstream
    dictionaries and partitioning properties).
    """
    fired = _resolve_joins(root)
    _lower_exprs(root)
    _validate(root)
    annotate(root)
    return fired

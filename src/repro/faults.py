"""Deterministic fault injection + the recovery machinery it proves out.

The paper's pitch is running a BSP dataframe engine *inside* generic
executors (Dask/Ray) whose headline feature is resilience — yet a BSP gang
is exactly where one lost worker or one overfull buffer kills (or silently
corrupts) the whole query.  This module maps executor-grade fault tolerance
onto the pseudo-BSP model:

* **Injection** — every hazard point in the execution spine is a registered
  *site* (``SITES``).  A ``FaultPlan`` — a seeded, deterministic list of
  ``FaultSpec`` (site pattern x occurrence index x failure kind) — decides
  which site visits fail.  Kinds: ``raise`` (the dispatch dies), ``hang``
  (the dispatch blocks until the query deadline), ``corrupt-capacity``
  (a buffer is silently under-sized, forcing capacity overflow).  Plans
  come from code, from the ``REPRO_FAULTS`` env var (via ``repro.flags``),
  or from ``random_plan`` (chaos testing under a fixed seed).

* **Retry** — ``RetryPolicy``: exponential backoff with deterministic
  jitter.  The executors replay failed dispatch units from driver-held
  inputs (in-core) or from comm-boundary spill checkpoints
  (``core.store.Checkpoint``, out-of-core), so a recovered query is
  bit-identical to the fault-free run.

* **Deadline / cancellation** — ``CancellationToken``: a driver-side
  deadline checked between morsels/stages and inside backoff sleeps, so
  hung dispatches and long retry loops are fenced by
  ``df.collect(timeout=...)``.

* **Overflow policy** — ``OverflowPolicy`` (``raise | warn | degrade``)
  replaces silent row drops: under ``degrade`` (the default) an overflowing
  segment re-executes out-of-core with auto-halved ``morsel_rows`` (then
  grown working capacity) until it fits — slower, never wrong.

All injection and recovery is **driver-side**: no site check runs inside a
compiled program, so with injection disabled the compile-cache keys are
bit-identical to a build without the harness (a test locks this).
"""

from __future__ import annotations

import dataclasses
import fnmatch
import random
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from . import flags

__all__ = [
    "SITES", "FaultError", "InjectedFault", "QueryTimeout", "QueryCancelled",
    "CapacityOverflow", "FaultSpec", "FaultPlan", "FaultRun", "NULL_FAULTS",
    "parse_fault_plan", "random_plan", "resolve_faults",
    "RetryPolicy", "resolve_retry", "CancellationToken", "resolve_token",
    "OverflowPolicy", "resolve_overflow",
]

#: Every registered injection site in the execution spine.  ``FaultSpec``
#: patterns must match at least one of these (typo guard), and the chaos
#: suite + hypothesis property test enumerate them.
SITES: Tuple[str, ...] = (
    "stage:launch",      # in-core: one per dispatch unit (program/stage/op)
    "a2a:chunk",         # in-core: one per all-to-all chunk of a shuffle unit
    "segment:launch",    # out-of-core: one per segment attempt
    "morsel:compile",    # out-of-core: first morsel of a segment (trace+build)
    "morsel:execute",    # out-of-core: every morsel dispatch
    "transfer:h2d",      # out-of-core: host->device morsel staging
    "transfer:d2h",      # out-of-core: device->host spill of a morsel output
    "spill:append",      # out-of-core: appending a chunk to a spill bucket
    "spill:respill",     # out-of-core: re-bucketing the input spill
    "spill:combine",     # out-of-core: cross-morsel groupby combine dispatch
    "build:resident",    # out-of-core: resident join build-side execution
)

KINDS: Tuple[str, ...] = ("raise", "hang", "corrupt-capacity")


# ---------------------------------------------------------------------- #
# Exceptions
# ---------------------------------------------------------------------- #
class FaultError(RuntimeError):
    """A recoverable execution fault (retried by the executors)."""


class InjectedFault(FaultError):
    """Raised by a firing ``raise`` (or expired ``hang``) fault."""

    def __init__(self, site: str, message: str = ""):
        super().__init__(message or f"injected fault at {site}")
        self.site = site


class QueryCancelled(RuntimeError):
    """The query's ``CancellationToken`` was cancelled."""


class QueryTimeout(TimeoutError):
    """The query's deadline passed (``df.collect(timeout=...)``)."""


class CapacityOverflow(RuntimeError):
    """Capacity pressure dropped rows and the overflow policy forbids it
    (``raise``) or degradation could not make the data fit."""


# ---------------------------------------------------------------------- #
# Fault plans
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault: fire ``kind`` at occurrence ``at`` of sites matching
    ``site`` (an ``fnmatch`` pattern), at most ``times`` times per query.

    ``at=None`` matches every occurrence (until ``times`` is exhausted).
    Occurrences are counted per concrete site name within one query run,
    so plans are deterministic given a deterministic execution order.
    """

    site: str
    kind: str = "raise"
    at: Optional[int] = 0
    times: int = 1

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")
        if not any(fnmatch.fnmatch(s, self.site) for s in SITES):
            raise ValueError(f"fault site pattern {self.site!r} matches no "
                             f"registered site; sites are {SITES}")

    def matches(self, site: str, occurrence: int) -> bool:
        return (fnmatch.fnmatch(site, self.site)
                and (self.at is None or occurrence == self.at))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic set of faults for one query (or many: each
    ``start()`` yields a fresh per-query ``FaultRun`` with its own
    occurrence counters)."""

    specs: Tuple[FaultSpec, ...] = ()
    seed: int = 0
    hang_s: float = 30.0   # how long a ``hang`` blocks without a deadline

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))

    def start(self) -> "FaultRun":
        return FaultRun(self)

    def __str__(self) -> str:
        parts = []
        for s in self.specs:
            at = "*" if s.at is None else str(s.at)
            parts.append(f"{s.site}@{at}x{s.times}={s.kind}")
        return ";".join(parts)


class FaultRun:
    """Per-query injection state: occurrence counters per concrete site and
    fire counts per spec.  Executors call ``check``/``capacity`` at every
    hazard point; both are no-ops on the shared ``NULL_FAULTS`` singleton.
    """

    enabled = True

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._seen: Dict[str, int] = {}
        self._fired: Dict[int, int] = {}
        self.injected = 0          # total faults fired this query

    def _arm(self, site: str,
             kinds: Tuple[str, ...] = KINDS) -> Optional[FaultSpec]:
        occ = self._seen.get(site, 0)
        self._seen[site] = occ + 1
        for i, spec in enumerate(self.plan.specs):
            if spec.kind not in kinds or self._fired.get(i, 0) >= spec.times:
                continue
            if spec.matches(site, occ):
                self._fired[i] = self._fired.get(i, 0) + 1
                self.injected += 1
                return spec
        return None

    def _fire(self, spec: FaultSpec, site: str,
              token: Optional["CancellationToken"], idx: Dict[str, Any]):
        where = site + (f" {idx}" if idx else "")
        if spec.kind == "raise":
            raise InjectedFault(site, f"injected fault at {where}")
        # hang: block until the query deadline fences us (or a bounded
        # fallback elapses, surfacing as a retryable fault)
        t0 = time.monotonic()
        while time.monotonic() - t0 < self.plan.hang_s:
            if token is not None:
                token.check(where)   # raises QueryTimeout / QueryCancelled
            time.sleep(0.002)
        raise InjectedFault(site, f"injected hang at {where} expired "
                                  f"after {self.plan.hang_s}s")

    def check(self, site: str, token: Optional["CancellationToken"] = None,
              **idx: Any) -> None:
        """Fire any armed ``raise``/``hang`` fault for this site visit.

        ``idx`` (stage=, morsel=, ...) is advisory labeling for the error
        message; matching is by site occurrence order, which is
        deterministic for a deterministic execution.
        """
        spec = self._arm(site, kinds=("raise", "hang"))
        if spec is None:
            return
        self._fire(spec, site, token, idx)

    def capacity(self, site: str, value: int,
                 token: Optional["CancellationToken"] = None,
                 **idx: Any) -> int:
        """Visit a site whose hazard is a buffer capacity: an armed
        ``corrupt-capacity`` fault shrinks ``value`` to a quarter (8-rounded,
        forcing overflow the overflow policy must repair); ``raise``/``hang``
        faults fire exactly as ``check``.  Each hazard point calls either
        ``check`` or ``capacity``, never both, so every site has one
        deterministic occurrence stream."""
        spec = self._arm(site)
        if spec is None:
            return value
        if spec.kind == "corrupt-capacity":
            return max(8, int(value) // 4 // 8 * 8)
        self._fire(spec, site, token, idx)
        return value


class _NullFaults:
    """Disabled harness: every call is a no-op (one attr lookup when off)."""

    __slots__ = ()
    enabled = False
    injected = 0

    def __bool__(self) -> bool:
        return False

    def check(self, site: str, token: Any = None, **idx: Any) -> None:
        return None

    def capacity(self, site: str, value: int, **idx: Any) -> int:
        return value


NULL_FAULTS = _NullFaults()


def parse_fault_plan(text: str) -> FaultPlan:
    """Parse the ``REPRO_FAULTS`` syntax: ``;``-separated entries
    ``site[@occurrence][xtimes]=kind`` plus optional ``seed=N``.

    ``site`` is an fnmatch pattern over ``SITES``; ``@occurrence`` defaults
    to 0 (first visit), ``@*`` means every visit; ``xN`` caps fires per
    query (default 1).  Examples::

        morsel:execute@2=raise
        stage:*=hang;seed=7
        transfer:h2d@*x3=raise
    """
    specs: List[FaultSpec] = []
    seed = 0
    for entry in text.split(";"):
        entry = entry.strip()
        if not entry:
            continue
        if "=" not in entry:
            raise ValueError(f"bad REPRO_FAULTS entry {entry!r}: "
                             f"expected site[@occ][xN]=kind")
        lhs, kind = entry.rsplit("=", 1)
        lhs, kind = lhs.strip(), kind.strip()
        if lhs == "seed":
            seed = int(kind)
            continue
        times = 1
        if "x" in lhs.rsplit("@", 1)[-1]:
            lhs, times_s = lhs.rsplit("x", 1)
            times = int(times_s)
        at: Optional[int] = 0
        if "@" in lhs:
            lhs, at_s = lhs.rsplit("@", 1)
            at = None if at_s == "*" else int(at_s)
        specs.append(FaultSpec(lhs, kind=kind, at=at, times=times))
    return FaultPlan(tuple(specs), seed=seed)


def random_plan(seed: int, nfaults: int = 1,
                kinds: Sequence[str] = ("raise",),
                max_occurrence: int = 3,
                sites: Sequence[str] = SITES) -> FaultPlan:
    """A deterministic random plan for chaos testing: ``nfaults`` single
    faults at uniformly drawn (site, occurrence, kind) triples."""
    rng = random.Random(seed)
    specs = tuple(
        FaultSpec(rng.choice(list(sites)), kind=rng.choice(list(kinds)),
                  at=rng.randrange(max_occurrence + 1))
        for _ in range(nfaults))
    return FaultPlan(specs, seed=seed)


def resolve_faults(faults: Any):
    """Normalize the ``faults=`` argument of the executors.

    ``None`` consults ``repro.flags`` / the ``REPRO_FAULTS`` env var;
    ``False`` forces off; a ``FaultPlan`` starts a fresh per-query run; a
    ``FaultRun`` continues (degrade re-entry keeps one occurrence stream);
    a string is parsed as ``REPRO_FAULTS`` syntax."""
    if isinstance(faults, (FaultRun, _NullFaults)):
        return faults
    if isinstance(faults, FaultPlan):
        return faults.start()
    if faults is False:
        return NULL_FAULTS
    if faults is None:
        spec = flags.fault_spec()
        return parse_fault_plan(spec).start() if spec else NULL_FAULTS
    if isinstance(faults, str):
        return parse_fault_plan(faults).start()
    raise TypeError(f"faults= must be None/False/str/FaultPlan, "
                    f"got {type(faults).__name__}")


# ---------------------------------------------------------------------- #
# Retry with exponential backoff + deterministic jitter
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Replay a failed dispatch unit up to ``retries`` times, sleeping
    ``backoff_s * 2**attempt`` (capped at ``backoff_max_s``) with
    deterministic jitter (seeded, so reproductions reproduce)."""

    retries: int = 2
    backoff_s: float = 0.005
    backoff_max_s: float = 0.25
    jitter: float = 0.5
    seed: int = 0

    def delay(self, attempt: int) -> float:
        base = min(self.backoff_max_s, self.backoff_s * (2.0 ** attempt))
        frac = random.Random(self.seed * 1000003 + attempt).random()
        return base * (1.0 + self.jitter * frac)

    def sleep(self, attempt: int,
              token: Optional["CancellationToken"] = None) -> None:
        """Back off before attempt ``attempt`` (0-based retry index),
        polling the cancellation token so a deadline fires mid-backoff."""
        remaining = self.delay(attempt)
        while remaining > 0:
            if token is not None:
                token.check(f"retry backoff (attempt {attempt + 1})")
            step = min(0.01, remaining)
            time.sleep(step)
            remaining -= step


def resolve_retry(retry: Any) -> RetryPolicy:
    """``None`` -> default policy; an int -> that many retries; a
    ``RetryPolicy`` passes through."""
    if retry is None:
        return RetryPolicy()
    if isinstance(retry, RetryPolicy):
        return retry
    if isinstance(retry, int) and not isinstance(retry, bool):
        return RetryPolicy(retries=retry)
    raise TypeError(f"retries= must be None/int/RetryPolicy, "
                    f"got {type(retry).__name__}")


# ---------------------------------------------------------------------- #
# Deadline / cancellation token
# ---------------------------------------------------------------------- #
class CancellationToken:
    """Driver-side deadline + cooperative cancellation for one query.

    Executors call ``check()`` between morsels / stages and around
    ``block_until_ready`` fences; injected hangs poll it, so a hung
    dispatch surfaces as ``QueryTimeout`` rather than blocking forever.

    ``parent`` links tokens into a tree: a child observes its parent's
    cancellation and deadline as well as its own.  The serving scheduler
    uses this for per-query tokens parented on one scheduler-wide token,
    so ``QueryScheduler.close(cancel_pending=True)`` cancels every queued
    and running query with a single call.  Cancellation is a plain flag
    write (atomic under CPython), safe to call from any thread.
    """

    def __init__(self, timeout: Optional[float] = None,
                 parent: Optional["CancellationToken"] = None):
        self.deadline = (time.monotonic() + timeout
                         if timeout is not None else None)
        self.timeout = timeout
        self.parent = parent
        self._cancelled = False
        self.reason = ""

    @property
    def cancelled(self) -> bool:
        return self._cancelled or (self.parent is not None
                                   and self.parent.cancelled)

    @property
    def cancel_reason(self) -> str:
        if self._cancelled or self.parent is None:
            return self.reason
        return self.parent.cancel_reason

    def cancel(self, reason: str = "") -> None:
        self.reason = reason
        self._cancelled = True

    def remaining(self) -> Optional[float]:
        own = (None if self.deadline is None
               else self.deadline - time.monotonic())
        if self.parent is None:
            return own
        up = self.parent.remaining()
        if own is None:
            return up
        return own if up is None else min(own, up)

    def expired(self) -> bool:
        rem = self.remaining()
        return rem is not None and rem <= 0

    def check(self, where: str = "") -> None:
        if self.cancelled:
            reason = self.cancel_reason
            raise QueryCancelled(
                f"query cancelled{': ' + reason if reason else ''}"
                + (f" (at {where})" if where else ""))
        if self.expired():
            timeout = self.timeout
            if timeout is None and self.parent is not None:
                timeout = self.parent.timeout
            raise QueryTimeout(
                f"query deadline ({timeout}s) passed"
                + (f" at {where}" if where else ""))


def resolve_token(timeout: Any) -> CancellationToken:
    """``None``/seconds -> fresh token; an existing token passes through."""
    if isinstance(timeout, CancellationToken):
        return timeout
    if timeout is not None and not isinstance(timeout, (int, float)):
        raise TypeError(f"timeout= must be None/seconds/CancellationToken, "
                        f"got {type(timeout).__name__}")
    return CancellationToken(timeout)


# ---------------------------------------------------------------------- #
# Overflow policy
# ---------------------------------------------------------------------- #
class OverflowPolicy:
    """What to do when capacity pressure drops rows (observable in morsel
    mode always, in-core when stats are collected):

    * ``raise``   — fail the query with ``CapacityOverflow``;
    * ``warn``    — keep the (truncated) result, emit one deduplicated
                    ``RuntimeWarning`` attributing the drops;
    * ``degrade`` — (default) re-execute the overflowing segment
                    out-of-core with auto-halved ``morsel_rows`` (then
                    grown working capacity) until every row fits —
                    slower, never wrong.
    """

    RAISE = "raise"
    WARN = "warn"
    DEGRADE = "degrade"
    ALL = (RAISE, WARN, DEGRADE)


def resolve_overflow(overflow: Any) -> str:
    if overflow is None:
        return OverflowPolicy.DEGRADE
    if overflow in OverflowPolicy.ALL:
        return overflow
    raise ValueError(f"overflow= must be one of {OverflowPolicy.ALL}, "
                     f"got {overflow!r}")


def default_degrade_step(morsel_rows: int, capacity: int) -> Tuple[int, int]:
    """The original blind degrade step: halve ``morsel_rows`` until the
    floor (8), then double the working ``capacity``.

    This is what ``overflow="degrade"`` replays with when morsel
    autotuning is off (``adaptive=False``) — kept as a standalone policy
    function so the adaptive controller (``repro.adapt.MorselTuner``) and
    the legacy path share one call site and the legacy behavior stays
    bit-for-bit what PR 7 shipped.
    """
    def _round8(x: int) -> int:
        return max(8, -(-int(x) // 8) * 8)
    if morsel_rows > 8:
        return max(8, _round8(morsel_rows // 2)), capacity
    return morsel_rows, _round8(capacity * 2)


def run_with_retries(fn, *, policy: RetryPolicy,
                     token: Optional[CancellationToken] = None,
                     tracer=None, label: str = "",
                     on_retry=None):
    """Call ``fn()`` with up to ``policy.retries`` replays on ``FaultError``.

    Timeouts/cancellations propagate immediately (they are not transient).
    ``on_retry(attempt, exc)`` is invoked before each replay (counter
    bumps); ``tracer`` gets a ``retry:{label}`` span around each replay.
    """
    attempt = 0
    while True:
        try:
            return fn()
        except FaultError as e:
            if attempt >= policy.retries:
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            policy.sleep(attempt, token)
            attempt += 1
            if tracer is not None and tracer.enabled:
                tracer.instant(f"retry:{label or 'unit'}", "retry",
                               attempt=attempt, error=str(e))

"""Mesh-elastic checkpointing (coarse-grained fault tolerance, paper §VI).

The paper's fault-tolerance plan for BSP environments is checkpoint/restart
rather than communication-level recovery.  Here:

* ``save``    — host-gathers the state pytree to a single ``.npz`` plus a
  JSON tree manifest.  Layout-agnostic: nothing about the mesh is stored, so
  a checkpoint written on a 512-chip mesh restores onto 8 chips (elastic
  restart after node loss).  ``save_async`` runs the gather+write on a
  worker thread, off the training critical path.
* ``restore`` — loads and re-shards onto the *current* mesh via
  ``jax.device_put`` with the target sharding tree.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def tree_paths(tree: Any):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [jax.tree_util.keystr(p) for p, _ in paths]


def save(path: str, state: Any, step: Optional[int] = None) -> None:
    """Host-gather ``state`` and write ``path`` (.npz + .json manifest)."""
    flat, treedef = _flatten(state)
    arrays = {f"a{i}": np.asarray(jax.device_get(x))
              for i, x in enumerate(flat)}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp.npz"
    np.savez(tmp, **arrays)
    os.replace(tmp, path + ".npz")
    manifest = {
        "num_leaves": len(flat),
        "step": step,
        "paths": tree_paths(state),
        "dtypes": [str(np.asarray(jax.device_get(x)).dtype) for x in flat],
    }
    with open(path + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


class AsyncCheckpointer:
    """Fire-and-forget saves on a worker thread (one in flight at a time)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def save(self, path: str, state: Any, step: Optional[int] = None) -> None:
        self.wait()
        # device_get on the caller thread (cheap, ordered); file IO async
        flat, treedef = _flatten(state)
        host = [np.asarray(jax.device_get(x)) for x in flat]
        snapshot = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            save(path, snapshot, step)
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def restore(path: str, like: Any, shardings: Optional[Any] = None) -> Any:
    """Load a checkpoint into the structure of ``like``.

    ``shardings``: optional pytree of ``jax.sharding.Sharding`` matching
    ``like`` — arrays are placed (and re-sharded) onto the current mesh.
    Works across mesh shapes: the npz holds full arrays.
    """
    flat_like, treedef = _flatten(like)
    with np.load(path + ".npz") as z:
        flat = [z[f"a{i}"] for i in range(len(flat_like))]
    if len(flat) != len(flat_like):
        raise ValueError(
            f"checkpoint has {len(flat)} leaves, expected {len(flat_like)}")
    for i, (a, l) in enumerate(zip(flat, flat_like)):
        if tuple(a.shape) != tuple(l.shape):
            raise ValueError(f"leaf {i}: shape {a.shape} != {l.shape}")
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        flat = [jax.device_put(a.astype(l.dtype), s)
                for a, l, s in zip(flat, flat_like, flat_sh)]
    else:
        flat = [jax.numpy.asarray(a.astype(np.dtype(str(l.dtype))))
                for a, l in zip(flat, flat_like)]
    return jax.tree_util.tree_unflatten(treedef, flat)


def latest_step(directory: str, prefix: str = "ckpt_") -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith(prefix) and name.endswith(".json"):
            try:
                steps.append(int(name[len(prefix):-len(".json")]))
            except ValueError:
                pass
    return max(steps) if steps else None

"""AdamW optimizer + LR schedule + global-norm clipping (pure pytrees).

No optax in this environment.  Moments are fp32 regardless of param dtype
(mixed-precision training: bf16 params/grads, fp32 state and update math).
Optimizer-state sharding follows the param specs by default; MoE expert
moments may be further sharded (ZeRO) via ``opt_specs``'s ``extra_fsdp``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params: Params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def opt_specs(param_specs: Params) -> Dict[str, Any]:
    """Optimizer-state PartitionSpecs mirroring the param specs."""
    from jax.sharding import PartitionSpec as P
    return {
        "step": P(),
        "m": param_specs,
        "v": param_specs,
    }


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def _is_matrix(p: jax.Array) -> bool:
    return p.ndim >= 2  # weight-decay matrices only (norm scales skip decay)


def adamw_update(params: Params, grads: Params, state: Dict[str, Any],
                 cfg: AdamWConfig) -> Tuple[Params, Dict[str, Any], Dict]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if _is_matrix(p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"step": step, "m": new_m, "v": new_v}, metrics

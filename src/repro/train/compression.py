"""int8-quantized gradient all-reduce with error feedback (beyond-paper).

The paper's communicator is modular precisely so the collective payload can
be optimized independently of the runtime; this module applies that idea to
the data-parallel gradient reduction: per-tensor-block int8 quantization
(scale = max|g|/127) before the all-reduce, dequantize after, with an error
feedback accumulator so quantization noise is re-injected next step
(1-bit-Adam-style convergence behaviour).

Runs inside ``jax.shard_map`` over the data axis — this is the explicit-DP
train-step variant; the GSPMD path keeps full-precision reductions.
4x fewer bytes on the wire at the cost of a 2-pass quantize/dequantize.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from ..comm import Communicator


def quantize_int8(g: jax.Array, block: int = 2048
                  ) -> Tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8: returns (q (n,) int8, scales (blocks,))."""
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    nb = -(-n // block)
    pad = nb * block - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(nb, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)),
                 -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype
                    ) -> jax.Array:
    blocks = q.astype(jnp.float32) * scale[:, None]
    n = 1
    for s in shape:
        n *= s
    return blocks.reshape(-1)[:n].reshape(shape).astype(dtype)


def compressed_all_reduce(g: jax.Array, comm: Communicator,
                          block: int = 2048) -> jax.Array:
    """Mean all-reduce with int8 payload (must run inside shard_map).

    Quantized locally, summed in int32 (exact for p <= 2^23/127 ranks),
    dequantized with the max scale — a single all-reduce of q plus a tiny
    all-reduce of scales.
    """
    q, scale = quantize_int8(g, block)
    p = comm.size()
    # max scale across ranks keeps the shared dequant grid conservative
    scale_max = comm.all_reduce_max(scale)
    # requantize onto the shared grid so integer sums align
    g_requant = dequantize_int8(q, scale, g.shape, jnp.float32)
    q2, _ = quantize_int8(g_requant, block)  # same grid locally
    qsum = comm.all_reduce(q2.astype(jnp.int32))
    out = (qsum.astype(jnp.float32) * scale_max[:, None]).reshape(-1)
    n = 1
    for s in g.shape:
        n *= s
    return (out[:n].reshape(g.shape) / p).astype(g.dtype)


def ef_compressed_all_reduce(g: jax.Array, err: jax.Array,
                             comm: Communicator, block: int = 2048
                             ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback variant: (reduced_grad, new_error).

    The local quantization residual is carried to the next step, so the
    *accumulated* gradient signal is preserved despite 4x compression.
    """
    g_ef = g.astype(jnp.float32) + err
    q, scale = quantize_int8(g_ef, block)
    local_dq = dequantize_int8(q, scale, g.shape, jnp.float32)
    new_err = g_ef - local_dq
    scale_max = comm.all_reduce_max(scale)
    qsum = comm.all_reduce(q.astype(jnp.int32))
    # NOTE scales differ per rank; summing ints on per-rank grids then using
    # max-scale bounds the error by (1 - s_r/s_max) per rank — the error
    # feedback absorbs it.  Exact-grid mode: see compressed_all_reduce.
    out = (qsum.astype(jnp.float32) * scale_max[:, None]).reshape(-1)
    n = 1
    for s in g.shape:
        n *= s
    p = comm.size()
    return (out[:n].reshape(g.shape) / p).astype(g.dtype), new_err

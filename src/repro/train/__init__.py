"""Training substrate: optimizer, step assembly, checkpointing, compression."""

from .optim import (AdamWConfig, adamw_update, clip_by_global_norm,
                    global_norm, init_opt_state, lr_at, opt_specs)
from .step import (batch_specs, init_train_state, make_train_step,
                   state_specs)
from .checkpoint import AsyncCheckpointer, latest_step, restore, save
from .compression import (compressed_all_reduce, dequantize_int8,
                          ef_compressed_all_reduce, quantize_int8)

__all__ = [
    "AdamWConfig", "adamw_update", "clip_by_global_norm", "global_norm",
    "init_opt_state", "lr_at", "opt_specs",
    "batch_specs", "init_train_state", "make_train_step", "state_specs",
    "AsyncCheckpointer", "latest_step", "restore", "save",
    "compressed_all_reduce", "dequantize_int8", "ef_compressed_all_reduce",
    "quantize_int8",
]

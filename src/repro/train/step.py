"""Train-step assembly: loss + grad + AdamW under GSPMD sharding.

``make_train_step`` returns a jit-able ``train_step(state, batch)`` plus the
in/out sharding trees for the production mesh.  Gradient reduction across
``data``/``pod`` falls out of the activation/param shardings (GSPMD inserts
reduce-scatter for FSDP params and hierarchical all-reduce across the pod
axis); see ``compression.py`` for the explicit int8 data-parallel variant.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models import transformer
from ..models.config import ModelConfig
from ..models.layers import ShardingRules
from .optim import AdamWConfig, adamw_update, init_opt_state, opt_specs

TrainState = Dict[str, Any]  # {"params": ..., "opt": ...}


def init_train_state(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> TrainState:
    params = transformer.init_params(key, cfg, dtype)
    return {"params": params, "opt": init_opt_state(params)}


def _densify_moment_spec(spec: P, shape, rules: ShardingRules) -> P:
    """Extra ZeRO sharding for fp32 optimizer moments.

    Training weights are sharded on ONE mesh axis (ZeRO over 'data' for
    dense weights; EP over 'model' for experts) — fine for bf16 params but
    not for their 2× fp32 m/v.  Insert every missing mesh axis into the
    largest still-unsharded divisible dims (2-D ZeRO); costs one param
    gather inside the update step, saves model_size× (or data_size×)
    moment memory."""
    if len(shape) < 2:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a is not None:
                used.add(a)
    for axis, size in ((rules.model, rules.model_size),
                       (rules.fsdp, rules.data_size)):
        if axis is None or size <= 1 or axis in used:
            continue
        cands = [i for i, e in enumerate(entries)
                 if e is None and shape[i] % size == 0]
        if not cands:
            continue
        dim = max(cands, key=lambda i: shape[i])
        entries[dim] = axis
        used.add(axis)
    return P(*entries)


def state_specs(cfg: ModelConfig, rules: ShardingRules) -> TrainState:
    ps = transformer.param_specs(cfg, rules)
    os_ = opt_specs(ps)
    if rules.model is not None and not rules.tp_weights:
        shapes = jax.eval_shape(
            lambda k: transformer.init_params(k, cfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        mom = jax.tree_util.tree_map(
            lambda sp, sh: _densify_moment_spec(sp, sh.shape, rules),
            ps, shapes, is_leaf=lambda x: isinstance(x, P))
        os_ = dict(os_, m=mom, v=mom)
    return {"params": ps, "opt": os_}


def batch_specs(cfg: ModelConfig, rules: ShardingRules) -> Dict[str, Any]:
    """Token ids replicated over 'model': GSPMD then partitions the
    vocab-parallel embedding gather as masked-local-gather + psum(model)
    (seq-sharded ids would make it all-gather the whole table instead)."""
    spec: Dict[str, Any] = {"tokens": rules.logical("batch", None),
                            "labels": rules.logical("batch", None)}
    if cfg.family == "audio":
        spec = {"tokens": rules.logical("batch", None, None),
                "labels": rules.logical("batch", None, None)}
    if cfg.family == "vlm":
        spec["patch_embeds"] = rules.logical("batch", "model", None)
    return spec


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    rules: ShardingRules, impl: str = "auto",
                    remat: bool = True, ce_chunk: int = 512
                    ) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        def loss(params):
            return transformer.loss_fn(params, cfg, batch, rules, impl,
                                       remat, ce_chunk)
        (l, parts), grads = jax.value_and_grad(loss, has_aux=True)(
            state["params"])
        new_params, new_opt, om = adamw_update(state["params"], grads,
                                               state["opt"], opt_cfg)
        metrics = {"loss": l, **parts, **om}
        return {"params": new_params, "opt": new_opt}, metrics
    return train_step

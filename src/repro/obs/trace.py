"""Query-level tracing: hierarchical spans with Chrome-trace export.

A ``Tracer`` records a tree of ``Span``s for ONE query execution
(query -> stage -> shuffle / morsel -> collective chunk).  All bookkeeping
is **driver-side**: spans are plain Python objects created around program
dispatches, never inside jit — enabling tracing cannot change what gets
compiled (a test locks that compile-cache keys are identical with tracing
on and off).

Timing convention: span end times are taken after the caller fences device
work (``jax.block_until_ready`` on the dispatch outputs), so a stage span's
duration covers dispatch + device execution, not just the Python submit.
``Span.fence(x)`` is the helper for that pattern.

The finished ``QueryTrace`` exports to the Chrome/Perfetto ``trace_event``
JSON format (``to_chrome_trace``) viewable in ``chrome://tracing`` or
https://ui.perfetto.dev: spans become complete ("X") events, zero-duration
markers (per-shuffle data volumes, per-chunk all-to-all steps) become
instant ("i") events nested inside their parent span's time range.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import time
from typing import Any, Dict, List, Optional

_span_ids = itertools.count(1)
_query_ids = itertools.count(1)


@dataclasses.dataclass
class Span:
    """One timed region (or instant marker when ``end_s`` == ``start_s``
    and ``instant`` is set).  ``attrs`` carry rows/bytes/rank/etc."""

    name: str
    category: str                      # "query" | "stage" | "shuffle" | ...
    start_s: float
    end_s: Optional[float] = None
    span_id: int = 0
    parent_id: Optional[int] = None
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    instant: bool = False

    @property
    def duration_s(self) -> float:
        return (self.end_s - self.start_s) if self.end_s is not None else 0.0

    def set(self, **attrs: Any) -> "Span":
        """Attach attributes (rows, bytes, ...) to the span."""
        self.attrs.update(attrs)
        return self

    def fence(self, x: Any) -> Any:
        """Block until ``x``'s device work completes, so the span end time
        (taken at ``__exit__``) covers execution, not just dispatch."""
        import jax
        return jax.block_until_ready(x)


class _SpanHandle:
    """Context manager that closes a span on exit (driver-side clock)."""

    __slots__ = ("_tracer", "span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self.span = span

    def set(self, **attrs: Any) -> "_SpanHandle":
        self.span.set(**attrs)
        return self

    def fence(self, x: Any) -> Any:
        return self.span.fence(x)

    def __enter__(self) -> "_SpanHandle":
        return self

    def __exit__(self, *exc) -> None:
        self._tracer._end(self.span)


class _NullHandle:
    """No-op stand-in so instrumented code needs no ``if tracer`` guards."""

    __slots__ = ()
    span = None

    def set(self, **attrs: Any) -> "_NullHandle":
        return self

    def fence(self, x: Any) -> Any:
        return x

    def __enter__(self) -> "_NullHandle":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_HANDLE = _NullHandle()


class _NullTracer:
    """Disabled tracer: every call is a no-op and ``bool()`` is False, so
    instrumented code pays one attribute lookup when tracing is off."""

    __slots__ = ()
    enabled = False

    def __bool__(self) -> bool:
        return False

    def span(self, name: str, category: str = "span", **attrs) -> _NullHandle:
        return _NULL_HANDLE

    def instant(self, name: str, category: str = "span", **attrs) -> None:
        return None

    def finish(self) -> None:
        return None


NULL_TRACER = _NullTracer()


class Tracer:
    """Records one query's span tree.  Not thread-safe by design: a tracer
    belongs to one driver-side execution (create one per query)."""

    enabled = True

    def __init__(self, name: str = "query",
                 clock=time.perf_counter):
        self.name = name
        self.query_id = next(_query_ids)
        self._clock = clock
        self._spans: List[Span] = []
        self._stack: List[Span] = []
        self._trace: Optional[QueryTrace] = None

    def __bool__(self) -> bool:
        return True

    # -- span API -------------------------------------------------------- #
    def span(self, name: str, category: str = "span", **attrs) -> _SpanHandle:
        """Open a span; use as a context manager.  Nesting follows the
        driver-side call structure (the innermost open span is the parent).
        """
        parent = self._stack[-1].span_id if self._stack else None
        s = Span(name, category, self._clock(), span_id=next(_span_ids),
                 parent_id=parent, attrs=dict(attrs))
        self._spans.append(s)
        self._stack.append(s)
        return _SpanHandle(self, s)

    def instant(self, name: str, category: str = "span", **attrs) -> Span:
        """Zero-duration marker under the currently open span (data-volume
        records for device-side ops whose timing the driver cannot see)."""
        parent = self._stack[-1].span_id if self._stack else None
        t = self._clock()
        s = Span(name, category, t, t, span_id=next(_span_ids),
                 parent_id=parent, attrs=dict(attrs), instant=True)
        self._spans.append(s)
        return s

    def _end(self, span: Span) -> None:
        span.end_s = self._clock()
        # tolerate mis-nested exits instead of corrupting the stack
        if self._stack and self._stack[-1] is span:
            self._stack.pop()
        elif span in self._stack:
            self._stack.remove(span)

    # -- completion ------------------------------------------------------ #
    def finish(self) -> "QueryTrace":
        """Close any open spans and freeze into a ``QueryTrace``."""
        while self._stack:
            self._end(self._stack[-1])
        if self._trace is None:
            self._trace = QueryTrace(self.name, self.query_id,
                                     list(self._spans))
            _set_last_trace(self._trace)
        return self._trace


class QueryTrace:
    """Finished span tree for one query."""

    def __init__(self, name: str, query_id: int, spans: List[Span]):
        self.name = name
        self.query_id = query_id
        self.spans = spans

    # -- structure ------------------------------------------------------- #
    def root(self) -> Optional[Span]:
        for s in self.spans:
            if s.parent_id is None and not s.instant:
                return s
        return None

    def children(self, span: Span) -> List[Span]:
        return [s for s in self.spans if s.parent_id == span.span_id]

    def find(self, category: Optional[str] = None,
             name_prefix: str = "") -> List[Span]:
        return [s for s in self.spans
                if (category is None or s.category == category)
                and s.name.startswith(name_prefix)]

    @property
    def duration_s(self) -> float:
        r = self.root()
        return r.duration_s if r is not None else 0.0

    # -- export ---------------------------------------------------------- #
    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "query_id": self.query_id,
            "duration_s": self.duration_s,
            "spans": [dataclasses.asdict(s) for s in self.spans],
        }

    def to_chrome_trace(self, path: Optional[str] = None) -> Dict[str, Any]:
        """Chrome/Perfetto ``trace_event`` JSON.  Returns the payload dict;
        writes it to ``path`` when given (open the file in
        ``chrome://tracing`` or https://ui.perfetto.dev).

        Spans -> complete ("X") events; instants -> "i" events.  All events
        share pid 0 / tid 0 so the viewer nests them by time containment,
        mirroring the driver-side call structure.  Timestamps are
        microseconds relative to the query start.
        """
        t0 = min((s.start_s for s in self.spans), default=0.0)

        def us(t: float) -> float:
            return round((t - t0) * 1e6, 3)

        events: List[Dict[str, Any]] = []
        for s in self.spans:
            args = {k: v for k, v in s.attrs.items()}
            if s.instant:
                events.append({"name": s.name, "cat": s.category, "ph": "i",
                               "ts": us(s.start_s), "pid": 0, "tid": 0,
                               "s": "t", "args": args})
            else:
                end = s.end_s if s.end_s is not None else s.start_s
                events.append({"name": s.name, "cat": s.category, "ph": "X",
                               "ts": us(s.start_s),
                               "dur": round((end - s.start_s) * 1e6, 3),
                               "pid": 0, "tid": 0, "args": args})
        payload = {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"query": self.name, "query_id": self.query_id},
        }
        if path is not None:
            with open(path, "w") as f:
                json.dump(payload, f, indent=1)
                f.write("\n")
        return payload


# ---------------------------------------------------------------------- #
# Ambient access: resolve the trace= argument, keep the last trace around
# ---------------------------------------------------------------------- #
_LAST_TRACE: List[Optional[QueryTrace]] = [None]


def _set_last_trace(trace: QueryTrace) -> None:
    _LAST_TRACE[0] = trace


def last_trace() -> Optional[QueryTrace]:
    """The most recently finished ``QueryTrace`` in this process — the
    retrieval path for ``execute(..., trace=True)`` callers that did not
    hold their own ``Tracer``."""
    return _LAST_TRACE[0]


def resolve_tracer(trace: Any, name: str = "query"):
    """Normalize the user-facing ``trace=`` argument.

    ``None`` consults the ``REPRO_TRACE`` env var (opt-in flag; "0"/"" off);
    ``False`` forces off; ``True`` builds a fresh ``Tracer``; a ``Tracer``
    passes through.  Returns ``NULL_TRACER`` when disabled, so call sites
    can use the handle unconditionally.
    """
    import os
    if isinstance(trace, (Tracer, _NullTracer)):
        return trace
    if trace is None:
        trace = os.environ.get("REPRO_TRACE", "") not in ("", "0")
    return Tracer(name) if trace else NULL_TRACER

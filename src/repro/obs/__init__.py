"""``repro.obs`` — tracing + metrics: make every execution self-describing.

Three layers (see ``docs/observability.md``):

* ``trace``   — ``Tracer`` / ``Span`` / ``QueryTrace``: driver-side
                hierarchical spans (query -> stage -> shuffle -> chunk)
                with Chrome/Perfetto ``trace_event`` export,
* ``metrics`` — process-global ``MetricsRegistry`` (labeled counters /
                gauges / histograms + per-query records), the feed for a
                future multi-query admission controller,
* ``analyze`` — EXPLAIN ANALYZE (``QueryReport``): the EXPLAIN tree
                re-rendered with *measured* per-node rows / bytes / times
                plus a per-stage roofline table (``launch.roofline``).

Tracing is opt-in (``trace=`` argument or ``REPRO_TRACE=1``) and purely
driver-side: compiled programs are bit-identical with tracing on or off.

``analyze`` is imported lazily: it depends on ``repro.planner``, which
itself imports this package's trace layer — eager import would cycle.
"""

from .trace import (NULL_TRACER, QueryTrace, Span, Tracer, last_trace,
                    resolve_tracer)
from .metrics import (METRICS, MetricsRegistry, record_exec,
                      record_serve_query)

_ANALYZE_NAMES = ("QueryReport", "run_analyzed", "render_analyze",
                  "stage_table")

__all__ = [
    "METRICS", "MetricsRegistry", "NULL_TRACER", "QueryReport", "QueryTrace",
    "Span", "Tracer", "last_trace", "record_exec", "record_serve_query",
    "render_analyze", "resolve_tracer", "run_analyzed", "stage_table",
]


def __getattr__(name: str):
    if name in _ANALYZE_NAMES:
        from . import analyze
        return getattr(analyze, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""EXPLAIN ANALYZE: the EXPLAIN tree re-rendered with *measured* actuals.

``run_analyzed`` executes a plan with stats collection + tracing on and
returns ``(result, QueryReport)``.  The report re-renders the physical plan
(``planner.explain`` labels) with per-node actual rows / bytes / drops from
``ExecStats.shuffle_records`` next to the planner's estimates, per-stage
wall times from ``ExecStats.stage_times``, and a per-stage roofline table
(``launch.roofline.stage_roofline``) showing how close each stage ran to
the modeled bandwidth bound.  The attached ``QueryTrace`` exports to the
Chrome ``trace_event`` format via ``QueryReport.to_chrome_trace``.

Frontend entry points: ``df.collect(analyze=True)`` and
``df.explain_analyze()`` (``repro.df``); plan-level callers use
``run_analyzed`` directly.  See ``docs/observability.md``.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Mapping, Optional, Tuple

from .trace import QueryTrace, Tracer

__all__ = ["QueryReport", "run_analyzed", "render_analyze", "stage_table"]


def _rows_of(table: Any) -> Optional[int]:
    """Total rows of any table-ish execute() input/output, else None."""
    if hasattr(table, "total_rows"):
        return int(table.total_rows())
    if isinstance(table, Mapping) and table:
        try:
            return len(next(iter(table.values())))
        except TypeError:
            return None
    return None


def _fmt_bytes(b: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(b) < 1024 or unit == "GiB":
            return f"{b:.1f}{unit}" if unit != "B" else f"{int(b)}B"
        b /= 1024
    return f"{b:.1f}GiB"


def _records_by_label(stats) -> Dict[str, Dict[str, int]]:
    """Aggregate shuffle records to per-label totals.  Out-of-core runs
    key records by ``(label, segment)``; the analyze rendering wants the
    whole-query per-label view, so same-label records sum."""
    agg: Dict[str, Dict[str, int]] = {}
    for r in stats.shuffle_records:
        a = agg.setdefault(r.label, {"rows": 0, "bytes": 0, "dropped": 0})
        a["rows"] += r.rows
        a["bytes"] += r.bytes
        a["dropped"] += r.dropped
    return agg


def _node_actuals(node, by_label: Dict[str, Dict[str, int]]
                  ) -> Optional[str]:
    """Measured annotation for one plan node, from its shuffle records.

    Labels match on the ``op(args)`` stem so salted extras the static
    plan does not predict (``groupby(k):remerge``, ``join(k):broadcast``)
    attribute to their node."""
    from ..planner.physical import node_stat_labels
    stems = {l.split(":")[0] for l in node_stat_labels(node)}
    labels = [l for l in by_label if l.split(":")[0] in stems]
    if not labels:
        return None
    rows = sum(by_label[l]["rows"] for l in labels)
    byts = sum(by_label[l]["bytes"] for l in labels)
    dropped = sum(by_label[l]["dropped"] for l in labels)
    s = f"moved {rows} rows / {_fmt_bytes(byts)}"
    if dropped:
        s += f", DROPPED {dropped}"
    return s


def _stage_seconds(stats) -> Dict[int, float]:
    """Map stage index -> measured seconds where attribution is exact
    (``bsp_staged`` one-dispatch-per-stage); other modes can only time the
    whole program / per-segment units."""
    out: Dict[int, float] = {}
    for name, secs in stats.stage_times:
        if name.startswith("stage:"):
            try:
                out[int(name.split(":", 1)[1])] = secs
            except ValueError:
                pass
    return out


def render_analyze(pplan, stats, scan_rows: Optional[Dict[str, int]] = None,
                   result_rows: Optional[int] = None) -> str:
    """The EXPLAIN tree with ``act:`` annotations from a finished run."""
    from ..planner.explain import adapt_note, node_label
    scan_rows = scan_rows or {}
    records = _records_by_label(stats)
    stage_secs = _stage_seconds(stats)
    cache = f"{stats.cache_hits} hits / {stats.cache_misses} misses"
    ft = ""
    if getattr(stats, "retries", 0) or getattr(stats, "degraded", 0):
        ft = (f" retries={getattr(stats, 'retries', 0)} "
              f"degraded={getattr(stats, 'degraded', 0)}")
    if (getattr(stats, "salted_shuffles", 0)
            or getattr(stats, "splitter_refreshes", 0)
            or getattr(stats, "autotune_steps", 0)):
        ft += (f" adapt[salted={getattr(stats, 'salted_shuffles', 0)} "
               f"refreshes={getattr(stats, 'splitter_refreshes', 0)} "
               f"autotune={getattr(stats, 'autotune_steps', 0)}]")
    salted_by_idx = {e["node_index"]: e
                     for e in getattr(stats, "adapt_events", [])
                     if e.get("kind") == "salted"}
    idx_of = {n.nid: i for i, n in enumerate(pplan.order)}
    lines = [
        f"== EXPLAIN ANALYZE: mode={stats.mode}, "
        f"wall={stats.wall_time_s:.4f}s, dispatches={stats.dispatches} "
        f"(compile cache: {cache}){ft} ==",
        f"   shuffled {stats.rows_shuffled} rows / "
        f"{_fmt_bytes(stats.bytes_shuffled)}"
        + (f", dropped {stats.rows_dropped}" if stats.rows_dropped else "")
        + (f", {stats.morsels} morsels" if getattr(stats, "morsels", 0)
           else ""),
    ]
    if getattr(stats, "rows_read", 0) or getattr(stats, "bytes_read", 0):
        # ingest attribution: a distinct "scan" stage ahead of stage 0,
        # fed by the scan tables' IngestInfo provenance (repro.io)
        lines.append(
            f"stage scan: ingested {getattr(stats, 'rows_read', 0)} rows / "
            f"{_fmt_bytes(getattr(stats, 'bytes_read', 0))} from source "
            f"files")
    by_stage: Dict[int, list] = {}
    for n in pplan.order:
        by_stage.setdefault(pplan.stage_of[n.nid], []).append(n)
    for s in sorted(by_stage):
        t = f"  [{stage_secs[s]:.4f}s]" if s in stage_secs else ""
        lines.append(f"stage {s}:{t}")
        for n in by_stage[s]:
            acts = []
            if n.op == "scan" and n.params["name"] in scan_rows:
                acts.append(f"rows={scan_rows[n.params['name']]}")
            a = _node_actuals(n, records)
            if a:
                acts.append(a)
            ev = salted_by_idx.get(idx_of.get(n.nid))
            if ev is not None:
                acts.append(adapt_note(ev))
            if n.nid == pplan.root.nid and result_rows is not None:
                acts.append(f"out_rows={result_rows}")
            est = f"rows~{int(n.est_rows):>9d}"
            act = f"  act: {'; '.join(acts)}" if acts else ""
            lines.append(f"  {node_label(n):44s} {est}{act}")
    if stats.stage_times:
        unmapped = [(k, v) for k, v in stats.stage_times
                    if not k.startswith("stage:")]
        if unmapped:
            lines.append("timed units:")
            for name, secs in unmapped:
                lines.append(f"  {name:44s} {secs:.4f}s")
    return "\n".join(lines)


def stage_table(pplan, stats, parallelism: int) -> List[Dict[str, Any]]:
    """Per-stage measured volumes + roofline terms (machine-readable rows;
    ``QueryReport.roofline_table`` renders the markdown)."""
    from ..launch.roofline import stage_roofline
    from ..planner.physical import node_stat_labels
    records = _records_by_label(stats)
    stage_secs = _stage_seconds(stats)
    by_stage: Dict[int, list] = {}
    for n in pplan.order:
        by_stage.setdefault(pplan.stage_of[n.nid], []).append(n)
    rows = []
    for s in sorted(by_stage):
        wire = 0
        srows = 0
        for n in by_stage[s]:
            stems = {l.split(":")[0] for l in node_stat_labels(n)}
            for l in records:
                if l.split(":")[0] in stems and not l.endswith(":overflow"):
                    wire += records[l]["bytes"]
                    srows += records[l]["rows"]
        secs = stage_secs.get(s)
        terms = stage_roofline(wire, secs, parallelism)
        rows.append({
            "stage": s,
            "ops": [n.op for n in by_stage[s]],
            "rows_shuffled": srows,
            "wire_bytes": wire,
            "elapsed_s": secs,
            "memory_s": terms["memory_s"],
            "collective_s": terms["collective_s"],
            "bound_s": terms["step_s_lower_bound"],
            "dominant": terms["dominant"],
            "roofline_fraction": terms["roofline_fraction"],
        })
    return rows


class QueryReport:
    """Everything one analyzed execution measured, in one object.

    ``explain_analyze()`` — the annotated plan tree;
    ``roofline_table()`` — per-stage bytes-moved + roofline fraction;
    ``to_chrome_trace(path)`` — the Chrome/Perfetto timeline;
    ``to_json(path)`` — the machine-readable bundle.  ``str(report)``
    concatenates the two human renderings.
    """

    def __init__(self, pplan, stats, trace: Optional[QueryTrace],
                 parallelism: int,
                 scan_rows: Optional[Dict[str, int]] = None,
                 result_rows: Optional[int] = None):
        self.pplan = pplan
        self.stats = stats
        self.trace = trace
        self.parallelism = parallelism
        self.scan_rows = dict(scan_rows or {})
        self.result_rows = result_rows

    @property
    def wall_time_s(self) -> float:
        return self.stats.wall_time_s

    def explain_analyze(self) -> str:
        return render_analyze(self.pplan, self.stats, self.scan_rows,
                              self.result_rows)

    def stage_table(self) -> List[Dict[str, Any]]:
        return stage_table(self.pplan, self.stats, self.parallelism)

    def roofline_table(self) -> str:
        hdr = ("| stage | ops | rows | wire | elapsed s | bound s "
               "| dominant | roofline frac |")
        lines = [hdr, "|" + "---|" * 8]
        for r in self.stage_table():
            el = f"{r['elapsed_s']:.4f}" if r["elapsed_s"] is not None else "-"
            frac = (f"{r['roofline_fraction']:.3f}"
                    if r["elapsed_s"] else "-")
            lines.append(
                f"| {r['stage']} | {','.join(r['ops'])} "
                f"| {r['rows_shuffled']} | {_fmt_bytes(r['wire_bytes'])} "
                f"| {el} | {r['bound_s']:.2e} | {r['dominant']} | {frac} |")
        return "\n".join(lines)

    def to_chrome_trace(self, path: Optional[str] = None) -> Dict[str, Any]:
        if self.trace is None:
            raise ValueError("no trace attached (run with trace enabled)")
        return self.trace.to_chrome_trace(path)

    def to_dict(self) -> Dict[str, Any]:
        st = self.stats
        return {
            "mode": st.mode,
            "fingerprint": self.pplan.fingerprint,
            "parallelism": self.parallelism,
            "wall_time_s": st.wall_time_s,
            "stage_times": list(st.stage_times),
            "dispatches": st.dispatches,
            "rows_shuffled": st.rows_shuffled,
            "bytes_shuffled": st.bytes_shuffled,
            "rows_dropped": st.rows_dropped,
            "cache_hits": st.cache_hits,
            "cache_misses": st.cache_misses,
            "retries": getattr(st, "retries", 0),
            "degraded": getattr(st, "degraded", 0),
            "faults_injected": getattr(st, "faults_injected", 0),
            "adaptive": getattr(st, "adaptive", False),
            "salted_shuffles": getattr(st, "salted_shuffles", 0),
            "splitter_refreshes": getattr(st, "splitter_refreshes", 0),
            "autotune_steps": getattr(st, "autotune_steps", 0),
            "adapt_events": list(getattr(st, "adapt_events", [])),
            "scan_rows": self.scan_rows,
            "rows_read": getattr(st, "rows_read", 0),
            "bytes_read": getattr(st, "bytes_read", 0),
            "result_rows": self.result_rows,
            "shuffle_records": [
                {"label": r.label, "segment": r.segment,
                 "rows": r.rows, "bytes": r.bytes,
                 "dropped": r.dropped,
                 "per_rank_rows": list(r.per_rank_rows),
                 "per_rank_dropped": list(r.per_rank_dropped)}
                for r in st.shuffle_records],
            "stages": self.stage_table(),
        }

    def to_json(self, path: Optional[str] = None, indent: int = 1) -> str:
        text = json.dumps(self.to_dict(), indent=indent, sort_keys=True,
                          default=str)
        if path is not None:
            with open(path, "w") as f:
                f.write(text + "\n")
        return text

    def __str__(self) -> str:
        return self.explain_analyze() + "\n\n" + self.roofline_table()


def run_analyzed(plan, env, tables: Dict[str, Any], mode: str = "bsp_staged",
                 optimize: bool = True, shuffle_impl: str = "radix",
                 a2a_chunks: int = 1, morsel_rows: Optional[int] = None,
                 trace: Any = True, **morsel_kw
                 ) -> Tuple[Any, QueryReport]:
    """Execute with stats + tracing on; returns ``(result, QueryReport)``.

    ``mode="bsp_staged"`` is the default because one dispatch per stage is
    what makes per-stage times attributable; ``bsp`` runs everything in one
    program (one "program" timing unit), ``morsel_rows`` streams out-of-core
    (per-segment units).  ``trace=False`` skips the timeline but keeps the
    annotated tree and roofline table.
    """
    from ..planner import compile_plan, run_physical
    from .trace import resolve_tracer
    tracer = resolve_tracer(trace, name="analyze")
    pplan = compile_plan(plan, tables, optimize_plan=optimize)
    with tracer.span("query", "query", mode=mode,
                     fingerprint=pplan.fingerprint,
                     stages=pplan.num_stages, shuffles=pplan.num_shuffles):
        result, stats = run_physical(
            pplan, env, tables, mode, collect_stats=True,
            shuffle_impl=shuffle_impl, a2a_chunks=a2a_chunks,
            morsel_rows=morsel_rows, tracer=tracer, **morsel_kw)
    qtrace = tracer.finish() if isinstance(tracer, Tracer) else None
    scan_rows = {name: r for name in pplan.scan_names
                 if (r := _rows_of(tables.get(name))) is not None}
    report = QueryReport(pplan, stats, qtrace, env.parallelism,
                         scan_rows=scan_rows, result_rows=_rows_of(result))
    return result, report

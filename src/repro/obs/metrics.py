"""Process-global metrics: labeled counters / gauges / histograms plus a
per-query record log.

This is the feed a multi-query admission controller needs (ROADMAP:
"async submission queue with admission control and per-query stats"): every
``collect_stats=True`` / traced execution appends one machine-readable
record (fingerprint, mode, wall time, rows/bytes shuffled, drops, cache
traffic) to ``MetricsRegistry.query_records`` and bumps the engine-wide
counters.  ``snapshot()`` / ``to_json()`` export the whole registry.

Instruments are cheap (a dict update under a lock, driver-side only) and
created lazily by name, Prometheus-style:

    METRICS.counter("queries_total").inc(mode="bsp")
    METRICS.histogram("query_wall_s").observe(0.12)
    METRICS.snapshot()["counters"]["queries_total"]

Label sets are kwargs; each distinct label combination tracks its own
series.  The registry is process-global (``repro.obs.METRICS``) so many
queries — eventually many concurrent sessions — accumulate into one place.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

_LabelKey = Tuple[Tuple[str, Any], ...]


def _key(labels: Dict[str, Any]) -> _LabelKey:
    return tuple(sorted(labels.items()))


class Counter:
    """Monotonically increasing sum per label set."""

    def __init__(self, name: str, help: str, lock: threading.Lock):
        self.name, self.help = name, help
        self._lock = lock
        self._values: Dict[_LabelKey, float] = {}

    def inc(self, value: float = 1.0, **labels: Any) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        k = _key(labels)
        with self._lock:
            self._values[k] = self._values.get(k, 0.0) + value

    def value(self, **labels: Any) -> float:
        return self._values.get(_key(labels), 0.0)

    def snapshot(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [{"labels": dict(k), "value": v}
                    for k, v in sorted(self._values.items())]


class Gauge:
    """Last-set value per label set (pool occupancy, queue depth, ...)."""

    def __init__(self, name: str, help: str, lock: threading.Lock):
        self.name, self.help = name, help
        self._lock = lock
        self._values: Dict[_LabelKey, float] = {}

    def set(self, value: float, **labels: Any) -> None:
        with self._lock:
            self._values[_key(labels)] = float(value)

    def value(self, **labels: Any) -> float:
        return self._values.get(_key(labels), 0.0)

    def snapshot(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [{"labels": dict(k), "value": v}
                    for k, v in sorted(self._values.items())]


#: default histogram buckets: ~log-spaced from 1ms to ~2min (seconds) —
#: sized for query wall times; byte-valued histograms pass their own
DEFAULT_BUCKETS = (0.001, 0.005, 0.02, 0.1, 0.5, 2.0, 10.0, 30.0, 120.0)


class Histogram:
    """Cumulative-bucket histogram per label set (count/sum/min/max too)."""

    def __init__(self, name: str, help: str, lock: threading.Lock,
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.name, self.help = name, help
        self.buckets = tuple(sorted(buckets))
        self._lock = lock
        self._series: Dict[_LabelKey, Dict[str, Any]] = {}

    def observe(self, value: float, **labels: Any) -> None:
        k = _key(labels)
        with self._lock:
            s = self._series.get(k)
            if s is None:
                s = self._series[k] = {
                    "count": 0, "sum": 0.0,
                    "min": float("inf"), "max": float("-inf"),
                    "bucket_counts": [0] * (len(self.buckets) + 1)}
            s["count"] += 1
            s["sum"] += value
            s["min"] = min(s["min"], value)
            s["max"] = max(s["max"], value)
            for i, b in enumerate(self.buckets):
                if value <= b:
                    s["bucket_counts"][i] += 1
                    break
            else:
                s["bucket_counts"][-1] += 1

    def series(self, **labels: Any) -> Optional[Dict[str, Any]]:
        s = self._series.get(_key(labels))
        return dict(s) if s is not None else None

    def snapshot(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [{"labels": dict(k), "buckets": list(self.buckets),
                     **{kk: (vv if kk != "bucket_counts" else list(vv))
                        for kk, vv in s.items()}}
                    for k, s in sorted(self._series.items())]


class MetricsRegistry:
    """Named instruments + the per-query record log.

    ``max_query_records`` bounds the log (drop-oldest) so a long-lived
    serving process cannot grow without bound.
    """

    def __init__(self, max_query_records: int = 4096):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self.max_query_records = max_query_records
        self._query_records: List[Dict[str, Any]] = []

    # -- instrument accessors (create-on-first-use) ---------------------- #
    def counter(self, name: str, help: str = "") -> Counter:
        with self._lock:
            if name not in self._counters:
                self._counters[name] = Counter(name, help, threading.Lock())
            return self._counters[name]

    def gauge(self, name: str, help: str = "") -> Gauge:
        with self._lock:
            if name not in self._gauges:
                self._gauges[name] = Gauge(name, help, threading.Lock())
            return self._gauges[name]

    def histogram(self, name: str, help: str = "",
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        with self._lock:
            if name not in self._histograms:
                self._histograms[name] = Histogram(name, help,
                                                   threading.Lock(), buckets)
            return self._histograms[name]

    # -- per-query records ----------------------------------------------- #
    def record_query(self, record: Dict[str, Any]) -> Dict[str, Any]:
        """Append one per-query record (adds a wall-clock timestamp)."""
        rec = {"recorded_at": time.time(), **record}
        with self._lock:
            self._query_records.append(rec)
            if len(self._query_records) > self.max_query_records:
                del self._query_records[
                    :len(self._query_records) - self.max_query_records]
        return rec

    @property
    def query_records(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._query_records)

    # -- export ----------------------------------------------------------- #
    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._histograms)
            records = list(self._query_records)
        return {
            "counters": {n: c.snapshot() for n, c in sorted(counters.items())},
            "gauges": {n: g.snapshot() for n, g in sorted(gauges.items())},
            "histograms": {n: h.snapshot() for n, h in sorted(hists.items())},
            "query_records": records,
        }

    def to_json(self, path: Optional[str] = None, indent: int = 1) -> str:
        text = json.dumps(self.snapshot(), indent=indent, sort_keys=True,
                          default=str)
        if path is not None:
            with open(path, "w") as f:
                f.write(text + "\n")
        return text

    def reset(self) -> None:
        """Drop all instruments and records (tests / fresh serving epoch)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()
            self._query_records.clear()


#: the process-global registry every execution reports into
METRICS = MetricsRegistry()


def record_serve_query(stats: Dict[str, Any], scheduler: str = "serve",
                       registry: Optional[MetricsRegistry] = None
                       ) -> Dict[str, Any]:
    """Fold one finished scheduler query (a ``QueryHandle.stats`` dict)
    into the registry: per-outcome completion counters plus queue-wait and
    execution-wall histograms, all labeled by scheduler name.  The
    per-stage engine metrics still arrive via ``record_exec`` from the
    worker's own execution."""
    reg = registry if registry is not None else METRICS
    state = stats.get("state", "unknown")
    reg.counter("serve_completed_total",
                "scheduler queries finished, by outcome").inc(
        scheduler=scheduler, state=state)
    if "queue_wait_s" in stats:
        reg.histogram("serve_queue_wait_s",
                      "time from submit to dequeue").observe(
            stats["queue_wait_s"], scheduler=scheduler)
    if "wall_s" in stats:
        reg.histogram("serve_query_wall_s",
                      "gang execution wall time").observe(
            stats["wall_s"], scheduler=scheduler, state=state)
    record = {"kind": "serve", "scheduler": scheduler}
    record.update({k: v for k, v in stats.items()
                   if not k.endswith("_monotonic")})
    return reg.record_query(record)


def record_exec(stats: Any, fingerprint: str, wall_time_s: float,
                query: str = "", registry: Optional[MetricsRegistry] = None
                ) -> Dict[str, Any]:
    """Fold one finished execution's ``ExecStats`` into the registry:
    engine-wide counters + one per-query record.  Called by the executors
    (``run_physical`` / ``run_morsel``) when stats were collected."""
    reg = registry if registry is not None else METRICS
    mode = stats.mode
    reg.counter("queries_total", "completed executions").inc(mode=mode)
    reg.counter("dispatches_total", "program dispatches").inc(
        stats.dispatches, mode=mode)
    reg.counter("rows_shuffled_total", "rows moved by shuffles").inc(
        stats.rows_shuffled, mode=mode)
    reg.counter("bytes_shuffled_total", "bytes moved by shuffles").inc(
        stats.bytes_shuffled, mode=mode)
    reg.counter("rows_dropped_total", "rows lost to capacity pressure").inc(
        stats.rows_dropped, mode=mode)
    reg.counter("compile_cache_hits_total", "compile-cache hits").inc(
        stats.cache_hits)
    reg.counter("compile_cache_misses_total", "compile-cache misses").inc(
        stats.cache_misses)
    if getattr(stats, "retries", 0):
        reg.counter("retries_total",
                    "dispatch units replayed after a fault").inc(
            stats.retries, mode=mode)
    if getattr(stats, "degraded", 0):
        reg.counter("degraded_total",
                    "capacity-degrade re-executions").inc(
            stats.degraded, mode=mode)
    if getattr(stats, "faults_injected", 0):
        reg.counter("faults_injected_total",
                    "faults fired by the active FaultPlan").inc(
            stats.faults_injected, mode=mode)
    if getattr(stats, "rows_read", 0):
        reg.counter("rows_read_total",
                    "rows ingested from scan sources").inc(
            stats.rows_read, mode=mode)
    if getattr(stats, "bytes_read", 0):
        reg.counter("bytes_read_total",
                    "source bytes ingested from scan sources").inc(
            stats.bytes_read, mode=mode)
    if getattr(stats, "salted_shuffles", 0):
        reg.counter("salted_shuffles_total",
                    "shuffle boundaries re-routed by hot-key salting").inc(
            stats.salted_shuffles, mode=mode)
    if getattr(stats, "splitter_refreshes", 0):
        reg.counter("splitter_refreshes_total",
                    "range-splitter re-samples on sort imbalance").inc(
            stats.splitter_refreshes, mode=mode)
    if getattr(stats, "autotune_steps", 0):
        reg.counter("autotune_steps_total",
                    "morsel-size autotuner adjustments").inc(
            stats.autotune_steps, mode=mode)
    if wall_time_s > 0:
        reg.histogram("query_wall_s", "end-to-end query wall time").observe(
            wall_time_s, mode=mode)
    record = {
        "query": query,
        "fingerprint": fingerprint,
        "mode": mode,
        "wall_time_s": wall_time_s,
        "stage_times": list(getattr(stats, "stage_times", ())),
        "dispatches": stats.dispatches,
        "num_stages": stats.num_stages,
        "num_shuffles": stats.num_shuffles,
        "rows_shuffled": stats.rows_shuffled,
        "bytes_shuffled": stats.bytes_shuffled,
        "rows_dropped": stats.rows_dropped,
        "cache_hits": stats.cache_hits,
        "cache_misses": stats.cache_misses,
        "shuffle_impl": stats.shuffle_impl,
        "morsels": getattr(stats, "morsels", 0),
        "spill_bytes": getattr(stats, "spill_bytes", 0),
        "h2d_bytes": getattr(stats, "h2d_bytes", 0),
        "retries": getattr(stats, "retries", 0),
        "degraded": getattr(stats, "degraded", 0),
        "faults_injected": getattr(stats, "faults_injected", 0),
        "rows_read": getattr(stats, "rows_read", 0),
        "bytes_read": getattr(stats, "bytes_read", 0),
        "salted_shuffles": getattr(stats, "salted_shuffles", 0),
        "splitter_refreshes": getattr(stats, "splitter_refreshes", 0),
        "autotune_steps": getattr(stats, "autotune_steps", 0),
    }
    return reg.record_query(record)

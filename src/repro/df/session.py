"""Session management: which ``CylonEnv`` a lazy DataFrame executes on.

The paper's pitch is that users write ordinary dataframe code while the
HPC environment underneath is supplied for them.  ``repro.df`` therefore
never requires an explicit env: ``collect()`` resolves the *active* env —
the innermost ``session(...)`` context manager, else a process-wide
default created lazily over all local devices:

    import repro.df as rdf

    df = rdf.read_numpy({"k": keys, "v": vals})     # default env
    out = df[df.k > 0].collect()

    with rdf.session(communicator="ring") as env:   # scoped override
        out = df2.collect()                         # runs on `env`

Sessions nest (a stack); an explicit ``env=`` argument on ``collect`` /
``read_numpy`` always wins.  ``set_default_env`` pins the process-wide
fallback (e.g. a ``DevicePool`` partition) without a ``with`` block.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Iterator, List, Optional, Sequence

import jax

from ..core.env import CylonEnv

__all__ = ["session", "get_env", "set_default_env", "reset_default_env",
           "get_session_defaults", "get_active_scheduler"]

_lock = threading.Lock()
_default: Optional[CylonEnv] = None
_tls = threading.local()

#: fault-tolerance / adaptivity knobs a session may default for every
#: collect() in its scope (an explicit collect() argument always wins);
#: see ``docs/fault_tolerance.md`` and ``docs/adaptive.md``
_DEFAULT_KEYS = ("timeout", "retries", "overflow", "faults", "adaptive")


def _stack() -> List[CylonEnv]:
    """Per-thread session stack: concurrent threads scope independently
    (the process default below is shared, guarded by ``_lock``)."""
    try:
        return _tls.stack
    except AttributeError:
        _tls.stack = []
        return _tls.stack


def _defaults_stack() -> List[dict]:
    """Per-thread stack of session-scoped collect() defaults (parallel to
    ``_stack`` but pushed only by sessions that set any)."""
    try:
        return _tls.defaults
    except AttributeError:
        _tls.defaults = []
        return _tls.defaults


def get_session_defaults() -> dict:
    """Effective fault-tolerance defaults for this thread: innermost
    session values win, outer sessions fill the gaps."""
    merged: dict = {}
    for layer in _defaults_stack():
        merged.update(layer)
    return merged


def get_active_scheduler():
    """The ``repro.serve.QueryScheduler`` the innermost session scopes on
    this thread, or None.  An inner env-bearing ``session(...)`` masks an
    outer scheduler session (its layer pins ``scheduler=None``), so plain
    in-thread execution wins wherever it is the innermost choice."""
    return get_session_defaults().get("scheduler")


def get_env() -> CylonEnv:
    """The active env: innermost env-bearing ``session`` on this thread
    (scheduler sessions scope no env and are skipped), else the
    lazily-created process default (all local devices, XLA communicator)."""
    global _default
    for e in reversed(_stack()):
        if e is not None:
            return e
    with _lock:
        if _default is None:
            _default = CylonEnv()
        return _default


def set_default_env(env: CylonEnv) -> None:
    """Pin the process-wide fallback env (overrides lazy creation)."""
    global _default
    with _lock:
        _default = env


def reset_default_env() -> None:
    """Drop the process default so the next ``get_env`` recreates it
    (mainly for tests that reconfigure the device mesh)."""
    global _default
    with _lock:
        _default = None


@contextlib.contextmanager
def session(env: Optional[CylonEnv] = None, *,
            devices: Optional[Sequence[jax.Device]] = None,
            communicator: Optional[str] = None,
            scheduler=None,
            timeout=None, retries=None, overflow=None,
            faults=None, adaptive=None) -> Iterator[Any]:
    """Scope an active env: ``with session(...) as env: df.collect()``.

    Pass an existing ``env``, or let the session build one from
    ``devices`` (default: all local) and ``communicator`` (default
    ``"xla"``).  Passing ``devices=`` or ``communicator=`` alongside an
    explicit ``env=`` raises ``TypeError`` — the env already pins both, so
    silently ignoring either would misconfigure the gang.  The compiled
    program cache lives on the env, so reusing one session across many
    ``collect`` calls is what makes repeat execution cheap.

    ``scheduler=`` scopes a ``repro.serve.QueryScheduler`` instead of an
    env: every ``collect()`` in scope (without an explicit ``env=`` or an
    ingest-pinned env) is submitted to the scheduler and blocks on its
    ``QueryHandle`` — many threads each inside such a session share the
    scheduler's gangs (``docs/serving.md``).  The session yields the
    scheduler.  Mutually exclusive with ``env=`` / ``devices=`` /
    ``communicator=``; a nested env-bearing session masks it.

    ``timeout`` / ``retries`` / ``overflow`` / ``faults`` set the
    session-wide fault-tolerance defaults applied to every ``collect()``
    in scope (``docs/fault_tolerance.md``); a per-call argument overrides,
    and nested sessions override outer ones per key.  A session-level
    ``timeout`` is a *per-query* deadline, re-armed at each collect.

    ``adaptive`` defaults the runtime skew-mitigation knob the same way
    (``docs/adaptive.md``): ``session(adaptive=False)`` pins every collect
    in scope to the non-adaptive programs; a dict or
    ``repro.adapt.AdaptiveConfig`` tunes detection thresholds.
    """
    if scheduler is not None:
        if env is not None or devices is not None or communicator is not None:
            raise TypeError("pass either scheduler= or an env (env= / "
                            "devices= / communicator=), not both")
    elif env is None:
        env = CylonEnv(devices=devices,
                       communicator=communicator
                       if communicator is not None else "xla")
    elif devices is not None:
        raise TypeError("pass either env= or devices=, not both")
    elif communicator is not None:
        raise TypeError(
            "pass either env= or communicator=, not both: the env already "
            "carries its communicator "
            f"({env.communicator_name!r})")
    layer = {k: v for k, v in (("timeout", timeout), ("retries", retries),
                               ("overflow", overflow), ("faults", faults),
                               ("adaptive", adaptive))
             if v is not None}
    # scheduler scoping is innermost-wins in both directions: a scheduler
    # session sets it, an env session explicitly masks any outer scheduler
    layer["scheduler"] = scheduler
    stack = _stack()
    stack.append(env)          # None marks a scheduler layer
    _defaults_stack().append(layer)
    try:
        yield scheduler if scheduler is not None else env
    finally:
        stack.pop()
        _defaults_stack().pop()

"""Session management: which ``CylonEnv`` a lazy DataFrame executes on.

The paper's pitch is that users write ordinary dataframe code while the
HPC environment underneath is supplied for them.  ``repro.df`` therefore
never requires an explicit env: ``collect()`` resolves the *active* env —
the innermost ``session(...)`` context manager, else a process-wide
default created lazily over all local devices:

    import repro.df as rdf

    df = rdf.read_numpy({"k": keys, "v": vals})     # default env
    out = df[df.k > 0].collect()

    with rdf.session(communicator="ring") as env:   # scoped override
        out = df2.collect()                         # runs on `env`

Sessions nest (a stack); an explicit ``env=`` argument on ``collect`` /
``read_numpy`` always wins.  ``set_default_env`` pins the process-wide
fallback (e.g. a ``DevicePool`` partition) without a ``with`` block.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Iterator, List, Optional, Sequence

import jax

from ..core.env import CylonEnv

__all__ = ["session", "get_env", "set_default_env", "reset_default_env"]

_lock = threading.Lock()
_default: Optional[CylonEnv] = None
_tls = threading.local()


def _stack() -> List[CylonEnv]:
    """Per-thread session stack: concurrent threads scope independently
    (the process default below is shared, guarded by ``_lock``)."""
    try:
        return _tls.stack
    except AttributeError:
        _tls.stack = []
        return _tls.stack


def get_env() -> CylonEnv:
    """The active env: innermost ``session`` on this thread, else the
    lazily-created process default (all local devices, XLA communicator)."""
    global _default
    stack = _stack()
    if stack:
        return stack[-1]
    with _lock:
        if _default is None:
            _default = CylonEnv()
        return _default


def set_default_env(env: CylonEnv) -> None:
    """Pin the process-wide fallback env (overrides lazy creation)."""
    global _default
    with _lock:
        _default = env


def reset_default_env() -> None:
    """Drop the process default so the next ``get_env`` recreates it
    (mainly for tests that reconfigure the device mesh)."""
    global _default
    with _lock:
        _default = None


@contextlib.contextmanager
def session(env: Optional[CylonEnv] = None, *,
            devices: Optional[Sequence[jax.Device]] = None,
            communicator: str = "xla") -> Iterator[CylonEnv]:
    """Scope an active env: ``with session(...) as env: df.collect()``.

    Pass an existing ``env``, or let the session build one from
    ``devices`` (default: all local) and ``communicator``.  The compiled
    program cache lives on the env, so reusing one session across many
    ``collect`` calls is what makes repeat execution cheap.
    """
    if env is None:
        env = CylonEnv(devices=devices, communicator=communicator)
    elif devices is not None:
        raise TypeError("pass either env= or devices=, not both")
    stack = _stack()
    stack.append(env)
    try:
        yield env
    finally:
        stack.pop()

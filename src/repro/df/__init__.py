"""``repro.df`` — the user-facing lazy DataFrame API.

Write ordinary dataframe code; the planner, compiled BSP execution, and
(optionally) out-of-core morsel streaming run underneath:

    import numpy as np
    import repro.df as rdf
    from repro.expr import col

    df = rdf.read_numpy({"k": keys, "v": vals})
    out = (df[df.v * 2 > 5]
           .assign(v2=df.v + 1)
           .groupby("k").agg({"v2": ["sum", "mean"]})
           .sort_values("k"))
    print(out.explain())        # optimized plan, rules fired
    table = out.collect()       # executes on the active session env
    pdf = out.to_pandas()

See ``docs/api.md`` for the full frontend + expression reference.
"""

from ..expr import Expr, col, lit
from .frame import (DataFrame, GroupBy, from_pandas, from_table, read_csv,
                    read_numpy, read_parquet)
from .session import get_env, reset_default_env, session, set_default_env

__all__ = [
    "DataFrame", "GroupBy", "Expr", "col", "lit",
    "read_numpy", "from_pandas", "from_table", "read_parquet", "read_csv",
    "session", "get_env", "set_default_env", "reset_default_env",
]

"""Lazy ``DataFrame``: pandas/Dask-style frontend over the planner.

A ``DataFrame`` is a *recipe*: it wraps a ``core.plan.Plan`` builder tree
plus the source tables its scans refer to, and tracks the output schema so
column references are validated at build time.  Nothing executes until
``collect()`` / ``to_pandas()``; ``explain()`` shows the optimized plan.
Every transformation returns a new DataFrame (builders are immutable), so
partial pipelines can be shared and extended freely — the structural
fingerprint compile cache means two DataFrames that describe the same
computation share one compiled program.

Column references are typed expressions (``repro.expr``): ``df.v`` /
``df["v"]`` is ``col("v")``, so ``df[df.v * 2 > 5]`` builds a declarative
predicate the optimizer can split, push past joins, and prune columns
through — none of which is possible with a lambda.
"""

from __future__ import annotations

import itertools
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.env import CylonEnv, DistTable
from ..core.plan import Plan, execute
from ..core.store import SpillTable
from ..expr import Col, Expr, ensure_expr
from ..planner.logical import groupby_schema, join_schema
from .session import get_active_scheduler, get_env, get_session_defaults

__all__ = ["DataFrame", "GroupBy", "read_numpy", "from_pandas", "from_table",
           "read_parquet", "read_csv"]

_src_ids = itertools.count()


def _source_schema(table: Any) -> Tuple[str, ...]:
    # validity masks (__m_*) are physical companions, not logical schema:
    # they ride along implicitly and never appear in df.columns
    from ..nulls import data_columns
    if hasattr(table, "column_names"):
        return tuple(sorted(data_columns(table.column_names)))
    if isinstance(table, Mapping):
        return tuple(sorted(data_columns(table)))
    raise TypeError(f"cannot infer a schema from {type(table).__name__}")


class DataFrame:
    """Lazy distributed dataframe (see module docstring).

    Do not construct directly — use ``read_numpy`` / ``from_pandas`` /
    ``from_table``, or derive from an existing DataFrame.
    """

    __slots__ = ("plan", "sources", "_schema", "_env")

    def __init__(self, plan: Plan, sources: Dict[str, Any],
                 schema: Sequence[str], env: Optional[CylonEnv] = None):
        object.__setattr__(self, "plan", plan)
        object.__setattr__(self, "sources", sources)
        object.__setattr__(self, "_schema", tuple(sorted(schema)))
        # env the data was ingested for (read_numpy(env=...)); preferred
        # over the ambient session at collect() so the frame keeps running
        # on the gang its tables are partitioned for
        object.__setattr__(self, "_env", env)

    def __setattr__(self, name, value):
        raise AttributeError(
            "DataFrames are immutable; use assign(...) to add columns")

    # ------------------------------------------------------------------ #
    # schema / column access
    # ------------------------------------------------------------------ #
    @property
    def columns(self) -> Tuple[str, ...]:
        return self._schema

    def _check_cols(self, cols, what: str) -> None:
        missing = sorted(set(cols) - set(self._schema))
        if missing:
            raise KeyError(f"{what} references unknown column(s) {missing}; "
                           f"have {list(self._schema)}")

    def _derive(self, plan: Plan, schema: Sequence[str],
                sources: Optional[Dict[str, Any]] = None,
                env: Optional[CylonEnv] = None) -> "DataFrame":
        return DataFrame(plan, self.sources if sources is None else sources,
                         schema, env if env is not None else self._env)

    def __getattr__(self, name: str) -> Col:
        # only reached when normal attribute lookup fails; shadowed column
        # names (e.g. a column called "merge") are reachable via df["merge"]
        if not name.startswith("_") and name in self._schema:
            return Col(name)
        raise AttributeError(f"no attribute or column {name!r} "
                             f"(columns: {list(self._schema)})")

    def __dir__(self) -> List[str]:
        return sorted(set(super().__dir__()) | set(self._schema))

    def __getitem__(self, key):
        if isinstance(key, Expr):
            return self.filter(key)
        if isinstance(key, str):
            self._check_cols([key], "df[...]")
            return Col(key)
        if isinstance(key, (list, tuple)):
            return self.select(key)
        raise TypeError(f"cannot index a DataFrame with {type(key).__name__}")

    # ------------------------------------------------------------------ #
    # transformations (all lazy)
    # ------------------------------------------------------------------ #
    def filter(self, pred: Expr) -> "DataFrame":
        """Keep rows where the boolean expression holds
        (``df[df.v > 0]`` is sugar for ``df.filter(df.v > 0)``)."""
        if not isinstance(pred, Expr):
            raise TypeError(
                "filter takes a typed expression (df.v > 0); for a legacy "
                "callable use the core Plan builder's deprecated shim")
        cols = pred.columns()
        if cols is not None:
            self._check_cols(cols, "filter predicate")
        return self._derive(self.plan.filter(pred), self._schema)

    def select(self, cols: Sequence[str]) -> "DataFrame":
        """Projection: ``df[["k", "v"]]``."""
        cols = list(cols)
        self._check_cols(cols, "select")
        return self._derive(self.plan.project(cols), cols)

    def assign(self, **exprs: Union[Expr, Any]) -> "DataFrame":
        """Add or replace columns: ``df.assign(v2=df.v * 2)``.

        All expressions read the *input* frame (simultaneous assignment,
        like pandas); bare scalars broadcast to constant columns.
        """
        return self.with_columns(exprs)

    def with_columns(self, exprs: Mapping[str, Union[Expr, Any]]
                     ) -> "DataFrame":
        """Dict form of ``assign`` (allows non-identifier column names)."""
        mapping = {name: ensure_expr(e) for name, e in exprs.items()}
        for name, e in mapping.items():
            cols = e.columns()
            if cols is not None:
                self._check_cols(cols, f"assign {name!r}")
        return self._derive(self.plan.with_columns(mapping),
                            set(self._schema) | set(mapping))

    def merge(self, other: "DataFrame", on: str, **kw) -> "DataFrame":
        """Inner equi-join (hash-partitioned on ``on``); colliding right
        columns get the ``_r`` suffix.  Extra ``kw`` (``out_capacity``,
        ``bucket_capacity``, ``shuffle_out_capacity``, ...) pass through to
        the join operator."""
        if not isinstance(other, DataFrame):
            raise TypeError("merge expects another repro.df.DataFrame")
        self._check_cols([on], "merge key")
        other._check_cols([on], "merge key")
        clash = [n for n in self.sources
                 if n in other.sources
                 and other.sources[n] is not self.sources[n]]
        if clash:
            # silently keeping one side would make both scans read the
            # same table and return wrong data
            raise ValueError(
                f"merge source name collision on {clash}: the frames were "
                f"built from different tables under the same scan name — "
                f"pass distinct name= to from_table/read_numpy")
        if (self._env is not None and other._env is not None
                and other._env is not self._env):
            raise ValueError(
                "merge of frames ingested for different envs; re-ingest "
                "one side (read_numpy(..., env=...)) on a common env")
        sources = {**self.sources, **other.sources}
        schema = join_schema(self._schema, other._schema, on)
        return self._derive(self.plan.join(other.plan, on=on, **kw),
                            schema, sources, env=self._env or other._env)

    def groupby(self, keys: Union[str, Sequence[str]], **kw) -> "GroupBy":
        """Group by key column(s); terminate with ``.agg(...)``.  Extra
        ``kw`` (``bucket_capacity``, ``out_capacity``, ``pre_aggregate``,
        ...) pass through to the groupby operator."""
        keys = [keys] if isinstance(keys, str) else list(keys)
        self._check_cols(keys, "groupby keys")
        return GroupBy(self, keys, kw)

    def sort_values(self, by: Union[str, Sequence[str]], **kw) -> "DataFrame":
        """Globally sort (ascending) by column(s): sample-sort range
        partitioning + local sort."""
        by = [by] if isinstance(by, str) else list(by)
        self._check_cols(by, "sort_values")
        return self._derive(self.plan.sort(by, **kw), self._schema)

    # -- missing data ---------------------------------------------------- #
    def dropna(self, subset: Optional[Sequence[str]] = None) -> "DataFrame":
        """Drop rows that are null in any of ``subset`` (default: any
        column).  Lowers to a null-aware filter, so the optimizer elides
        the check entirely for provably non-null columns."""
        cols = list(self._schema) if subset is None else list(subset)
        if subset is not None:
            self._check_cols(cols, "dropna subset")
        if not cols:
            return self
        pred: Expr = ~Col(cols[0]).is_null()
        for c in cols[1:]:
            pred = pred & ~Col(c).is_null()
        return self.filter(pred)

    def fillna(self, value: Union[Mapping[str, Any], Any],
               subset: Optional[Sequence[str]] = None) -> "DataFrame":
        """Replace nulls: a ``{column: fill}`` mapping, or one fill value
        for ``subset`` (default: every column).  String columns need a
        fill value present in their dictionary."""
        if isinstance(value, Mapping):
            if subset is not None:
                raise TypeError("pass either a mapping or subset=, not both")
            fills = dict(value)
        else:
            cols = list(self._schema) if subset is None else list(subset)
            fills = {c: value for c in cols}
        self._check_cols(fills, "fillna")
        return self.with_columns(
            {c: Col(c).fill_null(ensure_expr(v)) for c, v in fills.items()})

    def isna(self, subset: Optional[Sequence[str]] = None) -> "DataFrame":
        """Replace ``subset`` columns (default: all) by booleans that are
        True where the value is null (pandas ``df.isna()``)."""
        cols = list(self._schema) if subset is None else list(subset)
        if subset is not None:
            self._check_cols(cols, "isna subset")
        return self.with_columns({c: Col(c).is_null() for c in cols})

    def repartition(self, on: Union[str, Sequence[str]], **kw) -> "DataFrame":
        """Hash-partition rows by key column(s) (an explicit shuffle; the
        optimizer elides it if placement already holds)."""
        on = [on] if isinstance(on, str) else list(on)
        self._check_cols(on, "repartition")
        return self._derive(self.plan.shuffle(on, **kw), self._schema)

    # ------------------------------------------------------------------ #
    # execution
    # ------------------------------------------------------------------ #
    def collect(self, env: Optional[CylonEnv] = None, mode: str = "bsp",
                optimize: bool = True, collect_stats: bool = False,
                morsel_rows: Optional[int] = None, analyze: bool = False,
                trace: Any = None, timeout: Any = None, retries: Any = None,
                overflow: Any = None, faults: Any = None,
                adaptive: Any = None, **kw):
        """Run the accumulated plan; returns a ``DistTable`` (or a
        host-resident ``SpillTable`` with ``morsel_rows=``, and a
        ``(result, ExecStats)`` pair with ``collect_stats=True``).

        ``analyze=True`` returns ``(result, obs.QueryReport)`` instead: the
        EXPLAIN tree annotated with measured per-node rows/bytes/times, a
        per-stage roofline table, and (when tracing is on, the default under
        analyze) a Chrome-exportable ``QueryTrace``.  ``trace`` alone turns
        on query tracing for a plain collect (``repro.obs.last_trace()``
        retrieves the timeline).  See ``docs/observability.md``.

        ``env`` resolution: explicit argument > the env the data was
        ingested for (``read_numpy(env=...)``) > the active session env
        (``repro.df.session``).  Extra ``kw`` (``shuffle_impl``,
        ``a2a_chunks``, ``capacity_factor``, ...) pass through to
        ``core.plan.execute``.

        Fault tolerance (``docs/fault_tolerance.md``): ``timeout`` (s)
        deadlines the query, ``retries`` replays faulted dispatch units
        with backoff, ``overflow`` (``raise | warn | degrade``) governs
        capacity-pressure drops, ``faults`` injects a deterministic fault
        plan.  ``None`` falls back to the active session's defaults
        (``session(timeout=..., ...)``), then the library defaults.
        ``adaptive`` gates runtime skew mitigation the same way
        (``docs/adaptive.md``).

        Scheduler routing (``docs/serving.md``): inside a
        ``session(scheduler=...)`` scope, a collect with no explicit
        ``env=`` and no ingest-pinned env is submitted to the scheduler —
        it queues under admission control, runs on a gang carved from the
        scheduler's device pool, and this call blocks on the
        ``QueryHandle`` (use ``scheduler.submit(df, ...)`` directly for
        the non-blocking handle).
        """
        defaults = get_session_defaults()
        if timeout is None:
            timeout = defaults.get("timeout")
        if retries is None:
            retries = defaults.get("retries")
        if overflow is None:
            overflow = defaults.get("overflow")
        if faults is None:
            faults = defaults.get("faults")
        if adaptive is None:
            adaptive = defaults.get("adaptive")
        scheduler = defaults.get("scheduler")
        if scheduler is not None and env is None and self._env is None:
            handle = scheduler.submit(
                self, mode=mode, optimize=optimize,
                collect_stats=collect_stats, morsel_rows=morsel_rows,
                analyze=analyze, trace=trace, timeout=timeout,
                retries=retries, overflow=overflow, faults=faults,
                adaptive=adaptive, **kw)
            return handle.result()
        if env is None:
            env = self._env if self._env is not None else get_env()
        if morsel_rows is None:
            # catch gang mismatches here with a clear message instead of a
            # shard_map divisibility error deep inside compilation (the
            # morsel path re-buckets host spills, so it is exempt)
            for sname, t in self.sources.items():
                if (isinstance(t, DistTable)
                        and t.parallelism != env.parallelism):
                    raise ValueError(
                        f"source {sname!r} is partitioned for "
                        f"{t.parallelism} ranks but the resolved env has "
                        f"{env.parallelism}; pass collect(env=<ingest "
                        f"env>) or re-ingest under this session")
        if analyze:
            from ..obs.analyze import run_analyzed
            if collect_stats:
                raise TypeError("analyze=True already collects stats; drop "
                                "collect_stats")
            return run_analyzed(self.plan, env, self.sources, mode=mode,
                                optimize=optimize, morsel_rows=morsel_rows,
                                trace=True if trace is None else trace,
                                timeout=timeout, retries=retries,
                                overflow=overflow, faults=faults,
                                adaptive=adaptive, **kw)
        return execute(self.plan, env, self.sources, mode=mode,
                       optimize=optimize, collect_stats=collect_stats,
                       morsel_rows=morsel_rows, trace=trace,
                       timeout=timeout, retries=retries, overflow=overflow,
                       faults=faults, adaptive=adaptive, **kw)

    def to_numpy(self, nulls: str = "pandas", **kw) -> Dict[str, np.ndarray]:
        """``collect`` + gather valid rows to host numpy columns.

        ``nulls="pandas"`` (default) re-materializes validity masks as
        NaN / ``None``; ``nulls="mask"`` returns the raw physical layout
        (canonical-zero data + ``__m_*`` bool masks) for bit-identity
        checks."""
        return self.collect(**kw).to_numpy(nulls=nulls)

    def to_pandas(self, **kw):
        """``collect`` + convert to a ``pandas.DataFrame``."""
        import pandas as pd
        return pd.DataFrame(self.to_numpy(**kw))

    def explain(self, **kw) -> str:
        """EXPLAIN the optimized plan (stages, partitioning, fired rules)."""
        return self.plan.explain(self.sources, **kw)

    def explain_analyze(self, env: Optional[CylonEnv] = None,
                        mode: str = "bsp_staged", **kw) -> str:
        """Execute the plan and render the EXPLAIN tree annotated with
        measured per-node rows/bytes and per-stage times, plus the
        per-stage roofline table.  Defaults to ``bsp_staged`` (one dispatch
        per stage) so stage times are exactly attributable.  Same knobs as
        ``collect``; the full ``QueryReport`` (Chrome trace, JSON export)
        comes from ``collect(analyze=True)``."""
        _, report = self.collect(env=env, mode=mode, analyze=True, **kw)
        return str(report)

    def num_stages(self) -> int:
        return self.plan.num_stages()

    def __repr__(self) -> str:
        return (f"<repro.df.DataFrame cols={list(self._schema)} "
                f"sources={sorted(self.sources)} lazy>")


class GroupBy:
    """Intermediate ``df.groupby(keys)`` holder; ``agg`` builds the plan."""

    __slots__ = ("_df", "_keys", "_kw")

    def __init__(self, df: DataFrame, keys: List[str],
                 kw: Optional[Dict[str, Any]] = None):
        self._df = df
        self._keys = keys
        self._kw = kw or {}

    def agg(self, aggs: Optional[Mapping[str, Union[str, Sequence[str]]]]
            = None, **named: Union[str, Sequence[str]]) -> DataFrame:
        """Aggregate: ``.agg({"v": ["sum", "mean"]})`` or ``.agg(v="sum")``.

        Supported: sum / count / min / max / mean (mean decomposes into
        sum+count so distributed partials stay mergeable).  Output columns
        are ``{col}_{agg}``.
        """
        merged: Dict[str, List[str]] = {}
        for src in (aggs or {}), named:
            for colname, names in src.items():
                names = [names] if isinstance(names, str) else list(names)
                merged.setdefault(colname, []).extend(
                    a for a in names if a not in merged.get(colname, []))
        if not merged:
            raise ValueError("agg needs at least one {column: aggs} entry")
        self._df._check_cols(merged, "agg")
        schema = groupby_schema(self._keys, merged)
        return self._df._derive(
            self._df.plan.groupby(self._keys, merged, **self._kw), schema)


# ---------------------------------------------------------------------- #
# Constructors
# ---------------------------------------------------------------------- #
def from_table(table: Union[DistTable, SpillTable, Mapping[str, np.ndarray]],
               name: Optional[str] = None,
               env: Optional[CylonEnv] = None) -> DataFrame:
    """Wrap an existing ``DistTable`` / ``SpillTable`` / host column dict
    as a scan.  ``SpillTable`` sources run out-of-core under
    ``collect(morsel_rows=...)`` or are scattered onto the gang for
    in-core modes; raw column dicts require the morsel path.  ``env`` pins
    the gang the frame executes on (see ``DataFrame.collect``)."""
    name = name or f"t{next(_src_ids)}"
    return DataFrame(Plan.scan(name), {name: table}, _source_schema(table),
                     env)


def read_numpy(data: Mapping[str, np.ndarray], *,
               env: Optional[CylonEnv] = None,
               capacity: Optional[int] = None,
               spill: bool = False, chunk_rows: Optional[int] = None,
               name: Optional[str] = None) -> DataFrame:
    """Ingest host numpy columns as a distributed scan.

    Default: block-distribute onto the active env's devices (a
    ``DistTable``; ``capacity`` sets per-rank slots).  String columns are
    dictionary-encoded at ingest (the device holds int32 codes over a
    sorted dictionary — ``docs/data_model.md``).  An explicit ``env``
    both partitions the data for that gang and pins later ``collect()``
    calls to it.  ``spill=True`` keeps the data host-resident as a
    ``SpillTable`` (in ``chunk_rows`` pinned chunks) for out-of-core
    ``collect(morsel_rows=...)`` runs.

    Inside a ``session(scheduler=...)`` scope (and with no explicit
    ``env``), data is partitioned for the scheduler's gang size, so the
    frame can run on *any* gang the scheduler carves.
    """
    if env is not None:
        p = env.parallelism
    else:
        sched = get_active_scheduler()
        p = sched.gang_size if sched is not None else get_env().parallelism
    if spill:
        if capacity is not None:
            raise TypeError("capacity only applies to device tables "
                            "(spill=False); use chunk_rows for spills")
        table: Any = (SpillTable.from_numpy(data, p, chunk_rows=chunk_rows)
                      if chunk_rows else SpillTable.from_numpy(data, p))
    else:
        if chunk_rows is not None:
            raise TypeError("chunk_rows only applies with spill=True")
        table = DistTable.from_numpy(dict(data), p, capacity)
    return from_table(table, name, env)


def _resolve_parallelism(env: Optional[CylonEnv]) -> int:
    if env is not None:
        return env.parallelism
    sched = get_active_scheduler()
    return sched.gang_size if sched is not None else get_env().parallelism


def read_parquet(source, *, env: Optional[CylonEnv] = None,
                 columns: Optional[Sequence[str]] = None,
                 batch_rows: Optional[int] = None,
                 name: Optional[str] = None, **kw) -> DataFrame:
    """Ingest Parquet file(s) as a host-resident out-of-core scan.

    ``source`` is a path, a glob, or a list of either; row groups stream
    in ``batch_rows``-row batches straight into the spill format, round-
    robin over the gang — whole files are never materialized, so datasets
    larger than device memory run under ``collect(morsel_rows=...)``.
    Missing values become validity masks (NaN / ``None`` on the way back
    out); string columns are dictionary-encoded incrementally, with a
    process-level dictionary cache keyed by the source files.  Requires
    pyarrow (``read_csv`` does not).  See ``docs/io.md``.
    """
    from ..io import read_parquet as _read
    if batch_rows is not None:
        kw["batch_rows"] = batch_rows
    spill = _read(source, _resolve_parallelism(env), columns=columns, **kw)
    return from_table(spill, name, env)


def read_csv(source, *, env: Optional[CylonEnv] = None,
             batch_rows: Optional[int] = None,
             name: Optional[str] = None, **kw) -> DataFrame:
    """Ingest CSV file(s) (header row required) as a host-resident
    out-of-core scan — ``read_parquet`` semantics, CSV framing.  Empty
    fields are null in every column type.  Streams via pyarrow when
    available, else a pure-python fallback lane.  See ``docs/io.md``."""
    from ..io import read_csv as _read
    if batch_rows is not None:
        kw["batch_rows"] = batch_rows
    spill = _read(source, _resolve_parallelism(env), **kw)
    return from_table(spill, name, env)


def from_pandas(pdf, **kw) -> DataFrame:
    """Ingest a ``pandas.DataFrame`` — see ``read_numpy`` for keyword
    arguments.

    Numeric/bool columns pass through; object/string and ``Categorical``
    columns are dictionary-encoded (sorted dictionary + int32 codes on
    device, decoded back by ``to_pandas`` — see ``docs/data_model.md``).
    Anything else (datetimes, nested objects) raises."""
    import pandas as pd
    data = {}
    for colname in pdf.columns:
        series = pdf[colname]
        if isinstance(series.dtype, pd.CategoricalDtype):
            arr = np.asarray(series.astype(object))
        else:
            arr = np.asarray(series)
        # string-ish columns are validated element-wise by the encoder
        # itself (schema._as_str_array names the column in its error)
        if (arr.dtype.kind not in ("O", "U", "S")
                and not np.issubdtype(arr.dtype, np.number)
                and arr.dtype != np.bool_):
            raise TypeError(
                f"column {colname!r} has unsupported dtype {arr.dtype}; "
                f"supported: numeric, bool, str, Categorical[str]")
        data[str(colname)] = arr
    return read_numpy(data, **kw)

"""gemma-7b [dense] — GeGLU, head_dim=256 [arXiv:2403.08295; hf].

28L d_model=3072 16H (GQA kv=16, i.e. MHA on the 7b; MQA is the 2b)
d_ff=24576 vocab=256000, GeGLU activation, head_dim=256, tied embeddings.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    d_ff=24576,
    vocab_size=256000,
    head_dim=256,
    act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma-7b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=192,
    vocab_size=512,
    head_dim=32,
    act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)

"""qwen3-8b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936, head_dim=128,
qk-norm, SwiGLU.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12288,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    act="silu",
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen3-8b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    qk_norm=True,
    act="silu",
    rope_theta=1_000_000.0,
)

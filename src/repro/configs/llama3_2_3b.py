"""llama3.2-3b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B; unverified].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256, SwiGLU, RoPE 500k,
tied embeddings.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    head_dim=128,
    act="silu",
    rope_theta=500_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="llama3.2-3b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    act="silu",
    rope_theta=500_000.0,
    tie_embeddings=True,
)

"""llava-next-34b [vlm] — anyres tiling [hf:llava-hf/llava-v1.6; unverified].

Backbone only (Yi-34B-class decoder): 60L d_model=7168 56H (GQA kv=8)
d_ff=20480 vocab=64000.  The vision frontend is a STUB per the assignment:
``input_specs()`` provides precomputed anyres patch embeddings (B, P, D)
that are concatenated ahead of the text embeddings.
"""

from ..models.config import ModelConfig

#: anyres tiling: 4 tiles + 1 base image × 576 CLIP patches (24×24)
PATCHES_LARGE = 5 * 576  # 2880
PATCHES_SMALL = 576

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    act="silu",
    rope_theta=5_000_000.0,
    embed_inputs=True,
)

SMOKE = ModelConfig(
    name="llava-next-34b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    act="silu",
    rope_theta=5_000_000.0,
    embed_inputs=True,
)

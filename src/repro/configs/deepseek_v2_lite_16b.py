"""deepseek-v2-lite-16b [moe] — MLA + DeepSeek-MoE [arXiv:2405.04434; hf].

27L d_model=2048 16H, MLA kv_lora=512 (rope head 64, nope 128, v 128),
vocab=102400.  MoE: 64 routed experts top-6 + 2 shared, expert d_ff=1408,
layer 0 dense with d_ff=10944 (the assignment's "2 shared + 160 routed"
note describes full V2; the -Lite config it names has 64 routed experts,
matching its "MoE 64e top-6" spec line).
"""

from ..models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,              # expert width (spec line)
    vocab_size=102400,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2,
                  every_k_layers=1, first_dense_d_ff=10944),
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-16b-smoke",
    family="moe",
    num_layers=3,           # 1 dense prefix + 2 MoE
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=48,
    vocab_size=256,
    mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                  v_head_dim=16),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=48, num_shared=2,
                  every_k_layers=1, first_dense_d_ff=96,
                  capacity_factor=4.0),
    rope_theta=10_000.0,
)

"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE [arXiv:2403.19887; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.
Layer layout per the paper/HF config: attention at layer i%8==4
(attn_layer_period=8, offset=4), MoE at i%2==1 (expert_layer_period=2,
offset=1).  The Mamba mixer is modelled with the SSD block (d_state=16,
conv=4, expand=2 — Jamba's Mamba hyperparameters).  Hybrid: the 4 attention
layers make 500k-context decode feasible (sequence-sharded KV), so the
long_500k cell runs.
"""

from ..models.config import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336, num_shared=0,
                  every_k_layers=2),
    ssm=SSMConfig(d_state=16, expand=2, head_dim=64, d_conv=4, chunk=128),
    layer_pattern="mmmmammm",
    sub_quadratic=True,
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="jamba-v0.1-52b-smoke",
    family="hybrid",
    num_layers=8,           # one full pattern period
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128, num_shared=0,
                  every_k_layers=2, capacity_factor=4.0),
    ssm=SSMConfig(d_state=16, expand=2, head_dim=16, d_conv=4, chunk=32),
    layer_pattern="mmmmammm",
    sub_quadratic=True,
    rope_theta=10_000.0,
)

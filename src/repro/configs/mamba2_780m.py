"""mamba2-780m [ssm] — SSD (state-space duality) [arXiv:2405.21060; unverified].

48L d_model=1536 (attention-free) vocab=50280, ssm_state=128, expand=2,
head_dim=64.  Sub-quadratic: eligible for the long_500k cell.
"""

from ..models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=24,          # unused (attention-free); kept for accounting
    num_kv_heads=24,
    d_ff=0,                # pure SSM blocks, no FF
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, d_conv=4, chunk=128),
    layer_pattern="m",
    sub_quadratic=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-780m-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=256,
    ssm=SSMConfig(d_state=16, expand=2, head_dim=16, d_conv=4, chunk=32),
    layer_pattern="m",
    sub_quadratic=True,
    tie_embeddings=True,
)

"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060; hf].

16L d_model=2048 16H (GQA kv=16) expert d_ff=1024 vocab=50304,
MoE 64e top-8 on every layer, no shared experts, qk-norm.
"""

from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,              # expert width (spec line)
    vocab_size=50304,
    qk_norm=True,
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024, num_shared=0,
                  every_k_layers=1),
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="olmoe-1b-7b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=32,
    vocab_size=256,
    qk_norm=True,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32, num_shared=0,
                  every_k_layers=1, capacity_factor=4.0),
    rope_theta=10_000.0,
)

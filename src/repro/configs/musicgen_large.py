"""musicgen-large [audio] — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048 per codebook, K=4 EnCodec
codebooks.  The backbone sums the K codebook embeddings and emits K logit
heads; the EnCodec frontend + delay-pattern interleave is a STUB per the
assignment (``input_specs()`` provides the token streams directly).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    head_dim=64,
    act="silu",
    num_codebooks=4,
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="musicgen-large-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=64,
    head_dim=16,
    act="silu",
    num_codebooks=2,
    rope_theta=10_000.0,
)

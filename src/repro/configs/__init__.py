"""Assigned-architecture configs (one module per arch + smoke variants).

``get_config(arch)`` / ``get_smoke_config(arch)`` resolve the public arch ids
(e.g. ``"llama3.2-3b"``).  Every full config matches the assignment block
verbatim; smoke configs are same-family reductions for CPU tests.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ModelConfig

_MODULES: Dict[str, str] = {
    "llama3.2-3b": "llama3_2_3b",
    "qwen3-32b": "qwen3_32b",
    "gemma-7b": "gemma_7b",
    "qwen3-8b": "qwen3_8b",
    "mamba2-780m": "mamba2_780m",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llava-next-34b": "llava_next_34b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "musicgen-large": "musicgen_large",
}

ARCHS: List[str] = list(_MODULES)


def _module(arch: str):
    try:
        name = _MODULES[arch]
    except KeyError:
        raise ValueError(f"unknown arch {arch!r}; known: {ARCHS}") from None
    return importlib.import_module(f".{name}", __package__)


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE

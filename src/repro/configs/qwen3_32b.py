"""qwen3-32b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936, head_dim=128,
qk-norm, SwiGLU.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    act="silu",
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen3-32b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    qk_norm=True,
    act="silu",
    rope_theta=1_000_000.0,
)

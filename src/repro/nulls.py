"""Validity-mask conventions: how missing values exist inside the engine.

A nullable column ``c`` is physically a *pair* of columns: the data column
``c`` plus a boolean companion ``__m_c`` (True = valid).  Masks are ordinary
columns — they ride through ``take`` / shuffle / spill / rescatter with zero
extra plumbing — but they are **not** part of the logical schema: the
planner, EXPLAIN, and the frontend all see only ``c`` (annotated nullable),
and ``to_numpy`` / ``to_pandas`` re-materialize masks as NaN / None.

Two invariants make nulls cheap and bit-exact:

* **canonical zero** — a null slot holds the column's zero value (0 / 0.0 /
  code 0 / False).  Hashing, the packed shuffle, and bit-identity checks
  never see garbage; equal tables are equal byte-for-byte regardless of
  what the nulls "were" before ingest.
* **Kleene evaluation** (``repro.expr``) — masked expressions canonicalize
  their outputs, so the invariant is maintained through arithmetic,
  comparisons, and boolean logic.

This module is dependency-free on purpose: ``repro.expr`` and the
``repro.dataframe`` layers both import it.  See ``docs/data_model.md``.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Set, Tuple

import numpy as np

__all__ = ["MASK_PREFIX", "mask_name", "is_mask", "base_name",
           "data_columns", "nullable_columns", "extract_null_columns",
           "apply_null_columns", "check_reserved_names"]

#: reserved column-name prefix for validity masks (True = valid)
MASK_PREFIX = "__m_"


def mask_name(col: str) -> str:
    """The validity-mask column name for data column ``col``."""
    return MASK_PREFIX + col


def is_mask(name: str) -> bool:
    return name.startswith(MASK_PREFIX)


def base_name(mask: str) -> str:
    """Inverse of ``mask_name`` (callers check ``is_mask`` first)."""
    return mask[len(MASK_PREFIX):]


def data_columns(names: Iterable[str]) -> List[str]:
    """The logical (non-mask) column names, order preserved."""
    return [n for n in names if not is_mask(n)]


def nullable_columns(names: Iterable[str]) -> Set[str]:
    """Data columns that carry a validity mask in ``names``."""
    names = set(names)
    return {base_name(n) for n in names
            if is_mask(n) and base_name(n) in names}


def check_reserved_names(names: Iterable[str]) -> None:
    """Reject user columns squatting on the mask prefix with no base column
    (ingest boundary check; a well-formed mask is silently accepted)."""
    names = list(names)
    have = set(names)
    for n in names:
        if is_mask(n) and base_name(n) not in have:
            raise ValueError(
                f"column name {n!r} uses the reserved validity-mask prefix "
                f"{MASK_PREFIX!r} but no column {base_name(n)!r} exists")


def _valid_of(arr: np.ndarray) -> np.ndarray:
    """Element-is-valid for a host array: NaN and None are null."""
    if arr.dtype.kind == "f":
        return ~np.isnan(arr)
    if arr.dtype.kind == "O":
        # None / float NaN / pandas NA inside an object column are null
        def ok(x):
            if x is None:
                return False
            if isinstance(x, float) and np.isnan(x):
                return False
            return not (x is getattr(np, "nan", None))
        return np.fromiter((ok(x) for x in arr), dtype=bool, count=len(arr))
    return np.ones(len(arr), dtype=bool)


def extract_null_columns(data: Dict[str, np.ndarray]
                         ) -> Dict[str, np.ndarray]:
    """Host-side ingest normalization: NaN / None become explicit masks.

    For every data column, null slots are canonicalized — floats to ``0.0``,
    object (string) columns to their lexicographically smallest valid value
    (so the later dictionary encode assigns them code 0 without polluting
    the dictionary).  Pre-supplied ``__m_*`` columns are validated, cast to
    bool, and their bases canonicalized too.  Columns with no nulls and no
    explicit mask pass through untouched (no mask is created).
    """
    check_reserved_names(data.keys())
    out: Dict[str, np.ndarray] = {}
    for name, arr in data.items():
        if is_mask(name):
            continue
        arr = np.asarray(arr)
        m = data.get(mask_name(name))
        if m is not None:
            valid = np.asarray(m).astype(bool)
            if len(valid) != len(arr):
                raise ValueError(
                    f"mask {mask_name(name)!r} length {len(valid)} != "
                    f"column {name!r} length {len(arr)}")
            valid = valid & _valid_of(arr)
        else:
            valid = _valid_of(arr)
        if valid.all() and m is None:
            out[name] = arr
            continue
        arr = arr.copy()
        if arr.dtype.kind == "O":
            vals = arr[valid]
            fill = min(vals) if len(vals) else ""
            arr[~valid] = fill
        elif arr.dtype.kind == "f":
            arr[~valid] = 0.0
        else:
            arr[~valid] = 0
        out[name] = arr
        out[mask_name(name)] = valid
    return out


def apply_null_columns(cols: Dict[str, np.ndarray]
                       ) -> Dict[str, np.ndarray]:
    """Host-side output: re-materialize masks as pandas-style missing values.

    Floats get NaN; integers are widened to float64 with NaN (pandas
    behaviour for nullable ints); object/string columns get ``None``;
    booleans widen to object with ``None``.  Mask columns are consumed.
    A column whose mask is all-True still widens (nullability is a schema
    property, not a data property) so dtypes are stable across batches.
    """
    out: Dict[str, np.ndarray] = {}
    for name, arr in cols.items():
        if is_mask(name):
            continue
        m = cols.get(mask_name(name))
        if m is None:
            out[name] = arr
            continue
        valid = np.asarray(m).astype(bool)
        arr = np.asarray(arr)
        if arr.dtype.kind == "f":
            a = arr.astype(arr.dtype, copy=True)
            a[~valid] = np.nan
        elif arr.dtype.kind in "iu":
            a = arr.astype(np.float64)
            a[~valid] = np.nan
        else:
            a = arr.astype(object)
            a[~valid] = None
        out[name] = a
    return out

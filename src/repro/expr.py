"""Typed column-expression AST — the declarative frontend of the planner.

The original ``Plan.filter`` / ``Plan.map_columns`` took opaque Python
callables, which blinded every layer that wants to *reason* about the
computation: predicate pushdown could not tell which columns a lambda
touches, projection pushdown had to keep every input column alive, and the
structural-fingerprint compile cache could only key a callable by its
bytecode + closure (so two semantically identical lambdas from different
source lines forced separate compilations).

``Expr`` fixes all three at once.  An expression is a small immutable tree

    col("v") * 2 > lit(5)          # BinOp(">", BinOp("*", Col, Lit), Lit)

supporting arithmetic (``+ - * / // % **``), comparisons
(``< <= > >= == !=``), boolean algebra (``& | ^ ~``) and unary ops
(``-x``, ``abs``), and it exposes exactly the three views the engine needs:

* ``columns()``     — the set of input columns read (exact liveness for
                      projection pushdown and join-side predicate routing),
* ``fingerprint()`` — a canonical value-based string: equal for any two
                      structurally equal expressions however/wherever they
                      were built (stable compile-cache keys),
* ``evaluate(t)``   — lowering to a jnp computation over ``Table`` columns
                      (runs inside the compiled shard_map programs).

``OpaqueExpr`` wraps a legacy callable so the deprecated
``Plan.filter(callable)`` / ``Plan.map_columns`` paths keep executing; it
pins its *declared* columns (or ``None`` = unknown, blocking pushdown past
schema-changing boundaries, exactly the old conservative behaviour) and
fingerprints by bytecode + captured values, the best a callable allows.
"""

from __future__ import annotations

import hashlib
from typing import Any, Callable, FrozenSet, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .nulls import mask_name

__all__ = ["Expr", "Col", "Lit", "BinOp", "UnaryOp", "OpaqueExpr", "IsNull",
           "FillNull", "col", "lit", "ensure_expr", "token"]


# ---------------------------------------------------------------------- #
# Canonical value tokens (shared with the planner's structural fingerprint)
# ---------------------------------------------------------------------- #
def token(v: Any) -> str:
    """Canonical string for a parameter value, usable as a cache-key part.

    Expressions delegate to their value-based ``fingerprint``; callables
    are hashed by bytecode + defaults + captured closure values (bytecode
    alone is not identity — two lambdas from one source line may differ
    only in captured values); arrays are hashed by raw bytes (repr
    truncates large arrays).
    """
    if isinstance(v, Expr):
        return f"expr:{v.fingerprint()}"
    if callable(v):
        code = getattr(v, "__code__", None)
        if code is None:
            return f"fn:{getattr(v, '__qualname__', repr(v))}"
        cells = []
        for c in (v.__closure__ or ()):
            try:
                cells.append(token(c.cell_contents))
            except ValueError:           # empty cell
                cells.append("<empty>")
        extras = (token(v.__defaults__ or ())
                  + token(getattr(v, "__kwdefaults__", None) or {})
                  + "|".join(cells))
        h = hashlib.sha1(code.co_code + repr(code.co_consts).encode()
                         + extras.encode())
        return f"fn:{v.__module__}.{v.__qualname__}:{h.hexdigest()[:12]}"
    if isinstance(v, dict):
        return "{" + ",".join(f"{k}:{token(v[k])}" for k in sorted(v)) + "}"
    if isinstance(v, (list, tuple)):
        return "[" + ",".join(token(x) for x in v) + "]"
    if isinstance(v, (np.ndarray, jax.Array)):
        a = np.asarray(v)
        return (f"arr:{a.dtype}:{a.shape}:"
                f"{hashlib.sha1(a.tobytes()).hexdigest()[:12]}")
    return repr(v)


# ---------------------------------------------------------------------- #
# Operator tables
# ---------------------------------------------------------------------- #
_ARITH = {
    "+": jnp.add, "-": jnp.subtract, "*": jnp.multiply,
    "/": jnp.true_divide, "//": jnp.floor_divide, "%": jnp.mod,
    "**": jnp.power,
}
_COMPARE = {
    ">": jnp.greater, ">=": jnp.greater_equal,
    "<": jnp.less, "<=": jnp.less_equal,
    "==": jnp.equal, "!=": jnp.not_equal,
}
_BOOL = {
    "&": jnp.bitwise_and, "|": jnp.bitwise_or, "^": jnp.bitwise_xor,
}
_BINOPS = {**_ARITH, **_COMPARE, **_BOOL}
_UNARY = {"-": jnp.negative, "abs": jnp.abs, "~": jnp.invert}

#: precedence for minimal-paren pretty printing — matches *Python's* table
#: (comparisons bind looser than & | ^), so rendered expressions parse back
#: to the same tree
_PREC = {"==": 1, "!=": 1, "<": 1, "<=": 1, ">": 1, ">=": 1,
         "|": 2, "^": 3, "&": 4,
         "+": 5, "-": 5, "*": 6, "/": 6, "//": 6, "%": 6, "**": 8}


# ---------------------------------------------------------------------- #
# Three-valued (Kleene) helpers
# ---------------------------------------------------------------------- #
def _canon(value, valid):
    """Re-establish the canonical-zero invariant on a masked value."""
    if valid is None:
        return value
    value = jnp.asarray(value)
    return jnp.where(valid, value, jnp.zeros_like(value))


def _and_valid(ma, mb):
    """Null-propagating validity combine (None = provably all-valid)."""
    if ma is None:
        return mb
    if mb is None:
        return ma
    return ma & mb


class Expr:
    """Base class: operator overloads build the tree; subclasses store it."""

    __slots__ = ()

    # -- engine-facing views (implemented by subclasses) ----------------- #
    def columns(self) -> Optional[FrozenSet[str]]:
        """Exact set of input columns read, or ``None`` if unknown
        (opaque callables without declared columns)."""
        raise NotImplementedError

    def fingerprint(self) -> str:
        """Canonical value-based identity (compile-cache key component)."""
        raise NotImplementedError

    def evaluate(self, table) -> jax.Array:
        """Lower to a jnp value over ``table``'s columns (jit-traceable)."""
        raise NotImplementedError

    def evaluate_masked(self, table):
        """Kleene three-valued lowering: ``(value, valid)`` where ``valid``
        is a boolean validity array or ``None`` (provably all-valid — the
        common case, compiling to exactly the unmasked program).

        Invariant: wherever ``valid`` is False the returned ``value`` holds
        the canonical zero of its dtype (see ``repro.nulls``), so masked
        results hash / pack / compare bit-identically.
        """
        return self.evaluate(table), None

    def nullable(self, nulls) -> bool:
        """May this expression yield null, given ``nulls`` = the set of
        nullable input columns?  Conservative (True when unknown): the
        planner uses False to elide mask work, never to require it."""
        return True

    def is_boolean(self) -> bool:
        """True if this expression provably yields a boolean mask — the
        requirement for ``&``-conjunction splitting to be a sound rewrite
        (on integers ``&`` is bitwise, not logical)."""
        return False

    # -- operator overloads --------------------------------------------- #
    def _bin(self, op: str, other: Any, swap: bool = False) -> "BinOp":
        other = ensure_expr(other)
        return BinOp(op, other, self) if swap else BinOp(op, self, other)

    def __add__(self, o):
        return self._bin("+", o)

    def __radd__(self, o):
        return self._bin("+", o, swap=True)

    def __sub__(self, o):
        return self._bin("-", o)

    def __rsub__(self, o):
        return self._bin("-", o, swap=True)

    def __mul__(self, o):
        return self._bin("*", o)

    def __rmul__(self, o):
        return self._bin("*", o, swap=True)

    def __truediv__(self, o):
        return self._bin("/", o)

    def __rtruediv__(self, o):
        return self._bin("/", o, swap=True)

    def __floordiv__(self, o):
        return self._bin("//", o)

    def __rfloordiv__(self, o):
        return self._bin("//", o, swap=True)

    def __mod__(self, o):
        return self._bin("%", o)

    def __rmod__(self, o):
        return self._bin("%", o, swap=True)

    def __pow__(self, o):
        return self._bin("**", o)

    def __rpow__(self, o):
        return self._bin("**", o, swap=True)

    def __gt__(self, o):
        return self._bin(">", o)

    def __ge__(self, o):
        return self._bin(">=", o)

    def __lt__(self, o):
        return self._bin("<", o)

    def __le__(self, o):
        return self._bin("<=", o)

    # NOTE: == / != build expressions, so Exprs are not usefully hashable
    # by value and must not be used as dict keys / in sets.
    def __eq__(self, o):  # type: ignore[override]
        return self._bin("==", o)

    def __ne__(self, o):  # type: ignore[override]
        return self._bin("!=", o)

    __hash__ = None  # type: ignore[assignment]

    def __and__(self, o):
        return self._bin("&", o)

    def __rand__(self, o):
        return self._bin("&", o, swap=True)

    def __or__(self, o):
        return self._bin("|", o)

    def __ror__(self, o):
        return self._bin("|", o, swap=True)

    def __xor__(self, o):
        return self._bin("^", o)

    def __rxor__(self, o):
        return self._bin("^", o, swap=True)

    def __neg__(self):
        return UnaryOp("-", self)

    def __abs__(self):
        return UnaryOp("abs", self)

    def abs(self) -> "UnaryOp":
        return UnaryOp("abs", self)

    def is_null(self) -> "IsNull":
        """True where this expression is null (never null itself)."""
        return IsNull(self)

    def fill_null(self, value) -> "FillNull":
        """Replace null slots with ``value`` (scalar or expression)."""
        return FillNull(self, ensure_expr(value))

    def __invert__(self):
        return UnaryOp("~", self)

    def __bool__(self):
        raise TypeError(
            "an Expr has no truth value (it is a lazy column expression); "
            "use & | ~ for boolean logic, not `and`/`or`/`not`")

    def __repr__(self) -> str:
        return self._render(0)

    def _render(self, parent_prec: int) -> str:
        raise NotImplementedError


class Col(Expr):
    """Reference to a named input column."""

    __slots__ = ("name",)

    def __init__(self, name: str):
        if not isinstance(name, str):
            raise TypeError(f"column name must be a str, got {type(name)}")
        object.__setattr__(self, "name", name)

    def __setattr__(self, *_):
        raise AttributeError("Expr nodes are immutable")

    def columns(self) -> FrozenSet[str]:
        return frozenset((self.name,))

    def fingerprint(self) -> str:
        return f"col({self.name})"

    def evaluate(self, table) -> jax.Array:
        try:
            return table.columns[self.name]
        except KeyError:
            raise KeyError(
                f"column {self.name!r} not in table "
                f"(have {list(table.column_names)})") from None

    def evaluate_masked(self, table):
        # null slots already hold canonical zero (ingest invariant)
        return self.evaluate(table), table.columns.get(mask_name(self.name))

    def nullable(self, nulls) -> bool:
        return self.name in nulls

    def _render(self, parent_prec: int) -> str:
        return self.name


class Lit(Expr):
    """Literal scalar.  Python scalars stay weakly typed (so ``col + 1.0``
    follows jnp's weak-promotion rules, matching what inline jnp code would
    do); numpy scalars pin their dtype.

    String literals are allowed in the tree (``col("s") == "oak"``) but
    never reach the device: the planner lowers them into int32 code
    comparisons against the column's dictionary
    (``dataframe.schema.lower_expr``) before compilation."""

    __slots__ = ("value",)

    def __init__(self, value):
        if isinstance(value, Expr):
            raise TypeError("lit() of an Expr — pass a scalar")
        if isinstance(value, (np.ndarray, jax.Array)) and np.ndim(value) != 0:
            raise TypeError("lit() takes a scalar, not an array")
        object.__setattr__(self, "value", value)

    def __setattr__(self, *_):
        raise AttributeError("Expr nodes are immutable")

    def columns(self) -> FrozenSet[str]:
        return frozenset()

    def fingerprint(self) -> str:
        v = self.value
        if isinstance(v, (np.generic, np.ndarray, jax.Array)):
            a = np.asarray(v)
            return f"lit({a.dtype}:{a.item()!r})"
        return f"lit({type(v).__name__}:{v!r})"

    def is_boolean(self) -> bool:
        return isinstance(self.value, (bool, np.bool_))

    def evaluate(self, table) -> jax.Array:
        if isinstance(self.value, (str, np.str_)):
            raise TypeError(
                f"string literal {self.value!r} reached evaluation without "
                f"being lowered against a column dictionary; string "
                f"literals are only usable in comparisons against a "
                f"dictionary-encoded column (the planner lowers them — "
                f"see docs/data_model.md)")
        return self.value  # jnp ops promote python scalars weakly

    def nullable(self, nulls) -> bool:
        return False

    def _render(self, parent_prec: int) -> str:
        return repr(self.value)


class BinOp(Expr):
    __slots__ = ("op", "left", "right")

    def __init__(self, op: str, left: Expr, right: Expr):
        if op not in _BINOPS:
            raise ValueError(f"unknown binary op {op!r}")
        object.__setattr__(self, "op", op)
        object.__setattr__(self, "left", ensure_expr(left))
        object.__setattr__(self, "right", ensure_expr(right))

    def __setattr__(self, *_):
        raise AttributeError("Expr nodes are immutable")

    def columns(self) -> Optional[FrozenSet[str]]:
        l, r = self.left.columns(), self.right.columns()
        if l is None or r is None:
            return None
        return l | r

    def fingerprint(self) -> str:
        return (f"({self.left.fingerprint()}{self.op}"
                f"{self.right.fingerprint()})")

    def is_boolean(self) -> bool:
        if self.op in _COMPARE:
            return True
        if self.op in _BOOL:
            return self.left.is_boolean() and self.right.is_boolean()
        return False

    def evaluate(self, table) -> jax.Array:
        return _BINOPS[self.op](self.left.evaluate(table),
                                self.right.evaluate(table))

    def evaluate_masked(self, table):
        va, ma = self.left.evaluate_masked(table)
        vb, mb = self.right.evaluate_masked(table)
        if ma is None and mb is None:
            return _BINOPS[self.op](va, vb), None
        value = _BINOPS[self.op](va, vb)
        if (self.op in ("&", "|")
                and jnp.result_type(va) == jnp.bool_
                and jnp.result_type(vb) == jnp.bool_):
            # Kleene: a known false (&) / true (|) side decides the result
            # even when the other side is null.  Canonical zero means null
            # value slots already read as False.
            a_ok = True if ma is None else ma
            b_ok = True if mb is None else mb
            if self.op == "&":
                valid = (a_ok & b_ok) | (a_ok & ~va) | (b_ok & ~vb)
            else:
                valid = (a_ok & b_ok) | (a_ok & va) | (b_ok & vb)
        else:
            valid = _and_valid(ma, mb)
        return _canon(value, valid), valid

    def nullable(self, nulls) -> bool:
        return self.left.nullable(nulls) or self.right.nullable(nulls)

    def _render(self, parent_prec: int) -> str:
        prec = _PREC[self.op]
        if self.op == "**":    # right-associative: (a**b)**c needs parens
            s = (f"{self.left._render(prec + 1)} ** "
                 f"{self.right._render(prec)}")
        else:
            s = (f"{self.left._render(prec)} {self.op} "
                 f"{self.right._render(prec + 1)}")
        return f"({s})" if prec < parent_prec else s


class UnaryOp(Expr):
    __slots__ = ("op", "operand")

    def __init__(self, op: str, operand: Expr):
        if op not in _UNARY:
            raise ValueError(f"unknown unary op {op!r}")
        object.__setattr__(self, "op", op)
        object.__setattr__(self, "operand", ensure_expr(operand))

    def __setattr__(self, *_):
        raise AttributeError("Expr nodes are immutable")

    def columns(self) -> Optional[FrozenSet[str]]:
        return self.operand.columns()

    def fingerprint(self) -> str:
        return f"{self.op}({self.operand.fingerprint()})"

    def is_boolean(self) -> bool:
        return self.op == "~" and self.operand.is_boolean()

    def evaluate(self, table) -> jax.Array:
        return _UNARY[self.op](self.operand.evaluate(table))

    def evaluate_masked(self, table):
        v, m = self.operand.evaluate_masked(table)
        return _canon(_UNARY[self.op](v), m), m

    def nullable(self, nulls) -> bool:
        return self.operand.nullable(nulls)

    def _render(self, parent_prec: int) -> str:
        if self.op == "abs":
            return f"abs({self.operand._render(0)})"
        # unary - / ~ bind at 7: looser than ** (so (-a)**2 needs parens —
        # Python parses "-a ** 2" as -(a**2)), tighter than * and /
        s = f"{self.op}{self.operand._render(7)}"
        return f"({s})" if parent_prec > 7 else s


class IsNull(Expr):
    """``expr.is_null()`` — True where the operand is null; never null
    itself (the SQL ``IS NULL`` escape from three-valued logic)."""

    __slots__ = ("operand",)

    def __init__(self, operand: Expr):
        object.__setattr__(self, "operand", ensure_expr(operand))

    def __setattr__(self, *_):
        raise AttributeError("Expr nodes are immutable")

    def columns(self) -> Optional[FrozenSet[str]]:
        return self.operand.columns()

    def fingerprint(self) -> str:
        return f"isnull({self.operand.fingerprint()})"

    def is_boolean(self) -> bool:
        return True

    def nullable(self, nulls) -> bool:
        return False

    def evaluate(self, table) -> jax.Array:
        # unmasked path: the operand is provably non-null
        v = self.operand.evaluate(table)
        return jnp.zeros(jnp.shape(v), dtype=bool)

    def evaluate_masked(self, table):
        v, m = self.operand.evaluate_masked(table)
        if m is None:
            return jnp.zeros(jnp.shape(v), dtype=bool), None
        return ~m, None

    def _render(self, parent_prec: int) -> str:
        return f"is_null({self.operand._render(0)})"


class FillNull(Expr):
    """``expr.fill_null(v)`` — the operand with null slots replaced by
    ``v`` (a scalar or expression); null only where both are null."""

    __slots__ = ("operand", "fill")

    def __init__(self, operand: Expr, fill: Expr):
        object.__setattr__(self, "operand", ensure_expr(operand))
        object.__setattr__(self, "fill", ensure_expr(fill))

    def __setattr__(self, *_):
        raise AttributeError("Expr nodes are immutable")

    def columns(self) -> Optional[FrozenSet[str]]:
        a, b = self.operand.columns(), self.fill.columns()
        if a is None or b is None:
            return None
        return a | b

    def fingerprint(self) -> str:
        return (f"fillnull({self.operand.fingerprint()};"
                f"{self.fill.fingerprint()})")

    def is_boolean(self) -> bool:
        return self.operand.is_boolean() and self.fill.is_boolean()

    def nullable(self, nulls) -> bool:
        return self.fill.nullable(nulls)

    def evaluate(self, table) -> jax.Array:
        # unmasked path: nothing to fill
        return self.operand.evaluate(table)

    def evaluate_masked(self, table):
        vo, mo = self.operand.evaluate_masked(table)
        if mo is None:
            return vo, None
        vf, mf = self.fill.evaluate_masked(table)
        value = jnp.where(mo, vo, vf)
        valid = None if mf is None else (mo | mf)
        return _canon(value, valid), valid

    def _render(self, parent_prec: int) -> str:
        return (f"fill_null({self.operand._render(0)}, "
                f"{self.fill._render(0)})")


class OpaqueExpr(Expr):
    """Legacy-callable escape hatch (``fn(Table) -> Array``).

    ``cols`` pins the columns the callable reads; ``None`` means unknown,
    which forces the optimizer into the old conservative behaviour (no
    pushdown past schema-changing boundaries, full-schema liveness).  The
    fingerprint falls back to bytecode + captured values — stable for the
    *same* function object or closures over equal values, but distinct
    lambdas that compute the same thing still miss the cache (the
    instability typed expressions exist to fix).
    """

    __slots__ = ("fn", "_cols", "label")

    def __init__(self, fn: Callable, cols: Optional[Sequence[str]] = None,
                 label: Optional[str] = None):
        if not callable(fn):
            raise TypeError(f"OpaqueExpr needs a callable, got {type(fn)}")
        object.__setattr__(self, "fn", fn)
        object.__setattr__(self, "_cols",
                           None if cols is None else tuple(cols))
        object.__setattr__(self, "label",
                           label or getattr(fn, "__name__", "opaque"))

    def __setattr__(self, *_):
        raise AttributeError("Expr nodes are immutable")

    def columns(self) -> Optional[FrozenSet[str]]:
        return None if self._cols is None else frozenset(self._cols)

    def fingerprint(self) -> str:
        return f"opaque({token(self.fn)};cols={self._cols})"

    def evaluate(self, table) -> jax.Array:
        return self.fn(table)

    def _render(self, parent_prec: int) -> str:
        decl = ",".join(self._cols) if self._cols else "?"
        return f"<{self.label}:{decl}>"


# ---------------------------------------------------------------------- #
# Factories
# ---------------------------------------------------------------------- #
def col(name: str) -> Col:
    """Reference an input column: ``col("v") * 2 > lit(5)``."""
    return Col(name)


def lit(value) -> Lit:
    """Literal scalar (explicit form; bare scalars auto-lift in operators)."""
    return Lit(value)


def ensure_expr(v: Any) -> Expr:
    """Lift scalars to ``Lit``; pass ``Expr`` through; reject the rest.

    Strings lift too (``col("s") == "oak"``): they are lowered into
    dictionary-code comparisons by the planner, never evaluated raw."""
    if isinstance(v, Expr):
        return v
    if isinstance(v, (bool, int, float, complex, str, np.generic)):
        return Lit(v)
    if isinstance(v, (np.ndarray, jax.Array)) and np.ndim(v) == 0:
        return Lit(v)
    raise TypeError(f"cannot use {type(v).__name__} in a column expression; "
                    f"expected an Expr or a scalar")

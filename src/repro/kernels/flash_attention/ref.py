"""Pure-jnp oracle: quadratic GQA attention."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True, scale: Optional[float] = None
                  ) -> jax.Array:
    """q: (B, Hq, Sq, D); k,v: (B, Hkv, Sk, D) -> (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)

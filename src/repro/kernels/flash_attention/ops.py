"""Jit'd public wrapper: (B, H, S, D) layout, padding, backend dispatch."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..common import default_interpret, round_up
from .flash_attention import flash_attention_pallas
from .ref import attention_ref


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    use_kernel: bool = True,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Causal GQA attention. q: (B, Hq, Sq, D); k,v: (B, Hkv, Sk, D)."""
    if not use_kernel:
        return attention_ref(q, k, v, causal=causal, scale=scale)
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    bq = min(block_q, round_up(sq, 8))
    bk = min(block_k, round_up(sk, 8))
    sq_p, sk_p = round_up(sq, bq), round_up(sk, bk)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
    if sk_p != sk:
        # mask padded keys by pushing them outside every causal window; for
        # non-causal, fold the pad mask into k by a large negative bias trick
        # is unavailable here, so fall back to ref for non-causal + padding.
        if not causal:
            return attention_ref(q, k, v, causal=causal, scale=scale)
    out = flash_attention_pallas(
        qp.reshape(b * hq, sq_p, d), kp.reshape(b * hkv, sk_p, d),
        vp.reshape(b * hkv, sk_p, d), num_q_heads=hq, num_kv_heads=hkv,
        causal=causal, scale=scale, block_q=bq, block_k=bk,
        interpret=default_interpret(interpret))
    return out.reshape(b, hq, sq_p, d)[:, :, :sq]

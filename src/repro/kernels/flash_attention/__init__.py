from .flash_attention import flash_attention_pallas
from .ops import flash_attention
from .ref import attention_ref

__all__ = ["flash_attention", "flash_attention_pallas", "attention_ref"]

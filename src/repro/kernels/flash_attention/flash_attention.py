"""Pallas TPU kernel: causal GQA flash attention forward.

The LM stack's compute hot spot.  Standard IO-aware attention (FlashAttention
restructured for TPU): grid = (batch·q_heads, q_blocks, kv_blocks) with the
kv dimension innermost — TPU grids are sequential, so the online-softmax
running statistics (m, l) and the output accumulator live in VMEM scratch and
persist across kv steps while one (BQ×D) query tile stays resident.  GQA is
expressed in the *index maps*: the kv BlockSpec maps a query-head program id
to its kv head, so no materialized K/V repeat.

Block sizes default to 128×128 (MXU-native); VMEM per step =
q(BQ·D) + k,v(BK·D) + scores(BQ·BK) + acc(BQ·D) ≈ 0.4 MiB at D=128 f32,
leaving headroom for double buffering at D=256 (gemma).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, block_q: int, block_k: int,
            q_offset: int):
    """``q_offset = Sk - Sq``: queries are suffix-aligned to the keys (the
    decode/prefill-continuation convention; equals 0 for square attention)."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip blocks entirely above the (offset) diagonal
    run = (not causal) or (
        ki * block_k <= qi * block_q + block_q - 1 + q_offset)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)        # (BQ, D)
        k = k_ref[0].astype(jnp.float32)        # (BK, D)
        v = v_ref[0].astype(jnp.float32)        # (BK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, _NEG_INF)
        m_prev = m_ref[...]                      # (BQ, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _fin():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "num_q_heads",
                                             "num_kv_heads", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           num_q_heads: int, num_kv_heads: int,
                           causal: bool = True, scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: (B*Hq, Sq, D); k,v: (B*Hkv, Sk, D) -> (B*Hq, Sq, D).

    Sq % block_q == 0 and Sk % block_k == 0 (ops.py pads).
    """
    bhq, sq, d = q.shape
    bhk, sk, _ = k.shape
    group = num_q_heads // num_kv_heads
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    assert sq % block_q == 0 and sk % block_k == 0

    def kv_map(bh, qi, ki):
        b = bh // num_q_heads
        h = bh % num_q_heads
        return (b * num_kv_heads + h // group, ki, 0)

    grid = (bhq, sq // block_q, sk // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k,
                          q_offset=sk - sq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

"""Shared Pallas kernel utilities."""

from __future__ import annotations

import jax


def default_interpret(interpret=None) -> bool:
    """Kernels target TPU; on CPU (this container) run in interpret mode."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m

"""Jit'd public wrapper for the segmented-sum kernel (pads + dispatches)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..common import default_interpret, round_up
from .ref import segmented_sum_ref
from .segmented_reduce import segmented_sum_pallas


def segmented_sum(seg_ids: jax.Array, values: jax.Array, num_segments: int,
                  block_rows: int = 256, block_segments: int = 512,
                  use_kernel: bool = True,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Segment sums with TPU-kernel fast path and jnp fallback.

    seg_ids (n,) int32 in [0, num_segments); values (n,) or (n, C).
    """
    squeeze = values.ndim == 1
    if squeeze:
        values = values[:, None]
    if not use_kernel:
        out = segmented_sum_ref(seg_ids, values, num_segments)
        return out[:, 0] if squeeze else out
    n, c = values.shape
    n_pad = round_up(max(n, block_rows), block_rows)
    s_pad = round_up(max(num_segments, block_segments), block_segments)
    if n_pad != n:
        # zero-valued padding rows cannot perturb any segment sum
        seg_ids = jnp.concatenate(
            [seg_ids, jnp.zeros((n_pad - n,), seg_ids.dtype)])
        values = jnp.concatenate(
            [values, jnp.zeros((n_pad - n, c), values.dtype)])
    out = segmented_sum_pallas(seg_ids, values, s_pad,
                               block_rows=block_rows,
                               block_segments=block_segments,
                               interpret=default_interpret(interpret))
    out = out[:num_segments]
    return out[:, 0] if squeeze else out

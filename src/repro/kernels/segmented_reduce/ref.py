"""Pure-jnp oracle for the segmented-sum kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segmented_sum_ref(seg_ids: jax.Array, values: jax.Array,
                      num_segments: int) -> jax.Array:
    """Reference: jax.ops.segment_sum per value column."""
    return jax.ops.segment_sum(values, seg_ids.astype(jnp.int32),
                               num_segments=num_segments)

from .ops import segmented_sum
from .ref import segmented_sum_ref
from .segmented_reduce import segmented_sum_pallas

__all__ = ["segmented_sum", "segmented_sum_ref", "segmented_sum_pallas"]

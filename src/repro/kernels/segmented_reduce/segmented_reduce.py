"""Pallas TPU kernel: segmented sum over sorted segment ids (groupby core).

The groupby aggregation hot spot (paper Fig 2 "core local operator").  A C++
hash aggregation is pointer-chasing; the TPU-native formulation is a one-hot
matmul on the MXU: for a block of R rows with segment ids ``s`` and values
``V`` (R×C), the partial aggregate is ``one_hot(s)ᵀ @ V`` — an (S×R)·(R×C)
systolic matmul.  The 2-D grid tiles segments × row-blocks; the row-block
dimension is innermost (sequential on TPU), accumulating into the same VMEM
output tile, so each (SB×C) output tile stays resident while all row blocks
stream through — HBM traffic is ``n·C + S·C`` instead of ``n·C·num_blocks``.

Block sizes: rows per block R (default 256) and segments per tile SB
(default 512) keep the one-hot tile (R×SB f32 = 512 KiB) and the accumulator
(SB×C) comfortably inside the ~16 MiB VMEM budget with headroom for
double-buffered inputs; both are multiples of the (8,128) f32 tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(seg_ref, val_ref, out_ref, *, seg_block: int):
    sb = pl.program_id(0)
    rb = pl.program_id(1)

    @pl.when(rb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seg = seg_ref[...]  # (R, 1) int32
    vals = val_ref[...]  # (R, C)
    base = sb * seg_block
    # one-hot over this tile's segment range: (R, SB)
    local = seg - base
    cols = jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], seg_block), 1)
    onehot = (cols == local).astype(vals.dtype)
    # (SB, R) @ (R, C) on the MXU
    out_ref[...] += jax.lax.dot_general(
        onehot, vals, (((0,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_segments", "block_rows",
                                             "block_segments", "interpret"))
def segmented_sum_pallas(seg_ids: jax.Array, values: jax.Array,
                         num_segments: int, block_rows: int = 256,
                         block_segments: int = 512,
                         interpret: bool = True) -> jax.Array:
    """seg_ids: (n,) int32 ; values: (n, C) -> (num_segments, C) sums.

    ``n`` must be a multiple of ``block_rows`` and ``num_segments`` of
    ``block_segments`` (the ops.py wrapper pads).  Rows whose value is zero
    never perturb sums, so zero-padding rows is safe regardless of seg id.
    """
    n, c = values.shape
    assert n % block_rows == 0 and num_segments % block_segments == 0
    grid = (num_segments // block_segments, n // block_rows)
    return pl.pallas_call(
        functools.partial(_kernel, seg_block=block_segments),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda sb, rb: (rb, 0)),
            pl.BlockSpec((block_rows, c), lambda sb, rb: (rb, 0)),
        ],
        out_specs=pl.BlockSpec((block_segments, c), lambda sb, rb: (sb, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, c), values.dtype),
        interpret=interpret,
    )(seg_ids.reshape(-1, 1), values)

"""Jit'd public wrapper for the radix-partition kernel (pads + dispatches).

Implementation selection (``impl``):

* ``"auto"``   — the compiled Pallas kernel on TPU; the sort-free XLA
                 segment-cumsum path (``xla.py``) everywhere else.  The XLA
                 path is pure ``jnp``, so ``auto`` is always safe inside
                 ``shard_map`` / ``vmap`` regions (interpret-mode
                 ``pallas_call`` is not) — this is what the dataframe
                 shuffle uses.
* ``"pallas"`` — force the Pallas kernel (interpret mode off-TPU; tests).
* ``"xla"``    — force the sort-free XLA path.
* ``"ref"``    — the sort-based jnp oracle (``ref.py``).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..common import round_up
from .radix_partition import radix_partition_pallas
from .ref import radix_partition_ref
from .xla import radix_partition_xla


def radix_partition(dest: jax.Array, num_buckets: int, block_rows: int = 256,
                    use_kernel: bool = True,
                    interpret: Optional[bool] = None,
                    impl: str = "auto"):
    """(ranks, hist) for destination buckets; see module docstring for ``impl``."""
    if not use_kernel or impl == "ref":
        return radix_partition_ref(dest, num_buckets)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return radix_partition_xla(dest, num_buckets)
    if impl != "pallas":
        raise ValueError(f"unknown radix_partition impl {impl!r}")
    n = dest.shape[0]
    n_pad = round_up(max(n, block_rows), block_rows)
    # padded rows need a bucket strictly above every real bucket — round up
    # PAST num_buckets when rows are padded so the pad bucket never collides
    # with real bucket num_buckets-1.
    nb_pad = round_up(max(num_buckets + (1 if n_pad != n else 0), 128), 128)
    d = dest
    if n_pad != n:
        d = jnp.concatenate(
            [d, jnp.full((n_pad - n,), nb_pad - 1, dest.dtype)])
    ranks, hist = radix_partition_pallas(
        d, nb_pad, block_rows=block_rows, interpret=interpret)
    return ranks[:n], hist[:num_buckets]

"""Jit'd public wrapper for the radix-partition kernel (pads + dispatches)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..common import default_interpret, round_up
from .radix_partition import radix_partition_pallas
from .ref import radix_partition_ref


def radix_partition(dest: jax.Array, num_buckets: int, block_rows: int = 256,
                    use_kernel: bool = True,
                    interpret: Optional[bool] = None):
    """(ranks, hist) for destination buckets; kernel fast path + jnp fallback."""
    if not use_kernel:
        return radix_partition_ref(dest, num_buckets)
    n = dest.shape[0]
    n_pad = round_up(max(n, block_rows), block_rows)
    # padded rows need a bucket strictly above every real bucket — round up
    # PAST num_buckets when rows are padded so the pad bucket never collides
    # with real bucket num_buckets-1.
    nb_pad = round_up(max(num_buckets + (1 if n_pad != n else 0), 128), 128)
    d = dest
    if n_pad != n:
        d = jnp.concatenate(
            [d, jnp.full((n_pad - n,), nb_pad - 1, dest.dtype)])
    ranks, hist = radix_partition_pallas(
        d, nb_pad, block_rows=block_rows,
        interpret=default_interpret(interpret))
    return ranks[:n], hist[:num_buckets]

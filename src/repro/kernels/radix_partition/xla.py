"""Sort-free XLA formulation of radix partition (the CPU/GPU hot path).

``radix_partition_ref`` is the sort-based oracle (two O(n log n) passes —
exactly the cost the sort-free shuffle removes).  This module computes the
same (rank-in-bucket, histogram) pair as a *segment cumsum*: the stable
rank of row ``i`` is the running count of earlier rows with the same
destination, i.e. an exclusive prefix sum segmented by destination over an
unsorted segment vector.

Two regimes, both free of any sort and of a full ``(n, nb)`` one-hot
materialisation at scale:

* **dense** (``n * nb`` small): one exclusive cumsum over the one-hot
  matrix — a single fused elementwise+scan program, fastest for the
  shuffle's case where ``nb = p + 1`` is tiny;
* **blocked** (``n * nb`` large): ``lax.scan`` over row blocks carrying
  the running per-bucket histogram — the same structure as the Pallas TPU
  kernel, with peak memory O(block_rows · nb) instead of O(n · nb).

Used by ``ops.radix_partition`` on every non-TPU backend and by the
dataframe shuffle's scatter (it is pure ``jnp``, so it is safe under
``shard_map`` / ``vmap`` where an interpret-mode ``pallas_call`` is not).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..common import round_up

#: switch to the blocked scan above this many one-hot cells (~16 MiB i32)
_DENSE_CELLS = 1 << 22


def _dense(dest: jax.Array, num_buckets: int):
    n = dest.shape[0]
    onehot = (dest[:, None] == jnp.arange(num_buckets, dtype=dest.dtype)
              ).astype(jnp.int32)                       # (n, nb)
    excl = jnp.cumsum(onehot, axis=0) - onehot          # exclusive, per bucket
    safe = jnp.clip(dest, 0, num_buckets - 1).astype(jnp.int32)
    ranks = jnp.take_along_axis(excl, safe[:, None], axis=1)[:, 0]
    hist = jnp.sum(onehot, axis=0)
    return ranks, hist


def _blocked(dest: jax.Array, num_buckets: int, block_rows: int):
    n = dest.shape[0]
    n_pad = round_up(max(n, block_rows), block_rows)
    d = dest
    if n_pad != n:
        # pad bucket = num_buckets: one-hot all-zero, so the histogram and
        # the running counts never see the padding rows
        d = jnp.concatenate(
            [d, jnp.full((n_pad - n,), num_buckets, dest.dtype)])
    blocks = d.reshape(-1, block_rows)
    iota = jnp.arange(num_buckets, dtype=d.dtype)

    def step(running, db):
        onehot = (db[:, None] == iota).astype(jnp.int32)   # (R, nb)
        excl = jnp.cumsum(onehot, axis=0) - onehot
        safe = jnp.clip(db, 0, num_buckets - 1).astype(jnp.int32)
        in_block = jnp.take_along_axis(excl, safe[:, None], axis=1)[:, 0]
        ranks_b = jnp.take(running, safe) + in_block
        return running + jnp.sum(onehot, axis=0), ranks_b

    hist, ranks = jax.lax.scan(step, jnp.zeros((num_buckets,), jnp.int32),
                               blocks)
    return ranks.reshape(-1)[:n], hist


def radix_partition_xla(dest: jax.Array, num_buckets: int,
                        block_rows: Optional[int] = None):
    """Sort-free (ranks, hist): segment cumsum over destinations.

    ``dest``: (n,) int32 in [0, num_buckets); returns stable within-bucket
    ranks (n,) int32 and the bucket histogram (num_buckets,) int32.
    ``block_rows`` forces the blocked-scan regime (tests); ``None`` picks
    dense vs blocked from the one-hot cell count.
    """
    n = dest.shape[0]
    if block_rows is None:
        if n * num_buckets <= _DENSE_CELLS:
            return _dense(dest, num_buckets)
        block_rows = 4096
    return _blocked(dest, num_buckets, block_rows)

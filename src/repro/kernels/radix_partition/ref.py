"""Pure-jnp oracle for the radix-partition kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def radix_partition_ref(dest: jax.Array, num_buckets: int):
    """Stable within-bucket ranks + histogram (sort-based, like shuffle.py)."""
    n = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    sorted_dest = jnp.take(dest, order)
    start = jnp.searchsorted(sorted_dest, sorted_dest, side="left")
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - start.astype(jnp.int32)
    ranks = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)
    hist = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), dest,
                               num_segments=num_buckets)
    return ranks, hist

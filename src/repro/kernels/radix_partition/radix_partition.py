"""Pallas TPU kernel: stable radix partition (shuffle's bucketize hot spot).

Computes, for every row's destination bucket, its stable rank *within* that
bucket plus the global bucket histogram — exactly what the capacity-based
shuffle needs to scatter rows into its ``(p, bucket_cap)`` send buffer
(`repro.dataframe.shuffle`).  A GPU implementation would use atomics; the
TPU formulation exploits the *sequential* grid: a VMEM scratch carries the
running per-bucket counts across row blocks (a scan over blocks), and ranks
inside a block come from an exclusive cumsum over the block's one-hot
destination matrix — all VPU/MXU-friendly dense ops.

  rank[i]  = running[dest_i] + (# earlier rows in this block with dest_i)
  hist     = running counts after the last block

Block sizes: R rows × NB buckets one-hot (256×1024 i32 = 1 MiB) well inside
VMEM; NB is padded to a multiple of 128 lanes.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import default_interpret


def _kernel(dest_ref, rank_ref, hist_ref, running_ref):
    rb = pl.program_id(0)

    @pl.when(rb == 0)
    def _init():
        running_ref[...] = jnp.zeros_like(running_ref)

    dest = dest_ref[...]                      # (R, 1) int32
    r, nb = dest.shape[0], running_ref.shape[1]
    cols = jax.lax.broadcasted_iota(jnp.int32, (r, nb), 1)
    onehot = (cols == dest).astype(jnp.int32)  # (R, NB)
    # stable rank within block: exclusive cumsum down the rows
    excl = jnp.cumsum(onehot, axis=0) - onehot
    in_block = jnp.sum(excl * onehot, axis=1, keepdims=True)       # (R, 1)
    carried = jnp.sum(running_ref[...] * onehot, axis=1, keepdims=True)
    rank_ref[...] = carried + in_block
    running_ref[...] += jnp.sum(onehot, axis=0, keepdims=True)

    @pl.when(rb == pl.num_programs(0) - 1)
    def _fin():
        hist_ref[...] = running_ref[...]


@functools.partial(jax.jit, static_argnames=("num_buckets", "block_rows",
                                             "interpret"))
def radix_partition_pallas(dest: jax.Array, num_buckets: int,
                           block_rows: int = 256,
                           interpret: Optional[bool] = None):
    """dest: (n,) int32 in [0, num_buckets) -> (ranks (n,), hist (num_buckets,)).

    n must be a multiple of block_rows and num_buckets of 128 (ops.py pads;
    padded rows use bucket num_buckets-1 and their ranks are discarded).
    ``interpret=None`` selects from the backend: the real Mosaic kernel on
    TPU, interpret mode elsewhere (it used to default to ``interpret=True``,
    silently skipping the compiled kernel even on TPU).
    """
    interpret = default_interpret(interpret)
    n = dest.shape[0]
    assert n % block_rows == 0 and num_buckets % 128 == 0
    grid = (n // block_rows,)
    ranks, hist = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, 1), lambda rb: (rb, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, 1), lambda rb: (rb, 0)),
            pl.BlockSpec((1, num_buckets), lambda rb: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, num_buckets), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, num_buckets), jnp.int32)],
        interpret=interpret,
    )(dest.reshape(-1, 1))
    return ranks[:, 0], hist[0]

from .ops import radix_partition
from .radix_partition import radix_partition_pallas
from .ref import radix_partition_ref
from .xla import radix_partition_xla

__all__ = ["radix_partition", "radix_partition_pallas", "radix_partition_ref",
           "radix_partition_xla"]

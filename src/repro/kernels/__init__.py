"""Pallas TPU kernels for the system's compute hot spots.

Each kernel ships as ``<name>/<name>.py`` (pl.pallas_call + BlockSpec VMEM
tiling), ``<name>/ops.py`` (jit'd wrapper with padding + backend dispatch)
and ``<name>/ref.py`` (pure-jnp oracle).  On CPU (this container) kernels run
under ``interpret=True``; on TPU they compile via Mosaic.

  segmented_reduce  groupby aggregation (one-hot MXU matmul over row blocks)
  radix_partition   shuffle bucketize (scan-over-blocks running histogram)
  flash_attention   causal GQA attention (online softmax, kv-sequential grid)
  ssd_scan          Mamba-2 SSD chunked scan (VMEM-resident state)
"""

from .segmented_reduce import segmented_sum, segmented_sum_ref
from .radix_partition import (radix_partition, radix_partition_ref,
                              radix_partition_xla)
from .flash_attention import attention_ref, flash_attention
from .ssd_scan import ssd_scan, ssd_scan_chunked_jnp, ssd_scan_ref

__all__ = [
    "segmented_sum", "segmented_sum_ref",
    "radix_partition", "radix_partition_ref", "radix_partition_xla",
    "flash_attention", "attention_ref",
    "ssd_scan", "ssd_scan_chunked_jnp", "ssd_scan_ref",
]

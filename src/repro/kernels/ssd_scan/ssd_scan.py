"""Pallas TPU kernel: Mamba-2 SSD chunked scan (state-space duality).

Implements the SSD chunk recurrence [arXiv:2405.21060] for diagonal A (one
scalar decay per head):

    h_t = exp(A·dt_t) · h_{t-1} + dt_t · B_t xᵀ_t          (h: N×P)
    y_t = C_tᵀ h_t

The chunked form turns the recurrence into MXU matmuls: within a chunk of
length L the intra-chunk term is ``((C Bᵀ) ⊙ M) (X ⊙ dt)`` with decay mask
``M[t,s] = exp(cum_t − cum_s)·[t ≥ s]``, and the carried state advances as

    h_end = exp(cum_L) · h_start + (B ⊙ dt·exp(cum_L − cum))ᵀ X.

TPU mapping: grid = (batch·heads, chunks) with chunks innermost (sequential),
so the (N×P) state lives in VMEM scratch across chunk steps — the classic
scan-over-blocks pattern.  All heavy ops are (L×N)(N×P) / (L×L)(L×P) matmuls.
VMEM per step at L=128, N=128, P=64 f32 ≈ 0.4 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref, *,
            chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)      # (L, P)
    dt = dt_ref[0].astype(jnp.float32)    # (L, 1)
    a = a_ref[0, 0].astype(jnp.float32)   # scalar decay rate (< 0)
    bmat = b_ref[0].astype(jnp.float32)   # (L, N)
    cmat = c_ref[0].astype(jnp.float32)   # (L, N)

    adt = a * dt                          # (L, 1)
    cum = jnp.cumsum(adt, axis=0)         # inclusive
    l = x.shape[0]

    # intra-chunk: ((C Bᵀ) ⊙ M) (X ⊙ dt)
    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (L, L)
    seg = cum - cum.T                     # cum_t - cum_s  (t row, s col)
    rows = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    mask = rows >= cols
    decay = jnp.where(mask, jnp.exp(jnp.where(mask, seg, 0.0)), 0.0)
    y_intra = jax.lax.dot_general(scores * decay, x * dt,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: exp(cum_t) · C_t h_start
    h = h_ref[...]                        # (N, P)
    y_inter = jnp.exp(cum) * jax.lax.dot_general(
        cmat, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h_end = exp(cum_L)·h + (B ⊙ dt·exp(cum_L − cum))ᵀ X
    total = cum[l - 1:l]                  # (1, 1)
    w = dt * jnp.exp(total - cum)         # (L, 1)
    h_ref[...] = jnp.exp(total[0, 0]) * h + jax.lax.dot_general(
        bmat * w, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == pl.num_programs(1) - 1)
    def _emit_state():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                    c: jax.Array, chunk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """SSD scan over flattened (batch·heads) sequences.

    x: (BH, T, P); dt: (BH, T, 1); a: (BH, 1); b, c: (BH, T, N).
    T must be a multiple of ``chunk`` (ops.py pads).  Returns
    (y: (BH, T, P), h_final: (BH, N, P)) — the final state feeds decode.
    """
    bh, t, p = x.shape
    n = b.shape[-1]
    assert t % chunk == 0
    grid = (bh, t // chunk)
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, 1), lambda i, ci: (i, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, ci: (i, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, n, p), lambda i, ci: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, p), x.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)

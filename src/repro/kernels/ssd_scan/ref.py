"""Pure-jnp oracle: naive SSD recurrence (O(T) scan over time)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ssd_scan_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                 c: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (BH, T, P); dt: (BH, T, 1); a: (BH, 1); b, c: (BH, T, N).

    Returns (y: (BH, T, P), h_final: (BH, N, P)).
    """
    bh, t, p = x.shape
    n = b.shape[-1]

    def per_seq(xs, dts, a_s, bs, cs):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            decay = jnp.exp(a_s[0] * dtt[0])
            h = decay * h + dtt[0] * jnp.outer(bt, xt)   # (N, P)
            return h, ct @ h
        h0 = jnp.zeros((n, p), jnp.float32)
        hT, ys = jax.lax.scan(step, h0, (xs.astype(jnp.float32),
                                         dts.astype(jnp.float32),
                                         bs.astype(jnp.float32),
                                         cs.astype(jnp.float32)))
        return ys, hT

    out, h = jax.vmap(per_seq)(x, dt, a, b, c)
    return out.astype(x.dtype), h

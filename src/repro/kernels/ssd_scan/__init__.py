from .ops import ssd_scan, ssd_scan_chunked_jnp
from .ref import ssd_scan_ref
from .ssd_scan import ssd_scan_pallas

__all__ = ["ssd_scan", "ssd_scan_chunked_jnp", "ssd_scan_ref", "ssd_scan_pallas"]

"""Jit'd public wrapper for the SSD scan (padding + backend dispatch).

Also exports ``ssd_scan_chunked_jnp`` — the same chunked algorithm in pure
jnp.  It is used by the mamba2/jamba model stacks for the *dry-run* path
(Pallas TPU kernels cannot compile on the CPU backend) and doubles as a
second oracle for the kernel.

Both paths return ``(y, h_final)`` where ``h_final: (BH, N, P)`` is the SSD
state after the last timestep — the prefill→decode hand-off.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..common import default_interpret, round_up
from .ref import ssd_scan_ref
from .ssd_scan import ssd_scan_pallas


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, chunk: int = 128, use_kernel: bool = True,
             interpret: Optional[bool] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """SSD scan; x: (BH, T, P); dt: (BH, T, 1); a: (BH, 1); b,c: (BH, T, N).

    Returns (y: (BH, T, P), h_final: (BH, N, P)).
    """
    if not use_kernel:
        return ssd_scan_chunked_jnp(x, dt, a, b, c, chunk=chunk)
    bh, t, p = x.shape
    ch = min(chunk, round_up(t, 8))
    t_pad = round_up(t, ch)
    if t_pad != t:
        pad = ((0, 0), (0, t_pad - t), (0, 0))
        # dt=0 padding is inert: decay=1, no state update, y discarded
        x, dt, b, c = (jnp.pad(v, pad) for v in (x, dt, b, c))
    y, h = ssd_scan_pallas(x, dt, a, b, c, chunk=ch,
                           interpret=default_interpret(interpret))
    return y[:, :t], h


def ssd_scan_chunked_jnp(x: jax.Array, dt: jax.Array, a: jax.Array,
                         b: jax.Array, c: jax.Array, chunk: int = 128
                         ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD in pure jnp (dry-run path; same math as the kernel)."""
    bh, t, p = x.shape
    n = b.shape[-1]
    ch = min(chunk, t)
    t_pad = round_up(t, ch)
    if t_pad != t:
        pad = ((0, 0), (0, t_pad - t), (0, 0))
        x, dt, b, c = (jnp.pad(v, pad) for v in (x, dt, b, c))
    nc = t_pad // ch

    xc = x.reshape(bh, nc, ch, p).astype(jnp.float32)
    dtc = dt.reshape(bh, nc, ch, 1).astype(jnp.float32)
    bc = b.reshape(bh, nc, ch, n).astype(jnp.float32)
    cc = c.reshape(bh, nc, ch, n).astype(jnp.float32)
    af = a.astype(jnp.float32)

    adt = af.reshape(bh, 1, 1, 1) * dtc
    cum = jnp.cumsum(adt, axis=2)                        # (BH, NC, L, 1)
    seg = cum - jnp.swapaxes(cum, 2, 3)                  # (BH, NC, L, L)
    mask = jnp.tril(jnp.ones((ch, ch), bool))
    decay = jnp.where(mask, jnp.exp(jnp.where(mask, seg, 0.0)), 0.0)
    scores = jnp.einsum("zntk,znsk->znts", cc, bc)
    y_intra = jnp.einsum("znts,znsp->zntp", scores * decay, xc * dtc)

    total = cum[:, :, -1:, :]                            # (BH, NC, 1, 1)
    w = dtc * jnp.exp(total - cum)                       # (BH, NC, L, 1)
    h_in = jnp.einsum("znsk,znsp->znkp", bc * w, xc)     # per-chunk injection

    def carry(h, inp):
        tot, hin = inp                                   # tot: (BH, 1, 1)
        h_out = jnp.exp(tot[:, :, 0])[..., None] * h + hin  # (BH, N, P)
        return h_out, h
    tot_seq = jnp.moveaxis(total, 1, 0)                  # (NC, BH, 1, 1)
    hin_seq = jnp.moveaxis(h_in, 1, 0)                   # (NC, BH, N, P)
    # NOTE: deliberately NOT unrolled under the dry-run counting flags —
    # the carry body is tiny elementwise work (the heavy SSD einsums are
    # batched outside this scan), while unrolling T/chunk copies of it
    # makes the SPMD partitioner intractably slow on deep hybrid stacks.
    # Undercount from the rolled body is <0.1% of any cell's terms.
    h_final, h_starts = jax.lax.scan(
        carry, jnp.zeros((bh, n, p), jnp.float32), (tot_seq, hin_seq))
    h_starts = jnp.moveaxis(h_starts, 0, 1)              # (BH, NC, N, P)
    y_inter = jnp.exp(cum) * jnp.einsum("zntk,znkp->zntp", cc, h_starts)

    y = (y_intra + y_inter).reshape(bh, t_pad, p)[:, :t]
    return y.astype(x.dtype), h_final

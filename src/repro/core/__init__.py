"""The paper's primary contribution: pseudo-BSP DDF execution on device meshes.

Pieces: ``CylonEnv`` (stateful BSP environment), ``CylonExecutor`` (actor-gang
resource partitioning), ``Plan``/``execute`` (logical plan + coalescing, with
the AMT baseline mode), ``CylonStore`` (downstream hand-off + repartition).
"""

from .env import (AXIS, CylonEnv, DevicePool, DistTable, EnvContext, Lease,
                  MorselSource, PoolExhausted)
from .actor import CylonExecutor
from .plan import Plan, execute
from .store import CylonStore, SpillTable, repartition, rescatter

__all__ = [
    "AXIS", "CylonEnv", "CylonExecutor", "CylonStore", "DevicePool",
    "DistTable", "EnvContext", "Lease", "MorselSource", "Plan",
    "PoolExhausted", "SpillTable", "execute", "repartition", "rescatter",
]

"""Stateful pseudo-BSP execution environment (the paper's §IV-A).

``CylonEnv`` is the JAX analogue of the paper's ``Cylon_env`` actor state: it
pins a partition of the device mesh, keeps the communicator alive across
operators, and caches compiled programs so repeated submissions pay zero
re-initialization cost (the paper's motivation for stateful actors).

Driver/shard boundary convention
--------------------------------
Driver-side distributed tables (``DistTable``) hold global arrays of shape
``(p * capacity, ...)`` sharded over the env axis plus per-rank row counts
``(p,)``.  Inside the shard_map region user functions see a plain
``dataframe.Table`` with local ``(capacity, ...)`` columns and a scalar
``row_count`` — i.e. the BSP/SPMD view, exactly like a Cylon worker owning
its partition.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .. import compat
from ..comm import Communicator, get_communicator
from ..dataframe.table import Table
from ..obs.trace import NULL_TRACER

AXIS = "df"  # default dataframe axis name


# ---------------------------------------------------------------------- #
# Driver-side distributed table
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class DistTable:
    """Global view of a distributed Table: (p*cap,) columns + (p,) counts.

    ``dictionaries`` maps each dictionary-encoded string column to its
    sorted dictionary (``dataframe.schema``); the device columns for those
    names hold int32 codes.  Purely driver-side metadata — it never enters
    the compiled programs.
    """

    columns: Dict[str, jax.Array]
    row_counts: jax.Array  # (p,) int32
    capacity: int          # per-shard capacity
    dictionaries: Dict[str, Tuple[str, ...]] = \
        dataclasses.field(default_factory=dict)
    #: ``repro.io.IngestInfo`` when this table was read from Parquet/CSV
    #: (files, rows, source bytes); None for tables built in memory.
    #: Driver-side only — EXPLAIN ANALYZE attributes scan work from it.
    provenance: Optional[Any] = None

    @property
    def parallelism(self) -> int:
        return self.row_counts.shape[0]

    @property
    def column_names(self) -> Tuple[str, ...]:
        return tuple(sorted(self.columns))

    @classmethod
    def from_numpy(cls, data: Dict[str, np.ndarray], parallelism: int,
                   capacity: Optional[int] = None) -> "DistTable":
        """Block-distribute host rows over ``parallelism`` shards.

        String columns (object / unicode numpy arrays) are dictionary-
        encoded host-side: the device gets int32 codes, the sorted
        dictionary lands in ``dictionaries``.  NaN / ``None`` values (or
        explicit ``__m_*`` companions) become validity-mask columns with
        canonical-zero data slots (``repro.nulls``).  An explicit
        ``capacity`` — including ``0`` — is honored verbatim and validated
        against the per-shard row count."""
        from ..dataframe.schema import encode_columns
        from ..nulls import extract_null_columns
        data = extract_null_columns(
            {k: np.asarray(v) for k, v in data.items()})
        data, dicts = encode_columns(data)
        n = len(next(iter(data.values())))
        per = -(-n // parallelism)
        if capacity is None:
            capacity = max(8, -(-per // 8) * 8)
        if per > capacity:
            raise ValueError(f"rows/shard {per} exceeds capacity {capacity}")
        cols = {}
        counts = np.zeros((parallelism,), np.int32)
        for name, arr in data.items():
            arr = np.asarray(arr)
            buf = np.zeros((parallelism, capacity) + arr.shape[1:], arr.dtype)
            for r in range(parallelism):
                chunk = arr[r * per:(r + 1) * per]
                buf[r, :len(chunk)] = chunk
                counts[r] = len(chunk)
            cols[name] = jnp.asarray(buf.reshape((parallelism * capacity,) + arr.shape[1:]))
        return cls(cols, jnp.asarray(counts), capacity, dicts)

    def to_numpy(self, decode: bool = True, nulls: str = "pandas"
                 ) -> Dict[str, np.ndarray]:
        """Gather valid rows from every shard (driver side, not jitted).

        ``decode=True`` (default) maps dictionary-encoded columns back to
        numpy string arrays; ``decode=False`` returns the raw int32 codes.
        ``nulls="pandas"`` (default) re-materializes validity masks as
        NaN / ``None`` (consuming the ``__m_*`` columns);
        ``nulls="mask"`` returns the raw physical layout — canonical-zero
        data plus the bool mask columns — for bit-identity checks.
        """
        if nulls not in ("pandas", "mask"):
            raise ValueError(f"nulls must be 'pandas' or 'mask', got {nulls!r}")
        p, cap = self.parallelism, self.capacity
        counts = np.asarray(self.row_counts)
        out = {}
        for name, arr in self.columns.items():
            a = np.asarray(arr).reshape((p, cap) + arr.shape[1:])
            out[name] = np.concatenate([a[r, :counts[r]] for r in range(p)], axis=0)
        if decode and self.dictionaries:
            from ..dataframe.schema import decode_columns
            out = decode_columns(out, self.dictionaries)
        if nulls == "pandas":
            from ..nulls import apply_null_columns
            out = apply_null_columns(out)
        return out

    def total_rows(self) -> int:
        return int(np.asarray(self.row_counts).sum())


# ---------------------------------------------------------------------- #
# Morsel streaming: host spill -> fixed-capacity device batches
# ---------------------------------------------------------------------- #
class MorselSource:
    """Streams a host-resident table as fixed-capacity device ``DistTable``
    morsels (the out-of-core input path, ``docs/out_of_core.md``).

    ``source`` may be a ``core.store.SpillTable``, a device ``DistTable``
    (spilled first), or a dict of host numpy columns (block-distributed over
    ``parallelism`` ranks).  Every yielded morsel has the same per-rank
    capacity (``morsel_rows`` rounded up to 8), so one compiled program —
    a single structural-fingerprint cache entry — processes every morsel.

    Transfers are **double-buffered**: morsel ``m+1``'s host->device copy is
    enqueued (asynchronously, like a pinned-staging H2D DMA) before morsel
    ``m`` is handed to the consumer, overlapping transfer with compute.
    ``h2d_bytes`` accumulates the bytes shipped to devices.
    """

    def __init__(self, source, morsel_rows: int,
                 env: Optional["CylonEnv"] = None,
                 parallelism: Optional[int] = None, tracer=None,
                 faults=None, token=None):
        from .store import SpillTable  # deferred: store imports env
        if isinstance(source, DistTable):
            source = SpillTable.from_dist(source)
        elif isinstance(source, dict):
            p = parallelism or (env.parallelism if env is not None else 1)
            source = SpillTable.from_numpy(source, p)
        self.spill = source
        self.parallelism = source.parallelism
        if morsel_rows < 1:
            raise ValueError(f"morsel_rows must be >= 1, got {morsel_rows}")
        self.capacity = max(8, -(-int(morsel_rows) // 8) * 8)
        self.num_morsels = source.num_morsels(self.capacity)
        self.h2d_bytes = 0
        # one host-contiguous view per rank; a production backend would walk
        # the pinned chunks with a cursor instead of concatenating
        self._rank_cols = [source.rank_concat(r)
                           for r in range(self.parallelism)]
        self._names = source.column_names
        self._tracer = tracer if tracer is not None else NULL_TRACER
        # fault-injection hooks (repro.faults): the H2D staging of each
        # morsel is a registered hazard point; both default to no-ops
        if faults is None:
            from ..faults import NULL_FAULTS
            faults = NULL_FAULTS
        self._faults = faults
        self._token = token

    def _build(self, m: int) -> Optional[DistTable]:
        if m >= self.num_morsels:
            return None
        self._faults.check("transfer:h2d", token=self._token, morsel=m)
        b0 = self.h2d_bytes
        p, cap = self.parallelism, self.capacity
        lo, hi = m * cap, (m + 1) * cap
        counts = np.zeros((p,), np.int32)
        cols = {}
        for name in self._names:
            ref = self._rank_cols[0][name]
            buf = np.zeros((p, cap) + ref.shape[1:], ref.dtype)
            for r in range(p):
                piece = self._rank_cols[r][name][lo:hi]
                buf[r, :len(piece)] = piece
                counts[r] = len(piece)
            self.h2d_bytes += buf.nbytes
            cols[name] = jnp.asarray(buf.reshape((p * cap,) + ref.shape[1:]))
        self.h2d_bytes += counts.nbytes
        self._tracer.instant(f"h2d:morsel[{m}]", "transfer", morsel=m,
                             bytes=self.h2d_bytes - b0)
        return DistTable(cols, jnp.asarray(counts), cap,
                         dict(self.spill.dictionaries))

    def __iter__(self):
        nxt = self._build(0)
        m = 1
        while nxt is not None:
            cur = nxt
            nxt = self._build(m)  # prefetch: H2D for m enqueued before m-1 runs
            m += 1
            yield cur


# ---------------------------------------------------------------------- #
# The stateful environment
# ---------------------------------------------------------------------- #
class CylonEnv:
    """A pseudo-BSP environment pinned to a device partition.

    Parameters
    ----------
    devices:       explicit device list (a partition of the cluster, e.g. a
                   ``DevicePool`` lease), or None for all local devices.
    communicator:  registry name ("xla" | "ring" | "bruck").
    program_cache: a ``repro.serve.cache.ProgramCache`` to share compiled
                   programs with other envs (the serving scheduler passes
                   one per process so a freshly carved gang reuses every
                   program any earlier gang over the same devices built).
                   Default: a private cache, preserving single-env
                   semantics.

    Thread safety: ``run`` may be called from many threads.  Program
    lookups/builds go through the (locked, single-flight) program cache, so
    two threads racing the same key compile once; the per-env hit/miss
    counters are updated under a lock.
    """

    def __init__(self, devices: Optional[Sequence[jax.Device]] = None,
                 communicator: str = "xla", axis: str = AXIS,
                 program_cache: Optional[Any] = None):
        # deferred import: repro.serve.cache is standalone, but its package
        # __init__ must not be entered while core.env is still importing
        from ..serve.cache import ProgramCache
        self.devices = list(devices if devices is not None else jax.devices())
        self.axis = axis
        self.mesh = jax.sharding.Mesh(np.asarray(self.devices), (axis,))
        self.comm: Communicator = get_communicator(communicator, axis)
        self.communicator_name = communicator
        self.programs = (program_cache if program_cache is not None
                         else ProgramCache())
        #: compiled shard_map programs are mesh-bound, so the shared-cache
        #: key pins the gang's placement: platform + device ids + axis +
        #: communicator.  The DevicePool free-list hands out lowest ids
        #: first, so a released-and-recarved gang hits these entries.
        self._gang_key = (self.devices[0].platform if self.devices else "cpu",
                          tuple(d.id for d in self.devices), axis,
                          communicator)
        #: env-local memo in front of the shared cache (also the
        #: introspection surface tests use: ``set(env._cache)``)
        self._cache: Dict[Any, Callable] = {}
        self._lock = threading.Lock()
        #: compile-cache observability: a miss builds (traces + compiles) a
        #: program; a hit reuses one — whether it was compiled by this env
        #: or found in a shared program cache.  The morsel executor's
        #: per-morsel zero-recompile invariant is asserted against these
        #: counters.
        self.cache_hits = 0
        self.cache_misses = 0

    @property
    def parallelism(self) -> int:
        return len(self.devices)

    def close(self) -> None:
        """Drop this env's local program memo (shared ``programs`` entries
        persist for the next gang carved over these devices)."""
        with self._lock:
            self._cache.clear()

    # ------------------------------------------------------------------ #
    # Table conversion at the shard_map boundary
    # ------------------------------------------------------------------ #
    def _in_spec_for(self, x):
        if isinstance(x, DistTable):
            return ({n: P(self.axis) for n in x.column_names}, P(self.axis))
        return P()  # replicated scalar/array argument

    @staticmethod
    def _to_boundary(x):
        if isinstance(x, DistTable):
            return ({n: x.columns[n] for n in x.column_names}, x.row_counts)
        return x

    # ------------------------------------------------------------------ #
    # Submission API (the paper's run_cylon / execute_cylon)
    # ------------------------------------------------------------------ #
    def run(self, fn: Callable, *args, static_kwargs: Optional[dict] = None,
            key: Any = None):
        """Run ``fn(ctx, *local_args, **static_kwargs)`` under shard_map.

        ``fn`` receives this env's communicator-bearing context and local
        ``Table`` views of any ``DistTable`` args; it may return an arbitrary
        pytree of ``Table`` / arrays.  Returned Tables become ``DistTable``;
        returned arrays come back per-rank with a leading ``(p,)`` axis.
        Compiled programs are cached on the env (stateful reuse).
        """
        static_kwargs = static_kwargs or {}
        cache_key = key if key is not None else (
            fn, tuple(sorted(static_kwargs)),
            tuple(self._arg_sig(a) for a in args))
        boundary_args = tuple(self._to_boundary(a) for a in args)
        with self._lock:
            compiled = self._cache.get(cache_key)
        if compiled is None:
            # shared-cache path: single-flight build keyed by (program,
            # gang placement).  A hit here — the program was compiled by an
            # earlier env over the same devices, or by a racing thread —
            # counts as a hit, so a freshly carved gang that reuses every
            # program reports cache_misses == 0.
            compiled, built = self.programs.get_or_build(
                (cache_key, self._gang_key),
                lambda: self._build(fn, args, static_kwargs))
            with self._lock:
                self._cache[cache_key] = compiled
                if built:
                    self.cache_misses += 1
                else:
                    self.cache_hits += 1
        else:
            with self._lock:
                self.cache_hits += 1
        out_tree, caps = compiled(*boundary_args)
        return self._from_boundary(out_tree, caps)

    def _arg_sig(self, a):
        if isinstance(a, DistTable):
            return ("T", a.capacity,
                    tuple((n, str(a.columns[n].dtype), a.columns[n].shape[1:])
                          for n in a.column_names))
        x = jnp.asarray(a)
        return ("A", str(x.dtype), x.shape)

    def _build(self, fn, args, static_kwargs):
        env = self
        ctx = EnvContext(self.comm, self.axis)
        # capture only the arg KINDS: closing over `args` would pin the
        # first call's device arrays in the compile cache for the env's
        # lifetime (the morsel executor reuses programs across many inputs)
        is_dist = tuple(isinstance(a, DistTable) for a in args)

        def local_fn(*boundary_args):
            local_args = []
            for d, b in zip(is_dist, boundary_args):
                if d:
                    cols, counts = b
                    local_args.append(Table(dict(cols), counts[0]))
                else:
                    local_args.append(b)
            out = fn(ctx, *local_args, **static_kwargs)
            # normalize outputs: Table -> (cols, count[None]); array -> arr[None]
            def conv(x):
                if isinstance(x, Table):
                    return (dict(x.columns), x.row_count[None])
                x = jnp.asarray(x)
                return x[None]
            leaves, treedef = jax.tree_util.tree_flatten(
                out, is_leaf=lambda x: isinstance(x, Table))
            return treedef, tuple(conv(l) for l in leaves)

        in_specs = tuple(self._in_spec_for(a) for a in args)

        treedef_box = {}

        def shard_body(*bargs):
            treedef, converted = local_fn(*bargs)
            treedef_box["treedef"] = treedef
            return converted

        # out_specs is a tree *prefix*: every boundary leaf has a leading
        # per-shard axis (columns (cap,...), counts (1,), arrays (1,...)), so
        # a single P(axis) applies to the whole output tree and no separate
        # structure-discovery trace is needed.
        mapped = jax.jit(compat.shard_map(
            shard_body, mesh=self.mesh, in_specs=in_specs,
            out_specs=P(self.axis), check_vma=False))

        # serialize the first invocation: tracing fills treedef_box, and
        # concurrent submitters sharing a just-built program must not race
        # the trace (jit retraces for new shapes stay lock-free)
        first_call = threading.Lock()

        def runner(*bargs):
            if "treedef" not in treedef_box:
                with first_call:
                    out = mapped(*bargs)  # traces & fills treedef_box
            else:
                out = mapped(*bargs)
            return (treedef_box["treedef"], out), None
        return runner

    def _from_boundary(self, out_tree, caps):
        treedef, leaves = out_tree

        def unconv(x):
            if isinstance(x, tuple):  # (cols, counts)
                cols, counts = x
                cap = next(iter(cols.values())).shape[0] // self.parallelism
                return DistTable(dict(cols), counts[:, 0] if counts.ndim > 1
                                 else counts, cap)
            return x
        leaves = [unconv(l) for l in leaves]
        return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclasses.dataclass
class EnvContext:
    """What user functions see inside the BSP region (the Cylon_env arg)."""

    comm: Communicator
    axis: str

    def rank(self):
        return jax.lax.axis_index(self.axis)

    def size(self):
        return compat.axis_size(self.axis)


# ---------------------------------------------------------------------- #
# Device pool: resource partitioning for independent applications (§IV-A)
# ---------------------------------------------------------------------- #
class PoolExhausted(RuntimeError):
    """``DevicePool.reserve`` could not satisfy the request."""


class Lease(Sequence):
    """A disjoint device partition handed out by ``DevicePool.reserve``.

    Behaves as a sequence of devices (so ``CylonEnv(lease)`` and existing
    ``pool.reserve(n)[0]``-style code keep working) and carries its own
    ``release()``; it is also a context manager::

        with pool.reserve(2) as gang:
            env = CylonEnv(gang)
            ...
        # devices returned to the free list here
    """

    __slots__ = ("_pool", "_indices", "devices", "_released")

    def __init__(self, pool: "DevicePool", indices: Tuple[int, ...],
                 devices: Tuple[jax.Device, ...]):
        self._pool = pool
        self._indices = indices
        self.devices = devices
        self._released = False

    @property
    def indices(self) -> Tuple[int, ...]:
        return self._indices

    @property
    def released(self) -> bool:
        return self._released

    def release(self) -> None:
        """Return the partition to the pool (idempotent)."""
        self._pool.release(self)

    def __len__(self) -> int:
        return len(self.devices)

    def __getitem__(self, i):
        return self.devices[i]

    def __iter__(self):
        return iter(self.devices)

    def __enter__(self) -> "Lease":
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        state = "released" if self._released else "held"
        return f"<Lease devices={[d.id for d in self.devices]} {state}>"


class DevicePool:
    """Carves the device list into disjoint partitions (gang scheduling).

    A locked free-list replaces the old non-thread-safe bump pointer:
    ``reserve(n)`` hands out the ``n`` lowest-indexed free devices as a
    ``Lease`` that can be returned individually (``lease.release()`` /
    ``pool.release(lease)``) — two threads can never be handed overlapping
    partitions, and released partitions are re-carved lowest-ids-first so
    a re-carved gang matches its predecessor's placement (which is what
    lets the shared ``ProgramCache`` skip recompilation).  ``release_all``
    is kept for tests and whole-epoch resets.

    ``reserve(n, block=True)`` waits (optionally fenced by a
    ``CancellationToken``) until ``n`` devices free up — the serving
    scheduler's admission path.
    """

    def __init__(self, devices: Optional[Sequence[jax.Device]] = None):
        self._devices = list(devices if devices is not None else jax.devices())
        self._cond = threading.Condition(threading.Lock())
        self._free = list(range(len(self._devices)))  # kept sorted
        self._leases: Dict[int, Lease] = {}           # id(lease) -> lease

    @property
    def size(self) -> int:
        return len(self._devices)

    @property
    def available(self) -> int:
        with self._cond:
            return len(self._free)

    @property
    def devices(self) -> List[jax.Device]:
        return list(self._devices)

    def _try_reserve_locked(self, n: int) -> Optional[Lease]:
        if n > len(self._free):
            return None
        take = tuple(self._free[:n])
        del self._free[:n]
        lease = Lease(self, take, tuple(self._devices[i] for i in take))
        self._leases[id(lease)] = lease
        return lease

    def reserve(self, n: int, *, block: bool = False, token: Any = None,
                poll_s: float = 0.05) -> Lease:
        """Reserve the ``n`` lowest-indexed free devices.

        Non-blocking by default: raises ``PoolExhausted`` when fewer than
        ``n`` devices are free.  ``block=True`` waits for releases,
        polling ``token.check()`` (a ``repro.faults.CancellationToken``)
        so a queued reservation honors deadlines and cancellation.
        """
        if n < 1:
            raise ValueError(f"reserve needs n >= 1, got {n}")
        if n > len(self._devices):
            raise PoolExhausted(
                f"pool exhausted: want {n}, pool only has "
                f"{len(self._devices)} devices")
        with self._cond:
            while True:
                lease = self._try_reserve_locked(n)
                if lease is not None:
                    return lease
                if not block:
                    raise PoolExhausted(
                        f"pool exhausted: want {n}, have {len(self._free)} "
                        f"free of {len(self._devices)}")
                self._cond.wait(timeout=poll_s)
                if token is not None:
                    token.check("DevicePool.reserve")

    def try_reserve(self, n: int) -> Optional[Lease]:
        """``reserve`` that returns None instead of raising on exhaustion."""
        with self._cond:
            return self._try_reserve_locked(n) if n >= 1 else None

    def release(self, lease: Lease) -> None:
        """Return one lease's devices to the free list (idempotent)."""
        with self._cond:
            if lease._released or id(lease) not in self._leases:
                return
            lease._released = True
            del self._leases[id(lease)]
            self._free = sorted(self._free + list(lease._indices))
            self._cond.notify_all()

    def release_all(self) -> None:
        """Reclaim every outstanding lease (tests / epoch reset)."""
        with self._cond:
            for lease in list(self._leases.values()):
                lease._released = True
            self._leases.clear()
            self._free = list(range(len(self._devices)))
            self._cond.notify_all()

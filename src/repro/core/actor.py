"""CylonExecutor: actor-gang resource partitioning (paper §IV-A).

Mirrors the paper's API surface:

  * ``start_executable``  — install a stateful executable on the gang,
  * ``execute_cylon``     — run a method of the installed executable,
  * ``run_cylon``         — run a free function against the env.

An executor reserves ``parallelism`` devices from a ``DevicePool`` (the
analogue of Ray placement groups / Dask worker selection) and owns a
``CylonEnv`` whose communicator + compiled-program cache persist across
submissions — the stateful pseudo-BSP environment.  Independent executors on
disjoint partitions give the paper's application-level parallelism.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

from .env import CylonEnv, DevicePool


class CylonExecutor:
    def __init__(self, parallelism: int, pool: Optional[DevicePool] = None,
                 communicator: str = "xla", axis: str = "df"):
        pool = pool or DevicePool()
        self.lease = pool.reserve(parallelism)   # a core.env.Lease
        self.devices = self.lease               # sequence view of the gang
        self.env = CylonEnv(self.devices, communicator=communicator, axis=axis)
        self._executable = None

    @property
    def parallelism(self) -> int:
        return self.env.parallelism

    def release(self) -> None:
        """Return the gang's devices to the pool (idempotent)."""
        self.lease.release()

    # -- the paper's three endpoints ------------------------------------ #
    def start_executable(self, executable_cls: Callable, *args, **kwargs):
        """Instantiate a stateful executable inside the gang."""
        self._executable = executable_cls(*args, **kwargs)
        return self._executable

    def execute_cylon(self, method_name: str, *dist_args, **kw):
        if self._executable is None:
            raise RuntimeError("no executable installed; call start_executable")
        method = getattr(self._executable, method_name)
        return self.env.run(method, *dist_args, **kw)

    def run_cylon(self, fn: Callable, *dist_args, **kw):
        """Run ``fn(ctx, *tables)`` on the gang (ctx carries the communicator)."""
        return self.env.run(fn, *dist_args, **kw)

"""CylonStore: sharing DDF results with downstream applications (paper §IV-C).

Keyed store of distributed tables.  ``get`` with a different target
parallelism triggers the repartition routine the paper calls out: rows are
re-split across the new gang.  The store is the hand-off point between data
preprocessing executors and the training application (see
``repro.data.pipeline`` / ``examples/train_e2e.py``).
"""

from __future__ import annotations

import threading
import time
from typing import Dict, Optional

import jax
import numpy as np

from .env import CylonEnv, DistTable


class CylonStore:
    def __init__(self):
        self._data: Dict[str, DistTable] = {}
        self._cv = threading.Condition()

    def put(self, key: str, table: DistTable) -> None:
        with self._cv:
            self._data[key] = table
            self._cv.notify_all()

    def get(self, key: str, target_parallelism: Optional[int] = None,
            capacity: Optional[int] = None, timeout: Optional[float] = None
            ) -> DistTable:
        """Fetch (blocking, like the paper's example) + repartition if needed."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while key not in self._data:
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"CylonStore.get({key!r}) timed out")
                self._cv.wait(timeout=remaining)
            table = self._data[key]
        if target_parallelism is None or target_parallelism == table.parallelism:
            return table
        return repartition(table, target_parallelism, capacity)

    def keys(self):
        return sorted(self._data)

    def delete(self, key: str) -> None:
        with self._cv:
            self._data.pop(key, None)


def repartition(table: DistTable, parallelism: int,
                capacity: Optional[int] = None) -> DistTable:
    """Re-split a distributed table across a different gang size.

    Host-staged (gather + rescatter): correctness-first, used at application
    boundaries where the paper stages through NFS / the object store anyway.
    """
    data = table.to_numpy()
    n = len(next(iter(data.values()))) if data else 0
    per = -(-max(n, 1) // parallelism)
    cap = capacity or max(8, -(-per // 8) * 8)
    return DistTable.from_numpy(data, parallelism, capacity=cap)

"""CylonStore + host-resident spill tables (paper §IV-C, extended for
out-of-core execution).

Two pieces live here:

* ``SpillTable`` — the host-resident representation of a distributed table:
  per-rank lists of contiguous numpy chunks (the spill format of the morsel
  executor, ``docs/out_of_core.md``).  Shuffle output rows accumulate into
  these per-destination buckets as morsels stream through a plan; the same
  structure backs ``repartition`` as a *bucketed rescatter* (no full-table
  host gather).
* ``CylonStore`` — keyed store of distributed tables shared with downstream
  applications.  ``get`` with a different target parallelism (or capacity)
  triggers the repartition routine the paper calls out.  The store is the
  hand-off point between data preprocessing executors and the training
  application (see ``repro.data.pipeline`` / ``examples/train_e2e.py``).

On accelerator backends the chunk arrays would live in pinned host memory
(``jax.device_put`` to a pinned-host layout); on the CPU stand-in they are
plain contiguous numpy buffers — the driver-visible API is identical.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Mapping, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from ..obs.trace import NULL_TRACER
from .env import DistTable


def _round8(x: int) -> int:
    return max(8, -(-int(x) // 8) * 8)


# ---------------------------------------------------------------------- #
# Host-resident spill table
# ---------------------------------------------------------------------- #
class SpillTable:
    """Host-resident spill of a distributed table: per-rank chunk lists.

    Each chunk is a dict of equal-length contiguous numpy arrays (one
    morsel's worth of rows for that rank).  Rank placement is semantic —
    chunk rows belong to that rank exactly as a ``DistTable`` shard's rows
    do — so a ``SpillTable`` is the out-of-core twin of ``DistTable`` and
    can hold arbitrarily many rows per rank at zero device memory.

    ``schema`` (name -> (dtype, trailing shape)) is fixed at construction or
    by the first ``append``, so empty ranks and zero-row tables keep their
    columns and dtypes.  ``dictionaries`` carries the sorted per-column
    dictionaries of string columns (chunks hold int32 codes), exactly like
    ``DistTable.dictionaries``; spill/respill/rescatter preserve it.
    """

    def __init__(self, parallelism: int,
                 schema: Optional[Mapping[str, Tuple[np.dtype, Tuple[int, ...]]]]
                 = None,
                 dictionaries: Optional[Mapping[str, Tuple[str, ...]]] = None):
        if parallelism < 1:
            raise ValueError(f"parallelism must be >= 1, got {parallelism}")
        self.parallelism = parallelism
        self.dictionaries: Dict[str, Tuple[str, ...]] = \
            dict(dictionaries or {})
        #: ``repro.io.IngestInfo`` when read from Parquet/CSV, else None
        self.provenance = None
        self._chunks: List[List[Dict[str, np.ndarray]]] = \
            [[] for _ in range(parallelism)]
        self._schema: Optional[Dict[str, Tuple[np.dtype, Tuple[int, ...]]]] = (
            {k: (np.dtype(d), tuple(s)) for k, (d, s) in schema.items()}
            if schema is not None else None)

    # -- schema --------------------------------------------------------- #
    @property
    def column_names(self) -> Tuple[str, ...]:
        return tuple(sorted(self._schema)) if self._schema else ()

    @property
    def schema(self):
        return dict(self._schema) if self._schema else {}

    def _check_schema(self, columns: Dict[str, np.ndarray]) -> None:
        got = {k: (v.dtype, v.shape[1:]) for k, v in columns.items()}
        if self._schema is None:
            self._schema = got
            return
        if got != self._schema:
            raise ValueError(
                f"chunk schema {got} != spill schema {self._schema}")

    # -- writing -------------------------------------------------------- #
    def append(self, rank: int, columns: Mapping[str, np.ndarray]) -> int:
        """Append one chunk of rows to ``rank``'s bucket; returns its bytes."""
        cols = {k: np.ascontiguousarray(v) for k, v in columns.items()}
        if not cols:
            raise ValueError("cannot append a chunk with no columns")
        n = len(next(iter(cols.values())))
        for k, v in cols.items():
            if len(v) != n:
                raise ValueError(f"column {k!r} length {len(v)} != {n}")
        self._check_schema(cols)
        if n == 0:
            return 0
        self._chunks[rank].append(cols)
        return sum(v.nbytes for v in cols.values())

    # -- reading -------------------------------------------------------- #
    def rank_chunks(self, rank: int) -> Tuple[Dict[str, np.ndarray], ...]:
        return tuple(self._chunks[rank])

    def rank_rows(self, rank: int) -> int:
        return sum(len(next(iter(c.values()))) for c in self._chunks[rank])

    def total_rows(self) -> int:
        return sum(self.rank_rows(r) for r in range(self.parallelism))

    def nbytes(self) -> int:
        return sum(v.nbytes for chunks in self._chunks
                   for c in chunks for v in c.values())

    def _empty_cols(self) -> Dict[str, np.ndarray]:
        return {k: np.zeros((0,) + s, d)
                for k, (d, s) in (self._schema or {}).items()}

    def rank_concat(self, rank: int) -> Dict[str, np.ndarray]:
        chunks = self._chunks[rank]
        if not chunks:
            return self._empty_cols()
        return {k: np.concatenate([c[k] for c in chunks], axis=0)
                for k in chunks[0]}

    def to_numpy(self, decode: bool = True, nulls: str = "pandas"
                 ) -> Dict[str, np.ndarray]:
        """Gather valid rows from every rank in rank order (driver side).

        ``decode=True`` (default) maps dictionary-encoded columns back to
        numpy string arrays; ``decode=False`` returns the raw codes.
        ``nulls="pandas"`` (default) re-materializes ``__m_*`` validity
        masks as NaN / ``None``; ``nulls="mask"`` returns the raw physical
        layout (canonical-zero data + bool masks) for bit-identity checks."""
        if nulls not in ("pandas", "mask"):
            raise ValueError(f"nulls must be 'pandas' or 'mask', got {nulls!r}")
        parts = [self.rank_concat(r) for r in range(self.parallelism)]
        names = self.column_names
        if not names:
            return {}
        out = {k: np.concatenate([p[k] for p in parts], axis=0)
               for k in names}
        if decode and self.dictionaries:
            from ..dataframe.schema import decode_columns
            out = decode_columns(out, self.dictionaries)
        if nulls == "pandas":
            from ..nulls import apply_null_columns
            out = apply_null_columns(out)
        return out

    def num_morsels(self, morsel_rows: int) -> int:
        """Morsels needed to stream the widest rank at ``morsel_rows`` each."""
        widest = max(self.rank_rows(r) for r in range(self.parallelism))
        return max(1, -(-widest // max(1, morsel_rows)))

    # -- constructors ---------------------------------------------------- #
    @classmethod
    def from_numpy(cls, data: Mapping[str, np.ndarray], parallelism: int,
                   chunk_rows: Optional[int] = None) -> "SpillTable":
        """Block-distribute host rows over ``parallelism`` rank buckets,
        optionally pre-chunked into ``chunk_rows``-row pieces.  String
        columns are dictionary-encoded (chunks hold int32 codes)."""
        from ..dataframe.schema import encode_columns
        from ..nulls import extract_null_columns
        data = {k: np.asarray(v) for k, v in data.items()}
        if not data:
            raise ValueError("need at least one column")
        data = extract_null_columns(data)
        data, dicts = encode_columns(data)
        n = len(next(iter(data.values())))
        per = -(-n // parallelism) if n else 0
        out = cls(parallelism,
                  schema={k: (v.dtype, v.shape[1:]) for k, v in data.items()},
                  dictionaries=dicts)
        for r in range(parallelism):
            block = {k: v[r * per:(r + 1) * per] for k, v in data.items()}
            rows = len(next(iter(block.values())))
            step = chunk_rows or max(rows, 1)
            for s in range(0, rows, step):
                out.append(r, {k: v[s:s + step] for k, v in block.items()})
        return out

    @classmethod
    def from_dist(cls, table: DistTable) -> "SpillTable":
        """Spill a device-resident DistTable: one host chunk per rank."""
        p, cap = table.parallelism, table.capacity
        counts = np.asarray(table.row_counts)
        host = {k: np.asarray(v).reshape((p, cap) + v.shape[1:])
                for k, v in table.columns.items()}
        out = cls(p, schema={k: (v.dtype, v.shape[2:])
                             for k, v in host.items()},
                  dictionaries=table.dictionaries)
        out.provenance = table.provenance
        for r in range(p):
            c = int(counts[r])
            if c:
                out.append(r, {k: v[r, :c] for k, v in host.items()})
        return out


# ---------------------------------------------------------------------- #
# Checkpoints: spill buckets as durable replay points
# ---------------------------------------------------------------------- #
class Checkpoint:
    """A schema-stamped, reference-counted guard over a ``SpillTable``.

    Comm-boundary spills are the natural checkpoints of the morsel executor
    (the boundary-externalization idea): a segment's input spill is
    read-only while the segment streams, so a failed segment attempt can
    replay from it verbatim.  The checkpoint makes that contract explicit:

    * ``stamp`` — a cheap content stamp (schema, dictionaries, per-rank
      row counts, total bytes) taken at creation; ``validate()`` recomputes
      it before every replay and refuses a mutated or truncated spill.
    * reference counting — ``retain``/``release`` keep the checkpoint (and
      the spill it guards) alive across failed attempts; it is only
      considered consumed when the owning segment commits.  ``released``
      checkpoints refuse further validation, so a stale replay is an error
      rather than silent corruption.
    """

    def __init__(self, spill: SpillTable):
        self.spill = spill
        self._refs = 1
        self.stamp = self._stamp(spill)

    @staticmethod
    def _stamp(spill: SpillTable) -> Tuple:
        return (
            tuple(sorted((k, str(d), tuple(s))
                         for k, (d, s) in spill.schema.items())),
            tuple(sorted((k, tuple(v))
                         for k, v in spill.dictionaries.items())),
            tuple(spill.rank_rows(r) for r in range(spill.parallelism)),
            spill.nbytes(),
        )

    @property
    def refs(self) -> int:
        return self._refs

    @property
    def released(self) -> bool:
        return self._refs <= 0

    def retain(self) -> "Checkpoint":
        if self.released:
            raise RuntimeError("cannot retain a released checkpoint")
        self._refs += 1
        return self

    def release(self) -> None:
        """Drop one reference; at zero the checkpoint is consumed (the
        spill itself is NOT freed — it may be the caller's input data)."""
        if self._refs > 0:
            self._refs -= 1

    def validate(self) -> SpillTable:
        """Re-stamp the spill and return it for replay; raises on drift."""
        if self.released:
            raise RuntimeError(
                "checkpoint was released (segment already committed); "
                "replaying from it would read consumed state")
        now = self._stamp(self.spill)
        if now != self.stamp:
            raise RuntimeError(
                f"checkpoint validation failed: spill changed since the "
                f"checkpoint was taken (rows {self.stamp[2]} -> {now[2]}, "
                f"bytes {self.stamp[3]} -> {now[3]})")
        return self.spill


def _route_chunks(spill: SpillTable, parallelism: int
                  ) -> List[List[Dict[str, np.ndarray]]]:
    """Block-route every chunk's rows to per-destination bucket lists by
    global offset (each chunk slices across at most a few destinations).
    The single routing loop behind both ``respill`` and ``rescatter``."""
    n = spill.total_rows()
    per = -(-max(n, 1) // parallelism)
    buckets: List[List[Dict[str, np.ndarray]]] = [[] for _ in range(parallelism)]
    g = 0
    for r in range(spill.parallelism):
        for chunk in spill.rank_chunks(r):
            m = len(next(iter(chunk.values())))
            start = 0
            while start < m:
                dest = min((g + start) // per, parallelism - 1)
                take = min(m - start, (dest + 1) * per - (g + start))
                buckets[dest].append(
                    {k: v[start:start + take] for k, v in chunk.items()})
                start += take
            g += m
    return buckets


def respill(spill: SpillTable, parallelism: int,
            tracer=NULL_TRACER) -> SpillTable:
    """Re-bucket a SpillTable to a different gang size, chunk by chunk.

    Host-only (no device materialization — the spill may not fit a
    ``DistTable``).  ``tracer`` records a span with rows/bytes moved."""
    if parallelism == spill.parallelism:
        return spill
    with tracer.span("respill", "spill", from_p=spill.parallelism,
                     to_p=parallelism, rows=spill.total_rows(),
                     bytes=spill.nbytes()):
        out = SpillTable(parallelism, schema=spill.schema or None,
                         dictionaries=spill.dictionaries)
        out.provenance = spill.provenance
        for dest, pieces in enumerate(_route_chunks(spill, parallelism)):
            for piece in pieces:
                out.append(dest, piece)
    return out


def respill_routed(spill: SpillTable, dest_of,
                   tracer=NULL_TRACER) -> SpillTable:
    """Re-route a SpillTable's rows by an arbitrary per-row rule.

    ``dest_of(cols: Dict[str, np.ndarray]) -> np.ndarray[int]`` maps one
    chunk's columns to destination ranks; the routing itself stays a
    host-only chunk-by-chunk pass like ``respill`` (peak extra memory is
    one chunk).  This is the adaptive layer's merge primitive: salted
    groupby partials re-home by ``hash % p``, and splitter-refreshed sort
    output re-homes by the final splitters, without materializing the
    spill on device (``docs/adaptive.md``).
    """
    with tracer.span("respill-routed", "spill", p=spill.parallelism,
                     rows=spill.total_rows(), bytes=spill.nbytes()):
        out = SpillTable(spill.parallelism, schema=spill.schema or None,
                         dictionaries=spill.dictionaries)
        out.provenance = spill.provenance
        for r in range(spill.parallelism):
            for chunk in spill.rank_chunks(r):
                dest = np.asarray(dest_of(chunk))
                if dest.ndim != 1 or len(dest) != len(next(iter(chunk.values()))):
                    raise ValueError("dest_of must return one rank per row")
                for d in np.unique(dest):
                    sel = dest == d
                    out.append(int(d),
                               {k: v[sel] for k, v in chunk.items()})
    return out


# ---------------------------------------------------------------------- #
# Bucketed rescatter (replaces the host-gather repartition)
# ---------------------------------------------------------------------- #
def rescatter(spill: SpillTable, parallelism: int,
              capacity: Optional[int] = None,
              tracer=NULL_TRACER) -> DistTable:
    """SpillTable -> DistTable over a (possibly different) gang size.

    Rows are routed chunk-by-chunk into per-destination host buckets by
    their global block index — no rank's data is ever concatenated into a
    single full-table host array, so peak extra host memory is one
    destination shard, not the whole table.  ``tracer`` records the H2D
    volume as an instant event.
    """
    tracer.instant("rescatter", "transfer", to_p=parallelism,
                   rows=spill.total_rows(), bytes=spill.nbytes())
    n = spill.total_rows()
    per = -(-max(n, 1) // parallelism)
    cap = capacity if capacity is not None else _round8(per)
    if per > cap and n > 0:
        raise ValueError(f"rows/shard {per} exceeds capacity {cap}")
    schema = spill.schema
    buckets = _route_chunks(spill, parallelism)
    cols: Dict[str, jnp.ndarray] = {}
    counts = np.zeros((parallelism,), np.int32)
    for name, (dtype, trail) in schema.items():
        buf = np.zeros((parallelism, cap) + trail, dtype)
        for d in range(parallelism):
            pos = 0
            for piece in buckets[d]:
                v = piece[name]
                buf[d, pos:pos + len(v)] = v
                pos += len(v)
            counts[d] = pos
        cols[name] = jnp.asarray(
            buf.reshape((parallelism * cap,) + trail))
    return DistTable(cols, jnp.asarray(counts), cap,
                     dict(spill.dictionaries),
                     provenance=spill.provenance)


def repartition(table: Union[DistTable, SpillTable], parallelism: int,
                capacity: Optional[int] = None) -> DistTable:
    """Re-split a distributed table across a different gang size.

    Host-staged via the per-destination spill buckets (``rescatter``), used
    at application boundaries where the paper stages through NFS / the
    object store anyway.  An explicit ``capacity`` — including ``0`` — is
    honored verbatim (and validated), never silently replaced.
    """
    spill = table if isinstance(table, SpillTable) else SpillTable.from_dist(table)
    return rescatter(spill, parallelism, capacity)


class CylonStore:
    def __init__(self):
        self._data: Dict[str, Union[DistTable, SpillTable]] = {}
        self._cv = threading.Condition()

    def put(self, key: str, table: Union[DistTable, SpillTable]) -> None:
        with self._cv:
            self._data[key] = table
            self._cv.notify_all()

    def get(self, key: str, target_parallelism: Optional[int] = None,
            capacity: Optional[int] = None, timeout: Optional[float] = None
            ) -> Union[DistTable, SpillTable]:
        """Fetch (blocking, like the paper's example) + repartition if needed."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while key not in self._data:
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"CylonStore.get({key!r}) timed out")
                self._cv.wait(timeout=remaining)
            table = self._data[key]
        same_p = (target_parallelism is None
                  or target_parallelism == table.parallelism)
        same_cap = (capacity is None
                    or (isinstance(table, DistTable)
                        and capacity == table.capacity))
        if same_p and same_cap:
            return table
        return repartition(
            table,
            table.parallelism if target_parallelism is None
            else target_parallelism,
            capacity)

    def keys(self):
        return sorted(self._data)

    def delete(self, key: str) -> None:
        with self._cv:
            self._data.pop(key, None)

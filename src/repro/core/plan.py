"""Logical plan builder + execution entry point.

The paper's BSP execution *implicitly* coalesces every local sub-operator
between two communication boundaries (§III-B1).  The builder below records
the operator DAG; optimization and lowering live in ``repro.planner``:

  * ``repro.planner.logical``  — typed plan with partitioning / cardinality
                                 / liveness properties,
  * ``repro.planner.rules``    — shuffle elision, join-side selection,
                                 predicate & projection pushdown, pre-agg,
  * ``repro.planner.physical`` — stage DAG lowering + structural-fingerprint
                                 compile cache,
  * ``repro.planner.explain``  — EXPLAIN rendering.

``execute`` keeps the paper's three execution modes:

  * ``bsp``        — entire plan compiled into ONE shard_map program
                     (CylonFlow execution: one dispatch, XLA fuses all local
                     work between collectives; communicator state persists).
  * ``bsp_staged`` — one dispatch per *stage* (local chains still fused, but
                     a driver round-trip at every communication boundary).
                     Quantifies the coalescing gain alone.
  * ``amt``        — Dask-DDF-style baseline: one dispatch per sub-operator
                     and shuffles implemented as allgather-then-select (the
                     "generic data-sharing/object-store" pattern §III-B2 —
                     every rank receives all rows and keeps its own), i.e.
                     O(p·data) communication instead of O(data).

Used by ``benchmarks/bench_pipeline.py`` to reproduce the paper's Fig 9.
"""

from __future__ import annotations

import dataclasses
import itertools
import warnings
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Union

import jax

from ..dataframe.table import Table
from ..expr import Expr, OpaqueExpr, ensure_expr

_ids = itertools.count()


@dataclasses.dataclass
class Node:
    op: str
    inputs: List["Node"]
    params: Dict[str, Any]
    nid: int = dataclasses.field(default_factory=lambda: next(_ids))

    #: ops that require communication (stage boundaries)
    COMM_OPS = ("join", "groupby", "sort", "shuffle")


class Plan:
    """Chainable logical-plan builder over named input tables."""

    def __init__(self, node: Node):
        self.node = node

    # -- sources -------------------------------------------------------- #
    @staticmethod
    def scan(name: str) -> "Plan":
        return Plan(Node("scan", [], {"name": name}))

    # -- local ops ------------------------------------------------------ #
    def add_scalar(self, value, cols: Optional[Sequence[str]] = None) -> "Plan":
        return Plan(Node("add_scalar", [self.node], {"value": value, "cols": cols}))

    def filter(self, pred: Union[Expr, Callable[[Table], jax.Array]],
               cols: Optional[Sequence[str]] = None) -> "Plan":
        """Keep rows where the boolean expression holds.

        ``pred`` should be a typed column expression
        (``repro.expr.col("v") > 0``), which gives the optimizer exact
        column liveness (pushdown past joins, dead-column elimination) and
        the compile cache a value-based key.  Passing a callable
        ``fn(Table) -> bool array`` is **deprecated**: it is wrapped in an
        ``OpaqueExpr`` pinning the declared ``cols`` (``None`` = unknown,
        which blocks pushdown past schema-changing boundaries).
        """
        if isinstance(pred, Expr):
            if cols is not None:
                raise TypeError(
                    "cols= is only for the deprecated callable form; typed "
                    "expressions carry their own column set")
            expr = pred
        else:
            warnings.warn(
                "Plan.filter(callable) is deprecated; pass a typed "
                "expression (repro.expr.col(...) > ...) so the optimizer "
                "sees exact column liveness and the compile cache gets a "
                "value-based key", DeprecationWarning, stacklevel=2)
            expr = OpaqueExpr(pred, cols)
        return Plan(Node("filter", [self.node], {"expr": expr}))

    def project(self, cols: Sequence[str]) -> "Plan":
        return Plan(Node("project", [self.node], {"cols": tuple(cols)}))

    def with_columns(self, exprs: Mapping[str, Union[Expr, Any]]) -> "Plan":
        """Add or replace columns: ``{name: expression}``.

        All expressions read the *input* table (simultaneous assignment,
        like ``pandas.DataFrame.assign``); bare scalars auto-lift to
        literals and broadcast to full columns.
        """
        return Plan(Node("with_columns", [self.node],
                         {"exprs": {name: ensure_expr(e)
                                    for name, e in exprs.items()}}))

    def map_columns(self, fn, cols: Sequence[str]) -> "Plan":
        """**Deprecated**: apply ``fn`` to each named column.  Rewritten to
        ``with_columns`` over per-column ``OpaqueExpr`` wrappers; prefer
        typed expressions (``with_columns({"v": col("v") * 2})``)."""
        warnings.warn(
            "Plan.map_columns is deprecated; use with_columns with typed "
            "expressions (repro.expr.col) so the optimizer and compile "
            "cache see the computation", DeprecationWarning, stacklevel=2)
        exprs = {c: OpaqueExpr(lambda t, _f=fn, _c=c: _f(t.columns[_c]),
                               cols=(c,), label=getattr(fn, "__name__", "fn"))
                 for c in cols}
        return Plan(Node("with_columns", [self.node], {"exprs": exprs}))

    # -- communication ops ---------------------------------------------- #
    def join(self, other: "Plan", on: str, **kw) -> "Plan":
        return Plan(Node("join", [self.node, other.node], {"on": on, **kw}))

    def groupby(self, keys: Sequence[str], aggs: Mapping[str, Sequence[str]],
                **kw) -> "Plan":
        return Plan(Node("groupby", [self.node],
                         {"keys": tuple(keys), "aggs": dict(aggs), **kw}))

    def sort(self, by: Sequence[str], **kw) -> "Plan":
        return Plan(Node("sort", [self.node], {"by": tuple(by), **kw}))

    def shuffle(self, key_cols: Sequence[str], **kw) -> "Plan":
        return Plan(Node("shuffle", [self.node], {"key_cols": tuple(key_cols), **kw}))

    # -- introspection --------------------------------------------------- #
    def topo(self) -> List[Node]:
        seen, order = set(), []

        def visit(n: Node):
            if n.nid in seen:
                return
            seen.add(n.nid)
            for i in n.inputs:
                visit(i)
            order.append(n)
        visit(self.node)
        return order

    def num_stages(self) -> int:
        """1 + number of communication boundaries (unoptimized count; see
        ``planner.compile_plan(...).num_stages`` for the optimized one)."""
        return 1 + sum(1 for n in self.topo() if n.op in Node.COMM_OPS)

    def explain(self, tables: Optional[Mapping[str, Any]] = None,
                optimize: bool = True, mode: str = "bsp",
                shuffle_impl: str = "radix", a2a_chunks: int = 1,
                morsel_rows: Optional[int] = None) -> str:
        from ..planner import explain as planner_explain
        return planner_explain(self, tables, optimize_plan=optimize, mode=mode,
                               shuffle_impl=shuffle_impl,
                               a2a_chunks=a2a_chunks, morsel_rows=morsel_rows)


def execute(plan: Plan, env, tables: Dict[str, Any], mode: str = "bsp",
            optimize: bool = True, collect_stats: bool = False,
            shuffle_impl: str = "radix", a2a_chunks: int = 1,
            morsel_rows: Optional[int] = None, trace: Any = None,
            retries: Any = None, timeout: Any = None,
            overflow: Any = None, faults: Any = None,
            adaptive: Any = None, **morsel_kw):
    """Execute a plan against DistTables.  Returns a DistTable, or
    ``(DistTable, planner.ExecStats)`` with ``collect_stats=True``.

    ``env`` is a ``core.env.CylonEnv``; mode in {"bsp", "bsp_staged", "amt"}.
    ``optimize=False`` runs the plan exactly as written (the unoptimized
    baseline measured by ``benchmarks/bench_pipeline.py``) — except
    dictionary resolution (string-literal lowering + recode insertion on
    dictionary-mismatched joins, ``planner.dictionary``), which is a
    correctness pass and always runs; result dictionaries ride back on
    ``DistTable.dictionaries`` (see ``docs/data_model.md``).
    ``shuffle_impl`` ("radix" sort-free | "sorted" baseline) and
    ``a2a_chunks`` (all-to-all pipeline depth) are the plan-wide shuffle
    defaults; per-node params override (see ``docs/shuffle.md``).

    ``morsel_rows`` selects out-of-core morsel execution: ``tables`` may then
    hold host-resident data (``core.SpillTable`` / numpy dicts) larger than
    device capacity, streamed through the compiled stage DAG in
    ``morsel_rows``-row morsels; the result is a ``SpillTable`` (see
    ``docs/out_of_core.md``).  Extra ``morsel_kw`` (``capacity_factor``,
    ``samples``, ``debug_overflow``) are forwarded to the morsel executor.

    ``trace`` turns on query tracing (``docs/observability.md``): ``True``
    builds a fresh ``repro.obs.Tracer``, an existing ``Tracer`` is used
    as-is, and ``None`` consults the ``REPRO_TRACE`` env var.  The finished
    ``QueryTrace`` is retrievable via ``repro.obs.last_trace()`` (or from
    the tracer you passed).  Tracing is driver-side only — it never changes
    what gets compiled.

    Fault tolerance (``docs/fault_tolerance.md``): ``retries`` (int or
    ``repro.faults.RetryPolicy``) replays failed dispatch units with
    exponential backoff; ``timeout`` (seconds or a ``CancellationToken``)
    deadlines the whole query; ``overflow`` (``raise | warn | degrade``,
    default ``degrade``) governs capacity-pressure row drops; ``faults``
    arms a deterministic fault-injection plan (``None`` consults the
    ``REPRO_FAULTS`` env var).

    ``adaptive`` (None | bool | dict | ``repro.adapt.AdaptiveConfig``)
    gates runtime skew mitigation — hot-key salting, splitter refresh,
    morsel autotuning (``docs/adaptive.md``).  Default on; data with no
    detected skew executes exactly the ``adaptive=False`` programs.
    """
    from ..obs.trace import resolve_tracer
    from ..planner import compile_plan, run_physical
    tracer = resolve_tracer(trace)
    pplan = compile_plan(plan, tables, optimize_plan=optimize)
    with tracer.span("query", "query", mode=mode,
                     fingerprint=pplan.fingerprint,
                     stages=pplan.num_stages, shuffles=pplan.num_shuffles):
        out = run_physical(pplan, env, tables, mode,
                           collect_stats=collect_stats,
                           shuffle_impl=shuffle_impl, a2a_chunks=a2a_chunks,
                           morsel_rows=morsel_rows, tracer=tracer,
                           retries=retries, timeout=timeout,
                           overflow=overflow, faults=faults,
                           adaptive=adaptive, **morsel_kw)
    if tracer.enabled:
        tracer.finish()
    return out

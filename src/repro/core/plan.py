"""Logical plan + coalescing optimizer + execution modes.

The paper's BSP execution *implicitly* coalesces every local sub-operator
between two communication boundaries (§III-B1); AMT systems need an explicit
plan optimizer to approximate that (Spark Tungsten).  Here the plan makes the
boundary structure explicit so we can run the same pipeline three ways:

  * ``bsp``        — entire plan compiled into ONE shard_map program
                     (CylonFlow execution: one dispatch, XLA fuses all local
                     work between collectives; communicator state persists).
  * ``bsp_staged`` — one dispatch per *stage* (local chains still fused, but
                     a driver round-trip at every communication boundary).
                     Quantifies the coalescing gain alone.
  * ``amt``        — Dask-DDF-style baseline: one dispatch per sub-operator
                     and shuffles implemented as allgather-then-select (the
                     "generic data-sharing/object-store" pattern §III-B2 —
                     every rank receives all rows and keeps its own), i.e.
                     O(p·data) communication instead of O(data).

Used by ``benchmarks/bench_pipeline.py`` to reproduce the paper's Fig 9.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..comm import Communicator
from ..dataframe import ops_local
from ..dataframe.groupby import groupby as df_groupby
from ..dataframe.join import join as df_join
from ..dataframe.ops_local import hash_columns
from ..dataframe.shuffle import shuffle as df_shuffle
from ..dataframe.sort import sort as df_sort
from ..dataframe.table import Table

_ids = itertools.count()


@dataclasses.dataclass
class Node:
    op: str
    inputs: List["Node"]
    params: Dict[str, Any]
    nid: int = dataclasses.field(default_factory=lambda: next(_ids))

    #: ops that require communication (stage boundaries)
    COMM_OPS = ("join", "groupby", "sort", "shuffle")


class Plan:
    """Chainable logical-plan builder over named input tables."""

    def __init__(self, node: Node):
        self.node = node

    # -- sources -------------------------------------------------------- #
    @staticmethod
    def scan(name: str) -> "Plan":
        return Plan(Node("scan", [], {"name": name}))

    # -- local ops ------------------------------------------------------ #
    def add_scalar(self, value, cols: Optional[Sequence[str]] = None) -> "Plan":
        return Plan(Node("add_scalar", [self.node], {"value": value, "cols": cols}))

    def filter(self, pred: Callable[[Table], jax.Array]) -> "Plan":
        return Plan(Node("filter", [self.node], {"pred": pred}))

    def project(self, cols: Sequence[str]) -> "Plan":
        return Plan(Node("project", [self.node], {"cols": tuple(cols)}))

    def map_columns(self, fn, cols: Sequence[str]) -> "Plan":
        return Plan(Node("map_columns", [self.node], {"fn": fn, "cols": tuple(cols)}))

    # -- communication ops ---------------------------------------------- #
    def join(self, other: "Plan", on: str, **kw) -> "Plan":
        return Plan(Node("join", [self.node, other.node], {"on": on, **kw}))

    def groupby(self, keys: Sequence[str], aggs: Mapping[str, Sequence[str]],
                **kw) -> "Plan":
        return Plan(Node("groupby", [self.node],
                         {"keys": tuple(keys), "aggs": dict(aggs), **kw}))

    def sort(self, by: Sequence[str], **kw) -> "Plan":
        return Plan(Node("sort", [self.node], {"by": tuple(by), **kw}))

    def shuffle(self, key_cols: Sequence[str], **kw) -> "Plan":
        return Plan(Node("shuffle", [self.node], {"key_cols": tuple(key_cols), **kw}))

    # -- introspection --------------------------------------------------- #
    def topo(self) -> List[Node]:
        seen, order = set(), []

        def visit(n: Node):
            if n.nid in seen:
                return
            seen.add(n.nid)
            for i in n.inputs:
                visit(i)
            order.append(n)
        visit(self.node)
        return order

    def num_stages(self) -> int:
        """1 + number of communication boundaries (coalesced stage count)."""
        return 1 + sum(1 for n in self.topo() if n.op in Node.COMM_OPS)


# ---------------------------------------------------------------------- #
# Node evaluation (shared by all modes; runs inside shard_map)
# ---------------------------------------------------------------------- #
def _eval_node(node: Node, comm: Communicator, values: Dict[int, Table],
               tables: Dict[str, Table], shuffle_mode: str) -> Table:
    p = node.params
    ins = [values[i.nid] for i in node.inputs]
    if node.op == "scan":
        return tables[p["name"]]
    if node.op == "add_scalar":
        return ops_local.add_scalar(ins[0], p["value"], p["cols"])
    if node.op == "filter":
        return ops_local.filter_rows(ins[0], p["pred"])
    if node.op == "project":
        return ins[0].select(p["cols"])
    if node.op == "map_columns":
        return ops_local.map_columns(ins[0], p["fn"], p["cols"])

    kw = {k: v for k, v in p.items()
          if k not in ("on", "keys", "aggs", "by", "key_cols")}
    if shuffle_mode == "allgather":
        kw["shuffle_fn"] = _shuffle_allgather
    if node.op == "join":
        out, *_ = _join(ins[0], ins[1], comm, p["on"], **kw)
        return out
    if node.op == "groupby":
        out, _ = _groupby(ins[0], comm, p["keys"], p["aggs"], **kw)
        return out
    if node.op == "sort":
        out, _ = _sort(ins[0], comm, p["by"], **kw)
        return out
    if node.op == "shuffle":
        fn = kw.pop("shuffle_fn", df_shuffle)
        out, _ = fn(ins[0], comm, key_cols=p["key_cols"], **kw)
        return out
    raise ValueError(node.op)


# Wrappers letting the AMT baseline swap the shuffle implementation.
def _join(left, right, comm, on, shuffle_fn=df_shuffle, **kw):
    l_sh, l_st = shuffle_fn(left, comm, key_cols=[on], **{k: v for k, v in kw.items()
                                                          if k != "out_capacity"})
    r_sh, r_st = shuffle_fn(right, comm, key_cols=[on], **{k: v for k, v in kw.items()
                                                           if k != "out_capacity"})
    return (ops_local.join_local(l_sh, r_sh, on,
                                 out_capacity=kw.get("out_capacity")), l_st, r_st)


def _groupby(table, comm, keys, aggs, shuffle_fn=df_shuffle, **kw):
    if shuffle_fn is df_shuffle:
        return df_groupby(table, comm, keys, aggs, **kw)
    # AMT path: no pre-aggregation (Dask groupby ships raw rows by default
    # for nunique-style aggs; we keep pre-agg OFF to model task granularity)
    shuffled, st = shuffle_fn(table, comm, key_cols=list(keys),
                              **{k: v for k, v in kw.items() if k != "pre_aggregate"})
    from ..dataframe.groupby import _normalize
    physical, post = _normalize(aggs)
    final = ops_local.groupby_local(shuffled, keys, physical)
    out_cols = {k: final.columns[k] for k in keys}
    for out_name, kind, src in post:
        if kind == "copy":
            out_cols[out_name] = final.columns[src]
        else:
            s = final.columns[f"{src}_sum"]
            c = final.columns[f"{src}_count"]
            out_cols[out_name] = jnp.where(c > 0, s / jnp.maximum(c, 1).astype(s.dtype),
                                           jnp.zeros((), s.dtype))
    return Table(out_cols, final.row_count), st


def _sort(table, comm, by, shuffle_fn=df_shuffle, **kw):
    if shuffle_fn is df_shuffle:
        return df_sort(table, comm, by, **kw)
    from ..dataframe.sort import _sample_splitters
    key = table.columns[by[0]]
    splitters = _sample_splitters(key, table.row_count, comm, kw.pop("samples", 64))
    dest = jnp.searchsorted(splitters, key, side="right").astype(jnp.int32)
    shuffled, st = shuffle_fn(table, comm, dest=dest, **kw)
    return ops_local.sort_local(shuffled, by), st


# ---------------------------------------------------------------------- #
# AMT-baseline shuffle: allgather-then-select (object-store pattern)
# ---------------------------------------------------------------------- #
def _shuffle_allgather(table: Table, comm: Communicator,
                       key_cols=None, dest=None, out_capacity=None, **_):
    """Every rank receives ALL rows and keeps those hashed to it.

    This models Dask partd / Ray object-store data sharing: data is published
    globally rather than routed, costing O(p·rows) bandwidth per rank.
    """
    p = comm.size()
    rank = comm.rank()
    cap = table.capacity
    out_cap = out_capacity or cap
    valid = table.valid_mask()
    if dest is None:
        h = hash_columns(table, key_cols)
        dest = (h % jnp.uint32(p)).astype(jnp.int32)
    dest = jnp.where(valid, dest, p)

    gathered_dest = comm.all_gather(dest).reshape(-1)            # (p*cap,)
    keep = gathered_dest == rank
    order = jnp.argsort(jnp.where(keep, 0, 1), stable=True)[:out_cap]
    new_count = jnp.minimum(jnp.sum(keep), out_cap).astype(jnp.int32)
    cols = {}
    for name, col in table.columns.items():
        g = comm.all_gather(col).reshape((-1,) + col.shape[1:])
        cols[name] = jnp.take(g, order, axis=0)
    from ..dataframe.shuffle import ShuffleStats
    sent = jax.ops.segment_sum(jnp.ones((cap,), jnp.int32), dest, num_segments=p + 1)[:p]
    stats = ShuffleStats(sent, sent, jnp.asarray(0, jnp.int32),
                         jnp.maximum(jnp.sum(keep) - out_cap, 0))
    return Table(cols, new_count).mask_padding(), stats


# ---------------------------------------------------------------------- #
# Execution modes
# ---------------------------------------------------------------------- #
def execute(plan: Plan, env, tables: Dict[str, Any], mode: str = "bsp"):
    """Execute a plan against DistTables. Returns a DistTable.

    ``env`` is a ``core.env.CylonEnv``; mode in {"bsp", "bsp_staged", "amt"}.
    """
    order = plan.topo()
    names = sorted({n.params["name"] for n in order if n.op == "scan"})
    ins = [tables[name] for name in names]

    if mode == "bsp":
        def prog(ctx, *local_tables):
            tmap = dict(zip(names, local_tables))
            values: Dict[int, Table] = {}
            for node in order:
                values[node.nid] = _eval_node(node, ctx.comm, values, tmap, "direct")
            return values[plan.node.nid]
        return env.run(prog, *ins, key=("bsp", plan.node.nid, env.communicator_name))

    if mode in ("bsp_staged", "amt"):
        shuffle_mode = "direct" if mode == "bsp_staged" else "allgather"
        values: Dict[int, Any] = {}
        for node in order:  # one driver dispatch per node
            node_inputs = [values[i.nid] for i in node.inputs]

            def prog(ctx, *local_ins, _node=node):
                tmap = {}
                vals = {i.nid: t for i, t in zip(_node.inputs, local_ins)}
                if _node.op == "scan":
                    tmap[_node.params["name"]] = local_ins[0]
                    vals = {}
                return _eval_node(_node, ctx.comm, vals, tmap, shuffle_mode)

            if node.op == "scan":
                node_inputs = [tables[node.params["name"]]]
            out = env.run(prog, *node_inputs,
                          key=(mode, node.nid, env.communicator_name))
            jax.block_until_ready(out.row_counts)  # task-completion barrier
            values[node.nid] = out
        return values[plan.node.nid]

    raise ValueError(f"unknown mode {mode!r}")

"""The assigned input-shape cells + per-arch eligibility + input specs.

Every cell is lowered from ``ShapeDtypeStruct`` stand-ins — weak-type
correct, shardable, zero device allocation (the dry-run never materializes
a 34B-parameter model on this CPU container).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_config
from ..models.config import SHAPES, ModelConfig
from ..configs.llava_next_34b import PATCHES_LARGE, PATCHES_SMALL


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    kind: str              # train | prefill | decode
    global_batch: int
    seq_len: int
    eligible: bool
    skip_reason: Optional[str] = None


def cell(arch: str, shape: str) -> Cell:
    cfg = get_config(arch)
    info = SHAPES[shape]
    eligible, reason = True, None
    if shape == "long_500k" and not cfg.sub_quadratic:
        eligible = False
        reason = ("pure full-attention decoder: 512k dense-KV decode is "
                  "defined by the brief to require sub-quadratic attention "
                  "(see DESIGN.md §7)")
    return Cell(arch, shape, info["kind"], info["global_batch"],
                info["seq_len"], eligible, reason)


def all_cells() -> List[Cell]:
    return [cell(a, s) for a in ARCHS for s in SHAPES]


def vlm_patches(cfg: ModelConfig, seq_len: int) -> int:
    return PATCHES_SMALL if seq_len <= 4096 else PATCHES_LARGE


def train_batch_specs(cfg: ModelConfig, global_batch: int, seq_len: int
                      ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one training batch."""
    b, s = global_batch, seq_len
    i32 = jnp.int32
    if cfg.family == "audio":
        shape = (b, s, cfg.num_codebooks)
        return {"tokens": jax.ShapeDtypeStruct(shape, i32),
                "labels": jax.ShapeDtypeStruct(shape, i32)}
    if cfg.family == "vlm":
        p = vlm_patches(cfg, s)
        return {
            "patch_embeds": jax.ShapeDtypeStruct((b, p, cfg.d_model),
                                                 jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((b, s - p), i32),
            "labels": jax.ShapeDtypeStruct((b, s - p), i32),
        }
    return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32)}


def prefill_batch_specs(cfg: ModelConfig, global_batch: int, seq_len: int
                        ) -> Dict[str, jax.ShapeDtypeStruct]:
    specs = train_batch_specs(cfg, global_batch, seq_len)
    specs.pop("labels")
    return specs


def decode_token_specs(cfg: ModelConfig, global_batch: int
                       ) -> Tuple[jax.ShapeDtypeStruct, jax.ShapeDtypeStruct]:
    """(tokens, pos) stand-ins for one decode step."""
    i32 = jnp.int32
    if cfg.family == "audio":
        tok = jax.ShapeDtypeStruct((global_batch, 1, cfg.num_codebooks), i32)
    else:
        tok = jax.ShapeDtypeStruct((global_batch, 1), i32)
    return tok, jax.ShapeDtypeStruct((global_batch,), i32)

"""Batched serving driver (smoke-scale on CPU; production mesh via dryrun).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --batch 4 --prompt-len 16 --max-new 24
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config, get_smoke_config
from ..models import transformer
from ..models.layers import NO_SHARDING
from ..serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "vlm":
        raise SystemExit("serve driver covers token-LM archs")
    key = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(key, cfg, jnp.float32)
    cache_len = args.prompt_len + args.max_new
    engine = ServeEngine(cfg, params, cache_len)

    rng = np.random.default_rng(args.seed)
    shape = ((args.batch, args.prompt_len, cfg.num_codebooks)
             if cfg.family == "audio" else (args.batch, args.prompt_len))
    prompts = rng.integers(0, cfg.vocab_size, shape).astype(np.int32)

    t0 = time.time()
    res = engine.generate(prompts, max_new_tokens=args.max_new,
                          temperature=args.temperature, seed=args.seed)
    dt = time.time() - t0
    toks = res.tokens.reshape(args.batch, res.steps, -1)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prefill={res.prefill_len} decoded={res.steps} tokens "
          f"in {dt:.2f}s ({args.batch * res.steps / dt:.1f} tok/s)")
    print("first sequence:", toks[0, :, 0].tolist())


if __name__ == "__main__":
    main()

"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from cell JSONs.

  PYTHONPATH=src python -m repro.launch.report \
      [--dir experiments/dryrun/single_pod] [--write]

``--write`` splices the tables into EXPERIMENTS.md at the
``<!-- DRYRUN_TABLE -->`` / ``<!-- ROOFLINE_TABLE -->`` /
``<!-- ROOFLINE_NOTES -->`` markers.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

_SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirname: str) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    rows.sort(key=lambda r: (r["arch"], _SHAPE_ORDER.index(r["shape"])))
    return rows


def _gb(x) -> str:
    return f"{x / 2**30:.2f}"


def dryrun_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | status | compile s | args GiB/dev | temp GiB/dev "
           "| collectives (AG/AR/RS/A2A/CP) |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | SKIP (full attention"
                       f" @512k; DESIGN §7) | — | — | — | — |")
            continue
        ma = r.get("memory_analysis", {})
        c = r.get("raw_collectives", r.get("collectives", {}))
        ops = "/".join(str(int(c.get(k, {}).get("count", 0))) for k in
                       ("all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"))
        out.append(
            f"| {r['arch']} | {r['shape']} | OK | {r.get('compile_s', 0):.1f} "
            f"| {_gb(ma.get('argument_size_in_bytes', 0))} "
            f"| {_gb(ma.get('temp_size_in_bytes', 0))} | {ops} |")
    return "\n".join(out)


def roofline_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL_FLOPS | useful/HLO | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped") or "roofline" not in r:
            continue
        t = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} "
            f"| {t['memory_s']:.3f} | {t['collective_s']:.3f} "
            f"| **{t['dominant']}** | {t['model_flops']:.2e} "
            f"| {t['useful_flops_ratio']:.3f} "
            f"| {t['roofline_fraction']:.3f} |")
    return "\n".join(out)


def notes(rows: List[Dict]) -> str:
    live = [r for r in rows if not r.get("skipped") and "roofline" in r]
    doms = {}
    for r in live:
        doms.setdefault(r["roofline"]["dominant"], []).append(
            f"{r['arch']}×{r['shape']}")
    lines = ["Dominant-term census:"]
    for k, v in sorted(doms.items(), key=lambda kv: -len(kv[1])):
        lines.append(f"* **{k}** ({len(v)} cells): {', '.join(v)}")
    worst = sorted(live, key=lambda r: r["roofline"]["roofline_fraction"])[:3]
    best = sorted(live, key=lambda r: -r["roofline"]["roofline_fraction"])[:3]
    lines.append("")
    lines.append("Best roofline fractions: " + ", ".join(
        f"{r['arch']}×{r['shape']} ({r['roofline']['roofline_fraction']:.3f})"
        for r in best))
    lines.append("Worst roofline fractions: " + ", ".join(
        f"{r['arch']}×{r['shape']} ({r['roofline']['roofline_fraction']:.3f})"
        for r in worst))
    return "\n".join(lines)


def splice(md_path: str, marker: str, content: str) -> None:
    with open(md_path) as f:
        text = f.read()
    tag = f"<!-- {marker} -->"
    assert tag in text, marker
    block = f"{tag}\n\n{content}\n"
    # replace the marker line (keep it so re-runs regenerate)
    import re
    text = re.sub(rf"<!-- {marker} -->\n(?:(?!<!--|\n## ).*\n)*",
                  block, text, count=1)
    with open(md_path, "w") as f:
        f.write(text)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun/single_pod")
    ap.add_argument("--write", action="store_true")
    ap.add_argument("--md", default="EXPERIMENTS.md")
    args = ap.parse_args()
    rows = load(args.dir)
    dt = dryrun_table(rows)
    rt = roofline_table(rows)
    nt = notes(rows)
    if args.write:
        splice(args.md, "DRYRUN_TABLE", dt)
        splice(args.md, "ROOFLINE_TABLE", rt)
        splice(args.md, "ROOFLINE_NOTES", nt)
        print(f"wrote tables into {args.md} ({len(rows)} cells)")
    else:
        print(dt)
        print()
        print(rt)
        print()
        print(nt)


if __name__ == "__main__":
    main()

"""Roofline-term derivation from compiled dry-run artifacts.

Per (arch × shape × mesh) cell:

  compute term    = HLO_FLOPs / (chips × 197e12 bf16 FLOP/s)
  memory term     = HLO_bytes / (chips × 819e9 B/s HBM)
  collective term = wire_bytes / (chips × 50e9 B/s ICI link)

``cost_analysis()`` runs on the *partitioned* (per-device SPMD) module, so
its flops/bytes are per-device; multiplying by chips gives the global
numbers the formulas above expect — the two conventions cancel and we
compute terms directly from per-device quantities.

collective_bytes is NOT in cost_analysis: ``parse_collectives`` scans the
optimized HLO for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops, reads each result shape and replica-group size, and
applies a per-op wire model (ring-equivalent bytes actually serialized on a
link per device):

  all-reduce       2·b·(p-1)/p        (reduce-scatter + all-gather phases)
  all-gather       b_out·(p-1)/p
  reduce-scatter   b_out·(p-1)
  all-to-all       b·(p-1)/p
  collective-perm  b
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, List, Optional, Tuple

# TPU v5e-class hardware constants (assignment-specified)
PEAK_FLOPS = 197e12     # bf16 FLOP/s per chip
HBM_BW = 819e9          # B/s per chip
LINK_BW = 50e9          # B/s ICI per chip

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "bf16[8,4096,3072]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
_GROUP_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(type_str: str) -> int:
    """Bytes of one HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> Optional[int]:
    m = _GROUP_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUP_BRACE_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(1, len(ids))
    return None


def _wire_bytes(op: str, result_bytes: int, p: int) -> float:
    if p <= 1:
        return 0.0
    f = (p - 1) / p
    if op.startswith("all-reduce"):
        return 2.0 * result_bytes * f
    if op.startswith("all-gather"):
        return result_bytes * f
    if op == "reduce-scatter":
        return result_bytes * (p - 1)
    if op == "all-to-all":
        return result_bytes * f
    return float(result_bytes)  # collective-permute


def parse_collectives(hlo_text: str, default_p: int = 2) -> Dict[str, Any]:
    """Scan optimized HLO; returns per-op counts/bytes + total wire bytes."""
    stats = {op: {"count": 0, "result_bytes": 0, "wire_bytes": 0.0}
             for op in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, op = m.groups()
        base = op.replace("-start", "")
        b = _shape_bytes(type_str)
        p = _group_size(line) or default_p
        stats[base]["count"] += 1
        stats[base]["result_bytes"] += b
        stats[base]["wire_bytes"] += _wire_bytes(base, b, p)
    total = sum(s["wire_bytes"] for s in stats.values())
    stats["total_wire_bytes"] = total
    return stats


# ---------------------------------------------------------------------- #
# Roofline terms
# ---------------------------------------------------------------------- #
def model_flops(cfg, kind: str, global_batch: int, seq_len: int) -> float:
    """6·N_active·tokens (train) or 2·N_active·tokens (inference)."""
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * global_batch * seq_len
    if kind == "prefill":
        return 2.0 * n * global_batch * seq_len
    return 2.0 * n * global_batch  # decode: one token per sequence


def roofline_terms(per_device_flops: float, per_device_bytes: float,
                   per_device_wire_bytes: float) -> Dict[str, float]:
    compute_s = per_device_flops / PEAK_FLOPS
    memory_s = per_device_bytes / HBM_BW
    collective_s = per_device_wire_bytes / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant.replace("_s", "")
    terms["step_s_lower_bound"] = max(compute_s, memory_s, collective_s)
    return terms


def stage_roofline(wire_bytes: float, elapsed_s: Optional[float],
                   parallelism: int,
                   hbm_bytes: Optional[float] = None) -> Dict[str, float]:
    """Roofline terms for one *measured* query stage (``repro.obs``).

    ``wire_bytes`` is the stage's global shuffle volume (from
    ``ExecStats.shuffle_records``); ``hbm_bytes`` defaults to 2x wire (every
    shuffled byte is packed on the send side and unpacked on the receive
    side — a lower bound, ignoring the local operator work).  FLOPs are
    unknown for dataframe ops, so the compute term is 0 and the bound is
    memory/collective-only.  ``roofline_fraction`` compares that lower
    bound to the measured stage time: 1.0 means the stage ran at the
    modeled bandwidth limit, small values mean overhead (dispatch, compile,
    driver round-trips) dominates.
    """
    p = max(1, int(parallelism))
    wire_dev = float(wire_bytes) / p
    hbm_total = 2.0 * float(wire_bytes) if hbm_bytes is None else float(hbm_bytes)
    terms = roofline_terms(0.0, hbm_total / p, wire_dev)
    terms["wire_bytes"] = float(wire_bytes)
    terms["hbm_bytes"] = hbm_total
    terms["elapsed_s"] = float(elapsed_s) if elapsed_s is not None else None
    terms["roofline_fraction"] = (
        terms["step_s_lower_bound"] / float(elapsed_s)
        if elapsed_s else 0.0)
    return terms


def analyze(cell_result: Dict[str, Any], cfg, chips: int) -> Dict[str, Any]:
    """Attach roofline terms to one dry-run cell result dict."""
    ca = cell_result["cost_analysis"]
    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    wire_dev = float(cell_result["collectives"]["total_wire_bytes"])
    terms = roofline_terms(flops_dev, bytes_dev, wire_dev)
    mf = model_flops(cfg, cell_result["kind"], cell_result["global_batch"],
                     cell_result["seq_len"])
    hlo_flops_global = flops_dev * chips
    terms["model_flops"] = mf
    terms["hlo_flops_global"] = hlo_flops_global
    terms["useful_flops_ratio"] = (mf / hlo_flops_global
                                   if hlo_flops_global else 0.0)
    # roofline fraction: useful FLOP rate at the step lower bound vs peak
    step = terms["step_s_lower_bound"]
    terms["roofline_fraction"] = (
        mf / (step * chips * PEAK_FLOPS) if step > 0 else 0.0)
    return terms


# ---------------------------------------------------------------------- #
# Report generation from dry-run JSONs
# ---------------------------------------------------------------------- #
def format_table(rows: List[Dict[str, Any]]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL_FLOPS | useful/HLO | roofline frac |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} "
            f"| {t['memory_s']:.4f} | {t['collective_s']:.4f} "
            f"| **{t['dominant']}** | {t['model_flops']:.3e} "
            f"| {t['useful_flops_ratio']:.3f} | {t['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def main() -> None:
    import argparse
    import glob
    import os
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun/single_pod")
    args = ap.parse_args()
    rows = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    rows = [r for r in rows if "roofline" in r]
    print(format_table(rows))


if __name__ == "__main__":
    main()

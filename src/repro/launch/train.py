"""End-to-end training driver (CPU-scale models, production-shaped code).

Wires every substrate layer together: DDF data pipeline (on a CylonExecutor
gang) → CylonStore hand-off → sharded train step → async checkpointing with
``--resume`` elastic restart.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --resume
"""

from __future__ import annotations

import argparse
import time

import jax
from .. import compat
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, get_config, get_smoke_config
from ..core import CylonExecutor, CylonStore, DevicePool
from ..data import (CorpusConfig, batches_from_table, preprocess,
                    source_weights, synth_corpus)
from ..models.layers import NO_SHARDING
from ..train import (AdamWConfig, AsyncCheckpointer, init_train_state,
                     latest_step, make_train_step, restore)
from ..train.step import batch_specs, state_specs
from .mesh import make_local_mesh, rules_for_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--data-parallelism", type=int, default=None,
                    help="gang size for the DDF preprocessing application")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("train driver covers token-LM archs; see the "
                         "smoke tests for vlm/audio steps")

    n_dev = len(jax.devices())
    mesh = make_local_mesh(n_dev, model=args.model_axis)
    rules = rules_for_mesh(mesh) if n_dev > 1 else NO_SHARDING

    # ---- DDF preprocessing application (paper §IV-C) -------------------- #
    pool = DevicePool()
    gang = CylonExecutor(parallelism=args.data_parallelism or n_dev,
                         pool=pool)
    store = CylonStore()
    corpus = synth_corpus(CorpusConfig(num_docs=2048, payload_tokens=args.seq,
                                       vocab_size=cfg.vocab_size,
                                       seed=args.seed),
                          gang.parallelism)
    weights = source_weights(8, gang.parallelism)
    t0 = time.time()
    preprocess(gang, corpus, weights, store=store)
    table = store.get("train_corpus")
    print(f"[data] preprocessed {table.total_rows()} docs "
          f"on gang={gang.parallelism} in {time.time() - t0:.2f}s")
    batches = batches_from_table(table, args.batch, args.seq, seed=args.seed)

    # ---- training application ------------------------------------------ #
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    key = jax.random.PRNGKey(args.seed)
    state = init_train_state(key, cfg, jnp.float32)
    start_step = 0
    ckpt = AsyncCheckpointer()
    shardings = None
    if n_dev > 1:
        sp = state_specs(cfg, rules)
        shardings = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), sp,
            is_leaf=lambda x: isinstance(x, P))
        state = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), state, shardings)

    if args.resume and args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            state = restore(f"{args.ckpt_dir}/ckpt_{last}", state, shardings)
            start_step = last
            print(f"[ckpt] resumed from step {last} "
                  f"(mesh-elastic restore onto {n_dev} devices)")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, rules, ce_chunk=64))
    losses = []
    with compat.set_mesh(mesh) if n_dev > 1 else _nullcontext():
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % 5 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"dt {time.time() - t0:.3f}s", flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                ckpt.save(f"{args.ckpt_dir}/ckpt_{step + 1}", state,
                          step + 1)
    ckpt.wait()
    if len(losses) > 10:
        a, b = np.mean(losses[:5]), np.mean(losses[-5:])
        print(f"[loss] first5={a:.3f} last5={b:.3f} "
              f"({'improved' if b < a else 'NOT improved'})")


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()

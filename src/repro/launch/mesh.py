"""Production mesh construction + sharding-rule presets.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state; the dry-run driver forces
the 512-device placeholder backend *before* calling it.

Axis semantics:
  pod    — outer data-parallel axis across pods (its own collective domain;
           gradient reduction is hierarchical: reduce-scatter inside the pod,
           all-reduce across pods, all-gather inside the pod — GSPMD emits
           this from the (pod, data) batch sharding automatically).
  data   — FSDP/data-parallel inside one pod.
  model  — TP/EP/SP: attention heads & FFN columns, MoE experts, sequence-
           sharded activations between blocks, and sequence-sharded KV for
           decode.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from ..models.layers import ShardingRules


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(parallelism: Optional[int] = None,
                    model: int = 1) -> jax.sharding.Mesh:
    """Small CPU mesh for tests/benchmarks: (data, model)."""
    n = parallelism or len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def _axis_sizes(mesh):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("model", 1), sizes.get("data", 1)


def rules_for_mesh(mesh: jax.sharding.Mesh) -> ShardingRules:
    """Training rules: ZeRO-3 over data × SP/EP/vocab over model
    (weights replicated over model; ``tp`` dims resolve to None)."""
    names = mesh.axis_names
    ms, ds = _axis_sizes(mesh)
    if "pod" in names:
        return ShardingRules(batch=("pod", "data"), fsdp="data",
                             model="model", model_size=ms, data_size=ds)
    if "data" in names and "model" in names:
        return ShardingRules(batch="data", fsdp="data", model="model",
                             model_size=ms, data_size=ds)
    # single-axis mesh (dataframe engine's df axis): no model parallelism
    return ShardingRules(batch=names[0], fsdp=names[0], model=None)


def serve_rules_for_mesh(mesh: jax.sharding.Mesh) -> ShardingRules:
    """Serving rules: Megatron TP over model (fsdp=None, tp_weights=True) —
    at decode, ZeRO-style weight gathers would ship the whole model over ICI
    per token; TP reads only the local shard and psums tiny (B, 1, D)
    activations instead."""
    names = mesh.axis_names
    ms, ds = _axis_sizes(mesh)
    if "pod" in names:
        return ShardingRules(batch=("pod", "data"), fsdp=None, model="model",
                             tp_weights=True, model_size=ms, data_size=ds)
    return ShardingRules(batch="data", fsdp=None, model="model",
                         tp_weights=True, model_size=ms, data_size=ds)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first init, and the production meshes need 512
placeholder host devices.  (Do not import this module from tests/benches;
they want the real 1-device CPU backend.)

For every eligible cell this driver:
  1. builds the step function (train_step / prefill / decode_step) and
     ``ShapeDtypeStruct`` stand-ins for state + inputs (zero allocation),
  2. ``jax.jit(...).lower(...)`` with explicit NamedSharding in/out trees
     on the production mesh (16×16 single pod, 2×16×16 multi-pod),
  3. ``.compile()`` — proving the sharding is coherent and the collectives
     lower,
  4. records ``memory_analysis()`` / ``cost_analysis()`` / the parsed
     collective schedule + roofline terms to a JSON under
     ``experiments/dryrun/<mesh>/``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
"""

import argparse
import dataclasses
import json
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional

import jax
from .. import compat
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import flags
from ..configs import ARCHS, get_config
from ..models import transformer
from ..models.config import SHAPES
from ..train import AdamWConfig, make_train_step
from ..train.optim import init_opt_state, opt_specs
from ..train.step import batch_specs as batch_spec_tree, state_specs
from . import roofline
from .mesh import make_production_mesh, rules_for_mesh, serve_rules_for_mesh
from .shapes import (Cell, all_cells, cell, decode_token_specs,
                     prefill_batch_specs, train_batch_specs)


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _params_shapes(cfg):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(partial(transformer.init_params, cfg=cfg), key)


def build_lowered(c: Cell, mesh, ce_chunk: int = 512,
                  rules_override=None, extra: Optional[Dict] = None,
                  cfg_override=None):
    """Returns (lowered, meta) for one cell on ``mesh``."""
    cfg = cfg_override or get_config(c.arch)
    if rules_override is not None:
        rules = rules_override
    elif c.kind == "decode":
        rules = serve_rules_for_mesh(mesh)   # pure TP: no per-token gathers
    else:
        rules = rules_for_mesh(mesh)
    # batch=1 long-context cells cannot shard the batch dim; the KV cache
    # sequence sharding over 'model' carries the parallelism instead.
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    b_axes = rules.batch if isinstance(rules.batch, tuple) else (rules.batch,)
    divisor = 1
    for a in b_axes:
        divisor *= sizes.get(a, 1) if a else 1
    if c.global_batch % divisor:
        rules = dataclasses.replace(rules, batch=None)
    extra = extra or {}

    if c.kind == "train":
        step = make_train_step(cfg, AdamWConfig(), rules,
                               ce_chunk=ce_chunk, **extra)
        params_sh = _params_shapes(cfg)
        state_shapes = {"params": params_sh,
                        "opt": jax.eval_shape(init_opt_state, params_sh)}
        batch_shapes = train_batch_specs(cfg, c.global_batch, c.seq_len)
        st_specs = state_specs(cfg, rules)
        b_specs = batch_spec_tree(cfg, rules)
        with compat.set_mesh(mesh):
            lowered = jax.jit(
                step,
                in_shardings=(_named(mesh, st_specs), _named(mesh, b_specs)),
                out_shardings=(_named(mesh, st_specs), None),
            ).lower(state_shapes, batch_shapes)
        return lowered, {"cfg": cfg}

    params_sh = _params_shapes(cfg)
    p_specs = transformer.param_specs(cfg, rules)
    c_specs = transformer.cache_specs(cfg, rules)
    cache_shapes = jax.eval_shape(
        partial(transformer.init_caches, cfg, c.global_batch, c.seq_len))

    if c.kind == "prefill":
        def fn(params, batch):
            return transformer.prefill(params, cfg, batch, c.seq_len, rules,
                                       **extra)
        batch_shapes = prefill_batch_specs(cfg, c.global_batch, c.seq_len)
        b_specs = {k: v for k, v in batch_spec_tree(cfg, rules).items()
                   if k in batch_shapes}
        with compat.set_mesh(mesh):
            lowered = jax.jit(
                fn,
                in_shardings=(_named(mesh, p_specs), _named(mesh, b_specs)),
                out_shardings=(None, _named(mesh, c_specs)),
            ).lower(params_sh, batch_shapes)
        return lowered, {"cfg": cfg}

    # decode
    def fn(params, caches, tokens, pos):
        return transformer.decode_step(params, cfg, caches, tokens, pos,
                                       rules)
    tok_sh, pos_sh = decode_token_specs(cfg, c.global_batch)
    tok_spec = P(rules.batch, None, None) if cfg.family == "audio" \
        else P(rules.batch, None)
    with compat.set_mesh(mesh):
        lowered = jax.jit(
            fn,
            in_shardings=(_named(mesh, p_specs), _named(mesh, c_specs),
                          NamedSharding(mesh, tok_spec),
                          NamedSharding(mesh, P())),
            out_shardings=(None, _named(mesh, c_specs)),
            donate_argnums=(1,),   # serving updates KV caches in place
        ).lower(params_sh, cache_shapes, tok_sh, pos_sh)
    return lowered, {"cfg": cfg}


def counting_costs(c: Cell, mesh, ce_chunk, rules_override, extra
                   ) -> Dict[str, Any]:
    """Loop-corrected per-device costs via two-point depth extrapolation.

    ``HloCostAnalysis`` counts while-loop bodies ONCE (no trip-count
    multiplication), so the scanned full-depth build under-reports.  We
    compile the same cell at ``prefix + 1·period`` and ``prefix + 2·period``
    layers with **every scan unrolled** (layer scan, CE chunks, attention kv
    blocks, SSD state carries) and extrapolate linearly in period count —
    exact because body periods are homogeneous by construction.
    """
    cfg = get_config(c.arch)
    n_prefix, period, n_periods = transformer.layer_layout(cfg)
    two = {}
    for k in (1, 2):
        cfg_k = dataclasses.replace(cfg, num_layers=n_prefix + k * period)
        with flags.unrolled_scans():
            lowered, _ = build_lowered(c, mesh, ce_chunk, rules_override,
                                       extra, cfg_override=cfg_k)
            compiled = lowered.compile()
        ca = compiled.cost_analysis() or {}
        coll = roofline.parse_collectives(compiled.as_text())
        two[k] = {"flops": float(ca.get("flops", 0.0)),
                  "bytes": float(ca.get("bytes accessed", 0.0)),
                  "wire": float(coll["total_wire_bytes"]),
                  "collectives": coll}

    def extrap(key):
        per = two[2][key] - two[1][key]
        return two[1][key] + (n_periods - 1) * per

    coll_full = {}
    for op in roofline._COLLECTIVES:
        coll_full[op] = {}
        for field in ("count", "result_bytes", "wire_bytes"):
            v1 = two[1]["collectives"][op][field]
            v2 = two[2]["collectives"][op][field]
            coll_full[op][field] = v1 + (n_periods - 1) * (v2 - v1)
    coll_full["total_wire_bytes"] = extrap("wire")
    return {
        "flops": extrap("flops"),
        "bytes accessed": extrap("bytes"),
        "collectives": coll_full,
        "two_point": {str(k): {kk: vv for kk, vv in v.items()
                               if kk != "collectives"}
                      for k, v in two.items()},
        "n_periods": n_periods,
    }


def run_cell(c: Cell, mesh, mesh_name: str, out_dir: str,
             ce_chunk: int = 512, rules_override=None,
             extra: Optional[Dict] = None, tag: str = "",
             counting: bool = True) -> Dict[str, Any]:
    chips = mesh.devices.size
    result: Dict[str, Any] = {
        "arch": c.arch, "shape": c.shape, "kind": c.kind,
        "global_batch": c.global_batch, "seq_len": c.seq_len,
        "mesh": mesh_name, "chips": chips, "eligible": c.eligible,
    }
    if not c.eligible:
        result["skipped"] = c.skip_reason
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            name = f"{c.arch}__{c.shape}{('__' + tag) if tag else ''}.json"
            with open(os.path.join(out_dir, name), "w") as f:
                json.dump(result, f, indent=1)
        return result
    cfg = get_config(c.arch)

    # ---- the artifact: full-depth scanned build must lower AND compile ----
    t0 = time.time()
    lowered, meta = build_lowered(c, mesh, ce_chunk, rules_override, extra)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    result["lower_s"] = round(t1 - t0, 2)
    result["compile_s"] = round(t2 - t1, 2)

    ca = compiled.cost_analysis() or {}
    result["raw_cost_analysis"] = {k: float(v) for k, v in ca.items()
                                   if isinstance(v, (int, float))}
    try:
        ma = compiled.memory_analysis()
        result["memory_analysis"] = {
            a: int(getattr(ma, a)) for a in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")
            if hasattr(ma, a)}
    except Exception as e:  # backend-dependent
        result["memory_analysis"] = {"error": str(e)}
    result["raw_collectives"] = roofline.parse_collectives(compiled.as_text())

    # ---- loop-corrected costs (two-point unrolled counting builds) --------
    if counting:
        t3 = time.time()
        corrected = counting_costs(c, mesh, ce_chunk, rules_override, extra)
        result["counting_s"] = round(time.time() - t3, 2)
        result["cost_analysis"] = {
            "flops": corrected["flops"],
            "bytes accessed": corrected["bytes accessed"]}
        result["collectives"] = corrected["collectives"]
        result["two_point"] = corrected["two_point"]
    else:
        result["cost_analysis"] = result["raw_cost_analysis"]
        result["collectives"] = result["raw_collectives"]

    result["roofline"] = roofline.analyze(result, cfg, chips)

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{c.arch}__{c.shape}{('__' + tag) if tag else ''}.json"
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(result, f, indent=1)
    return result


def summarize(result: Dict[str, Any]) -> str:
    if result.get("skipped"):
        return (f"SKIP  {result['arch']:22s} {result['shape']:12s} "
                f"({result['skipped'][:40]}...)")
    t = result["roofline"]
    return (f"OK    {result['arch']:22s} {result['shape']:12s} "
            f"lower={result['lower_s']:6.1f}s compile={result['compile_s']:6.1f}s "
            f"comp={t['compute_s']:.3f}s mem={t['memory_s']:.3f}s "
            f"coll={t['collective_s']:.3f}s dom={t['dominant']:10s} "
            f"frac={t['roofline_fraction']:.3f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--ce-chunk", type=int, default=512)
    ap.add_argument("--no-counting", action="store_true",
                    help="skip the two-point unrolled counting builds "
                         "(compile-proof only; multi-pod pass uses this — "
                         "the roofline table is single-pod per the brief)")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_name = "multi_pod" if args.multi_pod else "single_pod"
    out_dir = os.path.join(args.out, mesh_name)

    if args.all:
        cells = all_cells()
    else:
        assert args.arch and args.shape, "--arch and --shape, or --all"
        cells = [cell(args.arch, args.shape)]

    failures = []
    for c in cells:
        done = os.path.join(out_dir, f"{c.arch}__{c.shape}.json")
        if args.skip_done and os.path.exists(done):
            print(f"done  {c.arch:22s} {c.shape}")
            continue
        try:
            result = run_cell(c, mesh, mesh_name, out_dir,
                              ce_chunk=args.ce_chunk,
                              counting=not args.no_counting)
            print(summarize(result), flush=True)
            if result.get("memory_analysis"):
                tmp = result["memory_analysis"].get("temp_size_in_bytes")
                arg = result["memory_analysis"].get("argument_size_in_bytes")
                if tmp is not None:
                    print(f"      memory: args={arg} temp={tmp}", flush=True)
        except Exception as e:
            failures.append((c.arch, c.shape, repr(e)))
            print(f"FAIL  {c.arch:22s} {c.shape:12s} {e!r}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cell(s) failed: {failures}")


if __name__ == "__main__":
    main()

"""Launchers: mesh construction, multi-pod dry-run, roofline, drivers.

NOTE: ``dryrun`` must only be imported as a program entry point (it forces a
512-device placeholder backend before jax initializes); this package
``__init__`` deliberately does not import it.
"""

from .mesh import make_local_mesh, make_production_mesh, rules_for_mesh

__all__ = ["make_local_mesh", "make_production_mesh", "rules_for_mesh"]

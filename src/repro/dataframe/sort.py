"""Distributed sort: sample sort (splitter-based range partition + local sort).

This is also the paper's §VI "sample-based repartitioning" for skew/straggler
mitigation: the splitters are sampled quantiles, so output partitions are
balanced even on skewed keys.  ``repartition_balanced`` exposes that use
directly (used by the training data pipeline for straggler mitigation).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..comm import Communicator
from .ops_local import sort_local
from .shuffle import ShuffleStats, shuffle
from .table import Table, _sentinel_for


def _sample_splitters(key: jax.Array, row_count: jax.Array,
                      comm: Communicator, samples: int) -> jax.Array:
    """Gather per-rank key samples and return p-1 global splitters."""
    p = comm.size()
    cap = key.shape[0]
    skey = jnp.sort(jnp.where(jnp.arange(cap) < row_count, key,
                              _sentinel_for(key.dtype)))
    # evenly spaced positions within the valid prefix
    n_local = jnp.minimum(row_count, samples)
    idx = (jnp.arange(samples) * jnp.maximum(row_count, 1)) // jnp.maximum(samples, 1)
    idx = jnp.minimum(idx, jnp.maximum(row_count - 1, 0)).astype(jnp.int32)
    local = jnp.where(jnp.arange(samples) < n_local, jnp.take(skey, idx),
                      _sentinel_for(key.dtype))
    allsamp = comm.all_gather(local).reshape(-1)          # (p*samples,)
    total_valid = jax.lax.psum(n_local, comm.axis)
    ssorted = jnp.sort(allsamp)
    qpos = ((jnp.arange(1, p) * total_valid) // p).astype(jnp.int32)
    qpos = jnp.minimum(qpos, p * samples - 1)
    return jnp.take(ssorted, qpos)                        # (p-1,)


def sort(
    table: Table,
    comm: Communicator,
    by: Sequence[str],
    samples: int = 64,
    **shuffle_kw,
) -> Tuple[Table, ShuffleStats]:
    """Globally sort by ``by[0]`` across ranks (full lexsort within rank).

    Rank r holds the r-th contiguous key range; within a rank rows are
    lex-sorted by all of ``by``.  (Distributed tie order across ranks follows
    the primary key only — the paper's benchmark sorts single int columns.)
    """
    key = table.columns[by[0]]
    splitters = _sample_splitters(key, table.row_count, comm, samples)
    dest = jnp.searchsorted(splitters, key, side="right").astype(jnp.int32)
    shuffled, stats = shuffle(table, comm, dest=dest, **shuffle_kw)
    return sort_local(shuffled, by), stats


def repartition_balanced(
    table: Table,
    comm: Communicator,
    key_col: str,
    samples: int = 64,
    **shuffle_kw,
) -> Tuple[Table, ShuffleStats]:
    """Sample-based repartition (paper §VI): balance rows across ranks.

    Range-partitions on sampled quantiles of ``key_col`` without the final
    local sort — used for skew/straggler mitigation in long pipelines.
    """
    key = table.columns[key_col]
    splitters = _sample_splitters(key, table.row_count, comm, samples)
    dest = jnp.searchsorted(splitters, key, side="right").astype(jnp.int32)
    return shuffle(table, comm, dest=dest, **shuffle_kw)

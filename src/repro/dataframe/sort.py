"""Distributed sort: sample sort (splitter-based range partition + local sort).

This is also the paper's §VI "sample-based repartitioning" for skew/straggler
mitigation: the splitters are sampled quantiles, so output partitions are
balanced even on skewed keys.  ``repartition_balanced`` exposes that use
directly (used by the training data pipeline for straggler mitigation).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..comm import Communicator
from ..nulls import mask_name
from .ops_local import sort_local
from .shuffle import ShuffleStats, shuffle
from .table import Table, _sentinel_for


def _range_dest(table: Table, key_col: str, comm: Communicator,
                samples: int) -> jax.Array:
    """Destination ranks for a range partition on ``key_col``.

    Nulls-last semantics: null keys are excluded from the splitter sample
    (their canonical-zero values would skew the quantiles toward rank 0)
    and routed to the last rank, where the local sort puts them at the
    tail — the global order ends ... , max, null, null."""
    p = comm.size()
    key = table.columns[key_col]
    m = table.columns.get(mask_name(key_col))
    valid = table.valid_mask()
    if m is None:
        splitters = _sample_splitters(key, valid, comm, samples)
        return jnp.searchsorted(splitters, key, side="right").astype(jnp.int32)
    splitters = _sample_splitters(key, valid & m, comm, samples)
    dest = jnp.searchsorted(splitters, key, side="right").astype(jnp.int32)
    return jnp.where(m, dest, p - 1)


def _sample_splitters(key: jax.Array, valid: jax.Array,
                      comm: Communicator, samples: int) -> jax.Array:
    """Gather per-rank key samples and return p-1 global splitters.

    ``valid`` is a boolean participation mask (row-count prefix for plain
    sorts; additionally excluding null keys for nullable sort columns —
    their canonical-zero values would drag the quantiles toward rank 0).
    """
    p = comm.size()
    n_valid = jnp.sum(valid).astype(jnp.int32)
    skey = jnp.sort(jnp.where(valid, key, _sentinel_for(key.dtype)))
    # evenly spaced positions within the sorted valid prefix
    n_local = jnp.minimum(n_valid, samples)
    idx = (jnp.arange(samples) * jnp.maximum(n_valid, 1)) // jnp.maximum(samples, 1)
    idx = jnp.minimum(idx, jnp.maximum(n_valid - 1, 0)).astype(jnp.int32)
    local = jnp.where(jnp.arange(samples) < n_local, jnp.take(skey, idx),
                      _sentinel_for(key.dtype))
    allsamp = comm.all_gather(local).reshape(-1)          # (p*samples,)
    total_valid = jax.lax.psum(n_local, comm.axis)
    ssorted = jnp.sort(allsamp)
    qpos = ((jnp.arange(1, p) * total_valid) // p).astype(jnp.int32)
    qpos = jnp.minimum(qpos, p * samples - 1)
    return jnp.take(ssorted, qpos)                        # (p-1,)


def sort(
    table: Table,
    comm: Communicator,
    by: Sequence[str],
    samples: int = 64,
    **shuffle_kw,
) -> Tuple[Table, ShuffleStats]:
    """Globally sort by ``by[0]`` across ranks (full lexsort within rank).

    Rank r holds the r-th contiguous key range; within a rank rows are
    lex-sorted by all of ``by``.  (Distributed tie order across ranks follows
    the primary key only — the paper's benchmark sorts single int columns.)
    """
    dest = _range_dest(table, by[0], comm, samples)
    shuffled, stats = shuffle(table, comm, dest=dest, **shuffle_kw)
    return sort_local(shuffled, by), stats


def repartition_balanced(
    table: Table,
    comm: Communicator,
    key_col: str,
    samples: int = 64,
    **shuffle_kw,
) -> Tuple[Table, ShuffleStats]:
    """Sample-based repartition (paper §VI): balance rows across ranks.

    Range-partitions on sampled quantiles of ``key_col`` without the final
    local sort — used for skew/straggler mitigation in long pipelines.
    """
    dest = _range_dest(table, key_col, comm, samples)
    return shuffle(table, comm, dest=dest, **shuffle_kw)

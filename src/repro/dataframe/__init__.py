"""Distributed dataframe engine (the paper's HP-DDF, adapted to JAX/TPU)."""

from .table import Table, concat_tables
from .ops_local import (
    add_scalar,
    filter_expr,
    filter_rows,
    groupby_local,
    hash_columns,
    join_local,
    join_overflow,
    map_columns,
    recode,
    sort_local,
    with_columns,
)
from .schema import (decode_codes, encode_strings, merge_dictionaries,
                     recode_mapping)
from .shuffle import ShuffleStats, default_bucket_capacity, shuffle
from .groupby import groupby
from .join import join
from .sort import repartition_balanced, sort

__all__ = [
    "Table", "concat_tables",
    "add_scalar", "filter_expr", "filter_rows", "groupby_local",
    "hash_columns", "join_local", "join_overflow", "map_columns",
    "recode", "sort_local", "with_columns",
    "decode_codes", "encode_strings", "merge_dictionaries",
    "recode_mapping",
    "ShuffleStats", "default_bucket_capacity", "shuffle",
    "groupby", "join", "sort", "repartition_balanced",
]

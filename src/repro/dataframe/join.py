"""Distributed equi-join: co-hash shuffle of both sides + local sort-merge.

Mirrors the paper's Fig 2 decomposition: hash-partition (communication
sub-operator) + local join (core local operator).  Both sides use the same
key hash so co-partitioned rows land on the same rank.
"""

from __future__ import annotations

from typing import Optional, Tuple

from ..comm import Communicator
from .ops_local import join_local
from .shuffle import ShuffleStats, shuffle
from .table import Table


def join(
    left: Table,
    right: Table,
    comm: Communicator,
    on: str,
    out_capacity: Optional[int] = None,
    **shuffle_kw,
) -> Tuple[Table, ShuffleStats, ShuffleStats]:
    """Distributed inner join over the comm axis (inside shard_map)."""
    l_sh, l_stats = shuffle(left, comm, key_cols=[on], **shuffle_kw)
    r_sh, r_stats = shuffle(right, comm, key_cols=[on], **shuffle_kw)
    out = join_local(l_sh, r_sh, on, out_capacity=out_capacity)
    return out, l_stats, r_stats

"""Local (per-partition) DDF sub-operators.

These are the "core local operator" / "auxiliary local operators" of the
paper's sub-operator decomposition (§III-B, Fig 2).  All are pure jnp and
static-shape; the TPU adaptation replaces C++ hash tables with sort-based
vectorized algorithms (see DESIGN.md §2).  The compute hot spots have Pallas
kernel twins in ``repro.kernels`` selected via ``repro.dataframe.ops`` — the
jnp versions here double as their oracles.
"""

from __future__ import annotations

from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .table import Table, _sentinel_for

# ---------------------------------------------------------------------- #
# Hashing (murmur3-style finalizer) — used for shuffle partitioning
# ---------------------------------------------------------------------- #


def _mix32(h: jax.Array) -> jax.Array:
    h = h.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def hash_columns(table: Table, key_cols: Sequence[str]) -> jax.Array:
    """Combined 32-bit hash of the key columns (row-wise).

    Dictionary-encoded string columns hash their int32 *codes* directly:
    the planner recodes join inputs onto a shared dictionary first
    (``planner.dictionary``), so equal strings always carry equal codes
    gang-wide and the hash placement stays consistent — no string-aware
    hashing is ever needed on device."""
    h = jnp.full((table.capacity,), 0x9E3779B9, jnp.uint32)
    for name in key_cols:
        v = table.columns[name]
        if jnp.issubdtype(v.dtype, jnp.floating):
            bits = jax.lax.bitcast_convert_type(v.astype(jnp.float32), jnp.uint32)
        else:
            bits = v.astype(jnp.uint32)
        h = _mix32(h ^ _mix32(bits) + jnp.uint32(0x9E3779B9) + (h << 6) + (h >> 2))
    return h


def _mix32_np(h: np.ndarray) -> np.ndarray:
    h = h.astype(np.uint32)
    h = h ^ (h >> np.uint32(16))
    h = h * np.uint32(0x85EBCA6B)
    h = h ^ (h >> np.uint32(13))
    h = h * np.uint32(0xC2B2AE35)
    h = h ^ (h >> np.uint32(16))
    return h


def hash_columns_np(columns, key_cols: Sequence[str]) -> np.ndarray:
    """Driver-side numpy mirror of ``hash_columns`` (bit-identical).

    Used by the out-of-core executor to sub-bucket host-spilled rows by key
    without a device round-trip; parity with the jnp version is what makes
    host buckets agree with device rank placement."""
    n = len(next(iter(columns.values())))
    h = np.full((n,), 0x9E3779B9, np.uint32)
    for name in key_cols:
        v = np.asarray(columns[name])
        if np.issubdtype(v.dtype, np.floating):
            bits = v.astype(np.float32).view(np.uint32)
        else:
            bits = v.astype(np.uint32)
        # same precedence as the jnp expression: ^ binds looser than +
        h = _mix32_np(h ^ (_mix32_np(bits) + np.uint32(0x9E3779B9)
                           + (h << np.uint32(6)) + (h >> np.uint32(2))))
    return h


# ---------------------------------------------------------------------- #
# Sort keys with invalid rows pushed to the end
# ---------------------------------------------------------------------- #


def _order_keys(table: Table, by: Sequence[str]) -> Tuple[jax.Array, ...]:
    """Key arrays for lexsort, with padding rows forced to sort last."""
    valid = table.valid_mask()
    keys = []
    for name in by:
        v = table.columns[name]
        keys.append(jnp.where(valid, v, _sentinel_for(v.dtype)))
    # jnp.lexsort sorts by the LAST key first; keep caller order = major first.
    return tuple(reversed(keys)) + (jnp.where(valid, 0, 1).astype(jnp.int32),)


def sort_local(table: Table, by: Sequence[str]) -> Table:
    """Stable multi-key sort of the valid prefix (padding stays at the end)."""
    keys = _order_keys(table, by)
    # validity flag is the most-major key so padding sorts last.
    order = jnp.lexsort(keys[:-1] + (keys[-1],))
    return table.take(order, table.row_count)


# ---------------------------------------------------------------------- #
# Filter / projection / elementwise
# ---------------------------------------------------------------------- #


def filter_rows(table: Table, pred: Callable[[Table], jax.Array]) -> Table:
    """Keep rows where ``pred`` is True; recompact."""
    keep = pred(table) & table.valid_mask()
    # stable compaction: order by (!keep)
    order = jnp.argsort(jnp.where(keep, 0, 1), stable=True)
    return table.take(order, jnp.sum(keep).astype(jnp.int32))


def filter_expr(table: Table, expr) -> Table:
    """Keep rows where the boolean ``repro.expr`` expression holds."""
    keep = jnp.asarray(expr.evaluate(table))
    if keep.dtype != jnp.bool_:
        raise TypeError(
            f"filter expression must be boolean, got {keep.dtype}: {expr!r}")
    keep = jnp.broadcast_to(keep, (table.capacity,)) & table.valid_mask()
    order = jnp.argsort(jnp.where(keep, 0, 1), stable=True)
    return table.take(order, jnp.sum(keep).astype(jnp.int32))


def with_columns(table: Table, exprs: Mapping[str, "object"]) -> Table:
    """Add/replace columns from ``{name: Expr}``; every expression reads
    the *input* table (simultaneous assignment).  Scalar results (pure
    literals) broadcast to full columns."""
    out = dict(table.columns)
    for name, e in exprs.items():
        v = jnp.asarray(e.evaluate(table))
        if v.ndim == 0:
            v = jnp.broadcast_to(v, (table.capacity,))
        out[name] = v
    return Table(out, table.row_count)


def recode(table: Table, mappings: Mapping[str, "np.ndarray"]) -> Table:
    """Remap dictionary codes: ``new = mapping[old]`` per recoded column.

    ``mappings`` maps column name -> static int32 gather table
    (``dataframe.schema.recode_mapping``), baked into the compiled program
    by the planner's ``recode`` node.  Padding rows gather garbage (their
    codes are not meaningful), exactly like every other operator here.
    """
    out = dict(table.columns)
    for name, mapping in mappings.items():
        m = jnp.asarray(np.asarray(mapping), jnp.int32)
        out[name] = jnp.take(m, table.columns[name], axis=0, mode="clip")
    return Table(out, table.row_count)


def add_scalar(table: Table, value, cols: Optional[Sequence[str]] = None) -> Table:
    """The paper's pipeline terminal op: add a scalar to value columns."""
    names = cols or table.column_names
    out = dict(table.columns)
    for n in names:
        out[n] = table.columns[n] + jnp.asarray(value, table.columns[n].dtype)
    return Table(out, table.row_count)


def map_columns(table: Table, fn: Callable[[jax.Array], jax.Array],
                cols: Sequence[str]) -> Table:
    out = dict(table.columns)
    for n in cols:
        out[n] = fn(table.columns[n])
    return Table(out, table.row_count)


# ---------------------------------------------------------------------- #
# Local groupby: sort + segment reduce
# ---------------------------------------------------------------------- #

_AGG_INIT = {
    "sum": lambda d: jnp.zeros((), d),
    "count": lambda d: jnp.zeros((), jnp.int32),
    "min": lambda d: _sentinel_for(d),
    "max": lambda d: (-_sentinel_for(d) if jnp.issubdtype(d, jnp.floating)
                      else jnp.asarray(jnp.iinfo(d).min, d)),
}


def groupby_local(table: Table, keys: Sequence[str],
                  aggs: Mapping[str, Sequence[str]]) -> Table:
    """Group by ``keys``; ``aggs`` maps value column -> list of agg names.

    Output columns: keys plus ``f"{col}_{agg}"``.  Mean is decomposed into
    sum+count by the distributed layer so partial aggregates compose.
    """
    sorted_t = sort_local(table, keys)
    valid = sorted_t.valid_mask()
    # segment ids: new segment where any key changes (within valid prefix)
    change = jnp.zeros((table.capacity,), bool)
    for name in keys:
        v = sorted_t.columns[name]
        change = change | jnp.concatenate([jnp.ones((1,), bool), v[1:] != v[:-1]])
    change = change & valid
    seg_ids = jnp.cumsum(change.astype(jnp.int32)) - 1  # 0-based, padding -> last
    seg_ids = jnp.where(valid, seg_ids, table.capacity - 1)
    num_groups = jnp.sum(change).astype(jnp.int32)

    out_cols: Dict[str, jax.Array] = {}
    cap = table.capacity
    for name in keys:
        v = sorted_t.columns[name]
        # first row of each segment carries the key
        out_cols[name] = jnp.zeros((cap,), v.dtype).at[seg_ids].set(
            jnp.where(valid, v, jnp.zeros((), v.dtype)), mode="drop")
    for col, agg_names in aggs.items():
        v = sorted_t.columns[col]
        for agg in agg_names:
            if agg == "sum":
                vv = jnp.where(valid, v, jnp.zeros((), v.dtype))
                r = jax.ops.segment_sum(vv, seg_ids, num_segments=cap)
            elif agg == "count":
                r = jax.ops.segment_sum(valid.astype(jnp.int32), seg_ids,
                                        num_segments=cap)
            elif agg == "min":
                vv = jnp.where(valid, v, _sentinel_for(v.dtype))
                r = jax.ops.segment_min(vv, seg_ids, num_segments=cap)
            elif agg == "max":
                lo = _AGG_INIT["max"](v.dtype)
                vv = jnp.where(valid, v, lo)
                r = jax.ops.segment_max(vv, seg_ids, num_segments=cap)
            else:
                raise ValueError(f"unsupported agg {agg!r}")
            out_cols[f"{col}_{agg}"] = r
    out = Table(out_cols, num_groups)
    return out.mask_padding()


# ---------------------------------------------------------------------- #
# Local join: sort-merge with bounded output capacity
# ---------------------------------------------------------------------- #


def join_local(left: Table, right: Table, on: str,
               out_capacity: Optional[int] = None,
               suffix: str = "_r", with_overflow: bool = False):
    """Inner equi-join via sort + searchsorted (vectorized merge).

    Output capacity is static: ``out_capacity`` (default: left.capacity).
    Row ``o`` of the output is derived by rank-searching the cumulative
    match counts — O(cap log cap), no data-dependent shapes.

    ``with_overflow=True`` additionally returns the number of result rows
    dropped by the static capacity (free here — the total match count is a
    byproduct of the merge — whereas ``join_overflow`` re-sorts both sides).
    """
    out_cap = out_capacity or left.capacity
    ls = sort_local(left, [on])
    rs = sort_local(right, [on])
    lvalid = ls.valid_mask()
    lkey = jnp.where(lvalid, ls.columns[on], _sentinel_for(ls.columns[on].dtype))
    rkey_raw = rs.columns[on]
    rvalid = rs.valid_mask()
    rkey = jnp.where(rvalid, rkey_raw, _sentinel_for(rkey_raw.dtype))

    # For each left row: range of matches in right.
    lo = jnp.searchsorted(rkey, lkey, side="left")
    hi = jnp.searchsorted(rkey, lkey, side="right")
    hi = jnp.minimum(hi, right.row_count)  # sentinel rows never match
    counts = jnp.where(lvalid, jnp.maximum(hi - lo, 0), 0)
    cum = jnp.cumsum(counts)
    total = cum[-1] if counts.shape[0] else jnp.asarray(0, jnp.int32)

    out_idx = jnp.arange(out_cap, dtype=jnp.int32)
    # left row owning output slot o: first l with cum[l] > o
    l_row = jnp.searchsorted(cum, out_idx, side="right")
    l_row_c = jnp.minimum(l_row, left.capacity - 1)
    start = jnp.where(l_row_c > 0, cum[l_row_c - 1], 0)
    k = out_idx - start
    r_row = jnp.minimum(lo[l_row_c] + k, right.capacity - 1)
    valid_out = out_idx < jnp.minimum(total, out_cap)

    cols: Dict[str, jax.Array] = {}
    for name in ls.column_names:
        cols[name] = jnp.take(ls.columns[name], l_row_c, axis=0)
    for name in rs.column_names:
        if name == on:
            continue
        tgt = name if name not in cols else name + suffix
        cols[tgt] = jnp.take(rs.columns[name], r_row, axis=0)
    out = Table(cols, jnp.minimum(total, out_cap).astype(jnp.int32))
    out = out.mask_padding()
    if with_overflow:
        return out, jnp.maximum(total - out_cap, 0).astype(jnp.int32)
    return out


def join_overflow(left: Table, right: Table, on: str, out_capacity: int) -> jax.Array:
    """Number of join result rows dropped by the static output capacity."""
    ls = sort_local(left, [on])
    rs = sort_local(right, [on])
    lvalid = ls.valid_mask()
    lkey = jnp.where(lvalid, ls.columns[on], _sentinel_for(ls.columns[on].dtype))
    rkey = jnp.where(rs.valid_mask(), rs.columns[on],
                     _sentinel_for(rs.columns[on].dtype))
    lo = jnp.searchsorted(rkey, lkey, side="left")
    hi = jnp.minimum(jnp.searchsorted(rkey, lkey, side="right"), rs.row_count)
    total = jnp.sum(jnp.where(lvalid, jnp.maximum(hi - lo, 0), 0))
    return jnp.maximum(total - out_capacity, 0)

"""Local (per-partition) DDF sub-operators.

These are the "core local operator" / "auxiliary local operators" of the
paper's sub-operator decomposition (§III-B, Fig 2).  All are pure jnp and
static-shape; the TPU adaptation replaces C++ hash tables with sort-based
vectorized algorithms (see DESIGN.md §2).  The compute hot spots have Pallas
kernel twins in ``repro.kernels`` selected via ``repro.dataframe.ops`` — the
jnp versions here double as their oracles.
"""

from __future__ import annotations

from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..nulls import mask_name
from .table import Table, _sentinel_for

# ---------------------------------------------------------------------- #
# Hashing (murmur3-style finalizer) — used for shuffle partitioning
# ---------------------------------------------------------------------- #


def _mix32(h: jax.Array) -> jax.Array:
    h = h.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def hash_columns(table: Table, key_cols: Sequence[str]) -> jax.Array:
    """Combined 32-bit hash of the key columns (row-wise).

    Dictionary-encoded string columns hash their int32 *codes* directly:
    the planner recodes join inputs onto a shared dictionary first
    (``planner.dictionary``), so equal strings always carry equal codes
    gang-wide and the hash placement stays consistent — no string-aware
    hashing is ever needed on device."""
    h = jnp.full((table.capacity,), 0x9E3779B9, jnp.uint32)
    for name in key_cols:
        v = table.columns[name]
        if jnp.issubdtype(v.dtype, jnp.floating):
            bits = jax.lax.bitcast_convert_type(v.astype(jnp.float32), jnp.uint32)
        else:
            bits = v.astype(jnp.uint32)
        h = _mix32(h ^ _mix32(bits) + jnp.uint32(0x9E3779B9) + (h << 6) + (h >> 2))
    return h


def _mix32_np(h: np.ndarray) -> np.ndarray:
    h = h.astype(np.uint32)
    h = h ^ (h >> np.uint32(16))
    h = h * np.uint32(0x85EBCA6B)
    h = h ^ (h >> np.uint32(13))
    h = h * np.uint32(0xC2B2AE35)
    h = h ^ (h >> np.uint32(16))
    return h


def hash_columns_np(columns, key_cols: Sequence[str]) -> np.ndarray:
    """Driver-side numpy mirror of ``hash_columns`` (bit-identical).

    Used by the out-of-core executor to sub-bucket host-spilled rows by key
    without a device round-trip; parity with the jnp version is what makes
    host buckets agree with device rank placement."""
    n = len(next(iter(columns.values())))
    h = np.full((n,), 0x9E3779B9, np.uint32)
    for name in key_cols:
        v = np.asarray(columns[name])
        if np.issubdtype(v.dtype, np.floating):
            bits = v.astype(np.float32).view(np.uint32)
        else:
            bits = v.astype(np.uint32)
        # same precedence as the jnp expression: ^ binds looser than +
        h = _mix32_np(h ^ (_mix32_np(bits) + np.uint32(0x9E3779B9)
                           + (h << np.uint32(6)) + (h >> np.uint32(2))))
    return h


# ---------------------------------------------------------------------- #
# Sort keys with invalid rows pushed to the end
# ---------------------------------------------------------------------- #


def _order_keys(table: Table, by: Sequence[str]) -> Tuple[jax.Array, ...]:
    """Key arrays for lexsort, with padding rows forced to sort last.

    Nullable sort columns contribute a null flag *more major* than their
    value key, so nulls sort last within each column (pandas
    ``na_position="last"``); ties among nulls resolve stably because null
    slots hold the canonical zero."""
    valid = table.valid_mask()
    keys = []
    # jnp.lexsort sorts by the LAST key first; build minor -> major.
    for name in reversed(by):
        v = table.columns[name]
        keys.append(jnp.where(valid, v, _sentinel_for(v.dtype)))
        m = table.columns.get(mask_name(name))
        if m is not None:
            keys.append(jnp.where(valid & ~m, 1, 0).astype(jnp.int32))
    return tuple(keys) + (jnp.where(valid, 0, 1).astype(jnp.int32),)


def sort_local(table: Table, by: Sequence[str]) -> Table:
    """Stable multi-key sort of the valid prefix (padding stays at the end)."""
    keys = _order_keys(table, by)
    # validity flag is the most-major key so padding sorts last.
    order = jnp.lexsort(keys[:-1] + (keys[-1],))
    return table.take(order, table.row_count)


def drop_null_keys(table: Table, keys: Sequence[str]) -> Table:
    """Drop rows whose value in any of ``keys`` is null, and retire the
    now-all-True key masks.  Pandas ``merge`` / ``groupby`` semantics: a
    null key never matches and never forms a group.  No-op (compiles to
    nothing) when no key carries a mask."""
    masks = [table.columns[m]
             for m in (mask_name(k) for k in keys) if m in table.columns]
    if not masks:
        return table
    keep = masks[0]
    for m in masks[1:]:
        keep = keep & m
    keep = keep & table.valid_mask()
    order = jnp.argsort(jnp.where(keep, 0, 1), stable=True)
    t = table.take(order, jnp.sum(keep).astype(jnp.int32))
    dead = {mask_name(k) for k in keys}
    return Table({n: v for n, v in t.columns.items() if n not in dead},
                 t.row_count).mask_padding()


# ---------------------------------------------------------------------- #
# Filter / projection / elementwise
# ---------------------------------------------------------------------- #


def filter_rows(table: Table, pred: Callable[[Table], jax.Array]) -> Table:
    """Keep rows where ``pred`` is True; recompact."""
    keep = pred(table) & table.valid_mask()
    # stable compaction: order by (!keep)
    order = jnp.argsort(jnp.where(keep, 0, 1), stable=True)
    return table.take(order, jnp.sum(keep).astype(jnp.int32))


def filter_expr(table: Table, expr) -> Table:
    """Keep rows where the boolean ``repro.expr`` expression holds.

    Three-valued semantics: a predicate that evaluates to null keeps
    nothing (SQL ``WHERE``) — the Kleene canonical-zero invariant already
    makes null predicate slots read False, and the validity conjunction
    below makes the intent explicit."""
    keep, pvalid = expr.evaluate_masked(table)
    keep = jnp.asarray(keep)
    if keep.dtype != jnp.bool_:
        raise TypeError(
            f"filter expression must be boolean, got {keep.dtype}: {expr!r}")
    keep = jnp.broadcast_to(keep, (table.capacity,))
    if pvalid is not None:
        keep = keep & jnp.broadcast_to(pvalid, (table.capacity,))
    keep = keep & table.valid_mask()
    order = jnp.argsort(jnp.where(keep, 0, 1), stable=True)
    return table.take(order, jnp.sum(keep).astype(jnp.int32))


def with_columns(table: Table, exprs: Mapping[str, "object"]) -> Table:
    """Add/replace columns from ``{name: Expr}``; every expression reads
    the *input* table (simultaneous assignment).  Scalar results (pure
    literals) broadcast to full columns.

    A nullable result materializes its validity mask as the companion
    ``__m_<name>`` column; a provably non-null result retires any stale
    mask the assignment overwrites (e.g. ``fillna``)."""
    out = dict(table.columns)
    for name, e in exprs.items():
        v, valid = e.evaluate_masked(table)
        v = jnp.asarray(v)
        if v.ndim == 0:
            v = jnp.broadcast_to(v, (table.capacity,))
        out[name] = v
        if valid is not None:
            out[mask_name(name)] = jnp.broadcast_to(
                valid, (table.capacity,))
        else:
            out.pop(mask_name(name), None)
    return Table(out, table.row_count)


def recode(table: Table, mappings: Mapping[str, "np.ndarray"]) -> Table:
    """Remap dictionary codes: ``new = mapping[old]`` per recoded column.

    ``mappings`` maps column name -> static int32 gather table
    (``dataframe.schema.recode_mapping``), baked into the compiled program
    by the planner's ``recode`` node.  Padding rows gather garbage (their
    codes are not meaningful), exactly like every other operator here.
    """
    out = dict(table.columns)
    for name, mapping in mappings.items():
        m = jnp.asarray(np.asarray(mapping), jnp.int32)
        out[name] = jnp.take(m, table.columns[name], axis=0, mode="clip")
    return Table(out, table.row_count)


def add_scalar(table: Table, value, cols: Optional[Sequence[str]] = None) -> Table:
    """The paper's pipeline terminal op: add a scalar to value columns."""
    names = cols or table.column_names
    out = dict(table.columns)
    for n in names:
        out[n] = table.columns[n] + jnp.asarray(value, table.columns[n].dtype)
    return Table(out, table.row_count)


def map_columns(table: Table, fn: Callable[[jax.Array], jax.Array],
                cols: Sequence[str]) -> Table:
    out = dict(table.columns)
    for n in cols:
        out[n] = fn(table.columns[n])
    return Table(out, table.row_count)


# ---------------------------------------------------------------------- #
# Local groupby: sort + segment reduce
# ---------------------------------------------------------------------- #

_AGG_INIT = {
    "sum": lambda d: jnp.zeros((), d),
    "count": lambda d: jnp.zeros((), jnp.int32),
    "size": lambda d: jnp.zeros((), jnp.int32),
    "min": lambda d: _sentinel_for(d),
    "max": lambda d: (-_sentinel_for(d) if jnp.issubdtype(d, jnp.floating)
                      else jnp.asarray(jnp.iinfo(d).min, d)),
}


def groupby_local(table: Table, keys: Sequence[str],
                  aggs: Mapping[str, Sequence[str]]) -> Table:
    """Group by ``keys``; ``aggs`` maps value column -> list of agg names.

    Output columns: keys plus ``f"{col}_{agg}"``.  Mean is decomposed into
    sum+count by the distributed layer so partial aggregates compose.

    Null semantics (pandas): rows with a null key are dropped; sum/count/
    min/max skip null values (``count`` counts non-null, ``size`` counts
    rows); min/max over an all-null group are null, so those outputs carry
    a ``__m_`` mask when their input does.  Because null value slots hold
    the column's sentinel-free canonical zero, the masked reductions below
    stay mergeable across morsels: an all-null partial emits its agg
    identity plus a False mask, and re-aggregating partials (whose masks
    make them nullable inputs) composes correctly.
    """
    table = drop_null_keys(table, keys)
    sorted_t = sort_local(table, keys)
    valid = sorted_t.valid_mask()
    # segment ids: new segment where any key changes (within valid prefix)
    change = jnp.zeros((table.capacity,), bool)
    for name in keys:
        v = sorted_t.columns[name]
        change = change | jnp.concatenate([jnp.ones((1,), bool), v[1:] != v[:-1]])
    change = change & valid
    seg_ids = jnp.cumsum(change.astype(jnp.int32)) - 1  # 0-based, padding -> last
    seg_ids = jnp.where(valid, seg_ids, table.capacity - 1)
    num_groups = jnp.sum(change).astype(jnp.int32)

    out_cols: Dict[str, jax.Array] = {}
    cap = table.capacity
    for name in keys:
        v = sorted_t.columns[name]
        # first row of each segment carries the key
        out_cols[name] = jnp.zeros((cap,), v.dtype).at[seg_ids].set(
            jnp.where(valid, v, jnp.zeros((), v.dtype)), mode="drop")
    for col, agg_names in aggs.items():
        v = sorted_t.columns[col]
        cmask = sorted_t.columns.get(mask_name(col))
        # effective = rows that contribute to null-skipping aggregates
        eff = valid if cmask is None else (valid & cmask)
        for agg in agg_names:
            out_mask = None
            if agg == "sum":
                vv = jnp.where(eff, v, jnp.zeros((), v.dtype))
                r = jax.ops.segment_sum(vv, seg_ids, num_segments=cap)
            elif agg == "count":
                r = jax.ops.segment_sum(eff.astype(jnp.int32), seg_ids,
                                        num_segments=cap)
            elif agg == "size":
                r = jax.ops.segment_sum(valid.astype(jnp.int32), seg_ids,
                                        num_segments=cap)
            elif agg == "min":
                vv = jnp.where(eff, v, _sentinel_for(v.dtype))
                r = jax.ops.segment_min(vv, seg_ids, num_segments=cap)
                if cmask is not None:
                    out_mask = jax.ops.segment_max(
                        eff.astype(jnp.int32), seg_ids,
                        num_segments=cap) > 0
            elif agg == "max":
                lo = _AGG_INIT["max"](v.dtype)
                vv = jnp.where(eff, v, lo)
                r = jax.ops.segment_max(vv, seg_ids, num_segments=cap)
                if cmask is not None:
                    out_mask = jax.ops.segment_max(
                        eff.astype(jnp.int32), seg_ids,
                        num_segments=cap) > 0
            else:
                raise ValueError(f"unsupported agg {agg!r}")
            if out_mask is not None:
                # canonical zero where the whole group was null
                r = jnp.where(out_mask, r, jnp.zeros((), r.dtype))
                out_cols[mask_name(f"{col}_{agg}")] = out_mask
            out_cols[f"{col}_{agg}"] = r
    out = Table(out_cols, num_groups)
    return out.mask_padding()


# ---------------------------------------------------------------------- #
# Local join: sort-merge with bounded output capacity
# ---------------------------------------------------------------------- #


def join_local(left: Table, right: Table, on: str,
               out_capacity: Optional[int] = None,
               suffix: str = "_r", with_overflow: bool = False):
    """Inner equi-join via sort + searchsorted (vectorized merge).

    Output capacity is static: ``out_capacity`` (default: left.capacity).
    Row ``o`` of the output is derived by rank-searching the cumulative
    match counts — O(cap log cap), no data-dependent shapes.

    ``with_overflow=True`` additionally returns the number of result rows
    dropped by the static capacity (free here — the total match count is a
    byproduct of the merge — whereas ``join_overflow`` re-sorts both sides).

    Null keys never match (pandas ``merge``): rows with a null ``on`` value
    are dropped from both sides first.  Nullable payload columns keep their
    masks; a right-side mask follows its base column through the collision
    suffix (``v`` -> ``v_r`` implies ``__m_v`` -> ``__m_v_r``).
    """
    out_cap = out_capacity or left.capacity
    left = drop_null_keys(left, [on])
    right = drop_null_keys(right, [on])
    ls = sort_local(left, [on])
    rs = sort_local(right, [on])
    lvalid = ls.valid_mask()
    lkey = jnp.where(lvalid, ls.columns[on], _sentinel_for(ls.columns[on].dtype))
    rkey_raw = rs.columns[on]
    rvalid = rs.valid_mask()
    rkey = jnp.where(rvalid, rkey_raw, _sentinel_for(rkey_raw.dtype))

    # For each left row: range of matches in right.
    lo = jnp.searchsorted(rkey, lkey, side="left")
    hi = jnp.searchsorted(rkey, lkey, side="right")
    hi = jnp.minimum(hi, right.row_count)  # sentinel rows never match
    counts = jnp.where(lvalid, jnp.maximum(hi - lo, 0), 0)
    cum = jnp.cumsum(counts)
    total = cum[-1] if counts.shape[0] else jnp.asarray(0, jnp.int32)

    out_idx = jnp.arange(out_cap, dtype=jnp.int32)
    # left row owning output slot o: first l with cum[l] > o
    l_row = jnp.searchsorted(cum, out_idx, side="right")
    l_row_c = jnp.minimum(l_row, left.capacity - 1)
    start = jnp.where(l_row_c > 0, cum[l_row_c - 1], 0)
    k = out_idx - start
    r_row = jnp.minimum(lo[l_row_c] + k, right.capacity - 1)
    valid_out = out_idx < jnp.minimum(total, out_cap)

    cols: Dict[str, jax.Array] = {}
    for name in ls.column_names:
        cols[name] = jnp.take(ls.columns[name], l_row_c, axis=0)
    for name in rs.column_names:
        if name == on or name.startswith(mask_name("")):
            continue
        tgt = name if name not in cols else name + suffix
        cols[tgt] = jnp.take(rs.columns[name], r_row, axis=0)
        rmask = rs.columns.get(mask_name(name))
        if rmask is not None:
            cols[mask_name(tgt)] = jnp.take(rmask, r_row, axis=0)
    out = Table(cols, jnp.minimum(total, out_cap).astype(jnp.int32))
    out = out.mask_padding()
    if with_overflow:
        return out, jnp.maximum(total - out_cap, 0).astype(jnp.int32)
    return out


def join_overflow(left: Table, right: Table, on: str, out_capacity: int) -> jax.Array:
    """Number of join result rows dropped by the static output capacity."""
    ls = sort_local(drop_null_keys(left, [on]), [on])
    rs = sort_local(drop_null_keys(right, [on]), [on])
    lvalid = ls.valid_mask()
    lkey = jnp.where(lvalid, ls.columns[on], _sentinel_for(ls.columns[on].dtype))
    rkey = jnp.where(rs.valid_mask(), rs.columns[on],
                     _sentinel_for(rs.columns[on].dtype))
    lo = jnp.searchsorted(rkey, lkey, side="left")
    hi = jnp.minimum(jnp.searchsorted(rkey, lkey, side="right"), rs.row_count)
    total = jnp.sum(jnp.where(lvalid, jnp.maximum(hi - lo, 0), 0))
    return jnp.maximum(total - out_capacity, 0)

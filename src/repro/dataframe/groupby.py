"""Distributed groupby: optional local pre-aggregation + shuffle + final agg.

The paper's groupby is shuffle-then-aggregate (map-reduce style).  We add a
*partial-aggregation pushdown* (classic distributed-DB optimization, and the
direction the paper's "coalescing" points at): aggregate locally first so the
shuffle moves one row per (rank, group) instead of one row per input row.
With 90%-cardinality data (the paper's worst case) pushdown barely helps; at
low cardinality it slashes the collective term — both regimes are measured in
``benchmarks/bench_strong_scaling.py``.
"""

from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp

from ..comm import Communicator
from .ops_local import groupby_local
from .shuffle import ShuffleStats, shuffle
from .table import Table

# agg -> (stage1 agg on raw col, stage2 agg on partial col, combiner name)
_DECOMP = {
    "sum": ("sum", "sum"),
    "count": ("count", "sum"),
    "min": ("min", "min"),
    "max": ("max", "max"),
}


def _normalize(aggs: Mapping[str, Sequence[str]]):
    """Expand mean into sum+count; return (physical aggs, post-processing)."""
    physical: Dict[str, List[str]] = {}
    post: List[Tuple[str, str, str]] = []  # (out_name, kind, col)
    for col, names in aggs.items():
        for a in names:
            if a == "mean":
                physical.setdefault(col, [])
                for b in ("sum", "count"):
                    if b not in physical[col]:
                        physical[col].append(b)
                post.append((f"{col}_mean", "mean", col))
            elif a in _DECOMP:
                physical.setdefault(col, [])
                if a not in physical[col]:
                    physical[col].append(a)
                post.append((f"{col}_{a}", "copy", f"{col}_{a}"))
            else:
                raise ValueError(f"unsupported agg {a!r}")
    return physical, post


def finalize_groupby(final: Table, keys: Sequence[str],
                     post: Sequence[Tuple[str, str, str]]) -> Table:
    """Post-processing (mean reconstruction) + column selection in user order."""
    out_cols = {k: final.columns[k] for k in keys}
    for out_name, kind, src in post:
        if kind == "copy":
            out_cols[out_name] = final.columns[src]
        else:  # mean
            s = final.columns[f"{src}_sum"]
            c = final.columns[f"{src}_count"]
            out_cols[out_name] = jnp.where(
                c > 0, s / jnp.maximum(c, 1).astype(s.dtype),
                jnp.zeros((), s.dtype))
    return Table(out_cols, final.row_count)


def _stage2_spec(physical: Mapping[str, Sequence[str]]):
    """Stage-2 agg spec over partial columns + the rename back to partial
    names (so stage-2 output composes with further stage-2 passes)."""
    stage2: Dict[str, List[str]] = {}
    rename: Dict[str, str] = {}
    for col, names in physical.items():
        for a in names:
            s2 = _DECOMP[a][1]
            stage2[f"{col}_{a}"] = [s2]
            rename[f"{col}_{a}_{s2}"] = f"{col}_{a}"
    return stage2, rename


def groupby(
    table: Table,
    comm: Communicator,
    keys: Sequence[str],
    aggs: Mapping[str, Sequence[str]],
    pre_aggregate: bool = True,
    **shuffle_kw,
) -> Tuple[Table, ShuffleStats]:
    """Distributed groupby over the comm axis (inside shard_map)."""
    physical, post = _normalize(aggs)

    if pre_aggregate:
        partial = groupby_local(table, keys, physical)
        # stage 2 operates on the partial columns
        stage2, rename = _stage2_spec(physical)
        shuffled, stats = shuffle(partial, comm, key_cols=list(keys), **shuffle_kw)
        final = groupby_local(shuffled, keys, stage2).rename(rename)
    else:
        shuffled, stats = shuffle(table, comm, key_cols=list(keys), **shuffle_kw)
        final = groupby_local(shuffled, keys, physical)

    return finalize_groupby(final, keys, post), stats


# ---------------------------------------------------------------------- #
# Out-of-core: per-morsel partials + rank-local cross-morsel combine
# ---------------------------------------------------------------------- #
def groupby_partial(
    table: Table,
    comm: Communicator,
    keys: Sequence[str],
    physical: Mapping[str, Sequence[str]],
    pre_aggregate: bool = False,
    elide_shuffle: bool = False,
    **shuffle_kw,
) -> Tuple[Table, Optional[ShuffleStats]]:
    """One morsel's contribution to a distributed groupby.

    Rows are placed on their final rank (``hash(keys) % p``) and aggregated
    into *mergeable* partial columns ``{col}_{agg}`` (mean stays sum+count;
    no finalization).  Because the hash placement is row-wise, partials for
    the same key land on the same rank in **every** morsel, so the
    cross-morsel combine (``combine_groupby_partials``) is rank-local — no
    further communication.
    """
    stage2, rename = _stage2_spec(physical)
    if elide_shuffle:
        # input already co-partitioned on the keys: local partial only
        return groupby_local(table, keys, physical), None
    if pre_aggregate:
        partial = groupby_local(table, keys, physical)
        shuffled, stats = shuffle(partial, comm, key_cols=list(keys),
                                  **shuffle_kw)
        return groupby_local(shuffled, keys, stage2).rename(rename), stats
    shuffled, stats = shuffle(table, comm, key_cols=list(keys), **shuffle_kw)
    return groupby_local(shuffled, keys, physical), stats


def combine_groupby_partials(
    partials: Table,
    keys: Sequence[str],
    physical: Mapping[str, Sequence[str]],
    post: Sequence[Tuple[str, str, str]],
) -> Table:
    """Cross-morsel combiner: re-aggregate mergeable partials + finalize.

    Purely local (runs per rank): the morsel layer guarantees every key's
    partials are co-resident.  Partial aggs compose under their stage-2
    combiner (sum of sums, min of mins, sum of counts), so this is exact
    for any morsel split of the input.
    """
    stage2, rename = _stage2_spec(physical)
    final = groupby_local(partials, keys, stage2).rename(rename)
    return finalize_groupby(final, keys, post)

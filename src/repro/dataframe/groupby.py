"""Distributed groupby: optional local pre-aggregation + shuffle + final agg.

The paper's groupby is shuffle-then-aggregate (map-reduce style).  We add a
*partial-aggregation pushdown* (classic distributed-DB optimization, and the
direction the paper's "coalescing" points at): aggregate locally first so the
shuffle moves one row per (rank, group) instead of one row per input row.
With 90%-cardinality data (the paper's worst case) pushdown barely helps; at
low cardinality it slashes the collective term — both regimes are measured in
``benchmarks/bench_strong_scaling.py``.
"""

from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp

from ..comm import Communicator
from ..nulls import mask_name
from .ops_local import drop_null_keys, groupby_local, hash_columns
from .shuffle import ShuffleStats, shuffle
from .table import Table

# agg -> (stage1 agg on raw col, stage2 agg on partial col, combiner name)
# ``count`` counts non-null values (pandas count); ``size`` counts rows.
_DECOMP = {
    "sum": ("sum", "sum"),
    "count": ("count", "sum"),
    "size": ("size", "sum"),
    "min": ("min", "min"),
    "max": ("max", "max"),
}


def _normalize(aggs: Mapping[str, Sequence[str]]):
    """Expand mean into sum+count; return (physical aggs, post-processing)."""
    physical: Dict[str, List[str]] = {}
    post: List[Tuple[str, str, str]] = []  # (out_name, kind, col)
    for col, names in aggs.items():
        for a in names:
            if a == "mean":
                physical.setdefault(col, [])
                for b in ("sum", "count"):
                    if b not in physical[col]:
                        physical[col].append(b)
                post.append((f"{col}_mean", "mean", col))
            elif a in _DECOMP:
                physical.setdefault(col, [])
                if a not in physical[col]:
                    physical[col].append(a)
                post.append((f"{col}_{a}", "copy", f"{col}_{a}"))
            else:
                raise ValueError(f"unsupported agg {a!r}")
    return physical, post


def nullable_agg_cols(table: Table,
                      physical: Mapping[str, Sequence[str]]) -> Tuple[str, ...]:
    """Aggregated columns that carry a validity mask in the *input* table.

    Finalization needs this (a group whose values are all null has
    ``count == 0`` and a null mean/min/max), and the partial tables alone
    cannot reveal it — sum/count partials carry no mask.
    """
    return tuple(sorted(c for c in physical
                        if mask_name(c) in table.columns))


def finalize_groupby(final: Table, keys: Sequence[str],
                     post: Sequence[Tuple[str, str, str]],
                     nullable_cols: Sequence[str] = ()) -> Table:
    """Post-processing (mean reconstruction) + column selection in user
    order.  ``nullable_cols`` names the aggregated input columns that were
    nullable: their mean outputs get a ``count > 0`` validity mask, and
    their min/max masks (computed by ``groupby_local``) are carried over."""
    nullable = set(nullable_cols)
    out_cols = {k: final.columns[k] for k in keys}
    for out_name, kind, src in post:
        if kind == "copy":
            out_cols[out_name] = final.columns[src]
            m = final.columns.get(mask_name(src))
            if m is not None:
                out_cols[mask_name(out_name)] = m
        else:  # mean
            s = final.columns[f"{src}_sum"]
            c = final.columns[f"{src}_count"]
            out_cols[out_name] = jnp.where(
                c > 0, s / jnp.maximum(c, 1).astype(s.dtype),
                jnp.zeros((), s.dtype))
            if src in nullable:
                out_cols[mask_name(out_name)] = c > 0
    return Table(out_cols, final.row_count)


def _stage2_spec(physical: Mapping[str, Sequence[str]]):
    """Stage-2 agg spec over partial columns + the rename back to partial
    names (so stage-2 output composes with further stage-2 passes).

    The rename also maps each partial's validity mask (present only for
    min/max of nullable columns); ``Table.rename`` ignores absent keys."""
    stage2: Dict[str, List[str]] = {}
    rename: Dict[str, str] = {}
    for col, names in physical.items():
        for a in names:
            s2 = _DECOMP[a][1]
            stage2[f"{col}_{a}"] = [s2]
            rename[f"{col}_{a}_{s2}"] = f"{col}_{a}"
            rename[mask_name(f"{col}_{a}_{s2}")] = mask_name(f"{col}_{a}")
    return stage2, rename


def groupby(
    table: Table,
    comm: Communicator,
    keys: Sequence[str],
    aggs: Mapping[str, Sequence[str]],
    pre_aggregate: bool = True,
    **shuffle_kw,
) -> Tuple[Table, ShuffleStats]:
    """Distributed groupby over the comm axis (inside shard_map)."""
    physical, post = _normalize(aggs)
    nullable = nullable_agg_cols(table, physical)
    table = drop_null_keys(table, keys)  # before the shuffle: less wire

    if pre_aggregate:
        partial = groupby_local(table, keys, physical)
        # stage 2 operates on the partial columns
        stage2, rename = _stage2_spec(physical)
        shuffled, stats = shuffle(partial, comm, key_cols=list(keys), **shuffle_kw)
        final = groupby_local(shuffled, keys, stage2).rename(rename)
    else:
        shuffled, stats = shuffle(table, comm, key_cols=list(keys), **shuffle_kw)
        final = groupby_local(shuffled, keys, physical)

    return finalize_groupby(final, keys, post, nullable), stats


# ---------------------------------------------------------------------- #
# Hot-key salting (repro.adapt): spread a hot key over k ranks, re-merge
# ---------------------------------------------------------------------- #
def salted_dest(table: Table, comm: Communicator, keys: Sequence[str],
                hot_hashes: Sequence[int], k: int):
    """Per-row destinations with hot keys spread over ``k`` ranks.

    Cold rows route to their hash home ``h % p`` exactly as an unsalted
    shuffle would; rows whose key hash is in ``hot_hashes`` (static
    constants baked by the decision layer) rotate over the ``k`` ranks
    following the home — the per-row ``arange % k`` salt is what spreads
    rows that all share one ``h``.  Returns ``(dest, is_hot)``.
    """
    p = comm.size()
    h = hash_columns(table, list(keys))
    base = (h % jnp.uint32(p)).astype(jnp.int32)
    hot = jnp.zeros((table.capacity,), jnp.bool_)
    for v in hot_hashes:
        hot = hot | (h == jnp.uint32(v))
    salt = jnp.arange(table.capacity, dtype=jnp.int32) % jnp.int32(max(k, 1))
    return jnp.where(hot, (base + salt) % p, base), hot


def groupby_salted(
    table: Table,
    comm: Communicator,
    keys: Sequence[str],
    aggs: Mapping[str, Sequence[str]],
    hot_hashes: Sequence[int],
    k: int,
    shuffle_kw: Optional[Mapping] = None,
    remerge_kw: Optional[Mapping] = None,
) -> Tuple[Table, ShuffleStats, ShuffleStats]:
    """Skew-mitigated distributed groupby (inside shard_map).

    Stage 1 shuffles rows by salted destination (a hot key's rows land on
    ``k`` ranks instead of one) and aggregates locally into mergeable
    partials; a second shuffle — tiny, one partial row per (rank, key) —
    re-merges each key's partials on its unsalted home rank, where stage 2
    combines them.  Exactly the pre-aggregation decomposition, so it is
    exact for every agg ``_DECOMP`` supports.  Returns
    ``(result, stage1 stats, re-merge stats)``.
    """
    physical, post = _normalize(aggs)
    nullable = nullable_agg_cols(table, physical)
    table = drop_null_keys(table, keys)
    dest, _ = salted_dest(table, comm, keys, hot_hashes, k)
    shuffled, st1 = shuffle(table, comm, dest=dest, **dict(shuffle_kw or {}))
    partial = groupby_local(shuffled, keys, physical)
    stage2, rename = _stage2_spec(physical)
    merged, st2 = shuffle(partial, comm, key_cols=list(keys),
                          **dict(remerge_kw or {}))
    final = groupby_local(merged, keys, stage2).rename(rename)
    return finalize_groupby(final, keys, post, nullable), st1, st2


# ---------------------------------------------------------------------- #
# Out-of-core: per-morsel partials + rank-local cross-morsel combine
# ---------------------------------------------------------------------- #
def groupby_partial(
    table: Table,
    comm: Communicator,
    keys: Sequence[str],
    physical: Mapping[str, Sequence[str]],
    pre_aggregate: bool = False,
    elide_shuffle: bool = False,
    salt: Optional[Tuple[Sequence[int], int]] = None,
    **shuffle_kw,
) -> Tuple[Table, Optional[ShuffleStats]]:
    """One morsel's contribution to a distributed groupby.

    Rows are placed on their final rank (``hash(keys) % p``) and aggregated
    into *mergeable* partial columns ``{col}_{agg}`` (mean stays sum+count;
    no finalization).  Because the hash placement is row-wise, partials for
    the same key land on the same rank in **every** morsel, so the
    cross-morsel combine (``combine_groupby_partials``) is rank-local — no
    further communication.

    ``salt=(hot_hashes, k)`` spreads hot keys over ``k`` ranks instead
    (``salted_dest``); the co-residency guarantee then holds only after
    the morsel driver host-re-routes the partial spill by ``hash % p``
    ahead of the combine.
    """
    stage2, rename = _stage2_spec(physical)
    table = drop_null_keys(table, keys)
    if elide_shuffle:
        # input already co-partitioned on the keys: local partial only
        return groupby_local(table, keys, physical), None
    if pre_aggregate:
        partial = groupby_local(table, keys, physical)
        shuffled, stats = shuffle(partial, comm, key_cols=list(keys),
                                  **shuffle_kw)
        return groupby_local(shuffled, keys, stage2).rename(rename), stats
    if salt is not None:
        hot_hashes, k = salt
        dest, _ = salted_dest(table, comm, keys, hot_hashes, k)
        shuffled, stats = shuffle(table, comm, dest=dest, **shuffle_kw)
    else:
        shuffled, stats = shuffle(table, comm, key_cols=list(keys),
                                  **shuffle_kw)
    return groupby_local(shuffled, keys, physical), stats


def combine_groupby_partials(
    partials: Table,
    keys: Sequence[str],
    physical: Mapping[str, Sequence[str]],
    post: Sequence[Tuple[str, str, str]],
    nullable_cols: Sequence[str] = (),
) -> Table:
    """Cross-morsel combiner: re-aggregate mergeable partials + finalize.

    Purely local (runs per rank): the morsel layer guarantees every key's
    partials are co-resident.  Partial aggs compose under their stage-2
    combiner (sum of sums, min of mins, sum of counts), so this is exact
    for any morsel split of the input.  ``nullable_cols`` (the *input*
    columns that carried masks — the caller knows, the partials don't)
    restores null mean/min/max for all-null groups at finalize.
    """
    stage2, rename = _stage2_spec(physical)
    final = groupby_local(partials, keys, stage2).rename(rename)
    return finalize_groupby(final, keys, post, nullable_cols)
